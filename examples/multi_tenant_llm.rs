//! END-TO-END DRIVER: serve real LLM inference (the AOT-compiled
//! JAX/Pallas model, executed via PJRT from Rust) for multiple concurrent
//! tenants under each virtualization backend, and report TTFT / ITL /
//! throughput per system.
//!
//! This is the proof that all three layers compose:
//!
//!   L1 Pallas attention kernel  ─┐ lowered once (make artifacts)
//!   L2 JAX decode-step model    ─┴→ artifacts/*.hlo.txt
//!   L3 this Rust binary: an engine thread owns the PJRT executables
//!      (PJRT handles are not Sync — the same single-owner design a
//!      serving router uses); tenant threads submit requests over a
//!      channel and measure TTFT/ITL including queueing; virtualization
//!      admission cost comes from the calibrated simulator.
//!
//! Request path: Rust only — python never runs here.
//!
//! ```bash
//! make artifacts && cargo run --release --example multi_tenant_llm
//! ```

use std::sync::mpsc;
use std::time::{Duration, Instant};

use gvb::coordinator::tenant::{run_tenants, throughput_per_tenant};
use gvb::metrics::RunConfig;
use gvb::runtime::Engine;
use gvb::stats::{jain_fairness, Summary};

const TENANTS: u32 = 4;
const REQUESTS_PER_TENANT: u64 = 8;
const DECODE_TOKENS: usize = 12;

/// A unit of work for the engine thread.
enum Job {
    Prefill(mpsc::SyncSender<()>),
    Decode(mpsc::SyncSender<()>),
    Shutdown,
}

/// Spawn the engine-owner thread: loads artifacts, then serves jobs
/// serially, sleeping `pace` per job for the backend's admission cost.
fn spawn_engine(pace: Duration) -> (mpsc::Sender<Job>, std::thread::JoinHandle<()>) {
    let (tx, rx) = mpsc::channel::<Job>();
    let handle = std::thread::spawn(move || {
        let engine = Engine::load_default().expect("run `make artifacts` first");
        let build_inputs = |name: &str| -> Vec<Vec<f32>> {
            engine
                .spec(name)
                .unwrap()
                .inputs
                .iter()
                .map(|t| (0..t.element_count()).map(|i| ((i % 97) as f32) * 0.01 - 0.5).collect())
                .collect()
        };
        let attn_inputs = build_inputs("attention_fp32");
        let decode_inputs = build_inputs("decode_step_fp32");
        while let Ok(job) = rx.recv() {
            match job {
                Job::Prefill(reply) => {
                    std::thread::sleep(pace);
                    engine.execute_f32("attention_fp32", &attn_inputs).expect("prefill");
                    let _ = reply.send(());
                }
                Job::Decode(reply) => {
                    std::thread::sleep(pace);
                    engine.execute_f32("decode_step_fp32", &decode_inputs).expect("decode");
                    let _ = reply.send(());
                }
                Job::Shutdown => break,
            }
        }
    });
    (tx, handle)
}

fn main() {
    // Fail fast with a clear message if artifacts are missing.
    if gvb::runtime::find_artifacts_dir().is_none() {
        eprintln!("artifacts/ not found — run `make artifacts` first");
        std::process::exit(1);
    }

    // Per-backend virtualization cost (simulated A100): measured once,
    // then applied as admission pacing on the real execution loop.
    println!("Calibrating per-backend launch/alloc overheads from the simulator...");
    let overheads: Vec<(String, f64)> = ["native", "hami", "fcsp", "mig"]
        .iter()
        .map(|sys| {
            let cfg = RunConfig::quick(sys);
            let launch = gvb::metrics::overhead::oh_001(&cfg).value; // µs
            let alloc = gvb::metrics::overhead::oh_002(&cfg).value; // µs
            // Per step: 1 launch + 2 KV-block alloc/frees equivalent.
            (sys.to_string(), (launch + 2.0 * alloc) * 1e3)
        })
        .collect();

    println!(
        "\n{:<8} {:>10} {:>10} {:>10} {:>12} {:>10}",
        "System", "pace µs", "TTFT ms", "ITL ms", "steps/s", "fairness"
    );
    println!("{}", "-".repeat(66));
    for (sys, pace_ns) in overheads {
        let (tx, handle) = spawn_engine(Duration::from_nanos(pace_ns as u64));
        let t_wall = Instant::now();
        let job_tx = tx.clone();
        let samples = run_tenants(TENANTS, REQUESTS_PER_TENANT, move |_tenant, _seq| {
            // Prefill.
            let (reply_tx, reply_rx) = mpsc::sync_channel(0);
            job_tx.send(Job::Prefill(reply_tx)).unwrap();
            reply_rx.recv().unwrap();
            // Decode loop.
            for _ in 0..DECODE_TOKENS {
                let (reply_tx, reply_rx) = mpsc::sync_channel(0);
                job_tx.send(Job::Decode(reply_tx)).unwrap();
                reply_rx.recv().unwrap();
            }
        });
        let wall_ns = t_wall.elapsed().as_nanos() as u64;
        tx.send(Job::Shutdown).unwrap();
        handle.join().unwrap();
        // Latency sample = one full request (prefill + decode); derive
        // TTFT/ITL proportions from the request structure.
        let req_ms: Vec<f64> = samples.iter().map(|s| s.latency_ns as f64 / 1e6).collect();
        let req = Summary::from_samples(&req_ms);
        let itl = req.mean / (DECODE_TOKENS as f64 + 1.0);
        let ttft = itl; // prefill ≈ one step at this model size
        let thr = throughput_per_tenant(&samples, wall_ns, TENANTS);
        let steps_per_s =
            samples.len() as f64 * (DECODE_TOKENS as f64 + 1.0) / (wall_ns as f64 / 1e9);
        println!(
            "{:<8} {:>10.1} {:>10.2} {:>10.2} {:>12.1} {:>10.3}",
            sys,
            pace_ns / 1e3,
            ttft,
            itl,
            steps_per_s,
            jain_fairness(&thr)
        );
    }
    println!("\nAll layers composed: JAX/Pallas artifacts executed from Rust via");
    println!("PJRT under concurrent tenant load, with virtualization pacing from");
    println!("the calibrated simulator. Recorded in EXPERIMENTS.md §E2E.");
}
