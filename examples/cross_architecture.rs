//! Cross-architecture check (paper §8.3 limitation: "evaluation was
//! conducted on A100; behavior may differ on other GPU architectures").
//!
//! Runs the core overhead/isolation measurements on both the A100-40GB
//! and H100-80GB device profiles to test whether the virtualization
//! rankings are architecture-stable — they are, because the interception
//! mechanisms are host-side and scale with API cost, not device FLOPs.
//!
//! ```bash
//! cargo run --release --example cross_architecture
//! ```

use gvb::benchkit::print_table;
use gvb::cudalite::Api;
use gvb::simgpu::kernel::KernelDesc;
use gvb::simgpu::{GpuDevice, GpuSpec};
use gvb::virt::{by_name, TenantConfig};

/// Launch + alloc/free costs for one backend on one device profile.
fn measure(spec: &GpuSpec, backend: &str) -> (f64, f64, f64) {
    let dev = GpuDevice::new(spec.clone(), 42);
    let virt = by_name(backend).unwrap();
    let mut api = Api::new(dev, virt);
    api.ctx_create(1, TenantConfig::unlimited().with_mem_limit(20 << 30)).unwrap();
    let kernel = KernelDesc::null();
    let reps = 100;
    let mut launch = 0.0;
    let mut alloc = 0.0;
    for _ in 0..reps {
        let t0 = api.now_ns();
        api.launch_kernel(1, 0, &kernel).unwrap();
        launch += (api.now_ns() - t0) as f64;
        api.sync_device(1).unwrap();
        let t0 = api.now_ns();
        let p = api.mem_alloc(1, 1 << 20).unwrap();
        alloc += (api.now_ns() - t0) as f64;
        api.mem_free(1, p).unwrap();
    }
    // A compute workload to expose the device-speed difference.
    let gemm = KernelDesc::gemm(4096, 4096, 4096, true);
    let t0 = api.now_ns();
    api.launch_kernel(1, 0, &gemm).unwrap();
    api.sync_device(1).unwrap();
    let gemm_us = (api.now_ns() - t0) as f64 / 1e3;
    (launch / reps as f64 / 1e3, alloc / reps as f64 / 1e3, gemm_us)
}

fn main() {
    let mut rows = Vec::new();
    for (gpu_name, spec) in [("A100-40GB", GpuSpec::a100_40gb()), ("H100-80GB", GpuSpec::h100_80gb())]
    {
        for backend in ["native", "hami", "fcsp"] {
            let (launch, alloc, gemm) = measure(&spec, backend);
            rows.push(vec![
                gpu_name.to_string(),
                backend.to_string(),
                format!("{launch:.1}"),
                format!("{alloc:.1}"),
                format!("{gemm:.0}"),
            ]);
        }
    }
    print_table(
        "Cross-architecture: virtualization overheads by device profile",
        &["GPU", "System", "Launch µs", "Alloc µs", "bf16 GEMM µs"],
        &rows,
    );
    // Stability check: the hami/native launch ratio on both devices.
    let ratio = |gpu: &str| -> f64 {
        let n: f64 = rows
            .iter()
            .find(|r| r[0] == gpu && r[1] == "native")
            .map(|r| r[2].parse().unwrap())
            .unwrap();
        let h: f64 = rows
            .iter()
            .find(|r| r[0] == gpu && r[1] == "hami")
            .map(|r| r[2].parse().unwrap())
            .unwrap();
        h / n
    };
    println!(
        "\nHAMi/native launch ratio: A100 {:.2}x vs H100 {:.2}x — the ranking",
        ratio("A100-40GB"),
        ratio("H100-80GB")
    );
    println!("is architecture-stable: interception costs are host-side and do");
    println!("not shrink with device FLOPs (if anything, faster devices make");
    println!("the fixed per-call overheads relatively worse).");
}
