//! Capacity planning: sweep tenant counts 2/4/6/8 per backend and report
//! how aggregate throughput, noisy-neighbour impact and fairness evolve —
//! the practitioner question the paper's §8.2 recommendations answer
//! ("how many tenants can I pack before isolation degrades?").
//!
//! ```bash
//! cargo run --release --example capacity_planning
//! ```

use gvb::benchkit::print_table;
use gvb::metrics::{isolation, overhead, RunConfig};

fn main() {
    let mut rows = Vec::new();
    for sys in ["hami", "fcsp", "mig"] {
        for tenants in [2u32, 4, 6] {
            let mut cfg = RunConfig::quick(sys);
            cfg.tenants = tenants;
            cfg.sm_limit = 1.0 / tenants as f64;
            cfg.mem_limit = (40u64 << 30) / tenants as u64;
            // MIG can't host 6 tenants above 1 slice each… it can: 6x1.
            let degradation = overhead::oh_010(&cfg).value;
            let noisy = isolation::is_009(&cfg).value;
            let fairness = isolation::is_008(&cfg).value;
            let sm_acc = isolation::is_003(&cfg).value;
            rows.push(vec![
                sys.to_string(),
                tenants.to_string(),
                format!("{degradation:.1}%"),
                format!("{noisy:.1}%"),
                format!("{fairness:.3}"),
                format!("{sm_acc:.1}%"),
            ]);
        }
    }
    print_table(
        "Capacity planning sweep (per-tenant limits = equal shares)",
        &["System", "Tenants", "Throughput loss", "Noisy-neighbor", "Fairness", "SM accuracy"],
        &rows,
    );
    println!("\nReading: pick the largest tenant count whose noisy-neighbor and");
    println!("fairness figures still meet your SLA; prefer FCSP over HAMi for");
    println!("LLM inference (paper §8.2), or MIG where geometry allows.");
}
