//! Isolation audit: the paper's Table 5 scenario as an operator tool —
//! run the full isolation category for a chosen tenant count / quota
//! configuration and print pass/fail + scores, like a pre-deployment gate.
//!
//! ```bash
//! cargo run --release --example isolation_audit -- hami 4
//! ```

use gvb::benchkit::print_table;
use gvb::coordinator::SuiteRunner;
use gvb::metrics::{Category, RunConfig};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let system = args.first().map(String::as_str).unwrap_or("hami").to_string();
    let tenants: u32 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    if gvb::virt::by_name(&system).is_none() {
        eprintln!("unknown system `{system}` (native|hami|fcsp|mig)");
        std::process::exit(2);
    }
    let mut cfg = RunConfig::quick(&system);
    cfg.tenants = tenants;
    cfg.sm_limit = 1.0 / tenants as f64;
    cfg.mem_limit = (40u64 << 30) / tenants as u64;
    println!("Isolation audit: system={system}, tenants={tenants}, quota={} GiB, sm_limit={:.2}", cfg.mem_limit >> 30, cfg.sm_limit);

    let mut runner =
        SuiteRunner::new(cfg).with_categories(vec![Category::Isolation]);
    let suite = runner.run(&system);
    let baseline = runner.baseline().to_vec();

    let mut rows = Vec::new();
    let mut failures = 0;
    for r in &suite.results {
        let d = gvb::metrics::taxonomy::by_id(r.id).unwrap();
        let score = suite
            .card
            .per_metric
            .iter()
            .find(|(id, _)| *id == r.id)
            .map(|(_, s)| *s)
            .unwrap_or(0.0);
        let expected = baseline.iter().find(|b| b.id == r.id).map(|b| b.value).unwrap_or(0.0);
        let verdict = match r.pass {
            Some(true) => "PASS".to_string(),
            Some(false) => {
                failures += 1;
                "FAIL".to_string()
            }
            None => {
                if score < 0.5 {
                    failures += 1;
                    "WARN".to_string()
                } else {
                    "ok".to_string()
                }
            }
        };
        rows.push(vec![
            r.id.to_string(),
            d.name.to_string(),
            format!("{:.3} {}", r.value, d.unit),
            format!("{expected:.3}"),
            format!("{score:.2}"),
            verdict,
        ]);
    }
    print_table(
        &format!("Isolation audit — {system} ({tenants} tenants)"),
        &["ID", "Metric", "Measured", "MIG baseline", "Score", "Verdict"],
        &rows,
    );
    println!(
        "\nCategory score: {:.1}%  ({failures} findings)",
        suite.card.per_category[&Category::Isolation] * 100.0
    );
    std::process::exit(if failures > 2 { 1 } else { 0 });
}
