//! Quickstart: run a few headline metrics for each virtualization backend
//! and print the comparison — the 60-second tour of the framework.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use gvb::benchkit::print_table;
use gvb::coordinator::SuiteRunner;
use gvb::metrics::RunConfig;

fn main() {
    let ids = ["OH-001", "OH-002", "OH-010", "IS-003", "IS-008", "LLM-004"];
    let mut runner = SuiteRunner::new(RunConfig::quick("native"))
        .with_metrics(ids.iter().map(|s| s.to_string()).collect());

    let systems = ["native", "hami", "fcsp", "mig"];
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut scores: Vec<(String, f64, String)> = Vec::new();
    let mut per_system = Vec::new();
    for sys in systems {
        let suite = runner.run(sys);
        scores.push((
            sys.to_string(),
            suite.card.mig_parity_percent(),
            suite.card.grade().letter().to_string(),
        ));
        per_system.push(suite);
    }
    for (i, id) in ids.iter().enumerate() {
        let d = gvb::metrics::taxonomy::by_id(id).unwrap();
        let mut row = vec![format!("{id} ({})", d.unit), d.name.to_string()];
        for suite in &per_system {
            row.push(format!("{:.2}", suite.results[i].value));
        }
        rows.push(row);
    }
    print_table(
        "GPU-Virt-Bench quickstart (A100-40GB simulation)",
        &["Metric", "Name", "native", "hami", "fcsp", "mig"],
        &rows,
    );
    println!("\nMIG-parity scores (spec-derived baseline):");
    for (sys, pct, grade) in scores {
        println!("  {sys:<8} {pct:>6.1}%  {grade}");
    }
    println!("\nNext: `gvbench run --all-systems --format txt` for all 56 metrics.");
}
