#!/usr/bin/env bash
# Arm (or re-arm) the CI regression gates from the gate jobs' uploaded
# artifacts — the scripted version of the manual flow in ci/README.md.
#
# Usage:
#   ci/arm_baselines.sh <artifacts-dir>
#
# <artifacts-dir> is a directory containing the downloaded artifacts of
# one CI run, e.g. as laid out by
#
#   gh run download <run-id> --dir artifacts
#
# which produces
#
#   artifacts/regression-baseline/fresh_quick.csv
#   artifacts/sweep-baseline/fresh_sweep.csv
#   artifacts/cluster-surface/fresh_cluster.csv
#
# (bare fresh_*.csv files directly inside <artifacts-dir> are accepted
# too). The script validates each snapshot — non-empty, expected header,
# data rows present — copies it over the committed ci/baseline_*.csv,
# and stages the result with `git add`; committing stays a human action
# so the accepted movement lands in the same commit as its explanation.
set -euo pipefail
cd "$(dirname "$0")/.."

if [ $# -ne 1 ]; then
  echo "usage: ci/arm_baselines.sh <artifacts-dir>" >&2
  exit 2
fi
artifacts=$1
if [ ! -d "$artifacts" ]; then
  echo "error: $artifacts is not a directory" >&2
  exit 2
fi

# Locate an artifact file: prefer the per-artifact subdirectory layout,
# fall back to a bare file in the artifacts dir.
find_artifact() {
  local artifact_dir=$1 file=$2
  for candidate in "$artifacts/$artifact_dir/$file" "$artifacts/$file"; do
    if [ -f "$candidate" ]; then
      echo "$candidate"
      return 0
    fi
  done
  return 1
}

# validate <file> <expected-first-header-field> — non-empty, sane header,
# at least one data row.
validate() {
  local file=$1 head_field=$2
  local header
  header=$(head -n 1 "$file")
  case "$header" in
    "$head_field"*) ;;
    *)
      echo "error: $file does not look like a baseline (header: $header)" >&2
      return 1
      ;;
  esac
  if [ "$(tail -n +2 "$file" | grep -c .)" -eq 0 ]; then
    echo "error: $file has no data rows" >&2
    return 1
  fi
}

armed=0
arm() {
  local artifact_dir=$1 file=$2 dest=$3 head_field=$4
  local src
  if ! src=$(find_artifact "$artifact_dir" "$file"); then
    echo "skip: $file not found under $artifacts (is the $artifact_dir artifact downloaded?)"
    return 0
  fi
  validate "$src" "$head_field"
  # The committed header must match the snapshot's: a mismatch means the
  # schema moved and the snapshot came from a stale build.
  if [ -f "$dest" ] && [ -s "$dest" ]; then
    if [ "$(head -n 1 "$src")" != "$(head -n 1 "$dest")" ]; then
      echo "error: $src header does not match committed $dest header (schema drift?)" >&2
      return 1
    fi
  fi
  cp "$src" "$dest"
  git add "$dest"
  echo "armed: $dest <- $src ($(tail -n +2 "$dest" | grep -c .) data rows)"
  armed=$((armed + 1))
}

arm regression-baseline fresh_quick.csv ci/baseline_quick.csv "id,"
arm sweep-baseline fresh_sweep.csv ci/baseline_sweep.csv "system,tenants,"
arm cluster-surface fresh_cluster.csv ci/baseline_cluster.csv "system,policy,"

if [ "$armed" -eq 0 ]; then
  echo "error: no baseline artifacts found under $artifacts" >&2
  exit 1
fi
echo
echo "$armed baseline(s) staged. Review the diff and commit:"
echo "  git diff --cached ci/"
echo "  git commit -m 'Arm CI regression baselines'"
