#!/usr/bin/env bash
# Arm (or re-arm) the CI regression gates — the scripted version of the
# flows in ci/README.md.
#
# Usage:
#   ci/arm_baselines.sh --generate [jobs]    # primary: regenerate locally
#   ci/arm_baselines.sh <artifacts-dir>      # fallback: from CI artifacts
#
# --generate builds the crate in release mode and runs the three exact
# deterministic grids the gate jobs re-run (pinned default seed 42,
# quick iteration counts), writing fresh snapshots into a temp dir; the
# optional [jobs] argument (default 4) only changes wall-clock, never
# the values — metric values are virtual-time simulation outputs,
# bit-identical across machines and job counts. This is the primary
# arming path: no CI round-trip needed.
#
# The artifacts-dir form covers the case where no local toolchain is
# available: point it at the downloaded artifacts of one CI run, e.g. as
# laid out by
#
#   gh run download <run-id> --dir artifacts
#
# which produces
#
#   artifacts/regression-baseline/fresh_quick.csv
#   artifacts/sweep-baseline/fresh_sweep.csv
#   artifacts/cluster-surface/fresh_cluster.csv
#
# (bare fresh_*.csv files directly inside <artifacts-dir> are accepted
# too). Either way the script validates each snapshot — non-empty,
# expected header, data rows present — copies it over the committed
# ci/baseline_*.csv, and stages the result with `git add`; committing
# stays a human action so the accepted movement lands in the same commit
# as its explanation.
set -euo pipefail
cd "$(dirname "$0")/.."

usage() {
  echo "usage: ci/arm_baselines.sh --generate [jobs] | ci/arm_baselines.sh <artifacts-dir>" >&2
  exit 2
}

[ $# -ge 1 ] || usage

artifacts=
if [ "$1" = "--generate" ]; then
  jobs=${2:-4}
  case "$jobs" in
    '' | *[!0-9]*) usage ;;
  esac
  if ! command -v cargo >/dev/null 2>&1; then
    echo "error: --generate needs a Rust toolchain (cargo not found); use the artifacts-dir form instead" >&2
    exit 1
  fi
  artifacts=$(mktemp -d)
  trap 'rm -rf "$artifacts"' EXIT
  echo "building gvbench (release)..."
  cargo build --release
  echo "regenerating the three gate snapshots (jobs=$jobs)..."
  # Exactly the gates' grids — see .github/workflows/ci.yml.
  ./target/release/gvbench run --all-systems --quick --jobs "$jobs" \
    --format csv --out "$artifacts/fresh_quick.csv"
  rm -f "$artifacts/fresh_quick.csv.timings.csv" # host timings; never committed
  ./target/release/gvbench sweep --quick --tenants 1,2 --quota 50,100 \
    --link nvlink,pcie --jobs "$jobs" --format csv --out "$artifacts/fresh_sweep.csv"
  ./target/release/gvbench cluster --policies first-fit,frag-gradient --nodes 2 \
    --scenario churn,failover --systems native,hami --jobs "$jobs" \
    --format csv --out /dev/null --summary-out "$artifacts/fresh_cluster.csv"
  # The dynamics goldens ride along: GVB_BLESS=1 rewrites
  # rust/tests/goldens/dynamics_{series,summary}.csv from the same
  # deterministic grid the test pins, so arming and blessing land in
  # one commit.
  echo "blessing dynamics goldens (GVB_BLESS=1)..."
  GVB_BLESS=1 cargo test -q --test dynamics_determinism
  for golden in rust/tests/goldens/dynamics_series.csv rust/tests/goldens/dynamics_summary.csv; do
    if [ -f "$golden" ]; then
      git add "$golden"
      echo "staged golden: $golden"
    fi
  done
else
  [ $# -eq 1 ] || usage
  artifacts=$1
  if [ ! -d "$artifacts" ]; then
    echo "error: $artifacts is not a directory" >&2
    exit 2
  fi
fi

# Locate a snapshot: prefer the per-artifact subdirectory layout, fall
# back to a bare file in the artifacts dir (also the --generate layout).
find_artifact() {
  local artifact_dir=$1 file=$2
  for candidate in "$artifacts/$artifact_dir/$file" "$artifacts/$file"; do
    if [ -f "$candidate" ]; then
      echo "$candidate"
      return 0
    fi
  done
  return 1
}

# Baselines may open with `#` comment lines (cluster summaries record
# `# arrivals=N`); the header is the first non-comment line and data
# rows are everything after it.
header_line() {
  grep -v '^#' "$1" | head -n 1
}
data_rows() {
  grep -v '^#' "$1" | tail -n +2 | grep -c . || true
}

# validate <file> <expected-first-header-field> — non-empty, sane header,
# at least one data row.
validate() {
  local file=$1 head_field=$2
  local header
  header=$(header_line "$file")
  case "$header" in
    "$head_field"*) ;;
    *)
      echo "error: $file does not look like a baseline (header: $header)" >&2
      return 1
      ;;
  esac
  if [ "$(data_rows "$file")" -eq 0 ]; then
    echo "error: $file has no data rows" >&2
    return 1
  fi
}

armed=0
arm() {
  local artifact_dir=$1 file=$2 dest=$3 head_field=$4
  local src
  if ! src=$(find_artifact "$artifact_dir" "$file"); then
    echo "skip: $file not found under $artifacts (is the $artifact_dir artifact downloaded?)"
    return 0
  fi
  validate "$src" "$head_field"
  # The committed header must match the snapshot's: a mismatch means the
  # schema moved and the snapshot came from a stale build.
  if [ -f "$dest" ] && [ -s "$dest" ]; then
    if [ "$(header_line "$src")" != "$(header_line "$dest")" ]; then
      echo "error: $src header does not match committed $dest header (schema drift?)" >&2
      return 1
    fi
  fi
  cp "$src" "$dest"
  git add "$dest"
  echo "armed: $dest <- $src ($(data_rows "$dest") data rows)"
  armed=$((armed + 1))
}

arm regression-baseline fresh_quick.csv ci/baseline_quick.csv "id,"
arm sweep-baseline fresh_sweep.csv ci/baseline_sweep.csv "system,tenants,"
arm cluster-surface fresh_cluster.csv ci/baseline_cluster.csv "system,policy,"

if [ "$armed" -eq 0 ]; then
  echo "error: no baseline snapshots found under $artifacts" >&2
  exit 1
fi
echo
echo "$armed baseline(s) staged. Review the diff and commit:"
echo "  git diff --cached ci/"
echo "  git commit -m 'Arm CI regression baselines'"
