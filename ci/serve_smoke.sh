#!/usr/bin/env bash
# Serve-smoke gate: end-to-end check of the benchmark service against a
# real daemon process (the in-process half lives in
# rust/tests/serve_determinism.rs).
#
#   1. Renders one-shot CLI references for all four grid schemas (CSV —
#      the render with no host timings).
#   2. Boots `gvbench serve` in the background and submits one job per
#      schema through `gvbench submit` (plus a `--trace` replay of the
#      committed ci/trace_mixed.txt fixture); every served report must
#      be byte-identical to its one-shot reference.
#   3. Submits a serve-backed regress gate against the fresh run CSV —
#      a warm-daemon replay of the same cells must pass against itself.
#   4. Asserts the streamed NDJSON lifecycle is well-formed (queued →
#      scheduled → … → report → finished, no failed events) and carries
#      the idle-time accounting fields.
#   5. Queries the daemon's telemetry (`gvbench jobs --stats` /
#      `--stats-format prometheus`): the counters must match the
#      submitted batch and the Prometheus render must be well-formed
#      text exposition format.
#   6. Drains the daemon with `gvbench jobs --shutdown` and verifies a
#      clean exit: status 0, socket file removed, no orphaned process.
#
# The full event trace is left in serve_trace.log (plus jobs_list.txt,
# stats_table.txt, stats_prom.txt and serve_regress_report.json) for the
# `serve-trace` CI artifact.
set -euo pipefail
cd "$(dirname "$0")/.."

GVB=./target/release/gvbench
if [ ! -x "$GVB" ]; then
  echo "error: $GVB not found; run 'cargo build --release' first" >&2
  exit 1
fi

work=$(mktemp -d)
sock="$work/gvbench.sock"
trace=serve_trace.log
: >"$trace"

serve_pid=
cleanup() {
  if [ -n "$serve_pid" ] && kill -0 "$serve_pid" 2>/dev/null; then
    kill "$serve_pid" 2>/dev/null || true
    wait "$serve_pid" 2>/dev/null || true
  fi
  rm -rf "$work"
}
trap cleanup EXIT

fail() {
  echo "::error::$1"
  exit 1
}

echo "== one-shot references (jobs flag only changes wall-clock) =="
$GVB run --all-systems --quick --jobs 2 --format csv --out "$work/oneshot_run.csv"
rm -f "$work/oneshot_run.csv.timings.csv" # host timings; not part of the report
$GVB sweep --quick --tenants 1,2 --quota 50,100 --jobs 2 \
  --format csv --out "$work/oneshot_sweep.csv"
$GVB dynamics --scenario steady,failover --systems native,hami \
  --duration-ms 400 --window-ms 50 --jobs 2 --format csv --out "$work/oneshot_dynamics.csv"
$GVB cluster --policies first-fit --nodes 2 --scenario churn --systems native,hami \
  --jobs 2 --format csv --out "$work/oneshot_cluster.csv"
$GVB dynamics --trace ci/trace_mixed.txt --systems native,hami \
  --jobs 2 --format csv --out "$work/oneshot_trace.csv"

echo "== boot daemon =="
$GVB serve --socket "$sock" --jobs 2 2>>"$trace" &
serve_pid=$!
for _ in $(seq 1 100); do
  [ -S "$sock" ] && break
  kill -0 "$serve_pid" 2>/dev/null || fail "daemon exited before binding its socket"
  sleep 0.1
done
[ -S "$sock" ] || fail "daemon socket never appeared at $sock"

echo "== served jobs: one per schema, byte-compared to one-shot =="
$GVB submit --socket "$sock" --out "$work/served_run.csv" \
  -- run --all-systems --quick --format csv 2>>"$trace"
$GVB submit --socket "$sock" --out "$work/served_sweep.csv" \
  -- sweep --quick --tenants 1,2 --quota 50,100 --format csv 2>>"$trace"
$GVB submit --socket "$sock" --out "$work/served_dynamics.csv" \
  -- dynamics --scenario steady,failover --systems native,hami \
  --duration-ms 400 --window-ms 50 --format csv 2>>"$trace"
$GVB submit --socket "$sock" --out "$work/served_cluster.csv" \
  -- cluster --policies first-fit --nodes 2 --scenario churn --systems native,hami \
  --format csv 2>>"$trace"
# Trace replay through the daemon: the file is read daemon-side (like
# --baseline), so the served report must match the one-shot replay.
$GVB submit --socket "$sock" --out "$work/served_trace.csv" \
  -- dynamics --trace ci/trace_mixed.txt --systems native,hami \
  --format csv 2>>"$trace"
for schema in run sweep dynamics cluster trace; do
  cmp "$work/oneshot_$schema.csv" "$work/served_$schema.csv" ||
    fail "served $schema report is not byte-identical to the one-shot CLI output"
  echo "served $schema == one-shot $schema"
done

echo "== serve-backed regress gate (warm pool, against the fresh run CSV) =="
$GVB submit --socket "$sock" --out serve_regress_report.json \
  -- regress --baseline "$work/oneshot_run.csv" --quick --threshold 5 2>>"$trace"
grep -q '"passed": true' serve_regress_report.json ||
  fail "serve-backed regress did not pass against its own baseline"

echo "== lifecycle stream well-formedness =="
for marker in '"event": "queued"' '"event": "scheduled"' '"event": "task_completed"' \
  '"event": "report"' '"event": "finished"'; do
  grep -qF "$marker" "$trace" || fail "trace has no $marker event"
done
for field in '"queue_wait_ms"' '"scheduler_idle_ms"' '"worker_idle_ms"' '"busy_ms"'; do
  grep -qF "$field" "$trace" || fail "trace lacks the $field idle-accounting field"
done
if grep -qF '"event": "failed"' "$trace"; then
  fail "a served job failed (see serve_trace.log)"
fi
finished=$(grep -cF '"event": "finished"' "$trace")
[ "$finished" -eq 6 ] || fail "expected 6 finished events, found $finished"
# Per-job ordering: job 1's stream must read queued, scheduled, ...,
# report, finished (task completions in between may land in any order).
sequence=$(grep -F '"job": 1,' "$trace" | grep -oE '"event": "[a-z_]+"' |
  sed 's/"event": "\([a-z_]*\)"/\1/' | tr '\n' ' ')
case "$sequence" in
"queued scheduled "*"report finished ") echo "job 1 lifecycle: $sequence" ;;
*) fail "job 1 lifecycle out of order: $sequence" ;;
esac

echo "== jobs listing =="
$GVB jobs --socket "$sock" | tee jobs_list.txt
listed=$(grep -c 'finished' jobs_list.txt || true)
[ "$listed" -eq 6 ] || fail "jobs listing shows $listed finished jobs, expected 6"

echo "== daemon telemetry (stats op) =="
$GVB jobs --socket "$sock" --stats | tee stats_table.txt
grep -qE '^jobs finished +6$' stats_table.txt ||
  fail "stats table does not show 6 finished jobs"
grep -qE '^jobs failed +0$' stats_table.txt ||
  fail "stats table shows failed jobs"
grep -qE '^jobs submitted +6$' stats_table.txt ||
  fail "stats table does not show 6 submitted jobs"
$GVB jobs --socket "$sock" --stats-format prometheus | tee stats_prom.txt
# Exposition-format shape: counters present with the expected values,
# histogram buckets cumulative and terminated by +Inf == _count.
grep -qx 'gvbench_jobs_submitted_total 6' stats_prom.txt ||
  fail "prometheus output lacks gvbench_jobs_submitted_total 6"
grep -qx 'gvbench_jobs{state="finished"} 6' stats_prom.txt ||
  fail "prometheus output lacks 6 finished jobs"
grep -qx 'gvbench_queue_wait_ms_count 6' stats_prom.txt ||
  fail "prometheus output lacks 6 queue-wait samples"
grep -qx 'gvbench_queue_wait_ms_bucket{le="+Inf"} 6' stats_prom.txt ||
  fail "queue-wait buckets do not end at +Inf == _count"
grep -q '# TYPE gvbench_queue_wait_ms histogram' stats_prom.txt ||
  fail "prometheus output lacks histogram TYPE lines"
# Every non-comment line must be `name[{labels}] value` with a numeric value.
if grep -vE '^(#|[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? -?[0-9.eE+-]+$)' stats_prom.txt |
  grep -q .; then
  fail "prometheus output has a malformed exposition line"
fi

echo "== clean shutdown =="
$GVB jobs --socket "$sock" --shutdown 2>>"$trace"
for _ in $(seq 1 100); do
  kill -0 "$serve_pid" 2>/dev/null || break
  sleep 0.1
done
if kill -0 "$serve_pid" 2>/dev/null; then
  fail "daemon still running after shutdown request"
fi
wait "$serve_pid" || fail "daemon exited non-zero"
serve_pid=
[ ! -e "$sock" ] || fail "socket file survived shutdown"
if command -v pgrep >/dev/null 2>&1; then
  if pgrep -f "gvbench serve" >/dev/null 2>&1; then
    fail "orphaned gvbench serve process after shutdown"
  fi
fi

# Markdown summary for the gate-report step-summary publishing.
{
  echo "## Serve smoke — benchmark service round-trip"
  echo ""
  echo "| check | result |"
  echo "| --- | --- |"
  echo "| served run/sweep/dynamics/cluster vs one-shot CLI | byte-identical |"
  echo "| served trace replay (ci/trace_mixed.txt) vs one-shot | byte-identical |"
  echo "| serve-backed regress vs fresh run CSV | passed |"
  echo "| lifecycle stream (queued → scheduled → … → finished) | well-formed, idle fields present |"
  echo "| daemon telemetry (stats op, table + prometheus) | counters match the batch |"
  echo "| drain + shutdown | exit 0, socket removed |"
  echo ""
  echo '```'
  cat jobs_list.txt
  echo '```'
} >serve_summary.md

echo "serve smoke passed: 6 served jobs, all byte-identical / gated, clean shutdown"
