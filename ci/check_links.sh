#!/usr/bin/env bash
# Existence check over the relative markdown links in the documentation
# surface (README.md, docs/*.md, ci/README.md). External links
# (http/https/mailto) and pure-anchor links (#section) are skipped;
# `path#anchor` links are checked for the path part only. Paths resolve
# relative to the linking file first, then to the repository root.
#
# Run from anywhere: the script cd's to the repository root (its parent
# directory). CI runs it as the blocking `docs` job; locally:
#
#   bash ci/check_links.sh
set -euo pipefail
cd "$(dirname "$0")/.."

fail=0
for f in README.md docs/*.md ci/README.md; do
  [ -e "$f" ] || continue
  dir=$(dirname "$f")
  # Inline links: the (target) part of [text](target).
  while IFS= read -r target; do
    case "$target" in
      http://* | https://* | mailto:* | "#"*) continue ;;
    esac
    path="${target%%#*}"
    [ -z "$path" ] && continue
    if [ ! -e "$dir/$path" ] && [ ! -e "$path" ]; then
      echo "::error file=$f::dangling relative link: ($target)"
      fail=1
    fi
  done < <(grep -oE '\]\([^)]+\)' "$f" | sed -E 's/^\]\((.*)\)$/\1/')
done

if [ "$fail" -eq 0 ]; then
  echo "all relative markdown links resolve"
fi
exit "$fail"
