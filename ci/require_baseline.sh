#!/usr/bin/env bash
# Fail with a ::error annotation when a committed gate baseline has no
# data rows yet — the shared "Require a committed baseline" step of the
# regress/sweep/cluster gate jobs (arming flow in ci/README.md).
#
# Usage: ci/require_baseline.sh <baseline-csv> <artifact-name> <fresh-name>
#
#   <baseline-csv>   committed baseline, e.g. ci/baseline_quick.csv
#   <artifact-name>  the gate's artifact carrying the fresh snapshot
#   <fresh-name>     the snapshot file inside that artifact
set -euo pipefail

if [ $# -ne 3 ]; then
  echo "usage: ci/require_baseline.sh <baseline-csv> <artifact-name> <fresh-name>" >&2
  exit 2
fi
baseline=$1
artifact=$2
fresh=$3

if [ ! -f "$baseline" ]; then
  echo "::error::$baseline does not exist"
  exit 1
fi

# Data rows = everything after the header, excluding `#` comment lines
# (cluster summaries open with a `# arrivals=N` recording comment).
data_rows() {
  grep -v '^#' "$1" | tail -n +2 | grep -c . || true
}

if [ "$(data_rows "$baseline")" -eq 0 ]; then
  echo "::error::$baseline has no data rows yet. Arm it locally with ci/arm_baselines.sh --generate (or download this run's $artifact artifact and commit its $fresh as $baseline). See ci/README.md."
  exit 1
fi
echo "$baseline is armed ($(data_rows "$baseline") data rows)"
