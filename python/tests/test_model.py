"""L2 correctness: decode step vs reference; shape/lowering checks."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels.ref import decode_step_ref


def params(batch, ctx, d_model, seed=0):
    rng = np.random.default_rng(seed)

    def t(*shape):
        return jnp.asarray(rng.standard_normal(shape) * 0.05, jnp.float32)

    return (
        t(batch, d_model),
        t(d_model, 3 * d_model),
        t(d_model, d_model),
        t(d_model, 4 * d_model),
        t(4 * d_model, d_model),
        t(batch, ctx - 1, d_model),
        t(batch, ctx - 1, d_model),
    )


@pytest.mark.parametrize("batch,ctx,d_model", [(1, 128, 64), (4, 128, 256)])
def test_decode_step_matches_ref(batch, ctx, d_model):
    args = params(batch, ctx, d_model)
    out, k_new, v_new = model.decode_step(*args)
    ref_out, ref_k, ref_v = decode_step_ref(*args)
    np.testing.assert_allclose(out, ref_out, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(k_new, ref_k, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(v_new, ref_v, atol=1e-5, rtol=1e-5)


def test_decode_step_shapes():
    args = params(2, 128, 64)
    out, k_new, v_new = model.decode_step(*args)
    assert out.shape == (2, 64)
    assert k_new.shape == (2, 64)
    assert v_new.shape == (2, 64)


def test_make_decode_fn_lowers():
    fn, specs = model.make_decode_fn(1, 128, 64)
    lowered = jax.jit(fn).lower(*specs)
    hlo = lowered.compiler_ir("stablehlo")
    assert "stablehlo" in str(hlo)


def test_prefill_attention_shape():
    q = jnp.zeros((2, 256, 64), jnp.float32)
    out = model.prefill_attention(q, q, q)
    assert out.shape == (2, 256, 64)
    # Zero queries and keys: softmax uniform; zero values → zero output.
    assert bool(jnp.all(out == 0))


def test_decode_autoregressive_consistency():
    # Two sequential decode steps through the model equal the reference's.
    batch, ctx, d_model = 1, 128, 64
    args = list(params(batch, ctx, d_model))
    out1, k1, v1 = model.decode_step(*args)
    # Append and step again (drop oldest to keep static length).
    args2 = list(args)
    args2[0] = out1
    args2[5] = jnp.concatenate([args[5][:, 1:], k1[:, None, :]], axis=1)
    args2[6] = jnp.concatenate([args[6][:, 1:], v1[:, None, :]], axis=1)
    out2, _, _ = model.decode_step(*args2)
    r1, rk1, rv1 = decode_step_ref(*args)
    rargs2 = list(args)
    rargs2[0] = r1
    rargs2[5] = jnp.concatenate([args[5][:, 1:], rk1[:, None, :]], axis=1)
    rargs2[6] = jnp.concatenate([args[6][:, 1:], rv1[:, None, :]], axis=1)
    r2, _, _ = decode_step_ref(*rargs2)
    np.testing.assert_allclose(out2, r2, atol=5e-4, rtol=5e-4)
