"""AOT path checks: every variant lowers to HLO text and the manifest is
well-formed (the Rust runtime's parser contract)."""

import re

import jax

from compile import aot


def test_all_variants_lower():
    for name, fn, specs in aot.variants():
        lowered = jax.jit(fn).lower(*specs)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_manifest_lines_parse():
    pat = re.compile(
        r"^name=\w+ file=[\w.]+ inputs=((f32|i32)\[[\d,]*\];?)+ outputs=\d+$"
    )
    for name, fn, specs in aot.variants():
        n_out = len(jax.eval_shape(fn, *specs))
        inputs = ";".join(aot.spec_str(s) for s in specs)
        line = f"name={name} file={name}.hlo.txt inputs={inputs} outputs={n_out}"
        assert pat.match(line), line


def test_spec_str():
    s = jax.ShapeDtypeStruct((4, 256, 64), "float32")
    assert aot.spec_str(s) == "f32[4,256,64]"


def test_decode_variant_outputs_three():
    _, fn, specs = next(v for v in aot.variants() if v[0] == "decode_step_fp32")
    assert len(jax.eval_shape(fn, *specs)) == 3
