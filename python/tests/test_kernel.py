"""L1 correctness: Pallas attention kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes and dtypes; assert_allclose against ref.py is the
core correctness signal for the whole three-layer stack.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels.attention import attention
from compile.kernels.ref import attention_ref


def rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


@pytest.mark.parametrize("batch", [1, 2, 4])
@pytest.mark.parametrize("seq", [128, 256])
@pytest.mark.parametrize("d", [32, 64])
def test_attention_matches_ref_fp32(batch, seq, d):
    q = rand((batch, seq, d), jnp.float32, 1)
    k = rand((batch, seq, d), jnp.float32, 2)
    v = rand((batch, seq, d), jnp.float32, 3)
    out = attention(q, k, v)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_attention_bf16_tolerance():
    q = rand((2, 256, 64), jnp.bfloat16, 4)
    k = rand((2, 256, 64), jnp.bfloat16, 5)
    v = rand((2, 256, 64), jnp.bfloat16, 6)
    out = attention(q, k, v).astype(jnp.float32)
    ref = attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    )
    np.testing.assert_allclose(out, ref, atol=2e-2, rtol=2e-2)


def test_attention_block_shapes_equivalent():
    q = rand((1, 512, 64), jnp.float32, 7)
    k = rand((1, 512, 64), jnp.float32, 8)
    v = rand((1, 512, 64), jnp.float32, 9)
    a = attention(q, k, v, block_q=128, block_k=128)
    b = attention(q, k, v, block_q=64, block_k=256)
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


def test_attention_rejects_indivisible_seq():
    q = rand((1, 100, 32), jnp.float32, 10)
    with pytest.raises(ValueError):
        attention(q, q, q, block_q=128, block_k=128)


def test_attention_rows_sum_property():
    # With v = all-ones, softmax mixing must return exactly ones.
    q = rand((2, 128, 32), jnp.float32, 11)
    k = rand((2, 128, 32), jnp.float32, 12)
    v = jnp.ones((2, 128, 32), jnp.float32)
    out = attention(q, k, v)
    np.testing.assert_allclose(out, np.ones_like(out), atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    batch=st.integers(1, 3),
    seq_pow=st.integers(6, 9),  # 64..512
    d=st.sampled_from([16, 32, 64, 128]),
    scale=st.floats(0.05, 2.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_hypothesis_sweep(batch, seq_pow, d, scale, seed):
    seq = 1 << seq_pow
    q = rand((batch, seq, d), jnp.float32, seed) * scale
    k = rand((batch, seq, d), jnp.float32, seed + 1)
    v = rand((batch, seq, d), jnp.float32, seed + 2)
    bq = min(128, seq)
    out = attention(q, k, v, block_q=bq, block_k=bq, sm_scale=scale)
    ref = attention_ref(q, k, v, sm_scale=scale)
    np.testing.assert_allclose(out, ref, atol=5e-5, rtol=5e-5)


@settings(max_examples=10, deadline=None)
@given(shift=st.floats(-30.0, 30.0))
def test_attention_online_softmax_stable_under_shift(shift):
    # Online softmax must be invariant to large score magnitudes.
    q = rand((1, 128, 32), jnp.float32, 21) + shift
    k = rand((1, 128, 32), jnp.float32, 22)
    v = rand((1, 128, 32), jnp.float32, 23)
    out = attention(q, k, v)
    assert bool(jnp.all(jnp.isfinite(out)))
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(out, ref, atol=5e-5, rtol=5e-5)
