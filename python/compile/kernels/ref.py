"""Pure-jnp oracle for the Pallas kernels — the CORE correctness signal.

Every Pallas kernel in this package must match its reference here to
numerical tolerance across shapes and dtypes (pytest + hypothesis sweeps
in ``python/tests/test_kernel.py``).
"""

import jax.numpy as jnp


def attention_ref(q, k, v, sm_scale=None):
    """Single-head attention ``softmax(q k^T * scale) v``.

    Args:
      q, k, v: ``(batch, seq, d)``.
      sm_scale: defaults to ``1/sqrt(d)``.
    """
    d = q.shape[-1]
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    s = jnp.einsum(
        "bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * sm_scale
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_step_ref(x, w_qkv, w_out, w_mlp_in, w_mlp_out, k_cache, v_cache):
    """Reference for the L2 decode step (see ``model.py`` for the layout).

    Shapes:
      x:         (batch, d_model)       — current-token activations
      w_qkv:     (d_model, 3*d_model)
      w_out:     (d_model, d_model)
      w_mlp_in:  (d_model, 4*d_model)
      w_mlp_out: (4*d_model, d_model)
      k_cache, v_cache: (batch, ctx, d_model) — prior context (static len)

    Returns (out, k_new, v_new): the next activations and this step's K/V
    rows to append to the cache.
    """
    qkv = x @ w_qkv
    q, k_new, v_new = jnp.split(qkv, 3, axis=-1)
    k = jnp.concatenate([k_cache, k_new[:, None, :]], axis=1)
    v = jnp.concatenate([v_cache, v_new[:, None, :]], axis=1)
    attn = attention_ref(q[:, None, :], k, v)[:, 0, :]
    h = x + attn @ w_out
    mlp = jnp.maximum(h @ w_mlp_in, 0.0) @ w_mlp_out
    return h + mlp, k_new, v_new
