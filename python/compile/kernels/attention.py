"""Layer 1: Pallas attention kernel (online-softmax / flash-style).

The paper's LLM benchmarks (§5.3, Listing 6) use a custom CUDA attention
kernel (`softmax(QK^T/sqrt(d)) V`). This is the TPU re-think of that
kernel, per the hardware-adaptation rule:

- CUDA shared-memory tiles        -> VMEM blocks staged via ``BlockSpec``
- threadblock (q-tile, k-tile)    -> grid over (batch, q-blocks); the
  K/V sweep is an in-kernel ``fori_loop`` carrying online-softmax state
- WMMA/tensor-core fragments      -> MXU contractions (``jnp.dot`` on
  (block_q, d) x (d, block_k) tiles)
- warp-level softmax reductions   -> VPU row reductions over the tile

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the same program runs
under the Rust PJRT client. Real-TPU performance is *estimated* in
DESIGN.md §8 from the VMEM footprint and MXU utilization.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, sm_scale: float):
    """One (batch, q-block) program: sweep K/V blocks with online softmax.

    Refs hold VMEM tiles:
      q_ref: (block_q, d)   — this program's query tile
      k_ref: (S, d)         — full keys for the batch element
      v_ref: (S, d)         — full values
      o_ref: (block_q, d)   — output tile
    """
    q = q_ref[...].astype(jnp.float32) * sm_scale
    seq_len = k_ref.shape[0]
    block_q, d = q.shape
    num_kb = seq_len // block_k

    def body(kb, carry):
        m_prev, l_prev, acc = carry
        k_tile = k_ref[pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        v_tile = v_ref[pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        # MXU: (block_q, d) @ (d, block_k).
        s = q @ k_tile.T
        # Online softmax (VPU): update running max and normalizer.
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        # MXU: (block_q, block_k) @ (block_k, d).
        acc_new = acc * alpha[:, None] + p @ v_tile
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, d), dtype=jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, num_kb, body, (m0, l0, acc0))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_k", "sm_scale", "interpret")
)
def attention(
    q,
    k,
    v,
    *,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    sm_scale: float | None = None,
    interpret: bool = True,
):
    """Single-head attention ``softmax(q k^T / sqrt(d)) v`` via Pallas.

    Args:
      q, k, v: ``(batch, seq, d)`` arrays (same shape; fp32 or bf16).
      block_q/block_k: VMEM tile sizes; must divide ``seq``.
      sm_scale: softmax scale; defaults to ``1/sqrt(d)``.
      interpret: keep True off-TPU (see module docstring).

    Returns:
      ``(batch, seq, d)`` attention output in the dtype of ``q``.
    """
    batch, seq, d = q.shape
    if seq % block_q or seq % block_k:
        raise ValueError(f"seq={seq} must be divisible by block_q/block_k")
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    bq = min(block_q, seq)
    kernel = functools.partial(
        _attention_kernel, block_k=min(block_k, seq), sm_scale=sm_scale
    )
    grid = (batch, seq // bq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, seq, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, seq, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
