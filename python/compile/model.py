"""Layer 2: the JAX model — a transformer decode step and prefill
attention, calling the Layer-1 Pallas kernel.

These are the compute graphs the paper's LLM benchmarks exercise
(attention throughput, TTFT/ITL, batch scaling). They are lowered ONCE by
``aot.py`` to HLO text; the Rust coordinator loads and executes them via
PJRT on its request path. Python never runs at serving time.
"""

import jax
import jax.numpy as jnp

from .kernels.attention import attention


def prefill_attention(q, k, v):
    """Prefill-phase attention over the whole prompt (Pallas kernel).

    q, k, v: (batch, seq, d).
    """
    return attention(q, k, v)


def decode_step(x, w_qkv, w_out, w_mlp_in, w_mlp_out, k_cache, v_cache):
    """One decode step of a single transformer block.

    Fused QKV projection → append K/V to the (static-length) cache →
    single-query attention over the context via the Pallas kernel → output
    projection + residual → ReLU MLP + residual.

    The attention call pads the single query to a kernel-friendly tile and
    slices the first row back out, so the same Pallas kernel serves both
    prefill and decode — one code path, two phases, like a production
    serving stack.

    Shapes: see ``kernels.ref.decode_step_ref`` (the oracle).
    Returns (out, k_new, v_new).
    """
    batch, d_model = x.shape
    qkv = x @ w_qkv
    q, k_new, v_new = jnp.split(qkv, 3, axis=-1)
    k = jnp.concatenate([k_cache, k_new[:, None, :]], axis=1)
    v = jnp.concatenate([v_cache, v_new[:, None, :]], axis=1)
    ctx = k.shape[1]
    # Pad the single query to a block the kernel tiles cleanly (the padded
    # rows attend to the same keys; we discard them after).
    block = min(ctx, 128)
    q_pad = jnp.broadcast_to(q[:, None, :], (batch, block, d_model))
    attn = attention(q_pad, k, v, block_q=block, block_k=block)[:, 0, :]
    h = x + attn @ w_out
    mlp = jnp.maximum(h @ w_mlp_in, 0.0) @ w_mlp_out
    return h + mlp, k_new, v_new


def make_decode_fn(batch: int, ctx: int, d_model: int, dtype=jnp.float32):
    """Build the decode-step function and its example arguments for AOT
    lowering (`ctx` must be a multiple of 128, or < 128)."""
    specs = [
        jax.ShapeDtypeStruct((batch, d_model), dtype),             # x
        jax.ShapeDtypeStruct((d_model, 3 * d_model), dtype),       # w_qkv
        jax.ShapeDtypeStruct((d_model, d_model), dtype),           # w_out
        jax.ShapeDtypeStruct((d_model, 4 * d_model), dtype),       # w_mlp_in
        jax.ShapeDtypeStruct((4 * d_model, d_model), dtype),       # w_mlp_out
        jax.ShapeDtypeStruct((batch, ctx - 1, d_model), dtype),    # k_cache
        jax.ShapeDtypeStruct((batch, ctx - 1, d_model), dtype),    # v_cache
    ]

    def fn(*args):
        return decode_step(*args)  # tuple of 3 outputs

    return fn, specs


def make_attention_fn(batch: int, seq: int, d: int, dtype=jnp.float32):
    """Build the prefill attention function + example args for AOT."""
    spec = jax.ShapeDtypeStruct((batch, seq, d), dtype)

    def fn(q, k, v):
        return (prefill_attention(q, k, v),)

    return fn, [spec, spec, spec]
