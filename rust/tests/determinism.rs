//! The executor's determinism guarantee, proven end-to-end: the full
//! 56-metric suite for all 4 systems produces **bit-identical**
//! `MetricResult`s and identical `ScoreCard` totals at `jobs=1` and
//! `jobs=8`, regardless of worker interleaving (per-task seed derivation
//! makes every task a pure function of the run seed and its coordinates).

use gvb::coordinator::SuiteRunner;
use gvb::metrics::{MetricResult, RunConfig};
use gvb::virt::ALL_SYSTEMS;

fn assert_bit_identical(system: &str, a: &MetricResult, b: &MetricResult) {
    assert_eq!(a.id, b.id, "{system}: metric order diverged");
    assert_eq!(a.system, b.system, "{system}/{}", a.id);
    assert_eq!(
        a.value.to_bits(),
        b.value.to_bits(),
        "{system}/{}: value {} vs {}",
        a.id,
        a.value,
        b.value
    );
    assert_eq!(a.pass, b.pass, "{system}/{}", a.id);
    assert_eq!(a.summary.count, b.summary.count, "{system}/{}", a.id);
    for (name, x, y) in [
        ("mean", a.summary.mean, b.summary.mean),
        ("stddev", a.summary.stddev, b.summary.stddev),
        ("min", a.summary.min, b.summary.min),
        ("max", a.summary.max, b.summary.max),
        ("median", a.summary.median, b.summary.median),
        ("p95", a.summary.p95, b.summary.p95),
        ("p99", a.summary.p99, b.summary.p99),
        ("cv", a.summary.cv, b.summary.cv),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{system}/{}: summary.{name}", a.id);
    }
}

#[test]
fn full_suite_bit_identical_at_any_job_count() {
    let mut serial = SuiteRunner::new(RunConfig::quick("native")).with_jobs(1);
    let mut sharded = SuiteRunner::new(RunConfig::quick("native")).with_jobs(8);
    for system in ALL_SYSTEMS {
        let a = serial.run(system);
        let b = sharded.run(system);
        assert_eq!(a.results.len(), 56, "{system}: all 56 metrics must run");
        assert_eq!(a.results.len(), b.results.len(), "{system}");
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_bit_identical(system, x, y);
        }
        // ScoreCard totals are identical too.
        assert_eq!(
            a.card.overall.to_bits(),
            b.card.overall.to_bits(),
            "{system}: overall {} vs {}",
            a.card.overall,
            b.card.overall
        );
        assert_eq!(a.card.per_metric.len(), b.card.per_metric.len(), "{system}");
        for ((id_a, s_a), (id_b, s_b)) in a.card.per_metric.iter().zip(&b.card.per_metric) {
            assert_eq!(id_a, id_b, "{system}");
            assert_eq!(s_a.to_bits(), s_b.to_bits(), "{system}/{id_a}: score");
        }
        for (cat, s_a) in &a.card.per_category {
            let s_b = b.card.per_category[cat];
            assert_eq!(s_a.to_bits(), s_b.to_bits(), "{system}/{:?}: category score", cat);
        }
        // Executor actually sharded: jobs recorded as requested.
        assert_eq!(a.stats.jobs, 1);
        assert_eq!(b.stats.jobs, 8);
        assert_eq!(b.stats.tasks.len(), 56);
    }
}
