//! Integration tests: full suite runs, report round-trips, determinism
//! and failure injection across module boundaries.

use gvb::coordinator::SuiteRunner;
use gvb::metrics::{registry, Category, RunConfig};
use gvb::report::{Format, Report};

#[test]
fn full_quick_suite_all_systems_and_report_roundtrip() {
    let mut runner = SuiteRunner::new(RunConfig::quick("native"));
    for sys in ["native", "hami", "fcsp", "mig"] {
        let suite = runner.run(sys);
        assert_eq!(suite.results.len(), 56, "{sys}: all 56 metrics must run");
        let baseline = runner.baseline().to_vec();
        let rep = Report::new(sys, &suite.results, &baseline, &suite.card);
        let json = rep.render(Format::Json);
        // Every metric id appears in every format.
        for r in &suite.results {
            assert!(json.contains(r.id), "{sys}: {} missing from JSON", r.id);
        }
        let csv = rep.render(Format::Csv);
        assert_eq!(csv.lines().count(), 57, "{sys}: csv rows");
        let txt = rep.render(Format::Txt);
        assert!(txt.contains("Grade:"));
        // Score sanity.
        assert!(suite.card.overall > 0.3 && suite.card.overall <= 1.0, "{sys}");
    }
}

#[test]
fn table7_ordering_holds() {
    let mut runner = SuiteRunner::new(RunConfig::quick("native"));
    let mig = runner.run("mig").card.overall;
    let fcsp = runner.run("fcsp").card.overall;
    let hami = runner.run("hami").card.overall;
    // Paper Table 7 ordering: MIG > FCSP > HAMi, with HAMi in the C band
    // and a clear FCSP lead.
    assert!(mig > fcsp && fcsp > hami, "mig={mig} fcsp={fcsp} hami={hami}");
    assert!(mig > 0.95, "mig={mig}");
    assert!(fcsp - hami > 0.03, "fcsp={fcsp} hami={hami}");
    assert!((0.60..0.85).contains(&hami), "hami={hami}");
}

#[test]
fn suite_is_deterministic_under_seed() {
    let run = |seed: u64| -> Vec<f64> {
        let mut cfg = RunConfig::quick("hami");
        cfg.seed = seed;
        registry::run_category(Category::Overhead, &cfg).iter().map(|r| r.value).collect()
    };
    assert_eq!(run(1234), run(1234));
    assert_ne!(run(1234), run(4321));
}

#[test]
fn single_metric_runs_for_every_id() {
    let cfg = RunConfig::quick("fcsp");
    for d in &gvb::metrics::taxonomy::ALL {
        let r = registry::run_metric(d.id, &cfg)
            .unwrap_or_else(|| panic!("{} not in registry", d.id));
        assert!(r.value.is_finite(), "{} produced non-finite value", d.id);
    }
}

#[test]
fn config_file_flows_into_runner() {
    let text = "system = fcsp\niterations = 10\nwarmup = 2\ntenants = 2\nseed = 9\n";
    let cfg = gvb::config::FileConfig::parse(text)
        .unwrap()
        .apply(RunConfig::default())
        .unwrap();
    assert_eq!(cfg.system, "fcsp");
    let mut runner = SuiteRunner::new(cfg).with_metrics(vec!["OH-009".into()]);
    let suite = runner.run("fcsp");
    assert_eq!(suite.results.len(), 1);
}

#[test]
fn failure_injection_does_not_poison_subsequent_runs() {
    use gvb::cudalite::Api;
    use gvb::simgpu::error::GpuFault;
    use gvb::virt::TenantConfig;
    let mut api = Api::with_backend("fcsp", 3);
    api.ctx_create(1, TenantConfig::unlimited()).unwrap();
    api.inject_fault(1, GpuFault::EccUncorrectable);
    api.dev.clock.advance(10_000_000);
    assert!(api.launch_kernel(1, 0, &gvb::simgpu::kernel::KernelDesc::null()).is_err());
    api.device_reset();
    api.ctx_create(1, TenantConfig::unlimited()).unwrap();
    assert!(api.launch_kernel(1, 0, &gvb::simgpu::kernel::KernelDesc::null()).is_ok());
}
