//! Golden snapshot tests for the report writers: a small fixed suite
//! (synthetic, hand-checkable values — scores 0.5 / 1.0 / 0.8) rendered to
//! JSON and CSV and compared byte-for-byte against checked-in golden
//! files. Any change to field ordering, number formatting or escaping
//! shows up as a diff here.
//!
//! To regenerate after an *intentional* format change:
//! `GVB_BLESS=1 cargo test -q --test golden_reports`

use std::path::PathBuf;

use gvb::metrics::MetricResult;
use gvb::report::{Format, Report};
use gvb::scoring::ScoreCard;

fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/goldens").join(name)
}

/// The fixed miniature suite: one lower-better, one boolean, one
/// higher-better metric, with values chosen so every derived number
/// (scores 0.5/1.0/0.8, deviations -100/0/-20 %, overall 0.331/0.42) is
/// exactly representable in the renderers' rounding.
fn sample() -> (Vec<MetricResult>, Vec<MetricResult>) {
    let results = vec![
        MetricResult::from_value("OH-001", "hami", 10.0),
        MetricResult::from_pass("IS-005", "hami", true),
        MetricResult::from_value("PCIE-001", "hami", 20.0),
    ];
    let baseline = vec![
        MetricResult::from_value("OH-001", "mig-ideal-spec", 5.0),
        MetricResult::from_pass("IS-005", "mig-ideal-spec", true),
        MetricResult::from_value("PCIE-001", "mig-ideal-spec", 25.0),
    ];
    (results, baseline)
}

fn render(format: Format) -> String {
    let (results, baseline) = sample();
    let card = ScoreCard::build("hami", &results, &baseline);
    Report::new("hami", &results, &baseline, &card).render(format)
}

fn check_golden(name: &str, rendered: &str) {
    let path = golden_path(name);
    if std::env::var("GVB_BLESS").is_ok() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, format!("{}\n", rendered.trim_end())).unwrap();
        return;
    }
    let golden = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("missing golden {} ({e}); run with GVB_BLESS=1", path.display()));
    let got = normalize_version(rendered.trim_end());
    let want = normalize_version(golden.trim_end());
    assert_eq!(got, want, "golden mismatch for {name} — if intentional, re-bless with GVB_BLESS=1");
}

/// Mask the `benchmark_version` field's value (whatever it is, on both the
/// rendered and the golden side) so a crate version bump alone doesn't
/// churn the golden. Inputs without the field pass through untouched.
fn normalize_version(s: &str) -> String {
    const KEY: &str = "\"benchmark_version\": \"";
    if let Some(start) = s.find(KEY) {
        let vstart = start + KEY.len();
        if let Some(vlen) = s[vstart..].find('"') {
            let version = s[vstart..vstart + vlen].to_string();
            return s.replace(&version, "{VERSION}");
        }
    }
    s.to_string()
}

#[test]
fn json_report_matches_golden() {
    check_golden("report.json", &render(Format::Json));
}

#[test]
fn csv_report_matches_golden() {
    check_golden("report.csv", &render(Format::Csv));
}

#[test]
fn sample_card_is_hand_checkable() {
    // Guard the premise of the goldens: the synthetic scores stay exact.
    let (results, baseline) = sample();
    let card = ScoreCard::build("hami", &results, &baseline);
    assert_eq!(card.per_metric, vec![("OH-001", 0.5), ("IS-005", 1.0), ("PCIE-001", 0.8)]);
    assert!((card.overall - 0.331 / 0.42).abs() < 1e-12, "overall={}", card.overall);
    assert_eq!(card.grade().letter(), "C");
}
