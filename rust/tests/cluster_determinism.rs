//! The cluster subsystem's determinism guarantee, proven end-to-end: the
//! same fleet grid and seed produce a **bit-identical** placement surface
//! at `--jobs 1` and `--jobs 8` (per-cell seeds are pure functions of the
//! run seed and the (system, policy, nodes, scenario) coordinates), the
//! rendered CSV surfaces — which carry no host timings — match
//! byte-for-byte, and the summary CSV round-trips through the regression
//! engine with a clean pass against itself at both job counts. A crafted
//! workload also separates first-fit from frag-gradient, so the policy
//! axis is provably not a no-op.

use gvb::cluster::{self, run_cluster, ClusterSpec, ClusterSurface, Demand, Fleet};
use gvb::metrics::RunConfig;
use gvb::report::cluster::{render_csv, render_summary_csv};

fn spec() -> ClusterSpec {
    ClusterSpec {
        systems: vec!["native".into(), "hami".into()],
        policies: vec!["first-fit", "frag-gradient"],
        node_counts: vec![2],
        scenarios: vec!["churn", "failover"],
        // The regression engine replays cluster baselines at the default
        // arrival count, so the round-trip test below needs it too.
        arrivals: cluster::DEFAULT_ARRIVALS,
    }
}

fn base() -> RunConfig {
    let mut cfg = RunConfig::quick("native");
    cfg.seed = 42;
    cfg
}

fn assert_surfaces_bit_identical(a: &ClusterSurface, b: &ClusterSurface) {
    assert_eq!(a.seed, b.seed);
    assert_eq!(a.arrivals, b.arrivals);
    assert_eq!(a.runs.len(), b.runs.len());
    for (x, y) in a.runs.iter().zip(&b.runs) {
        let ctx = format!("{}/{}@{}n/{}", x.system, x.policy, x.nodes, x.scenario);
        assert_eq!(x.system, y.system, "{ctx}: run order diverged");
        assert_eq!(x.policy, y.policy, "{ctx}: run order diverged");
        assert_eq!(x.nodes, y.nodes, "{ctx}: run order diverged");
        assert_eq!(x.scenario, y.scenario, "{ctx}: run order diverged");
        assert_eq!(x.arrivals, y.arrivals, "{ctx}");
        assert_eq!(x.placed, y.placed, "{ctx}");
        assert_eq!(x.migrations, y.migrations, "{ctx}");
        assert_eq!(x.evictions, y.evictions, "{ctx}");
        assert_eq!(x.node_stats.len(), y.node_stats.len(), "{ctx}");
        for (i, (p, q)) in x.node_stats.iter().zip(&y.node_stats).enumerate() {
            assert_eq!(p.mem_used, q.mem_used, "{ctx} node {i}");
            assert_eq!(p.sm_used.to_bits(), q.sm_used.to_bits(), "{ctx} node {i}");
            assert_eq!(p.tenants, q.tenants, "{ctx} node {i}");
            assert_eq!(p.alive, q.alive, "{ctx} node {i}");
        }
        assert_eq!(x.summary.len(), y.summary.len(), "{ctx}");
        for ((ia, va), (ib, vb)) in x.summary.iter().zip(&y.summary) {
            assert_eq!(ia, ib, "{ctx}: summary order");
            assert_eq!(va.to_bits(), vb.to_bits(), "{ctx}/{ia}: {va} vs {vb}");
        }
    }
}

#[test]
fn cluster_surface_bit_identical_at_any_job_count() {
    let base = base();
    let serial = run_cluster(&base, &spec(), 1);
    let sharded = run_cluster(&base, &spec(), 8);
    assert_eq!(serial.stats.jobs, 1);
    assert_eq!(sharded.stats.jobs, 8);
    // 2 systems × 2 policies × 1 node count × 2 scenarios.
    assert_eq!(serial.runs.len(), 8);
    assert_eq!(serial.stats.tasks.len(), 8);
    assert_surfaces_bit_identical(&serial, &sharded);
    // The rendered surfaces (no host timings) match byte-for-byte.
    assert_eq!(render_csv(&serial), render_csv(&sharded));
    assert_eq!(render_summary_csv(&serial), render_summary_csv(&sharded));
}

#[test]
fn cluster_is_a_pure_function_of_the_seed() {
    let a = run_cluster(&base(), &spec(), 4);
    let b = run_cluster(&base(), &spec(), 4);
    assert_surfaces_bit_identical(&a, &b);
    let mut other = base();
    other.seed = 43;
    let c = run_cluster(&other, &spec(), 4);
    assert!(
        a.runs.iter().zip(&c.runs).any(|(x, y)| {
            x.summary
                .iter()
                .zip(&y.summary)
                .any(|((_, va), (_, vb))| va.to_bits() != vb.to_bits())
        }),
        "seed change did not affect the surface"
    );
}

/// Policy-disagreement smoke: on a hand-built two-node fleet, first-fit
/// greedily co-locates a small SM-light request onto the SM-drained node
/// 0, stranding its memory — the follow-up 6 GiB request then fits
/// nowhere. Frag-gradient steers the small request to node 1 (strictly
/// lower stranding gradient), keeping node 0 open. The two policies are
/// provably different procedures, not renamings of one another.
#[test]
fn crafted_workload_separates_first_fit_from_frag_gradient() {
    let gib = 1u64 << 30;
    let demands = [
        Demand { mem: 4 * gib, sm: 0.8 },  // SM-heavy: drains node 0's SMs
        Demand { mem: 8 * gib, sm: 0.2 },  // mem-heavy: only node 1 fits
        Demand { mem: gib, sm: 0.15 },     // the placement the policies dispute
        Demand { mem: 6 * gib, sm: 0.05 }, // fits only if node 0 was kept open
    ];
    let replay = |policy_key: &str| {
        let policy = cluster::policy::by_name(policy_key).unwrap();
        let mut fleet = Fleet::new(2, 10 * gib, 1.0);
        demands
            .iter()
            .enumerate()
            .map(|(t, d)| fleet.place(policy, t as u64, *d))
            .collect::<Vec<_>>()
    };
    let ff = replay("first-fit");
    let fg = replay("frag-gradient");
    assert_eq!(ff, vec![Some(0), Some(1), Some(0), None]);
    assert_eq!(fg, vec![Some(0), Some(1), Some(1), Some(0)]);
}

#[test]
fn summary_round_trips_through_the_regression_engine() {
    let base = base();
    let surface = run_cluster(&base, &spec(), 4);
    let summary = render_summary_csv(&surface);
    let baseline = gvb::regress::parse_baseline_csv(&summary, "native").unwrap();
    assert_eq!(baseline.schema, gvb::regress::BaselineSchema::Cluster);
    // 8 fleet cells × 5 summary statistics.
    assert_eq!(baseline.rows.len(), 40);
    // Re-run at both job counts: clean pass with a tight threshold.
    for jobs in [1usize, 8] {
        let mut cfg = base.clone();
        cfg.jobs = jobs;
        let out = gvb::regress::run_regression(&cfg, &baseline, 0.0001).unwrap();
        assert_eq!(out.checked(), 40);
        assert!(out.passed(), "jobs={jobs}: {:?}", out.regressions());
        assert_eq!(out.schema, gvb::regress::BaselineSchema::Cluster);
    }
}
