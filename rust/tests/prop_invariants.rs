//! Property-based tests (via the in-tree `testkit`) on substrate and
//! coordinator invariants.

use gvb::cudalite::Api;
use gvb::simgpu::memory::HbmAllocator;
use gvb::stats::jain_fairness;
use gvb::testkit::{check, gens};
use gvb::util::Rng;
use gvb::virt::wfq::WfqScheduler;
use gvb::virt::TenantConfig;

/// Allocator invariant: after any interleaving of allocs and frees,
/// used + total_free == capacity and the free list stays coalesced
/// (no two adjacent free blocks).
#[test]
fn prop_allocator_conserves_memory() {
    check(
        "allocator-conservation",
        0xA110C,
        64,
        |rng: &mut Rng| {
            let ops: Vec<(bool, u64)> = (0..rng.range(1, 200))
                .map(|_| (rng.chance(0.6), gens::alloc_size(rng) % (1 << 28) + 256))
                .collect();
            ops
        },
        |ops| {
            let cap = 1u64 << 32;
            let mut a = HbmAllocator::new(cap);
            let mut live = Vec::new();
            for (is_alloc, size) in ops {
                if *is_alloc {
                    if let Ok(o) = a.alloc(*size) {
                        live.push(o.ptr);
                    }
                } else if !live.is_empty() {
                    let p = live.swap_remove(live.len() / 2);
                    if a.free(p).is_none() {
                        return false; // double free must be impossible here
                    }
                }
            }
            a.used() + a.frag_stats().total_free == cap
        },
    );
}

/// Quota invariant: under any sequence of allocations, a HAMi/FCSP tenant
/// can never hold more device memory than its configured limit.
#[test]
fn prop_quota_never_exceeded() {
    for backend in ["hami", "fcsp"] {
        check(
            "quota-never-exceeded",
            0x900A + backend.len() as u64,
            24,
            |rng: &mut Rng| {
                let quota = rng.range(1 << 28, 1 << 31) as u64;
                let sizes: Vec<u64> =
                    (0..rng.range(1, 60)).map(|_| gens::alloc_size(rng)).collect();
                (quota, sizes)
            },
            |(quota, sizes)| {
                let mut api = Api::with_backend(backend, 7);
                api.ctx_create(1, TenantConfig::unlimited().with_mem_limit(*quota)).unwrap();
                let mut held = 0u64;
                for s in sizes {
                    if api.mem_alloc(1, *s).is_ok() {
                        held += HbmAllocator::round_up(*s);
                    }
                    if held > *quota {
                        return false;
                    }
                }
                true
            },
        );
    }
}

/// WFQ invariant: with equal weights and everyone backlogged, long-run
/// service shares are near-equal regardless of per-tenant cost skew.
#[test]
fn prop_wfq_equal_share() {
    check(
        "wfq-equal-share",
        0x3F9,
        32,
        |rng: &mut Rng| {
            let n = rng.range(2, 6);
            let costs: Vec<f64> = (0..n).map(|_| rng.f64_range(0.5, 20.0)).collect();
            costs
        },
        |costs| {
            let mut wfq = WfqScheduler::new();
            for t in 0..costs.len() as u32 {
                wfq.add_tenant(t, 1.0);
            }
            let mut served = vec![0.0; costs.len()];
            for _ in 0..5000 {
                let pending: Vec<(u32, f64)> =
                    (0..costs.len()).map(|t| (t as u32, costs[t])).collect();
                let pick = wfq.pick(&pending).unwrap();
                let (t, c) = pending[pick];
                wfq.serve(t, c);
                served[t as usize] += c;
            }
            jain_fairness(&served) > 0.97
        },
    );
}

/// Limiter invariant: achieved utilization never exceeds the limit by
/// more than one kernel per poll window (HAMi) / one burst (FCSP).
#[test]
fn prop_limiter_bounded_overshoot() {
    check(
        "limiter-bounded-overshoot",
        0x11117,
        24,
        |rng: &mut Rng| (gens::fraction(rng).max(0.05), rng.f64_range(5e5, 2e7)),
        |(limit, kernel_ns)| {
            let mut l = gvb::virt::rate_limiter::AdaptiveBucket::new(*limit);
            let (mut now, mut busy) = (0.0, 0.0);
            while now < 3e9 {
                let a = l.acquire(*kernel_ns, now);
                now += a.wait_ns + a.overhead_ns + kernel_ns;
                busy += kernel_ns;
                l.on_complete(1.0, *kernel_ns, now);
            }
            let achieved: f64 = busy / now;
            // GCRA pacing: long-run overshoot bounded by burst/horizon.
            achieved <= limit + kernel_ns / 3e9 + 0.02
        },
    );
}

/// Clock invariant: every cudalite call moves virtual time forward.
#[test]
fn prop_virtual_time_monotone() {
    for backend in ["native", "hami", "fcsp", "mig"] {
        let mut api = Api::with_backend(backend, 99);
        api.ctx_create(1, TenantConfig::unlimited().with_sm_limit(0.5)).unwrap();
        let mut last = api.now_ns();
        let mut rng = Rng::new(5);
        for _ in 0..200 {
            match rng.range(0, 3) {
                0 => {
                    if let Ok(p) = api.mem_alloc(1, 4096) {
                        api.mem_free(1, p).unwrap();
                    }
                }
                1 => {
                    api.launch_kernel(1, 0, &gvb::simgpu::kernel::KernelDesc::null()).unwrap();
                }
                _ => {
                    api.sync_device(1).unwrap();
                }
            }
            let now = api.now_ns();
            assert!(now >= last, "{backend}: time went backwards");
            last = now;
        }
    }
}
