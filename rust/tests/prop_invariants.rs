//! Property-based tests (via the in-tree `testkit`) on substrate,
//! coordinator and fleet-placement invariants.

use std::collections::HashSet;

use gvb::cluster::{self, Fleet, FleetEvent};
use gvb::coordinator::executor::{self, Task};
use gvb::cudalite::Api;
use gvb::metrics::{taxonomy, RunConfig};
use gvb::simgpu::memory::HbmAllocator;
use gvb::stats::jain_fairness;
use gvb::testkit::{check, check_with_shrink, gens, shrink};
use gvb::util::rng::{cluster_seed, dynamics_seed, scenario_seed, task_seed, topology_seed};
use gvb::util::Rng;
use gvb::virt::wfq::WfqScheduler;
use gvb::virt::{TenantConfig, ALL_SYSTEMS};

/// Allocator invariant: after any interleaving of allocs and frees,
/// used + total_free == capacity and the free list stays coalesced
/// (no two adjacent free blocks). Runs on the shrinking runner, so a
/// failure reports the minimal op subsequence that still breaks it.
#[test]
fn prop_allocator_conserves_memory() {
    check_with_shrink(
        "allocator-conservation",
        0xA110C,
        64,
        |rng: &mut Rng| {
            let ops: Vec<(bool, u64)> = (0..rng.range(1, 200))
                .map(|_| (rng.chance(0.6), gens::alloc_size(rng) % (1 << 28) + 256))
                .collect();
            ops
        },
        |ops| shrink::vec_drops(ops),
        |ops| {
            let cap = 1u64 << 32;
            let mut a = HbmAllocator::new(cap);
            let mut live = Vec::new();
            for (is_alloc, size) in ops {
                if *is_alloc {
                    if let Ok(o) = a.alloc(*size) {
                        live.push(o.ptr);
                    }
                } else if !live.is_empty() {
                    let p = live.swap_remove(live.len() / 2);
                    if a.free(p).is_none() {
                        return false; // double free must be impossible here
                    }
                }
            }
            a.used() + a.frag_stats().total_free == cap
        },
    );
}

/// Quota invariant: under any sequence of allocations, a HAMi/FCSP tenant
/// can never hold more device memory than its configured limit. Shrinks
/// the allocation sequence (quota held fixed) on failure.
#[test]
fn prop_quota_never_exceeded() {
    for backend in ["hami", "fcsp"] {
        check_with_shrink(
            "quota-never-exceeded",
            0x900A + backend.len() as u64,
            24,
            |rng: &mut Rng| {
                let quota = rng.range(1 << 28, 1 << 31) as u64;
                let sizes: Vec<u64> =
                    (0..rng.range(1, 60)).map(|_| gens::alloc_size(rng)).collect();
                (quota, sizes)
            },
            |(quota, sizes)| {
                shrink::vec_drops(sizes).into_iter().map(|s| (*quota, s)).collect()
            },
            |(quota, sizes)| {
                let mut api = Api::with_backend(backend, 7);
                api.ctx_create(1, TenantConfig::unlimited().with_mem_limit(*quota)).unwrap();
                let mut held = 0u64;
                for s in sizes {
                    if api.mem_alloc(1, *s).is_ok() {
                        held += HbmAllocator::round_up(*s);
                    }
                    if held > *quota {
                        return false;
                    }
                }
                true
            },
        );
    }
}

/// WFQ invariant: with equal weights and everyone backlogged, long-run
/// service shares are near-equal regardless of per-tenant cost skew.
#[test]
fn prop_wfq_equal_share() {
    check(
        "wfq-equal-share",
        0x3F9,
        32,
        |rng: &mut Rng| {
            let n = rng.range(2, 6);
            let costs: Vec<f64> = (0..n).map(|_| rng.f64_range(0.5, 20.0)).collect();
            costs
        },
        |costs| {
            let mut wfq = WfqScheduler::new();
            for t in 0..costs.len() as u32 {
                wfq.add_tenant(t, 1.0);
            }
            let mut served = vec![0.0; costs.len()];
            for _ in 0..5000 {
                let pending: Vec<(u32, f64)> =
                    (0..costs.len()).map(|t| (t as u32, costs[t])).collect();
                let pick = wfq.pick(&pending).unwrap();
                let (t, c) = pending[pick];
                wfq.serve(t, c);
                served[t as usize] += c;
            }
            jain_fairness(&served) > 0.97
        },
    );
}

/// Limiter invariant: achieved utilization never exceeds the limit by
/// more than one kernel per poll window (HAMi) / one burst (FCSP).
#[test]
fn prop_limiter_bounded_overshoot() {
    check(
        "limiter-bounded-overshoot",
        0x11117,
        24,
        |rng: &mut Rng| (gens::fraction(rng).max(0.05), rng.f64_range(5e5, 2e7)),
        |(limit, kernel_ns)| {
            let mut l = gvb::virt::rate_limiter::AdaptiveBucket::new(*limit);
            let (mut now, mut busy) = (0.0, 0.0);
            while now < 3e9 {
                let a = l.acquire(*kernel_ns, now);
                now += a.wait_ns + a.overhead_ns + kernel_ns;
                busy += kernel_ns;
                l.on_complete(1.0, *kernel_ns, now);
            }
            let achieved: f64 = busy / now;
            // GCRA pacing: long-run overshoot bounded by burst/horizon.
            achieved <= limit + kernel_ns / 3e9 + 0.02
        },
    );
}

/// Seed-derivation invariant: for any base seed, `task_seed` is stable
/// across calls and collision-free over the entire 4-system × 56-metric
/// (224-cell) evaluation matrix.
#[test]
fn prop_task_seed_stable_and_collision_free() {
    check(
        "task-seed-stable-collision-free",
        0x5EED5,
        128,
        |rng: &mut Rng| rng.next_u64(),
        |&base| {
            let mut seen = HashSet::new();
            for system in ALL_SYSTEMS {
                for d in &taxonomy::ALL {
                    let s = task_seed(base, system, d.id);
                    if s != task_seed(base, system, d.id) {
                        return false; // must be a pure function
                    }
                    if !seen.insert(s) {
                        return false; // collision across the matrix
                    }
                }
            }
            seen.len() == ALL_SYSTEMS.len() * taxonomy::ALL.len()
        },
    );
}

/// Sweep-seed invariant: composed scenario+topology+task seeds — the
/// per-cell derivation used by `coordinator::sweep` — are collision-free
/// across the entire expanded (systems × metrics × tenants × quotas ×
/// gpu_counts × links) matrix for any base seed. A collision would make
/// two sweep cells draw identical jitter streams and silently correlate
/// their numbers.
#[test]
fn prop_sweep_cell_seeds_collision_free() {
    let tenants = [1u32, 2, 3, 4, 8, 16];
    let quotas = [10u32, 25, 50, 75, 100];
    let topologies = [(2u32, "nvlink"), (2, "pcie"), (4, "nvlink"), (4, "pcie"), (8, "nvlink")];
    let expanded = ALL_SYSTEMS.len()
        * taxonomy::ALL.len()
        * tenants.len()
        * quotas.len()
        * topologies.len();
    check(
        "sweep-cell-seeds-collision-free",
        0x5EED6,
        8,
        |rng: &mut Rng| rng.next_u64(),
        |&base| {
            let mut seen = HashSet::new();
            for &t in &tenants {
                for &q in &quotas {
                    let scenario = scenario_seed(base, t, q);
                    for &(g, l) in &topologies {
                        let cell = topology_seed(scenario, g, l);
                        for system in ALL_SYSTEMS {
                            for d in &taxonomy::ALL {
                                if !seen.insert(task_seed(cell, system, d.id)) {
                                    return false; // collision across the matrix
                                }
                            }
                        }
                    }
                }
            }
            seen.len() == expanded
        },
    );
}

/// Dynamics-seed invariant: composed dynamics+task seeds — the per-task
/// derivation used by `dynsim::run_dynamics` — are collision-free across
/// a (systems × scenarios × durations × windows) grid for any base seed,
/// and never collide with the sweep-layer derivations for the same base
/// seed (the 0xFD separator keeps the layers apart). A collision would
/// make two timelines draw identical request/jitter streams and silently
/// correlate their series. The scenario axis covers all six presets
/// *plus* the `trace` replay coordinate, so an external-trace timeline
/// can never share a jitter stream with a preset timeline of matching
/// geometry.
#[test]
fn prop_dynamics_seeds_collision_free_and_layer_distinct() {
    let scenarios: Vec<&str> = gvb::dynsim::PRESETS
        .iter()
        .copied()
        .chain([gvb::dynsim::TRACE_SCENARIO])
        .collect();
    let durations = [250u64, 1000, 2000];
    let windows = [50u64, 100, 250];
    let expanded = ALL_SYSTEMS.len() * scenarios.len() * durations.len() * windows.len();
    check(
        "dynamics-seeds-collision-free",
        0x5EED7,
        8,
        |rng: &mut Rng| rng.next_u64(),
        |&base| {
            let mut seen = HashSet::new();
            for &sc in &scenarios {
                for &d in &durations {
                    for &w in &windows {
                        let layer = dynamics_seed(base, sc, d, w);
                        for system in ALL_SYSTEMS {
                            if !seen.insert(task_seed(layer, system, sc)) {
                                return false; // collision across the grid
                            }
                        }
                    }
                }
            }
            if seen.len() != expanded {
                return false;
            }
            // Layer separation: a dynamics task seed never equals the
            // sweep-layer task seed of matching numeric coordinates.
            let dynv = task_seed(dynamics_seed(base, "steady", 4, 50), "hami", "OH-001");
            let sweep = task_seed(scenario_seed(base, 4, 50), "hami", "OH-001");
            let topo = task_seed(topology_seed(scenario_seed(base, 4, 50), 4, "pcie"), "hami", "OH-001");
            dynv != sweep && dynv != topo
        },
    );
}

/// Trace round-trip invariant: any generated trace timeline survives
/// `render_trace` → `parse_trace` exactly (structural spec equality),
/// and replaying the parsed spec is bit-identical to replaying the
/// original — the textual trace format loses nothing the engine can
/// observe. Failures shrink by event-prefix truncation, which never
/// leaves the parseable set.
#[test]
fn prop_trace_render_parse_replay_identity() {
    check_with_shrink(
        "trace-render-parse-replay",
        0x712ACE,
        24,
        |rng: &mut Rng| gens::trace(rng, 12),
        shrink::trace_events,
        |spec| {
            let parsed = match gvb::dynsim::parse_trace(&gvb::dynsim::render_trace(spec)) {
                Ok(p) => p,
                Err(_) => return false,
            };
            if parsed != *spec {
                return false;
            }
            let mut cfg = RunConfig::quick("hami");
            cfg.seed = 0xBEEF ^ spec.events.len() as u64;
            let a = gvb::dynsim::engine::run_scenario(&cfg, spec);
            let b = gvb::dynsim::engine::run_scenario(&cfg, &parsed);
            // `Debug` for f64 prints the shortest round-trip form, so
            // equal strings here means bit-equal runs.
            format!("{a:?}") == format!("{b:?}")
        },
    );
}

/// Executor invariant: for randomized metric-id subsets (kept in Table-8
/// order, as the runner emits them), the parallel executor returns results
/// in exactly the input order at any worker count.
#[test]
fn prop_executor_preserves_table8_order() {
    // A pool of cheap metrics so randomized cases stay fast; pool indices
    // are in Table-8 order.
    let pool: [&'static str; 6] =
        ["OH-007", "OH-009", "PCIE-001", "PCIE-002", "PCIE-004", "BW-003"];
    check(
        "executor-preserves-order",
        0x0D3B,
        6,
        |rng: &mut Rng| {
            let system = *rng.choose(&ALL_SYSTEMS);
            let n = rng.range(1, pool.len() + 1);
            // Random subset, preserving pool (Table-8) order.
            let mut picked: Vec<&'static str> = Vec::new();
            for id in pool {
                if picked.len() < n && rng.chance(0.6) {
                    picked.push(id);
                }
            }
            if picked.is_empty() {
                picked.push(pool[rng.range(0, pool.len())]);
            }
            let jobs = rng.range(1, 5);
            (system.to_string(), picked, jobs)
        },
        |(system, ids, jobs)| {
            let tasks: Vec<Task> = ids
                .iter()
                .map(|id| Task { system: system.clone(), metric_id: *id })
                .collect();
            let (results, stats) = executor::execute(&RunConfig::quick(system), &tasks, *jobs);
            results.len() == ids.len()
                && stats.tasks.len() == ids.len()
                && results.iter().zip(ids).all(|(r, id)| r.id == *id)
                && stats.tasks.iter().zip(ids).all(|(t, id)| t.metric_id == *id)
        },
    );
}

/// Recompute a fleet's per-node usage from its placement map and compare
/// against the incrementally maintained node state: every tenant sits on
/// exactly one *alive* node (the map admits at most one entry per tenant,
/// so a second placement could only hide as a usage mismatch), and no
/// node exceeds its memory or SM capacity.
fn fleet_consistent(fleet: &Fleet) -> bool {
    let nodes = fleet.nodes();
    let mut mem = vec![0u64; nodes.len()];
    let mut sm = vec![0f64; nodes.len()];
    let mut count = vec![0u32; nodes.len()];
    for (_, &(n, d)) in fleet.placements() {
        if !nodes[n].alive {
            return false; // tenant placed on a dead node
        }
        mem[n] += d.mem;
        sm[n] += d.sm;
        count[n] += 1;
    }
    nodes.iter().enumerate().all(|(i, n)| {
        n.mem_used == mem[i]
            && (n.sm_used - sm[i]).abs() < 1e-6
            && n.tenants == count[i]
            && n.mem_used <= n.mem_capacity
            && n.sm_used <= n.sm_capacity + 1e-6
    })
}

/// Placement invariant: across any generated churn timeline and any
/// policy, after every event the fleet's placement map and node usage
/// agree (one node per tenant, usage = sum of live demands, capacity
/// never exceeded). Failures shrink to a minimal event subsequence.
#[test]
fn prop_fleet_placement_invariants() {
    for policy_name in cluster::POLICIES {
        let policy = cluster::policy::by_name(policy_name).unwrap();
        check_with_shrink(
            "fleet-placement-invariants",
            0xF1EE7 + policy_name.len() as u64,
            16,
            |rng: &mut Rng| gens::fleet_timeline(rng, 300),
            |tl| shrink::vec_drops(tl),
            |timeline| {
                // 16 nodes covers every Fail index the generator emits;
                // 40 GiB / 4-SM nodes saturate under ~300 arrivals, so
                // both the placed and rejected paths are exercised.
                let mut fleet = Fleet::new(16, 40 << 30, 4.0);
                for ev in timeline {
                    match ev {
                        FleetEvent::Arrive { tenant, demand } => {
                            fleet.place(policy, *tenant, *demand);
                        }
                        FleetEvent::Depart { tenant } => {
                            fleet.remove(*tenant);
                        }
                        FleetEvent::Fail { node } => {
                            for (t, d) in fleet.fail_node(*node) {
                                fleet.place(policy, t, d);
                            }
                        }
                    }
                    if !fleet_consistent(&fleet) {
                        return false;
                    }
                }
                true
            },
        );
    }
}

/// Purity invariant: a fleet replay is a pure function of (seed, policy,
/// scenario, nodes, arrivals) — replaying the same cell twice yields
/// identical summaries, counters and final node states, bit for bit.
#[test]
fn prop_fleet_replay_pure() {
    check(
        "fleet-replay-pure",
        0xF1EE8,
        12,
        |rng: &mut Rng| {
            let system = *rng.choose(&ALL_SYSTEMS);
            (
                rng.next_u64(),
                system.to_string(),
                gens::policy(rng),
                gens::scenario(rng),
                rng.range(1, 9) as u32,
                rng.range(20, 120) as u32,
            )
        },
        |(seed, system, policy_name, scenario, nodes, arrivals)| {
            let policy = cluster::policy::by_name(policy_name).unwrap();
            let mut cfg = RunConfig::quick(system);
            cfg.seed = *seed;
            let a = cluster::replay_fleet(&cfg, policy, *nodes, *scenario, *arrivals);
            let b = cluster::replay_fleet(&cfg, policy, *nodes, *scenario, *arrivals);
            a.summary == b.summary
                && (a.placed, a.migrations, a.evictions) == (b.placed, b.migrations, b.evictions)
                && a.node_stats.len() == b.node_stats.len()
                && a.node_stats.iter().zip(&b.node_stats).all(|(x, y)| {
                    x.mem_used == y.mem_used
                        && x.sm_used == y.sm_used
                        && x.tenants == y.tenants
                        && x.alive == y.alive
                })
        },
    );
}

/// Cluster-seed invariant: composed cluster+task seeds — the per-cell
/// derivation used by `cluster::run_cluster` — are collision-free across
/// the full (systems × policies × node counts × scenarios) matrix for
/// any base seed, and never collide with the sweep-, topology- or
/// dynamics-layer derivations of matching coordinates (the 0xFC
/// separator keeps the layers apart). A collision would make two fleet
/// cells draw identical arrival streams and silently correlate.
#[test]
fn prop_cluster_seeds_collision_free_and_layer_distinct() {
    let node_counts = [1u32, 2, 4, 8, 16, 64, 1024];
    let scenarios = gvb::dynsim::PRESETS;
    let expanded =
        ALL_SYSTEMS.len() * cluster::POLICIES.len() * node_counts.len() * scenarios.len();
    check(
        "cluster-seeds-collision-free",
        0x5EED8,
        8,
        |rng: &mut Rng| rng.next_u64(),
        |&base| {
            let mut seen = HashSet::new();
            for &p in &cluster::POLICIES {
                for &n in &node_counts {
                    for &sc in &scenarios {
                        let layer = cluster_seed(base, p, n, sc);
                        for system in ALL_SYSTEMS {
                            if !seen.insert(task_seed(layer, system, sc)) {
                                return false; // collision across the matrix
                            }
                        }
                    }
                }
            }
            if seen.len() != expanded {
                return false;
            }
            // Layer separation: a cluster task seed never equals the
            // sweep/topology/dynamics task seeds of matching coordinates.
            let cl = task_seed(cluster_seed(base, "first-fit", 4, "steady"), "hami", "steady");
            let dy = task_seed(dynamics_seed(base, "steady", 4, 50), "hami", "steady");
            let sw = task_seed(scenario_seed(base, 4, 50), "hami", "steady");
            let tp =
                task_seed(topology_seed(scenario_seed(base, 4, 50), 4, "pcie"), "hami", "steady");
            cl != dy && cl != sw && cl != tp
        },
    );
}

/// Clock invariant: every cudalite call moves virtual time forward.
#[test]
fn prop_virtual_time_monotone() {
    for backend in ["native", "hami", "fcsp", "mig"] {
        let mut api = Api::with_backend(backend, 99);
        api.ctx_create(1, TenantConfig::unlimited().with_sm_limit(0.5)).unwrap();
        let mut last = api.now_ns();
        let mut rng = Rng::new(5);
        for _ in 0..200 {
            match rng.range(0, 3) {
                0 => {
                    if let Ok(p) = api.mem_alloc(1, 4096) {
                        api.mem_free(1, p).unwrap();
                    }
                }
                1 => {
                    api.launch_kernel(1, 0, &gvb::simgpu::kernel::KernelDesc::null()).unwrap();
                }
                _ => {
                    api.sync_device(1).unwrap();
                }
            }
            let now = api.now_ns();
            assert!(now >= last, "{backend}: time went backwards");
            last = now;
        }
    }
}

/// Event-queue invariant: the dynsim queue pops occurrences in the
/// deterministic `(t, kind rank, key)` total order — boundaries before
/// scenario events before arrivals at equal timestamps, equal-time
/// arrivals tenant-ascending — for *any* insertion order. The order is
/// pure data (derived `Ord`, no hash or insertion state), which is what
/// makes the event core's replay independent of how occurrences were
/// scheduled.
#[test]
fn prop_event_queue_total_order() {
    use gvb::dynsim::queue::{EventQueue, Occ, OccKind};

    // Explicit statement of the intended order, independent of the
    // derived impl under test.
    fn sort_key(o: &Occ) -> (u64, u8, u64, u64) {
        match o.kind {
            OccKind::Boundary(w) => (o.t_ns, 0, w as u64, 0),
            OccKind::Event(i) => (o.t_ns, 1, i as u64, 0),
            OccKind::Arrival { tenant, epoch } => (o.t_ns, 2, tenant as u64, epoch),
        }
    }

    check(
        "event-queue-total-order",
        0x0CC5,
        128,
        |rng: &mut Rng| {
            // Small timestamp range forces heavy ties across all kinds.
            (0..rng.range(1, 120))
                .map(|_| {
                    let t_ns = rng.range(0, 8) as u64;
                    let kind = match rng.range(0, 3) {
                        0 => OccKind::Boundary(rng.range(0, 6)),
                        1 => OccKind::Event(rng.range(0, 10)),
                        _ => OccKind::Arrival {
                            tenant: rng.range(1, 7) as u32,
                            epoch: rng.range(0, 4) as u64,
                        },
                    };
                    Occ { t_ns, kind }
                })
                .collect::<Vec<Occ>>()
        },
        |occs| {
            let mut q = EventQueue::with_capacity(occs.len());
            for &o in occs {
                q.push(o);
            }
            let mut expected = occs.clone();
            expected.sort_by_key(sort_key);
            let popped: Vec<Occ> = std::iter::from_fn(|| q.pop()).collect();
            popped == expected
        },
    );
}
