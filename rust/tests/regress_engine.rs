//! End-to-end coverage of the sweep-aware regression subsystem: a fresh
//! sweep surface — topology axes included — rendered to the long-format
//! CSV and parsed back, must regress clean against itself at any job
//! count; a PR-3-era 4-tuple baseline (no `gpu_count`/`link` columns)
//! still parses and gates; a cluster summary surface is auto-detected as
//! the fourth baseline schema and replays clean; infeasible cells are
//! skipped; a single perturbed cell is flagged with its exact full
//! coordinate (fleet coordinates included); malformed and mixed-schema
//! baselines are rejected with named rows.

use gvb::cluster::{run_cluster, ClusterSpec, DEFAULT_ARRIVALS};
use gvb::coordinator::executor;
use gvb::report::cluster::render_summary_csv;
use gvb::coordinator::sweep::{run_sweep, SweepSpec, DEFAULT_GPU_COUNT, DEFAULT_LINK};
use gvb::metrics::{taxonomy, Category, Direction, RunConfig};
use gvb::regress::{parse_baseline_csv, render_json, render_markdown, run_regression, BaselineSchema};
use gvb::report::sweep::render_csv;
use gvb::simgpu::nvlink::LinkKind;

fn base() -> RunConfig {
    let mut cfg = RunConfig::quick("native");
    cfg.seed = 42;
    cfg
}

fn spec() -> SweepSpec {
    SweepSpec {
        systems: vec!["hami".into(), "fcsp".into()],
        tenants: vec![1, 2],
        quotas: vec![50, 100],
        gpu_counts: vec![DEFAULT_GPU_COUNT],
        links: vec![DEFAULT_LINK],
        categories: Some(vec![Category::Pcie]),
    }
}

/// A spec exercising the topology axes (NCCL so the link kind matters).
fn topo_spec() -> SweepSpec {
    SweepSpec {
        systems: vec!["hami".into()],
        tenants: vec![1, 2],
        quotas: vec![50],
        gpu_counts: vec![4, 8],
        links: vec![LinkKind::NvLink, LinkKind::Pcie],
        categories: Some(vec![Category::Nccl]),
    }
}

#[test]
fn sweep_baseline_roundtrips_clean_at_jobs_1_and_8() {
    let surface = run_sweep(&base(), &spec(), 2);
    let csv = render_csv(&surface);
    let baseline = parse_baseline_csv(&csv, "native").unwrap();
    assert_eq!(baseline.schema, BaselineSchema::Sweep);
    // 2 systems × 1 topology × 4 scenarios ((1,100) in-grid) × 4 PCIe
    // metrics.
    assert_eq!(baseline.rows.len(), 32);
    assert!(baseline.infeasible.is_empty());
    // The produced rows carry the extended topology coordinate.
    assert_eq!(
        baseline.rows[0].cell.unwrap().topo,
        Some((DEFAULT_GPU_COUNT, DEFAULT_LINK))
    );
    for jobs in [1, 8] {
        let mut cfg = base();
        cfg.jobs = jobs;
        let outcome = run_regression(&cfg, &baseline, 0.0001).unwrap();
        assert_eq!(outcome.checked(), 32);
        assert!(
            outcome.passed(),
            "jobs={jobs}: {:?}",
            outcome
                .regressions()
                .iter()
                .map(|r| format!("{}/{}/{}", r.system, r.cell_label(), r.id))
                .collect::<Vec<_>>()
        );
    }
}

#[test]
fn topology_sweep_baseline_roundtrips_clean_at_jobs_1_and_8() {
    let surface = run_sweep(&base(), &topo_spec(), 2);
    let csv = render_csv(&surface);
    let baseline = parse_baseline_csv(&csv, "native").unwrap();
    // 1 system × 4 topologies × 3 scenarios ((1,100) injected) × 4 NCCL
    // metrics.
    assert_eq!(baseline.rows.len(), 48);
    // All four topology cells are represented.
    for topo in [
        (4, LinkKind::NvLink),
        (4, LinkKind::Pcie),
        (8, LinkKind::NvLink),
        (8, LinkKind::Pcie),
    ] {
        assert!(
            baseline.rows.iter().any(|r| r.cell.unwrap().topo == Some(topo)),
            "missing topology cell {topo:?}"
        );
    }
    for jobs in [1, 8] {
        let mut cfg = base();
        cfg.jobs = jobs;
        let outcome = run_regression(&cfg, &baseline, 0.0001).unwrap();
        assert_eq!(outcome.checked(), 48);
        assert!(
            outcome.passed(),
            "jobs={jobs}: {:?}",
            outcome
                .regressions()
                .iter()
                .map(|r| format!("{}/{}/{}", r.system, r.cell_label(), r.id))
                .collect::<Vec<_>>()
        );
    }
}

#[test]
fn pr3_era_baseline_without_topology_columns_still_gates() {
    // Fabricate a baseline exactly as the PR-3 sweep produced it: same
    // quota→mem/SM mapping and default node, seeds stopping at the
    // scenario layer (`legacy_cell_cfg` reproduces that derivation).
    // Such a 4-tuple CSV must parse (topo-less coordinate) and re-run
    // bit-identically against the unchanged tree at any job count.
    let base = base();
    let mut legacy_csv = String::from("system,tenants,quota_pct,feasible,id,value\n");
    let metric_ids = ["PCIE-001", "PCIE-002", "PCIE-003", "PCIE-004"];
    for sys in ["hami", "fcsp"] {
        for (tenants, quota) in [(1u32, 100u32), (2, 50)] {
            let cfg = gvb::coordinator::sweep::legacy_cell_cfg(&base, sys, tenants, quota);
            let tasks: Vec<executor::Task> = metric_ids
                .iter()
                .map(|&id| executor::Task { system: sys.to_string(), metric_id: id })
                .collect();
            let (results, _) = executor::execute(&cfg, &tasks, 2);
            for r in &results {
                // 6-decimal recording resolution, as the CSV writer uses.
                legacy_csv.push_str(&format!(
                    "{sys},{tenants},{quota},true,{},{:.6}\n",
                    r.id, r.value
                ));
            }
        }
    }
    let baseline = parse_baseline_csv(&legacy_csv, "native").unwrap();
    assert_eq!(baseline.schema, BaselineSchema::Sweep);
    assert_eq!(baseline.rows.len(), 16);
    for r in &baseline.rows {
        assert_eq!(r.cell.unwrap().topo, None, "legacy rows must carry no topology");
    }
    for jobs in [1, 8] {
        let mut cfg = base.clone();
        cfg.jobs = jobs;
        let outcome = run_regression(&cfg, &baseline, 0.0001).unwrap();
        assert_eq!(outcome.checked(), 16);
        assert!(
            outcome.passed(),
            "jobs={jobs}: {:?}",
            outcome
                .regressions()
                .iter()
                .map(|r| format!("{}/{}/{}", r.system, r.cell_label(), r.id))
                .collect::<Vec<_>>()
        );
    }
}

#[test]
fn infeasible_cells_are_skipped_not_flagged() {
    // MIG cannot host 8 tenants; the surface records the cell as
    // infeasible and the regress engine skips it.
    let spec = SweepSpec {
        systems: vec!["mig".into()],
        tenants: vec![8],
        quotas: vec![50],
        gpu_counts: vec![DEFAULT_GPU_COUNT],
        links: vec![DEFAULT_LINK],
        categories: Some(vec![Category::Pcie]),
    };
    let surface = run_sweep(&base(), &spec, 2);
    let csv = render_csv(&surface);
    let baseline = parse_baseline_csv(&csv, "native").unwrap();
    // Only the injected (1,100) baseline cell carries metric rows.
    assert_eq!(baseline.rows.len(), 4);
    assert_eq!(baseline.infeasible.len(), 1);
    assert_eq!(baseline.infeasible[0].0, "mig");
    let coord = baseline.infeasible[0].1;
    assert_eq!((coord.tenants, coord.quota_pct), (8, 50));
    assert_eq!(coord.topo, Some((DEFAULT_GPU_COUNT, DEFAULT_LINK)));
    let outcome = run_regression(&base(), &baseline, 1.0).unwrap();
    assert_eq!(outcome.checked(), 4);
    assert_eq!(outcome.skipped_infeasible, 1);
    assert!(outcome.passed(), "{:?}", outcome.regressions());
    // The skip is surfaced in both machine-readable reports.
    let j = render_json(&outcome, "b.csv");
    assert!(j.contains("\"skipped_infeasible\": 1"), "{j}");
    let m = render_markdown(&outcome, "b.csv");
    assert!(m.contains("1 infeasible cell(s) skipped"), "{m}");
}

#[test]
fn injected_regression_is_detected_with_its_cell_coordinate() {
    let surface = run_sweep(&base(), &spec(), 2);
    let csv = render_csv(&surface);
    let mut baseline = parse_baseline_csv(&csv, "native").unwrap();
    // Perturb exactly one non-baseline cell's metric against its
    // direction, so the unchanged re-run reads as a large regression.
    let idx = baseline
        .rows
        .iter()
        .position(|r| {
            let c = r.cell.unwrap();
            r.system == "hami"
                && (c.tenants, c.quota_pct) == (2, 50)
                && r.value > 1e-3
                && !matches!(
                    taxonomy::by_id(&r.id).unwrap().direction,
                    Direction::Boolean
                )
        })
        .expect("a perturbable hami 2t@50% row");
    let (system, cell, id) = {
        let row = &mut baseline.rows[idx];
        match taxonomy::by_id(&row.id).unwrap().direction {
            Direction::LowerBetter => row.value /= 2.0,
            Direction::HigherBetter => row.value *= 2.0,
            Direction::Boolean => unreachable!("filtered out above"),
        }
        (row.system.clone(), row.cell, row.id.clone())
    };
    let outcome = run_regression(&base(), &baseline, 5.0).unwrap();
    assert!(!outcome.passed());
    let regressions = outcome.regressions();
    assert_eq!(regressions.len(), 1, "{regressions:?}");
    assert_eq!(regressions[0].system, system);
    assert_eq!(regressions[0].cell, cell);
    assert_eq!(regressions[0].id, id);
    assert!(regressions[0].worse_percent > 5.0);
    // Both reports name the offending cell — full topology coordinate
    // included — and flip to FAIL.
    let j = render_json(&outcome, "b.csv");
    assert!(j.contains("\"passed\": false"), "{j}");
    assert!(j.contains("\"regression_count\": 1"), "{j}");
    let m = render_markdown(&outcome, "b.csv");
    assert!(m.contains("❌ FAIL"), "{m}");
    assert!(
        m.contains(&format!("| {} | 2t@50%/4g/pcie | {} |", system, id)),
        "{m}"
    );
}

#[test]
fn injected_regression_in_a_topology_cell_names_the_full_coordinate() {
    // Same detection story, but the perturbed cell lives on a non-default
    // topology: the 8-GPU NVLink node.
    let surface = run_sweep(&base(), &topo_spec(), 2);
    let csv = render_csv(&surface);
    let mut baseline = parse_baseline_csv(&csv, "native").unwrap();
    let idx = baseline
        .rows
        .iter()
        .position(|r| {
            let c = r.cell.unwrap();
            (c.tenants, c.quota_pct) == (2, 50)
                && c.topo == Some((8, LinkKind::NvLink))
                && r.id == "NCCL-001" // allreduce latency, lower-better
        })
        .expect("the 8-GPU NVLink 2t@50% NCCL-001 row");
    baseline.rows[idx].value /= 2.0; // lower-better: re-run reads 2x worse
    let outcome = run_regression(&base(), &baseline, 5.0).unwrap();
    let regressions = outcome.regressions();
    assert_eq!(regressions.len(), 1, "{regressions:?}");
    assert_eq!(regressions[0].cell_label(), "2t@50%/8g/nvlink");
    assert_eq!(regressions[0].id, "NCCL-001");
    let m = render_markdown(&outcome, "b.csv");
    assert!(m.contains("| hami | 2t@50%/8g/nvlink | NCCL-001 |"), "{m}");
    // The by-link breakdown blames the nvlink group, not pcie.
    let j = render_json(&outcome, "b.csv");
    let idx = j.find("\"by_link\"").unwrap();
    assert!(j[idx..].contains("\"link\": \"nvlink\""), "{j}");
}

/// A small fleet grid at the default arrival count (the count the
/// regression engine pins when replaying cluster baselines).
fn cluster_spec() -> ClusterSpec {
    ClusterSpec {
        systems: vec!["hami".into()],
        policies: vec!["first-fit", "frag-gradient"],
        node_counts: vec![2],
        scenarios: vec!["churn"],
        arrivals: DEFAULT_ARRIVALS,
    }
}

#[test]
fn cluster_summary_baseline_is_auto_detected_and_roundtrips() {
    let surface = run_cluster(&base(), &cluster_spec(), 2);
    let csv = render_summary_csv(&surface);
    let baseline = parse_baseline_csv(&csv, "native").unwrap();
    // The `policy`/`nodes` columns select the fourth schema, even though
    // the header also carries `scenario` (which alone means dynamics).
    assert_eq!(baseline.schema, BaselineSchema::Cluster);
    // 2 fleet cells × 5 summary statistics.
    assert_eq!(baseline.rows.len(), 10);
    let c = baseline.rows[0].cluster_cell.unwrap();
    assert_eq!((c.policy, c.nodes, c.scenario), ("first-fit", 2, "churn"));
    for jobs in [1, 8] {
        let mut cfg = base();
        cfg.jobs = jobs;
        let outcome = run_regression(&cfg, &baseline, 0.0001).unwrap();
        assert_eq!(outcome.checked(), 10);
        assert_eq!(outcome.schema, BaselineSchema::Cluster);
        assert!(
            outcome.passed(),
            "jobs={jobs}: {:?}",
            outcome
                .regressions()
                .iter()
                .map(|r| format!("{}/{}/{}", r.system, r.cell_label(), r.id))
                .collect::<Vec<_>>()
        );
    }
}

#[test]
fn injected_cluster_regression_names_the_full_fleet_coordinate() {
    let surface = run_cluster(&base(), &cluster_spec(), 2);
    let csv = render_summary_csv(&surface);
    let mut baseline = parse_baseline_csv(&csv, "native").unwrap();
    // Direction-aware perturbation: CL-SUCCESS is higher-better, so
    // doubling the recorded baseline makes the unchanged re-run read as
    // a 50% regression on exactly that cell.
    let idx = baseline
        .rows
        .iter()
        .position(|r| {
            r.cluster_cell.unwrap().policy == "frag-gradient" && r.id == "CL-SUCCESS"
        })
        .expect("the frag-gradient CL-SUCCESS row");
    assert!(baseline.rows[idx].value > 0.0, "success rate must be non-zero to perturb");
    baseline.rows[idx].value *= 2.0;
    let outcome = run_regression(&base(), &baseline, 5.0).unwrap();
    assert!(!outcome.passed());
    let regressions = outcome.regressions();
    assert_eq!(regressions.len(), 1, "{regressions:?}");
    assert_eq!(regressions[0].system, "hami");
    assert_eq!(regressions[0].cell_label(), "frag-gradient@2n/churn");
    assert_eq!(regressions[0].id, "CL-SUCCESS");
    assert!(regressions[0].worse_percent > 5.0);
    // Both reports name the offending fleet cell by its full coordinate.
    let m = render_markdown(&outcome, "b.csv");
    assert!(m.contains("❌ FAIL"), "{m}");
    assert!(m.contains("| hami | frag-gradient@2n/churn | CL-SUCCESS |"), "{m}");
    let j = render_json(&outcome, "b.csv");
    assert!(j.contains("\"schema\": \"cluster\""), "{j}");
    assert!(j.contains("\"policy\": \"frag-gradient\""), "{j}");
    assert!(j.contains("\"passed\": false"), "{j}");
    // The per-link breakdown groups fleet cells under the `cluster` key.
    let at = j.find("\"by_link\"").unwrap();
    assert!(j[at..].contains("\"link\": \"cluster\""), "{j}");
}

#[test]
fn malformed_cluster_rows_are_named_errors() {
    let hdr = "system,policy,nodes,scenario,id,value\n";
    // Unknown placement policy, naming the offending row.
    let e = parse_baseline_csv(
        &format!("{hdr}hami,worst-fit,2,churn,CL-SUCCESS,50.0\n"),
        "native",
    )
    .unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("row 2") && msg.contains("worst-fit"), "{msg}");
    // Out-of-range node count.
    let e = parse_baseline_csv(
        &format!("{hdr}hami,first-fit,0,churn,CL-SUCCESS,50.0\n"),
        "native",
    )
    .unwrap_err();
    assert!(format!("{e:#}").contains("out of range (1..=1024)"), "{e:#}");
    // Unknown summary id under the cluster schema.
    let e = parse_baseline_csv(
        &format!("{hdr}hami,first-fit,2,churn,ZZ-999,50.0\n"),
        "native",
    )
    .unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("row 2") && msg.contains("ZZ-999"), "{msg}");
    // Half a cluster coordinate (`policy` without `nodes`) is neither
    // schema generation.
    let e = parse_baseline_csv(
        "system,policy,scenario,id,value\nhami,first-fit,churn,CL-SUCCESS,50.0\n",
        "native",
    )
    .unwrap_err();
    assert!(format!("{e:#}").contains("mixed-schema"), "{e:#}");
    // Cluster columns glued onto a sweep coordinate are rejected too.
    let e = parse_baseline_csv(
        "system,policy,nodes,tenants,quota_pct,scenario,id,value\n",
        "native",
    )
    .unwrap_err();
    assert!(format!("{e:#}").contains("mixed-schema"), "{e:#}");
}

#[test]
fn point_baseline_roundtrips_through_the_same_engine() {
    // A hand-rolled point table (the `gvbench run --format csv` schema,
    // reduced to its regress-relevant columns) re-runs at the
    // invocation's operating point and compares clean at any job count.
    let cfg = base();
    let tasks = vec![
        executor::Task { system: "native".into(), metric_id: "PCIE-001" },
        executor::Task { system: "hami".into(), metric_id: "PCIE-001" },
        executor::Task { system: "fcsp".into(), metric_id: "BW-003" },
    ];
    let (results, _) = executor::execute(&cfg, &tasks, 1);
    let mut csv = String::from("id,system,value\n");
    for r in &results {
        // 6-decimal recording resolution, exactly as the CSV reporter
        // writes it — the comparison guard must absorb the rounding.
        csv.push_str(&format!("{},{},{:.6}\n", r.id, r.system, r.value));
    }
    let baseline = parse_baseline_csv(&csv, "native").unwrap();
    assert_eq!(baseline.schema, BaselineSchema::Point);
    for jobs in [1, 8] {
        let mut cfg = base();
        cfg.jobs = jobs;
        let outcome = run_regression(&cfg, &baseline, 0.0001).unwrap();
        assert_eq!(outcome.checked(), 3);
        assert!(outcome.passed(), "jobs={jobs}: {:?}", outcome.regressions());
    }
}

#[test]
fn unknown_coordinates_are_named_errors_not_panics() {
    // Unknown metric id, naming the offending row.
    let e = parse_baseline_csv("id,system,value\nOH-001,hami,1.0\nZZ-999,hami,2.0\n", "native")
        .unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("row 3"), "{msg}");
    assert!(msg.contains("ZZ-999"), "{msg}");
    // Unknown system, naming the offending row.
    let e = parse_baseline_csv("id,system,value\nOH-001,vgpu,1.0\n", "native").unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("row 2"), "{msg}");
    assert!(msg.contains("vgpu"), "{msg}");
    // Same for the sweep schema.
    let hdr = "system,tenants,quota_pct,feasible,id,value\n";
    let e = parse_baseline_csv(&format!("{hdr}hami,2,50,true,ZZ-999,1.0\n"), "native")
        .unwrap_err();
    assert!(format!("{e:#}").contains("ZZ-999"), "{e:#}");
    // And for the extended schema's topology fields.
    let hdr = "system,tenants,quota_pct,gpu_count,link,feasible,id,value\n";
    let e = parse_baseline_csv(&format!("{hdr}hami,2,50,4,infiniband,true,OH-001,1.0\n"), "native")
        .unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("row 2"), "{msg}");
    assert!(msg.contains("infiniband"), "{msg}");
}

#[test]
fn malformed_and_mixed_schema_baselines_are_rejected() {
    // Half a sweep header is neither schema.
    let e = parse_baseline_csv("system,quota_pct,id,value\nhami,50,OH-001,1.0\n", "native")
        .unwrap_err();
    assert!(format!("{e:#}").contains("mixed-schema"), "{e:#}");
    // Half a topology coordinate is neither generation.
    let e = parse_baseline_csv(
        "system,tenants,quota_pct,link,feasible,id,value\nhami,2,50,pcie,true,OH-001,1.0\n",
        "native",
    )
    .unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("gpu_count") && msg.contains("link"), "{msg}");
    // A sweep surface concatenated under a point table: the stray header
    // row is rejected by name, not silently skipped.
    let glued = "id,system,value\nOH-001,hami,1.0\nsystem,tenants,quota_pct,gpu_count,link,is_baseline,feasible,id,value,overall_score,delta_vs_baseline_pct,grade\n";
    let e = parse_baseline_csv(glued, "native").unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("row 3"), "{msg}");
    // Truncated sweep rows are named.
    let hdr = "system,tenants,quota_pct,feasible,id,value\n";
    let e = parse_baseline_csv(&format!("{hdr}hami,2,50,true\n"), "native").unwrap_err();
    assert!(format!("{e:#}").contains("row 2"), "{e:#}");
}
