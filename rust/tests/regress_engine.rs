//! End-to-end coverage of the sweep-aware regression subsystem: a fresh
//! sweep surface, rendered to the long-format CSV and parsed back, must
//! regress clean against itself at any job count; infeasible cells are
//! skipped; a single perturbed cell is flagged with its exact coordinate;
//! malformed and mixed-schema baselines are rejected with named rows.

use gvb::coordinator::executor;
use gvb::coordinator::sweep::{run_sweep, SweepSpec};
use gvb::metrics::{taxonomy, Category, Direction, RunConfig};
use gvb::regress::{parse_baseline_csv, render_json, render_markdown, run_regression, BaselineSchema};
use gvb::report::sweep::render_csv;

fn base() -> RunConfig {
    let mut cfg = RunConfig::quick("native");
    cfg.seed = 42;
    cfg
}

fn spec() -> SweepSpec {
    SweepSpec {
        systems: vec!["hami".into(), "fcsp".into()],
        tenants: vec![1, 2],
        quotas: vec![50, 100],
        categories: Some(vec![Category::Pcie]),
    }
}

#[test]
fn sweep_baseline_roundtrips_clean_at_jobs_1_and_8() {
    let surface = run_sweep(&base(), &spec(), 2);
    let csv = render_csv(&surface);
    let baseline = parse_baseline_csv(&csv, "native").unwrap();
    assert_eq!(baseline.schema, BaselineSchema::Sweep);
    // 2 systems × 4 scenarios ((1,100) in-grid) × 4 PCIe metrics.
    assert_eq!(baseline.rows.len(), 32);
    assert!(baseline.infeasible.is_empty());
    for jobs in [1, 8] {
        let mut cfg = base();
        cfg.jobs = jobs;
        let outcome = run_regression(&cfg, &baseline, 0.0001).unwrap();
        assert_eq!(outcome.checked(), 32);
        assert!(
            outcome.passed(),
            "jobs={jobs}: {:?}",
            outcome
                .regressions()
                .iter()
                .map(|r| format!("{}/{}/{}", r.system, r.cell_label(), r.id))
                .collect::<Vec<_>>()
        );
    }
}

#[test]
fn infeasible_cells_are_skipped_not_flagged() {
    // MIG cannot host 8 tenants; the surface records the cell as
    // infeasible and the regress engine skips it.
    let spec = SweepSpec {
        systems: vec!["mig".into()],
        tenants: vec![8],
        quotas: vec![50],
        categories: Some(vec![Category::Pcie]),
    };
    let surface = run_sweep(&base(), &spec, 2);
    let csv = render_csv(&surface);
    let baseline = parse_baseline_csv(&csv, "native").unwrap();
    // Only the injected (1,100) baseline cell carries metric rows.
    assert_eq!(baseline.rows.len(), 4);
    assert_eq!(baseline.infeasible, vec![("mig".to_string(), 8, 50)]);
    let outcome = run_regression(&base(), &baseline, 1.0).unwrap();
    assert_eq!(outcome.checked(), 4);
    assert_eq!(outcome.skipped_infeasible, 1);
    assert!(outcome.passed(), "{:?}", outcome.regressions());
    // The skip is surfaced in both machine-readable reports.
    let j = render_json(&outcome, "b.csv");
    assert!(j.contains("\"skipped_infeasible\": 1"), "{j}");
    let m = render_markdown(&outcome, "b.csv");
    assert!(m.contains("1 infeasible cell(s) skipped"), "{m}");
}

#[test]
fn injected_regression_is_detected_with_its_cell_coordinate() {
    let surface = run_sweep(&base(), &spec(), 2);
    let csv = render_csv(&surface);
    let mut baseline = parse_baseline_csv(&csv, "native").unwrap();
    // Perturb exactly one non-baseline cell's metric against its
    // direction, so the unchanged re-run reads as a large regression.
    let idx = baseline
        .rows
        .iter()
        .position(|r| {
            r.system == "hami"
                && r.cell == Some((2, 50))
                && r.value > 1e-3
                && !matches!(
                    taxonomy::by_id(&r.id).unwrap().direction,
                    Direction::Boolean
                )
        })
        .expect("a perturbable hami 2t@50% row");
    let (system, cell, id) = {
        let row = &mut baseline.rows[idx];
        match taxonomy::by_id(&row.id).unwrap().direction {
            Direction::LowerBetter => row.value /= 2.0,
            Direction::HigherBetter => row.value *= 2.0,
            Direction::Boolean => unreachable!("filtered out above"),
        }
        (row.system.clone(), row.cell, row.id.clone())
    };
    let outcome = run_regression(&base(), &baseline, 5.0).unwrap();
    assert!(!outcome.passed());
    let regressions = outcome.regressions();
    assert_eq!(regressions.len(), 1, "{regressions:?}");
    assert_eq!(regressions[0].system, system);
    assert_eq!(regressions[0].cell, cell);
    assert_eq!(regressions[0].id, id);
    assert!(regressions[0].worse_percent > 5.0);
    // Both reports name the offending cell and flip to FAIL.
    let j = render_json(&outcome, "b.csv");
    assert!(j.contains("\"passed\": false"), "{j}");
    assert!(j.contains("\"regression_count\": 1"), "{j}");
    let m = render_markdown(&outcome, "b.csv");
    assert!(m.contains("❌ FAIL"), "{m}");
    assert!(m.contains(&format!("| {} | 2t@50% | {} |", system, id)), "{m}");
}

#[test]
fn point_baseline_roundtrips_through_the_same_engine() {
    // A hand-rolled point table (the `gvbench run --format csv` schema,
    // reduced to its regress-relevant columns) re-runs at the
    // invocation's operating point and compares clean at any job count.
    let cfg = base();
    let tasks = vec![
        executor::Task { system: "native".into(), metric_id: "PCIE-001" },
        executor::Task { system: "hami".into(), metric_id: "PCIE-001" },
        executor::Task { system: "fcsp".into(), metric_id: "BW-003" },
    ];
    let (results, _) = executor::execute(&cfg, &tasks, 1);
    let mut csv = String::from("id,system,value\n");
    for r in &results {
        // 6-decimal recording resolution, exactly as the CSV reporter
        // writes it — the comparison guard must absorb the rounding.
        csv.push_str(&format!("{},{},{:.6}\n", r.id, r.system, r.value));
    }
    let baseline = parse_baseline_csv(&csv, "native").unwrap();
    assert_eq!(baseline.schema, BaselineSchema::Point);
    for jobs in [1, 8] {
        let mut cfg = base();
        cfg.jobs = jobs;
        let outcome = run_regression(&cfg, &baseline, 0.0001).unwrap();
        assert_eq!(outcome.checked(), 3);
        assert!(outcome.passed(), "jobs={jobs}: {:?}", outcome.regressions());
    }
}

#[test]
fn unknown_coordinates_are_named_errors_not_panics() {
    // Unknown metric id, naming the offending row.
    let e = parse_baseline_csv("id,system,value\nOH-001,hami,1.0\nZZ-999,hami,2.0\n", "native")
        .unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("row 3"), "{msg}");
    assert!(msg.contains("ZZ-999"), "{msg}");
    // Unknown system, naming the offending row.
    let e = parse_baseline_csv("id,system,value\nOH-001,vgpu,1.0\n", "native").unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("row 2"), "{msg}");
    assert!(msg.contains("vgpu"), "{msg}");
    // Same for the sweep schema.
    let hdr = "system,tenants,quota_pct,feasible,id,value\n";
    let e = parse_baseline_csv(&format!("{hdr}hami,2,50,true,ZZ-999,1.0\n"), "native")
        .unwrap_err();
    assert!(format!("{e:#}").contains("ZZ-999"), "{e:#}");
}

#[test]
fn malformed_and_mixed_schema_baselines_are_rejected() {
    // Half a sweep header is neither schema.
    let e = parse_baseline_csv("system,quota_pct,id,value\nhami,50,OH-001,1.0\n", "native")
        .unwrap_err();
    assert!(format!("{e:#}").contains("mixed-schema"), "{e:#}");
    // A sweep surface concatenated under a point table: the stray header
    // row is rejected by name, not silently skipped.
    let glued = "id,system,value\nOH-001,hami,1.0\nsystem,tenants,quota_pct,is_baseline,feasible,id,value,overall_score,delta_vs_baseline_pct,grade\n";
    let e = parse_baseline_csv(glued, "native").unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("row 3"), "{msg}");
    // Truncated sweep rows are named.
    let hdr = "system,tenants,quota_pct,feasible,id,value\n";
    let e = parse_baseline_csv(&format!("{hdr}hami,2,50,true\n"), "native").unwrap_err();
    assert!(format!("{e:#}").contains("row 2"), "{e:#}");
}
