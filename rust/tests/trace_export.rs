//! The observability layer's end-to-end guarantees, proven through the
//! public CLI surface:
//!
//! 1. **Clock-domain determinism** — the virtual-time Chrome trace that
//!    `gvbench dynamics --trace-out` writes is **byte-identical** at
//!    `--jobs 1` and `--jobs 8`: every span derives from the replay's
//!    deterministic virtual clock, never from host timing.
//! 2. **Well-formedness** — the file is valid trace-event JSON (the
//!    object flavour Perfetto loads): every event carries `ph`/`pid`/
//!    `tid`/`ts`, complete spans have non-negative `dur`, and tenant
//!    lanes match the tenants the replay actually saw.
//! 3. **Fixture export round-trip** — `--export-trace` renders a preset
//!    through the trace grammar; the exported file re-parses to the
//!    same rendering (parse∘render identity), exports reproducibly, and
//!    replays through `--trace` byte-identically at any worker count.

use gvb::cli::args::{Args, Command};
use gvb::cli::commands::dispatch;
use gvb::dynsim::{self, DynSpec};
use gvb::metrics::RunConfig;
use gvb::obs::chrome;
use gvb::serve::jsonl::{self, Value};

fn spec() -> DynSpec {
    DynSpec {
        systems: vec!["native".to_string(), "hami".to_string()],
        scenarios: vec![dynsim::scenario::canonical("mixed-churn").unwrap()],
        duration_ms: 400,
        window_ms: 50,
        trace: None,
    }
}

fn dynamics_args() -> Args {
    let mut a = Args::default();
    a.command = Command::Dynamics;
    a.system = "native".to_string();
    a.system_set = true;
    a.quick = true;
    a.dyn_scenarios = Some(vec!["mixed-churn".to_string()]);
    a.duration_ms = Some(400);
    a.window_ms = Some(50);
    a.format = "csv".to_string();
    a
}

#[test]
fn virtual_trace_is_byte_identical_across_worker_counts() {
    let cfg = RunConfig::quick("native");
    let (_, one) = dynsim::run_dynamics_traced(&cfg, &spec(), 1);
    let (_, eight) = dynsim::run_dynamics_traced(&cfg, &spec(), 8);
    let a = chrome::render_virtual(&one);
    let b = chrome::render_virtual(&eight);
    assert_eq!(a, b, "virtual-time trace must not depend on --jobs");
    assert!(a.len() > 1_000, "trace should carry real content: {} bytes", a.len());
}

#[test]
fn virtual_trace_is_wellformed_trace_event_json() {
    let cfg = RunConfig::quick("native");
    let (surface, tasks) = dynsim::run_dynamics_traced(&cfg, &spec(), 4);
    let text = chrome::render_virtual(&tasks);
    let v = jsonl::parse(text.trim_end()).expect("trace must be one valid JSON object");
    assert_eq!(v.get("displayTimeUnit").and_then(Value::as_str), Some("ms"));
    let events = v.get("traceEvents").and_then(Value::as_array).expect("traceEvents array");
    assert!(!events.is_empty());
    let mut complete = 0usize;
    for e in events {
        for key in ["ph", "pid", "tid"] {
            assert!(e.get(key).is_some(), "event lacks {key}");
        }
        let ph = e.get("ph").and_then(Value::as_str).expect("string ph");
        assert!(matches!(ph, "X" | "i" | "M"), "unexpected phase {ph}");
        if ph != "M" {
            let ts = e.get("ts").and_then(Value::as_f64).expect("numeric ts");
            assert!(ts >= 0.0);
            // All spans live inside the replayed horizon (400 ms = 4e5 µs).
            assert!(ts <= 400_000.0, "ts {ts} outside the horizon");
        }
        if ph == "X" {
            complete += 1;
            let dur = e.get("dur").and_then(Value::as_f64).expect("numeric dur");
            assert!(dur >= 0.0, "end-before-start span");
        }
    }
    assert!(complete > 0, "a mixed-churn replay must produce complete spans");
    // Span tenant lanes are exactly the tenants each replay admitted
    // (plus lane 0, the timeline lane) — cross-checked against the
    // surface the same runs produced.
    for (t, run) in tasks.iter().zip(surface.runs.iter()) {
        assert_eq!(t.system, run.system);
        for s in &t.spans {
            if let Some(tenant) = s.tenant {
                assert!(
                    run.tenants.contains(&tenant),
                    "span on tenant {tenant} unknown to the {} replay",
                    t.system
                );
            }
        }
    }
}

#[test]
fn cli_trace_out_files_match_across_worker_counts() {
    let dir = std::env::temp_dir();
    let p1 = dir.join("gvb_test_trace_out_j1.json");
    let p8 = dir.join("gvb_test_trace_out_j8.json");
    let mut a = dynamics_args();
    a.out = Some(dir.join("gvb_test_trace_out_surface.csv").to_str().unwrap().to_string());
    a.jobs = Some(1);
    a.trace_out = Some(p1.to_str().unwrap().to_string());
    dispatch(&a).unwrap();
    a.jobs = Some(8);
    a.trace_out = Some(p8.to_str().unwrap().to_string());
    dispatch(&a).unwrap();
    let one = std::fs::read_to_string(&p1).unwrap();
    let eight = std::fs::read_to_string(&p8).unwrap();
    assert_eq!(one, eight, "--trace-out must be byte-identical at any --jobs");
    assert!(jsonl::parse(one.trim_end()).is_ok());
    for p in [&p1, &p8] {
        std::fs::remove_file(p).ok();
    }
    std::fs::remove_file(a.out.as_deref().unwrap()).ok();
}

#[test]
fn export_trace_round_trips_and_replays_deterministically() {
    let dir = std::env::temp_dir();
    let fixture = dir.join("gvb_test_export_mixed_churn.txt");
    let fixture2 = dir.join("gvb_test_export_mixed_churn_again.txt");
    let mut a = dynamics_args();
    a.export_trace = Some(fixture.to_str().unwrap().to_string());
    dispatch(&a).unwrap();
    a.export_trace = Some(fixture2.to_str().unwrap().to_string());
    dispatch(&a).unwrap();
    let text = std::fs::read_to_string(&fixture).unwrap();
    // Exporting is deterministic…
    assert_eq!(text, std::fs::read_to_string(&fixture2).unwrap());
    // …carries the preset's geometry as editable headers…
    assert!(text.contains("duration-ms 400"), "{text}");
    assert!(text.contains("window-ms 50"), "{text}");
    // …and round-trips through the parser to the identical rendering.
    let parsed = dynsim::parse_trace(&text).unwrap();
    assert_eq!(dynsim::render_trace(&parsed), text);
    assert!(!parsed.events.is_empty());

    // Replaying the exported fixture through --trace produces the same
    // summary bytes at any worker count.
    let s1 = dir.join("gvb_test_export_replay_j1.csv");
    let s8 = dir.join("gvb_test_export_replay_j8.csv");
    let mut r = Args::default();
    r.command = Command::Dynamics;
    r.system = "native".to_string();
    r.system_set = true;
    r.quick = true;
    r.trace = Some(fixture.to_str().unwrap().to_string());
    r.format = "csv".to_string();
    r.out = Some(dir.join("gvb_test_export_replay_series.csv").to_str().unwrap().to_string());
    r.jobs = Some(1);
    r.summary_out = Some(s1.to_str().unwrap().to_string());
    dispatch(&r).unwrap();
    r.jobs = Some(8);
    r.summary_out = Some(s8.to_str().unwrap().to_string());
    dispatch(&r).unwrap();
    let one = std::fs::read_to_string(&s1).unwrap();
    assert_eq!(one, std::fs::read_to_string(&s8).unwrap());
    // The replay rides the reserved `trace` scenario coordinate.
    assert!(one.contains(",trace,"), "{one}");
    for p in [&fixture, &fixture2, &s1, &s8] {
        std::fs::remove_file(p).ok();
    }
    std::fs::remove_file(r.out.as_deref().unwrap()).ok();
}
