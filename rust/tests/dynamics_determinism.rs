//! The dynsim subsystem's determinism guarantee, proven end-to-end: the
//! same dynamics grid and seed produce a **bit-identical** time-series
//! surface at `--jobs 1` and `--jobs 8` (per-task seeds are pure
//! functions of the run seed and the (system, scenario, duration,
//! window) coordinates), the rendered CSV surfaces — which carry no host
//! timings — match byte-for-byte, and the summary CSV round-trips
//! through the regression engine with a clean pass against itself.
//!
//! Since the event-queue rewrite the rendered surfaces must also match
//! the committed goldens under `tests/goldens/` byte-for-byte at both
//! job counts — the goldens were blessed from the pre-rewrite engine's
//! output, so they carry the old-vs-new equivalence proof (the frozen
//! in-tree reference engine has been retired in favour of these pins).

use gvb::dynsim::{run_dynamics, DynSpec, DynSurface, ScenarioRun, ScenarioSpec};
use gvb::metrics::RunConfig;
use gvb::report::dynamics::{render_csv, render_summary_csv};

fn spec() -> DynSpec {
    DynSpec {
        systems: vec!["native".into(), "hami".into()],
        scenarios: vec!["churn", "failover"],
        duration_ms: 300,
        window_ms: 50,
        trace: None,
    }
}

/// The training-preset grid: same geometry as `spec()`, but over the two
/// training-bearing presets. Kept out of `spec()` so the inference-only
/// goldens (which predate training) keep pinning exactly the grid they
/// were blessed against.
fn train_spec() -> DynSpec {
    DynSpec {
        systems: vec!["native".into(), "hami".into()],
        scenarios: vec!["train-steady", "mixed-churn"],
        duration_ms: 300,
        window_ms: 50,
        trace: None,
    }
}

/// The committed CI fixture as a replayable grid: the trace's headers
/// carry the geometry, exactly as `gvbench dynamics --trace` builds it.
fn trace_spec() -> (DynSpec, ScenarioSpec) {
    let tr = gvb::dynsim::parse_trace(include_str!("../../ci/trace_mixed.txt"))
        .expect("ci/trace_mixed.txt parses");
    let grid = DynSpec {
        systems: vec!["native".into(), "hami".into()],
        scenarios: vec![gvb::dynsim::TRACE_SCENARIO],
        duration_ms: tr.duration_ms,
        window_ms: tr.window_ms,
        trace: Some(tr.clone()),
    };
    (grid, tr)
}

fn base() -> RunConfig {
    let mut cfg = RunConfig::quick("native");
    cfg.seed = 42;
    cfg
}

fn assert_runs_bit_identical(x: &ScenarioRun, y: &ScenarioRun) {
    let ctx = format!("{}/{}", x.system, x.scenario);
    assert_eq!(x.system, y.system, "{ctx}: run order diverged");
    assert_eq!(x.scenario, y.scenario, "{ctx}: run order diverged");
    assert_eq!(x.windows, y.windows, "{ctx}");
    assert_eq!(x.tenants, y.tenants, "{ctx}");
    assert_eq!(x.completed, y.completed, "{ctx}");
    assert_eq!(x.failed, y.failed, "{ctx}");
    assert_eq!(x.recovery, y.recovery, "{ctx}");
    assert_eq!(x.occurrences, y.occurrences, "{ctx}");
    assert_eq!(x.series.len(), y.series.len(), "{ctx}");
    for (p, q) in x.series.iter().zip(&y.series) {
        assert_eq!(p.id, q.id, "{ctx}: series order diverged");
        assert_eq!(p.window, q.window, "{ctx}/{}", p.id);
        assert_eq!(p.tenant, q.tenant, "{ctx}/{}", p.id);
        assert_eq!(
            p.value.to_bits(),
            q.value.to_bits(),
            "{ctx}/{} window {}: {} vs {}",
            p.id,
            p.window,
            p.value,
            q.value
        );
    }
    assert_eq!(x.summary.len(), y.summary.len(), "{ctx}");
    for ((ia, va), (ib, vb)) in x.summary.iter().zip(&y.summary) {
        assert_eq!(ia, ib, "{ctx}: summary order");
        assert_eq!(va.to_bits(), vb.to_bits(), "{ctx}/{ia}");
    }
}

fn assert_surfaces_bit_identical(a: &DynSurface, b: &DynSurface) {
    assert_eq!(a.seed, b.seed);
    assert_eq!(a.runs.len(), b.runs.len());
    for (x, y) in a.runs.iter().zip(&b.runs) {
        assert_runs_bit_identical(x, y);
    }
}

#[test]
fn dynamics_surface_bit_identical_at_any_job_count() {
    let base = base();
    let serial = run_dynamics(&base, &spec(), 1);
    let sharded = run_dynamics(&base, &spec(), 8);
    assert_eq!(serial.stats.jobs, 1);
    assert_eq!(sharded.stats.jobs, 8);
    // 2 systems × 2 scenarios.
    assert_eq!(serial.runs.len(), 4);
    assert_eq!(serial.stats.tasks.len(), 4);
    assert_surfaces_bit_identical(&serial, &sharded);
    // The rendered surfaces (no host timings) match byte-for-byte.
    assert_eq!(render_csv(&serial), render_csv(&sharded));
    assert_eq!(render_summary_csv(&serial), render_summary_csv(&sharded));
}

#[test]
fn dynamics_is_a_pure_function_of_the_seed() {
    let a = run_dynamics(&base(), &spec(), 4);
    let b = run_dynamics(&base(), &spec(), 4);
    assert_surfaces_bit_identical(&a, &b);
    let mut other = base();
    other.seed = 43;
    let c = run_dynamics(&other, &spec(), 4);
    assert!(
        a.runs.iter().zip(&c.runs).any(|(x, y)| {
            x.series
                .iter()
                .zip(&y.series)
                .any(|(p, q)| p.value.to_bits() != q.value.to_bits())
        }),
        "seed change did not affect the surface"
    );
}

/// Compare `rendered` against the committed golden `tests/goldens/<name>`.
/// `GVB_BLESS=1` rewrites the golden; a *missing* golden is written and
/// loudly noted instead of failing, so the first toolchain-equipped run
/// pins the bytes every later run (and CI) is held to.
fn check_committed_golden(name: &str, rendered: &str) {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/goldens")
        .join(name);
    let body = format!("{}\n", rendered.trim_end());
    let bless = std::env::var("GVB_BLESS").is_ok();
    if bless || !path.exists() {
        std::fs::create_dir_all(path.parent().expect("goldens dir")).expect("mkdir goldens");
        std::fs::write(&path, &body).expect("write golden");
        if !bless {
            eprintln!(
                "note: golden {} was missing and has been blessed from this run; \
                 commit it so future runs are pinned to these bytes",
                path.display()
            );
        }
        return;
    }
    let expected = std::fs::read_to_string(&path).expect("read golden");
    assert_eq!(
        expected,
        body,
        "{name} diverged from the committed golden (GVB_BLESS=1 regenerates after an \
         intended surface change)"
    );
}

#[test]
fn rendered_surfaces_match_the_committed_golden() {
    // Byte-level pin of the dynamics CSV surfaces (the goldens were
    // blessed from the pre-rewrite engine's output, so this holds the
    // event core to the old loop's exact bytes), checked at both job
    // counts — the committed artifact that carries the ISSUE-7
    // equivalence contract now that the in-tree reference engine is
    // retired.
    for jobs in [1usize, 8] {
        let surface = run_dynamics(&base(), &spec(), jobs);
        check_committed_golden("dynamics_series.csv", &render_csv(&surface));
        check_committed_golden("dynamics_summary.csv", &render_summary_csv(&surface));
    }
}

#[test]
fn timelines_actually_diverge_across_systems_and_scenarios() {
    // Sanity against a degenerate pass: the interception system must not
    // produce the same timeline as native, and churn must not equal
    // failover on the same system.
    let surface = run_dynamics(&base(), &spec(), 0);
    let run_of = |system: &str, scenario: &str| {
        surface
            .runs
            .iter()
            .find(|r| r.system == system && r.scenario == scenario)
            .unwrap()
    };
    let native = run_of("native", "churn");
    let hami = run_of("hami", "churn");
    assert!(
        native
            .series
            .iter()
            .zip(&hami.series)
            .any(|(p, q)| p.value.to_bits() != q.value.to_bits()),
        "hami timeline identical to native"
    );
    let failover = run_of("hami", "failover");
    assert!(failover.recovery.is_some());
    assert!(hami.recovery.is_none());
}

#[test]
fn injected_fault_recovery_is_attributed_to_the_right_tenant_and_window() {
    let surface = run_dynamics(&base(), &spec(), 2);
    for system in ["native", "hami"] {
        let run = surface
            .runs
            .iter()
            .find(|r| r.system == system && r.scenario == "failover")
            .unwrap();
        let rec = run
            .recovery
            .unwrap_or_else(|| panic!("{system}/failover recorded no recovery"));
        // The failover preset faults tenant 2 at 40% of the 300 ms
        // horizon.
        assert_eq!(rec.tenant, 2, "{system}");
        assert_eq!(rec.fault_ns, 120_000_000, "{system}");
        assert!(rec.recovered_ns > rec.fault_ns, "{system}");
        // The summary carries the same recovery time…
        assert_eq!(
            run.summary_value("DYN-RECOVERY"),
            Some(rec.recovery_ms()),
            "{system}"
        );
        // …and the windowed marker lands in the recovery window, on the
        // faulted tenant (window 2 of 6 is the fault window; recovery can
        // only complete there or later).
        let markers: Vec<_> = run.series.iter().filter(|p| p.id == "DYN-RECOVERY").collect();
        assert_eq!(markers.len(), 1, "{system}");
        assert_eq!(markers[0].tenant, Some(2), "{system}");
        assert_eq!(markers[0].window, run.window_of(rec.recovered_ns), "{system}");
        assert!(markers[0].window >= 2, "{system}: window {}", markers[0].window);
        assert!((markers[0].value - rec.recovery_ms()).abs() < 1e-12, "{system}");
    }
}

#[test]
fn summary_round_trips_through_the_regression_engine() {
    let base = base();
    let surface = run_dynamics(&base, &spec(), 4);
    let summary = render_summary_csv(&surface);
    let baseline = gvb::regress::parse_baseline_csv(&summary, "native").unwrap();
    assert_eq!(baseline.schema, gvb::regress::BaselineSchema::Dynamics);
    // 4 timelines × 5 summary statistics (DYN-EVENTS included, so the
    // occurrence count is value-gated like any other summary cell).
    assert_eq!(baseline.rows.len(), 20);
    assert!(baseline.rows.iter().any(|r| r.id == "DYN-EVENTS"));
    // Re-run at both job counts: clean pass with a tight threshold.
    for jobs in [1usize, 8] {
        let mut cfg = base.clone();
        cfg.jobs = jobs;
        let out = gvb::regress::run_regression(&cfg, &baseline, 0.0001).unwrap();
        assert_eq!(out.checked(), 20);
        assert!(out.passed(), "jobs={jobs}: {:?}", out.regressions());
        assert_eq!(out.schema, gvb::regress::BaselineSchema::Dynamics);
    }
}

#[test]
fn training_surface_bit_identical_at_any_job_count() {
    // The tentpole determinism claim extended to the training presets:
    // the gradient-allreduce path, step pacing and mixed train+infer
    // interference all ride the same per-task seed derivation, so the
    // surface is byte-identical at every job count.
    let base = base();
    let serial = run_dynamics(&base, &train_spec(), 1);
    let sharded = run_dynamics(&base, &train_spec(), 8);
    assert_eq!(serial.runs.len(), 4);
    assert_surfaces_bit_identical(&serial, &sharded);
    assert_eq!(render_csv(&serial), render_csv(&sharded));
    assert_eq!(render_summary_csv(&serial), render_summary_csv(&sharded));
    for run in &serial.runs {
        assert!(run.train_steps > 0, "{}/{}: no training steps", run.system, run.scenario);
        // Training timelines carry the three training statistics on top
        // of the five inference ones.
        assert_eq!(run.summary.len(), 8, "{}/{}", run.system, run.scenario);
        assert!(
            run.summary_value("DYN-TRAIN-STEP-P99").is_some_and(|v| v > 0.0),
            "{}/{}: missing DYN-TRAIN-STEP-P99",
            run.system,
            run.scenario
        );
        for id in ["DYN-ALLREDUCE", "DYN-MIX-INTERFERENCE"] {
            assert!(
                run.summary_value(id).is_some(),
                "{}/{}: missing {id}",
                run.system,
                run.scenario
            );
        }
        // train-steady's 20 Hz streams cross the 4-step accumulation
        // boundary inside the 300 ms horizon, so an allreduce must have
        // actually happened there.
        if run.scenario == "train-steady" {
            assert!(
                run.summary_value("DYN-ALLREDUCE").is_some_and(|v| v > 0.0),
                "{}/train-steady: no allreduce landed",
                run.system
            );
        }
    }
}

#[test]
fn training_surfaces_match_the_committed_golden() {
    // Byte-level pin of the training-grid surfaces, checked at both job
    // counts like the inference goldens above.
    for jobs in [1usize, 8] {
        let surface = run_dynamics(&base(), &train_spec(), jobs);
        check_committed_golden("dynamics_train_series.csv", &render_csv(&surface));
        check_committed_golden("dynamics_train_summary.csv", &render_summary_csv(&surface));
    }
}

#[test]
fn trace_replay_bit_identical_at_any_job_count() {
    // Deterministic external replay: the committed CI fixture replays to
    // a byte-identical surface at --jobs 1 and --jobs 8, and the mixed
    // tenant population exercises both the training and inference paths.
    let base = base();
    let (grid, _) = trace_spec();
    let serial = run_dynamics(&base, &grid, 1);
    let sharded = run_dynamics(&base, &grid, 8);
    // 2 systems × the single trace timeline.
    assert_eq!(serial.runs.len(), 2);
    assert_surfaces_bit_identical(&serial, &sharded);
    assert_eq!(render_csv(&serial), render_csv(&sharded));
    assert_eq!(render_summary_csv(&serial), render_summary_csv(&sharded));
    for run in &serial.runs {
        assert_eq!(run.scenario, gvb::dynsim::TRACE_SCENARIO);
        assert_eq!((run.duration_ms, run.window_ms), (400, 50));
        assert!(run.completed > 0, "{}: no inference requests", run.system);
        assert!(run.train_steps > 0, "{}: no training steps", run.system);
        assert!(run.summary_value("DYN-TRAIN-STEP-P99").is_some(), "{}", run.system);
    }
}

#[test]
fn trace_summary_round_trips_through_the_regression_engine() {
    use gvb::coordinator::executor::Backend;

    let base = base();
    let (grid, tr) = trace_spec();
    let surface = run_dynamics(&base, &grid, 4);
    let summary = render_summary_csv(&surface);
    let baseline = gvb::regress::parse_baseline_csv(&summary, "native").unwrap();
    assert_eq!(baseline.schema, gvb::regress::BaselineSchema::Dynamics);
    // 2 timelines × 8 summary statistics (training rows included).
    assert_eq!(baseline.rows.len(), 16);
    // Re-supplying the producing trace replays clean at both job counts.
    for jobs in [1usize, 8] {
        let mut cfg = base.clone();
        cfg.jobs = jobs;
        let out = gvb::regress::run_regression_with_trace(
            &Backend::Scoped(jobs),
            &cfg,
            &baseline,
            0.0001,
            None,
            Some(&tr),
        )
        .unwrap();
        assert_eq!(out.checked(), 16);
        assert!(out.passed(), "jobs={jobs}: {:?}", out.regressions());
    }
    // Without the trace the rows are unreplayable, and the error says
    // how to fix it.
    let err = gvb::regress::run_regression(&base, &baseline, 0.0001).unwrap_err();
    assert!(format!("{err:#}").contains("--trace"), "{err:#}");
}
