//! The serve subsystem's core contract, proven end-to-end against an
//! in-process daemon: a job submitted to a **warm** daemon produces a
//! report **bit-identical** to its one-shot CLI equivalent — for all
//! four grid schemas, at pool sizes 1 and 8, regardless of queue order
//! and of other jobs having run first on the same pool. The comparisons
//! use CSV renders, which carry no host timings (JSON embeds the
//! execution object, whose wall-clock fields legitimately differ).
//!
//! Also pinned here: the NDJSON lifecycle stream is well-formed
//! (`queued` → `scheduled` → `task_completed` × N → `report` →
//! `finished`) with the idle-time accounting fields present; a
//! malformed job yields a *named* `failed` event without poisoning the
//! shared worker pool; forbidden flags and protocol garbage are refused
//! at the socket; the `stats` op answers telemetry counters consistent
//! with the lifecycle events that produced them; and shutdown drains
//! accepted jobs, joins every thread and removes the socket file.

use std::path::PathBuf;

use gvb::cli::args::Command;
use gvb::cli::commands;
use gvb::cli::Args;
use gvb::coordinator::executor::Backend;
use gvb::report::Format;
use gvb::serve::jsonl::{self, Value};
use gvb::serve::{client, Daemon, ServeConfig};

/// One small, fast job per servable grid schema (all CSV + `--quick`).
const RUN_JOB: &[&str] = &["run", "--all-systems", "--metric", "OH-009", "--quick", "--format", "csv"];
const SWEEP_JOB: &[&str] = &[
    "sweep", "--system", "native", "--tenants", "1,2", "--quota", "50,100", "--category", "pcie",
    "--quick", "--format", "csv",
];
const DYN_JOB: &[&str] = &[
    "dynamics", "--system", "native", "--scenario", "steady", "--duration-ms", "200",
    "--window-ms", "50", "--quick", "--format", "csv",
];
const CLUSTER_JOB: &[&str] = &[
    "cluster", "--system", "native", "--policies", "first-fit", "--nodes", "2", "--scenario",
    "churn", "--quick", "--format", "csv",
];

fn sock(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("gvb_serve_{name}_{}.sock", std::process::id()))
}

fn argv(tokens: &[&str]) -> Vec<String> {
    tokens.iter().map(|s| (*s).to_string()).collect()
}

/// Render the job the way its one-shot CLI command would: same
/// `Args::parse`, same spec builders, serial scoped execution.
fn one_shot(tokens: &[&str]) -> String {
    let args = Args::parse(&argv(tokens)).expect("job argv parses");
    let fmt = Format::from_key(&args.format).expect("known format");
    match args.command {
        Command::Run => {
            commands::run_report_on(&args, &Backend::Scoped(1), None).expect("run succeeds").0
        }
        Command::Sweep => {
            let i = commands::sweep_inputs(&args).expect("sweep inputs");
            gvb::report::sweep::render(&gvb::coordinator::sweep::run_sweep(&i.cfg, &i.spec, 1), fmt)
        }
        Command::Dynamics => {
            let i = commands::dynamics_inputs(&args).expect("dynamics inputs");
            gvb::report::dynamics::render(&gvb::dynsim::run_dynamics(&i.cfg, &i.spec, 1), fmt)
        }
        Command::Cluster => {
            let i = commands::cluster_inputs(&args).expect("cluster inputs");
            gvb::report::cluster::render(&gvb::cluster::run_cluster(&i.cfg, &i.spec, 1), fmt)
        }
        _ => unreachable!("only grid schemas are exercised here"),
    }
}

#[test]
fn served_reports_bit_identical_to_one_shot_at_any_pool_size() {
    let jobs: [&[&str]; 4] = [RUN_JOB, SWEEP_JOB, DYN_JOB, CLUSTER_JOB];
    let references: Vec<String> = jobs.iter().map(|j| one_shot(j)).collect();
    for pool in [1usize, 8] {
        let socket = sock(&format!("pool{pool}"));
        let daemon =
            Daemon::start(ServeConfig { socket: socket.clone(), jobs: pool }).expect("daemon");
        assert_eq!(daemon.workers(), pool);
        for (tokens, want) in jobs.iter().zip(&references) {
            let out = client::submit_and_wait(&socket, &argv(tokens), 0, &mut |_| {})
                .unwrap_or_else(|e| panic!("{}: {e}", tokens[0]));
            assert!(out.error.is_none(), "{}: {:?}", tokens[0], out.error);
            assert_eq!(
                out.report.as_deref(),
                Some(want.as_str()),
                "served {} diverged from its one-shot render at pool={pool}",
                tokens[0]
            );
        }
        // Dropping an un-waited daemon shuts it down and removes the
        // socket — the in-process equivalent of `jobs --shutdown`.
        drop(daemon);
        assert!(!socket.exists(), "socket file survived shutdown");
    }
}

#[test]
fn results_independent_of_queue_order_and_prior_jobs() {
    let want = one_shot(RUN_JOB);
    let socket = sock("order");
    let _daemon = Daemon::start(ServeConfig { socket: socket.clone(), jobs: 2 }).expect("daemon");
    // Same run job twice, with an unrelated high-priority job between
    // them warming (and reordering around) the shared pool.
    let a = client::submit(&socket, &argv(RUN_JOB), 0).expect("submit a");
    let mid = client::submit(&socket, &argv(DYN_JOB), 10).expect("submit mid");
    let b = client::submit(&socket, &argv(RUN_JOB), -5).expect("submit b");
    for id in [a, mid, b] {
        let out = client::report(&socket, id).expect("report");
        assert!(out.error.is_none(), "job {id}: {:?}", out.error);
    }
    let ra = client::report(&socket, a).unwrap().report.unwrap();
    let rb = client::report(&socket, b).unwrap().report.unwrap();
    assert_eq!(ra, want, "first served run diverged from one-shot");
    assert_eq!(rb, want, "warm-pool rerun diverged after other jobs ran");
    let rows = client::jobs(&socket).expect("jobs listing");
    assert_eq!(rows.len(), 3);
    assert!(rows.iter().all(|r| r.state == "finished"), "{rows:?}");
}

#[test]
fn lifecycle_stream_is_well_formed_with_idle_accounting() {
    let socket = sock("lifecycle");
    let _daemon = Daemon::start(ServeConfig { socket: socket.clone(), jobs: 2 }).expect("daemon");
    let mut lines: Vec<String> = Vec::new();
    let out = client::submit_and_wait(&socket, &argv(RUN_JOB), 7, &mut |l| {
        lines.push(l.to_string());
    })
    .expect("submit");
    assert!(out.error.is_none(), "{:?}", out.error);
    let events: Vec<Value> = lines
        .iter()
        .map(|l| jsonl::parse(l).unwrap_or_else(|e| panic!("unparseable event `{l}`: {e}")))
        .collect();
    let kind = |v: &Value| v.get("event").and_then(Value::as_str).unwrap().to_string();
    // Exact shape: queued, scheduled, 4 task completions (one per
    // system on OH-009), report, finished.
    assert_eq!(kind(&events[0]), "queued");
    assert_eq!(events[0].get("command").and_then(Value::as_str), Some("run"));
    assert_eq!(events[0].get("priority").and_then(Value::as_i64), Some(7));
    assert_eq!(kind(&events[1]), "scheduled");
    for f in ["queue_wait_ms", "scheduler_idle_ms"] {
        assert!(events[1].get(f).and_then(Value::as_f64).is_some(), "scheduled lacks {f}");
    }
    let done: Vec<&Value> = events.iter().filter(|v| kind(v) == "task_completed").collect();
    assert_eq!(done.len(), 4, "{lines:#?}");
    let mut indices: Vec<u64> =
        done.iter().map(|v| v.get("index").and_then(Value::as_u64).unwrap()).collect();
    indices.sort_unstable();
    assert_eq!(indices, vec![0, 1, 2, 3]);
    for v in &done {
        assert_eq!(v.get("total").and_then(Value::as_u64), Some(4));
        assert_eq!(v.get("label").and_then(Value::as_str), Some("OH-009"));
        assert!(v.get("system").and_then(Value::as_str).is_some());
    }
    let n = events.len();
    assert_eq!(kind(&events[n - 2]), "report");
    assert_eq!(kind(&events[n - 1]), "finished");
    let execution = events[n - 1].get("execution");
    for f in
        ["tasks", "workers", "wall_ms", "busy_ms", "queue_wait_ms", "scheduler_idle_ms", "worker_idle_ms"]
    {
        assert!(execution.and_then(|e| e.get(f)).is_some(), "finished execution lacks {f}");
    }
    assert_eq!(execution.and_then(|e| e.get("tasks")).and_then(Value::as_u64), Some(4));
    assert_eq!(execution.and_then(|e| e.get("workers")).and_then(Value::as_u64), Some(2));
    // The streamed report event carries the exact report text.
    assert_eq!(
        events[n - 2].get("report").and_then(Value::as_str),
        out.report.as_deref(),
        "report event and terminal report diverged"
    );
}

#[test]
fn bad_jobs_fail_named_without_poisoning_the_pool() {
    let socket = sock("poison");
    let _daemon = Daemon::start(ServeConfig { socket: socket.clone(), jobs: 1 }).expect("daemon");
    // Semantic errors surface at schedule time as a `failed` lifecycle
    // event naming the problem...
    let bad = &["run", "--system", "mps", "--quick"];
    let mut saw_failed = false;
    let out = client::submit_and_wait(&socket, &argv(bad), 0, &mut |l| {
        saw_failed |= l.contains("\"event\": \"failed\"");
    })
    .expect("transport stays healthy");
    let err = out.error.expect("bad system must fail the job");
    assert!(err.contains("mps"), "error does not name the bad system: {err}");
    assert!(saw_failed, "no failed lifecycle event streamed");
    // ...file-output and pool flags are refused at submit time...
    for forbidden in [
        vec!["run", "--quick", "--out", "/tmp/x"],
        vec!["run", "--quick", "--jobs", "4"],
        vec!["compare"],
    ] {
        let e = client::submit(&socket, &argv(&forbidden), 0)
            .expect_err("forbidden argv must be refused");
        assert!(e.to_string().contains("daemon refused"), "{e}");
    }
    // ...protocol garbage gets a structured refusal...
    {
        use std::io::{BufRead, BufReader, Write};
        let mut s = std::os::unix::net::UnixStream::connect(&socket).expect("connect");
        writeln!(s, "this is not json").unwrap();
        let mut line = String::new();
        BufReader::new(s.try_clone().unwrap()).read_line(&mut line).unwrap();
        let v = jsonl::parse(line.trim_end()).expect("refusal parses");
        assert_eq!(v.get("ok").and_then(Value::as_bool), Some(false));
    }
    // ...and the pool is not poisoned: the next job runs clean.
    let good = client::submit_and_wait(&socket, &argv(RUN_JOB), 0, &mut |_| {}).expect("submit");
    assert!(good.error.is_none(), "{:?}", good.error);
    assert_eq!(good.report.unwrap(), one_shot(RUN_JOB));
}

#[test]
fn served_regress_gate_passes_on_its_own_baseline() {
    let baseline = one_shot(RUN_JOB);
    let bpath = std::env::temp_dir().join(format!("gvb_serve_regress_{}.csv", std::process::id()));
    std::fs::write(&bpath, &baseline).expect("write baseline");
    let socket = sock("regress");
    let _daemon = Daemon::start(ServeConfig { socket: socket.clone(), jobs: 2 }).expect("daemon");
    let job = vec![
        "regress".to_string(),
        "--baseline".to_string(),
        bpath.to_str().unwrap().to_string(),
        "--quick".to_string(),
        "--threshold".to_string(),
        "5".to_string(),
    ];
    let out = client::submit_and_wait(&socket, &job, 0, &mut |_| {}).expect("submit");
    assert!(out.error.is_none(), "{:?}", out.error);
    // The gate verdict rides the finished event and the report JSON.
    assert_eq!(out.passed, Some(true));
    let report = out.report.unwrap();
    assert!(report.contains("\"passed\": true"), "{report}");
    assert!(report.contains("\"schema\": \"point\""), "{report}");
    std::fs::remove_file(&bpath).ok();
}

#[test]
fn stats_op_tracks_the_job_lifecycle() {
    let socket = sock("stats");
    let _daemon = Daemon::start(ServeConfig { socket: socket.clone(), jobs: 2 }).expect("daemon");
    // A fresh daemon reports its pool size and all-zero counters.
    let snap = client::stats(&socket).expect("stats");
    assert_eq!(snap.workers, 2);
    assert_eq!(snap.queue_depth, 0);
    assert_eq!(snap.jobs_submitted, 0);
    assert_eq!(snap.jobs_finished + snap.jobs_failed, 0);
    assert_eq!(snap.queue_wait_ms.count, 0);
    // Two jobs that finish, one that fails at schedule time.
    for job in [RUN_JOB, DYN_JOB] {
        let out = client::submit_and_wait(&socket, &argv(job), 0, &mut |_| {}).expect("submit");
        assert!(out.error.is_none(), "{:?}", out.error);
    }
    let bad = &["run", "--system", "mps", "--quick"];
    let out = client::submit_and_wait(&socket, &argv(bad), 0, &mut |_| {}).expect("transport");
    assert!(out.error.is_some(), "bad system must fail the job");
    // Counters are consistent with the lifecycle events that fed them.
    let snap = client::stats(&socket).expect("stats");
    assert_eq!(snap.jobs_submitted, 3);
    assert_eq!(snap.jobs_finished, 2);
    assert_eq!(snap.jobs_failed, 1);
    assert_eq!(snap.jobs_queued, 0);
    assert_eq!(snap.jobs_running, 0);
    assert_eq!(snap.queue_depth, 0);
    // RUN_JOB executes one OH-009 task per system (4); DYN_JOB one
    // timeline cell; the failed job never reached the executor.
    assert_eq!(snap.tasks_completed, 5);
    // One schedule-time sample per job that left the queue, one
    // terminal worker-idle sample per job that ended.
    assert_eq!(snap.queue_wait_ms.count, 3);
    assert_eq!(snap.scheduler_idle_ms.count, 3);
    assert_eq!(snap.worker_idle_ms.count, 3);
    assert_eq!(snap.job_tasks_per_sec.count, 2, "throughput samples come from finished jobs");
    // The snapshot agrees with the jobs listing the same daemon serves.
    let rows = client::jobs(&socket).expect("jobs listing");
    assert_eq!(
        rows.iter().filter(|r| r.state == "finished").count() as u64,
        snap.jobs_finished
    );
    assert_eq!(
        rows.iter().filter(|r| r.state == "failed").count() as u64,
        snap.jobs_failed
    );
    // Both client-side renders expose the same numbers.
    let table = snap.render_table();
    assert!(table.contains("jobs finished"), "{table}");
    assert!(table.contains("jobs submitted         3"), "{table}");
    let prom = snap.render_prometheus();
    assert!(prom.contains("gvbench_jobs{state=\"finished\"} 2\n"), "{prom}");
    assert!(prom.contains("gvbench_jobs_submitted_total 3\n"), "{prom}");
    assert!(prom.contains("gvbench_workers 2\n"), "{prom}");
    assert!(
        prom.contains("gvbench_queue_wait_ms_bucket{le=\"+Inf\"} 3\n"),
        "cumulative buckets must end at +Inf == _count: {prom}"
    );
    assert!(prom.contains("gvbench_queue_wait_ms_count 3\n"), "{prom}");
}

#[test]
fn shutdown_drains_accepted_jobs_and_removes_the_socket() {
    let socket = sock("shutdown");
    let daemon = Daemon::start(ServeConfig { socket: socket.clone(), jobs: 2 }).expect("daemon");
    let id = client::submit(&socket, &argv(DYN_JOB), 0).expect("submit");
    // A watcher opened before shutdown must still see the job through
    // to a terminal state — shutdown drains, it does not drop. The
    // channel blocks until the watcher has streamed its first event, so
    // its connection is in place before shutdown is requested.
    let (tx, rx) = std::sync::mpsc::channel::<()>();
    let watcher = {
        let socket = socket.clone();
        std::thread::spawn(move || {
            client::watch(&socket, id, &mut |_| {
                let _ = tx.send(());
            })
        })
    };
    rx.recv().expect("watcher streamed no event");
    client::shutdown(&socket).expect("shutdown ack");
    let out = watcher.join().expect("watcher thread").expect("watch");
    assert!(out.error.is_none(), "drained job failed: {:?}", out.error);
    assert!(out.report.is_some(), "drained job produced no report");
    daemon.wait().expect("daemon joins all threads");
    assert!(!socket.exists(), "socket file survived shutdown");
    // A second daemon can bind the same path immediately.
    let again = Daemon::start(ServeConfig { socket: socket.clone(), jobs: 1 }).expect("rebind");
    drop(again);
}
