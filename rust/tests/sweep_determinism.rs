//! The sweep subsystem's determinism guarantee, proven end-to-end over
//! the full extended cell coordinate: the same sweep spec and seed —
//! including the `gpu_count` × `link` topology axes — produce a
//! **bit-identical** sweep surface at `--jobs 1` and `--jobs 8`
//! (per-cell seeds are pure functions of the run seed and the cell
//! coordinates), and the rendered CSV surface — which carries no host
//! timings — matches byte-for-byte.

use gvb::coordinator::sweep::{run_sweep, SweepSpec, SweepSurface};
use gvb::metrics::{Category, RunConfig};
use gvb::report::sweep::render_csv;
use gvb::simgpu::nvlink::LinkKind;

fn spec() -> SweepSpec {
    SweepSpec {
        systems: vec!["hami".into(), "fcsp".into()],
        tenants: vec![1, 2],
        quotas: vec![50, 100],
        gpu_counts: vec![2, 4],
        links: vec![LinkKind::NvLink, LinkKind::Pcie],
        categories: Some(vec![Category::Pcie]),
    }
}

fn base() -> RunConfig {
    let mut cfg = RunConfig::quick("native");
    cfg.seed = 42;
    cfg
}

fn assert_surfaces_bit_identical(a: &SweepSurface, b: &SweepSurface) {
    assert_eq!(a.seed, b.seed);
    assert_eq!(a.metric_ids, b.metric_ids);
    assert_eq!(a.cells.len(), b.cells.len());
    for (x, y) in a.cells.iter().zip(&b.cells) {
        let ctx = format!(
            "{}/{}t/{}%/{}g/{}",
            x.system,
            x.tenants,
            x.quota_pct,
            x.gpu_count,
            x.link.key()
        );
        assert_eq!(x.system, y.system, "{ctx}: cell order diverged");
        assert_eq!(x.tenants, y.tenants, "{ctx}");
        assert_eq!(x.quota_pct, y.quota_pct, "{ctx}");
        assert_eq!(x.gpu_count, y.gpu_count, "{ctx}: topology order diverged");
        assert_eq!(x.link, y.link, "{ctx}: topology order diverged");
        assert_eq!(x.is_baseline, y.is_baseline, "{ctx}");
        assert_eq!(
            x.overall.to_bits(),
            y.overall.to_bits(),
            "{ctx}: overall {} vs {}",
            x.overall,
            y.overall
        );
        assert_eq!(
            x.delta_vs_baseline_pct.to_bits(),
            y.delta_vs_baseline_pct.to_bits(),
            "{ctx}: delta"
        );
        assert_eq!(x.per_category.len(), y.per_category.len(), "{ctx}");
        for ((ca, sa), (cb, sb)) in x.per_category.iter().zip(&y.per_category) {
            assert_eq!(ca, cb, "{ctx}: category order");
            assert_eq!(sa.to_bits(), sb.to_bits(), "{ctx}/{:?}: category score", ca);
        }
        // The raw per-metric results the CSV surface / regress baselines
        // are built from are bit-identical too.
        assert_eq!(x.results.len(), y.results.len(), "{ctx}");
        for (ra, rb) in x.results.iter().zip(&y.results) {
            assert_eq!(ra.id, rb.id, "{ctx}: metric order");
            assert_eq!(ra.value.to_bits(), rb.value.to_bits(), "{ctx}/{}", ra.id);
        }
    }
}

#[test]
fn sweep_surface_bit_identical_at_any_job_count() {
    let base = base();
    let serial = run_sweep(&base, &spec(), 1);
    let sharded = run_sweep(&base, &spec(), 8);
    assert_eq!(serial.stats.jobs, 1);
    assert_eq!(sharded.stats.jobs, 8);
    // 2 systems × 4 topologies × 4 scenarios (baseline in-grid) ×
    // 4 PCIe metrics.
    assert_eq!(serial.cells.len(), 32);
    assert_eq!(serial.metric_ids.len(), 4);
    assert_eq!(serial.stats.tasks.len(), 128);
    assert_surfaces_bit_identical(&serial, &sharded);
    // The rendered CSV surface (no host timings) matches byte-for-byte.
    assert_eq!(render_csv(&serial), render_csv(&sharded));
}

#[test]
fn sweep_cells_differ_across_scenarios() {
    // Sanity against a degenerate pass: different scenarios must not all
    // collapse to the same numbers for a quota-sensitive system.
    let surface = run_sweep(&base(), &spec(), 0);
    let hami: Vec<_> = surface.cells.iter().filter(|c| c.system == "hami").collect();
    assert!(
        hami.iter().any(|c| c.overall.to_bits() != hami[0].overall.to_bits()),
        "all hami cells identical: {:?}",
        hami.iter().map(|c| c.overall).collect::<Vec<_>>()
    );
}

#[test]
fn topology_axes_reach_the_metric_backends() {
    // NCCL metrics must actually see the cell's node: P2P bandwidth on
    // the NVLink cells is an order of magnitude above the PCIe cells'.
    let spec = SweepSpec {
        systems: vec!["native".into()],
        tenants: vec![1],
        quotas: vec![100],
        gpu_counts: vec![4],
        links: vec![LinkKind::NvLink, LinkKind::Pcie],
        categories: Some(vec![Category::Nccl]),
    };
    let surface = run_sweep(&base(), &spec, 2);
    assert_eq!(surface.cells.len(), 2);
    let idx = surface.metric_ids.iter().position(|id| *id == "NCCL-003").unwrap();
    let p2p = |link: LinkKind| -> f64 {
        surface.cells.iter().find(|c| c.link == link).unwrap().results[idx].value
    };
    assert!(
        p2p(LinkKind::NvLink) > p2p(LinkKind::Pcie) * 5.0,
        "nvlink={} pcie={}",
        p2p(LinkKind::NvLink),
        p2p(LinkKind::Pcie)
    );
}

#[test]
fn sweep_is_a_pure_function_of_the_seed() {
    let mut other = base();
    other.seed = 43;
    let a = run_sweep(&base(), &spec(), 4);
    let b = run_sweep(&base(), &spec(), 4);
    let c = run_sweep(&other, &spec(), 4);
    assert_surfaces_bit_identical(&a, &b);
    // A different run seed must actually change some cell somewhere.
    assert!(
        a.cells
            .iter()
            .zip(&c.cells)
            .any(|(x, y)| x.overall.to_bits() != y.overall.to_bits()),
        "seed change did not affect the surface"
    );
}
