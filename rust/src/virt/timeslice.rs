//! Time-slicing backend (paper §1.2's second sharing approach, an
//! extension beyond Table 2): "the GPU scheduler alternates between
//! workloads, providing each with full GPU access during its time slice.
//! Maximum flexibility but no isolation guarantees."
//!
//! Mechanisms: no interception (zero hook cost, no quotas), but every
//! cross-tenant launch pays a context-switch when the previous slice
//! belonged to someone else, and under contention a tenant waits for the
//! other tenants' remaining slices — which is exactly why the paper calls
//! out aggressive workloads impacting neighbours.

use std::collections::HashMap;

use crate::simgpu::error::GpuError;
use crate::simgpu::kernel::{duration_ns, ExecContext, KernelDesc};
use crate::simgpu::sm::SmGrant;
use crate::simgpu::{GpuDevice, TenantId};

use super::{LaunchGate, TenantConfig, VirtLayer};

/// Kubernetes-device-plugin-style time slicing.
pub struct TimeSlice {
    tenants: HashMap<TenantId, TenantConfig>,
    /// Scheduler slice quantum, ns (the nvidia device plugin default is
    /// on the order of milliseconds).
    slice_ns: f64,
    /// Tenant owning the current slice.
    current: Option<TenantId>,
    rr_counter: usize,
}

impl TimeSlice {
    pub fn new() -> TimeSlice {
        TimeSlice {
            tenants: HashMap::new(),
            slice_ns: 2_000_000.0, // 2 ms quantum
            current: None,
            rr_counter: 0,
        }
    }

    /// Expected wait for the device when `n` tenants share slices and the
    /// caller does not own the current slice: on average half the other
    /// tenants' quanta are in front of us.
    fn slice_wait_ns(&self, tenant: TenantId, dev: &mut GpuDevice) -> f64 {
        let others = self.tenants.len().saturating_sub(1) as f64;
        if others == 0.0 || self.current == Some(tenant) {
            return 0.0;
        }
        // Busy neighbours each hold ~1 quantum; arrival lands mid-rotation.
        let busy_others: f64 = others.min(dev.concurrent_shared(tenant) as f64 - 1.0).max(0.0);
        busy_others * self.slice_ns * dev.rng().f64_range(0.0, 1.0)
    }
}

impl Default for TimeSlice {
    fn default() -> Self {
        Self::new()
    }
}

impl VirtLayer for TimeSlice {
    fn name(&self) -> &'static str {
        "timeslice"
    }

    fn register_tenant(
        &mut self,
        tenant: TenantId,
        cfg: TenantConfig,
        dev: &mut GpuDevice,
    ) -> Result<(), GpuError> {
        // Quotas are accepted but NOT enforced — the defining property.
        self.tenants.insert(tenant, cfg);
        dev.grant_sms(tenant, SmGrant::Shared).map_err(|_| GpuError::InvalidValue)
    }

    fn unregister_tenant(&mut self, tenant: TenantId, dev: &mut GpuDevice) {
        self.tenants.remove(&tenant);
        dev.sms.unregister(tenant);
        if self.current == Some(tenant) {
            self.current = None;
        }
    }

    fn hook_overhead_ns(&mut self, _dev: &mut GpuDevice) -> f64 {
        0.0 // no interception layer at all
    }

    fn context_create_overhead_ns(&mut self, _t: TenantId, _dev: &mut GpuDevice) -> f64 {
        0.0
    }

    fn pre_alloc(&mut self, _t: TenantId, _s: u64, _d: &mut GpuDevice) -> Result<f64, GpuError> {
        Ok(0.0) // no quota: first-come-first-served until device OOM
    }

    fn post_alloc(&mut self, _t: TenantId, _s: u64, _d: &mut GpuDevice) -> f64 {
        0.0
    }

    fn pre_free(&mut self, _t: TenantId, _d: &mut GpuDevice) -> f64 {
        0.0
    }

    fn post_free(&mut self, _t: TenantId, _s: u64, _d: &mut GpuDevice) -> f64 {
        0.0
    }

    fn gate_launch(
        &mut self,
        tenant: TenantId,
        kernel: &KernelDesc,
        dev: &mut GpuDevice,
    ) -> LaunchGate {
        let mut wait = self.slice_wait_ns(tenant, dev);
        let mut overhead = 0.0;
        if self.current != Some(tenant) {
            // Context switch into this tenant's slice.
            overhead += dev.spec.ctx_switch_ns as f64 * dev.jitter();
            self.current = Some(tenant);
        }
        // A kernel longer than the quantum keeps getting rescheduled: it
        // pays a switch per extra quantum under contention.
        let others = self.tenants.len().saturating_sub(1);
        if others > 0 {
            let est = duration_ns(&dev.spec, kernel, &ExecContext::uncontended(dev.spec.sm_count));
            let extra_quanta = (est / self.slice_ns).floor();
            wait += extra_quanta * others as f64 * self.slice_ns
                * (dev.concurrent_shared(tenant) as f64 - 1.0).clamp(0.0, 1.0);
        }
        LaunchGate {
            overhead_ns: overhead,
            throttle_wait_ns: wait,
            granted_sms: dev.spec.sm_count, // full device during the slice
        }
    }

    fn on_kernel_complete(&mut self, _t: TenantId, _f: f64, _b: f64, _n: f64) {}

    fn mem_info(&self, _t: TenantId, dev: &GpuDevice) -> (u64, u64) {
        (dev.memory.free_bytes(), dev.memory.capacity())
    }

    fn tick(&mut self, _dev: &mut GpuDevice) {}

    fn monitor_cpu_overhead(&self) -> f64 {
        0.0
    }

    fn arbitrate(&mut self, pending: &[(TenantId, KernelDesc)]) -> usize {
        if pending.is_empty() {
            return 0;
        }
        let idx = self.rr_counter % pending.len();
        self.rr_counter += 1;
        idx
    }

    fn sm_limit(&self, _tenant: TenantId) -> f64 {
        1.0 // no SM limiting whatsoever
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_quota_enforcement() {
        let mut dev = GpuDevice::a100(1);
        let mut ts = TimeSlice::new();
        ts.register_tenant(1, TenantConfig::unlimited().with_mem_limit(1 << 20), &mut dev)
            .unwrap();
        // Configured 1 MiB quota is ignored entirely.
        assert!(ts.pre_alloc(1, 10 << 30, &mut dev).is_ok());
        assert_eq!(ts.sm_limit(1), 1.0);
    }

    #[test]
    fn solo_tenant_runs_uncontended() {
        let mut dev = GpuDevice::a100(2);
        dev.spec.jitter_sigma = 0.0;
        let mut ts = TimeSlice::new();
        ts.register_tenant(1, TenantConfig::unlimited(), &mut dev).unwrap();
        let g1 = ts.gate_launch(1, &KernelDesc::null(), &mut dev);
        // First launch pays the switch into the slice, then nothing.
        assert!(g1.overhead_ns > 0.0);
        let g2 = ts.gate_launch(1, &KernelDesc::null(), &mut dev);
        assert_eq!(g2.overhead_ns, 0.0);
        assert_eq!(g2.throttle_wait_ns, 0.0);
        assert_eq!(g2.granted_sms, 108);
    }

    #[test]
    fn cross_tenant_switches_cost() {
        let mut dev = GpuDevice::a100(3);
        dev.spec.jitter_sigma = 0.0;
        let mut ts = TimeSlice::new();
        ts.register_tenant(1, TenantConfig::unlimited(), &mut dev).unwrap();
        ts.register_tenant(2, TenantConfig::unlimited(), &mut dev).unwrap();
        ts.gate_launch(1, &KernelDesc::null(), &mut dev);
        let g = ts.gate_launch(2, &KernelDesc::null(), &mut dev);
        assert!((g.overhead_ns - dev.spec.ctx_switch_ns as f64).abs() < 1.0);
    }

    #[test]
    fn long_kernels_wait_under_contention() {
        let mut dev = GpuDevice::a100(4);
        let mut ts = TimeSlice::new();
        ts.register_tenant(1, TenantConfig::unlimited(), &mut dev).unwrap();
        ts.register_tenant(2, TenantConfig::unlimited(), &mut dev).unwrap();
        dev.set_background(
            2,
            crate::simgpu::device::BackgroundLoad { membw_demand: 0.5, resident_kernels: 1 },
        );
        // A 7 ms kernel spans ~3 quanta → pays rescheduling waits.
        let g = ts.gate_launch(1, &KernelDesc::gemm(4096, 4096, 4096, false), &mut dev);
        assert!(g.throttle_wait_ns >= 2.0 * 2_000_000.0, "wait={}", g.throttle_wait_ns);
    }
}
