//! Compute rate limiters (OH-008, IS-003, IS-004).
//!
//! Two designs, mirroring the systems in the paper:
//!
//! - [`HamiLimiter`] — HAMi-core's scheme: a token pool refilled **only at
//!   NVML polling boundaries** (default 100 ms), driven by a utilization
//!   measurement that is *lagged one window* and *quantized* (NVML reports
//!   coarse percentages). Admission is checked **before** launch, so one
//!   kernel can overshoot past zero, and the debt is **forgiven** at the
//!   next boundary (the pool floors at zero before refill). Non-conserving
//!   tokens + coarse feedback ⇒ persistent overshoot and oscillation —
//!   exactly why the paper measures ~85 % SM-limit accuracy for HAMi.
//!
//! - [`AdaptiveBucket`] — BUD-FCSP's scheme ("adaptive token bucket with
//!   burst handling", §2.3.2): GCRA-style pacing with a small burst
//!   allowance and **conserved debt** — a kernel is admitted while the
//!   balance is non-negative and the spend is always repaid. An integral
//!   trim corrects bias between *estimated* and *actual* kernel cost, the
//!   "adaptive" part ⇒ sub-percentage long-run control, ~93 % accuracy.
//!
//! Token unit: **SM·ns** (one token = one nanosecond of the full device's
//! SMs). A kernel occupying fraction `f` of the device for `d` ns costs
//! `f · d` tokens.

/// Outcome of an admission attempt.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Admission {
    /// Wait before the kernel may start, ns.
    pub wait_ns: f64,
    /// CPU cost of the limiter bookkeeping itself, ns (OH-008).
    pub overhead_ns: f64,
}

/// HAMi-core-style fixed-window limiter.
#[derive(Clone, Debug)]
pub struct HamiLimiter {
    /// Target utilization fraction (0..=1].
    limit: f64,
    /// Poll interval, ns (default 100 ms).
    window_ns: f64,
    /// Token pool, SM·ns. Admission requires `tokens > 0`; the pool may go
    /// negative transiently but is floored at zero on refill (HAMi resets
    /// its core counter — debt is forgiven).
    tokens: f64,
    /// Busy SM·ns accumulated in the current window (feedback source).
    window_busy: f64,
    /// Utilization of the *previous* window (the lagged measurement the
    /// refill controller sees).
    lagged_util: f64,
    /// End of the current window in virtual time.
    window_end_ns: f64,
    /// Proportional gain on (limit - measured). 1.0 reproduces HAMi; the
    /// ablation bench sweeps it.
    kp: f64,
    /// NVML measurement quantization step (0.10 = whole deciles).
    quant: f64,
    /// Per-admission bookkeeping cost, ns.
    check_ns: f64,
    pub admissions: u64,
    pub blocks: u64,
}

impl HamiLimiter {
    pub fn new(limit: f64) -> HamiLimiter {
        HamiLimiter {
            limit: limit.clamp(0.01, 1.0),
            window_ns: 100e6, // 100 ms NVML poll (paper §3.1.8)
            tokens: 0.0,
            window_busy: 0.0,
            lagged_util: 0.0,
            window_end_ns: 0.0,
            kp: 1.0,
            quant: 0.10,
            check_ns: 32.0,
            admissions: 0,
            blocks: 0,
        }
    }

    pub fn set_window_ns(&mut self, w: f64) {
        self.window_ns = w;
    }

    /// Feedback gain (ablation).
    pub fn set_kp(&mut self, kp: f64) {
        self.kp = kp;
    }

    /// Measurement quantization step (ablation; 0 disables quantization).
    pub fn set_quant(&mut self, q: f64) {
        self.quant = q.max(0.0);
    }

    pub fn limit(&self) -> f64 {
        self.limit
    }

    pub fn set_limit(&mut self, l: f64) {
        self.limit = l.clamp(0.01, 1.0);
    }

    fn quantize(&self, util: f64) -> f64 {
        if self.quant <= 0.0 {
            util
        } else {
            (util / self.quant).floor() * self.quant
        }
    }

    /// Advance window boundaries up to `now`, applying the refill at each
    /// boundary (the 100 ms NVML poll firing).
    fn advance(&mut self, now_ns: f64) {
        if self.window_end_ns == 0.0 {
            // First use: one quantum of credit.
            self.window_end_ns = now_ns + self.window_ns;
            self.tokens = self.limit * self.window_ns;
            return;
        }
        while now_ns >= self.window_end_ns {
            // The measurement driving this refill is the utilization NVML
            // reported for the *previous* window, quantized.
            let measured = self.quantize(self.lagged_util);
            self.lagged_util = (self.window_busy / self.window_ns).min(1.5);
            self.window_busy = 0.0;
            let refill = (self.limit + self.kp * (self.limit - measured)).max(0.0) * self.window_ns;
            // Debt forgiveness: floor at zero before refill, cap at one
            // full window of device time.
            self.tokens = (self.tokens.max(0.0) + refill).min(self.window_ns);
            self.window_end_ns += self.window_ns;
        }
    }

    /// Try to admit a kernel expected to cost `cost_smns` SM·ns at virtual
    /// time `now_ns`.
    pub fn acquire(&mut self, cost_smns: f64, now_ns: f64) -> Admission {
        self.advance(now_ns);
        self.admissions += 1;
        if self.tokens > 0.0 {
            // Admit immediately — possibly overshooting past zero (the
            // check-before-launch behaviour that degrades accuracy).
            self.tokens -= cost_smns;
            return Admission { wait_ns: 0.0, overhead_ns: self.check_ns };
        }
        // Blocked: sleep to poll boundaries until a refill lands.
        self.blocks += 1;
        let mut wait = self.window_end_ns - now_ns;
        let mut guard = 0;
        loop {
            let t = self.window_end_ns;
            self.advance(t + 1.0);
            if self.tokens > 0.0 || guard > 64 {
                break;
            }
            wait += self.window_ns;
            guard += 1;
        }
        self.tokens -= cost_smns;
        Admission { wait_ns: wait, overhead_ns: self.check_ns + 210.0 /* futex sleep+wake */ }
    }

    /// Completion feedback: `sm_frac` of the device busy for `busy_ns`.
    pub fn on_complete(&mut self, sm_frac: f64, busy_ns: f64) {
        self.window_busy += sm_frac * busy_ns;
    }
}

/// BUD-FCSP-style adaptive token bucket (GCRA pacing + integral trim).
#[derive(Clone, Debug)]
pub struct AdaptiveBucket {
    limit: f64,
    /// Continuous refill rate, SM·ns per ns (== limit, adjusted by trim).
    rate: f64,
    /// Burst capacity, SM·ns (small: sub-percentage long-run granularity).
    burst: f64,
    /// Balance. Admission requires `tokens >= 0`; spend is conserved (the
    /// balance goes negative and must be repaid by refill).
    tokens: f64,
    last_ns: f64,
    /// Integral error correction on achieved utilization (the adaptive
    /// part: compensates biased kernel-cost estimates).
    err_integral: f64,
    total_busy: f64,
    start_ns: f64,
    check_ns: f64,
    pub admissions: u64,
    pub blocks: u64,
}

impl AdaptiveBucket {
    pub fn new(limit: f64) -> AdaptiveBucket {
        let limit = limit.clamp(0.001, 1.0);
        AdaptiveBucket {
            limit,
            rate: limit,
            // 2 ms of device time worth of burst at the limit rate.
            burst: limit * 2e6,
            tokens: limit * 2e6,
            last_ns: f64::NAN,
            err_integral: 0.0,
            total_busy: 0.0,
            start_ns: f64::NAN,
            check_ns: 41.0,
            admissions: 0,
            blocks: 0,
        }
    }

    pub fn limit(&self) -> f64 {
        self.limit
    }

    pub fn set_limit(&mut self, l: f64) {
        let l = l.clamp(0.001, 1.0);
        self.limit = l;
        self.rate = l;
        self.burst = l * 2e6;
    }

    fn refill(&mut self, now_ns: f64) {
        if self.start_ns.is_nan() {
            self.start_ns = now_ns;
            self.last_ns = now_ns;
        }
        let dt = (now_ns - self.last_ns).max(0.0);
        self.tokens = (self.tokens + self.rate * dt).min(self.burst);
        self.last_ns = now_ns;
    }

    /// Admit a kernel costing `cost_smns` SM·ns at time `now_ns`.
    pub fn acquire(&mut self, cost_smns: f64, now_ns: f64) -> Admission {
        self.refill(now_ns);
        self.admissions += 1;
        if self.tokens >= 0.0 {
            // Balance non-negative: admit now; the spend may drive the
            // balance negative (conserved debt = pacing).
            self.tokens -= cost_smns;
            return Admission { wait_ns: 0.0, overhead_ns: self.check_ns };
        }
        // In debt: wait exactly until the balance returns to zero.
        self.blocks += 1;
        let wait = -self.tokens / self.rate.max(1e-9);
        self.tokens = -cost_smns;
        self.last_ns = now_ns + wait;
        Admission { wait_ns: wait, overhead_ns: self.check_ns + 180.0 }
    }

    /// Completion feedback with integral trim: nudge the refill rate so the
    /// long-run *achieved* utilization converges on the limit even when
    /// admission-time cost estimates are biased.
    pub fn on_complete(&mut self, sm_frac: f64, busy_ns: f64, now_ns: f64) {
        self.total_busy += sm_frac * busy_ns;
        if self.start_ns.is_nan() {
            return;
        }
        let elapsed = (now_ns - self.start_ns).max(1.0);
        let achieved = self.total_busy / elapsed;
        let err = self.limit - achieved;
        self.err_integral = (self.err_integral + err).clamp(-0.2, 0.2);
        self.rate = (self.limit + 0.1 * self.err_integral).clamp(self.limit * 0.5, 1.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive a limiter with a synthetic back-to-back kernel load and return
    /// achieved utilization. `kernel_ns` at `sm_frac` occupancy.
    fn drive_hami(limit: f64, kernel_ns: f64, sm_frac: f64, sim_ns: f64) -> f64 {
        let mut l = HamiLimiter::new(limit);
        let mut now = 0.0;
        let mut busy = 0.0;
        while now < sim_ns {
            let cost = kernel_ns * sm_frac;
            let a = l.acquire(cost, now);
            now += a.wait_ns + a.overhead_ns;
            now += kernel_ns;
            busy += cost;
            l.on_complete(sm_frac, kernel_ns);
        }
        busy / now
    }

    fn drive_adaptive(limit: f64, kernel_ns: f64, sm_frac: f64, sim_ns: f64) -> f64 {
        let mut l = AdaptiveBucket::new(limit);
        let mut now = 0.0;
        let mut busy = 0.0;
        while now < sim_ns {
            let cost = kernel_ns * sm_frac;
            let a = l.acquire(cost, now);
            now += a.wait_ns + a.overhead_ns;
            now += kernel_ns;
            busy += cost;
            l.on_complete(sm_frac, kernel_ns, now);
        }
        busy / now
    }

    #[test]
    fn hami_roughly_tracks_limit() {
        let achieved = drive_hami(0.5, 2e6, 1.0, 3e9);
        assert!(achieved > 0.35 && achieved < 0.75, "achieved={achieved}");
    }

    #[test]
    fn adaptive_tracks_limit_tightly() {
        for limit in [0.3, 0.5, 0.7] {
            for kernel in [2e6, 7e6] {
                let achieved = drive_adaptive(limit, kernel, 1.0, 5e9);
                let err = (achieved - limit).abs() / limit;
                assert!(err < 0.05, "limit={limit} kernel={kernel} achieved={achieved}");
            }
        }
    }

    #[test]
    fn adaptive_more_accurate_than_hami() {
        // 7 ms kernels don't divide the window allowance evenly, so HAMi's
        // forgiven overshoot persists — the IS-003 accuracy gap the paper
        // measures (85 % vs 93 %).
        let mut hami_err = 0.0;
        let mut fcsp_err = 0.0;
        for limit in [0.3, 0.5, 0.7] {
            hami_err += ((drive_hami(limit, 7e6, 1.0, 5e9) - limit) / limit).abs();
            fcsp_err += ((drive_adaptive(limit, 7e6, 1.0, 5e9) - limit) / limit).abs();
        }
        assert!(fcsp_err < hami_err, "fcsp_err={fcsp_err} hami_err={hami_err}");
        // HAMi's mean relative error should be visible (> 3 %).
        assert!(hami_err / 3.0 > 0.03, "hami_err={hami_err}");
    }

    #[test]
    fn unlimited_passes_through() {
        let mut l = AdaptiveBucket::new(1.0);
        let a = l.acquire(1000.0, 0.0);
        assert_eq!(a.wait_ns, 0.0);
    }

    #[test]
    fn hami_blocks_when_exhausted() {
        let mut l = HamiLimiter::new(0.1);
        // Burn the entire first window's allowance in one shot.
        let a1 = l.acquire(0.1 * 100e6 * 2.0, 0.0);
        assert_eq!(a1.wait_ns, 0.0); // overshoot admit
        let a2 = l.acquire(1e6, 1.0);
        assert!(a2.wait_ns > 0.0, "wait={}", a2.wait_ns);
        assert!(l.blocks >= 1);
    }

    #[test]
    fn adaptive_paces_in_debt() {
        let mut l = AdaptiveBucket::new(0.5);
        // First admit spends burst + goes into debt.
        let a0 = l.acquire(0.5 * 2e6 + 3e6, 0.0);
        assert_eq!(a0.wait_ns, 0.0);
        // Second admit must wait for the debt (3e6) to be repaid at rate 0.5.
        let a1 = l.acquire(1e6, 0.0);
        assert!((a1.wait_ns - 6e6).abs() < 1e3, "wait={}", a1.wait_ns);
    }

    #[test]
    fn hami_forgives_debt_at_boundary() {
        let mut l = HamiLimiter::new(0.5);
        // Overshoot hugely in window 1.
        l.acquire(0.5 * 100e6 * 3.0, 0.0);
        // After one boundary the pool is floored at 0 then refilled → a
        // new kernel is admitted without repaying the huge debt.
        let a = l.acquire(1e6, 100e6 + 2.0);
        assert_eq!(a.wait_ns, 0.0);
    }

    #[test]
    fn overhead_charged_per_admission() {
        let mut l = HamiLimiter::new(0.9);
        let a = l.acquire(10.0, 0.0);
        assert!(a.overhead_ns >= 32.0);
        let mut b = AdaptiveBucket::new(0.9);
        let a = b.acquire(10.0, 0.0);
        assert!(a.overhead_ns >= 41.0);
    }

    #[test]
    fn is004_limit_change_response() {
        // Dynamic reconfiguration (IS-004): halve the limit mid-run and
        // check the adaptive bucket converges to the new target.
        let mut l = AdaptiveBucket::new(0.8);
        let mut now = 0.0;
        for _ in 0..500 {
            let a = l.acquire(2e6, now);
            now += a.wait_ns + a.overhead_ns + 2e6;
            l.on_complete(1.0, 2e6, now);
        }
        l.set_limit(0.4);
        let t_change = now;
        let mut busy_after = 0.0;
        for _ in 0..800 {
            let a = l.acquire(2e6, now);
            now += a.wait_ns + a.overhead_ns + 2e6;
            busy_after += 2e6;
        }
        let achieved = busy_after / (now - t_change);
        assert!((achieved - 0.4).abs() < 0.06, "achieved={achieved}");
    }
}
