//! Weighted fair queuing for cross-tenant kernel arbitration (BUD-FCSP's
//! "enhanced multi-tenant fairness", paper §2.3.2; measured by IS-008).
//!
//! Classic virtual-finish-time WFQ: each tenant carries a virtual finish
//! tag; the scheduler always serves the request whose tenant has the
//! smallest tag, then advances that tag by `cost / weight`. Aggressive
//! tenants (more submissions) accumulate tag debt and cannot starve others
//! — unlike FIFO, where submission rate directly buys throughput.

use std::collections::HashMap;

use crate::simgpu::TenantId;

/// WFQ arbiter state.
#[derive(Clone, Debug, Default)]
pub struct WfqScheduler {
    weights: HashMap<TenantId, f64>,
    finish_tags: HashMap<TenantId, f64>,
    /// Global virtual time (max served tag) — new tenants join here, not at
    /// zero, so they can't claim unbounded catch-up service.
    vtime: f64,
    pub served: u64,
}

impl WfqScheduler {
    pub fn new() -> WfqScheduler {
        WfqScheduler::default()
    }

    /// Register a tenant with a scheduling weight (default 1.0).
    pub fn add_tenant(&mut self, tenant: TenantId, weight: f64) {
        self.weights.insert(tenant, weight.max(1e-6));
        self.finish_tags.entry(tenant).or_insert(self.vtime);
    }

    pub fn remove_tenant(&mut self, tenant: TenantId) {
        self.weights.remove(&tenant);
        self.finish_tags.remove(&tenant);
    }

    /// Pick the index of the pending request to serve next: the one whose
    /// tenant has the smallest virtual finish tag (FIFO among a tenant's
    /// own requests — `pending` preserves arrival order).
    pub fn pick(&self, pending: &[(TenantId, f64)]) -> Option<usize> {
        if pending.is_empty() {
            return None;
        }
        let mut best = 0;
        let mut best_tag = f64::INFINITY;
        let mut seen: Vec<TenantId> = Vec::new();
        for (i, (t, _)) in pending.iter().enumerate() {
            if seen.contains(t) {
                continue; // only a tenant's head-of-line request competes
            }
            seen.push(*t);
            let tag = self.finish_tags.get(t).copied().unwrap_or(self.vtime);
            if tag < best_tag {
                best_tag = tag;
                best = i;
            }
        }
        Some(best)
    }

    /// Account a served request of `cost` for `tenant`.
    pub fn serve(&mut self, tenant: TenantId, cost: f64) {
        let w = self.weights.get(&tenant).copied().unwrap_or(1.0);
        let tag = self.finish_tags.entry(tenant).or_insert(self.vtime);
        *tag = tag.max(self.vtime) + cost / w;
        self.vtime = self.vtime.max(*tag - cost / w);
        self.served += 1;
    }

    pub fn finish_tag(&self, tenant: TenantId) -> f64 {
        self.finish_tags.get(&tenant).copied().unwrap_or(self.vtime)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Simulate serving from queues where tenant `a` submits 4x as many
    /// requests as others; return per-tenant served cost.
    fn run_contention(wfq: &mut WfqScheduler, rounds: usize) -> HashMap<TenantId, f64> {
        let mut served: HashMap<TenantId, f64> = HashMap::new();
        // Build a pending queue: tenant 1 floods, tenants 2-4 steady.
        let mut pending: Vec<(TenantId, f64)> = Vec::new();
        for _ in 0..rounds {
            for _ in 0..4 {
                pending.push((1, 100.0));
            }
            for t in 2..=4 {
                pending.push((t, 100.0));
            }
        }
        while let Some(i) = wfq.pick(&pending) {
            let (t, c) = pending.remove(i);
            wfq.serve(t, c);
            *served.entry(t).or_default() += c;
            if wfq.served > (rounds * 4) as u64 {
                break; // serve only part of the queue: measure share
            }
        }
        served
    }

    #[test]
    fn equal_weights_equal_service() {
        let mut w = WfqScheduler::new();
        for t in 1..=4 {
            w.add_tenant(t, 1.0);
        }
        let served = run_contention(&mut w, 50);
        let vals: Vec<f64> = (1..=4).map(|t| served.get(&t).copied().unwrap_or(0.0)).collect();
        let max = vals.iter().cloned().fold(0.0, f64::max);
        let min = vals.iter().cloned().fold(f64::INFINITY, f64::min);
        // Despite tenant 1 flooding 4x, service is near-equal.
        assert!(max / min < 1.3, "vals={vals:?}");
    }

    #[test]
    fn weights_bias_service() {
        let mut w = WfqScheduler::new();
        w.add_tenant(1, 2.0);
        w.add_tenant(2, 1.0);
        let mut pending: Vec<(TenantId, f64)> = Vec::new();
        for _ in 0..100 {
            pending.push((1, 10.0));
            pending.push((2, 10.0));
        }
        let mut served = HashMap::new();
        for _ in 0..90 {
            let i = w.pick(&pending).unwrap();
            let (t, c) = pending.remove(i);
            w.serve(t, c);
            *served.entry(t).or_default() += c;
        }
        let s1: f64 = served[&1];
        let s2: f64 = served[&2];
        assert!((s1 / s2 - 2.0).abs() < 0.25, "s1={s1} s2={s2}");
    }

    #[test]
    fn late_joiner_not_starved_or_boosted() {
        let mut w = WfqScheduler::new();
        w.add_tenant(1, 1.0);
        for _ in 0..100 {
            w.serve(1, 10.0);
        }
        w.add_tenant(2, 1.0);
        // New tenant joins at current vtime, not zero.
        assert!(w.finish_tag(2) > 0.0);
        let pending = vec![(1, 10.0), (2, 10.0)];
        // Tenant 2's tag is at vtime <= tenant 1's tag → tenant 2 served.
        assert_eq!(w.pick(&pending), Some(1));
    }

    #[test]
    fn empty_queue() {
        let w = WfqScheduler::new();
        assert_eq!(w.pick(&[]), None);
    }

    #[test]
    fn head_of_line_per_tenant() {
        let mut w = WfqScheduler::new();
        w.add_tenant(1, 1.0);
        w.add_tenant(2, 1.0);
        w.serve(1, 100.0); // tenant 1 now behind
        let pending = vec![(1, 10.0), (1, 10.0), (2, 10.0)];
        assert_eq!(w.pick(&pending), Some(2)); // tenant 2's head, not 1's second
    }
}
