//! Bare-metal baseline: no interception, no quotas, no limits (Table 2,
//! `native`). Every hook returns zero added cost; the device's base costs
//! are the only thing the metrics observe.

use std::collections::HashMap;

use crate::simgpu::error::GpuError;
use crate::simgpu::kernel::KernelDesc;
use crate::simgpu::sm::SmGrant;
use crate::simgpu::{GpuDevice, TenantId};

use super::{LaunchGate, TenantConfig, VirtLayer};

/// The passthrough backend.
#[derive(Debug, Default)]
pub struct Native {
    tenants: HashMap<TenantId, TenantConfig>,
    rr_counter: usize,
}

impl Native {
    pub fn new() -> Native {
        Native::default()
    }
}

impl VirtLayer for Native {
    fn name(&self) -> &'static str {
        "native"
    }

    fn register_tenant(
        &mut self,
        tenant: TenantId,
        cfg: TenantConfig,
        dev: &mut GpuDevice,
    ) -> Result<(), GpuError> {
        // Native ignores quotas entirely — the whole point of the baseline.
        self.tenants.insert(tenant, cfg);
        dev.grant_sms(tenant, SmGrant::Shared).map_err(|_| GpuError::InvalidValue)
    }

    fn unregister_tenant(&mut self, tenant: TenantId, dev: &mut GpuDevice) {
        self.tenants.remove(&tenant);
        dev.sms.unregister(tenant);
    }

    fn hook_overhead_ns(&mut self, _dev: &mut GpuDevice) -> f64 {
        0.0
    }

    fn context_create_overhead_ns(&mut self, _tenant: TenantId, _dev: &mut GpuDevice) -> f64 {
        0.0
    }

    fn pre_alloc(
        &mut self,
        _tenant: TenantId,
        _size: u64,
        _dev: &mut GpuDevice,
    ) -> Result<f64, GpuError> {
        Ok(0.0)
    }

    fn post_alloc(&mut self, _tenant: TenantId, _size: u64, _dev: &mut GpuDevice) -> f64 {
        0.0
    }

    fn pre_free(&mut self, _tenant: TenantId, _dev: &mut GpuDevice) -> f64 {
        0.0
    }

    fn post_free(&mut self, _tenant: TenantId, _size: u64, _dev: &mut GpuDevice) -> f64 {
        0.0
    }

    fn gate_launch(
        &mut self,
        tenant: TenantId,
        _kernel: &KernelDesc,
        dev: &mut GpuDevice,
    ) -> LaunchGate {
        let concurrent = dev.concurrent_shared(tenant);
        LaunchGate {
            overhead_ns: 0.0,
            throttle_wait_ns: 0.0,
            granted_sms: dev.sms.effective_sms(tenant, concurrent),
        }
    }

    fn on_kernel_complete(&mut self, _tenant: TenantId, _sm_frac: f64, _busy_ns: f64, _now_ns: f64) {}

    fn mem_info(&self, _tenant: TenantId, dev: &GpuDevice) -> (u64, u64) {
        (dev.memory.free_bytes(), dev.memory.capacity())
    }

    fn tick(&mut self, _dev: &mut GpuDevice) {}

    fn monitor_cpu_overhead(&self) -> f64 {
        0.0
    }

    fn arbitrate(&mut self, pending: &[(TenantId, KernelDesc)]) -> usize {
        // The CUDA driver timeslices contexts round-robin.
        if pending.is_empty() {
            return 0;
        }
        let idx = self.rr_counter % pending.len();
        self.rr_counter += 1;
        idx
    }

    fn sm_limit(&self, _tenant: TenantId) -> f64 {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_overhead_everywhere() {
        let mut dev = GpuDevice::a100(1);
        let mut n = Native::new();
        n.register_tenant(1, TenantConfig::equal_share(4, dev.spec.hbm_bytes), &mut dev).unwrap();
        assert_eq!(n.hook_overhead_ns(&mut dev), 0.0);
        assert_eq!(n.pre_alloc(1, 1 << 40, &mut dev).unwrap(), 0.0); // no quota!
        let g = n.gate_launch(1, &KernelDesc::null(), &mut dev);
        assert_eq!(g.overhead_ns, 0.0);
        assert_eq!(g.throttle_wait_ns, 0.0);
        assert_eq!(g.granted_sms, 108);
        assert_eq!(n.monitor_cpu_overhead(), 0.0);
        assert_eq!(n.sm_limit(1), 1.0);
    }

    #[test]
    fn mem_info_reports_physical_device() {
        let mut dev = GpuDevice::a100(2);
        let n = Native::new();
        let (free, total) = n.mem_info(1, &dev);
        assert_eq!(total, dev.spec.hbm_bytes);
        assert_eq!(free, dev.spec.hbm_bytes);
        dev.raw_alloc(1 << 20).0.unwrap();
        let (free2, _) = n.mem_info(1, &dev);
        assert_eq!(free2, dev.spec.hbm_bytes - (1 << 20));
    }
}
