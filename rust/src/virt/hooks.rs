//! dlsym-hook cost model (OH-005).
//!
//! HAMi-core intercepts CUDA/NVML entry points through `dlsym` shims. Each
//! intercepted call pays: symbol-table lookup in the shim's dispatch table
//! plus the real-symbol indirection. BUD-FCSP's "optimized dlsym hook
//! resolution paths" (paper §2.3.2) cache the resolved pointer per call
//! site after first use, leaving only the indirect-branch cost.
//!
//! Calibration: paper Table 4 reports OH-005 = 85 ns (HAMi) vs 42 ns
//! (FCSP). Those numbers *emerge* here from `lookup_ns` vs `cached_ns`
//! given the resolution policy.

use crate::simgpu::GpuDevice;

/// Resolution strategy for intercepted symbols.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Resolution {
    /// Hash-table dispatch on every call (HAMi-core style).
    PerCall,
    /// Resolve once, then indirect-branch through a cached pointer
    /// (BUD-FCSP style).
    Cached,
}

/// Per-call hook cost model.
#[derive(Clone, Debug)]
pub struct HookTable {
    resolution: Resolution,
    /// Cost of a full dispatch-table lookup (hashing the symbol, probing).
    lookup_ns: f64,
    /// Cost of the cached indirect call path.
    cached_ns: f64,
    /// Whether the first call for each symbol has been paid (cold path).
    warmed: bool,
    /// One-time cost of resolving the full symbol table (library ctor).
    cold_resolve_ns: f64,
    pub calls: u64,
}

impl HookTable {
    /// HAMi-core defaults: 70 ns table probe + ~15 ns shim prologue ⇒ ~85 ns.
    pub fn hami() -> HookTable {
        HookTable {
            resolution: Resolution::PerCall,
            lookup_ns: 70.0,
            cached_ns: 15.0,
            warmed: false,
            cold_resolve_ns: 180_000.0,
            calls: 0,
        }
    }

    /// BUD-FCSP defaults: cached pointer + shim prologue ⇒ ~42 ns
    /// (27 ns branch-predicted indirect call + 15 ns prologue).
    pub fn fcsp() -> HookTable {
        HookTable {
            resolution: Resolution::Cached,
            lookup_ns: 70.0,
            cached_ns: 27.0 + 15.0,
            warmed: false,
            cold_resolve_ns: 140_000.0,
            calls: 0,
        }
    }

    /// Cost of one intercepted call, with jitter from the device's RNG.
    pub fn call_ns(&mut self, dev: &mut GpuDevice) -> f64 {
        self.calls += 1;
        let base = match self.resolution {
            Resolution::PerCall => self.lookup_ns + self.cached_ns,
            Resolution::Cached => {
                if !self.warmed {
                    self.warmed = true;
                    // First call resolves and installs the cache entry.
                    self.lookup_ns + self.cached_ns
                } else {
                    self.cached_ns
                }
            }
        };
        base * dev.jitter()
    }

    /// One-time library-constructor cost (part of OH-004 context overhead).
    pub fn cold_resolve_ns(&self) -> f64 {
        self.cold_resolve_ns
    }

    /// Steady-state per-call cost without jitter (for reporting).
    pub fn steady_ns(&self) -> f64 {
        match self.resolution {
            Resolution::PerCall => self.lookup_ns + self.cached_ns,
            Resolution::Cached => self.cached_ns,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simgpu::GpuDevice;

    #[test]
    fn hami_steady_cost_matches_paper() {
        // Table 4 OH-005: HAMi = 85 ns.
        assert!((HookTable::hami().steady_ns() - 85.0).abs() < 1e-9);
    }

    #[test]
    fn fcsp_steady_cost_matches_paper() {
        // Table 4 OH-005: FCSP = 42 ns.
        assert!((HookTable::fcsp().steady_ns() - 42.0).abs() < 1e-9);
    }

    #[test]
    fn fcsp_first_call_pays_lookup() {
        let mut dev = GpuDevice::a100(1);
        dev.spec.jitter_sigma = 0.0;
        let mut h = HookTable::fcsp();
        let first = h.call_ns(&mut dev);
        let second = h.call_ns(&mut dev);
        assert!(first > second, "first={first} second={second}");
        assert!((second - 42.0).abs() < 1e-9);
    }

    #[test]
    fn hami_pays_lookup_every_call() {
        let mut dev = GpuDevice::a100(2);
        dev.spec.jitter_sigma = 0.0;
        let mut h = HookTable::hami();
        let a = h.call_ns(&mut dev);
        let b = h.call_ns(&mut dev);
        assert!((a - 85.0).abs() < 1e-9);
        assert!((b - 85.0).abs() < 1e-9);
        assert_eq!(h.calls, 2);
    }
}
