//! Shared-memory accounting region with semaphore synchronization.
//!
//! HAMi-core keeps per-GPU usage counters in a POSIX shared-memory region
//! mapped into every container, guarded by a semaphore (paper Listing 2).
//! Every allocation/free takes the lock, updates the tenant's usage and the
//! device total, and releases. Under multi-tenant churn the semaphore
//! becomes a contention point — OH-006 measures exactly that wait.
//!
//! The model keeps *real* accounting state (quota enforcement reads it) and
//! models the lock with an M/D/1-style wait: expected wait grows with the
//! utilization of the critical section by other tenants.

use std::collections::HashMap;

use crate::simgpu::{GpuDevice, TenantId};

/// Outcome of a quota reservation attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Reserve {
    Granted,
    /// Over quota: `used + request > limit`.
    OverQuota { used: u64, limit: u64 },
}

/// The shared accounting region.
#[derive(Clone, Debug)]
pub struct SharedRegion {
    /// Tenant → (used bytes, limit bytes).
    usage: HashMap<TenantId, (u64, Option<u64>)>,
    /// Critical-section service time (update + bookkeeping), ns.
    critical_ns: f64,
    /// Per-tenant lock acquisition rate while active (ops/sec), used to
    /// estimate contention probability.
    op_rate_hz: f64,
    /// Tenants currently performing allocation churn (contend for lock).
    active_tenants: u32,
    pub lock_acquisitions: u64,
    /// Cumulative modelled wait, ns (OH-006 numerator).
    pub total_wait_ns: f64,
}

impl SharedRegion {
    pub fn new(critical_ns: f64, op_rate_hz: f64) -> SharedRegion {
        SharedRegion {
            usage: HashMap::new(),
            critical_ns,
            op_rate_hz,
            active_tenants: 1,
            lock_acquisitions: 0,
            total_wait_ns: 0.0,
        }
    }

    /// HAMi-core calibration: ~400 ns critical section (semaphore pair +
    /// counter updates in shared memory).
    pub fn hami() -> SharedRegion {
        SharedRegion::new(400.0, 2_000.0)
    }

    /// FCSP uses atomics on the fast path; the semaphore is only taken for
    /// slow-path rebalancing, shrinking the effective critical section.
    pub fn fcsp() -> SharedRegion {
        SharedRegion::new(90.0, 2_000.0)
    }

    /// Register a tenant with an optional byte quota.
    pub fn add_tenant(&mut self, tenant: TenantId, limit: Option<u64>) {
        self.usage.insert(tenant, (0, limit));
    }

    pub fn remove_tenant(&mut self, tenant: TenantId) {
        self.usage.remove(&tenant);
    }

    /// Set how many tenants are concurrently hammering the lock (metric
    /// scenarios configure this; defaults to 1 = uncontended).
    pub fn set_active_tenants(&mut self, n: u32) {
        self.active_tenants = n.max(1);
    }

    /// Expected semaphore wait for one acquisition, ns. With `k` other
    /// active tenants each holding the lock for `critical_ns` at
    /// `op_rate_hz`, the probability an arrival finds the lock busy is
    /// `rho = k * op_rate * critical`, and the conditional wait is half a
    /// residual critical section plus queueing (M/D/1):
    /// `W = rho/(2(1-rho)) * critical`.
    pub fn expected_wait_ns(&self) -> f64 {
        let k = (self.active_tenants - 1) as f64;
        let rho = (k * self.op_rate_hz * self.critical_ns * 1e-9).min(0.95);
        if rho <= 0.0 {
            return 0.0;
        }
        rho / (2.0 * (1.0 - rho)) * self.critical_ns
    }

    /// Recalibrate the per-tenant lock acquisition rate from observed
    /// traffic (acquisitions over elapsed virtual time). Alloc-churn
    /// benchmarks drive the lock far harder than the default estimate.
    pub fn observe_rate(&mut self, elapsed_ns: f64) {
        if elapsed_ns > 0.0 && self.lock_acquisitions > 16 {
            let total_hz = self.lock_acquisitions as f64 / (elapsed_ns * 1e-9);
            self.op_rate_hz = total_hz / self.active_tenants as f64;
        }
    }

    /// `(total modelled wait ns, acquisitions)` for OH-006.
    pub fn contention_stats(&self) -> (f64, u64) {
        (self.total_wait_ns, self.lock_acquisitions)
    }

    /// Acquire-update-release for a reservation of `bytes`. Returns
    /// `(outcome, cost_ns)`; cost includes modelled lock wait + critical
    /// section (with jitter).
    pub fn reserve(&mut self, tenant: TenantId, bytes: u64, dev: &mut GpuDevice) -> (Reserve, f64) {
        let wait = self.lock_cost(dev);
        let (used, limit) = self.usage.entry(tenant).or_insert((0, None));
        let outcome = match *limit {
            Some(l) if *used + bytes > l => Reserve::OverQuota { used: *used, limit: l },
            _ => {
                *used += bytes;
                Reserve::Granted
            }
        };
        (outcome, wait)
    }

    /// Release `bytes` back to the tenant's quota.
    pub fn release(&mut self, tenant: TenantId, bytes: u64, dev: &mut GpuDevice) -> f64 {
        let wait = self.lock_cost(dev);
        if let Some((used, _)) = self.usage.get_mut(&tenant) {
            *used = used.saturating_sub(bytes);
        }
        wait
    }

    /// One lock acquisition: modelled wait (stochastic around the M/D/1
    /// expectation) + critical section.
    fn lock_cost(&mut self, dev: &mut GpuDevice) -> f64 {
        self.lock_acquisitions += 1;
        let expected = self.expected_wait_ns();
        // Exponential-ish spread around the expectation: waits are bursty.
        let wait = if expected > 0.0 {
            expected * dev.rng().exponential(1.0)
        } else {
            0.0
        };
        self.total_wait_ns += wait;
        wait + self.critical_ns * dev.jitter()
    }

    /// Tenant's current usage and limit.
    pub fn usage(&self, tenant: TenantId) -> (u64, Option<u64>) {
        self.usage.get(&tenant).copied().unwrap_or((0, None))
    }

    /// Total bytes accounted across tenants.
    pub fn total_used(&self) -> u64 {
        self.usage.values().map(|(u, _)| *u).sum()
    }

    pub fn critical_ns(&self) -> f64 {
        self.critical_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> GpuDevice {
        let mut d = GpuDevice::a100(1);
        d.spec.jitter_sigma = 0.0;
        d
    }

    #[test]
    fn quota_enforced() {
        let mut d = dev();
        let mut r = SharedRegion::hami();
        r.add_tenant(1, Some(1000));
        let (o, _) = r.reserve(1, 800, &mut d);
        assert_eq!(o, Reserve::Granted);
        let (o, _) = r.reserve(1, 300, &mut d);
        assert_eq!(o, Reserve::OverQuota { used: 800, limit: 1000 });
        // Release makes room.
        r.release(1, 500, &mut d);
        let (o, _) = r.reserve(1, 300, &mut d);
        assert_eq!(o, Reserve::Granted);
        assert_eq!(r.usage(1).0, 600);
    }

    #[test]
    fn unlimited_tenant_never_blocked() {
        let mut d = dev();
        let mut r = SharedRegion::hami();
        r.add_tenant(1, None);
        let (o, _) = r.reserve(1, u64::MAX / 2, &mut d);
        assert_eq!(o, Reserve::Granted);
    }

    #[test]
    fn uncontended_wait_is_zero() {
        let r = SharedRegion::hami();
        assert_eq!(r.expected_wait_ns(), 0.0);
    }

    #[test]
    fn contention_grows_with_tenants() {
        let mut r = SharedRegion::hami();
        r.set_active_tenants(2);
        let w2 = r.expected_wait_ns();
        r.set_active_tenants(8);
        let w8 = r.expected_wait_ns();
        assert!(w8 > w2 && w2 > 0.0, "w2={w2} w8={w8}");
    }

    #[test]
    fn fcsp_critical_section_smaller() {
        let mut h = SharedRegion::hami();
        let mut f = SharedRegion::fcsp();
        h.set_active_tenants(4);
        f.set_active_tenants(4);
        assert!(f.expected_wait_ns() < h.expected_wait_ns());
        assert!(f.critical_ns() < h.critical_ns());
    }

    #[test]
    fn accounting_tracks_totals() {
        let mut d = dev();
        let mut r = SharedRegion::hami();
        r.add_tenant(1, None);
        r.add_tenant(2, None);
        r.reserve(1, 100, &mut d);
        r.reserve(2, 200, &mut d);
        assert_eq!(r.total_used(), 300);
        assert_eq!(r.lock_acquisitions, 2);
    }

    #[test]
    fn release_saturates_at_zero() {
        let mut d = dev();
        let mut r = SharedRegion::hami();
        r.add_tenant(1, Some(100));
        r.release(1, 500, &mut d);
        assert_eq!(r.usage(1).0, 0);
    }
}
