//! Virtualized NVML: utilization polling and per-container memory
//! reporting (paper §2.3.1 — "NVML interception virtualizes memory
//! reporting to show container-specific limits").
//!
//! The poller model drives OH-009: HAMi-core calls
//! `nvmlDeviceGetUtilizationRates()` every `interval_ns`; each call costs
//! `poll_cost_ns` of CPU. The steady-state CPU overhead fraction is
//! `poll_cost / interval` (paper eq. 4). The poll results also feed the
//! rate limiter (its only view of utilization — the source of HAMi's
//! coarse control).

use crate::simgpu::{GpuDevice, TenantId};

/// Background utilization poller.
#[derive(Clone, Debug)]
pub struct NvmlPoller {
    /// Poll interval, virtual ns (HAMi default 100 ms).
    pub interval_ns: u64,
    /// CPU cost per poll (NVML ioctl + bookkeeping), ns.
    pub poll_cost_ns: f64,
    /// Last poll boundary processed.
    last_poll_ns: u64,
    /// Most recent utilization sample per the poller's view.
    pub last_device_util: f64,
    pub polls: u64,
}

impl NvmlPoller {
    pub fn new(interval_ns: u64, poll_cost_ns: f64) -> NvmlPoller {
        NvmlPoller { interval_ns, poll_cost_ns, last_poll_ns: 0, last_device_util: 0.0, polls: 0 }
    }

    /// HAMi defaults: 100 ms interval, ~55 µs per poll (NVML ioctl round
    /// trip plus shared-region update) ⇒ ~0.055 % CPU.
    pub fn hami() -> NvmlPoller {
        NvmlPoller::new(100_000_000, 55_000.0)
    }

    /// FCSP polls less often (event-assisted) and with a cheaper read.
    pub fn fcsp() -> NvmlPoller {
        NvmlPoller::new(250_000_000, 30_000.0)
    }

    /// Advance the poller to the device's current virtual time, sampling
    /// utilization at each boundary crossed. Returns number of polls fired.
    pub fn tick(&mut self, dev: &mut GpuDevice) -> u32 {
        let now = dev.clock.now_ns();
        let mut fired = 0;
        while now.saturating_sub(self.last_poll_ns) >= self.interval_ns {
            self.last_poll_ns += self.interval_ns;
            self.last_device_util = dev.sms.device_utilization(self.last_poll_ns);
            self.polls += 1;
            fired += 1;
        }
        fired
    }

    /// Steady-state CPU overhead fraction (paper eq. 4 / OH-009).
    pub fn cpu_overhead(&self) -> f64 {
        self.poll_cost_ns / self.interval_ns as f64
    }
}

/// Virtualized `nvmlDeviceGetMemoryInfo`: the container sees its quota as
/// "total" and quota-minus-used as "free" (IS-001 checks this equals the
/// configured limit).
pub fn virtual_mem_info(
    tenant: TenantId,
    used: u64,
    limit: Option<u64>,
    dev: &GpuDevice,
) -> (u64, u64) {
    let _ = tenant;
    match limit {
        Some(l) => (l.saturating_sub(used), l),
        None => (dev.memory.free_bytes(), dev.memory.capacity()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_overhead_matches_eq4() {
        let p = NvmlPoller::hami();
        // 55 µs / 100 ms = 0.055 %.
        assert!((p.cpu_overhead() - 0.00055).abs() < 1e-9);
        assert!(NvmlPoller::fcsp().cpu_overhead() < p.cpu_overhead());
    }

    #[test]
    fn tick_fires_once_per_interval() {
        let mut dev = GpuDevice::a100(1);
        let mut p = NvmlPoller::new(1_000, 10.0);
        dev.clock.advance(3_500);
        assert_eq!(p.tick(&mut dev), 3);
        assert_eq!(p.polls, 3);
        // No double-fire.
        assert_eq!(p.tick(&mut dev), 0);
        dev.clock.advance(600);
        assert_eq!(p.tick(&mut dev), 1);
    }

    #[test]
    fn virtual_mem_info_shows_quota() {
        let dev = GpuDevice::a100(2);
        let (free, total) = virtual_mem_info(1, 400, Some(1000), &dev);
        assert_eq!((free, total), (600, 1000));
        // Unlimited tenant sees the physical device.
        let (free, total) = virtual_mem_info(1, 0, None, &dev);
        assert_eq!(total, dev.memory.capacity());
        assert_eq!(free, dev.memory.free_bytes());
    }

    #[test]
    fn over_quota_free_saturates() {
        let dev = GpuDevice::a100(3);
        let (free, _) = virtual_mem_info(1, 2000, Some(1000), &dev);
        assert_eq!(free, 0);
    }
}
