//! HAMi-core-like backend (paper §2.3.1).
//!
//! Mechanisms composed here, each contributing measurable overhead:
//!
//! - **dlsym hooks** ([`super::hooks::HookTable::hami`]): full dispatch
//!   lookup on every intercepted call (~85 ns, OH-005).
//! - **Shared-region accounting** ([`super::shared_region`]): every
//!   alloc/free takes the semaphore and updates usage (OH-002/003/006/007).
//! - **Fixed-window rate limiter** ([`super::rate_limiter::HamiLimiter`]):
//!   token pool refilled only when the 100 ms NVML poll fires — coarse
//!   closed-loop SM limiting (OH-001/008, IS-003/004).
//! - **NVML poller** ([`super::nvml::NvmlPoller::hami`]): background
//!   utilization sampling (OH-009), also the limiter's only feedback path.
//!
//! Memory quota violations are rejected *before* touching the driver
//! (IS-002), and NVML memory queries report the container quota (IS-001).

use std::collections::HashMap;

use crate::simgpu::error::GpuError;
use crate::simgpu::kernel::{duration_ns, ExecContext, KernelDesc};
use crate::simgpu::sm::SmGrant;
use crate::simgpu::{GpuDevice, TenantId};

use super::hooks::HookTable;
use super::nvml::{virtual_mem_info, NvmlPoller};
use super::rate_limiter::HamiLimiter;
use super::shared_region::{Reserve, SharedRegion};
use super::{LaunchGate, TenantConfig, VirtLayer};

struct HamiTenant {
    cfg: TenantConfig,
    limiter: Option<HamiLimiter>,
}

/// The HAMi-core-like layer.
pub struct HamiCore {
    hooks: HookTable,
    region: SharedRegion,
    poller: NvmlPoller,
    tenants: HashMap<TenantId, HamiTenant>,
    /// Round-robin arbitration pointer (the CUDA driver's context
    /// timeslicer — HAMi adds no cross-tenant scheduler of its own).
    rr_counter: usize,
    /// Per-allocation tracking cost: hash-table insert/remove in the
    /// interception library (OH-007), ns.
    tracking_ns: f64,
    /// Quota-check arithmetic on the launch path, ns.
    quota_check_ns: f64,
    /// NVML `nvmlDeviceGetMemoryInfo` ioctl round-trip HAMi performs on
    /// every allocation to reconcile the shared region against the real
    /// device (the dominant term in Table 4's 45.2 µs alloc).
    nvml_alloc_check_ns: f64,
    /// Region reconciliation + NVML poke on the free path (Table 4:
    /// 32.4 µs free vs 8.1 native).
    nvml_free_sync_ns: f64,
    /// Launch-path shared-region synchronization: HAMi takes the region
    /// semaphore and scans per-tenant core counters on *every* launch
    /// (Table 4: launch 15.3 µs vs 4.2 native — the dominant added term).
    launch_region_sync_ns: f64,
}

/// Device memory the interception library's own context bookkeeping
/// consumes out of the tenant's quota (CUDA context + tracking tables).
/// This is why memory-limit accuracy is below 100 % (IS-001: 98.2 %).
pub const CTX_RESERVE: u64 = 180 << 20;

impl HamiCore {
    pub fn new() -> HamiCore {
        HamiCore {
            hooks: HookTable::hami(),
            region: SharedRegion::hami(),
            poller: NvmlPoller::hami(),
            tenants: HashMap::new(),
            rr_counter: 0,
            tracking_ns: 260.0,
            quota_check_ns: 110.0,
            nvml_alloc_check_ns: 31_500.0,
            nvml_free_sync_ns: 23_600.0,
            launch_region_sync_ns: 10_000.0,
        }
    }

    fn active_tenants(&self) -> u32 {
        self.tenants.len() as u32
    }
}

impl Default for HamiCore {
    fn default() -> Self {
        Self::new()
    }
}

impl VirtLayer for HamiCore {
    fn name(&self) -> &'static str {
        "hami"
    }

    fn register_tenant(
        &mut self,
        tenant: TenantId,
        cfg: TenantConfig,
        dev: &mut GpuDevice,
    ) -> Result<(), GpuError> {
        self.region.add_tenant(tenant, cfg.mem_limit);
        if cfg.mem_limit.is_some() {
            // The context itself eats into the quota.
            self.region.reserve(tenant, CTX_RESERVE, dev);
        }
        let limiter = cfg.sm_limit.filter(|l| *l < 1.0).map(HamiLimiter::new);
        self.tenants.insert(tenant, HamiTenant { cfg, limiter });
        self.region.set_active_tenants(self.active_tenants());
        dev.grant_sms(tenant, SmGrant::Shared).map_err(|_| GpuError::InvalidValue)
    }

    fn unregister_tenant(&mut self, tenant: TenantId, dev: &mut GpuDevice) {
        self.tenants.remove(&tenant);
        self.region.remove_tenant(tenant);
        self.region.set_active_tenants(self.active_tenants().max(1));
        dev.sms.unregister(tenant);
    }

    fn hook_overhead_ns(&mut self, dev: &mut GpuDevice) -> f64 {
        self.hooks.call_ns(dev)
    }

    fn context_create_overhead_ns(&mut self, _tenant: TenantId, dev: &mut GpuDevice) -> f64 {
        // Library constructor: resolve hook table, map the shared region,
        // initialize semaphores. Paper Table 4: 312 µs vs 125 µs native.
        (self.hooks.cold_resolve_ns() + 7_000.0) * dev.jitter()
    }

    fn pre_alloc(
        &mut self,
        tenant: TenantId,
        size: u64,
        dev: &mut GpuDevice,
    ) -> Result<f64, GpuError> {
        let hook = self.hooks.call_ns(dev);
        let (outcome, lock_cost) = self.region.reserve(tenant, size, dev);
        match outcome {
            // Granted: HAMi reconciles against the physical device with an
            // NVML memory-info query before letting the driver allocate.
            Reserve::Granted => Ok(hook
                + lock_cost
                + (self.quota_check_ns + self.nvml_alloc_check_ns) * dev.jitter()),
            // Rejection is decided from the shared region alone — fast.
            Reserve::OverQuota { .. } => Err(GpuError::QuotaExceeded),
        }
    }

    fn post_alloc(&mut self, _tenant: TenantId, _size: u64, dev: &mut GpuDevice) -> f64 {
        // Allocation-table insert + size bookkeeping.
        self.tracking_ns * dev.jitter()
    }

    fn pre_free(&mut self, _tenant: TenantId, dev: &mut GpuDevice) -> f64 {
        self.hooks.call_ns(dev)
            + (self.tracking_ns + self.nvml_free_sync_ns) * dev.jitter()
    }

    fn post_free(&mut self, tenant: TenantId, size: u64, dev: &mut GpuDevice) -> f64 {
        self.region.release(tenant, size, dev)
    }

    fn gate_launch(
        &mut self,
        tenant: TenantId,
        kernel: &KernelDesc,
        dev: &mut GpuDevice,
    ) -> LaunchGate {
        self.tick(dev);
        let mut overhead = self.hooks.call_ns(dev) + self.quota_check_ns * dev.jitter();
        // HAMi consults the shared region under its semaphore on every
        // launch (core-counter scan) — even for unlimited tenants.
        overhead += (2.0 * self.region.critical_ns() + self.launch_region_sync_ns)
            * dev.jitter();
        let concurrent = dev.concurrent_shared(tenant);
        let granted = dev.sms.effective_sms(tenant, concurrent);
        let mut wait = 0.0;
        if let Some(t) = self.tenants.get_mut(&tenant) {
            if let Some(lim) = t.limiter.as_mut() {
                let est = duration_ns(&dev.spec, kernel, &ExecContext::uncontended(granted));
                let sm_frac = (granted as f64 / dev.spec.sm_count as f64)
                    * kernel.occupancy.clamp(1.0 / 2048.0, 1.0);
                let adm = lim.acquire(est * sm_frac, dev.clock.now_ns() as f64);
                overhead += adm.overhead_ns;
                wait = adm.wait_ns;
            }
        }
        LaunchGate { overhead_ns: overhead, throttle_wait_ns: wait, granted_sms: granted }
    }

    fn on_kernel_complete(&mut self, tenant: TenantId, sm_frac: f64, busy_ns: f64, _now_ns: f64) {
        if let Some(t) = self.tenants.get_mut(&tenant) {
            if let Some(lim) = t.limiter.as_mut() {
                lim.on_complete(sm_frac, busy_ns);
            }
        }
    }

    fn mem_info(&self, tenant: TenantId, dev: &GpuDevice) -> (u64, u64) {
        let (used, limit) = self.region.usage(tenant);
        virtual_mem_info(tenant, used, limit, dev)
    }

    fn tick(&mut self, dev: &mut GpuDevice) {
        self.poller.tick(dev);
        self.region.observe_rate(dev.clock.now_ns() as f64);
    }

    fn monitor_cpu_overhead(&self) -> f64 {
        self.poller.cpu_overhead()
    }

    fn contention_stats(&self) -> (f64, u64) {
        self.region.contention_stats()
    }

    fn tracking_cost_ns(&self) -> f64 {
        self.tracking_ns
    }

    fn arbitrate(&mut self, pending: &[(TenantId, KernelDesc)]) -> usize {
        // Driver-level round robin over submitted work: one head-of-line
        // item per turn, regardless of its size — large-kernel tenants get
        // more *service time* per turn, which is HAMi's fairness gap.
        if pending.is_empty() {
            return 0;
        }
        let idx = self.rr_counter % pending.len();
        self.rr_counter += 1;
        idx
    }

    fn sm_limit(&self, tenant: TenantId) -> f64 {
        self.tenants
            .get(&tenant)
            .and_then(|t| t.cfg.sm_limit)
            .unwrap_or(1.0)
    }

    fn update_sm_limit(&mut self, tenant: TenantId, limit: f64) -> bool {
        if let Some(t) = self.tenants.get_mut(&tenant) {
            t.cfg.sm_limit = Some(limit);
            match t.limiter.as_mut() {
                Some(l) => l.set_limit(limit),
                None => t.limiter = Some(HamiLimiter::new(limit)),
            }
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (GpuDevice, HamiCore) {
        let mut dev = GpuDevice::a100(7);
        dev.spec.jitter_sigma = 0.0;
        let mut h = HamiCore::new();
        h.register_tenant(1, TenantConfig::unlimited().with_mem_limit(1 << 30), &mut dev)
            .unwrap();
        (dev, h)
    }

    #[test]
    fn hook_cost_near_85ns() {
        let (mut dev, mut h) = setup();
        let c = h.hook_overhead_ns(&mut dev);
        assert!((c - 85.0).abs() < 1.0, "c={c}");
    }

    #[test]
    fn quota_rejects_over_allocation() {
        let (mut dev, mut h) = setup();
        assert!(h.pre_alloc(1, 1 << 29, &mut dev).is_ok());
        assert_eq!(h.pre_alloc(1, 1 << 30, &mut dev), Err(GpuError::QuotaExceeded));
    }

    #[test]
    fn mem_info_shows_container_quota() {
        let (mut dev, mut h) = setup();
        h.pre_alloc(1, 1 << 20, &mut dev).unwrap();
        let (free, total) = h.mem_info(1, &dev);
        assert_eq!(total, 1 << 30);
        // Free = quota - allocation - the library's context reserve.
        assert_eq!(free, (1 << 30) - (1 << 20) - CTX_RESERVE);
    }

    #[test]
    fn launch_overhead_well_above_native() {
        let (mut dev, mut h) = setup();
        let g = h.gate_launch(1, &KernelDesc::null(), &mut dev);
        // Hook + quota + 2 shared-region touches ≈ 1 µs; the paper's 15.3µs
        // total includes the driver's 4.2µs base plus limiter waits — the
        // full path is asserted in the metrics tests.
        assert!(g.overhead_ns > 500.0, "overhead={}", g.overhead_ns);
        assert_eq!(g.granted_sms, 108);
    }

    #[test]
    fn limited_tenant_gets_throttled_eventually() {
        let mut dev = GpuDevice::a100(9);
        dev.spec.jitter_sigma = 0.0;
        let mut h = HamiCore::new();
        h.register_tenant(2, TenantConfig::unlimited().with_sm_limit(0.25), &mut dev).unwrap();
        let k = KernelDesc::gemm(2048, 2048, 2048, false);
        let mut throttled = false;
        for _ in 0..400 {
            let g = h.gate_launch(2, &k, &mut dev);
            let span = dev.raw_launch(2, 0, &k, g.granted_sms).unwrap();
            dev.clock.advance_to(span.1);
            h.on_kernel_complete(2, 1.0, (span.1 - span.0) as f64, span.1 as f64);
            if g.throttle_wait_ns > 0.0 {
                throttled = true;
                break;
            }
        }
        assert!(throttled, "limiter never engaged");
    }

    #[test]
    fn context_overhead_calibrated() {
        let (mut dev, mut h) = setup();
        let extra = h.context_create_overhead_ns(1, &mut dev);
        // Table 4: HAMi context = 312 µs = 125 native + ~187 added.
        assert!((extra - 187_000.0).abs() < 30_000.0, "extra={extra}");
    }

    #[test]
    fn unregister_releases_state() {
        let (mut dev, mut h) = setup();
        h.unregister_tenant(1, &mut dev);
        // Unknown tenant → unlimited view.
        let (_, total) = h.mem_info(1, &dev);
        assert_eq!(total, dev.memory.capacity());
    }
}
