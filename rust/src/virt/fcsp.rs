//! BUD-FCSP-like backend (paper §2.3.2): HAMi-compatible API with four
//! measurable improvements —
//!
//! 1. **Cached hook resolution** ([`super::hooks::HookTable::fcsp`]):
//!    ~42 ns per intercepted call vs HAMi's ~85 ns.
//! 2. **Lock-light accounting** ([`super::shared_region::SharedRegion::fcsp`]):
//!    atomics on the fast path shrink the critical section ~4×.
//! 3. **Adaptive token bucket** ([`super::rate_limiter::AdaptiveBucket`]):
//!    continuous refill + burst credit + integral trim ⇒ sub-percentage SM
//!    control (IS-003 ≈ 93 % vs 85 %).
//! 4. **Weighted fair queuing** ([`super::wfq::WfqScheduler`]): cross-tenant
//!    arbitration by virtual finish time (IS-008 ≈ 0.94 vs 0.87).

use std::collections::HashMap;

use crate::simgpu::error::GpuError;
use crate::simgpu::kernel::{duration_ns, ExecContext, KernelDesc};
use crate::simgpu::sm::SmGrant;
use crate::simgpu::{GpuDevice, TenantId};

use super::hooks::HookTable;
use super::nvml::{virtual_mem_info, NvmlPoller};
use super::rate_limiter::AdaptiveBucket;
use super::shared_region::{Reserve, SharedRegion};
use super::wfq::WfqScheduler;
use super::{LaunchGate, TenantConfig, VirtLayer};

struct FcspTenant {
    cfg: TenantConfig,
    limiter: Option<AdaptiveBucket>,
}

/// The BUD-FCSP-like layer.
pub struct BudFcsp {
    hooks: HookTable,
    region: SharedRegion,
    poller: NvmlPoller,
    wfq: WfqScheduler,
    tenants: HashMap<TenantId, FcspTenant>,
    /// Per-allocation tracking cost (open-addressing table, cheaper than
    /// HAMi's chained hash), ns.
    tracking_ns: f64,
    /// Launch-path quota check (branch on cached quota state), ns.
    quota_check_ns: f64,
    /// FCSP batches NVML reconciliation: a cheaper cached read with
    /// periodic refresh amortizes the ioctl (Table 4: 28.3 µs alloc).
    nvml_alloc_check_ns: f64,
    /// Lighter free-path sync (Table 4: 18.6 µs free).
    nvml_free_sync_ns: f64,
    /// Launch-path state sync: FCSP reads an atomic snapshot instead of
    /// taking the semaphore, but still refreshes its cached core counters
    /// (Table 4: launch 8.7 µs vs 4.2 native).
    launch_sync_ns: f64,
}

/// Context bookkeeping reserve charged against the quota (leaner tables
/// than HAMi's — IS-001: 99.1 %).
pub const CTX_RESERVE: u64 = 90 << 20;

impl BudFcsp {
    pub fn new() -> BudFcsp {
        BudFcsp {
            hooks: HookTable::fcsp(),
            region: SharedRegion::fcsp(),
            poller: NvmlPoller::fcsp(),
            wfq: WfqScheduler::new(),
            tenants: HashMap::new(),
            tracking_ns: 120.0,
            quota_check_ns: 45.0,
            nvml_alloc_check_ns: 15_300.0,
            nvml_free_sync_ns: 10_200.0,
            launch_sync_ns: 4_300.0,
        }
    }
}

impl Default for BudFcsp {
    fn default() -> Self {
        Self::new()
    }
}

impl VirtLayer for BudFcsp {
    fn name(&self) -> &'static str {
        "fcsp"
    }

    fn register_tenant(
        &mut self,
        tenant: TenantId,
        cfg: TenantConfig,
        dev: &mut GpuDevice,
    ) -> Result<(), GpuError> {
        self.region.add_tenant(tenant, cfg.mem_limit);
        if cfg.mem_limit.is_some() {
            self.region.reserve(tenant, CTX_RESERVE, dev);
        }
        self.wfq.add_tenant(tenant, cfg.weight);
        let limiter = cfg.sm_limit.filter(|l| *l < 1.0).map(AdaptiveBucket::new);
        self.tenants.insert(tenant, FcspTenant { cfg, limiter });
        self.region.set_active_tenants(self.tenants.len() as u32);
        dev.grant_sms(tenant, SmGrant::Shared).map_err(|_| GpuError::InvalidValue)
    }

    fn unregister_tenant(&mut self, tenant: TenantId, dev: &mut GpuDevice) {
        self.tenants.remove(&tenant);
        self.region.remove_tenant(tenant);
        self.wfq.remove_tenant(tenant);
        self.region.set_active_tenants((self.tenants.len() as u32).max(1));
        dev.sms.unregister(tenant);
    }

    fn hook_overhead_ns(&mut self, dev: &mut GpuDevice) -> f64 {
        self.hooks.call_ns(dev)
    }

    fn context_create_overhead_ns(&mut self, _tenant: TenantId, dev: &mut GpuDevice) -> f64 {
        // Lazy symbol resolution + smaller shared mapping: Table 4 shows
        // 198 µs vs native 125 µs ⇒ ~73 µs added.
        (self.hooks.cold_resolve_ns() / 2.0 + 3_000.0) * dev.jitter()
    }

    fn pre_alloc(
        &mut self,
        tenant: TenantId,
        size: u64,
        dev: &mut GpuDevice,
    ) -> Result<f64, GpuError> {
        let hook = self.hooks.call_ns(dev);
        let (outcome, lock_cost) = self.region.reserve(tenant, size, dev);
        match outcome {
            Reserve::Granted => Ok(hook
                + lock_cost
                + (self.quota_check_ns + self.nvml_alloc_check_ns) * dev.jitter()),
            Reserve::OverQuota { .. } => Err(GpuError::QuotaExceeded),
        }
    }

    fn post_alloc(&mut self, _tenant: TenantId, _size: u64, dev: &mut GpuDevice) -> f64 {
        self.tracking_ns * dev.jitter()
    }

    fn pre_free(&mut self, _tenant: TenantId, dev: &mut GpuDevice) -> f64 {
        self.hooks.call_ns(dev)
            + (self.tracking_ns + self.nvml_free_sync_ns) * dev.jitter()
    }

    fn post_free(&mut self, tenant: TenantId, size: u64, dev: &mut GpuDevice) -> f64 {
        self.region.release(tenant, size, dev)
    }

    fn gate_launch(
        &mut self,
        tenant: TenantId,
        kernel: &KernelDesc,
        dev: &mut GpuDevice,
    ) -> LaunchGate {
        self.tick(dev);
        // Fast path: hook + cached-quota branch; the shared region is NOT
        // locked per launch (atomic snapshot read + counter refresh).
        let mut overhead = self.hooks.call_ns(dev)
            + (self.quota_check_ns + self.launch_sync_ns) * dev.jitter();
        let concurrent = dev.concurrent_shared(tenant);
        let granted = dev.sms.effective_sms(tenant, concurrent);
        let mut wait = 0.0;
        if let Some(t) = self.tenants.get_mut(&tenant) {
            if let Some(lim) = t.limiter.as_mut() {
                let est = duration_ns(&dev.spec, kernel, &ExecContext::uncontended(granted));
                let sm_frac = (granted as f64 / dev.spec.sm_count as f64)
                    * kernel.occupancy.clamp(1.0 / 2048.0, 1.0);
                let adm = lim.acquire(est * sm_frac, dev.clock.now_ns() as f64);
                overhead += adm.overhead_ns;
                wait = adm.wait_ns;
            }
        }
        // WFQ virtual-time accounting for this tenant's submission.
        self.wfq.serve(tenant, kernel.flops.max(1.0));
        LaunchGate { overhead_ns: overhead, throttle_wait_ns: wait, granted_sms: granted }
    }

    fn on_kernel_complete(&mut self, tenant: TenantId, sm_frac: f64, busy_ns: f64, now_ns: f64) {
        if let Some(t) = self.tenants.get_mut(&tenant) {
            if let Some(lim) = t.limiter.as_mut() {
                lim.on_complete(sm_frac, busy_ns, now_ns);
            }
        }
    }

    fn mem_info(&self, tenant: TenantId, dev: &GpuDevice) -> (u64, u64) {
        let (used, limit) = self.region.usage(tenant);
        virtual_mem_info(tenant, used, limit, dev)
    }

    fn tick(&mut self, dev: &mut GpuDevice) {
        self.poller.tick(dev);
        self.region.observe_rate(dev.clock.now_ns() as f64);
    }

    fn contention_stats(&self) -> (f64, u64) {
        self.region.contention_stats()
    }

    fn tracking_cost_ns(&self) -> f64 {
        self.tracking_ns
    }

    fn monitor_cpu_overhead(&self) -> f64 {
        self.poller.cpu_overhead()
    }

    fn fair_scheduler(&self) -> bool {
        true
    }

    fn arbitrate(&mut self, pending: &[(TenantId, KernelDesc)]) -> usize {
        let costs: Vec<(TenantId, f64)> =
            pending.iter().map(|(t, k)| (*t, k.flops.max(1.0))).collect();
        self.wfq.pick(&costs).unwrap_or(0)
    }

    fn sm_limit(&self, tenant: TenantId) -> f64 {
        self.tenants
            .get(&tenant)
            .and_then(|t| t.cfg.sm_limit)
            .unwrap_or(1.0)
    }

    fn update_sm_limit(&mut self, tenant: TenantId, limit: f64) -> bool {
        if let Some(t) = self.tenants.get_mut(&tenant) {
            t.cfg.sm_limit = Some(limit);
            match t.limiter.as_mut() {
                Some(l) => l.set_limit(limit),
                None => t.limiter = Some(AdaptiveBucket::new(limit)),
            }
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (GpuDevice, BudFcsp) {
        let mut dev = GpuDevice::a100(11);
        dev.spec.jitter_sigma = 0.0;
        let mut f = BudFcsp::new();
        f.register_tenant(1, TenantConfig::unlimited().with_mem_limit(1 << 30), &mut dev)
            .unwrap();
        (dev, f)
    }

    #[test]
    fn hook_cost_near_42ns_after_warmup() {
        let (mut dev, mut f) = setup();
        f.hook_overhead_ns(&mut dev); // cold
        let c = f.hook_overhead_ns(&mut dev);
        assert!((c - 42.0).abs() < 1.0, "c={c}");
    }

    #[test]
    fn cheaper_than_hami_on_every_path() {
        let mut dev = GpuDevice::a100(12);
        dev.spec.jitter_sigma = 0.0;
        let mut f = BudFcsp::new();
        let mut h = super::super::hami::HamiCore::new();
        f.register_tenant(1, TenantConfig::unlimited(), &mut dev).unwrap();
        h.register_tenant(2, TenantConfig::unlimited(), &mut dev).unwrap();
        f.hook_overhead_ns(&mut dev); // warm the cache
        assert!(f.hook_overhead_ns(&mut dev) < h.hook_overhead_ns(&mut dev));
        assert!(
            f.context_create_overhead_ns(1, &mut dev) < h.context_create_overhead_ns(2, &mut dev)
        );
        let gf = f.gate_launch(1, &KernelDesc::null(), &mut dev);
        let gh = h.gate_launch(2, &KernelDesc::null(), &mut dev);
        assert!(gf.overhead_ns < gh.overhead_ns, "f={} h={}", gf.overhead_ns, gh.overhead_ns);
    }

    #[test]
    fn quota_still_enforced() {
        let (mut dev, mut f) = setup();
        assert!(f.pre_alloc(1, 1 << 29, &mut dev).is_ok());
        assert_eq!(f.pre_alloc(1, 1 << 30, &mut dev), Err(GpuError::QuotaExceeded));
    }

    #[test]
    fn arbitrate_uses_wfq() {
        let mut dev = GpuDevice::a100(13);
        let mut f = BudFcsp::new();
        f.register_tenant(1, TenantConfig::unlimited(), &mut dev).unwrap();
        f.register_tenant(2, TenantConfig::unlimited(), &mut dev).unwrap();
        // Tenant 1 has consumed lots of virtual time.
        for _ in 0..50 {
            f.gate_launch(1, &KernelDesc::gemm(512, 512, 512, false), &mut dev);
        }
        let pending = vec![
            (1, KernelDesc::null()),
            (2, KernelDesc::null()),
        ];
        assert_eq!(f.arbitrate(&pending), 1); // tenant 2 is behind → served
    }

    #[test]
    fn monitor_overhead_below_hami() {
        let f = BudFcsp::new();
        let h = super::super::hami::HamiCore::new();
        assert!(f.monitor_cpu_overhead() < h.monitor_cpu_overhead());
    }
}
