//! MIG-Ideal backend (paper §4.3, Table 2 `mig`).
//!
//! Models *hardware* partitioning: each tenant receives a dedicated SM
//! slice, a dedicated HBM capacity quota and a dedicated L2 way range when
//! registered. There is no software interception, so every hook is free;
//! isolation is perfect by construction. The paper's MIG-Ideal is likewise
//! simulated ("derived from NVIDIA specifications, not measured") and
//! serves as the scoring baseline — 100 % by definition.
//!
//! Partition geometry: tenants register with an SM fraction (via
//! `TenantConfig::sm_limit`); the backend maps it onto the nearest valid
//! slice out of the 7 compute slices an A100 exposes (1g…7g), mirroring
//! MIG's fixed geometries.

use std::collections::HashMap;

use crate::simgpu::cache::Partition;
use crate::simgpu::error::GpuError;
use crate::simgpu::kernel::KernelDesc;
use crate::simgpu::sm::SmGrant;
use crate::simgpu::{GpuDevice, TenantId};

use super::{LaunchGate, TenantConfig, VirtLayer};

/// Number of compute slices MIG exposes on an A100.
pub const COMPUTE_SLICES: u32 = 7;

struct MigTenant {
    /// Compute slices granted (1..=7).
    slices: u32,
    sms: u32,
    mem_quota: u64,
    mem_used: u64,
}

/// The simulated-ideal MIG backend.
pub struct MigIdeal {
    tenants: HashMap<TenantId, MigTenant>,
    slices_used: u32,
}

impl MigIdeal {
    pub fn new() -> MigIdeal {
        MigIdeal { tenants: HashMap::new(), slices_used: 0 }
    }

    /// Map an SM fraction onto whole MIG compute slices. Rounds *down*
    /// (with a 1-slice floor) so that equal-share configurations like
    /// 4 x 25 % always fit the 7-slice geometry — the conservative choice
    /// an operator makes on real MIG (4 x 1g instances on an A100).
    pub fn slices_for(frac: f64) -> u32 {
        ((frac * COMPUTE_SLICES as f64).floor() as u32).clamp(1, COMPUTE_SLICES)
    }

    fn rebuild_l2_partition(&self, dev: &mut GpuDevice) {
        let total_ways = dev.l2.ways() as u32;
        let mut map = HashMap::new();
        let mut cursor = 0u32;
        for (&t, mt) in &self.tenants {
            let ways = ((mt.slices * total_ways) / COMPUTE_SLICES).max(1);
            let end = (cursor + ways).min(total_ways);
            map.insert(t, cursor..end);
            cursor = end;
        }
        dev.l2.set_partition(Partition::Ways(map));
    }
}

impl Default for MigIdeal {
    fn default() -> Self {
        Self::new()
    }
}

impl VirtLayer for MigIdeal {
    fn name(&self) -> &'static str {
        "mig"
    }

    fn register_tenant(
        &mut self,
        tenant: TenantId,
        cfg: TenantConfig,
        dev: &mut GpuDevice,
    ) -> Result<(), GpuError> {
        let frac = cfg.sm_limit.unwrap_or(1.0);
        let slices = Self::slices_for(frac);
        if self.slices_used + slices > COMPUTE_SLICES {
            // No free geometry — the hard constraint MIG reconfiguration
            // hits in practice.
            return Err(GpuError::InvalidValue);
        }
        let sms = ((dev.spec.sm_count * slices) / COMPUTE_SLICES).max(1);
        dev.grant_sms(tenant, SmGrant::Dedicated(sms)).map_err(|_| GpuError::InvalidValue)?;
        let mem_quota = cfg
            .mem_limit
            .unwrap_or(dev.spec.hbm_bytes * slices as u64 / COMPUTE_SLICES as u64);
        self.slices_used += slices;
        let _ = cfg;
        self.tenants.insert(tenant, MigTenant { slices, sms, mem_quota, mem_used: 0 });
        self.rebuild_l2_partition(dev);
        Ok(())
    }

    fn unregister_tenant(&mut self, tenant: TenantId, dev: &mut GpuDevice) {
        if let Some(t) = self.tenants.remove(&tenant) {
            self.slices_used -= t.slices;
        }
        dev.sms.unregister(tenant);
        self.rebuild_l2_partition(dev);
    }

    fn hook_overhead_ns(&mut self, _dev: &mut GpuDevice) -> f64 {
        0.0 // hardware partitioning: no interception layer
    }

    fn context_create_overhead_ns(&mut self, _tenant: TenantId, _dev: &mut GpuDevice) -> f64 {
        0.0
    }

    fn pre_alloc(
        &mut self,
        tenant: TenantId,
        size: u64,
        _dev: &mut GpuDevice,
    ) -> Result<f64, GpuError> {
        // The instance's own memory controller enforces capacity — an
        // over-quota allocation fails exactly like device OOM, at no added
        // software cost.
        match self.tenants.get_mut(&tenant) {
            Some(t) if t.mem_used + size > t.mem_quota => Err(GpuError::OutOfMemory),
            Some(t) => {
                t.mem_used += size;
                Ok(0.0)
            }
            None => Ok(0.0),
        }
    }

    fn post_alloc(&mut self, _tenant: TenantId, _size: u64, _dev: &mut GpuDevice) -> f64 {
        0.0
    }

    fn pre_free(&mut self, _tenant: TenantId, _dev: &mut GpuDevice) -> f64 {
        0.0
    }

    fn post_free(&mut self, tenant: TenantId, size: u64, _dev: &mut GpuDevice) -> f64 {
        if let Some(t) = self.tenants.get_mut(&tenant) {
            t.mem_used = t.mem_used.saturating_sub(size);
        }
        0.0
    }

    fn gate_launch(
        &mut self,
        tenant: TenantId,
        _kernel: &KernelDesc,
        dev: &mut GpuDevice,
    ) -> LaunchGate {
        let granted = self
            .tenants
            .get(&tenant)
            .map(|t| t.sms)
            .unwrap_or(dev.spec.sm_count);
        LaunchGate { overhead_ns: 0.0, throttle_wait_ns: 0.0, granted_sms: granted }
    }

    fn on_kernel_complete(&mut self, _t: TenantId, _f: f64, _b: f64, _n: f64) {}

    fn mem_info(&self, tenant: TenantId, dev: &GpuDevice) -> (u64, u64) {
        match self.tenants.get(&tenant) {
            Some(t) => (t.mem_quota - t.mem_used.min(t.mem_quota), t.mem_quota),
            None => (dev.memory.free_bytes(), dev.memory.capacity()),
        }
    }

    fn tick(&mut self, _dev: &mut GpuDevice) {}

    fn monitor_cpu_overhead(&self) -> f64 {
        0.0
    }

    fn hardware_isolated(&self) -> bool {
        true
    }

    fn sm_limit(&self, tenant: TenantId) -> f64 {
        self.tenants
            .get(&tenant)
            .map(|t| t.slices as f64 / COMPUTE_SLICES as f64)
            .unwrap_or(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slices_for_fractions() {
        assert_eq!(MigIdeal::slices_for(0.25), 1); // 4 x 25% must fit
        assert_eq!(MigIdeal::slices_for(0.3), 2);
        assert_eq!(MigIdeal::slices_for(0.14), 1);
        assert_eq!(MigIdeal::slices_for(1.0), 7);
        assert_eq!(MigIdeal::slices_for(0.0), 1);
    }

    #[test]
    fn geometry_oversubscription_rejected() {
        let mut dev = GpuDevice::a100(1);
        let mut m = MigIdeal::new();
        for t in 0..3 {
            m.register_tenant(t, TenantConfig::unlimited().with_sm_limit(0.3), &mut dev)
                .unwrap(); // 2 slices each = 6
        }
        // 7th slice can fit a 1-slice tenant but not a 2-slice one.
        assert!(m
            .register_tenant(10, TenantConfig::unlimited().with_sm_limit(0.3), &mut dev)
            .is_err());
        assert!(m
            .register_tenant(11, TenantConfig::unlimited().with_sm_limit(0.14), &mut dev)
            .is_ok());
    }

    #[test]
    fn dedicated_sms_immune_to_contention() {
        let mut dev = GpuDevice::a100(2);
        let mut m = MigIdeal::new();
        m.register_tenant(1, TenantConfig::unlimited().with_sm_limit(0.5), &mut dev).unwrap();
        let g = m.gate_launch(1, &KernelDesc::null(), &mut dev);
        // floor(0.5 * 7) = 3 slices of 108/7 SMs.
        assert_eq!(g.granted_sms, (108 * 3) / 7);
        // Background noise changes nothing.
        dev.set_background(
            9,
            crate::simgpu::device::BackgroundLoad { membw_demand: 1.0, resident_kernels: 8 },
        );
        let g2 = m.gate_launch(1, &KernelDesc::null(), &mut dev);
        assert_eq!(g2.granted_sms, g.granted_sms);
    }

    #[test]
    fn memory_quota_is_hardware_oom() {
        let mut dev = GpuDevice::a100(3);
        let mut m = MigIdeal::new();
        m.register_tenant(1, TenantConfig::unlimited().with_sm_limit(1.0 / 7.0), &mut dev)
            .unwrap();
        let quota = dev.spec.hbm_bytes / 7;
        assert_eq!(m.mem_info(1, &dev).1, quota);
        assert!(m.pre_alloc(1, quota / 2, &mut dev).is_ok());
        assert_eq!(m.pre_alloc(1, quota, &mut dev), Err(GpuError::OutOfMemory));
    }

    #[test]
    fn l2_ways_partitioned() {
        let mut dev = GpuDevice::a100(4);
        let mut m = MigIdeal::new();
        m.register_tenant(1, TenantConfig::unlimited().with_sm_limit(0.5), &mut dev).unwrap();
        m.register_tenant(2, TenantConfig::unlimited().with_sm_limit(0.28), &mut dev).unwrap();
        // Tenant 1 fills its ways; tenant 2's streaming can't evict it.
        dev.l2.access_range(1, 0, 1 << 20);
        dev.l2.access_range(2, 1 << 30, 8 << 20);
        assert_eq!(dev.l2.stats(1).evicted_by_others, 0);
    }

    #[test]
    fn zero_overhead_and_hardware_isolated() {
        let mut dev = GpuDevice::a100(5);
        let mut m = MigIdeal::new();
        m.register_tenant(1, TenantConfig::unlimited().with_sm_limit(0.5), &mut dev).unwrap();
        assert_eq!(m.hook_overhead_ns(&mut dev), 0.0);
        assert_eq!(m.context_create_overhead_ns(1, &mut dev), 0.0);
        assert!(m.hardware_isolated());
        assert_eq!(m.monitor_cpu_overhead(), 0.0);
    }

    #[test]
    fn unregister_frees_slices() {
        let mut dev = GpuDevice::a100(6);
        let mut m = MigIdeal::new();
        m.register_tenant(1, TenantConfig::unlimited().with_sm_limit(1.0), &mut dev).unwrap();
        assert!(m.register_tenant(2, TenantConfig::unlimited().with_sm_limit(0.14), &mut dev).is_err());
        m.unregister_tenant(1, &mut dev);
        assert!(m.register_tenant(2, TenantConfig::unlimited().with_sm_limit(0.14), &mut dev).is_ok());
    }
}
