//! Virtualization backends under test (paper Table 2).
//!
//! Every backend implements [`VirtLayer`] — the interposition surface the
//! `cudalite` driver API calls around each operation, exactly where
//! HAMi-core's `dlsym` hooks sit around the real CUDA driver:
//!
//! | backend  | key    | mechanisms |
//! |----------|--------|------------|
//! | [`native`] | `native` | passthrough; zero added cost |
//! | [`hami`]   | `hami`   | per-call dlsym hook resolution, shared-region accounting behind a semaphore, fixed-window utilization enforcement driven by a 100 ms NVML poller, fixed token bucket |
//! | [`fcsp`]   | `fcsp`   | cached hook resolution, lock-free accounting fast path, adaptive token bucket with burst credit, weighted fair queuing |
//! | [`mig`]    | `mig`    | ideal hardware partitioning: dedicated SM/memory/L2 slices, no interception cost |
//!
//! The shared mechanism implementations live in [`hooks`],
//! [`shared_region`], [`rate_limiter`], [`wfq`] and [`nvml`]; the backends
//! compose them with different parameters and policies, so the performance
//! differences measured by the metrics *emerge* from the mechanisms.

pub mod fcsp;
pub mod hami;
pub mod hooks;
pub mod mig;
pub mod native;
pub mod nvml;
pub mod rate_limiter;
pub mod shared_region;
pub mod timeslice;
pub mod wfq;

use crate::simgpu::error::GpuError;
use crate::simgpu::kernel::KernelDesc;
use crate::simgpu::{GpuDevice, TenantId};

/// Per-tenant resource configuration (the pod annotations HAMi consumes).
#[derive(Clone, Copy, Debug)]
pub struct TenantConfig {
    /// Device-memory quota in bytes (`None` = unlimited).
    pub mem_limit: Option<u64>,
    /// SM-utilization limit as a fraction of the device (`None` = 1.0).
    pub sm_limit: Option<f64>,
    /// Scheduling weight (WFQ backends only).
    pub weight: f64,
}

impl TenantConfig {
    pub fn unlimited() -> TenantConfig {
        TenantConfig { mem_limit: None, sm_limit: None, weight: 1.0 }
    }

    /// Equal 1/n share of a device (the paper's 4-tenant scenarios use
    /// `equal_share(4)`).
    pub fn equal_share(n: u32, dev_mem: u64) -> TenantConfig {
        TenantConfig {
            mem_limit: Some(dev_mem / n as u64),
            sm_limit: Some(1.0 / n as f64),
            weight: 1.0,
        }
    }

    pub fn with_mem_limit(mut self, bytes: u64) -> Self {
        self.mem_limit = Some(bytes);
        self
    }

    pub fn with_sm_limit(mut self, frac: f64) -> Self {
        self.sm_limit = Some(frac);
        self
    }

    pub fn with_weight(mut self, w: f64) -> Self {
        self.weight = w;
        self
    }
}

/// Decision returned by [`VirtLayer::gate_launch`].
#[derive(Clone, Copy, Debug, Default)]
pub struct LaunchGate {
    /// CPU-side latency the layer adds to the launch call (hook + checks).
    pub overhead_ns: f64,
    /// Throttle delay before the kernel may be submitted (rate limiting).
    pub throttle_wait_ns: f64,
    /// SMs granted to the kernel body.
    pub granted_sms: u32,
}

/// The interposition surface. One instance serves all tenants of a device
/// (mirroring the per-GPU shared region HAMi-core maps into containers).
pub trait VirtLayer {
    /// Backend key (Table 2).
    fn name(&self) -> &'static str;

    /// Register a tenant (container start). MIG reserves its hardware
    /// slice here and can fail on oversubscription.
    fn register_tenant(
        &mut self,
        tenant: TenantId,
        cfg: TenantConfig,
        dev: &mut GpuDevice,
    ) -> Result<(), GpuError>;

    /// Unregister (container stop); releases slices/accounting.
    fn unregister_tenant(&mut self, tenant: TenantId, dev: &mut GpuDevice);

    /// Per-intercepted-call hook cost (OH-005). Called for *every*
    /// driver-API entry the layer intercepts.
    fn hook_overhead_ns(&mut self, dev: &mut GpuDevice) -> f64;

    /// Extra context-creation work (OH-004 beyond native).
    fn context_create_overhead_ns(&mut self, tenant: TenantId, dev: &mut GpuDevice) -> f64;

    /// Memory-quota admission check (IS-001/002). `Err(QuotaExceeded)`
    /// blocks the allocation; `Ok(cost)` is the added latency.
    fn pre_alloc(
        &mut self,
        tenant: TenantId,
        size: u64,
        dev: &mut GpuDevice,
    ) -> Result<f64, GpuError>;

    /// Post-allocation accounting (OH-007). Returns added latency.
    fn post_alloc(&mut self, tenant: TenantId, size: u64, dev: &mut GpuDevice) -> f64;

    /// Pre/post free accounting. Return added latency.
    fn pre_free(&mut self, tenant: TenantId, dev: &mut GpuDevice) -> f64;
    fn post_free(&mut self, tenant: TenantId, size: u64, dev: &mut GpuDevice) -> f64;

    /// Kernel-launch gate: hook + quota check + rate limiting (OH-001,
    /// OH-008, IS-003). Must be called with the device clock at submission
    /// time.
    fn gate_launch(
        &mut self,
        tenant: TenantId,
        kernel: &KernelDesc,
        dev: &mut GpuDevice,
    ) -> LaunchGate;

    /// Completion feedback for closed-loop limiters: the kernel occupied
    /// `sm_frac` of the device for `busy_ns`, completing at virtual time
    /// `now_ns`.
    fn on_kernel_complete(&mut self, tenant: TenantId, sm_frac: f64, busy_ns: f64, now_ns: f64);

    /// Virtualized NVML memory report `(free, total)` — containers must
    /// see their quota, not the physical device (HAMi's NVML interception).
    fn mem_info(&self, tenant: TenantId, dev: &GpuDevice) -> (u64, u64);

    /// Advance background machinery (pollers) to the current virtual time.
    fn tick(&mut self, dev: &mut GpuDevice);

    /// Steady-state CPU overhead of monitoring, as a fraction (OH-009).
    fn monitor_cpu_overhead(&self) -> f64;

    /// Pick the next request to run from a cross-tenant pending queue
    /// (index into `pending`). Default: FIFO. FCSP overrides with WFQ;
    /// `mig` runs tenants in parallel so arbitration is moot but FIFO is a
    /// sound default.
    fn arbitrate(&mut self, pending: &[(TenantId, KernelDesc)]) -> usize {
        if pending.is_empty() { 0 } else { 0 }
    }

    /// Whether tenants are hardware-isolated (dedicated SMs/L2): used by
    /// metrics to decide contention topology.
    fn hardware_isolated(&self) -> bool {
        false
    }

    /// Configured SM limit for a tenant (1.0 when unlimited/unknown).
    fn sm_limit(&self, tenant: TenantId) -> f64;

    /// Whether the backend schedules cross-tenant submissions through a
    /// fair queue (FCSP's WFQ). Fair interleaving prevents a noisy
    /// tenant's bursts from stacking against a victim's accesses.
    fn fair_scheduler(&self) -> bool {
        false
    }

    /// Per-allocation tracking cost in ns (OH-007: the accounting data
    /// structure alone, excluding hooks/locks/NVML).
    fn tracking_cost_ns(&self) -> f64 {
        0.0
    }

    /// Cumulative shared-region lock contention: `(total_wait_ns,
    /// acquisitions)` (OH-006). Backends without a shared region return
    /// zeros.
    fn contention_stats(&self) -> (f64, u64) {
        (0.0, 0)
    }

    /// Dynamically reconfigure a tenant's SM limit (IS-004). Backends
    /// without dynamic limiting ignore it. Returns whether the change took
    /// effect online (MIG requires quiescing and returns `false`).
    fn update_sm_limit(&mut self, _tenant: TenantId, _limit: f64) -> bool {
        false
    }
}

/// Construct a backend by key (Table 2: `native`, `hami`, `fcsp`, `mig`).
pub fn by_name(name: &str) -> Option<Box<dyn VirtLayer>> {
    match name {
        "native" => Some(Box::new(native::Native::new())),
        "hami" => Some(Box::new(hami::HamiCore::new())),
        "fcsp" => Some(Box::new(fcsp::BudFcsp::new())),
        "mig" => Some(Box::new(mig::MigIdeal::new())),
        "timeslice" => Some(Box::new(timeslice::TimeSlice::new())),
        _ => None,
    }
}

/// All backend keys in the paper's comparison order (Table 2).
pub const ALL_SYSTEMS: [&str; 4] = ["native", "hami", "fcsp", "mig"];

/// Extended system list including the §1.2 time-slicing approach.
pub const ALL_SYSTEMS_EXTENDED: [&str; 5] = ["native", "hami", "fcsp", "mig", "timeslice"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_constructs_all() {
        for key in ALL_SYSTEMS {
            let l = by_name(key).unwrap_or_else(|| panic!("missing backend {key}"));
            assert_eq!(l.name(), key);
        }
        assert!(by_name("timeslice").is_some()); // §1.2 extension
        assert!(by_name("mps").is_none());
    }

    #[test]
    fn equal_share_splits() {
        let c = TenantConfig::equal_share(4, 40 << 30);
        assert_eq!(c.mem_limit, Some(10 << 30));
        assert!((c.sm_limit.unwrap() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn builder_methods() {
        let c = TenantConfig::unlimited().with_mem_limit(1024).with_sm_limit(0.5).with_weight(2.0);
        assert_eq!(c.mem_limit, Some(1024));
        assert_eq!(c.sm_limit, Some(0.5));
        assert_eq!(c.weight, 2.0);
    }
}
