//! `gvbench` — the GPU-Virt-Bench command-line tool.
//!
//! See `gvbench help` (or [`gvb::cli::args::USAGE`]) for usage.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(gvb::cli::main_with_args(&argv));
}
