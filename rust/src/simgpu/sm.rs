//! SM (streaming multiprocessor) pool with per-tenant grants and
//! utilization accounting over virtual time.
//!
//! Two grant modes mirror the systems under test:
//!
//! - **Static partition** (MIG): a tenant owns `n` SMs exclusively; other
//!   tenants' activity cannot touch them.
//! - **Shared** (native / software virtualization): kernels get the whole
//!   device; software limiters control the *duty cycle* (when kernels may
//!   launch), not which SMs they use — this is exactly why software SM
//!   limiting is approximate in the paper (IS-003: 85–93 %).
//!
//! Utilization is integrated busy-time per tenant over a measurement
//! window, which is what the (virtualized) NVML reports back.

use std::collections::HashMap;

use super::TenantId;

/// How a tenant's compute is granted.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SmGrant {
    /// Full device access (kernels use all SMs they can occupy).
    Shared,
    /// Exclusive static slice of `n` SMs (MIG).
    Dedicated(u32),
}

/// Busy-interval accounting for one tenant.
#[derive(Clone, Debug, Default)]
struct TenantUse {
    grant: Option<SmGrant>,
    /// Sum over intervals of `sm_fraction * duration_ns`.
    busy_sm_ns: f64,
    /// Wall (virtual) ns during which at least one kernel of this tenant ran.
    active_ns: f64,
    kernels_run: u64,
}

/// The SM pool.
#[derive(Clone, Debug)]
pub struct SmPool {
    total_sms: u32,
    dedicated_total: u32,
    tenants: HashMap<TenantId, TenantUse>,
    /// Start of the current utilization window.
    window_start_ns: u64,
}

impl SmPool {
    pub fn new(total_sms: u32) -> SmPool {
        SmPool {
            total_sms,
            dedicated_total: 0,
            tenants: HashMap::new(),
            window_start_ns: 0,
        }
    }

    pub fn total_sms(&self) -> u32 {
        self.total_sms
    }

    /// Register a tenant with a grant. Dedicated grants reserve SMs;
    /// over-subscription of dedicated SMs is an error.
    pub fn register(&mut self, tenant: TenantId, grant: SmGrant) -> Result<(), String> {
        if let SmGrant::Dedicated(n) = grant {
            if self.dedicated_total + n > self.total_sms {
                return Err(format!(
                    "dedicated SM oversubscription: {} + {} > {}",
                    self.dedicated_total, n, self.total_sms
                ));
            }
            self.dedicated_total += n;
        }
        self.tenants.entry(tenant).or_default().grant = Some(grant);
        Ok(())
    }

    pub fn unregister(&mut self, tenant: TenantId) {
        if let Some(u) = self.tenants.remove(&tenant) {
            if let Some(SmGrant::Dedicated(n)) = u.grant {
                self.dedicated_total -= n;
            }
        }
    }

    /// SMs effectively available to a tenant's kernel right now, given how
    /// many tenants are concurrently active on the shared pool.
    ///
    /// `concurrent_shared` is the number of tenants with shared grants that
    /// currently have kernels resident (the GPU's block scheduler
    /// space-shares SMs among resident kernels).
    pub fn effective_sms(&self, tenant: TenantId, concurrent_shared: u32) -> u32 {
        match self.tenants.get(&tenant).and_then(|u| u.grant) {
            Some(SmGrant::Dedicated(n)) => n,
            Some(SmGrant::Shared) | None => {
                let shared_pool = self.total_sms - self.dedicated_total;
                (shared_pool / concurrent_shared.max(1)).max(1)
            }
        }
    }

    /// Record that `tenant` ran kernels occupying `sm_fraction` **of the
    /// whole device** for `duration_ns` of virtual time (a MIG tenant fully
    /// using a half-device slice records 0.5).
    pub fn record_busy(&mut self, tenant: TenantId, sm_fraction: f64, duration_ns: f64) {
        let u = self.tenants.entry(tenant).or_default();
        u.busy_sm_ns += sm_fraction.clamp(0.0, 1.0) * duration_ns;
        u.active_ns += duration_ns;
        u.kernels_run += 1;
    }

    /// Utilization of `tenant` over `[window_start, now]` as a fraction of
    /// the *whole device* (what NVML's `utilization.gpu` approximates).
    pub fn utilization(&self, tenant: TenantId, now_ns: u64) -> f64 {
        let window = (now_ns.saturating_sub(self.window_start_ns)) as f64;
        if window <= 0.0 {
            return 0.0;
        }
        let u = match self.tenants.get(&tenant) {
            Some(u) => u,
            None => return 0.0,
        };
        (u.busy_sm_ns / window).min(1.0)
    }

    /// Device-wide utilization over the window.
    pub fn device_utilization(&self, now_ns: u64) -> f64 {
        let window = (now_ns.saturating_sub(self.window_start_ns)) as f64;
        if window <= 0.0 {
            return 0.0;
        }
        let busy: f64 = self.tenants.values().map(|u| u.busy_sm_ns).sum();
        (busy / window).min(1.0)
    }

    /// Begin a fresh utilization window at `now_ns`.
    pub fn reset_window(&mut self, now_ns: u64) {
        self.window_start_ns = now_ns;
        for u in self.tenants.values_mut() {
            u.busy_sm_ns = 0.0;
            u.active_ns = 0.0;
        }
    }

    pub fn kernels_run(&self, tenant: TenantId) -> u64 {
        self.tenants.get(&tenant).map(|u| u.kernels_run).unwrap_or(0)
    }

    pub fn dedicated_total(&self) -> u32 {
        self.dedicated_total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedicated_reservation_enforced() {
        let mut p = SmPool::new(108);
        p.register(1, SmGrant::Dedicated(54)).unwrap();
        p.register(2, SmGrant::Dedicated(54)).unwrap();
        assert!(p.register(3, SmGrant::Dedicated(1)).is_err());
        p.unregister(2);
        assert!(p.register(3, SmGrant::Dedicated(10)).is_ok());
    }

    #[test]
    fn effective_sms_dedicated() {
        let mut p = SmPool::new(108);
        p.register(1, SmGrant::Dedicated(27)).unwrap();
        assert_eq!(p.effective_sms(1, 99), 27); // immune to contention
    }

    #[test]
    fn effective_sms_shared_splits_pool() {
        let mut p = SmPool::new(108);
        p.register(1, SmGrant::Shared).unwrap();
        p.register(2, SmGrant::Shared).unwrap();
        assert_eq!(p.effective_sms(1, 1), 108);
        assert_eq!(p.effective_sms(1, 2), 54);
        assert_eq!(p.effective_sms(1, 4), 27);
    }

    #[test]
    fn shared_pool_excludes_dedicated() {
        let mut p = SmPool::new(108);
        p.register(1, SmGrant::Dedicated(54)).unwrap();
        p.register(2, SmGrant::Shared).unwrap();
        assert_eq!(p.effective_sms(2, 1), 54);
    }

    #[test]
    fn utilization_integrates_busy_time() {
        let mut p = SmPool::new(100);
        p.register(1, SmGrant::Shared).unwrap();
        p.reset_window(0);
        // Busy 50% of SMs for 1000ns within a 2000ns window → 25% util.
        p.record_busy(1, 0.5, 1000.0);
        let u = p.utilization(1, 2000);
        assert!((u - 0.25).abs() < 1e-9, "u={u}");
    }

    #[test]
    fn utilization_clamped_to_one() {
        let mut p = SmPool::new(100);
        p.register(1, SmGrant::Shared).unwrap();
        p.reset_window(0);
        p.record_busy(1, 1.0, 5000.0);
        assert_eq!(p.utilization(1, 1000), 1.0);
    }

    #[test]
    fn dedicated_utilization_scaled_by_slice() {
        let mut p = SmPool::new(100);
        p.register(1, SmGrant::Dedicated(25)).unwrap();
        p.reset_window(0);
        // Fully busy on a quarter slice for the whole window: the caller
        // records 0.25 device-fraction → 25% of device.
        p.record_busy(1, 0.25, 1000.0);
        let u = p.utilization(1, 1000);
        assert!((u - 0.25).abs() < 1e-9, "u={u}");
    }

    #[test]
    fn window_reset_clears_accounting() {
        let mut p = SmPool::new(100);
        p.register(1, SmGrant::Shared).unwrap();
        p.record_busy(1, 1.0, 1000.0);
        p.reset_window(1000);
        assert_eq!(p.utilization(1, 2000), 0.0);
    }
}
