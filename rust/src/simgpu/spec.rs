//! Device specifications and latency calibration.
//!
//! The default profile models the paper's testbed (NVIDIA A100-40GB PCIe,
//! §7.1). Base API costs are calibrated to the paper's *native* column in
//! Table 4; virtualization layers add their own mechanism costs on top, so
//! the HAMi/FCSP columns *emerge* rather than being transcribed.

/// Static description of a simulated GPU.
#[derive(Clone, Debug)]
pub struct GpuSpec {
    pub name: String,
    /// Number of streaming multiprocessors.
    pub sm_count: u32,
    /// SM clock in GHz.
    pub clock_ghz: f64,
    /// Peak dense FP32 throughput (TFLOP/s) across the whole device.
    pub fp32_tflops: f64,
    /// Peak dense FP16/BF16 (tensor-core) throughput (TFLOP/s).
    pub fp16_tflops: f64,
    /// Device memory (HBM) capacity in bytes.
    pub hbm_bytes: u64,
    /// Device memory bandwidth in GB/s.
    pub hbm_bw_gbps: f64,
    /// L2 cache capacity in bytes.
    pub l2_bytes: u64,
    /// L2 line size in bytes.
    pub l2_line: u32,
    /// L2 associativity (ways).
    pub l2_ways: u32,
    /// L2 bandwidth multiplier over HBM (how much faster a hit is).
    pub l2_speedup: f64,
    /// PCIe unidirectional bandwidth in GB/s (Gen4 x16 ≈ 25 effective).
    pub pcie_gbps: f64,
    /// Pinned-to-pageable host memory transfer efficiency ratio (>1).
    pub pinned_speedup: f64,
    /// NVLink per-direction bandwidth in GB/s (0 = no NVLink).
    pub nvlink_gbps: f64,

    // --- calibrated native API base costs (virtual ns) -------------------
    /// `cuLaunchKernel` CPU-side cost (Table 4 native: 4.2 µs).
    pub launch_ns: u64,
    /// `cuMemAlloc` base cost excluding free-list search (Table 4: 12.5 µs).
    pub alloc_base_ns: u64,
    /// Extra cost per free-list node visited during allocation search.
    pub alloc_per_node_ns: u64,
    /// `cuMemFree` base cost (Table 4: 8.1 µs).
    pub free_base_ns: u64,
    /// Context creation (Table 4: 125 µs).
    pub ctx_create_ns: u64,
    /// Context destruction.
    pub ctx_destroy_ns: u64,
    /// CUDA context switch latency (SCHED-001 baseline, ~10 µs on A100).
    pub ctx_switch_ns: u64,
    /// Host-side per-event record cost.
    pub event_record_ns: u64,
    /// Fixed DMA setup cost per memcpy.
    pub dma_setup_ns: u64,
    /// Device reset / error recovery time (ERR-002 baseline, ~2 ms).
    pub reset_ns: u64,
    /// Multiplicative log-normal jitter sigma applied to API latencies.
    pub jitter_sigma: f64,
}

impl GpuSpec {
    /// The paper's testbed: A100-40GB PCIe (§7.1).
    pub fn a100_40gb() -> GpuSpec {
        GpuSpec {
            name: "A100-40GB-PCIe".to_string(),
            sm_count: 108,
            clock_ghz: 1.41,
            fp32_tflops: 19.5,
            fp16_tflops: 312.0,
            hbm_bytes: 40 * (1 << 30),
            hbm_bw_gbps: 1555.0,
            l2_bytes: 40 * (1 << 20),
            l2_line: 128,
            l2_ways: 16,
            l2_speedup: 3.2,
            pcie_gbps: 25.0,
            pinned_speedup: 2.4,
            nvlink_gbps: 0.0, // PCIe SKU
            launch_ns: 4_200,
            alloc_base_ns: 12_500,
            alloc_per_node_ns: 35,
            free_base_ns: 8_100,
            ctx_create_ns: 125_000,
            ctx_destroy_ns: 60_000,
            ctx_switch_ns: 10_500,
            event_record_ns: 900,
            dma_setup_ns: 6_000,
            reset_ns: 2_100_000,
            jitter_sigma: 0.04,
        }
    }

    /// An SXM A100 with NVLink, for multi-GPU (NCCL) scenarios.
    pub fn a100_80gb_sxm() -> GpuSpec {
        let mut s = GpuSpec::a100_40gb();
        s.name = "A100-80GB-SXM".to_string();
        s.hbm_bytes = 80 * (1 << 30);
        s.hbm_bw_gbps = 2039.0;
        s.nvlink_gbps = 300.0; // NVLink3 aggregate per direction
        s
    }

    /// An H100 PCIe profile (for cross-architecture sanity experiments).
    pub fn h100_80gb() -> GpuSpec {
        GpuSpec {
            name: "H100-80GB-PCIe".to_string(),
            sm_count: 114,
            clock_ghz: 1.755,
            fp32_tflops: 51.0,
            fp16_tflops: 756.0,
            hbm_bytes: 80 * (1 << 30),
            hbm_bw_gbps: 2000.0,
            l2_bytes: 50 * (1 << 20),
            l2_line: 128,
            l2_ways: 16,
            l2_speedup: 3.5,
            pcie_gbps: 50.0,
            pinned_speedup: 2.2,
            nvlink_gbps: 0.0,
            launch_ns: 3_900,
            alloc_base_ns: 11_800,
            alloc_per_node_ns: 32,
            free_base_ns: 7_600,
            ctx_create_ns: 118_000,
            ctx_destroy_ns: 55_000,
            ctx_switch_ns: 9_800,
            event_record_ns: 850,
            dma_setup_ns: 5_500,
            reset_ns: 1_900_000,
            jitter_sigma: 0.04,
        }
    }

    /// A MIG slice of this device: `frac_num/frac_den` of SMs, memory and
    /// L2, with dedicated (partitioned) resources. E.g. 1g.5gb on A100-40GB
    /// is (1, 7) compute and (1, 8) memory; we use a uniform fraction for
    /// simplicity and note it in DESIGN.md.
    pub fn mig_slice(&self, frac_num: u32, frac_den: u32) -> GpuSpec {
        assert!(frac_num >= 1 && frac_num <= frac_den);
        let f = frac_num as f64 / frac_den as f64;
        let mut s = self.clone();
        s.name = format!("{}-mig-{}of{}", self.name, frac_num, frac_den);
        s.sm_count = ((self.sm_count as f64 * f).round() as u32).max(1);
        s.fp32_tflops *= f;
        s.fp16_tflops *= f;
        s.hbm_bytes = (self.hbm_bytes as f64 * f) as u64;
        s.hbm_bw_gbps *= f;
        s.l2_bytes = (self.l2_bytes as f64 * f) as u64;
        s.l2_ways = ((self.l2_ways as f64 * f).round() as u32).max(1);
        s
    }

    /// Peak FLOP/s for a given precision.
    pub fn peak_flops(&self, half_precision: bool) -> f64 {
        (if half_precision { self.fp16_tflops } else { self.fp32_tflops }) * 1e12
    }

    /// Per-SM FP32 throughput in FLOP/s (used when a tenant is granted a
    /// subset of SMs).
    pub fn flops_per_sm(&self, half_precision: bool) -> f64 {
        self.peak_flops(half_precision) / self.sm_count as f64
    }
}

impl Default for GpuSpec {
    fn default() -> Self {
        GpuSpec::a100_40gb()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_matches_paper_native_calibration() {
        let s = GpuSpec::a100_40gb();
        assert_eq!(s.launch_ns, 4_200); // Table 4 native launch = 4.2 µs
        assert_eq!(s.alloc_base_ns, 12_500); // 12.5 µs
        assert_eq!(s.free_base_ns, 8_100); // 8.1 µs
        assert_eq!(s.ctx_create_ns, 125_000); // 125 µs
        assert_eq!(s.sm_count, 108);
        assert_eq!(s.hbm_bytes, 40 * (1 << 30));
    }

    #[test]
    fn mig_slice_scales_resources() {
        let a100 = GpuSpec::a100_40gb();
        let half = a100.mig_slice(1, 2);
        assert_eq!(half.sm_count, 54);
        assert_eq!(half.hbm_bytes, 20 * (1 << 30));
        assert!((half.fp32_tflops - 9.75).abs() < 1e-9);
        // Base API latencies are a host-side property and do not scale.
        assert_eq!(half.launch_ns, a100.launch_ns);
    }

    #[test]
    fn mig_slice_minimums() {
        let a100 = GpuSpec::a100_40gb();
        let tiny = a100.mig_slice(1, 200);
        assert!(tiny.sm_count >= 1);
        assert!(tiny.l2_ways >= 1);
    }

    #[test]
    fn peak_flops_precision() {
        let s = GpuSpec::a100_40gb();
        assert!(s.peak_flops(true) > s.peak_flops(false));
        assert!((s.flops_per_sm(false) * 108.0 - 19.5e12).abs() < 1e6);
    }
}
