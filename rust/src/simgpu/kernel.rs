//! Kernel descriptors and the roofline duration model.
//!
//! A kernel is characterised by its arithmetic work (FLOPs), memory traffic
//! (bytes) and precision. Execution time on a granted set of SMs is the
//! max of the compute-bound and memory-bound times (classic roofline),
//! degraded by achieved L2 hit-rate and bandwidth contention. The LLM
//! metric category builds transformer-shaped kernels with these costs; the
//! microbenchmarks use tiny null kernels (launch-overhead dominated).

use super::spec::GpuSpec;

/// Workload shape of one kernel launch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KernelDesc {
    /// Floating-point operations performed.
    pub flops: f64,
    /// Bytes read from + written to device memory (before cache filtering).
    pub bytes: f64,
    /// Half precision (FP16/BF16 — tensor-core eligible).
    pub half_precision: bool,
    /// Fraction of the granted SMs the launch geometry can occupy (0..=1].
    pub occupancy: f64,
}

impl KernelDesc {
    /// The paper's `null_kernel<<<1,1>>>` used for launch-overhead
    /// measurement (Listing 3).
    pub fn null() -> KernelDesc {
        KernelDesc { flops: 0.0, bytes: 0.0, half_precision: false, occupancy: 1.0 / 2048.0 }
    }

    /// A dense GEMM `m×k · k×n` in the given precision.
    pub fn gemm(m: u64, n: u64, k: u64, half_precision: bool) -> KernelDesc {
        let elt = if half_precision { 2.0 } else { 4.0 };
        KernelDesc {
            flops: 2.0 * m as f64 * n as f64 * k as f64,
            bytes: elt * ((m * k) as f64 + (k * n) as f64 + (m * n) as f64),
            half_precision,
            occupancy: 1.0,
        }
    }

    /// Single-head attention for (batch, seq, dim) — the paper's LLM-001
    /// FLOP proxy `2·B·S²·D` (eq. 12) plus the `P·V` contraction.
    pub fn attention(batch: u64, seq: u64, dim: u64, half_precision: bool) -> KernelDesc {
        let (b, s, d) = (batch as f64, seq as f64, dim as f64);
        let elt = if half_precision { 2.0 } else { 4.0 };
        KernelDesc {
            // QK^T and PV: 2 * (2*B*S^2*D)
            flops: 4.0 * b * s * s * d,
            // Q,K,V read + scores + output written.
            bytes: elt * (3.0 * b * s * d + b * s * s + b * s * d),
            half_precision,
            occupancy: 1.0,
        }
    }

    /// A streaming (bandwidth-bound) kernel touching `bytes` of memory.
    pub fn streaming(bytes: f64) -> KernelDesc {
        KernelDesc { flops: bytes / 4.0, bytes, half_precision: false, occupancy: 1.0 }
    }

    /// Arithmetic intensity in FLOP/byte.
    pub fn intensity(&self) -> f64 {
        if self.bytes <= 0.0 { f64::INFINITY } else { self.flops / self.bytes }
    }
}

/// Dynamic execution conditions for one launch.
#[derive(Clone, Copy, Debug)]
pub struct ExecContext {
    /// SMs granted to this launch.
    pub sms: u32,
    /// Fraction of `bytes` served from L2 (hit rate measured by the cache
    /// model for this tenant's recent access pattern).
    pub l2_hit_rate: f64,
    /// Share of HBM bandwidth available (1.0 = uncontended; `1/n` under
    /// n-way bandwidth contention).
    pub bw_share: f64,
}

impl ExecContext {
    pub fn uncontended(sms: u32) -> ExecContext {
        ExecContext { sms, l2_hit_rate: 0.0, bw_share: 1.0 }
    }
}

/// Roofline duration of `kernel` on `spec` under `ctx`, in nanoseconds.
/// Pure function — the device wraps it with jitter and accounting.
pub fn duration_ns(spec: &GpuSpec, kernel: &KernelDesc, ctx: &ExecContext) -> f64 {
    let sms = ctx.sms.clamp(1, spec.sm_count) as f64;
    // Compute-bound time.
    let flops_rate = spec.flops_per_sm(kernel.half_precision) * sms * kernel.occupancy.clamp(1e-6, 1.0);
    let t_compute = if kernel.flops > 0.0 { kernel.flops / flops_rate * 1e9 } else { 0.0 };
    // Memory-bound time: hits are served at l2_speedup, misses at the
    // contended HBM bandwidth share.
    let hit = ctx.l2_hit_rate.clamp(0.0, 1.0);
    let hbm_bw = spec.hbm_bw_gbps * 1e9 * ctx.bw_share.clamp(1e-3, 1.0);
    let l2_bw = spec.hbm_bw_gbps * 1e9 * spec.l2_speedup;
    let t_mem = if kernel.bytes > 0.0 {
        (kernel.bytes * (1.0 - hit) / hbm_bw + kernel.bytes * hit / l2_bw) * 1e9
    } else {
        0.0
    };
    // A launch always takes at least a couple of SM clock cycles.
    let floor = 2.0 / (spec.clock_ghz * 1e9) * 1e9;
    t_compute.max(t_mem).max(floor)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> GpuSpec {
        GpuSpec::a100_40gb()
    }

    #[test]
    fn null_kernel_is_fast() {
        let d = duration_ns(&spec(), &KernelDesc::null(), &ExecContext::uncontended(108));
        assert!(d < 100.0, "d={d}");
    }

    #[test]
    fn gemm_compute_bound_time() {
        // 4096^3 GEMM fp32: 2*4096^3 = 137.4 GFLOP at 19.5 TFLOP/s ≈ 7.05 ms.
        let k = KernelDesc::gemm(4096, 4096, 4096, false);
        let d = duration_ns(&spec(), &k, &ExecContext::uncontended(108));
        let expect = 2.0 * 4096f64.powi(3) / 19.5e12 * 1e9;
        assert!((d - expect).abs() / expect < 0.01, "d={d} expect={expect}");
    }

    #[test]
    fn streaming_bandwidth_bound_time() {
        // 1 GiB stream at 1555 GB/s ≈ 0.69 ms.
        let k = KernelDesc::streaming(1_073_741_824.0);
        let d = duration_ns(&spec(), &k, &ExecContext::uncontended(108));
        let expect = 1_073_741_824.0 / 1555e9 * 1e9;
        assert!((d - expect).abs() / expect < 0.01, "d={d} expect={expect}");
    }

    #[test]
    fn fewer_sms_slower_compute() {
        let k = KernelDesc::gemm(2048, 2048, 2048, false);
        let full = duration_ns(&spec(), &k, &ExecContext::uncontended(108));
        let half = duration_ns(&spec(), &k, &ExecContext::uncontended(54));
        assert!((half / full - 2.0).abs() < 0.05, "ratio={}", half / full);
    }

    #[test]
    fn half_precision_faster() {
        let f32k = KernelDesc::gemm(2048, 2048, 2048, false);
        let f16k = KernelDesc::gemm(2048, 2048, 2048, true);
        let ctx = ExecContext::uncontended(108);
        let s = spec();
        let t32 = duration_ns(&s, &f32k, &ctx);
        let t16 = duration_ns(&s, &f16k, &ctx);
        // A100: 312/19.5 = 16x peak ratio; memory bound caps realized gain.
        assert!(t16 < t32, "t16={t16} t32={t32}");
    }

    #[test]
    fn bandwidth_contention_slows_memory_bound() {
        let k = KernelDesc::streaming((1u64 << 28) as f64);
        let s = spec();
        let solo = duration_ns(&s, &k, &ExecContext { sms: 108, l2_hit_rate: 0.0, bw_share: 1.0 });
        let quarter = duration_ns(&s, &k, &ExecContext { sms: 108, l2_hit_rate: 0.0, bw_share: 0.25 });
        assert!((quarter / solo - 4.0).abs() < 0.05);
    }

    #[test]
    fn cache_hits_speed_up_memory_bound() {
        let k = KernelDesc::streaming((1u64 << 28) as f64);
        let s = spec();
        let cold = duration_ns(&s, &k, &ExecContext { sms: 108, l2_hit_rate: 0.0, bw_share: 1.0 });
        let warm = duration_ns(&s, &k, &ExecContext { sms: 108, l2_hit_rate: 0.9, bw_share: 1.0 });
        assert!(warm < cold * 0.5, "warm={warm} cold={cold}");
    }

    #[test]
    fn attention_flops_match_paper_proxy() {
        let k = KernelDesc::attention(8, 1024, 64, false);
        // eq. 12 proxy counts 2*B*S^2*D for QK^T; we add PV → 2x.
        let proxy = 2.0 * 8.0 * 1024.0 * 1024.0 * 64.0;
        assert!((k.flops - 2.0 * proxy).abs() < 1.0);
    }

    #[test]
    fn intensity() {
        let k = KernelDesc::gemm(4096, 4096, 4096, false);
        assert!(k.intensity() > 100.0); // large GEMMs are compute bound
        let st = KernelDesc::streaming(1e6);
        assert!(st.intensity() < 1.0);
    }
}
