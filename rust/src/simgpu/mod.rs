//! Discrete-event simulated GPU substrate.
//!
//! The paper benchmarks real NVIDIA hardware; this environment has none, so
//! `simgpu` models the device the benchmarks observe. The model is
//! *mechanistic* where the paper's phenomena demand it:
//!
//! - [`memory`] is a real first-fit free-list allocator over the simulated
//!   HBM range — fragmentation (FRAG-001..003) and allocation-latency
//!   degradation emerge from the data structure, they are not scripted.
//! - [`cache`] is a real set-associative LRU L2 — hit-rates, evictions and
//!   working-set collisions (CACHE-001..004) come from simulated accesses.
//! - [`sm`] tracks SM grants per tenant; software limiters (token buckets,
//!   WFQ) gate *when* kernels run, so utilization accuracy (IS-003) is the
//!   closed-loop behaviour of the limiter, not a constant.
//! - [`pcie`] / [`nvlink`] are bandwidth-sharing link models with
//!   contention; [`kernel`] converts FLOPs/bytes to durations through a
//!   roofline model; [`error`] is a fault-injection + recovery state
//!   machine.
//!
//! Time is virtual (nanoseconds, [`clock::VirtualClock`]) so runs are
//! deterministic under a fixed seed.

pub mod cache;
pub mod clock;
pub mod device;
pub mod error;
pub mod kernel;
pub mod memory;
pub mod nvlink;
pub mod pcie;
pub mod sm;
pub mod spec;
pub mod stream;

pub use clock::VirtualClock;
pub use device::GpuDevice;
pub use error::{GpuError, GpuFault};
pub use kernel::KernelDesc;
pub use spec::GpuSpec;

/// Identifier for a tenant (container / process) sharing the device.
pub type TenantId = u32;

/// Identifier for a simulated CUDA stream.
pub type StreamId = u32;
