//! PCIe link model: host↔device transfers with pinned/pageable asymmetry
//! and bandwidth sharing under multi-tenant contention (PCIE-001..004).

use std::collections::HashMap;

use super::TenantId;

/// Direction of a host↔device transfer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Direction {
    HostToDevice,
    DeviceToHost,
}

/// Per-tenant transfer accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct PcieStats {
    pub bytes_h2d: u64,
    pub bytes_d2h: u64,
    pub transfers: u64,
}

/// The PCIe link. Each direction has independent bandwidth (full duplex);
/// concurrent flows in the same direction share it equally (the switch
/// arbitrates round-robin at TLP granularity, which averages to a fair
/// share).
#[derive(Clone, Debug)]
pub struct PcieLink {
    /// Peak effective unidirectional bandwidth, GB/s.
    bw_gbps: f64,
    /// Pageable transfers are staged through a bounce buffer: effective
    /// bandwidth is divided by this factor.
    pinned_speedup: f64,
    /// Fixed DMA setup cost per transfer, ns.
    setup_ns: u64,
    /// Registered concurrent background flows per direction (tenant → GB/s
    /// demand). Used to compute the contended share deterministically.
    background: HashMap<(TenantId, Direction), f64>,
    stats: HashMap<TenantId, PcieStats>,
}

impl PcieLink {
    pub fn new(bw_gbps: f64, pinned_speedup: f64, setup_ns: u64) -> PcieLink {
        PcieLink {
            bw_gbps,
            pinned_speedup,
            setup_ns,
            background: HashMap::new(),
            stats: HashMap::new(),
        }
    }

    pub fn bw_gbps(&self) -> f64 {
        self.bw_gbps
    }

    /// Declare a sustained background flow (noisy neighbour / contention
    /// scenarios). `demand_gbps` is the unthrottled demand.
    pub fn set_background(&mut self, tenant: TenantId, dir: Direction, demand_gbps: f64) {
        if demand_gbps <= 0.0 {
            self.background.remove(&(tenant, dir));
        } else {
            self.background.insert((tenant, dir), demand_gbps);
        }
    }

    pub fn clear_background(&mut self) {
        self.background.clear();
    }

    /// Bandwidth share available to `tenant` in `dir`, as a fraction of
    /// peak, given current background flows (max-min fair allocation).
    pub fn share(&self, tenant: TenantId, dir: Direction) -> f64 {
        let others: Vec<f64> = self
            .background
            .iter()
            .filter(|((t, d), _)| *t != tenant && *d == dir)
            .map(|(_, demand)| *demand)
            .collect();
        if others.is_empty() {
            return 1.0;
        }
        // Max-min fair: every flow (others + this one) gets an equal share,
        // but a background flow never takes more than its demand.
        let n = others.len() + 1;
        let fair = self.bw_gbps / n as f64;
        let mut leftover = self.bw_gbps;
        let mut unconstrained = 1usize; // this tenant
        for d in &others {
            if *d <= fair {
                leftover -= d;
            } else {
                unconstrained += 1;
            }
        }
        (leftover / unconstrained as f64 / self.bw_gbps).clamp(0.0, 1.0)
    }

    /// Duration of a transfer in ns, and effective bandwidth in GB/s.
    pub fn transfer_ns(
        &mut self,
        tenant: TenantId,
        dir: Direction,
        bytes: u64,
        pinned: bool,
    ) -> (f64, f64) {
        let share = self.share(tenant, dir);
        let mut bw = self.bw_gbps * share;
        if !pinned {
            bw /= self.pinned_speedup;
        }
        let dur = self.setup_ns as f64 + bytes as f64 / (bw * 1e9) * 1e9;
        let s = self.stats.entry(tenant).or_default();
        match dir {
            Direction::HostToDevice => s.bytes_h2d += bytes,
            Direction::DeviceToHost => s.bytes_d2h += bytes,
        }
        s.transfers += 1;
        let eff_bw = bytes as f64 / dur; // bytes/ns == GB/s
        (dur, eff_bw)
    }

    pub fn stats(&self, tenant: TenantId) -> PcieStats {
        self.stats.get(&tenant).copied().unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn link() -> PcieLink {
        PcieLink::new(25.0, 2.4, 6_000)
    }

    #[test]
    fn pinned_transfer_near_peak() {
        let mut l = link();
        let (_, bw) = l.transfer_ns(1, Direction::HostToDevice, 1 << 30, true);
        assert!(bw > 24.0 && bw <= 25.0, "bw={bw}");
    }

    #[test]
    fn pageable_slower_by_factor() {
        let mut l = link();
        let (_, pinned) = l.transfer_ns(1, Direction::HostToDevice, 1 << 30, true);
        let (_, pageable) = l.transfer_ns(1, Direction::HostToDevice, 1 << 30, false);
        let ratio = pinned / pageable;
        assert!((ratio - 2.4).abs() < 0.05, "ratio={ratio}");
    }

    #[test]
    fn setup_cost_dominates_small_transfers() {
        let mut l = link();
        let (dur, bw) = l.transfer_ns(1, Direction::HostToDevice, 4096, true);
        assert!(dur > 6_000.0);
        assert!(bw < 1.0, "bw={bw}");
    }

    #[test]
    fn directions_are_independent() {
        let mut l = link();
        l.set_background(2, Direction::DeviceToHost, 25.0);
        assert_eq!(l.share(1, Direction::HostToDevice), 1.0);
        assert!(l.share(1, Direction::DeviceToHost) < 0.6);
    }

    #[test]
    fn contention_halves_share() {
        let mut l = link();
        l.set_background(2, Direction::HostToDevice, 25.0);
        let s = l.share(1, Direction::HostToDevice);
        assert!((s - 0.5).abs() < 1e-9, "s={s}");
    }

    #[test]
    fn small_background_flow_leaves_most_bandwidth() {
        let mut l = link();
        l.set_background(2, Direction::HostToDevice, 2.5); // 10% demand
        let s = l.share(1, Direction::HostToDevice);
        assert!((s - 0.9).abs() < 1e-9, "s={s}");
    }

    #[test]
    fn four_way_contention() {
        let mut l = link();
        for t in 2..5 {
            l.set_background(t, Direction::HostToDevice, 25.0);
        }
        let s = l.share(1, Direction::HostToDevice);
        assert!((s - 0.25).abs() < 1e-9, "s={s}");
    }

    #[test]
    fn stats_accumulate() {
        let mut l = link();
        l.transfer_ns(1, Direction::HostToDevice, 100, true);
        l.transfer_ns(1, Direction::DeviceToHost, 200, true);
        let s = l.stats(1);
        assert_eq!(s.bytes_h2d, 100);
        assert_eq!(s.bytes_d2h, 200);
        assert_eq!(s.transfers, 2);
    }
}
