//! The assembled simulated GPU: allocator + cache + SM pool + PCIe +
//! streams + error state over one virtual clock.
//!
//! `GpuDevice` exposes the raw *hardware* operations; [`crate::cudalite`]
//! wraps them in driver-API semantics and [`crate::virt`] interposes
//! virtualization policy. All durations are virtual nanoseconds; the device
//! itself never blocks the host thread.

use crate::util::Rng;

use super::cache::L2Cache;
use super::clock::VirtualClock;
use super::error::{ErrorState, GpuFault};
use super::kernel::{duration_ns, ExecContext, KernelDesc};
use super::memory::{AllocError, AllocOutcome, DevicePtr, HbmAllocator};
use super::pcie::{Direction, PcieLink};
use super::sm::{SmGrant, SmPool};
use super::spec::GpuSpec;
use super::stream::{StreamPriority, StreamTable};
use super::{StreamId, TenantId};

/// Sustained background demand a tenant puts on shared device resources —
/// used to model contention deterministically in multi-tenant scenarios.
#[derive(Clone, Copy, Debug, Default)]
pub struct BackgroundLoad {
    /// Fraction of HBM bandwidth demanded (0..=1).
    pub membw_demand: f64,
    /// Number of concurrently resident kernels (space-sharing pressure).
    pub resident_kernels: u32,
}

/// The simulated device.
pub struct GpuDevice {
    pub spec: GpuSpec,
    pub clock: VirtualClock,
    pub memory: HbmAllocator,
    pub l2: L2Cache,
    pub sms: SmPool,
    pub pcie: PcieLink,
    pub streams: StreamTable,
    pub errors: ErrorState,
    rng: Rng,
    background: std::collections::HashMap<TenantId, BackgroundLoad>,
}

impl GpuDevice {
    pub fn new(spec: GpuSpec, seed: u64) -> GpuDevice {
        let clock = VirtualClock::new();
        GpuDevice {
            memory: HbmAllocator::new(spec.hbm_bytes),
            l2: L2Cache::new(spec.l2_bytes, spec.l2_line, spec.l2_ways),
            sms: SmPool::new(spec.sm_count),
            pcie: PcieLink::new(spec.pcie_gbps, spec.pinned_speedup, spec.dma_setup_ns),
            streams: StreamTable::new(),
            errors: ErrorState::new(),
            rng: Rng::new(seed),
            background: std::collections::HashMap::new(),
            clock,
            spec,
        }
    }

    /// A100-40GB device with the given seed (the common case in tests).
    pub fn a100(seed: u64) -> GpuDevice {
        GpuDevice::new(GpuSpec::a100_40gb(), seed)
    }

    /// Multiplicative latency jitter sample.
    #[inline]
    pub fn jitter(&mut self) -> f64 {
        let s = self.spec.jitter_sigma;
        if s <= 0.0 { 1.0 } else { self.rng.jitter(s) }
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    // ---- background load registry --------------------------------------

    /// Declare a tenant's sustained background load (contention scenarios).
    pub fn set_background(&mut self, tenant: TenantId, load: BackgroundLoad) {
        if load.membw_demand <= 0.0 && load.resident_kernels == 0 {
            self.background.remove(&tenant);
        } else {
            self.background.insert(tenant, load);
        }
    }

    pub fn clear_background(&mut self) {
        self.background.clear();
    }

    /// HBM bandwidth share available to `tenant` given background demands
    /// (max-min fair, mirroring the PCIe model).
    pub fn membw_share(&self, tenant: TenantId) -> f64 {
        let others: Vec<f64> = self
            .background
            .iter()
            .filter(|(t, _)| **t != tenant)
            .map(|(_, l)| l.membw_demand)
            .filter(|d| *d > 0.0)
            .collect();
        if others.is_empty() {
            return 1.0;
        }
        let n = others.len() + 1;
        let fair = 1.0 / n as f64;
        let mut leftover = 1.0;
        let mut unconstrained = 1usize;
        for d in &others {
            if *d <= fair {
                leftover -= d;
            } else {
                unconstrained += 1;
            }
        }
        (leftover / unconstrained as f64).clamp(0.0, 1.0)
    }

    /// Number of kernels space-sharing the shared SM pool with `tenant`'s
    /// launch (its own launch counts as one).
    pub fn concurrent_shared(&self, tenant: TenantId) -> u32 {
        1 + self
            .background
            .iter()
            .filter(|(t, _)| **t != tenant)
            .map(|(_, l)| l.resident_kernels)
            .sum::<u32>()
    }

    // ---- hardware operations (no virtualization policy here) -----------

    /// Raw allocation: free-list search + latency model. Returns the
    /// outcome and the virtual-ns cost (caller advances the clock — the
    /// virt layer may add its own overhead first).
    pub fn raw_alloc(&mut self, size: u64) -> (Result<AllocOutcome, AllocError>, f64) {
        let result = self.memory.alloc(size);
        let nodes = match &result {
            Ok(o) => o.nodes_visited,
            Err(_) => self.memory.free_list_len(),
        };
        let cost = (self.spec.alloc_base_ns as f64
            + nodes as f64 * self.spec.alloc_per_node_ns as f64)
            * self.jitter();
        (result, cost)
    }

    /// Raw free. Returns freed size (None = invalid pointer) and cost.
    pub fn raw_free(&mut self, ptr: DevicePtr) -> (Option<u64>, f64) {
        let freed = self.memory.free(ptr);
        let cost = self.spec.free_base_ns as f64 * self.jitter();
        (freed, cost)
    }

    /// Raw kernel execution: computes the duration from the roofline model
    /// and the tenant's current cache/bandwidth conditions, enqueues it on
    /// `stream`, and records SM busy time. Returns `(start, end)` virtual
    /// times of the kernel body (the *launch* overhead is charged by the
    /// API layer).
    pub fn raw_launch(
        &mut self,
        tenant: TenantId,
        stream: StreamId,
        kernel: &KernelDesc,
        granted_sms: u32,
    ) -> Option<(u64, u64)> {
        let ctx = ExecContext {
            sms: granted_sms,
            l2_hit_rate: self.l2.stats(tenant).hit_rate(),
            bw_share: self.membw_share(tenant),
        };
        let dur = duration_ns(&self.spec, kernel, &ctx) * self.jitter();
        let now = self.clock.now_ns();
        let span = self.streams.enqueue(stream, now, dur.round() as u64)?;
        let occupancy_frac =
            (granted_sms as f64 / self.spec.sm_count as f64).min(1.0) * kernel.occupancy.clamp(0.0, 1.0).max(1.0 / 2048.0);
        self.sms.record_busy(tenant, occupancy_frac.min(1.0), dur);
        Some(span)
    }

    /// Raw host↔device copy. Returns `(duration_ns, achieved_gbps)`.
    pub fn raw_transfer(
        &mut self,
        tenant: TenantId,
        dir: Direction,
        bytes: u64,
        pinned: bool,
    ) -> (f64, f64) {
        let j = self.jitter();
        let (dur, bw) = self.pcie.transfer_ns(tenant, dir, bytes, pinned);
        (dur * j, bw / j)
    }

    /// Register tenant compute grant (dedicated = MIG slice).
    pub fn grant_sms(&mut self, tenant: TenantId, grant: SmGrant) -> Result<(), String> {
        self.sms.register(tenant, grant)
    }

    /// Create a stream.
    pub fn create_stream(&mut self, priority: StreamPriority) -> StreamId {
        self.streams.create(priority)
    }

    /// Inject a fault (fault-injection harness for ERR/IS-010 metrics).
    /// Detection latency: ECC errors surface on the next scrub (~ms);
    /// illegal addresses surface at the next sync (~µs).
    pub fn inject_fault(&mut self, tenant: TenantId, fault: GpuFault) {
        let detect_ns = match fault {
            GpuFault::EccUncorrectable => 1_500_000,
            GpuFault::IllegalAddress => 35_000,
            GpuFault::LaunchTimeout => 2_000_000,
            GpuFault::OutOfMemory => 0,
        };
        let jitter = self.jitter();
        let now = self.clock.now_ns();
        self.errors.inject(tenant, fault, now, (detect_ns as f64 * jitter) as u64);
    }

    /// Full device reset (ERR-002): clears memory, caches, streams, errors.
    /// Returns the virtual-ns cost.
    pub fn reset(&mut self) -> f64 {
        self.memory.reset();
        self.l2.flush();
        self.streams.reset();
        self.errors.reset();
        self.spec.reset_ns as f64 * self.jitter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_cost_calibrated_to_table4() {
        let mut d = GpuDevice::a100(1);
        let (r, cost) = d.raw_alloc(1 << 20);
        assert!(r.is_ok());
        // Table 4 native alloc = 12.5 µs; fresh allocator visits 1 node.
        assert!((cost - 12_535.0).abs() < 12_535.0 * 0.2, "cost={cost}");
    }

    #[test]
    fn alloc_cost_grows_with_fragmentation() {
        let mut d = GpuDevice::a100(2);
        let mb = 1 << 20;
        let ptrs: Vec<_> = (0..512).map(|_| d.raw_alloc(mb).0.unwrap().ptr).collect();
        for (i, p) in ptrs.iter().enumerate() {
            if i % 2 == 0 {
                d.raw_free(*p);
            }
        }
        // Request larger than any hole → walks the whole free list.
        let (_, cost) = d.raw_alloc(2 * mb);
        assert!(cost > 18_000.0, "cost={cost}");
    }

    #[test]
    fn launch_records_utilization() {
        let mut d = GpuDevice::a100(3);
        d.grant_sms(1, SmGrant::Shared).unwrap();
        d.sms.reset_window(0);
        let k = KernelDesc::gemm(1024, 1024, 1024, false);
        let (_, end) = d.raw_launch(1, 0, &k, 108).unwrap();
        d.clock.advance_to(end);
        let util = d.sms.utilization(1, d.clock.now_ns());
        assert!(util > 0.9, "util={util}");
    }

    #[test]
    fn membw_share_under_background() {
        let mut d = GpuDevice::a100(4);
        d.set_background(2, BackgroundLoad { membw_demand: 1.0, resident_kernels: 1 });
        assert!((d.membw_share(1) - 0.5).abs() < 1e-9);
        d.set_background(3, BackgroundLoad { membw_demand: 1.0, resident_kernels: 1 });
        assert!((d.membw_share(1) - 1.0 / 3.0).abs() < 1e-9);
        d.clear_background();
        assert_eq!(d.membw_share(1), 1.0);
    }

    #[test]
    fn concurrent_shared_counts_residents() {
        let mut d = GpuDevice::a100(5);
        assert_eq!(d.concurrent_shared(1), 1);
        d.set_background(2, BackgroundLoad { membw_demand: 0.0, resident_kernels: 3 });
        assert_eq!(d.concurrent_shared(1), 4);
    }

    #[test]
    fn transfer_roundtrip() {
        let mut d = GpuDevice::a100(6);
        let (dur, bw) = d.raw_transfer(1, Direction::HostToDevice, 1 << 30, true);
        assert!(bw > 20.0 && bw < 27.0, "bw={bw}");
        assert!(dur > 1e9 / 26.0, "dur={dur}");
    }

    #[test]
    fn reset_restores_clean_state() {
        let mut d = GpuDevice::a100(7);
        d.raw_alloc(1 << 20).0.unwrap();
        d.inject_fault(1, GpuFault::EccUncorrectable);
        d.clock.advance(10_000_000);
        assert!(d.errors.check(1, d.clock.now_ns()).is_some());
        let cost = d.reset();
        assert!(cost > 1e6, "cost={cost}");
        assert_eq!(d.memory.used(), 0);
        assert!(d.errors.check(1, d.clock.now_ns()).is_none());
    }

    #[test]
    fn fault_detection_latency_ordering() {
        // Illegal address detected faster than ECC.
        let mut d = GpuDevice::a100(8);
        d.inject_fault(1, GpuFault::IllegalAddress);
        d.inject_fault(2, GpuFault::EccUncorrectable);
        d.clock.advance(100_000); // 100µs: illegal addr observable, ECC not
        assert!(d.errors.check(1, d.clock.now_ns()).is_some());
        // ECC matures later and then poisons everyone.
        d.clock.advance(3_000_000);
        assert!(d.errors.check(3, d.clock.now_ns()).is_some());
    }

    #[test]
    fn deterministic_under_seed() {
        let run = |seed| {
            let mut d = GpuDevice::a100(seed);
            let (_, c1) = d.raw_alloc(1024);
            let (_, c2) = d.raw_alloc(4096);
            (c1, c2)
        };
        assert_eq!(run(42), run(42));
        assert_ne!(run(42), run(43));
    }
}
