//! Set-associative L2 cache model with LRU replacement and optional
//! way-partitioning (the MIG mode).
//!
//! CACHE-001..004 are measured by replaying tenant access streams through
//! this model: hit rates, cross-tenant evictions and working-set collisions
//! all emerge from the replacement policy. MIG partitions ways per tenant,
//! which eliminates cross-tenant evictions by construction — exactly the
//! hardware behaviour the paper uses as its ideal baseline.

use std::collections::HashMap;

use super::TenantId;

/// Per-tenant cache statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Lines this tenant lost to evictions caused by *other* tenants.
    pub evicted_by_others: u64,
    /// Lines this tenant lost to its own capacity misses.
    pub self_evictions: u64,
}

impl CacheStats {
    pub fn accesses(&self) -> u64 {
        self.hits + self.misses
    }

    /// `hits / (hits + misses)` (paper eq. 25); 0 for no accesses.
    pub fn hit_rate(&self) -> f64 {
        let n = self.accesses();
        if n == 0 { 0.0 } else { self.hits as f64 / n as f64 }
    }

    /// Fraction of this tenant's evictions caused by other tenants
    /// (CACHE-002).
    pub fn cross_eviction_rate(&self) -> f64 {
        let total = self.evicted_by_others + self.self_evictions;
        if total == 0 { 0.0 } else { self.evicted_by_others as f64 / total as f64 }
    }
}

#[derive(Clone, Copy, Debug)]
struct Line {
    tag: u64,
    owner: TenantId,
    /// LRU timestamp (higher = more recent).
    lru: u64,
    valid: bool,
}

/// Way-partitioning policy.
#[derive(Clone, Debug, PartialEq)]
pub enum Partition {
    /// All tenants share all ways (native / software virtualization).
    Shared,
    /// Each tenant owns an exclusive contiguous range of ways
    /// (MIG hardware partitioning). Tenants not in the map get no ways and
    /// always miss (modelling an unconfigured instance).
    Ways(HashMap<TenantId, std::ops::Range<u32>>),
}

/// Set-associative cache with per-tenant accounting.
#[derive(Clone, Debug)]
pub struct L2Cache {
    sets: usize,
    ways: usize,
    line_size: u64,
    lines: Vec<Line>, // sets * ways, row-major by set
    tick: u64,
    partition: Partition,
    stats: HashMap<TenantId, CacheStats>,
}

impl L2Cache {
    /// Build from total capacity, line size and associativity.
    pub fn new(capacity_bytes: u64, line_size: u32, ways: u32) -> L2Cache {
        let ways = ways.max(1) as usize;
        let line_size = line_size.max(32) as u64;
        let total_lines = (capacity_bytes / line_size).max(ways as u64) as usize;
        let sets = (total_lines / ways).max(1);
        L2Cache {
            sets,
            ways,
            line_size,
            lines: vec![Line { tag: 0, owner: 0, lru: 0, valid: false }; sets * ways],
            tick: 0,
            partition: Partition::Shared,
            stats: HashMap::new(),
        }
    }

    pub fn sets(&self) -> usize {
        self.sets
    }

    pub fn ways(&self) -> usize {
        self.ways
    }

    pub fn line_size(&self) -> u64 {
        self.line_size
    }

    pub fn capacity_bytes(&self) -> u64 {
        (self.sets * self.ways) as u64 * self.line_size
    }

    /// Install a partition policy (clears the cache — reconfiguration
    /// quiesces, as MIG does).
    pub fn set_partition(&mut self, p: Partition) {
        self.partition = p;
        for l in &mut self.lines {
            l.valid = false;
        }
    }

    fn way_range(&self, tenant: TenantId) -> std::ops::Range<usize> {
        match &self.partition {
            Partition::Shared => 0..self.ways,
            Partition::Ways(map) => match map.get(&tenant) {
                Some(r) => (r.start as usize).min(self.ways)..(r.end as usize).min(self.ways),
                None => 0..0,
            },
        }
    }

    /// Access one byte address; returns `true` on hit. Installs the line on
    /// miss (write-allocate, as L2 is unified).
    pub fn access(&mut self, tenant: TenantId, addr: u64) -> bool {
        self.tick += 1;
        let tick = self.tick;
        let block = addr / self.line_size;
        let set = (block % self.sets as u64) as usize;
        let tag = block / self.sets as u64;
        let ways = self.way_range(tenant);
        let entry = self.stats.entry(tenant).or_default();
        if ways.is_empty() {
            // Unpartitioned tenant: bypasses cache entirely.
            entry.misses += 1;
            return false;
        }
        let base = set * self.ways;
        // Hit check.
        for w in ways.clone() {
            let l = &mut self.lines[base + w];
            if l.valid && l.tag == tag && l.owner == tenant {
                l.lru = tick;
                entry.hits += 1;
                return true;
            }
        }
        entry.misses += 1;
        // Victim: invalid first, else LRU within the tenant's ways.
        let mut victim = ways.start;
        let mut best = u64::MAX;
        for w in ways {
            let l = &self.lines[base + w];
            if !l.valid {
                victim = w;

                break;
            }
            if l.lru < best {
                best = l.lru;
                victim = w;
            }
        }
        let v = self.lines[base + victim];
        if v.valid {
            let victim_stats = self.stats.entry(v.owner).or_default();
            if v.owner == tenant {
                victim_stats.self_evictions += 1;
            } else {
                victim_stats.evicted_by_others += 1;
            }
        }
        self.lines[base + victim] = Line { tag, owner: tenant, lru: tick, valid: true };
        false
    }

    /// Stream `bytes` of sequential accesses starting at `addr` and return
    /// the number of line-granular hits (used by the kernel cost model).
    pub fn access_range(&mut self, tenant: TenantId, addr: u64, bytes: u64) -> (u64, u64) {
        let mut hits = 0;
        let mut misses = 0;
        let first = addr / self.line_size;
        let last = (addr + bytes.max(1) - 1) / self.line_size;
        for block in first..=last {
            if self.access(tenant, block * self.line_size) {
                hits += 1;
            } else {
                misses += 1;
            }
        }
        (hits, misses)
    }

    pub fn stats(&self, tenant: TenantId) -> CacheStats {
        self.stats.get(&tenant).copied().unwrap_or_default()
    }

    pub fn reset_stats(&mut self) {
        self.stats.clear();
    }

    /// Invalidate everything (device reset).
    pub fn flush(&mut self) {
        for l in &mut self.lines {
            l.valid = false;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> L2Cache {
        // 64 lines of 128B, 4-way → 16 sets.
        L2Cache::new(64 * 128, 128, 4)
    }

    #[test]
    fn geometry() {
        let c = small();
        assert_eq!(c.ways(), 4);
        assert_eq!(c.sets(), 16);
        assert_eq!(c.capacity_bytes(), 64 * 128);
    }

    #[test]
    fn repeat_access_hits() {
        let mut c = small();
        assert!(!c.access(1, 0)); // cold miss
        assert!(c.access(1, 0)); // hit
        assert!(c.access(1, 64)); // same line
        let s = c.stats(1);
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 1);
    }

    #[test]
    fn lru_evicts_oldest() {
        let mut c = small();
        // 5 distinct tags mapping to set 0 in a 4-way cache.
        for i in 0..5u64 {
            c.access(1, i * 16 * 128); // stride = sets*line
        }
        // Tag 0 was evicted by tag 4; re-accessing tag 0 misses and evicts
        // tag 1 (now LRU); tag 2 is still resident.
        assert!(!c.access(1, 0));
        assert!(c.access(1, 2 * 16 * 128));
    }

    #[test]
    fn working_set_within_capacity_hits_after_warmup() {
        let mut c = small();
        let ws = 32 * 128; // half of capacity
        c.access_range(1, 0, ws);
        let (hits, misses) = c.access_range(1, 0, ws);
        assert_eq!(misses, 0);
        assert_eq!(hits, 32);
    }

    #[test]
    fn cross_tenant_eviction_tracked_when_shared() {
        let mut c = small();
        // Tenant 1 fills the cache, tenant 2 streams over it.
        c.access_range(1, 0, 64 * 128);
        c.access_range(2, 1 << 20, 64 * 128);
        let s1 = c.stats(1);
        assert!(s1.evicted_by_others > 0, "{s1:?}");
    }

    #[test]
    fn partition_prevents_cross_eviction() {
        let mut c = small();
        let mut map = HashMap::new();
        map.insert(1, 0..2u32);
        map.insert(2, 2..4u32);
        c.set_partition(Partition::Ways(map));
        c.access_range(1, 0, 32 * 128);
        c.access_range(2, 1 << 20, 64 * 128);
        assert_eq!(c.stats(1).evicted_by_others, 0);
        assert_eq!(c.stats(2).evicted_by_others, 0);
    }

    #[test]
    fn partitioned_tenant_has_reduced_capacity() {
        let mut c = small();
        let mut map = HashMap::new();
        map.insert(1, 0..2u32); // half the ways
        c.set_partition(Partition::Ways(map));
        // Working set = full capacity now thrashes.
        c.access_range(1, 0, 64 * 128);
        let warm = c.stats(1);
        c.reset_stats();
        c.access_range(1, 0, 64 * 128);
        let after = c.stats(1);
        assert!(after.hit_rate() < 0.5, "hit_rate={} warm={:?}", after.hit_rate(), warm);
    }

    #[test]
    fn unmapped_tenant_always_misses() {
        let mut c = small();
        c.set_partition(Partition::Ways(HashMap::new()));
        assert!(!c.access(9, 0));
        assert!(!c.access(9, 0));
        assert_eq!(c.stats(9).hits, 0);
    }

    #[test]
    fn same_address_different_tenants_do_not_share_lines() {
        // Software virtualization gives tenants distinct VA spaces; the
        // model tags lines by owner so tenant 2 misses on tenant 1's line.
        let mut c = small();
        c.access(1, 0);
        assert!(!c.access(2, 0));
    }

    #[test]
    fn flush_invalidates() {
        let mut c = small();
        c.access(1, 0);
        c.flush();
        assert!(!c.access(1, 0));
    }
}
