//! CUDA-stream model: per-stream FIFO timelines in virtual time.
//!
//! Work items on one stream serialize; items on different streams overlap
//! up to resource limits (the launch path decides the SM split). Events are
//! timestamps on a stream's timeline — `elapsed = end - start`, exactly the
//! CUDA-event arithmetic the paper's harness uses.

use std::collections::HashMap;

use super::StreamId;

/// Priority for preemption tests (SCHED-004).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum StreamPriority {
    Low,
    Normal,
    High,
}

#[derive(Clone, Debug)]
struct StreamState {
    /// Virtual time at which the stream's last queued work finishes.
    ready_at_ns: u64,
    priority: StreamPriority,
    /// Number of work items ever enqueued.
    depth: u64,
}

/// The per-device stream table.
#[derive(Clone, Debug, Default)]
pub struct StreamTable {
    streams: HashMap<StreamId, StreamState>,
    next_id: StreamId,
}

impl StreamTable {
    pub fn new() -> StreamTable {
        let mut t = StreamTable::default();
        // Stream 0 is the default (legacy) stream.
        t.streams.insert(
            0,
            StreamState { ready_at_ns: 0, priority: StreamPriority::Normal, depth: 0 },
        );
        t.next_id = 1;
        t
    }

    pub fn create(&mut self, priority: StreamPriority) -> StreamId {
        let id = self.next_id;
        self.next_id += 1;
        self.streams.insert(id, StreamState { ready_at_ns: 0, priority, depth: 0 });
        id
    }

    pub fn destroy(&mut self, id: StreamId) -> bool {
        if id == 0 {
            return false; // default stream is indestructible
        }
        self.streams.remove(&id).is_some()
    }

    pub fn exists(&self, id: StreamId) -> bool {
        self.streams.contains_key(&id)
    }

    pub fn priority(&self, id: StreamId) -> Option<StreamPriority> {
        self.streams.get(&id).map(|s| s.priority)
    }

    /// Count of streams with queued work finishing after `now` (i.e.
    /// concurrently active).
    pub fn active_at(&self, now_ns: u64) -> u32 {
        self.streams.values().filter(|s| s.ready_at_ns > now_ns).count() as u32
    }

    /// Enqueue `duration_ns` of work on `stream` at `now_ns`; returns
    /// `(start, end)` in virtual time. Returns `None` for an unknown stream.
    pub fn enqueue(&mut self, stream: StreamId, now_ns: u64, duration_ns: u64) -> Option<(u64, u64)> {
        let s = self.streams.get_mut(&stream)?;
        let start = s.ready_at_ns.max(now_ns);
        let end = start + duration_ns;
        s.ready_at_ns = end;
        s.depth += 1;
        Some((start, end))
    }

    /// `cudaStreamSynchronize`: virtual time at which the stream drains.
    pub fn sync_time(&self, stream: StreamId, now_ns: u64) -> Option<u64> {
        self.streams.get(&stream).map(|s| s.ready_at_ns.max(now_ns))
    }

    /// `cudaDeviceSynchronize`: all streams drained.
    pub fn device_sync_time(&self, now_ns: u64) -> u64 {
        self.streams.values().map(|s| s.ready_at_ns).max().unwrap_or(0).max(now_ns)
    }

    /// Preemption point for a high-priority launch: the earliest time the
    /// device can switch to it — end of the currently-running (not queued)
    /// work item. We approximate the running item's remainder as
    /// `min(ready_at - now, typical_slice)`.
    pub fn preemption_delay_ns(&self, now_ns: u64, slice_ns: u64) -> u64 {
        let busy_until = self
            .streams
            .values()
            .filter(|s| s.ready_at_ns > now_ns)
            .map(|s| s.ready_at_ns - now_ns)
            .min()
            .unwrap_or(0);
        busy_until.min(slice_ns)
    }

    pub fn depth(&self, stream: StreamId) -> u64 {
        self.streams.get(&stream).map(|s| s.depth).unwrap_or(0)
    }

    pub fn stream_count(&self) -> usize {
        self.streams.len()
    }

    /// Reset all stream timelines (device reset).
    pub fn reset(&mut self) {
        for s in self.streams.values_mut() {
            s.ready_at_ns = 0;
            s.depth = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_stream_exists() {
        let t = StreamTable::new();
        assert!(t.exists(0));
        assert_eq!(t.stream_count(), 1);
    }

    #[test]
    fn same_stream_serializes() {
        let mut t = StreamTable::new();
        let (s1, e1) = t.enqueue(0, 0, 100).unwrap();
        let (s2, e2) = t.enqueue(0, 0, 100).unwrap();
        assert_eq!((s1, e1), (0, 100));
        assert_eq!((s2, e2), (100, 200));
    }

    #[test]
    fn different_streams_overlap() {
        let mut t = StreamTable::new();
        let a = t.create(StreamPriority::Normal);
        let b = t.create(StreamPriority::Normal);
        let (sa, _) = t.enqueue(a, 0, 100).unwrap();
        let (sb, _) = t.enqueue(b, 0, 100).unwrap();
        assert_eq!(sa, 0);
        assert_eq!(sb, 0); // overlapping start
        assert_eq!(t.device_sync_time(0), 100);
    }

    #[test]
    fn sync_times() {
        let mut t = StreamTable::new();
        let a = t.create(StreamPriority::Normal);
        t.enqueue(a, 0, 500).unwrap();
        assert_eq!(t.sync_time(a, 0), Some(500));
        assert_eq!(t.sync_time(0, 42), Some(42)); // idle stream syncs now
        assert_eq!(t.device_sync_time(0), 500);
    }

    #[test]
    fn destroy_default_stream_forbidden() {
        let mut t = StreamTable::new();
        assert!(!t.destroy(0));
        let a = t.create(StreamPriority::Low);
        assert!(t.destroy(a));
        assert!(!t.exists(a));
    }

    #[test]
    fn active_count() {
        let mut t = StreamTable::new();
        let a = t.create(StreamPriority::Normal);
        let b = t.create(StreamPriority::Normal);
        t.enqueue(a, 0, 100).unwrap();
        t.enqueue(b, 0, 200).unwrap();
        assert_eq!(t.active_at(0), 2);
        assert_eq!(t.active_at(150), 1);
        assert_eq!(t.active_at(250), 0);
    }

    #[test]
    fn preemption_delay_bounded_by_slice() {
        let mut t = StreamTable::new();
        t.enqueue(0, 0, 1_000_000).unwrap(); // long-running kernel
        assert_eq!(t.preemption_delay_ns(0, 50_000), 50_000);
        // Idle device → immediate.
        assert_eq!(t.preemption_delay_ns(2_000_000, 50_000), 0);
    }

    #[test]
    fn later_enqueue_starts_at_now() {
        let mut t = StreamTable::new();
        t.enqueue(0, 0, 100).unwrap();
        let (s, e) = t.enqueue(0, 500, 100).unwrap();
        assert_eq!((s, e), (500, 600));
    }
}
