//! Fault injection and the device error/recovery state machine
//! (ERR-001..003).
//!
//! A fault puts the device into a sticky error state: subsequent API calls
//! return the fault's CUDA-style error code until the owning context is
//! destroyed or the device is reset. Detection latency (how long until an
//! API call first observes the asynchronous fault) and recovery time (reset
//! duration) are modelled explicitly; *fault isolation* (IS-010) holds when
//! only the faulting tenant's context is poisoned — which is what both
//! HAMi-core and MIG provide, via process isolation and hardware isolation
//! respectively.

use super::TenantId;

/// Kinds of injected GPU faults.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GpuFault {
    /// Out-of-bounds access — `CUDA_ERROR_ILLEGAL_ADDRESS`, poisons context.
    IllegalAddress,
    /// Double-bit ECC error — poisons the device until reset.
    EccUncorrectable,
    /// Kernel exceeded the watchdog — `CUDA_ERROR_LAUNCH_TIMEOUT`.
    LaunchTimeout,
    /// Allocation beyond quota/capacity — recoverable, context survives.
    OutOfMemory,
}

impl GpuFault {
    /// Whether the fault poisons the whole device (vs just the context).
    pub fn device_fatal(&self) -> bool {
        matches!(self, GpuFault::EccUncorrectable)
    }

    /// Whether the context survives (error returned, future calls OK).
    pub fn recoverable_in_place(&self) -> bool {
        matches!(self, GpuFault::OutOfMemory)
    }
}

/// CUDA-style error codes surfaced to the API layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GpuError {
    OutOfMemory,
    IllegalAddress,
    LaunchTimeout,
    EccUncorrectable,
    InvalidValue,
    InvalidContext,
    NotInitialized,
    /// Virtualization-layer memory-quota rejection (reported to the app as
    /// OOM, but distinguished internally for IS-002 measurement).
    QuotaExceeded,
}

impl std::fmt::Display for GpuError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let code = match self {
            GpuError::OutOfMemory => "CUDA_ERROR_OUT_OF_MEMORY",
            GpuError::IllegalAddress => "CUDA_ERROR_ILLEGAL_ADDRESS",
            GpuError::LaunchTimeout => "CUDA_ERROR_LAUNCH_TIMEOUT",
            GpuError::EccUncorrectable => "CUDA_ERROR_ECC_UNCORRECTABLE",
            GpuError::InvalidValue => "CUDA_ERROR_INVALID_VALUE",
            GpuError::InvalidContext => "CUDA_ERROR_INVALID_CONTEXT",
            GpuError::NotInitialized => "CUDA_ERROR_NOT_INITIALIZED",
            GpuError::QuotaExceeded => "VGPU_ERROR_QUOTA_EXCEEDED",
        };
        write!(f, "{code}")
    }
}

impl std::error::Error for GpuError {}

impl From<GpuFault> for GpuError {
    fn from(f: GpuFault) -> GpuError {
        match f {
            GpuFault::IllegalAddress => GpuError::IllegalAddress,
            GpuFault::EccUncorrectable => GpuError::EccUncorrectable,
            GpuFault::LaunchTimeout => GpuError::LaunchTimeout,
            GpuFault::OutOfMemory => GpuError::OutOfMemory,
        }
    }
}

/// A pending (not yet observed) asynchronous fault.
#[derive(Clone, Copy, Debug)]
struct PendingFault {
    fault: GpuFault,
    tenant: TenantId,
    /// Virtual time at which the fault becomes observable (hardware raises
    /// the interrupt / the next sync notices).
    observable_at_ns: u64,
}

/// Error state machine for one device.
#[derive(Clone, Debug, Default)]
pub struct ErrorState {
    pending: Vec<PendingFault>,
    /// Tenants whose contexts are poisoned (fault kind recorded).
    poisoned: Vec<(TenantId, GpuFault)>,
    /// Device-fatal fault outstanding (requires reset).
    device_poisoned: Option<GpuFault>,
    pub faults_injected: u64,
    pub resets: u64,
}

impl ErrorState {
    pub fn new() -> ErrorState {
        ErrorState::default()
    }

    /// Inject `fault` attributed to `tenant`, observable after
    /// `detect_latency_ns` of virtual time.
    pub fn inject(&mut self, tenant: TenantId, fault: GpuFault, now_ns: u64, detect_latency_ns: u64) {
        self.faults_injected += 1;
        self.pending.push(PendingFault {
            fault,
            tenant,
            observable_at_ns: now_ns + detect_latency_ns,
        });
    }

    /// Called on every API touchpoint: promote observable pending faults to
    /// poisoned state. Returns the error the *calling tenant* should see
    /// now, if any.
    pub fn check(&mut self, tenant: TenantId, now_ns: u64) -> Option<GpuError> {
        // Promote matured faults.
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].observable_at_ns <= now_ns {
                let p = self.pending.remove(i);
                if p.fault.device_fatal() {
                    self.device_poisoned = Some(p.fault);
                } else if !p.fault.recoverable_in_place() {
                    self.poisoned.push((p.tenant, p.fault));
                }
                // Recoverable faults only surface once, at injection site —
                // handled by the API layer returning the error code.
            } else {
                i += 1;
            }
        }
        if let Some(f) = self.device_poisoned {
            return Some(f.into());
        }
        self.poisoned
            .iter()
            .find(|(t, _)| *t == tenant)
            .map(|(_, f)| (*f).into())
    }

    /// Whether `tenant`'s context is poisoned (ignoring device-fatal state).
    pub fn tenant_poisoned(&self, tenant: TenantId) -> bool {
        self.poisoned.iter().any(|(t, _)| *t == tenant)
    }

    pub fn device_poisoned(&self) -> bool {
        self.device_poisoned.is_some()
    }

    /// Destroy-and-recreate the tenant's context: clears tenant poison.
    pub fn recover_tenant(&mut self, tenant: TenantId) {
        self.poisoned.retain(|(t, _)| *t != tenant);
    }

    /// Full device reset: clears everything. Caller charges `spec.reset_ns`.
    pub fn reset(&mut self) {
        self.pending.clear();
        self.poisoned.clear();
        self.device_poisoned = None;
        self.resets += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_not_observable_before_latency() {
        let mut e = ErrorState::new();
        e.inject(1, GpuFault::IllegalAddress, 0, 1_000);
        assert_eq!(e.check(1, 500), None);
        assert_eq!(e.check(1, 1_000), Some(GpuError::IllegalAddress));
    }

    #[test]
    fn context_fault_isolated_to_tenant() {
        let mut e = ErrorState::new();
        e.inject(1, GpuFault::IllegalAddress, 0, 0);
        assert_eq!(e.check(1, 1), Some(GpuError::IllegalAddress));
        assert_eq!(e.check(2, 1), None); // other tenant unaffected (IS-010)
    }

    #[test]
    fn ecc_fault_poisons_device() {
        let mut e = ErrorState::new();
        e.inject(1, GpuFault::EccUncorrectable, 0, 0);
        assert_eq!(e.check(2, 1), Some(GpuError::EccUncorrectable));
        assert!(e.device_poisoned());
    }

    #[test]
    fn oom_is_recoverable_in_place() {
        let mut e = ErrorState::new();
        e.inject(1, GpuFault::OutOfMemory, 0, 0);
        // OOM does not poison: subsequent calls succeed.
        assert_eq!(e.check(1, 1), None);
        assert!(!e.tenant_poisoned(1));
    }

    #[test]
    fn tenant_recovery_clears_poison() {
        let mut e = ErrorState::new();
        e.inject(1, GpuFault::LaunchTimeout, 0, 0);
        e.check(1, 1);
        assert!(e.tenant_poisoned(1));
        e.recover_tenant(1);
        assert!(!e.tenant_poisoned(1));
        assert_eq!(e.check(1, 2), None);
    }

    #[test]
    fn device_reset_clears_all() {
        let mut e = ErrorState::new();
        e.inject(1, GpuFault::EccUncorrectable, 0, 0);
        e.check(1, 1);
        e.reset();
        assert!(!e.device_poisoned());
        assert_eq!(e.check(1, 2), None);
        assert_eq!(e.resets, 1);
    }

    #[test]
    fn sticky_until_recovered() {
        let mut e = ErrorState::new();
        e.inject(1, GpuFault::IllegalAddress, 0, 0);
        for t in 1..5 {
            assert_eq!(e.check(1, t), Some(GpuError::IllegalAddress));
        }
    }
}
