//! Multi-GPU interconnect topology and collective cost models
//! (NCCL-001..004).
//!
//! Devices are connected either all-to-all via NVLink (SXM systems) or
//! through the PCIe host bridge. Collective times use the standard ring
//! algorithm cost models (the same first-order models NCCL tuning uses):
//!
//! - allreduce:  `2·(n-1)/n · size / bw + 2·(n-1)·latency`
//! - allgather / reduce-scatter: `(n-1)/n · size / bw + (n-1)·latency`
//! - broadcast (ring-pipelined): `size / bw + (n-1)·latency`
//!
//! Since PR 4 the node topology is an **experiment axis** rather than a
//! fixed constant: a sweep cell's full coordinate is
//! `(system, tenants, quota_pct, gpu_count, link)`, where `gpu_count`
//! selects the device count passed to [`Topology::nvlink_node`] /
//! [`Topology::pcie_node`] and `link` is a [`LinkKind`]. The NCCL/P2P
//! and PCIe metric backends build their topology from those two
//! `RunConfig` fields, so multi-GPU communication numbers are keyed to
//! the cell being evaluated (see `docs/sweeps.md`).

/// Interconnect flavour between a device pair.
///
/// Also a sweep axis: `gvbench sweep --link nvlink,pcie` evaluates every
/// scenario on both node flavours. [`LinkKind::key`] /
/// [`LinkKind::from_key`] define the CLI / config-file / CSV spelling.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LinkKind {
    /// Direct NVLink.
    NvLink,
    /// Through the PCIe switch / host bridge.
    Pcie,
}

impl LinkKind {
    /// Both kinds, in CLI listing order.
    pub const ALL: [LinkKind; 2] = [LinkKind::NvLink, LinkKind::Pcie];

    /// Stable lower-case key used by the CLI (`--link nvlink,pcie`), the
    /// `[sweep]` config section and the sweep CSV `link` column.
    pub fn key(&self) -> &'static str {
        match self {
            LinkKind::NvLink => "nvlink",
            LinkKind::Pcie => "pcie",
        }
    }

    /// Inverse of [`LinkKind::key`]; `None` for unknown spellings.
    pub fn from_key(key: &str) -> Option<LinkKind> {
        LinkKind::ALL.iter().copied().find(|l| l.key() == key)
    }
}

/// A multi-GPU node topology.
#[derive(Clone, Debug)]
pub struct Topology {
    pub device_count: u32,
    /// Per-direction NVLink bandwidth between a pair, GB/s (0 = no NVLink).
    pub nvlink_gbps: f64,
    /// PCIe P2P bandwidth, GB/s.
    pub pcie_gbps: f64,
    /// Per-hop latency, ns.
    pub nvlink_latency_ns: f64,
    pub pcie_latency_ns: f64,
}

impl Topology {
    /// DGX-like node: `n` devices, all-to-all NVLink.
    pub fn nvlink_node(n: u32, nvlink_gbps: f64) -> Topology {
        Topology {
            device_count: n,
            nvlink_gbps,
            pcie_gbps: 25.0,
            nvlink_latency_ns: 1_300.0,
            pcie_latency_ns: 2_800.0,
        }
    }

    /// PCIe-only node (the paper's A100 PCIe testbed).
    pub fn pcie_node(n: u32, pcie_gbps: f64) -> Topology {
        Topology {
            device_count: n,
            nvlink_gbps: 0.0,
            pcie_gbps,
            nvlink_latency_ns: 1_300.0,
            pcie_latency_ns: 2_800.0,
        }
    }

    pub fn link_kind(&self) -> LinkKind {
        if self.nvlink_gbps > 0.0 { LinkKind::NvLink } else { LinkKind::Pcie }
    }

    fn link_bw_gbps(&self) -> f64 {
        match self.link_kind() {
            LinkKind::NvLink => self.nvlink_gbps,
            LinkKind::Pcie => self.pcie_gbps,
        }
    }

    fn hop_latency_ns(&self) -> f64 {
        match self.link_kind() {
            LinkKind::NvLink => self.nvlink_latency_ns,
            LinkKind::Pcie => self.pcie_latency_ns,
        }
    }

    /// Point-to-point transfer time in ns and achieved GB/s.
    /// `bw_share` models contention from other tenants' collectives.
    pub fn p2p_ns(&self, bytes: u64, bw_share: f64) -> (f64, f64) {
        let bw = self.link_bw_gbps() * bw_share.clamp(1e-3, 1.0);
        let dur = self.hop_latency_ns() + bytes as f64 / (bw * 1e9) * 1e9;
        (dur, bytes as f64 / dur)
    }

    /// Ring allreduce over `n` ranks of a `bytes` buffer.
    pub fn allreduce_ns(&self, bytes: u64, bw_share: f64) -> f64 {
        let n = self.device_count.max(2) as f64;
        let bw = self.link_bw_gbps() * bw_share.clamp(1e-3, 1.0) * 1e9;
        2.0 * (n - 1.0) / n * bytes as f64 / bw * 1e9 + 2.0 * (n - 1.0) * self.hop_latency_ns()
    }

    /// Ring allgather of `bytes` total output.
    pub fn allgather_ns(&self, bytes: u64, bw_share: f64) -> f64 {
        let n = self.device_count.max(2) as f64;
        let bw = self.link_bw_gbps() * bw_share.clamp(1e-3, 1.0) * 1e9;
        (n - 1.0) / n * bytes as f64 / bw * 1e9 + (n - 1.0) * self.hop_latency_ns()
    }

    /// Pipelined ring broadcast of `bytes`.
    pub fn broadcast_ns(&self, bytes: u64, bw_share: f64) -> f64 {
        let n = self.device_count.max(2) as f64;
        let bw = self.link_bw_gbps() * bw_share.clamp(1e-3, 1.0) * 1e9;
        bytes as f64 / bw * 1e9 + (n - 1.0) * self.hop_latency_ns()
    }

    /// Algorithm ("bus") bandwidth for an allreduce: the figure NCCL tests
    /// report — `size / time · 2(n-1)/n`.
    pub fn allreduce_busbw_gbps(&self, bytes: u64, bw_share: f64) -> f64 {
        let n = self.device_count.max(2) as f64;
        let t = self.allreduce_ns(bytes, bw_share);
        bytes as f64 / t * (2.0 * (n - 1.0) / n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nvlink_beats_pcie() {
        let nv = Topology::nvlink_node(4, 300.0);
        let pc = Topology::pcie_node(4, 25.0);
        let b = 1 << 28;
        assert!(nv.allreduce_ns(b, 1.0) < pc.allreduce_ns(b, 1.0) / 5.0);
    }

    #[test]
    fn allreduce_busbw_approaches_link_bw() {
        let nv = Topology::nvlink_node(8, 300.0);
        // Large message: bus bandwidth ≈ link bandwidth.
        let busbw = nv.allreduce_busbw_gbps(1 << 30, 1.0);
        assert!(busbw > 270.0 && busbw <= 300.0, "busbw={busbw}");
    }

    #[test]
    fn latency_dominates_small_messages() {
        let nv = Topology::nvlink_node(8, 300.0);
        let t_small = nv.allreduce_ns(1024, 1.0);
        // 2*(n-1)*latency = 14 * 1300 = 18200ns floor.
        assert!(t_small >= 18_200.0, "t={t_small}");
    }

    #[test]
    fn contention_scales_time() {
        let nv = Topology::nvlink_node(4, 300.0);
        let solo = nv.allreduce_ns(1 << 30, 1.0);
        let half = nv.allreduce_ns(1 << 30, 0.5);
        assert!(half > solo * 1.8 && half < solo * 2.1);
    }

    #[test]
    fn p2p_achieves_share() {
        let nv = Topology::nvlink_node(2, 300.0);
        let (_, bw) = nv.p2p_ns(1 << 30, 1.0);
        assert!(bw > 290.0, "bw={bw}");
        let (_, bw_half) = nv.p2p_ns(1 << 30, 0.5);
        assert!(bw_half < 155.0, "bw={bw_half}");
    }

    #[test]
    fn link_kind_keys_roundtrip() {
        for l in LinkKind::ALL {
            assert_eq!(LinkKind::from_key(l.key()), Some(l));
        }
        assert_eq!(LinkKind::from_key("NVLINK"), None);
        assert_eq!(LinkKind::from_key("sli"), None);
        // The constructors produce nodes of the matching kind.
        assert_eq!(Topology::nvlink_node(4, 300.0).link_kind(), LinkKind::NvLink);
        assert_eq!(Topology::pcie_node(4, 25.0).link_kind(), LinkKind::Pcie);
    }

    #[test]
    fn collective_ordering() {
        // For the same payload: broadcast < allgather < allreduce.
        let nv = Topology::nvlink_node(8, 300.0);
        let b = 1 << 28;
        let br = nv.broadcast_ns(b, 1.0);
        let ag = nv.allgather_ns(b, 1.0);
        let ar = nv.allreduce_ns(b, 1.0);
        assert!(ar > ag, "ar={ar} ag={ag}");
        // Pipelined broadcast moves the full buffer once; allgather (n-1)/n
        // of it — they are close, allreduce is ~2x allgather.
        assert!(ar / ag > 1.8);
        assert!(br < ar);
    }
}
