//! Simulated HBM allocator.
//!
//! A real first-fit free-list allocator over the device address range.
//! Fragmentation metrics (FRAG-001..003) and allocation-latency degradation
//! (FRAG-002) are *emergent*: repeated alloc/free churn grows the free list,
//! lengthening the first-fit search that [`AllocOutcome::nodes_visited`]
//! reports to the latency model.

use std::collections::BTreeMap;

/// Device pointer (byte offset into simulated HBM).
pub type DevicePtr = u64;

/// Allocation granularity — CUDA rounds device allocations up; 256 B
/// matches `cuMemAlloc` alignment.
pub const ALIGN: u64 = 256;

/// A contiguous free region `[start, start + len)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FreeBlock {
    pub start: u64,
    pub len: u64,
}

/// Result of a successful allocation, including the search cost used by the
/// latency model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AllocOutcome {
    pub ptr: DevicePtr,
    /// Rounded-up size actually reserved.
    pub reserved: u64,
    /// Free-list nodes visited during the first-fit search.
    pub nodes_visited: usize,
}

/// Why an allocation failed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllocError {
    /// Not enough total free memory.
    OutOfMemory { requested: u64, free: u64 },
    /// Enough total free memory but no contiguous block (fragmentation).
    Fragmented { requested: u64, largest_free: u64 },
    /// Zero-byte allocation.
    ZeroSize,
}

/// Fragmentation snapshot (paper eq. 27).
#[derive(Clone, Copy, Debug, Default)]
pub struct FragStats {
    pub total_free: u64,
    pub largest_free: u64,
    pub free_blocks: usize,
    /// `1 - largest_free/total_free` (0 when nothing is free).
    pub fragmentation_index: f64,
}

/// First-fit free-list allocator over `[0, capacity)`.
#[derive(Clone, Debug)]
pub struct HbmAllocator {
    capacity: u64,
    /// Free blocks ordered by start address (coalescing needs order).
    free: Vec<FreeBlock>,
    /// Live allocations: ptr → reserved length.
    live: BTreeMap<DevicePtr, u64>,
    /// Total bytes currently reserved.
    used: u64,
    /// Cumulative counters.
    pub total_allocs: u64,
    pub total_frees: u64,
    pub failed_allocs: u64,
}

impl HbmAllocator {
    pub fn new(capacity: u64) -> HbmAllocator {
        HbmAllocator {
            capacity,
            free: vec![FreeBlock { start: 0, len: capacity }],
            live: BTreeMap::new(),
            used: 0,
            total_allocs: 0,
            total_frees: 0,
            failed_allocs: 0,
        }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    pub fn free_bytes(&self) -> u64 {
        self.capacity - self.used
    }

    pub fn live_allocations(&self) -> usize {
        self.live.len()
    }

    /// Round a request up to allocation granularity.
    pub fn round_up(size: u64) -> u64 {
        size.div_ceil(ALIGN) * ALIGN
    }

    /// First-fit allocation.
    pub fn alloc(&mut self, size: u64) -> Result<AllocOutcome, AllocError> {
        if size == 0 {
            self.failed_allocs += 1;
            return Err(AllocError::ZeroSize);
        }
        let need = Self::round_up(size);
        let mut visited = 0;
        for i in 0..self.free.len() {
            visited += 1;
            let b = self.free[i];
            if b.len >= need {
                let ptr = b.start;
                if b.len == need {
                    self.free.remove(i);
                } else {
                    self.free[i] = FreeBlock { start: b.start + need, len: b.len - need };
                }
                self.live.insert(ptr, need);
                self.used += need;
                self.total_allocs += 1;
                return Ok(AllocOutcome { ptr, reserved: need, nodes_visited: visited });
            }
        }
        self.failed_allocs += 1;
        let stats = self.frag_stats();
        if need > stats.total_free {
            Err(AllocError::OutOfMemory { requested: need, free: stats.total_free })
        } else {
            Err(AllocError::Fragmented { requested: need, largest_free: stats.largest_free })
        }
    }

    /// Free a previous allocation, coalescing with neighbours.
    /// Returns the reserved length, or `None` for an invalid pointer
    /// (double-free / wild pointer — surfaced as a CUDA error upstream).
    pub fn free(&mut self, ptr: DevicePtr) -> Option<u64> {
        let len = self.live.remove(&ptr)?;
        self.used -= len;
        self.total_frees += 1;
        // Insert sorted by start, then coalesce with neighbours.
        let idx = self.free.partition_point(|b| b.start < ptr);
        self.free.insert(idx, FreeBlock { start: ptr, len });
        // Coalesce with next.
        if idx + 1 < self.free.len() && self.free[idx].start + self.free[idx].len == self.free[idx + 1].start {
            self.free[idx].len += self.free[idx + 1].len;
            self.free.remove(idx + 1);
        }
        // Coalesce with previous.
        if idx > 0 && self.free[idx - 1].start + self.free[idx - 1].len == self.free[idx].start {
            self.free[idx - 1].len += self.free[idx].len;
            self.free.remove(idx);
        }
        Some(len)
    }

    /// Whether `ptr` is a live allocation base pointer.
    pub fn is_live(&self, ptr: DevicePtr) -> bool {
        self.live.contains_key(&ptr)
    }

    /// Reserved size of a live allocation.
    pub fn size_of(&self, ptr: DevicePtr) -> Option<u64> {
        self.live.get(&ptr).copied()
    }

    /// Fragmentation snapshot (paper eq. 27:
    /// `frag = 1 - largest_free_block / total_free_memory`).
    pub fn frag_stats(&self) -> FragStats {
        let total_free: u64 = self.free.iter().map(|b| b.len).sum();
        let largest_free = self.free.iter().map(|b| b.len).max().unwrap_or(0);
        FragStats {
            total_free,
            largest_free,
            free_blocks: self.free.len(),
            fragmentation_index: if total_free == 0 {
                0.0
            } else {
                1.0 - largest_free as f64 / total_free as f64
            },
        }
    }

    /// Compact live allocations to the bottom of the address range
    /// (FRAG-003). Returns the number of bytes moved — the cost model
    /// charges `moved / hbm_bw` for the copy. Pointers are relocated; the
    /// returned map gives old → new addresses.
    pub fn compact(&mut self) -> (u64, BTreeMap<DevicePtr, DevicePtr>) {
        let mut moved_bytes = 0;
        let mut relocations = BTreeMap::new();
        let mut cursor = 0u64;
        let mut new_live = BTreeMap::new();
        for (&ptr, &len) in &self.live {
            if ptr != cursor {
                moved_bytes += len;
                relocations.insert(ptr, cursor);
            }
            new_live.insert(cursor, len);
            cursor += len;
        }
        self.live = new_live;
        self.free = if cursor < self.capacity {
            vec![FreeBlock { start: cursor, len: self.capacity - cursor }]
        } else {
            Vec::new()
        };
        (moved_bytes, relocations)
    }

    /// Free every live allocation (device reset).
    pub fn reset(&mut self) {
        self.live.clear();
        self.used = 0;
        self.free = vec![FreeBlock { start: 0, len: self.capacity }];
    }

    /// Number of free-list nodes (search-length proxy exported to tests).
    pub fn free_list_len(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: u64 = 1 << 20;

    #[test]
    fn alloc_free_roundtrip() {
        let mut a = HbmAllocator::new(64 * MB);
        let o = a.alloc(MB).unwrap();
        assert_eq!(o.ptr, 0);
        assert_eq!(o.reserved, MB);
        assert_eq!(a.used(), MB);
        assert_eq!(a.free(o.ptr), Some(MB));
        assert_eq!(a.used(), 0);
        assert_eq!(a.free_list_len(), 1); // fully coalesced
    }

    #[test]
    fn rounds_up_to_alignment() {
        let mut a = HbmAllocator::new(MB);
        let o = a.alloc(1).unwrap();
        assert_eq!(o.reserved, ALIGN);
    }

    #[test]
    fn zero_size_rejected() {
        let mut a = HbmAllocator::new(MB);
        assert_eq!(a.alloc(0), Err(AllocError::ZeroSize));
    }

    #[test]
    fn oom_reports_free() {
        let mut a = HbmAllocator::new(MB);
        a.alloc(MB).unwrap();
        match a.alloc(1) {
            Err(AllocError::OutOfMemory { free, .. }) => assert_eq!(free, 0),
            other => panic!("expected OOM, got {other:?}"),
        }
    }

    #[test]
    fn fragmentation_emerges_from_churn() {
        let mut a = HbmAllocator::new(64 * MB);
        // Allocate 64 x 1MB, free every other one → 32 free holes.
        let ptrs: Vec<_> = (0..64).map(|_| a.alloc(MB).unwrap().ptr).collect();
        for (i, p) in ptrs.iter().enumerate() {
            if i % 2 == 0 {
                a.free(*p);
            }
        }
        let fs = a.frag_stats();
        assert_eq!(fs.free_blocks, 32);
        assert_eq!(fs.total_free, 32 * MB);
        assert_eq!(fs.largest_free, MB);
        assert!((fs.fragmentation_index - (1.0 - 1.0 / 32.0)).abs() < 1e-12);
        // A 2MB contiguous request fails even though 32MB is free.
        match a.alloc(2 * MB) {
            Err(AllocError::Fragmented { largest_free, .. }) => assert_eq!(largest_free, MB),
            other => panic!("expected Fragmented, got {other:?}"),
        }
    }

    #[test]
    fn search_length_grows_with_fragmentation() {
        let mut a = HbmAllocator::new(256 * MB);
        let ptrs: Vec<_> = (0..128).map(|_| a.alloc(MB).unwrap().ptr).collect();
        for (i, p) in ptrs.iter().enumerate() {
            if i % 2 == 0 {
                a.free(*p);
            }
        }
        // All holes are 1MB; a 1.5MB request walks all 64 holes + tail.
        let before = a.free_list_len();
        assert!(before > 60);
        let o = a.alloc(3 * MB / 2).unwrap();
        assert!(o.nodes_visited >= 60, "visited={}", o.nodes_visited);
    }

    #[test]
    fn coalescing_merges_neighbours() {
        let mut a = HbmAllocator::new(4 * MB);
        let p0 = a.alloc(MB).unwrap().ptr;
        let p1 = a.alloc(MB).unwrap().ptr;
        let p2 = a.alloc(MB).unwrap().ptr;
        a.free(p0);
        a.free(p2);
        // p2 coalesces with the tail: [hole@p0, hole@p2+tail].
        assert_eq!(a.free_list_len(), 2);
        a.free(p1); // merges all
        assert_eq!(a.free_list_len(), 1);
        assert_eq!(a.frag_stats().largest_free, 4 * MB);
    }

    #[test]
    fn double_free_detected() {
        let mut a = HbmAllocator::new(MB);
        let p = a.alloc(1024).unwrap().ptr;
        assert!(a.free(p).is_some());
        assert!(a.free(p).is_none());
    }

    #[test]
    fn compaction_restores_contiguity() {
        let mut a = HbmAllocator::new(64 * MB);
        let ptrs: Vec<_> = (0..32).map(|_| a.alloc(MB).unwrap().ptr).collect();
        for (i, p) in ptrs.iter().enumerate() {
            if i % 2 == 0 {
                a.free(*p);
            }
        }
        // 16 x 1MB holes + 32MB tail: frag = 1 - 32/48 = 1/3.
        assert!(a.frag_stats().fragmentation_index > 0.3);
        let (moved, reloc) = a.compact();
        assert!(moved > 0);
        assert!(!reloc.is_empty());
        let fs = a.frag_stats();
        assert_eq!(fs.free_blocks, 1);
        assert!((fs.fragmentation_index).abs() < 1e-12);
        // 16 x 1MB survivors now occupy the bottom 16MB.
        assert_eq!(a.used(), 16 * MB);
        assert!(a.is_live(0));
    }

    #[test]
    fn reset_clears_everything() {
        let mut a = HbmAllocator::new(MB);
        a.alloc(1024).unwrap();
        a.reset();
        assert_eq!(a.used(), 0);
        assert_eq!(a.live_allocations(), 0);
        assert_eq!(a.free_list_len(), 1);
    }
}
