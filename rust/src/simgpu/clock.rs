//! Virtual time. All simulated latencies are expressed in nanoseconds and
//! advance a per-run [`VirtualClock`], making measurements deterministic and
//! independent of host scheduling.

use std::cell::Cell;
use std::rc::Rc;

/// A shareable virtual clock counting nanoseconds since run start.
///
/// Cloning shares the underlying counter (`Rc<Cell<u64>>`), so a device and
/// its API front-ends observe a single timeline.
#[derive(Clone, Debug, Default)]
pub struct VirtualClock {
    now_ns: Rc<Cell<u64>>,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock::default()
    }

    /// Current virtual time in nanoseconds.
    #[inline]
    pub fn now_ns(&self) -> u64 {
        self.now_ns.get()
    }

    /// Advance by `ns` nanoseconds and return the new time.
    #[inline]
    pub fn advance(&self, ns: u64) -> u64 {
        let t = self.now_ns.get() + ns;
        self.now_ns.set(t);
        t
    }

    /// Advance by a (possibly fractional) nanosecond amount; fractional
    /// parts are rounded to the nearest nanosecond.
    #[inline]
    pub fn advance_f(&self, ns: f64) -> u64 {
        self.advance(ns.max(0.0).round() as u64)
    }

    /// Jump to an absolute time (used when joining parallel timelines:
    /// `max(now, t)`).
    #[inline]
    pub fn advance_to(&self, t_ns: u64) {
        if t_ns > self.now_ns.get() {
            self.now_ns.set(t_ns);
        }
    }
}

/// A stopwatch over the virtual clock, mirroring `clock_gettime` usage in
/// the paper's listings.
pub struct VirtualStopwatch {
    clock: VirtualClock,
    start_ns: u64,
}

impl VirtualStopwatch {
    pub fn start(clock: &VirtualClock) -> VirtualStopwatch {
        VirtualStopwatch { clock: clock.clone(), start_ns: clock.now_ns() }
    }

    /// Elapsed virtual nanoseconds since `start`.
    pub fn elapsed_ns(&self) -> u64 {
        self.clock.now_ns() - self.start_ns
    }

    /// Elapsed virtual microseconds (the unit most paper tables use).
    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_ns() as f64 / 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_advances() {
        let c = VirtualClock::new();
        assert_eq!(c.now_ns(), 0);
        c.advance(100);
        assert_eq!(c.now_ns(), 100);
        c.advance_f(0.6);
        assert_eq!(c.now_ns(), 101);
    }

    #[test]
    fn clones_share_timeline() {
        let a = VirtualClock::new();
        let b = a.clone();
        a.advance(42);
        assert_eq!(b.now_ns(), 42);
    }

    #[test]
    fn advance_to_is_monotonic() {
        let c = VirtualClock::new();
        c.advance(100);
        c.advance_to(50); // no-op
        assert_eq!(c.now_ns(), 100);
        c.advance_to(150);
        assert_eq!(c.now_ns(), 150);
    }

    #[test]
    fn stopwatch_measures_interval() {
        let c = VirtualClock::new();
        c.advance(10);
        let sw = VirtualStopwatch::start(&c);
        c.advance(4_200);
        assert_eq!(sw.elapsed_ns(), 4_200);
        assert!((sw.elapsed_us() - 4.2).abs() < 1e-9);
    }
}
