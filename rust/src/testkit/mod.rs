//! Property-testing mini-framework (proptest substitute for the offline
//! build): seeded generators + runners that report the failing seed and
//! shrink failing inputs toward a minimal counterexample.
//!
//! Layout:
//!
//! - [`check`] / [`check_with_shrink`] — the runners; the latter takes a
//!   candidate generator (see [`shrink`]) and greedily walks the failing
//!   input down before panicking.
//! - [`shrink`] — reusable candidate generators: sub-sequence drops for
//!   vectors, halvings for counters, axis drops for cluster grid specs,
//!   event-prefix truncation for trace timelines.
//! - [`gens`] — value generators: scalar helpers plus the cluster-domain
//!   generators (tenant demands, fleet churn timelines, whole
//!   [`crate::cluster::ClusterSpec`] grids) and the dynsim timeline
//!   generators (external traces, training-heavy scenarios).
//!
//! Used by `rust/tests/prop_*.rs` to check coordinator/substrate/fleet
//! invariants across randomized inputs.

use crate::util::Rng;

/// Number of cases per property by default.
pub const DEFAULT_CASES: usize = 256;

/// A generator of random values of `T`.
pub trait Gen<T> {
    fn generate(&self, rng: &mut Rng) -> T;
}

impl<T, F: Fn(&mut Rng) -> T> Gen<T> for F {
    fn generate(&self, rng: &mut Rng) -> T {
        self(rng)
    }
}

/// Run `prop` on `cases` random inputs from `gen`; panics with the seed
/// and case number on the first failure.
pub fn check<T: std::fmt::Debug, G: Gen<T>, P: Fn(&T) -> bool>(
    name: &str,
    seed: u64,
    cases: usize,
    gen: G,
    prop: P,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen.generate(&mut rng);
        if !prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed})\n  input: {input:?}"
            );
        }
    }
}

/// Like [`check`] but, on failure, greedily shrinks the failing input
/// through `candidates` — a generator of strictly simpler variants (see
/// [`shrink`]) — so the panic message carries a minimal counterexample.
pub fn check_with_shrink<T, G, P, S>(
    name: &str,
    seed: u64,
    cases: usize,
    gen: G,
    candidates: S,
    prop: P,
) where
    T: std::fmt::Debug + Clone,
    G: Gen<T>,
    P: Fn(&T) -> bool,
    S: Fn(&T) -> Vec<T>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen.generate(&mut rng);
        if !prop(&input) {
            let minimal = shrink_with(&input, &candidates, &prop);
            panic!(
                "property '{name}' failed at case {case} (seed {seed})\n  shrunk input: {minimal:?}"
            );
        }
    }
}

/// Greedy candidate-driven shrink: repeatedly move to the first proposed
/// candidate that still fails `prop`. Step-bounded, so candidate
/// generators need not be strictly decreasing.
pub fn shrink_with<T: Clone, P: Fn(&T) -> bool, S: Fn(&T) -> Vec<T>>(
    input: &T,
    candidates: &S,
    prop: &P,
) -> T {
    let mut cur = input.clone();
    for _ in 0..1000 {
        match candidates(&cur).into_iter().find(|c| !prop(c)) {
            Some(next) => cur = next,
            None => break,
        }
    }
    cur
}

/// Like [`check`] but shrinks a failing `Vec<u64>` input by halving and
/// element dropping before reporting.
pub fn check_vec_u64<P: Fn(&[u64]) -> bool>(
    name: &str,
    seed: u64,
    cases: usize,
    max_len: usize,
    max_val: u64,
    prop: P,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let len = rng.range(0, max_len + 1);
        let input: Vec<u64> = (0..len).map(|_| rng.below(max_val.max(1))).collect();
        if !prop(&input) {
            let minimal = shrink_vec(&input, &prop);
            panic!(
                "property '{name}' failed at case {case} (seed {seed})\n  shrunk input ({} of {} elems): {minimal:?}",
                minimal.len(),
                input.len()
            );
        }
    }
}

/// Greedy shrink: repeatedly try removing chunks while the property still
/// fails; return the smallest failing input found.
pub fn shrink_vec<T: Clone, P: Fn(&[T]) -> bool>(input: &[T], prop: &P) -> Vec<T> {
    let mut cur = input.to_vec();
    let mut chunk = (cur.len() / 2).max(1);
    while chunk >= 1 && !cur.is_empty() {
        let mut i = 0;
        let mut progressed = false;
        while i + chunk <= cur.len() {
            let mut candidate = cur.clone();
            candidate.drain(i..i + chunk);
            if !prop(&candidate) {
                cur = candidate;
                progressed = true;
            } else {
                i += chunk;
            }
        }
        if !progressed {
            if chunk == 1 {
                break;
            }
            chunk /= 2;
        }
    }
    cur
}

/// Candidate generators for [`check_with_shrink`]: each proposes
/// strictly simpler variants of a failing input, tried in order.
pub mod shrink {
    use crate::cluster::ClusterSpec;
    use crate::dynsim::ScenarioSpec;

    /// Sub-sequence candidates for a vector: the back half, the front
    /// half, then every single-element drop.
    pub fn vec_drops<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
        let mut out = Vec::new();
        if v.len() > 1 {
            out.push(v[v.len() / 2..].to_vec());
            out.push(v[..v.len() / 2].to_vec());
        }
        for i in 0..v.len() {
            let mut c = v.to_vec();
            c.remove(i);
            out.push(c);
        }
        out
    }

    /// Integer candidates: 1, then repeated halvings toward 1 (counters
    /// like node/arrival counts stay in their valid >= 1 ranges).
    pub fn halves(n: u32) -> Vec<u32> {
        let mut out = Vec::new();
        if n > 1 {
            out.push(1);
        }
        let mut h = n / 2;
        while h > 1 {
            out.push(h);
            h /= 2;
        }
        out
    }

    /// Cluster-grid candidates: drop one axis value at a time (keeping
    /// every axis non-empty) and halve the node/arrival counters — the
    /// shrinker paired with [`super::gens::cluster_spec`].
    pub fn cluster_spec(spec: &ClusterSpec) -> Vec<ClusterSpec> {
        let mut out = Vec::new();
        for a in halves(spec.arrivals) {
            let mut c = spec.clone();
            c.arrivals = a;
            out.push(c);
        }
        if spec.systems.len() > 1 {
            for i in 0..spec.systems.len() {
                let mut c = spec.clone();
                c.systems.remove(i);
                out.push(c);
            }
        }
        if spec.policies.len() > 1 {
            for i in 0..spec.policies.len() {
                let mut c = spec.clone();
                c.policies.remove(i);
                out.push(c);
            }
        }
        if spec.scenarios.len() > 1 {
            for i in 0..spec.scenarios.len() {
                let mut c = spec.clone();
                c.scenarios.remove(i);
                out.push(c);
            }
        }
        if spec.node_counts.len() > 1 {
            for i in 0..spec.node_counts.len() {
                let mut c = spec.clone();
                c.node_counts.remove(i);
                out.push(c);
            }
        } else if let Some(&n) = spec.node_counts.first() {
            for h in halves(n) {
                let mut c = spec.clone();
                c.node_counts = vec![h];
                out.push(c);
            }
        }
        out
    }

    /// Trace-timeline candidates: event-stream *prefixes* (half, then
    /// drop-last). Every prefix of a valid trace stays valid — the
    /// timestamp monotonicity and active-tenant rules only constrain a
    /// line against *earlier* lines — so the shrink walk never leaves
    /// the parseable set. Paired with [`super::gens::trace`].
    pub fn trace_events(spec: &ScenarioSpec) -> Vec<ScenarioSpec> {
        let n = spec.events.len();
        let mut keeps: Vec<usize> = Vec::new();
        if n > 1 {
            keeps.push(n / 2);
        }
        if n > 0 {
            keeps.push(n - 1);
        }
        keeps.dedup();
        keeps
            .into_iter()
            .map(|keep| {
                let mut c = spec.clone();
                c.events.truncate(keep);
                c
            })
            .collect()
    }
}

/// Common generators.
pub mod gens {
    use crate::cluster::{self, ClusterSpec, Demand, FleetEvent};
    use crate::dynsim::scenario::{
        EventKind, ScenarioSpec, TenantEvent, WorkloadKind, TRACE_SCENARIO,
    };
    use crate::simgpu::TenantId;
    use crate::util::Rng;
    use crate::virt::ALL_SYSTEMS;

    /// Allocation sizes: log-uniform across bytes..GiB.
    pub fn alloc_size(rng: &mut Rng) -> u64 {
        let exp = rng.f64_range(6.0, 30.0);
        (2f64).powf(exp) as u64
    }

    /// A fraction in (0, 1].
    pub fn fraction(rng: &mut Rng) -> f64 {
        rng.f64_range(0.01, 1.0)
    }

    /// A small tenant count 1..=8.
    pub fn tenants(rng: &mut Rng) -> u32 {
        rng.range(1, 9) as u32
    }

    /// A canonical dynsim scenario preset key.
    pub fn scenario(rng: &mut Rng) -> &'static str {
        *rng.choose(&crate::dynsim::PRESETS)
    }

    /// A canonical placement-policy key.
    pub fn policy(rng: &mut Rng) -> &'static str {
        *rng.choose(&cluster::POLICIES)
    }

    /// One tenant fleet demand: the cluster layer's own arrival
    /// distribution (1–16 GiB memory, 0.05–0.25 GPU SM share).
    pub fn demand(rng: &mut Rng) -> Demand {
        cluster::sample_demand(rng)
    }

    /// A fleet churn timeline: a random scenario preset shaped through
    /// the cluster layer's arrival model, up to `max_arrivals` arrivals
    /// on a random 1..=16-node fleet.
    pub fn fleet_timeline(rng: &mut Rng, max_arrivals: u32) -> Vec<FleetEvent> {
        let sc = scenario(rng);
        let nodes = rng.range(1, 17) as u32;
        let arrivals = rng.range(1, max_arrivals.max(1) as usize + 1) as u32;
        cluster::arrival_stream(sc, arrivals, nodes, rng)
    }

    /// A valid random cluster grid: non-empty subsets of every axis,
    /// 1..=16 nodes, `1..=max_arrivals` arrivals. Shrinks through
    /// [`super::shrink::cluster_spec`].
    pub fn cluster_spec(rng: &mut Rng, max_arrivals: u32) -> ClusterSpec {
        fn subset<T: Copy>(rng: &mut Rng, pool: &[T]) -> Vec<T> {
            let mut picked: Vec<T> = pool.iter().copied().filter(|_| rng.chance(0.5)).collect();
            if picked.is_empty() {
                picked.push(*rng.choose(pool));
            }
            picked
        }
        ClusterSpec {
            systems: subset(rng, &ALL_SYSTEMS).into_iter().map(str::to_string).collect(),
            policies: subset(rng, &cluster::POLICIES),
            node_counts: subset(rng, &[1u32, 2, 4, 8, 16]),
            scenarios: subset(rng, &crate::dynsim::PRESETS),
            arrivals: rng.range(1, max_arrivals.max(1) as usize + 1) as u32,
        }
    }

    /// A random valid external-trace timeline under the reserved
    /// [`TRACE_SCENARIO`] key: a small replayable geometry (2–5 windows
    /// of 10–50 ms), non-decreasing timestamps inside the horizon, a
    /// consistent tenant population (depart/burst/fail/request only
    /// name active tenants; departed ids may re-arrive), and mixed
    /// infer/train workloads — i.e. exactly the set
    /// [`crate::dynsim::parse_trace`] accepts. Shrinks through
    /// [`super::shrink::trace_events`].
    pub fn trace(rng: &mut Rng, max_events: usize) -> ScenarioSpec {
        let window_ms = *rng.choose(&[10u64, 20, 25, 50]);
        let duration_ms = window_ms * rng.range(2, 6) as u64;
        let n = rng.range(1, max_events.max(1) + 1);
        let mut events: Vec<TenantEvent> = Vec::with_capacity(n);
        let mut active: Vec<TenantId> = Vec::new();
        let mut departed: Vec<TenantId> = Vec::new();
        let mut next_tenant: TenantId = 1;
        let mut t = 0u64;
        for _ in 0..n {
            if rng.chance(0.6) {
                t = rng.range(t as usize, duration_ms as usize) as u64;
            }
            if active.is_empty() || rng.chance(0.4) {
                let tenant = if !departed.is_empty() && rng.chance(0.3) {
                    departed.swap_remove(rng.range(0, departed.len()))
                } else {
                    let id = next_tenant;
                    next_tenant += 1;
                    id
                };
                let workload =
                    if rng.chance(0.5) { WorkloadKind::Train } else { WorkloadKind::Infer };
                events.push(TenantEvent {
                    at_ms: t,
                    tenant,
                    kind: EventKind::Arrive {
                        rate_hz: rng.range(5, 61) as f64,
                        quota_pct: rng.range(10, 51) as u32,
                        workload,
                    },
                });
                active.push(tenant);
            } else {
                let i = rng.range(0, active.len());
                let tenant = active[i];
                let kind = match rng.range(0, 4) {
                    0 => {
                        active.swap_remove(i);
                        departed.push(tenant);
                        EventKind::Depart
                    }
                    1 => EventKind::Burst {
                        factor: rng.range(2, 5) as f64,
                        until_ms: t + window_ms,
                    },
                    2 => EventKind::Fail,
                    _ => EventKind::Request,
                };
                events.push(TenantEvent { at_ms: t, tenant, kind });
            }
        }
        ScenarioSpec { name: TRACE_SCENARIO, duration_ms, window_ms, events }
    }

    /// A random training-heavy timeline: 1–3 training tenants plus 0–2
    /// inference co-tenants, all arriving in the first half of a small
    /// horizon, sorted into timeline order. Always `has_training()`,
    /// and always renderable/parseable as a trace.
    pub fn training_spec(rng: &mut Rng) -> ScenarioSpec {
        let window_ms = *rng.choose(&[25u64, 50]);
        let duration_ms = window_ms * rng.range(3, 7) as u64;
        let mut events: Vec<TenantEvent> = Vec::new();
        let mut tenant: TenantId = 1;
        let trains = rng.range(1, 4);
        let infers = rng.range(0, 3);
        for _ in 0..trains {
            events.push(TenantEvent {
                at_ms: rng.range(0, (duration_ms / 2) as usize) as u64,
                tenant,
                kind: EventKind::Arrive {
                    rate_hz: rng.range(5, 31) as f64,
                    quota_pct: rng.range(20, 51) as u32,
                    workload: WorkloadKind::Train,
                },
            });
            tenant += 1;
        }
        for _ in 0..infers {
            events.push(TenantEvent {
                at_ms: rng.range(0, (duration_ms / 2) as usize) as u64,
                tenant,
                kind: EventKind::Arrive {
                    rate_hz: rng.range(20, 61) as f64,
                    quota_pct: rng.range(10, 31) as u32,
                    workload: WorkloadKind::Infer,
                },
            });
            tenant += 1;
        }
        events.sort_by_key(|e| (e.at_ms, e.tenant));
        ScenarioSpec { name: TRACE_SCENARIO, duration_ms, window_ms, events }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check("sum-commutes", 1, 64, |r: &mut Rng| (r.below(100), r.below(100)), |&(a, b)| {
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics_with_seed() {
        check("always-false", 2, 8, |r: &mut Rng| r.below(10), |_| false);
    }

    #[test]
    fn shrink_finds_minimal_counterexample() {
        // Property: "no element equals 7" — fails iff input contains 7.
        let prop = |v: &[u64]| !v.contains(&7);
        let shrunk = shrink_vec(&[1, 2, 7, 3, 7, 4], &prop);
        assert_eq!(shrunk, vec![7]);
    }

    #[test]
    fn shrink_keeps_failing_invariant() {
        let prop = |v: &[u64]| v.iter().sum::<u64>() < 10;
        let input = vec![5, 5, 5, 5];
        let shrunk = shrink_vec(&input, &prop);
        assert!(!prop(&shrunk));
        assert!(shrunk.len() <= input.len());
    }

    #[test]
    #[should_panic(expected = "shrunk input: [7]")]
    fn check_with_shrink_reports_minimal_vector() {
        check_with_shrink(
            "no-sevens",
            3,
            16,
            |r: &mut Rng| {
                // Exactly one 7 amid 0..=6 noise, at a random position.
                let mut v: Vec<u64> = (0..r.range(0, 19)).map(|_| r.below(7)).collect();
                let at = r.range(0, v.len() + 1);
                v.insert(at, 7);
                v
            },
            |v| shrink::vec_drops(v),
            |v| !v.contains(&7),
        );
    }

    #[test]
    fn shrink_with_walks_candidates_to_a_fixpoint() {
        // Property: "n < 3" — fails for large n; halvings bottom out at
        // the smallest still-failing value reachable through /2 steps.
        let min = shrink_with(&1000u32, &|&n: &u32| shrink::halves(n), &|&n: &u32| n < 3);
        assert!(min < 1000 && min >= 3, "{min}");
        assert!(shrink::halves(min).iter().all(|&c| c < 3), "{min} not minimal");
    }

    #[test]
    fn halves_stay_in_valid_counter_range() {
        assert!(shrink::halves(1).is_empty());
        for n in [2u32, 7, 1000] {
            let cs = shrink::halves(n);
            assert!(!cs.is_empty());
            assert!(cs.iter().all(|&c| c >= 1 && c < n), "{cs:?}");
        }
    }

    #[test]
    fn cluster_spec_gen_and_shrinker_stay_valid() {
        let mut rng = Rng::new(11);
        for _ in 0..50 {
            let spec = gens::cluster_spec(&mut rng, 64);
            assert!(!spec.systems.is_empty() && !spec.policies.is_empty());
            assert!(!spec.node_counts.is_empty() && !spec.scenarios.is_empty());
            assert!((1..=64).contains(&spec.arrivals));
            for c in shrink::cluster_spec(&spec) {
                // Every candidate is itself a valid, strictly simpler grid.
                assert!(!c.systems.is_empty() && !c.policies.is_empty());
                assert!(!c.node_counts.is_empty() && !c.scenarios.is_empty());
                assert!(c.arrivals >= 1);
                let size = |s: &crate::cluster::ClusterSpec| {
                    s.systems.len() * s.policies.len() * s.node_counts.len() * s.scenarios.len()
                };
                assert!(
                    size(&c) < size(&spec)
                        || c.arrivals < spec.arrivals
                        || c.node_counts < spec.node_counts,
                    "candidate {c:?} no simpler than {spec:?}"
                );
            }
        }
    }

    #[test]
    fn fleet_timeline_gen_arrivals_bounded_and_well_formed() {
        use crate::cluster::FleetEvent;
        let mut rng = Rng::new(12);
        for _ in 0..50 {
            let tl = gens::fleet_timeline(&mut rng, 40);
            let arrivals =
                tl.iter().filter(|e| matches!(e, FleetEvent::Arrive { .. })).count();
            assert!((1..=40).contains(&arrivals));
            // Departures only reference tenants that already arrived.
            let mut seen = std::collections::HashSet::new();
            for ev in &tl {
                match ev {
                    FleetEvent::Arrive { tenant, .. } => {
                        assert!(seen.insert(*tenant), "duplicate arrival {tenant}");
                    }
                    FleetEvent::Depart { tenant } => {
                        assert!(seen.contains(tenant), "departure before arrival");
                    }
                    FleetEvent::Fail { .. } => {}
                }
            }
        }
    }

    #[test]
    fn trace_gen_emits_parseable_traces_and_prefix_shrinks_stay_valid() {
        use crate::dynsim::{parse_trace, render_trace, TRACE_SCENARIO};
        let mut rng = Rng::new(13);
        for _ in 0..50 {
            let spec = gens::trace(&mut rng, 12);
            assert_eq!(spec.name, TRACE_SCENARIO);
            assert!(!spec.events.is_empty());
            assert!(spec.events.windows(2).all(|w| w[0].at_ms <= w[1].at_ms));
            // Generated specs live exactly in the parser's accepted set…
            let parsed = parse_trace(&render_trace(&spec)).unwrap();
            assert_eq!(parsed, spec);
            // …and so does every prefix candidate the shrinker proposes.
            for c in shrink::trace_events(&spec) {
                assert!(c.events.len() < spec.events.len());
                assert_eq!(parse_trace(&render_trace(&c)).unwrap(), c);
            }
        }
    }

    #[test]
    fn training_spec_gen_always_carries_training() {
        use crate::dynsim::{parse_trace, render_trace};
        let mut rng = Rng::new(14);
        for _ in 0..50 {
            let spec = gens::training_spec(&mut rng);
            assert!(spec.has_training());
            assert!(spec.windows() >= 3);
            assert!(spec.events.windows(2).all(|w| w[0].at_ms <= w[1].at_ms));
            assert_eq!(parse_trace(&render_trace(&spec)).unwrap(), spec);
        }
    }

    #[test]
    fn generators_in_range() {
        let mut rng = crate::util::Rng::new(5);
        for _ in 0..200 {
            let s = gens::alloc_size(&mut rng);
            assert!(s >= 64 && s <= (1 << 30));
            let f = gens::fraction(&mut rng);
            assert!(f > 0.0 && f <= 1.0);
            let t = gens::tenants(&mut rng);
            assert!((1..=8).contains(&t));
        }
    }
}
