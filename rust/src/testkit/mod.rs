//! Property-testing mini-framework (proptest substitute for the offline
//! build): seeded generators + a runner that reports the failing seed and
//! attempts input shrinking for integer-vector cases.
//!
//! Used by `rust/tests/prop_*.rs` to check coordinator/substrate
//! invariants across randomized inputs.

use crate::util::Rng;

/// Number of cases per property by default.
pub const DEFAULT_CASES: usize = 256;

/// A generator of random values of `T`.
pub trait Gen<T> {
    fn generate(&self, rng: &mut Rng) -> T;
}

impl<T, F: Fn(&mut Rng) -> T> Gen<T> for F {
    fn generate(&self, rng: &mut Rng) -> T {
        self(rng)
    }
}

/// Run `prop` on `cases` random inputs from `gen`; panics with the seed
/// and case number on the first failure.
pub fn check<T: std::fmt::Debug, G: Gen<T>, P: Fn(&T) -> bool>(
    name: &str,
    seed: u64,
    cases: usize,
    gen: G,
    prop: P,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen.generate(&mut rng);
        if !prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed})\n  input: {input:?}"
            );
        }
    }
}

/// Like [`check`] but shrinks a failing `Vec<u64>` input by halving and
/// element dropping before reporting.
pub fn check_vec_u64<P: Fn(&[u64]) -> bool>(
    name: &str,
    seed: u64,
    cases: usize,
    max_len: usize,
    max_val: u64,
    prop: P,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let len = rng.range(0, max_len + 1);
        let input: Vec<u64> = (0..len).map(|_| rng.below(max_val.max(1))).collect();
        if !prop(&input) {
            let minimal = shrink_vec(&input, &prop);
            panic!(
                "property '{name}' failed at case {case} (seed {seed})\n  shrunk input ({} of {} elems): {minimal:?}",
                minimal.len(),
                input.len()
            );
        }
    }
}

/// Greedy shrink: repeatedly try removing chunks while the property still
/// fails; return the smallest failing input found.
pub fn shrink_vec<P: Fn(&[u64]) -> bool>(input: &[u64], prop: &P) -> Vec<u64> {
    let mut cur = input.to_vec();
    let mut chunk = (cur.len() / 2).max(1);
    while chunk >= 1 && !cur.is_empty() {
        let mut i = 0;
        let mut progressed = false;
        while i + chunk <= cur.len() {
            let mut candidate = cur.clone();
            candidate.drain(i..i + chunk);
            if !prop(&candidate) {
                cur = candidate;
                progressed = true;
            } else {
                i += chunk;
            }
        }
        if !progressed {
            if chunk == 1 {
                break;
            }
            chunk /= 2;
        }
    }
    cur
}

/// Common generators.
pub mod gens {
    use crate::util::Rng;

    /// Allocation sizes: log-uniform across bytes..GiB.
    pub fn alloc_size(rng: &mut Rng) -> u64 {
        let exp = rng.f64_range(6.0, 30.0);
        (2f64).powf(exp) as u64
    }

    /// A fraction in (0, 1].
    pub fn fraction(rng: &mut Rng) -> f64 {
        rng.f64_range(0.01, 1.0)
    }

    /// A small tenant count 1..=8.
    pub fn tenants(rng: &mut Rng) -> u32 {
        rng.range(1, 9) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check("sum-commutes", 1, 64, |r: &mut Rng| (r.below(100), r.below(100)), |&(a, b)| {
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics_with_seed() {
        check("always-false", 2, 8, |r: &mut Rng| r.below(10), |_| false);
    }

    #[test]
    fn shrink_finds_minimal_counterexample() {
        // Property: "no element equals 7" — fails iff input contains 7.
        let prop = |v: &[u64]| !v.contains(&7);
        let shrunk = shrink_vec(&[1, 2, 7, 3, 7, 4], &prop);
        assert_eq!(shrunk, vec![7]);
    }

    #[test]
    fn shrink_keeps_failing_invariant() {
        let prop = |v: &[u64]| v.iter().sum::<u64>() < 10;
        let input = vec![5, 5, 5, 5];
        let shrunk = shrink_vec(&input, &prop);
        assert!(!prop(&shrunk));
        assert!(shrunk.len() <= input.len());
    }

    #[test]
    fn generators_in_range() {
        let mut rng = crate::util::Rng::new(5);
        for _ in 0..200 {
            let s = gens::alloc_size(&mut rng);
            assert!(s >= 64 && s <= (1 << 30));
            let f = gens::fraction(&mut rng);
            assert!(f > 0.0 && f <= 1.0);
            let t = gens::tenants(&mut rng);
            assert!((1..=8).contains(&t));
        }
    }
}
