//! Argument parsing for `gvbench`.

use std::fmt;

/// Usage text (also serves as the CLI reference in README).
pub const USAGE: &str = "\
GPU-Virt-Bench — benchmarking framework for GPU virtualization systems

USAGE:
  gvbench run [--system <native|hami|fcsp|mig>] [--all-systems]
              [--category <key>] [--metric <ID>] [--iterations N]
              [--warmup N] [--tenants N] [--seed N] [--jobs N] [--quick]
              [--config <file>] [--format <txt|json|csv>] [--out <file>]
              [--trace-out <file>]
  gvbench sweep [--system S | --systems S,S,...|all | --all-systems]
              [--tenants N,N,...]
              [--quota PCT,PCT,...] [--gpus N,N,...] [--link nvlink,pcie]
              [--category key,key,...]
              [--iterations N] [--warmup N] [--seed N] [--jobs N] [--quick]
              [--config <file>] [--format <txt|json|csv>] [--out <file>]
              [--trace-out <file>]
  gvbench dynamics [--scenario steady,churn,spike,failover,train-steady,mixed-churn]
              [--trace <file>]
              [--system S | --systems S,S,...|all | --all-systems]
              [--duration-ms N] [--window-ms N] [--seed N] [--jobs N]
              [--config <file>] [--format <txt|json|csv>] [--out <file>]
              [--summary-out <file>] [--trace-out <file>]
              [--export-trace <file>]
  gvbench cluster [--policies first-fit,best-fit,frag-gradient]
              [--nodes N,N,...] [--arrivals N]
              [--scenario steady,churn,spike,failover]
              [--system S | --systems S,S,...|all | --all-systems]
              [--seed N] [--jobs N]
              [--config <file>] [--format <txt|json|csv>] [--out <file>]
              [--summary-out <file>] [--trace-out <file>]
  gvbench list [--full | --systems | --categories]
  gvbench compare [--quick] [--jobs N]  # Table 7: overall scores, all systems
  gvbench regress --baseline <csv> [--system S] [--threshold PCT] [--quick]
              [--trace <file>] [--jobs N]
              [--report-json <file>] [--report-md <file>]
  gvbench serve [--socket <path>] [--jobs N]
  gvbench submit [--socket <path>] [--priority N] [--out <file>]
              (--spec-file <file> | -- <run|sweep|dynamics|cluster|regress> ...)
  gvbench jobs [--socket <path>] [--shutdown | --stats]
              [--stats-format <table|prometheus>]
  gvbench help

EXAMPLES:
  gvbench run --system hami --category overhead
  gvbench run --all-systems --quick --format json --out results.json
  gvbench run --all-systems --jobs 8      # shard the matrix over 8 workers
  gvbench sweep --tenants 1,2,4,8 --quota 25,50,100 --jobs 8 --format csv
  gvbench sweep --gpus 2,4,8 --link nvlink,pcie --category nccl --quick
  gvbench sweep --category isolation,fragmentation --quick
  gvbench dynamics --scenario churn,failover --systems hami,fcsp --jobs 8
  gvbench dynamics --scenario train-steady,mixed-churn --summary-out s.csv
  gvbench dynamics --trace ci/trace_mixed.txt --systems hami,fcsp --jobs 8
  gvbench dynamics --duration-ms 2000 --window-ms 200 --format csv --out dyn.csv
  gvbench cluster --policies first-fit,frag-gradient --nodes 8,16 --jobs 8
  gvbench cluster --scenario churn --arrivals 5000 --format csv --out fleet.csv
  gvbench compare --quick
  gvbench serve --socket /tmp/gvb.sock --jobs 8     # warm benchmark daemon
  gvbench submit --socket /tmp/gvb.sock -- sweep --tenants 1,2 --format csv
  gvbench jobs --socket /tmp/gvb.sock --stats-format prometheus
  gvbench jobs --socket /tmp/gvb.sock --shutdown
  gvbench dynamics --scenario mixed-churn --trace-out trace.json  # Perfetto
  gvbench dynamics --scenario churn --export-trace churn.txt      # fixture

Scenario sweeps: `sweep` expands (systems x tenants x quota x gpus x
link x metrics) into one executor task list; quota is the percent of the
whole device each tenant gets (memory + SM), and --gpus/--link select
the simulated multi-GPU node the NCCL/P2P and PCIe metrics run on.
Defaults: all systems, tenants 1,2,4,8, quota 25,50,100, one 4-GPU PCIe
node. Every cell reports its score delta vs the (1 tenant, 100%)
baseline cell of its own topology. Topology axes multiply the whole
grid but only the NCCL/P2P and PCIe categories read them — scope
topology sweeps with --category nccl,pcie unless you want the full
taxonomy re-measured per node. A config file `[sweep]` section
(tenants/quota/gpus/link/systems/categories keys) sets the grid; CLI
flags override it.

Dynamic scenarios: `dynamics` replays virtual-time tenant timelines
(arrive / depart / burst / fail events driving per-tenant LLM request
streams or paced training jobs) against each system and reports
*windowed time series*: latency p50/p99, throughput, per-tenant SM/
memory occupancy, fragmentation ratio and fault recovery time.
Scenarios are named presets (steady, churn, spike, failover,
train-steady, mixed-churn; default: all six) on a --duration-ms
horizon (default 1000) cut into --window-ms windows (default 100) —
or one external trace file (--trace FILE): line-oriented
`at <ms> <arrive|depart|burst|fail|request> <tenant> ...` events under
`duration-ms`/`window-ms` headers (see docs/dynamics.md), replayed
bit-identically at any --jobs count. The trace carries its own
timeline and geometry, so --trace excludes --scenario, --duration-ms
and --window-ms. --out writes the long-format time series in --format;
--summary-out writes the per-scenario summary CSV (steady-state p99,
worst-window degradation, mean throughput, recovery time — plus
train-step p99, allreduce latency and train/infer interference on
timelines with training tenants) — a regress-gateable baseline. A
config file `[dynsim]` section (scenarios/duration_ms/window_ms/
systems keys) sets the grid; CLI flags override it.

Cluster placement: `cluster` raises the unit of measurement to an
N-node fleet. Each (system x policy x nodes x scenario) cell replays a
churn timeline of --arrivals tenant arrivals (default 1000), placing
every arrival through the named policy (first-fit, best-fit,
frag-gradient; default: all three) on --nodes fleet sizes (default 8),
and reports allocation success rate, fleet fragmentation, utilization
imbalance and migration/eviction counts. --out writes the long-format
per-node CSV in --format; --summary-out writes the per-cell summary
CSV — a regress-gateable baseline keyed by (system, policy, nodes,
scenario, id). Regress replays always use the default arrival count,
so write summary baselines at it. A config file `[cluster]` section
(policies/nodes/scenarios/arrivals/systems keys) sets the grid; CLI
flags override it.

Regression gate: `regress` re-runs every cell in the baseline CSV (all
systems in the file, or just --system S) sharded across --jobs workers,
and exits 1 if any metric moved against its direction by more than
--threshold percent. The baseline schema is auto-detected: a `gvbench
run --format csv` table re-runs at this invocation's operating point,
a `gvbench sweep --format csv` surface re-runs every
(system, tenants, quota, gpus, link) cell with the sweep's own quota
mapping, node topology and seed derivation (`feasible=false` cells are
skipped; PR-3-era baselines without gpu_count/link columns re-run on
the default 4-GPU PCIe node), a `gvbench dynamics --summary-out`
summary replays each (system, scenario) timeline with the producing
run's seed derivation (rows recorded from a `--trace` replay need the
same trace file re-supplied via `regress --trace FILE`), and a
`gvbench cluster --summary-out` summary
replays each (system, policy, nodes, scenario) fleet cell at the
default arrival count. --report-json and --report-md write
machine-readable reports (per-cell deltas / a GitHub-flavored summary
of the worst regressions per system and per link kind).

Benchmark service: `serve` runs the framework as a daemon owning one
persistent executor worker pool (--jobs, fixed for the daemon's
lifetime) and a FIFO-with-priorities job queue, listening on a local
Unix socket (default: <temp-dir>/gvbench.sock). `submit` sends the argv
of any one-shot invocation (run/sweep/dynamics/cluster/regress; file
outputs, --config and --jobs are refused) as one job — inline after
`--`, or one token per line via --spec-file (# comments and blank lines
skipped) — streams its NDJSON lifecycle events (queued / scheduled /
task_completed / report / finished|failed, with queue-wait,
scheduler-idle and worker-idle accounting) to stderr, and writes the
report to --out or stdout. Exit status follows the job, including the
gate verdict of served regress jobs. `jobs` lists the daemon's jobs;
`jobs --shutdown` drains already-accepted jobs and stops the daemon.
A served report is byte-identical to its one-shot CLI equivalent.

Observability: --trace-out FILE writes a Chrome trace-event JSON file
(open in Perfetto / chrome://tracing). Under dynamics/cluster the
trace is on the replay's virtual clock — one process per (system,
scenario) task, one thread lane per tenant (or fleet node) — and is
byte-identical at any --jobs count; under run/sweep it records the
executor's wall-clock worker lanes, which (like the JSON `execution`
object) are host timings and never byte-stable. `dynamics
--export-trace FILE` renders one preset's timeline (exactly one
--scenario) into the editable trace format --trace replays, without
running anything. `jobs --stats` asks a serve daemon for its telemetry
counters (queue depth, jobs by state, queue-wait / idle / throughput
histograms); --stats-format prometheus emits text exposition format
for scraping. See docs/observability.md.

Parallelism: --jobs N shards the task matrix across N worker threads
(0 or unset = all cores). Same --seed => bit-identical numbers at any job
count, for `run` and `sweep` alike — and under `serve`, at any daemon
pool size and in any queue order.
";

/// Parsed command line.
#[derive(Clone, Debug, PartialEq)]
pub enum Command {
    Run,
    Sweep,
    Dynamics,
    Cluster,
    List,
    Compare,
    Regress,
    Serve,
    Submit,
    Jobs,
    Help,
}

#[derive(Clone, Debug)]
pub struct Args {
    pub command: Command,
    pub system: String,
    /// True when `--system` was passed explicitly (vs the default); sweep
    /// and regress use this to distinguish "restrict to S" from "all".
    pub system_set: bool,
    pub all_systems: bool,
    pub category: Option<String>,
    pub metric: Option<String>,
    pub iterations: Option<usize>,
    pub warmup: Option<usize>,
    pub tenants: Option<u32>,
    pub seed: Option<u64>,
    pub jobs: Option<usize>,
    pub quick: bool,
    pub config: Option<String>,
    pub format: String,
    pub out: Option<String>,
    pub list_full: bool,
    pub list_systems: bool,
    pub list_categories: bool,
    pub baseline: Option<String>,
    pub threshold: f64,
    /// `regress`: write the JSON regression report here.
    pub report_json: Option<String>,
    /// `regress`: write the markdown regression summary here.
    pub report_md: Option<String>,
    /// Sweep grid: tenant counts (`--tenants 1,2,4` under `sweep`).
    pub sweep_tenants: Option<Vec<u32>>,
    /// Sweep grid: per-tenant quota percents (`--quota 25,50,100`).
    pub sweep_quotas: Option<Vec<u32>>,
    /// Sweep grid: node GPU counts (`--gpus 2,4,8`).
    pub sweep_gpus: Option<Vec<u32>>,
    /// Sweep grid: node link kinds (`--link nvlink,pcie`).
    pub sweep_links: Option<Vec<String>>,
    /// Sweep grid: explicit system list (`--systems hami,fcsp`;
    /// `--systems all` sets `all_systems` instead).
    pub sweep_systems: Option<Vec<String>>,
    /// Sweep grid: category keys (`--category isolation,fragmentation`).
    pub sweep_categories: Option<Vec<String>>,
    /// Dynamics/cluster grid: scenario preset keys (`--scenario churn,spike`).
    pub dyn_scenarios: Option<Vec<String>>,
    /// Dynamics grid: timeline horizon (`--duration-ms 2000`).
    pub duration_ms: Option<u64>,
    /// Dynamics grid: reporting window (`--window-ms 200`).
    pub window_ms: Option<u64>,
    /// `dynamics`/`regress`: external trace timeline file (`--trace
    /// FILE`). The file's headers carry the geometry, so it excludes
    /// `--scenario`/`--duration-ms`/`--window-ms` under `dynamics`.
    pub trace: Option<String>,
    /// `dynamics`/`cluster`: write the regress-compatible summary CSV here.
    pub summary_out: Option<String>,
    /// Cluster grid: placement policy keys (`--policies first-fit,best-fit`).
    pub cluster_policies: Option<Vec<String>>,
    /// Cluster grid: fleet sizes in nodes (`--nodes 8,16`).
    pub cluster_nodes: Option<Vec<u32>>,
    /// Cluster grid: tenant arrivals per replay (`--arrivals 5000`).
    pub arrivals: Option<u32>,
    /// `serve`/`submit`/`jobs`: daemon socket path (`--socket`; default
    /// `<temp-dir>/gvbench.sock`).
    pub socket: Option<String>,
    /// `submit`: queue priority, higher runs first (`--priority`,
    /// -1000..=1000, default 0; FIFO within a level).
    pub priority: i64,
    /// `submit`: file holding the job argv, one token per line
    /// (`--spec-file`; `#` comments and blank lines skipped).
    pub spec_file: Option<String>,
    /// `jobs --shutdown`: ask the daemon to drain and exit.
    pub shutdown: bool,
    /// `submit`: inline job argv captured after `--`.
    pub job_argv: Option<Vec<String>>,
    /// `run`/`sweep`/`dynamics`/`cluster`: write a Chrome trace-event
    /// JSON file here (`--trace-out`). Virtual-time spans under
    /// dynamics/cluster; wall-clock executor lanes under run/sweep.
    pub trace_out: Option<String>,
    /// `dynamics --export-trace FILE`: render the (single) selected
    /// preset's timeline as an editable trace file and exit without
    /// replaying anything.
    pub export_trace: Option<String>,
    /// `jobs --stats`: ask the daemon for its telemetry counters.
    pub stats: bool,
    /// `jobs --stats-format <table|prometheus>`; implies `--stats`.
    pub stats_format: Option<String>,
}

impl Default for Args {
    fn default() -> Args {
        Args {
            command: Command::Help,
            system: "hami".to_string(),
            system_set: false,
            all_systems: false,
            category: None,
            metric: None,
            iterations: None,
            warmup: None,
            tenants: None,
            seed: None,
            jobs: None,
            quick: false,
            config: None,
            format: "txt".to_string(),
            out: None,
            list_full: false,
            list_systems: false,
            list_categories: false,
            baseline: None,
            threshold: 10.0,
            report_json: None,
            report_md: None,
            sweep_tenants: None,
            sweep_quotas: None,
            sweep_gpus: None,
            sweep_links: None,
            sweep_systems: None,
            sweep_categories: None,
            dyn_scenarios: None,
            duration_ms: None,
            window_ms: None,
            trace: None,
            summary_out: None,
            cluster_policies: None,
            cluster_nodes: None,
            arrivals: None,
            socket: None,
            priority: 0,
            spec_file: None,
            shutdown: false,
            job_argv: None,
            trace_out: None,
            export_trace: None,
            stats: false,
            stats_format: None,
        }
    }
}

/// Parse failure.
#[derive(Debug, PartialEq)]
pub struct ParseError(pub String);

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for ParseError {}

fn err(msg: impl Into<String>) -> ParseError {
    ParseError(msg.into())
}

/// Parse a comma-separated u32 list flag value (`1,2,4`).
fn parse_u32_list(flag: &str, v: &str) -> Result<Vec<u32>, ParseError> {
    let xs: Result<Vec<u32>, _> = v.split(',').map(|s| s.trim().parse::<u32>()).collect();
    match xs {
        Ok(xs) if !xs.is_empty() => Ok(xs),
        _ => Err(err(format!("bad {flag} list `{v}` (expected e.g. 1,2,4)"))),
    }
}

/// Range checks shared by the CLI flags and config-file `[sweep]` grids:
/// tenant counts in 1..=64, quota percents in 1..=100, node GPU counts in
/// 1..=16 (matching the baseline parser's acceptance ranges).
pub fn validate_sweep_grid(
    tenants: Option<&[u32]>,
    quotas: Option<&[u32]>,
    gpus: Option<&[u32]>,
) -> Result<(), String> {
    if let Some(ts) = tenants {
        for &t in ts {
            if !(1..=64).contains(&t) {
                return Err(format!("--tenants value {t} out of range (1..=64)"));
            }
        }
    }
    if let Some(qs) = quotas {
        for &q in qs {
            if !(1..=100).contains(&q) {
                return Err(format!("--quota value {q} out of range (1..=100)"));
            }
        }
    }
    if let Some(gs) = gpus {
        for &g in gs {
            if !(1..=16).contains(&g) {
                return Err(format!("--gpus value {g} out of range (1..=16)"));
            }
        }
    }
    Ok(())
}

/// Validate `--link` / `[sweep] link` keys against the known link kinds.
pub fn validate_sweep_links(links: Option<&[String]>) -> Result<(), String> {
    if let Some(ls) = links {
        for l in ls {
            if crate::simgpu::nvlink::LinkKind::from_key(l).is_none() {
                return Err(format!("unknown link kind `{l}` (expected nvlink, pcie)"));
            }
        }
    }
    Ok(())
}

/// Range/name checks shared by the `cluster` CLI flags and config-file
/// `[cluster]` grids: policy names must be known placement policies,
/// node counts fit 1..=1024 (matching the cluster baseline parser's
/// acceptance range), and the arrival count fits 1..=100000.
pub fn validate_cluster_grid(
    policies: Option<&[String]>,
    nodes: Option<&[u32]>,
    arrivals: Option<u32>,
) -> Result<(), String> {
    if let Some(ps) = policies {
        if ps.is_empty() {
            return Err("--policies list is empty".to_string());
        }
        for p in ps {
            if crate::cluster::canonical_policy(p).is_none() {
                return Err(format!(
                    "unknown placement policy `{p}` (expected: first-fit, best-fit, frag-gradient)"
                ));
            }
        }
    }
    if let Some(ns) = nodes {
        if ns.is_empty() {
            return Err("--nodes list is empty".to_string());
        }
        for &n in ns {
            if !(1..=1024).contains(&n) {
                return Err(format!("--nodes value {n} out of range (1..=1024)"));
            }
        }
    }
    if let Some(a) = arrivals {
        if !(1..=100_000).contains(&a) {
            return Err(format!("--arrivals value {a} out of range (1..=100000)"));
        }
    }
    Ok(())
}

/// Range/name checks shared by the `dynamics` CLI flags and config-file
/// `[dynsim]` grids: scenario names must be known presets, the horizon
/// fits 1 ms..=1 h, and the window fits inside the horizon (matching the
/// dynamics baseline parser's acceptance ranges).
pub fn validate_dynamics_grid(
    scenarios: Option<&[String]>,
    duration_ms: Option<u64>,
    window_ms: Option<u64>,
) -> Result<(), String> {
    if let Some(ss) = scenarios {
        if ss.is_empty() {
            return Err("--scenario list is empty".to_string());
        }
        for s in ss {
            if crate::dynsim::scenario::canonical(s).is_none() {
                return Err(format!(
                    "unknown scenario `{s}` (expected: steady, churn, spike, failover, \
                     train-steady, mixed-churn)"
                ));
            }
        }
    }
    if let Some(d) = duration_ms {
        if !(1..=3_600_000).contains(&d) {
            return Err(format!("--duration-ms value {d} out of range (1..=3600000)"));
        }
    }
    if let Some(w) = window_ms {
        if w == 0 {
            return Err("--window-ms must be at least 1".to_string());
        }
        if let Some(d) = duration_ms {
            if w > d {
                return Err(format!(
                    "--window-ms value {w} exceeds the --duration-ms horizon {d}"
                ));
            }
        }
    }
    Ok(())
}

impl Args {
    /// Parse argv (without the program name).
    pub fn parse(argv: &[String]) -> Result<Args, ParseError> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        args.command = match it.next().map(|s| s.as_str()) {
            Some("run") => Command::Run,
            Some("sweep") => Command::Sweep,
            Some("dynamics") => Command::Dynamics,
            Some("cluster") => Command::Cluster,
            Some("list") => Command::List,
            Some("compare") => Command::Compare,
            Some("regress") => Command::Regress,
            Some("serve") => Command::Serve,
            Some("submit") => Command::Submit,
            Some("jobs") => Command::Jobs,
            Some("help") | Some("--help") | Some("-h") | None => Command::Help,
            Some(other) => return Err(err(format!("unknown command `{other}`"))),
        };
        let next_value = |it: &mut std::iter::Peekable<std::slice::Iter<String>>,
                              flag: &str|
         -> Result<String, ParseError> {
            it.next().cloned().ok_or_else(|| err(format!("{flag} requires a value")))
        };
        while let Some(flag) = it.next() {
            match flag.as_str() {
                "--" => {
                    if args.command != Command::Submit {
                        return Err(err("a `--` job argv is only valid for `gvbench submit`"));
                    }
                    args.job_argv = Some(it.by_ref().cloned().collect());
                }
                "--socket" => {
                    if !matches!(args.command, Command::Serve | Command::Submit | Command::Jobs) {
                        return Err(err(
                            "--socket is only valid for `gvbench serve`, `gvbench submit` or \
                             `gvbench jobs`",
                        ));
                    }
                    args.socket = Some(next_value(&mut it, flag)?);
                }
                "--priority" => {
                    if args.command != Command::Submit {
                        return Err(err("--priority is only valid for `gvbench submit`"));
                    }
                    let p: i64 =
                        next_value(&mut it, flag)?.parse().map_err(|_| err("bad --priority"))?;
                    if !(-1000..=1000).contains(&p) {
                        return Err(err(format!(
                            "--priority value {p} out of range (-1000..=1000)"
                        )));
                    }
                    args.priority = p;
                }
                "--spec-file" => {
                    if args.command != Command::Submit {
                        return Err(err("--spec-file is only valid for `gvbench submit`"));
                    }
                    args.spec_file = Some(next_value(&mut it, flag)?);
                }
                "--shutdown" => {
                    if args.command != Command::Jobs {
                        return Err(err("--shutdown is only valid for `gvbench jobs`"));
                    }
                    args.shutdown = true;
                }
                "--stats" => {
                    if args.command != Command::Jobs {
                        return Err(err("--stats is only valid for `gvbench jobs`"));
                    }
                    args.stats = true;
                }
                "--stats-format" => {
                    if args.command != Command::Jobs {
                        return Err(err("--stats-format is only valid for `gvbench jobs`"));
                    }
                    let v = next_value(&mut it, flag)?;
                    if !matches!(v.as_str(), "table" | "prometheus") {
                        return Err(err(format!(
                            "unknown stats format `{v}` (expected table, prometheus)"
                        )));
                    }
                    args.stats = true;
                    args.stats_format = Some(v);
                }
                "--trace-out" => {
                    if !matches!(
                        args.command,
                        Command::Run | Command::Sweep | Command::Dynamics | Command::Cluster
                    ) {
                        return Err(err(
                            "--trace-out is only valid for `gvbench run`, `gvbench sweep`, \
                             `gvbench dynamics` or `gvbench cluster`",
                        ));
                    }
                    args.trace_out = Some(next_value(&mut it, flag)?);
                }
                "--export-trace" => {
                    if args.command != Command::Dynamics {
                        return Err(err("--export-trace is only valid for `gvbench dynamics`"));
                    }
                    args.export_trace = Some(next_value(&mut it, flag)?);
                }
                "--system" => {
                    args.system = next_value(&mut it, flag)?;
                    args.system_set = true;
                }
                "--all-systems" => args.all_systems = true,
                "--category" => {
                    let v = next_value(&mut it, flag)?;
                    if args.command == Command::Sweep {
                        // Sweeps take a comma-separated category list.
                        args.sweep_categories =
                            Some(v.split(',').map(|s| s.trim().to_string()).collect());
                    } else {
                        args.category = Some(v);
                    }
                }
                "--metric" => args.metric = Some(next_value(&mut it, flag)?),
                "--scenario" => {
                    if !matches!(args.command, Command::Dynamics | Command::Cluster) {
                        return Err(err(
                            "--scenario is only valid for `gvbench dynamics` or `gvbench cluster`",
                        ));
                    }
                    let v = next_value(&mut it, flag)?;
                    args.dyn_scenarios =
                        Some(v.split(',').map(|s| s.trim().to_string()).collect());
                }
                "--policies" => {
                    if args.command != Command::Cluster {
                        return Err(err("--policies is only valid for `gvbench cluster`"));
                    }
                    let v = next_value(&mut it, flag)?;
                    args.cluster_policies =
                        Some(v.split(',').map(|s| s.trim().to_string()).collect());
                }
                "--nodes" => {
                    if args.command != Command::Cluster {
                        return Err(err("--nodes is only valid for `gvbench cluster`"));
                    }
                    let v = next_value(&mut it, flag)?;
                    args.cluster_nodes = Some(parse_u32_list(flag, &v)?);
                }
                "--arrivals" => {
                    if args.command != Command::Cluster {
                        return Err(err("--arrivals is only valid for `gvbench cluster`"));
                    }
                    args.arrivals = Some(
                        next_value(&mut it, flag)?.parse().map_err(|_| err("bad --arrivals"))?,
                    );
                }
                "--duration-ms" => {
                    if args.command != Command::Dynamics {
                        return Err(err("--duration-ms is only valid for `gvbench dynamics`"));
                    }
                    args.duration_ms = Some(
                        next_value(&mut it, flag)?.parse().map_err(|_| err("bad --duration-ms"))?,
                    );
                }
                "--window-ms" => {
                    if args.command != Command::Dynamics {
                        return Err(err("--window-ms is only valid for `gvbench dynamics`"));
                    }
                    args.window_ms = Some(
                        next_value(&mut it, flag)?.parse().map_err(|_| err("bad --window-ms"))?,
                    );
                }
                "--trace" => {
                    if !matches!(args.command, Command::Dynamics | Command::Regress) {
                        return Err(err(
                            "--trace is only valid for `gvbench dynamics` or `gvbench regress`",
                        ));
                    }
                    args.trace = Some(next_value(&mut it, flag)?);
                }
                "--summary-out" => {
                    if !matches!(args.command, Command::Dynamics | Command::Cluster) {
                        return Err(err(
                            "--summary-out is only valid for `gvbench dynamics` or `gvbench cluster`",
                        ));
                    }
                    args.summary_out = Some(next_value(&mut it, flag)?);
                }
                "--iterations" => {
                    args.iterations = Some(
                        next_value(&mut it, flag)?.parse().map_err(|_| err("bad --iterations"))?,
                    )
                }
                "--warmup" => {
                    args.warmup =
                        Some(next_value(&mut it, flag)?.parse().map_err(|_| err("bad --warmup"))?)
                }
                "--tenants" => {
                    let v = next_value(&mut it, flag)?;
                    if args.command == Command::Sweep {
                        args.sweep_tenants = Some(parse_u32_list(flag, &v)?);
                    } else {
                        args.tenants = Some(v.parse().map_err(|_| err("bad --tenants"))?);
                    }
                }
                "--quota" => {
                    if args.command != Command::Sweep {
                        return Err(err("--quota is only valid for `gvbench sweep`"));
                    }
                    let v = next_value(&mut it, flag)?;
                    args.sweep_quotas = Some(parse_u32_list(flag, &v)?);
                }
                "--gpus" => {
                    if args.command != Command::Sweep {
                        return Err(err("--gpus is only valid for `gvbench sweep`"));
                    }
                    let v = next_value(&mut it, flag)?;
                    args.sweep_gpus = Some(parse_u32_list(flag, &v)?);
                }
                "--link" => {
                    if args.command != Command::Sweep {
                        return Err(err("--link is only valid for `gvbench sweep`"));
                    }
                    let v = next_value(&mut it, flag)?;
                    args.sweep_links =
                        Some(v.split(',').map(|s| s.trim().to_string()).collect());
                }
                "--seed" => {
                    args.seed =
                        Some(next_value(&mut it, flag)?.parse().map_err(|_| err("bad --seed"))?)
                }
                "--jobs" => {
                    args.jobs =
                        Some(next_value(&mut it, flag)?.parse().map_err(|_| err("bad --jobs"))?)
                }
                "--quick" => args.quick = true,
                "--config" => args.config = Some(next_value(&mut it, flag)?),
                "--format" => args.format = next_value(&mut it, flag)?,
                "--out" => args.out = Some(next_value(&mut it, flag)?),
                "--baseline" => args.baseline = Some(next_value(&mut it, flag)?),
                "--report-json" => {
                    if args.command != Command::Regress {
                        return Err(err("--report-json is only valid for `gvbench regress`"));
                    }
                    args.report_json = Some(next_value(&mut it, flag)?);
                }
                "--report-md" => {
                    if args.command != Command::Regress {
                        return Err(err("--report-md is only valid for `gvbench regress`"));
                    }
                    args.report_md = Some(next_value(&mut it, flag)?);
                }
                "--threshold" => {
                    args.threshold = next_value(&mut it, flag)?
                        .parse()
                        .map_err(|_| err("bad --threshold"))?
                }
                "--full" => args.list_full = true,
                "--systems" => {
                    if matches!(args.command, Command::Sweep | Command::Dynamics | Command::Cluster)
                    {
                        // Sweeps/dynamics/cluster take a system list (`all` = every system).
                        let v = next_value(&mut it, flag)?;
                        if v.trim() == "all" {
                            args.all_systems = true;
                        } else {
                            args.sweep_systems =
                                Some(v.split(',').map(|s| s.trim().to_string()).collect());
                        }
                    } else {
                        args.list_systems = true;
                    }
                }
                "--categories" => args.list_categories = true,
                other => return Err(err(format!("unknown flag `{other}`"))),
            }
        }
        // Validation.
        if args.command == Command::Regress && args.baseline.is_none() {
            return Err(err("regress requires --baseline <csv>"));
        }
        if args.command == Command::Submit {
            let has_argv = matches!(&args.job_argv, Some(v) if !v.is_empty());
            if args.spec_file.is_some() && has_argv {
                return Err(err(
                    "--spec-file and an inline `--` job argv are mutually exclusive",
                ));
            }
            if args.spec_file.is_none() && !has_argv {
                return Err(err(
                    "submit requires a job: `gvbench submit -- <run|sweep|dynamics|cluster|\
                     regress> ...` or --spec-file <file>",
                ));
            }
        }
        let takes_suite_flags = matches!(
            args.command,
            Command::Run | Command::Regress | Command::Sweep | Command::Dynamics | Command::Cluster
        );
        if takes_suite_flags {
            if crate::virt::by_name(&args.system).is_none() {
                return Err(err(format!(
                    "unknown system `{}` (expected: native, hami, fcsp, mig, timeslice)",
                    args.system
                )));
            }
            if let Some(c) = &args.category {
                if crate::metrics::Category::from_key(c).is_none() {
                    return Err(err(format!("unknown category `{c}`")));
                }
            }
            if let Some(m) = &args.metric {
                if crate::metrics::taxonomy::by_id(m).is_none() {
                    return Err(err(format!("unknown metric `{m}`")));
                }
            }
            if crate::report::Format::from_key(&args.format).is_none() {
                return Err(err(format!("unknown format `{}`", args.format)));
            }
        }
        if args.command == Command::Sweep {
            if args.metric.is_some() {
                return Err(err("--metric is not supported by `gvbench sweep`; use --category"));
            }
            if let Some(cats) = &args.sweep_categories {
                for c in cats {
                    if crate::metrics::Category::from_key(c).is_none() {
                        return Err(err(format!("unknown category `{c}`")));
                    }
                }
            }
            if let Some(ss) = &args.sweep_systems {
                for s in ss {
                    if crate::virt::by_name(s).is_none() {
                        return Err(err(format!(
                            "unknown system `{s}` (expected: native, hami, fcsp, mig, timeslice, or `all`)"
                        )));
                    }
                }
            }
            validate_sweep_grid(
                args.sweep_tenants.as_deref(),
                args.sweep_quotas.as_deref(),
                args.sweep_gpus.as_deref(),
            )
            .map_err(err)?;
            validate_sweep_links(args.sweep_links.as_deref()).map_err(err)?;
        }
        if args.command == Command::Dynamics {
            if args.metric.is_some() || args.category.is_some() {
                return Err(err(
                    "--metric/--category are not supported by `gvbench dynamics`; use --scenario",
                ));
            }
            if args.tenants.is_some() {
                return Err(err(
                    "--tenants is not supported by `gvbench dynamics`; the tenant population \
                     comes from the scenario preset's timeline",
                ));
            }
            if let Some(ss) = &args.sweep_systems {
                for s in ss {
                    if crate::virt::by_name(s).is_none() {
                        return Err(err(format!(
                            "unknown system `{s}` (expected: native, hami, fcsp, mig, timeslice, or `all`)"
                        )));
                    }
                }
            }
            if args.trace.is_some() {
                if args.dyn_scenarios.is_some() {
                    return Err(err(
                        "--trace and --scenario are mutually exclusive; the trace file is \
                         the timeline",
                    ));
                }
                if args.duration_ms.is_some() || args.window_ms.is_some() {
                    return Err(err(
                        "--duration-ms/--window-ms are not supported with --trace; the \
                         trace's `duration-ms`/`window-ms` headers set the geometry",
                    ));
                }
            }
            validate_dynamics_grid(
                args.dyn_scenarios.as_deref(),
                args.duration_ms,
                args.window_ms,
            )
            .map_err(err)?;
            if args.export_trace.is_some() {
                if args.trace.is_some() {
                    return Err(err(
                        "--export-trace and --trace are mutually exclusive; exporting \
                         renders a preset, replaying consumes a trace",
                    ));
                }
                if args.trace_out.is_some() {
                    return Err(err(
                        "--export-trace and --trace-out are mutually exclusive; exporting \
                         skips the replay, so there is no span trace to write",
                    ));
                }
                if args.dyn_scenarios.as_ref().map(|s| s.len()) != Some(1) {
                    return Err(err(
                        "--export-trace requires exactly one --scenario preset to render",
                    ));
                }
            }
        }
        if args.command == Command::Jobs && args.stats && args.shutdown {
            return Err(err("--stats and --shutdown are mutually exclusive"));
        }
        if args.command == Command::Cluster {
            if args.metric.is_some() || args.category.is_some() {
                return Err(err(
                    "--metric/--category are not supported by `gvbench cluster`; use \
                     --policies/--nodes/--scenario",
                ));
            }
            if args.tenants.is_some() {
                return Err(err(
                    "--tenants is not supported by `gvbench cluster`; the tenant population \
                     comes from the --arrivals timeline",
                ));
            }
            if let Some(ss) = &args.sweep_systems {
                for s in ss {
                    if crate::virt::by_name(s).is_none() {
                        return Err(err(format!(
                            "unknown system `{s}` (expected: native, hami, fcsp, mig, timeslice, or `all`)"
                        )));
                    }
                }
            }
            // Scenario names share the dynamics presets; geometry flags
            // (--duration-ms/--window-ms) are rejected at the flag site.
            validate_dynamics_grid(args.dyn_scenarios.as_deref(), None, None).map_err(err)?;
            validate_cluster_grid(
                args.cluster_policies.as_deref(),
                args.cluster_nodes.as_deref(),
                args.arrivals,
            )
            .map_err(err)?;
        }
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<Args, ParseError> {
        let argv: Vec<String> = s.split_whitespace().map(String::from).collect();
        Args::parse(&argv)
    }

    #[test]
    fn run_with_flags() {
        let a = parse("run --system fcsp --category overhead --iterations 50 --quick").unwrap();
        assert_eq!(a.command, Command::Run);
        assert_eq!(a.system, "fcsp");
        assert_eq!(a.category.as_deref(), Some("overhead"));
        assert_eq!(a.iterations, Some(50));
        assert!(a.quick);
    }

    #[test]
    fn rejects_unknown_system_and_metric() {
        assert!(parse("run --system mps").is_err());
        assert!(parse("run --system hami --metric OH-099").is_err());
        assert!(parse("run --system hami --category bogus").is_err());
        assert!(parse("run --system hami --format xml").is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(parse("run --system").is_err());
    }

    #[test]
    fn jobs_flag_parses() {
        let a = parse("run --system hami --jobs 8").unwrap();
        assert_eq!(a.jobs, Some(8));
        assert!(parse("run --system hami --jobs lots").is_err());
        assert_eq!(parse("run --system hami").unwrap().jobs, None);
    }

    #[test]
    fn sweep_parses_lists() {
        let a = parse("sweep --tenants 1,2,4 --quota 50,100 --category isolation,pcie --jobs 8 --seed 42")
            .unwrap();
        assert_eq!(a.command, Command::Sweep);
        assert_eq!(a.sweep_tenants, Some(vec![1, 2, 4]));
        assert_eq!(a.sweep_quotas, Some(vec![50, 100]));
        assert_eq!(
            a.sweep_categories,
            Some(vec!["isolation".to_string(), "pcie".to_string()])
        );
        assert_eq!(a.jobs, Some(8));
        assert_eq!(a.seed, Some(42));
        assert!(!a.system_set);
    }

    #[test]
    fn sweep_rejects_bad_grids() {
        assert!(parse("sweep --tenants 1,lots").is_err());
        assert!(parse("sweep --tenants 0").is_err());
        assert!(parse("sweep --tenants 65").is_err());
        assert!(parse("sweep --quota 0").is_err());
        assert!(parse("sweep --quota 101").is_err());
        assert!(parse("sweep --gpus 0").is_err());
        assert!(parse("sweep --gpus 32").is_err());
        assert!(parse("sweep --gpus 2,lots").is_err());
        assert!(parse("sweep --link sli").is_err());
        assert!(parse("sweep --link nvlink,bogus").is_err());
        assert!(parse("sweep --category bogus").is_err());
        assert!(parse("sweep --format xml").is_err());
        assert!(parse("sweep --metric OH-001").is_err());
        // --quota / --gpus / --link belong to sweep only.
        assert!(parse("run --system hami --quota 50").is_err());
        assert!(parse("run --system hami --gpus 2,4").is_err());
        assert!(parse("run --system hami --link nvlink").is_err());
    }

    #[test]
    fn sweep_parses_topology_axes() {
        let a = parse("sweep --gpus 2,4,8 --link nvlink,pcie").unwrap();
        assert_eq!(a.sweep_gpus, Some(vec![2, 4, 8]));
        assert_eq!(
            a.sweep_links,
            Some(vec!["nvlink".to_string(), "pcie".to_string()])
        );
        // Absent: the sweep falls back to the default 4-GPU PCIe node.
        let a = parse("sweep --tenants 1,2").unwrap();
        assert_eq!(a.sweep_gpus, None);
        assert_eq!(a.sweep_links, None);
    }

    #[test]
    fn sweep_systems_list_and_all() {
        // `--systems all` is shorthand for --all-systems under sweep.
        let a = parse("sweep --systems all --tenants 1,2").unwrap();
        assert!(a.all_systems);
        assert_eq!(a.sweep_systems, None);
        let a = parse("sweep --systems hami,fcsp").unwrap();
        assert!(!a.all_systems);
        assert_eq!(
            a.sweep_systems,
            Some(vec!["hami".to_string(), "fcsp".to_string()])
        );
        assert!(parse("sweep --systems hami,mps").is_err());
        // Under `list`, --systems stays the boolean section selector.
        let a = parse("list --systems").unwrap();
        assert!(a.list_systems);
        assert_eq!(a.sweep_systems, None);
    }

    #[test]
    fn dynamics_parses_grid_and_outputs() {
        let a = parse(
            "dynamics --scenario churn,failover --systems hami,fcsp --duration-ms 2000 \
             --window-ms 200 --jobs 8 --seed 7 --format csv --out d.csv --summary-out s.csv",
        )
        .unwrap();
        assert_eq!(a.command, Command::Dynamics);
        assert_eq!(
            a.dyn_scenarios,
            Some(vec!["churn".to_string(), "failover".to_string()])
        );
        assert_eq!(a.sweep_systems, Some(vec!["hami".to_string(), "fcsp".to_string()]));
        assert_eq!(a.duration_ms, Some(2000));
        assert_eq!(a.window_ms, Some(200));
        assert_eq!(a.jobs, Some(8));
        assert_eq!(a.seed, Some(7));
        assert_eq!(a.summary_out.as_deref(), Some("s.csv"));
        // Defaults: everything optional.
        let a = parse("dynamics").unwrap();
        assert_eq!(a.dyn_scenarios, None);
        assert_eq!(a.duration_ms, None);
        // `--systems all` works like the sweep shorthand.
        let a = parse("dynamics --systems all").unwrap();
        assert!(a.all_systems);
    }

    #[test]
    fn dynamics_rejects_bad_grids() {
        assert!(parse("dynamics --scenario meltdown").is_err());
        assert!(parse("dynamics --duration-ms 0").is_err());
        assert!(parse("dynamics --duration-ms lots").is_err());
        assert!(parse("dynamics --window-ms 0").is_err());
        assert!(parse("dynamics --duration-ms 100 --window-ms 200").is_err());
        assert!(parse("dynamics --systems hami,mps").is_err());
        assert!(parse("dynamics --metric OH-001").is_err());
        assert!(parse("dynamics --category llm").is_err());
        assert!(parse("dynamics --tenants 8").is_err());
        assert!(parse("dynamics --format xml").is_err());
        // Dynamics flags belong to dynamics only.
        assert!(parse("run --system hami --scenario churn").is_err());
        assert!(parse("sweep --duration-ms 100").is_err());
        assert!(parse("run --system hami --summary-out s.csv").is_err());
    }

    #[test]
    fn dynamics_accepts_the_training_presets() {
        let a = parse("dynamics --scenario train-steady,mixed-churn").unwrap();
        assert_eq!(
            a.dyn_scenarios,
            Some(vec!["train-steady".to_string(), "mixed-churn".to_string()])
        );
        // `trace` is a reserved timeline coordinate, not a preset name:
        // trace timelines come in through --trace, never --scenario.
        assert!(parse("dynamics --scenario trace").is_err());
        assert!(parse("cluster --scenario trace").is_err());
    }

    #[test]
    fn trace_flag_excludes_the_grid_flags() {
        let a = parse("dynamics --trace t.txt --systems hami,fcsp --jobs 4").unwrap();
        assert_eq!(a.trace.as_deref(), Some("t.txt"));
        assert_eq!(a.dyn_scenarios, None);
        // The trace supplies timeline and geometry itself.
        assert!(parse("dynamics --trace t.txt --scenario steady").is_err());
        assert!(parse("dynamics --scenario steady --trace t.txt").is_err());
        assert!(parse("dynamics --trace t.txt --duration-ms 500").is_err());
        assert!(parse("dynamics --trace t.txt --window-ms 50").is_err());
        assert!(parse("dynamics --trace").is_err());
        // --trace belongs to dynamics and regress only.
        assert!(parse("run --system hami --trace t.txt").is_err());
        assert!(parse("sweep --trace t.txt").is_err());
        assert!(parse("cluster --trace t.txt").is_err());
        let a = parse("regress --baseline b.csv --trace t.txt").unwrap();
        assert_eq!(a.trace.as_deref(), Some("t.txt"));
    }

    #[test]
    fn cluster_parses_grid_and_outputs() {
        let a = parse(
            "cluster --policies first-fit,frag-gradient --nodes 8,16 --arrivals 5000 \
             --scenario churn,failover --systems hami,fcsp --jobs 8 --seed 7 \
             --format csv --out fleet.csv --summary-out s.csv",
        )
        .unwrap();
        assert_eq!(a.command, Command::Cluster);
        assert_eq!(
            a.cluster_policies,
            Some(vec!["first-fit".to_string(), "frag-gradient".to_string()])
        );
        assert_eq!(a.cluster_nodes, Some(vec![8, 16]));
        assert_eq!(a.arrivals, Some(5000));
        assert_eq!(
            a.dyn_scenarios,
            Some(vec!["churn".to_string(), "failover".to_string()])
        );
        assert_eq!(a.sweep_systems, Some(vec!["hami".to_string(), "fcsp".to_string()]));
        assert_eq!(a.jobs, Some(8));
        assert_eq!(a.seed, Some(7));
        assert_eq!(a.summary_out.as_deref(), Some("s.csv"));
        // Defaults: everything optional.
        let a = parse("cluster").unwrap();
        assert_eq!(a.cluster_policies, None);
        assert_eq!(a.cluster_nodes, None);
        assert_eq!(a.arrivals, None);
        // `--systems all` works like the sweep shorthand.
        let a = parse("cluster --systems all").unwrap();
        assert!(a.all_systems);
    }

    #[test]
    fn cluster_rejects_bad_grids() {
        assert!(parse("cluster --policies random").is_err());
        assert!(parse("cluster --nodes 0").is_err());
        assert!(parse("cluster --nodes 4096").is_err());
        assert!(parse("cluster --nodes 8,lots").is_err());
        assert!(parse("cluster --arrivals 0").is_err());
        assert!(parse("cluster --arrivals 200000").is_err());
        assert!(parse("cluster --scenario meltdown").is_err());
        assert!(parse("cluster --systems hami,mps").is_err());
        assert!(parse("cluster --metric OH-001").is_err());
        assert!(parse("cluster --category overhead").is_err());
        assert!(parse("cluster --tenants 8").is_err());
        assert!(parse("cluster --duration-ms 1000").is_err());
        assert!(parse("cluster --window-ms 100").is_err());
        assert!(parse("cluster --format xml").is_err());
        // Cluster flags belong to cluster only.
        assert!(parse("run --system hami --policies first-fit").is_err());
        assert!(parse("sweep --nodes 8").is_err());
        assert!(parse("dynamics --arrivals 1000").is_err());
        // --scenario/--summary-out are shared with dynamics, nothing else.
        let a = parse("cluster --scenario churn --summary-out s.csv").unwrap();
        assert_eq!(a.dyn_scenarios, Some(vec!["churn".to_string()]));
        assert_eq!(a.summary_out.as_deref(), Some("s.csv"));
    }

    #[test]
    fn system_set_tracks_explicit_flag() {
        assert!(parse("sweep --system fcsp").unwrap().system_set);
        assert!(!parse("run").unwrap().system_set);
        assert!(parse("run --system hami").unwrap().system_set);
    }

    #[test]
    fn run_tenants_stays_scalar() {
        let a = parse("run --system hami --tenants 8").unwrap();
        assert_eq!(a.tenants, Some(8));
        assert_eq!(a.sweep_tenants, None);
        assert!(parse("run --system hami --tenants 1,2").is_err());
    }

    #[test]
    fn list_flags() {
        let a = parse("list --full").unwrap();
        assert_eq!(a.command, Command::List);
        assert!(a.list_full);
    }

    #[test]
    fn regress_requires_baseline() {
        assert!(parse("regress").is_err());
        let a = parse("regress --baseline b.csv --threshold 5 --system fcsp").unwrap();
        assert_eq!(a.command, Command::Regress);
        assert_eq!(a.baseline.as_deref(), Some("b.csv"));
        assert_eq!(a.threshold, 5.0);
        assert_eq!(a.report_json, None);
        assert_eq!(a.report_md, None);
    }

    #[test]
    fn regress_report_flags() {
        let a = parse("regress --baseline b.csv --report-json r.json --report-md r.md").unwrap();
        assert_eq!(a.report_json.as_deref(), Some("r.json"));
        assert_eq!(a.report_md.as_deref(), Some("r.md"));
        // Report flags belong to regress only.
        assert!(parse("run --system hami --report-json r.json").is_err());
        assert!(parse("sweep --report-md r.md").is_err());
        assert!(parse("regress --baseline b.csv --report-json").is_err());
    }

    #[test]
    fn help_default() {
        let a = parse("").unwrap();
        assert_eq!(a.command, Command::Help);
    }

    #[test]
    fn serve_parses_socket_and_jobs() {
        let a = parse("serve --socket /tmp/s.sock --jobs 4").unwrap();
        assert_eq!(a.command, Command::Serve);
        assert_eq!(a.socket.as_deref(), Some("/tmp/s.sock"));
        assert_eq!(a.jobs, Some(4));
        // Default socket is resolved later (commands layer), not here.
        assert_eq!(parse("serve").unwrap().socket, None);
        // --socket belongs to the service commands only.
        assert!(parse("run --system hami --socket /tmp/s.sock").is_err());
    }

    #[test]
    fn submit_captures_inline_argv_after_double_dash() {
        let a = parse("submit --socket /tmp/s.sock --priority 5 -- sweep --tenants 1,2 --quick")
            .unwrap();
        assert_eq!(a.command, Command::Submit);
        assert_eq!(a.priority, 5);
        assert_eq!(
            a.job_argv,
            Some(vec![
                "sweep".to_string(),
                "--tenants".to_string(),
                "1,2".to_string(),
                "--quick".to_string(),
            ])
        );
        // The job argv is opaque at submit-parse time: flags the submit
        // command itself does not know stay untouched behind `--`.
        let a = parse("submit -- regress --baseline b.csv --threshold 5").unwrap();
        assert_eq!(a.job_argv.as_ref().unwrap().len(), 5);
    }

    #[test]
    fn submit_requires_exactly_one_job_source() {
        assert!(parse("submit").is_err());
        assert!(parse("submit --").is_err(), "empty inline argv is no job");
        assert!(parse("submit --spec-file job.txt -- run --quick").is_err());
        let a = parse("submit --spec-file job.txt").unwrap();
        assert_eq!(a.spec_file.as_deref(), Some("job.txt"));
        assert_eq!(a.job_argv, None);
    }

    #[test]
    fn submit_priority_is_range_checked() {
        assert_eq!(parse("submit --priority -3 -- run").unwrap().priority, -3);
        assert_eq!(parse("submit -- run").unwrap().priority, 0);
        assert!(parse("submit --priority 1001 -- run").is_err());
        assert!(parse("submit --priority -1001 -- run").is_err());
        assert!(parse("submit --priority lots -- run").is_err());
        assert!(parse("run --system hami --priority 1").is_err());
    }

    #[test]
    fn jobs_command_and_shutdown_flag() {
        let a = parse("jobs --socket /tmp/s.sock").unwrap();
        assert_eq!(a.command, Command::Jobs);
        assert!(!a.shutdown);
        assert!(parse("jobs --shutdown").unwrap().shutdown);
        assert!(parse("run --system hami --shutdown").is_err());
        // `--` stays submit-only.
        assert!(parse("jobs -- run").is_err());
    }

    #[test]
    fn jobs_stats_flags() {
        let a = parse("jobs --stats").unwrap();
        assert!(a.stats);
        assert_eq!(a.stats_format, None);
        // --stats-format implies --stats and validates its value.
        let a = parse("jobs --stats-format prometheus").unwrap();
        assert!(a.stats);
        assert_eq!(a.stats_format.as_deref(), Some("prometheus"));
        assert_eq!(
            parse("jobs --stats-format table").unwrap().stats_format.as_deref(),
            Some("table")
        );
        assert!(parse("jobs --stats-format xml").is_err());
        assert!(parse("jobs --stats-format").is_err());
        // A stats query and a shutdown request cannot share one invocation.
        assert!(parse("jobs --stats --shutdown").is_err());
        // The stats flags belong to `jobs` only.
        assert!(parse("run --system hami --stats").is_err());
        assert!(parse("serve --stats-format prometheus").is_err());
    }

    #[test]
    fn trace_out_belongs_to_the_grid_commands() {
        assert_eq!(
            parse("run --system hami --trace-out t.json")
                .unwrap()
                .trace_out
                .as_deref(),
            Some("t.json")
        );
        assert!(parse("sweep --trace-out t.json").unwrap().trace_out.is_some());
        assert!(parse("dynamics --trace-out t.json").unwrap().trace_out.is_some());
        assert!(parse("cluster --trace-out t.json").unwrap().trace_out.is_some());
        // A replayed timeline still exports its (virtual-time) spans.
        let a = parse("dynamics --trace t.txt --trace-out t.json").unwrap();
        assert_eq!(a.trace_out.as_deref(), Some("t.json"));
        assert!(parse("serve --trace-out t.json").is_err());
        assert!(parse("regress --baseline b.csv --trace-out t.json").is_err());
        assert!(parse("dynamics --trace-out").is_err());
    }

    #[test]
    fn export_trace_renders_exactly_one_preset() {
        let a = parse("dynamics --scenario mixed-churn --export-trace churn.txt").unwrap();
        assert_eq!(a.export_trace.as_deref(), Some("churn.txt"));
        assert_eq!(a.dyn_scenarios, Some(vec!["mixed-churn".to_string()]));
        // Exactly one preset: none or several is ambiguous.
        assert!(parse("dynamics --export-trace churn.txt").is_err());
        assert!(parse("dynamics --scenario churn,failover --export-trace t.txt").is_err());
        // Export renders a preset; replay and span export make no sense with it.
        assert!(parse("dynamics --trace t.txt --export-trace out.txt").is_err());
        assert!(
            parse("dynamics --scenario churn --export-trace t.txt --trace-out c.json").is_err()
        );
        // --export-trace belongs to dynamics only.
        assert!(parse("cluster --scenario churn --export-trace t.txt").is_err());
        assert!(parse("run --system hami --export-trace t.txt").is_err());
    }
}
