//! Argument parsing for `gvbench`.

use std::fmt;

/// Usage text (also serves as the CLI reference in README).
pub const USAGE: &str = "\
GPU-Virt-Bench — benchmarking framework for GPU virtualization systems

USAGE:
  gvbench run [--system <native|hami|fcsp|mig>] [--all-systems]
              [--category <key>] [--metric <ID>] [--iterations N]
              [--warmup N] [--tenants N] [--seed N] [--jobs N] [--quick]
              [--config <file>] [--format <txt|json|csv>] [--out <file>]
  gvbench list [--full | --systems | --categories]
  gvbench compare [--quick] [--jobs N]  # Table 7: overall scores, all systems
  gvbench regress --baseline <csv> [--system S] [--threshold PCT] [--quick]
  gvbench help

EXAMPLES:
  gvbench run --system hami --category overhead
  gvbench run --all-systems --quick --format json --out results.json
  gvbench run --all-systems --jobs 8      # shard the matrix over 8 workers
  gvbench compare --quick

Parallelism: --jobs N shards the (system x metric) matrix across N worker
threads (0 or unset = all cores). Same --seed => bit-identical numbers at
any job count.
";

/// Parsed command line.
#[derive(Clone, Debug, PartialEq)]
pub enum Command {
    Run,
    List,
    Compare,
    Regress,
    Help,
}

#[derive(Clone, Debug)]
pub struct Args {
    pub command: Command,
    pub system: String,
    pub all_systems: bool,
    pub category: Option<String>,
    pub metric: Option<String>,
    pub iterations: Option<usize>,
    pub warmup: Option<usize>,
    pub tenants: Option<u32>,
    pub seed: Option<u64>,
    pub jobs: Option<usize>,
    pub quick: bool,
    pub config: Option<String>,
    pub format: String,
    pub out: Option<String>,
    pub list_full: bool,
    pub list_systems: bool,
    pub list_categories: bool,
    pub baseline: Option<String>,
    pub threshold: f64,
}

impl Default for Args {
    fn default() -> Args {
        Args {
            command: Command::Help,
            system: "hami".to_string(),
            all_systems: false,
            category: None,
            metric: None,
            iterations: None,
            warmup: None,
            tenants: None,
            seed: None,
            jobs: None,
            quick: false,
            config: None,
            format: "txt".to_string(),
            out: None,
            list_full: false,
            list_systems: false,
            list_categories: false,
            baseline: None,
            threshold: 10.0,
        }
    }
}

/// Parse failure.
#[derive(Debug, PartialEq)]
pub struct ParseError(pub String);

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for ParseError {}

fn err(msg: impl Into<String>) -> ParseError {
    ParseError(msg.into())
}

impl Args {
    /// Parse argv (without the program name).
    pub fn parse(argv: &[String]) -> Result<Args, ParseError> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        args.command = match it.next().map(|s| s.as_str()) {
            Some("run") => Command::Run,
            Some("list") => Command::List,
            Some("compare") => Command::Compare,
            Some("regress") => Command::Regress,
            Some("help") | Some("--help") | Some("-h") | None => Command::Help,
            Some(other) => return Err(err(format!("unknown command `{other}`"))),
        };
        let next_value = |it: &mut std::iter::Peekable<std::slice::Iter<String>>,
                              flag: &str|
         -> Result<String, ParseError> {
            it.next().cloned().ok_or_else(|| err(format!("{flag} requires a value")))
        };
        while let Some(flag) = it.next() {
            match flag.as_str() {
                "--system" => args.system = next_value(&mut it, flag)?,
                "--all-systems" => args.all_systems = true,
                "--category" => args.category = Some(next_value(&mut it, flag)?),
                "--metric" => args.metric = Some(next_value(&mut it, flag)?),
                "--iterations" => {
                    args.iterations = Some(
                        next_value(&mut it, flag)?.parse().map_err(|_| err("bad --iterations"))?,
                    )
                }
                "--warmup" => {
                    args.warmup =
                        Some(next_value(&mut it, flag)?.parse().map_err(|_| err("bad --warmup"))?)
                }
                "--tenants" => {
                    args.tenants =
                        Some(next_value(&mut it, flag)?.parse().map_err(|_| err("bad --tenants"))?)
                }
                "--seed" => {
                    args.seed =
                        Some(next_value(&mut it, flag)?.parse().map_err(|_| err("bad --seed"))?)
                }
                "--jobs" => {
                    args.jobs =
                        Some(next_value(&mut it, flag)?.parse().map_err(|_| err("bad --jobs"))?)
                }
                "--quick" => args.quick = true,
                "--config" => args.config = Some(next_value(&mut it, flag)?),
                "--format" => args.format = next_value(&mut it, flag)?,
                "--out" => args.out = Some(next_value(&mut it, flag)?),
                "--baseline" => args.baseline = Some(next_value(&mut it, flag)?),
                "--threshold" => {
                    args.threshold = next_value(&mut it, flag)?
                        .parse()
                        .map_err(|_| err("bad --threshold"))?
                }
                "--full" => args.list_full = true,
                "--systems" => args.list_systems = true,
                "--categories" => args.list_categories = true,
                other => return Err(err(format!("unknown flag `{other}`"))),
            }
        }
        // Validation.
        if args.command == Command::Regress && args.baseline.is_none() {
            return Err(err("regress requires --baseline <csv>"));
        }
        if args.command == Command::Run || args.command == Command::Regress {
            if crate::virt::by_name(&args.system).is_none() {
                return Err(err(format!(
                    "unknown system `{}` (expected: native, hami, fcsp, mig, timeslice)",
                    args.system
                )));
            }
            if let Some(c) = &args.category {
                if crate::metrics::Category::from_key(c).is_none() {
                    return Err(err(format!("unknown category `{c}`")));
                }
            }
            if let Some(m) = &args.metric {
                if crate::metrics::taxonomy::by_id(m).is_none() {
                    return Err(err(format!("unknown metric `{m}`")));
                }
            }
            if crate::report::Format::from_key(&args.format).is_none() {
                return Err(err(format!("unknown format `{}`", args.format)));
            }
        }
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<Args, ParseError> {
        let argv: Vec<String> = s.split_whitespace().map(String::from).collect();
        Args::parse(&argv)
    }

    #[test]
    fn run_with_flags() {
        let a = parse("run --system fcsp --category overhead --iterations 50 --quick").unwrap();
        assert_eq!(a.command, Command::Run);
        assert_eq!(a.system, "fcsp");
        assert_eq!(a.category.as_deref(), Some("overhead"));
        assert_eq!(a.iterations, Some(50));
        assert!(a.quick);
    }

    #[test]
    fn rejects_unknown_system_and_metric() {
        assert!(parse("run --system mps").is_err());
        assert!(parse("run --system hami --metric OH-099").is_err());
        assert!(parse("run --system hami --category bogus").is_err());
        assert!(parse("run --system hami --format xml").is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(parse("run --system").is_err());
    }

    #[test]
    fn jobs_flag_parses() {
        let a = parse("run --system hami --jobs 8").unwrap();
        assert_eq!(a.jobs, Some(8));
        assert!(parse("run --system hami --jobs lots").is_err());
        assert_eq!(parse("run --system hami").unwrap().jobs, None);
    }

    #[test]
    fn list_flags() {
        let a = parse("list --full").unwrap();
        assert_eq!(a.command, Command::List);
        assert!(a.list_full);
    }

    #[test]
    fn regress_requires_baseline() {
        assert!(parse("regress").is_err());
        let a = parse("regress --baseline b.csv --threshold 5 --system fcsp").unwrap();
        assert_eq!(a.command, Command::Regress);
        assert_eq!(a.baseline.as_deref(), Some("b.csv"));
        assert_eq!(a.threshold, 5.0);
    }

    #[test]
    fn help_default() {
        let a = parse("").unwrap();
        assert_eq!(a.command, Command::Help);
    }
}
