//! `gvbench regress` — automated regression testing for virtualization
//! systems (the paper's §9 future-work item, implemented).
//!
//! Workflow:
//!
//! ```bash
//! gvbench run --system fcsp --format csv --out baseline.csv   # pin a release
//! ... upgrade the virtualization stack ...
//! gvbench regress --system fcsp --baseline baseline.csv --threshold 10
//! ```
//!
//! Re-runs every metric present in the baseline CSV and flags any that
//! moved against its direction (Table 8) by more than `threshold` percent.
//! Exit code 1 on regressions — CI-friendly.
//!
//! Seed parity: baselines are produced by `gvbench run`, which executes
//! through the parallel executor with per-task derived seeds. The re-run
//! here derives the same seed per metric ([`executor::derive_cfg`]), so an
//! unchanged system compared against its own fresh baseline reports zero
//! regressions.

use std::collections::BTreeMap;

use crate::anyhow::{bail, Context, Result};

use crate::coordinator::executor;
use crate::metrics::{registry, taxonomy, Direction, RunConfig};

/// A parsed baseline: metric id → recorded value.
pub fn parse_baseline_csv(text: &str) -> Result<BTreeMap<String, f64>> {
    let mut out = BTreeMap::new();
    let mut lines = text.lines();
    let header = lines.next().context("empty baseline file")?;
    let cols: Vec<&str> = header.split(',').collect();
    let id_col = cols.iter().position(|c| *c == "id").context("no `id` column")?;
    let value_col = cols.iter().position(|c| *c == "value").context("no `value` column")?;
    for (i, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        // Our CSV quotes only name/description fields; id and value never
        // contain commas, but quoted fields may. Split carefully.
        let fields = split_csv(line);
        let id = fields.get(id_col).with_context(|| format!("row {}: missing id", i + 2))?;
        let value: f64 = fields
            .get(value_col)
            .with_context(|| format!("row {}: missing value", i + 2))?
            .parse()
            .with_context(|| format!("row {}: bad value", i + 2))?;
        if taxonomy::by_id(id).is_none() {
            bail!("row {}: unknown metric id `{id}`", i + 2);
        }
        out.insert(id.to_string(), value);
    }
    if out.is_empty() {
        bail!("baseline contains no metrics");
    }
    Ok(out)
}

/// Minimal CSV field splitter honouring double-quoted fields.
fn split_csv(line: &str) -> Vec<String> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes && chars.peek() == Some(&'"') => {
                cur.push('"');
                chars.next();
            }
            '"' => in_quotes = !in_quotes,
            ',' if !in_quotes => fields.push(std::mem::take(&mut cur)),
            c => cur.push(c),
        }
    }
    fields.push(cur);
    fields
}

/// One regression finding.
#[derive(Clone, Debug)]
pub struct Regression {
    pub id: String,
    pub baseline: f64,
    pub current: f64,
    /// Signed change in the *bad* direction, percent.
    pub regression_percent: f64,
}

/// Re-run the baseline's metrics on `cfg` and compare.
pub fn run_regression(
    cfg: &RunConfig,
    baseline: &BTreeMap<String, f64>,
    threshold_percent: f64,
) -> Result<(Vec<Regression>, usize)> {
    let mut regressions = Vec::new();
    let mut checked = 0;
    for (id, base) in baseline {
        let d = taxonomy::by_id(id).context("unknown id")?;
        // Match the seed derivation of the executor that produced the
        // baseline, or identical code would show phantom regressions.
        let task_cfg = executor::derive_cfg(cfg, &cfg.system, d.id);
        let Some(result) = registry::run_metric(id, &task_cfg) else {
            continue;
        };
        checked += 1;
        let cur = result.value;
        // Positive = got worse, in the metric's own direction.
        let worse_pct = match d.direction {
            Direction::LowerBetter => {
                if base.abs() < 1e-12 {
                    if cur > 1e-12 { 100.0 } else { 0.0 }
                } else {
                    (cur - base) / base * 100.0
                }
            }
            Direction::HigherBetter => {
                if base.abs() < 1e-12 {
                    0.0
                } else {
                    (base - cur) / base * 100.0
                }
            }
            Direction::Boolean => {
                if cur < *base { 100.0 } else { 0.0 }
            }
        };
        if worse_pct > threshold_percent {
            regressions.push(Regression {
                id: id.clone(),
                baseline: *base,
                current: cur,
                regression_percent: worse_pct,
            });
        }
    }
    Ok((regressions, checked))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_splitter_handles_quotes() {
        assert_eq!(split_csv("a,\"b,c\",d"), vec!["a", "b,c", "d"]);
        assert_eq!(split_csv("x,\"say \"\"hi\"\"\",y"), vec!["x", "say \"hi\"", "y"]);
    }

    #[test]
    fn parses_baseline() {
        let csv = "id,name,category,unit,system,value\nOH-001,\"Kernel Launch, x\",Overhead,µs,hami,15.3\n";
        let b = parse_baseline_csv(csv).unwrap();
        assert_eq!(b["OH-001"], 15.3);
    }

    #[test]
    fn rejects_unknown_ids_and_empty() {
        assert!(parse_baseline_csv("id,value\nXX-1,3\n").is_err());
        assert!(parse_baseline_csv("id,value\n").is_err());
    }

    #[test]
    fn detects_direction_aware_regressions() {
        // OH-001 lower-better: 4.2 → 15.3 is a regression.
        let mut base = BTreeMap::new();
        base.insert("OH-009".to_string(), 0.001); // hami will measure 0.055
        let cfg = RunConfig::quick("hami");
        let (regs, checked) = run_regression(&cfg, &base, 10.0).unwrap();
        assert_eq!(checked, 1);
        assert_eq!(regs.len(), 1);
        assert!(regs[0].regression_percent > 100.0);
        // And no regression when the baseline matches.
        let mut base = BTreeMap::new();
        base.insert("OH-009".to_string(), 0.055);
        let (regs, _) = run_regression(&cfg, &base, 10.0).unwrap();
        assert!(regs.is_empty(), "{regs:?}");
    }
}
