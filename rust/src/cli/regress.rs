//! `gvbench regress` — automated regression testing for virtualization
//! systems (the paper's §9 future-work item, implemented).
//!
//! Workflow:
//!
//! ```bash
//! gvbench run --all-systems --format csv --out baseline.csv  # pin a release
//! ... upgrade the virtualization stack ...
//! gvbench regress --baseline baseline.csv --threshold 10 --jobs 4
//! ```
//!
//! Re-runs every (system, metric) row present in the baseline CSV —
//! **sharded across `--jobs` workers through the parallel executor**, so a
//! 224-row all-systems baseline re-checks at CI speed — and flags any
//! metric that moved against its direction (Table 8) by more than
//! `threshold` percent. Exit code 1 on regressions — CI-friendly.
//!
//! Baselines may span multiple systems (the `system` column written by
//! `gvbench run --all-systems --format csv`); single-system baselines
//! without a `system` column attribute rows to `--system` (default hami).
//!
//! Seed parity: baselines are produced by `gvbench run`, which executes
//! through the parallel executor with per-task derived seeds. The re-run
//! here goes through the same executor, deriving the same
//! `task_seed(seed, system, metric)` per row — so an unchanged system
//! compared against its own fresh baseline reports zero regressions.

use std::collections::BTreeSet;

use crate::anyhow::{bail, Context, Result};

use crate::coordinator::executor;
use crate::metrics::{taxonomy, Direction, RunConfig};

/// One parsed baseline row.
#[derive(Clone, Debug)]
pub struct BaselineRow {
    pub system: String,
    pub id: String,
    pub value: f64,
}

/// Parse a baseline CSV into rows, in file order. Rows without a `system`
/// column are attributed to `default_system`. Unknown metric ids, unknown
/// systems and duplicate (system, id) pairs are rejected.
pub fn parse_baseline_csv(text: &str, default_system: &str) -> Result<Vec<BaselineRow>> {
    let mut out: Vec<BaselineRow> = Vec::new();
    let mut seen: BTreeSet<(String, String)> = BTreeSet::new();
    let mut lines = text.lines();
    let header = lines.next().context("empty baseline file")?;
    let cols: Vec<&str> = header.split(',').collect();
    let id_col = cols.iter().position(|c| *c == "id").context("no `id` column")?;
    let value_col = cols.iter().position(|c| *c == "value").context("no `value` column")?;
    let system_col = cols.iter().position(|c| *c == "system");
    for (i, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        // Our CSV quotes only name/description fields; id and value never
        // contain commas, but quoted fields may. Split carefully.
        let fields = split_csv(line);
        let id = fields.get(id_col).with_context(|| format!("row {}: missing id", i + 2))?;
        let value: f64 = fields
            .get(value_col)
            .with_context(|| format!("row {}: missing value", i + 2))?
            .parse()
            .with_context(|| format!("row {}: bad value", i + 2))?;
        if taxonomy::by_id(id).is_none() {
            bail!("row {}: unknown metric id `{id}`", i + 2);
        }
        let system = match system_col {
            Some(c) => fields
                .get(c)
                .with_context(|| format!("row {}: missing system", i + 2))?
                .clone(),
            None => default_system.to_string(),
        };
        if crate::virt::by_name(&system).is_none() {
            bail!("row {}: unknown system `{system}`", i + 2);
        }
        if !seen.insert((system.clone(), id.clone())) {
            bail!("row {}: duplicate baseline entry for {system}/{id}", i + 2);
        }
        out.push(BaselineRow { system, id: id.clone(), value });
    }
    if out.is_empty() {
        bail!("baseline contains no metrics");
    }
    Ok(out)
}

/// Minimal CSV field splitter honouring double-quoted fields.
fn split_csv(line: &str) -> Vec<String> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes && chars.peek() == Some(&'"') => {
                cur.push('"');
                chars.next();
            }
            '"' => in_quotes = !in_quotes,
            ',' if !in_quotes => fields.push(std::mem::take(&mut cur)),
            c => cur.push(c),
        }
    }
    fields.push(cur);
    fields
}

/// One regression finding.
#[derive(Clone, Debug)]
pub struct Regression {
    pub system: String,
    pub id: String,
    pub baseline: f64,
    pub current: f64,
    /// Signed change in the *bad* direction, percent.
    pub regression_percent: f64,
}

/// Re-run the baseline's (system, metric) rows — sharded across
/// `cfg.jobs` executor workers — and compare against the recorded values.
pub fn run_regression(
    cfg: &RunConfig,
    baseline: &[BaselineRow],
    threshold_percent: f64,
) -> Result<(Vec<Regression>, usize)> {
    // Every row's id was validated at parse time, so the executor returns
    // exactly one result per task, in row order. `execute` derives each
    // task's seed from (cfg.seed, system, metric) — the same derivation
    // `gvbench run` used to produce the baseline.
    let tasks: Vec<executor::Task> = baseline
        .iter()
        .filter_map(|r| {
            taxonomy::by_id(&r.id)
                .map(|d| executor::Task { system: r.system.clone(), metric_id: d.id })
        })
        .collect();
    let (results, _stats) = executor::execute(cfg, &tasks, cfg.jobs);
    if results.len() != baseline.len() {
        bail!("regression re-run produced {} results for {} rows", results.len(), baseline.len());
    }
    let mut regressions = Vec::new();
    let checked = results.len();
    for (row, result) in baseline.iter().zip(&results) {
        let d = taxonomy::by_id(&row.id).context("unknown id")?;
        let (base, cur) = (row.value, result.value);
        // Baseline CSVs record 6 decimal places; a move inside that
        // recording resolution is rounding noise, not a regression (and
        // would otherwise read as an infinite relative move when a tiny
        // value rounded to 0 in the baseline).
        if (cur - base).abs() <= 1.5e-6 {
            continue;
        }
        // Positive = got worse, in the metric's own direction.
        let worse_pct = match d.direction {
            Direction::LowerBetter => {
                if base.abs() < 1e-12 {
                    if cur > 1e-12 { 100.0 } else { 0.0 }
                } else {
                    (cur - base) / base * 100.0
                }
            }
            Direction::HigherBetter => {
                if base.abs() < 1e-12 {
                    0.0
                } else {
                    (base - cur) / base * 100.0
                }
            }
            Direction::Boolean => {
                if cur < base { 100.0 } else { 0.0 }
            }
        };
        if worse_pct > threshold_percent {
            regressions.push(Regression {
                system: row.system.clone(),
                id: row.id.clone(),
                baseline: base,
                current: cur,
                regression_percent: worse_pct,
            });
        }
    }
    Ok((regressions, checked))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_splitter_handles_quotes() {
        assert_eq!(split_csv("a,\"b,c\",d"), vec!["a", "b,c", "d"]);
        assert_eq!(split_csv("x,\"say \"\"hi\"\"\",y"), vec!["x", "say \"hi\"", "y"]);
    }

    #[test]
    fn parses_baseline_with_system_column() {
        let csv = "id,name,category,unit,system,value\n\
                   OH-001,\"Kernel Launch, x\",Overhead,µs,hami,15.3\n\
                   OH-001,\"Kernel Launch, x\",Overhead,µs,fcsp,8.1\n";
        let b = parse_baseline_csv(csv, "native").unwrap();
        assert_eq!(b.len(), 2);
        assert_eq!(b[0].system, "hami");
        assert_eq!(b[0].value, 15.3);
        assert_eq!(b[1].system, "fcsp");
    }

    #[test]
    fn parses_baseline_without_system_column() {
        let csv = "id,value\nOH-001,15.3\n";
        let b = parse_baseline_csv(csv, "fcsp").unwrap();
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].system, "fcsp");
        assert_eq!(b[0].id, "OH-001");
    }

    #[test]
    fn rejects_unknown_duplicate_and_empty() {
        assert!(parse_baseline_csv("id,value\nXX-1,3\n", "hami").is_err());
        assert!(parse_baseline_csv("id,value\n", "hami").is_err());
        // Unknown system.
        let csv = "id,system,value\nOH-001,mps,1.0\n";
        assert!(parse_baseline_csv(csv, "hami").is_err());
        // Duplicate (system, id).
        let csv = "id,system,value\nOH-001,hami,1.0\nOH-001,hami,2.0\n";
        assert!(parse_baseline_csv(csv, "hami").is_err());
    }

    #[test]
    fn detects_direction_aware_regressions() {
        // OH-009 lower-better: hami measures 0.055, so a 0.001 baseline is
        // a large regression; a matching baseline is clean.
        let rows = |v: f64| {
            vec![BaselineRow { system: "hami".to_string(), id: "OH-009".to_string(), value: v }]
        };
        let cfg = RunConfig::quick("hami");
        let (regs, checked) = run_regression(&cfg, &rows(0.001), 10.0).unwrap();
        assert_eq!(checked, 1);
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].system, "hami");
        assert!(regs[0].regression_percent > 100.0);
        let (regs, _) = run_regression(&cfg, &rows(0.055), 10.0).unwrap();
        assert!(regs.is_empty(), "{regs:?}");
    }

    #[test]
    fn rerun_matches_its_own_fresh_baseline_across_systems() {
        // A multi-system "baseline" produced by the executor compares
        // clean against a sharded re-run at a different job count.
        let cfg = RunConfig::quick("native");
        let tasks = vec![
            executor::Task { system: "native".into(), metric_id: "PCIE-001" },
            executor::Task { system: "hami".into(), metric_id: "PCIE-001" },
            executor::Task { system: "fcsp".into(), metric_id: "BW-003" },
        ];
        let (results, _) = executor::execute(&cfg, &tasks, 1);
        let baseline: Vec<BaselineRow> = results
            .iter()
            .map(|r| BaselineRow {
                system: r.system.clone(),
                id: r.id.to_string(),
                value: r.value,
            })
            .collect();
        let mut cfg8 = cfg.clone();
        cfg8.jobs = 8;
        let (regs, checked) = run_regression(&cfg8, &baseline, 0.0001).unwrap();
        assert_eq!(checked, 3);
        assert!(regs.is_empty(), "{regs:?}");
    }
}
