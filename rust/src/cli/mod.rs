//! `gvbench` command-line front end (clap substitute for the offline
//! build): subcommands `run`, `sweep`, `dynamics`, `cluster`, `list`,
//! `compare`, `regress`, the benchmark service (`serve`, `submit`,
//! `jobs`), plus `--help`.

pub mod args;
pub mod commands;

pub use args::{Args, ParseError};

/// Entry point used by `main.rs`. Returns the process exit code.
pub fn main_with_args(argv: &[String]) -> i32 {
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("{}", args::USAGE);
            return 2;
        }
    };
    match commands::dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}
