//! Command implementations for `gvbench`.
//!
//! The spec-building halves of the grid commands (`sweep_inputs`,
//! `dynamics_inputs`, `cluster_inputs`, `run_report_on`,
//! `load_baseline`) are public: the serve daemon executes submitted
//! jobs through the *same* helpers the one-shot commands use, which is
//! what makes a served report bit-identical to its CLI equivalent.

use crate::anyhow::{bail, Context, Result};

use crate::cluster::{self, ClusterSpec};
use crate::config::{ClusterOverlay, DynOverlay, FileConfig, SweepOverlay};
use crate::coordinator::executor::{Backend, ExecutionStats, Observer};
use crate::coordinator::sweep::{self, SweepSpec};
use crate::coordinator::SuiteRunner;
use crate::dynsim::{self, DynSpec, ScenarioSpec};
use crate::metrics::{taxonomy, Category, RunConfig};
use crate::report::{Format, Report};
use crate::simgpu::nvlink::LinkKind;
use crate::virt::ALL_SYSTEMS;

use super::args::{Args, Command, USAGE};

/// Dispatch the parsed command.
pub fn dispatch(args: &Args) -> Result<()> {
    match args.command {
        Command::Help => {
            println!("{USAGE}");
            Ok(())
        }
        Command::List => cmd_list(args),
        Command::Run => cmd_run(args),
        Command::Sweep => cmd_sweep(args),
        Command::Dynamics => cmd_dynamics(args),
        Command::Cluster => cmd_cluster(args),
        Command::Compare => cmd_compare(args),
        Command::Regress => cmd_regress(args),
        Command::Serve => cmd_serve(args),
        Command::Submit => cmd_submit(args),
        Command::Jobs => cmd_jobs(args),
    }
}

/// Read and parse `--baseline`, restricted to `--system` when one was
/// given explicitly. Returns the path alongside the parsed baseline —
/// shared by [`cmd_regress`] and the serve daemon's regress jobs.
pub fn load_baseline(args: &Args) -> Result<(String, crate::regress::Baseline)> {
    let path = args.baseline.as_ref().context("regress requires --baseline <csv>")?;
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let mut baseline = crate::regress::parse_baseline_csv(&text, &args.system)?;
    if args.system_set {
        // Explicit --system restricts a multi-system baseline to one row set.
        baseline.rows.retain(|r| r.system == args.system);
        baseline.infeasible.retain(|(s, _)| s == &args.system);
        if baseline.rows.is_empty() {
            bail!("baseline {path} has no rows for system `{}`", args.system);
        }
    }
    Ok((path.clone(), baseline))
}

/// Read and parse `--trace FILE` when one was given — shared by the
/// dynamics grid builder, `cmd_regress` and the serve daemon's jobs.
pub fn load_trace_spec(args: &Args) -> Result<Option<ScenarioSpec>> {
    match &args.trace {
        Some(path) => {
            let text =
                std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            let spec =
                dynsim::parse_trace(&text).with_context(|| format!("parsing trace {path}"))?;
            Ok(Some(spec))
        }
        None => Ok(None),
    }
}

fn cmd_regress(args: &Args) -> Result<()> {
    let (path, baseline) = load_baseline(args)?;
    let path = &path;
    let trace = load_trace_spec(args)?;
    let cfg = build_config(args)?;
    let systems: std::collections::BTreeSet<&str> =
        baseline.rows.iter().map(|r| r.system.as_str()).collect();
    println!(
        "Regression check: {} baseline, systems=[{}], {} cells, threshold {:.1}%, jobs={}",
        baseline.schema.key(),
        systems.into_iter().collect::<Vec<_>>().join(","),
        baseline.rows.len(),
        args.threshold,
        crate::coordinator::executor::resolve_jobs(cfg.jobs),
    );
    let outcome = crate::regress::run_regression_with_trace(
        &Backend::Scoped(cfg.jobs),
        &cfg,
        &baseline,
        args.threshold,
        None,
        trace.as_ref(),
    )?;
    // Reports are written before the pass/fail verdict so CI can publish
    // them from failed gate runs.
    if let Some(p) = &args.report_json {
        std::fs::write(p, crate::regress::render_json(&outcome, path))
            .with_context(|| format!("writing {p}"))?;
        eprintln!("wrote {p}");
    }
    if let Some(p) = &args.report_md {
        std::fs::write(p, crate::regress::render_markdown(&outcome, path))
            .with_context(|| format!("writing {p}"))?;
        eprintln!("wrote {p}");
    }
    if outcome.skipped_infeasible > 0 {
        println!("  ({} infeasible cell(s) skipped)", outcome.skipped_infeasible);
    }
    let regressions = outcome.regressions();
    if regressions.is_empty() {
        println!("OK — {} cells within threshold.", outcome.checked());
        return Ok(());
    }
    println!("{} regressions / {} cells:", regressions.len(), outcome.checked());
    for r in &regressions {
        // Dynamics and cluster summary ids live outside the Table-8
        // taxonomy.
        let d = taxonomy::by_id(&r.id)
            .or_else(|| taxonomy::dyn_summary_by_id(&r.id))
            .or_else(|| taxonomy::cluster_summary_by_id(&r.id))
            .expect("engine validated the id");
        println!(
            "  {:<10} {:<9} {:<10} {:<32} {:.3} -> {:.3} {}  ({:+.1}% worse)",
            r.system,
            r.cell_label(),
            r.id,
            d.name,
            r.baseline,
            r.current,
            d.unit,
            r.worse_percent
        );
    }
    bail!("{} cell(s) regressed beyond {:.1}%", regressions.len(), args.threshold)
}

/// Load `--config <file>` if one was given.
fn load_file_config(args: &Args) -> Result<Option<FileConfig>> {
    match &args.config {
        Some(path) => {
            let text =
                std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            Ok(Some(FileConfig::parse(&text)?))
        }
        None => Ok(None),
    }
}

/// The single config path for one-shot commands and served jobs alike:
/// base config ← `--config` file ← CLI flag overrides.
pub fn build_config(args: &Args) -> Result<RunConfig> {
    let file = load_file_config(args)?;
    build_config_with(args, file.as_ref())
}

/// Base config ← config file ← CLI flag overrides.
fn build_config_with(args: &Args, file: Option<&FileConfig>) -> Result<RunConfig> {
    let mut cfg = if args.quick {
        RunConfig::quick(&args.system)
    } else {
        RunConfig::for_system(&args.system)
    };
    if let Some(fc) = file {
        cfg = fc.apply(cfg)?;
    }
    if let Some(v) = args.iterations {
        cfg.iterations = v;
    }
    if let Some(v) = args.warmup {
        cfg.warmup = v;
    }
    if let Some(v) = args.tenants {
        cfg.tenants = v;
    }
    if let Some(v) = args.seed {
        cfg.seed = v;
    }
    if let Some(v) = args.jobs {
        cfg.jobs = v;
    }
    Ok(cfg)
}

/// The resolved inputs of a sweep invocation: the run config and the
/// fully validated grid spec. Built identically for `gvbench sweep` and
/// for served sweep jobs.
pub struct SweepInputs {
    pub cfg: RunConfig,
    pub spec: SweepSpec,
}

/// Build the sweep grid (CLI flags > config-file `[sweep]` section >
/// default grid).
pub fn sweep_inputs(args: &Args) -> Result<SweepInputs> {
    let file = load_file_config(args)?;
    let cfg = build_config_with(args, file.as_ref())?;
    let overlay = match file.as_ref() {
        Some(fc) => fc.sweep()?,
        None => SweepOverlay::default(),
    };
    let tenants = args
        .sweep_tenants
        .clone()
        .or(overlay.tenants)
        .unwrap_or_else(|| vec![1, 2, 4, 8]);
    let quotas = args
        .sweep_quotas
        .clone()
        .or(overlay.quotas)
        .unwrap_or_else(|| vec![25, 50, 100]);
    let gpus = args
        .sweep_gpus
        .clone()
        .or(overlay.gpus)
        .unwrap_or_else(|| vec![sweep::DEFAULT_GPU_COUNT]);
    let link_keys = args.sweep_links.clone().or(overlay.links);
    if let Err(e) =
        super::args::validate_sweep_grid(Some(&tenants), Some(&quotas), Some(&gpus))
    {
        bail!("{e}");
    }
    // One validation path for CLI flags and config-file keys alike.
    if let Err(e) = super::args::validate_sweep_links(link_keys.as_deref()) {
        bail!("{e} in sweep grid");
    }
    let links: Vec<LinkKind> = match link_keys {
        None => vec![sweep::DEFAULT_LINK],
        Some(keys) => keys
            .iter()
            .map(|k| LinkKind::from_key(k).expect("validated above"))
            .collect(),
    };
    let systems = resolve_grid_systems(args, overlay.systems, "sweep")?;
    let categories = match args.sweep_categories.clone().or(overlay.categories) {
        None => None,
        Some(keys) => {
            let mut cats = Vec::new();
            for k in &keys {
                match Category::from_key(k) {
                    Some(c) => cats.push(c),
                    None => bail!("unknown category `{k}` in sweep grid"),
                }
            }
            Some(cats)
        }
    };
    let spec = SweepSpec { systems, tenants, quotas, gpu_counts: gpus, links, categories };
    Ok(SweepInputs { cfg, spec })
}

/// Run the sweep grid through the executor and emit the surface.
fn cmd_sweep(args: &Args) -> Result<()> {
    let SweepInputs { cfg, spec } = sweep_inputs(args)?;
    let surface = sweep::run_sweep(&cfg, &spec, cfg.jobs);
    eprintln!(
        "[gvbench] sweep: {} cells x {} metrics on {} workers in {:.2}s (busy/wall {:.2}x)",
        surface.cells.len(),
        surface.metric_ids.len(),
        surface.stats.jobs,
        surface.stats.wall_ns as f64 / 1e9,
        surface.stats.speedup_estimate(),
    );
    let format = Format::from_key(&args.format).expect("validated");
    let rendered = crate::report::sweep::render(&surface, format);
    match &args.out {
        Some(path) => {
            std::fs::write(path, &rendered).with_context(|| format!("writing {path}"))?;
            eprintln!("wrote {path}");
        }
        None => print!("{rendered}"),
    }
    write_wall_trace(args, &surface.stats)?;
    Ok(())
}

/// Resolve the systems a grid command evaluates (CLI flags > config
/// overlay > all Table-2 systems).
fn resolve_grid_systems(
    args: &Args,
    overlay_systems: Option<Vec<String>>,
    section: &str,
) -> Result<Vec<String>> {
    if args.all_systems {
        return Ok(ALL_SYSTEMS.iter().map(|s| s.to_string()).collect());
    }
    if let Some(ss) = args.sweep_systems.clone() {
        return Ok(ss);
    }
    if args.system_set {
        return Ok(vec![args.system.clone()]);
    }
    if let Some(ss) = overlay_systems {
        for s in &ss {
            if crate::virt::by_name(s).is_none() {
                bail!("unknown system `{s}` in [{section}] config");
            }
        }
        return Ok(ss);
    }
    Ok(ALL_SYSTEMS.iter().map(|s| s.to_string()).collect())
}

/// The resolved inputs of a dynamics invocation — shared by
/// `gvbench dynamics` and served dynamics jobs.
pub struct DynInputs {
    pub cfg: RunConfig,
    pub spec: DynSpec,
}

/// Build the dynamics grid (CLI flags > config-file `[dynsim]` section >
/// defaults).
pub fn dynamics_inputs(args: &Args) -> Result<DynInputs> {
    let file = load_file_config(args)?;
    let cfg = build_config_with(args, file.as_ref())?;
    let overlay = match file.as_ref() {
        Some(fc) => fc.dynsim()?,
        None => DynOverlay::default(),
    };
    if let Some(tr) = load_trace_spec(args)? {
        // The trace file is the whole grid: its headers carry the
        // geometry, and the arg parser already rejected
        // --scenario/--duration-ms/--window-ms alongside --trace.
        let systems = resolve_grid_systems(args, overlay.systems, "dynsim")?;
        let spec = DynSpec {
            systems,
            scenarios: vec![dynsim::TRACE_SCENARIO],
            duration_ms: tr.duration_ms,
            window_ms: tr.window_ms,
            trace: Some(tr),
        };
        return Ok(DynInputs { cfg, spec });
    }
    let scenario_keys = args.dyn_scenarios.clone().or(overlay.scenarios);
    let duration_ms = args
        .duration_ms
        .or(overlay.duration_ms)
        .unwrap_or(dynsim::DEFAULT_DURATION_MS);
    let window_ms = args
        .window_ms
        .or(overlay.window_ms)
        .unwrap_or_else(|| dynsim::DEFAULT_WINDOW_MS.min(duration_ms));
    // One validation path for CLI flags and config-file keys alike.
    if let Err(e) = super::args::validate_dynamics_grid(
        scenario_keys.as_deref(),
        Some(duration_ms),
        Some(window_ms),
    ) {
        bail!("{e} in dynamics grid");
    }
    let scenarios: Vec<&'static str> = match scenario_keys {
        None => dynsim::PRESETS.to_vec(),
        Some(keys) => keys
            .iter()
            .map(|k| dynsim::scenario::canonical(k).expect("validated above"))
            .collect(),
    };
    let systems = resolve_grid_systems(args, overlay.systems, "dynsim")?;
    let spec = DynSpec { systems, scenarios, duration_ms, window_ms, trace: None };
    Ok(DynInputs { cfg, spec })
}

/// Replay the dynamics grid through the executor and emit the surface.
fn cmd_dynamics(args: &Args) -> Result<()> {
    let DynInputs { cfg, spec } = dynamics_inputs(args)?;
    if let Some(path) = &args.export_trace {
        // The parser guaranteed exactly one preset --scenario. Render its
        // event timeline through the trace grammar so the exported file is
        // an editable fixture that `--trace` replays without loss.
        let name = spec.scenarios[0];
        let sc = ScenarioSpec::preset(name, spec.duration_ms, spec.window_ms)
            .expect("validated preset");
        std::fs::write(path, dynsim::render_trace(&sc))
            .with_context(|| format!("writing {path}"))?;
        eprintln!("wrote {path} (editable --trace fixture for `{name}`)");
        return Ok(());
    }
    let (surface, spans) = match &args.trace_out {
        Some(_) => dynsim::run_dynamics_traced(&cfg, &spec, cfg.jobs),
        None => (dynsim::run_dynamics(&cfg, &spec, cfg.jobs), Vec::new()),
    };
    eprintln!(
        "[gvbench] dynamics: {} timeline(s) x {} window(s) on {} workers in {:.2}s (busy/wall {:.2}x)",
        surface.runs.len(),
        surface.runs.first().map(|r| r.windows).unwrap_or(0),
        surface.stats.jobs,
        surface.stats.wall_ns as f64 / 1e9,
        surface.stats.speedup_estimate(),
    );
    let format = Format::from_key(&args.format).expect("validated");
    let rendered = crate::report::dynamics::render(&surface, format);
    match &args.out {
        Some(path) => {
            std::fs::write(path, &rendered).with_context(|| format!("writing {path}"))?;
            eprintln!("wrote {path}");
        }
        None => print!("{rendered}"),
    }
    if let Some(path) = &args.summary_out {
        std::fs::write(path, crate::report::dynamics::render_summary_csv(&surface))
            .with_context(|| format!("writing {path}"))?;
        eprintln!("wrote {path} (regress-compatible summary)");
    }
    if let Some(path) = &args.trace_out {
        // Virtual-time spans only: byte-identical at any --jobs.
        std::fs::write(path, crate::obs::chrome::render_virtual(&spans))
            .with_context(|| format!("writing {path}"))?;
        eprintln!("wrote {path} (virtual-time Chrome trace; open in Perfetto)");
    }
    Ok(())
}

/// The resolved inputs of a cluster invocation — shared by
/// `gvbench cluster` and served cluster jobs. The spec carries the
/// arrivals count, so served fleets replay exactly what the CLI would.
pub struct ClusterInputs {
    pub cfg: RunConfig,
    pub spec: ClusterSpec,
}

/// Build the cluster placement grid (CLI flags > config-file `[cluster]`
/// section > defaults).
pub fn cluster_inputs(args: &Args) -> Result<ClusterInputs> {
    let file = load_file_config(args)?;
    let cfg = build_config_with(args, file.as_ref())?;
    let overlay = match file.as_ref() {
        Some(fc) => fc.cluster()?,
        None => ClusterOverlay::default(),
    };
    let policy_keys = args.cluster_policies.clone().or(overlay.policies);
    let node_counts = args
        .cluster_nodes
        .clone()
        .or(overlay.nodes)
        .unwrap_or_else(|| cluster::DEFAULT_NODE_COUNTS.to_vec());
    let scenario_keys = args.dyn_scenarios.clone().or(overlay.scenarios);
    let arrivals = args.arrivals.or(overlay.arrivals).unwrap_or(cluster::DEFAULT_ARRIVALS);
    // One validation path for CLI flags and config-file keys alike.
    if let Err(e) = super::args::validate_cluster_grid(
        policy_keys.as_deref(),
        Some(&node_counts),
        Some(arrivals),
    ) {
        bail!("{e} in cluster grid");
    }
    if let Err(e) = super::args::validate_dynamics_grid(scenario_keys.as_deref(), None, None) {
        bail!("{e} in cluster grid");
    }
    let policies: Vec<&'static str> = match policy_keys {
        None => cluster::POLICIES.to_vec(),
        Some(keys) => keys
            .iter()
            .map(|k| cluster::canonical_policy(k).expect("validated above"))
            .collect(),
    };
    let scenarios: Vec<&'static str> = match scenario_keys {
        None => dynsim::PRESETS.to_vec(),
        Some(keys) => keys
            .iter()
            .map(|k| dynsim::scenario::canonical(k).expect("validated above"))
            .collect(),
    };
    let systems = resolve_grid_systems(args, overlay.systems, "cluster")?;
    let spec = ClusterSpec { systems, policies, node_counts, scenarios, arrivals };
    Ok(ClusterInputs { cfg, spec })
}

/// Replay the fleet grid through the executor and emit the surface.
fn cmd_cluster(args: &Args) -> Result<()> {
    let ClusterInputs { cfg, spec } = cluster_inputs(args)?;
    let arrivals = spec.arrivals;
    let (surface, spans) = match &args.trace_out {
        Some(_) => cluster::run_cluster_traced(&cfg, &spec, cfg.jobs),
        None => (cluster::run_cluster(&cfg, &spec, cfg.jobs), Vec::new()),
    };
    eprintln!(
        "[gvbench] cluster: {} fleet cell(s) x {} arrival(s) on {} workers in {:.2}s (busy/wall {:.2}x)",
        surface.runs.len(),
        surface.arrivals,
        surface.stats.jobs,
        surface.stats.wall_ns as f64 / 1e9,
        surface.stats.speedup_estimate(),
    );
    let format = Format::from_key(&args.format).expect("validated");
    let rendered = crate::report::cluster::render(&surface, format);
    match &args.out {
        Some(path) => {
            std::fs::write(path, &rendered).with_context(|| format!("writing {path}"))?;
            eprintln!("wrote {path}");
        }
        None => print!("{rendered}"),
    }
    if let Some(path) = &args.summary_out {
        if arrivals != cluster::DEFAULT_ARRIVALS {
            // The summary schema keys rows by (system, policy, nodes,
            // scenario, id) — no arrivals column — and regress replays
            // always use the default count, so a summary recorded at a
            // different count would never round-trip clean.
            eprintln!(
                "[gvbench] warning: --summary-out recorded at --arrivals {arrivals}; \
                 `gvbench regress` replays cluster baselines at the default {} arrivals",
                cluster::DEFAULT_ARRIVALS
            );
        }
        std::fs::write(path, crate::report::cluster::render_summary_csv(&surface))
            .with_context(|| format!("writing {path}"))?;
        eprintln!("wrote {path} (regress-compatible summary)");
    }
    if let Some(path) = &args.trace_out {
        // Virtual-time spans only: byte-identical at any --jobs.
        std::fs::write(path, crate::obs::chrome::render_virtual(&spans))
            .with_context(|| format!("writing {path}"))?;
        eprintln!("wrote {path} (virtual-time Chrome trace; open in Perfetto)");
    }
    Ok(())
}

fn build_runner(args: &Args, cfg: RunConfig) -> SuiteRunner {
    let mut runner = SuiteRunner::new(cfg);
    if let Some(m) = &args.metric {
        runner = runner.with_metrics(vec![m.clone()]);
    } else if let Some(c) = &args.category {
        let cat = Category::from_key(c).expect("validated");
        runner = runner.with_categories(vec![cat]);
    }
    runner
}

/// Run the suite for every requested system on `exec` and render the
/// combined report — the shared core of `gvbench run` and served run
/// jobs. Returns the rendered text plus the combined execution stats
/// (tasks from every system; worker count and summed wall time).
pub fn run_report_on(
    args: &Args,
    exec: &Backend<'_>,
    observer: Option<Observer>,
) -> Result<(String, ExecutionStats)> {
    let cfg = build_config(args)?;
    let mut runner = build_runner(args, cfg);
    let systems: Vec<&str> =
        if args.all_systems { ALL_SYSTEMS.to_vec() } else { vec![args.system.as_str()] };
    let format = Format::from_key(&args.format)
        .with_context(|| format!("unknown format `{}`", args.format))?;
    let mut rendered = String::new();
    let mut all_stats = ExecutionStats::default();
    for (i, system) in systems.iter().enumerate() {
        let system: &str = system;
        let suite = runner.run_on(system, exec, observer.clone());
        let baseline = runner.baseline().to_vec();
        let report =
            Report::new(system, &suite.results, &baseline, &suite.card).with_stats(&suite.stats);
        let text = report.render(format);
        if format == Format::Csv {
            // CSV concatenates as one table with a single header, so a
            // multi-system run stays parseable as a regress baseline.
            if i == 0 {
                rendered.push_str(&text);
            } else {
                rendered.push_str(text.split_once('\n').map(|(_, body)| body).unwrap_or(""));
            }
        } else {
            rendered.push_str(&text);
            rendered.push('\n');
        }
        eprintln!(
            "[gvbench] {system}: {} tasks on {} workers in {:.2}s (busy/wall {:.2}x)",
            suite.stats.tasks.len(),
            suite.stats.jobs,
            suite.stats.wall_ns as f64 / 1e9,
            suite.stats.speedup_estimate(),
        );
        all_stats.jobs = suite.stats.jobs;
        all_stats.wall_ns += suite.stats.wall_ns;
        all_stats.tasks.extend(suite.stats.tasks.iter().cloned());
    }
    Ok((rendered, all_stats))
}

fn cmd_run(args: &Args) -> Result<()> {
    // Same scoped-thread backend `runner.run` would pick; the daemon
    // calls `run_report_on` with its persistent pool instead.
    let jobs = build_config(args)?.jobs;
    let (rendered, all_stats) = run_report_on(args, &Backend::Scoped(jobs), None)?;
    let format = Format::from_key(&args.format).expect("validated");
    match &args.out {
        Some(path) => {
            std::fs::write(path, &rendered).with_context(|| format!("writing {path}"))?;
            eprintln!("wrote {path}");
            // CSV keeps the metric table parseable as a regress baseline;
            // executor timings go to a sidecar file instead.
            if format == Format::Csv {
                let tpath = format!("{path}.timings.csv");
                std::fs::write(&tpath, crate::report::csv::render_timings(&all_stats))
                    .with_context(|| format!("writing {tpath}"))?;
                eprintln!("wrote {tpath}");
            }
        }
        None => print!("{rendered}"),
    }
    write_wall_trace(args, &all_stats)?;
    Ok(())
}

/// Write the wall-clock executor trace for `run`/`sweep --trace-out`.
/// Host timings live here and nowhere else — the metric report stays
/// deterministic, the trace is expected to differ run to run.
fn write_wall_trace(args: &Args, stats: &ExecutionStats) -> Result<()> {
    if let Some(path) = &args.trace_out {
        std::fs::write(path, crate::obs::chrome::render_wall(stats))
            .with_context(|| format!("writing {path}"))?;
        eprintln!("wrote {path} (wall-clock Chrome trace; open in Perfetto)");
    }
    Ok(())
}

fn cmd_list(args: &Args) -> Result<()> {
    if args.list_systems {
        println!("Supported systems (Table 2):");
        println!("  native  Bare metal baseline");
        println!("  hami    HAMi-core-like CUDA interception");
        println!("  fcsp    BUD-FCSP-like enhanced SM partitioning");
        println!("  mig     Simulated ideal MIG (from specs)");
        println!("  timeslice  Kubernetes-style time slicing (no isolation; §1.2 extension)");
        return Ok(());
    }
    if args.list_categories {
        println!("{:<18} {:>6} {:>7}", "Category", "Count", "Weight");
        for c in Category::ALL {
            println!("{:<18} {:>6} {:>7.2}", c.name(), taxonomy::by_category(c).len(), c.weight());
        }
        return Ok(());
    }
    // Metric list (Table 1 overview, or Table 8 with --full).
    if args.list_full {
        for d in &taxonomy::ALL {
            println!(
                "{:<10} {:<34} [{:<8}] {:<16} {}",
                d.id,
                d.name,
                d.unit,
                d.category.name(),
                d.description
            );
        }
    } else {
        println!("{:<18} {:>6}  (use --full for all 56 metrics)", "Category", "Count");
        for c in Category::ALL {
            println!("{:<18} {:>6}", c.name(), taxonomy::by_category(c).len());
        }
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let mut cfg =
        if args.quick { RunConfig::quick("native") } else { RunConfig::for_system("native") };
    if let Some(v) = args.jobs {
        cfg.jobs = v;
    }
    let mut runner = SuiteRunner::new(cfg);
    println!("Running the full 56-metric suite for all systems (this runs");
    println!("the simulated A100 in virtual time; ~seconds per system)...\n");
    println!("{:<12} {:>8} {:>12} {:>8}", "System", "Score", "MIG Parity", "Grade");
    println!("{}", "-".repeat(44));
    for system in ["mig", "native", "fcsp", "hami"] {
        let suite = runner.run(system);
        println!(
            "{:<12} {:>7.1}% {:>11.1}% {:>8}",
            system,
            suite.card.overall * 100.0,
            suite.card.mig_parity_percent(),
            suite.card.grade().letter()
        );
    }
    Ok(())
}

/// Socket path for the serve daemon and its clients
/// (`--socket` > `<temp-dir>/gvbench.sock`).
fn resolve_socket(args: &Args) -> std::path::PathBuf {
    match &args.socket {
        Some(s) => std::path::PathBuf::from(s),
        None => std::env::temp_dir().join("gvbench.sock"),
    }
}

/// Resolve the job argv of a `submit`: the inline `--` tail, or one
/// token per line from `--spec-file` (blank lines and `#` comments
/// skipped).
fn job_argv(args: &Args) -> Result<Vec<String>> {
    if let Some(path) = &args.spec_file {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let argv: Vec<String> = text
            .lines()
            .map(str::trim)
            .filter(|l| !l.is_empty() && !l.starts_with('#'))
            .map(String::from)
            .collect();
        if argv.is_empty() {
            bail!("spec file {path} contains no job arguments");
        }
        return Ok(argv);
    }
    Ok(args.job_argv.clone().expect("validated"))
}

/// `gvbench serve`: run the benchmark daemon in the foreground until a
/// client sends the shutdown op.
fn cmd_serve(args: &Args) -> Result<()> {
    let socket = resolve_socket(args);
    let daemon = crate::serve::Daemon::start(crate::serve::ServeConfig {
        socket: socket.clone(),
        jobs: args.jobs.unwrap_or(0),
    })?;
    eprintln!(
        "[gvbench] serve: listening on {} with {} pool worker(s); \
         stop with `gvbench jobs --socket {} --shutdown`",
        socket.display(),
        daemon.workers(),
        socket.display(),
    );
    daemon.wait()
}

/// `gvbench submit`: submit one job, mirror its lifecycle events to
/// stderr, and deliver the report to `--out` or stdout. The exit status
/// follows the job: a failed job — or a served regress gate that found
/// regressions — exits non-zero, like its one-shot equivalent.
fn cmd_submit(args: &Args) -> Result<()> {
    let socket = resolve_socket(args);
    let argv = job_argv(args)?;
    let outcome = crate::serve::client::submit_and_wait(
        &socket,
        &argv,
        args.priority,
        &mut |line: &str| eprintln!("{line}"),
    )?;
    if let Some(e) = outcome.error {
        bail!("job {} failed: {e}", outcome.job);
    }
    let report = outcome.report.unwrap_or_default();
    match &args.out {
        Some(path) => {
            std::fs::write(path, &report).with_context(|| format!("writing {path}"))?;
            eprintln!("wrote {path}");
        }
        None => print!("{report}"),
    }
    if outcome.passed == Some(false) {
        bail!("job {} reported regressions (gate failed)", outcome.job);
    }
    Ok(())
}

/// `gvbench jobs`: list the daemon's jobs, or drain and stop it with
/// `--shutdown`.
fn cmd_jobs(args: &Args) -> Result<()> {
    let socket = resolve_socket(args);
    if args.stats {
        let snap = crate::serve::client::stats(&socket)?;
        match args.stats_format.as_deref() {
            Some("prometheus") => print!("{}", snap.render_prometheus()),
            _ => print!("{}", snap.render_table()),
        }
        return Ok(());
    }
    if args.shutdown {
        crate::serve::client::shutdown(&socket)?;
        eprintln!(
            "[gvbench] daemon on {} acknowledged shutdown (draining accepted jobs)",
            socket.display()
        );
        return Ok(());
    }
    let rows = crate::serve::client::jobs(&socket)?;
    println!("{:<6} {:<10} {:<10} {:>8}", "JOB", "COMMAND", "STATE", "PRIORITY");
    for r in rows {
        println!("{:<6} {:<10} {:<10} {:>8}", r.job, r.command, r.state, r.priority);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn list_commands_run() {
        let mut a = Args::default();
        a.command = Command::List;
        assert!(dispatch(&a).is_ok());
        a.list_full = true;
        assert!(dispatch(&a).is_ok());
        a.list_full = false;
        a.list_systems = true;
        assert!(dispatch(&a).is_ok());
    }

    #[test]
    fn run_single_metric_txt() {
        let mut a = Args::default();
        a.command = Command::Run;
        a.system = "native".into();
        a.metric = Some("OH-009".into());
        a.quick = true;
        assert!(dispatch(&a).is_ok());
    }

    #[test]
    fn run_writes_output_file() {
        let mut a = Args::default();
        a.command = Command::Run;
        a.system = "hami".into();
        a.metric = Some("OH-009".into());
        a.quick = true;
        a.format = "json".into();
        let path = std::env::temp_dir().join("gvb_test_out.json");
        a.out = Some(path.to_str().unwrap().to_string());
        dispatch(&a).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"OH-009\""));
        assert!(text.contains("\"execution\""));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sweep_writes_surface_csv() {
        let mut a = Args::default();
        a.command = Command::Sweep;
        a.system = "native".into();
        a.system_set = true;
        a.quick = true;
        a.sweep_tenants = Some(vec![1, 2]);
        a.sweep_quotas = Some(vec![100]);
        a.sweep_categories = Some(vec!["pcie".into()]);
        a.format = "csv".into();
        let path = std::env::temp_dir().join("gvb_test_sweep.csv");
        a.out = Some(path.to_str().unwrap().to_string());
        dispatch(&a).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], crate::report::sweep::CSV_HEADER);
        // Long format: header + 2 cells × 4 PCIe metrics, on the default
        // 4-GPU PCIe node when no topology flags are given.
        assert_eq!(lines.len(), 9);
        assert!(lines[1].starts_with("native,1,100,4,pcie,true,true,PCIE-"));
        assert!(lines[5].starts_with("native,2,100,4,pcie,false,true,PCIE-"));
        // The written surface is directly consumable as a regress baseline.
        let b = crate::regress::parse_baseline_csv(&text, "native").unwrap();
        assert_eq!(b.rows.len(), 8);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sweep_topology_flags_expand_the_surface() {
        let mut a = Args::default();
        a.command = Command::Sweep;
        a.system = "native".into();
        a.system_set = true;
        a.quick = true;
        a.sweep_tenants = Some(vec![1]);
        a.sweep_quotas = Some(vec![100]);
        a.sweep_gpus = Some(vec![2, 4]);
        a.sweep_links = Some(vec!["nvlink".into(), "pcie".into()]);
        a.sweep_categories = Some(vec!["nccl".into()]);
        a.format = "csv".into();
        let path = std::env::temp_dir().join("gvb_test_sweep_topo.csv");
        a.out = Some(path.to_str().unwrap().to_string());
        dispatch(&a).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        // Header + 1 scenario × 4 topologies × 4 NCCL metrics.
        assert_eq!(text.lines().count(), 17);
        assert!(text.contains("native,1,100,2,nvlink,true,true,NCCL-"), "{text}");
        assert!(text.contains("native,1,100,4,pcie,true,true,NCCL-"), "{text}");
        // Unknown link keys are rejected before any work runs.
        a.sweep_links = Some(vec!["sli".into()]);
        assert!(dispatch(&a).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn dynamics_writes_series_and_summary_and_summary_regresses_clean() {
        let dir = std::env::temp_dir();
        let series_path = dir.join("gvb_test_dyn_series.csv");
        let summary_path = dir.join("gvb_test_dyn_summary.csv");
        let mut a = Args::default();
        a.command = Command::Dynamics;
        a.system = "native".into();
        a.system_set = true;
        a.quick = true;
        a.dyn_scenarios = Some(vec!["steady".into()]);
        a.duration_ms = Some(200);
        a.window_ms = Some(50);
        a.format = "csv".into();
        a.out = Some(series_path.to_str().unwrap().to_string());
        a.summary_out = Some(summary_path.to_str().unwrap().to_string());
        dispatch(&a).unwrap();
        let series = std::fs::read_to_string(&series_path).unwrap();
        let lines: Vec<&str> = series.lines().collect();
        assert_eq!(lines[0], crate::report::dynamics::CSV_HEADER);
        // 4 windows × (6 aggregate + 2 per-tenant × 4 tenants) series.
        assert_eq!(lines.len(), 1 + 4 * (6 + 8));
        assert!(lines[1].starts_with("native,steady,200,50,0,50,all,DYN-LAT-P50,"));
        // The summary CSV is directly consumable by `gvbench regress`
        // and passes against itself.
        let summary = std::fs::read_to_string(&summary_path).unwrap();
        let b = crate::regress::parse_baseline_csv(&summary, "native").unwrap();
        assert_eq!(b.schema, crate::regress::BaselineSchema::Dynamics);
        assert_eq!(b.rows.len(), 5);
        let cfg = RunConfig::quick("native");
        let out = crate::regress::run_regression(&cfg, &b, 0.0001).unwrap();
        assert!(out.passed(), "{:?}", out.regressions());
        std::fs::remove_file(&series_path).ok();
        std::fs::remove_file(&summary_path).ok();
    }

    #[test]
    fn dynamics_trace_run_writes_summary_that_regresses_with_the_trace() {
        let dir = std::env::temp_dir();
        let trace_path = dir.join("gvb_test_cmd_trace.txt");
        let summary_path = dir.join("gvb_test_cmd_trace_summary.csv");
        std::fs::write(
            &trace_path,
            "duration-ms 200\nwindow-ms 50\n\
             at 0 arrive 1 infer rate=30 quota=40\n\
             at 50 arrive 2 train rate=10 quota=40\n",
        )
        .unwrap();
        let mut a = Args::default();
        a.command = Command::Dynamics;
        a.system = "native".into();
        a.system_set = true;
        a.quick = true;
        a.trace = Some(trace_path.to_str().unwrap().to_string());
        a.summary_out = Some(summary_path.to_str().unwrap().to_string());
        dispatch(&a).unwrap();
        // The replay rode the reserved `trace` scenario coordinate and —
        // because the trace carries a training tenant — emitted the
        // training statistics alongside the classic five.
        let summary = std::fs::read_to_string(&summary_path).unwrap();
        assert!(summary.contains(",trace,"), "{summary}");
        assert!(summary.contains("DYN-TRAIN-STEP-P99"), "{summary}");
        // The summary round-trips through `gvbench regress --trace`…
        let mut r = Args::default();
        r.command = Command::Regress;
        r.quick = true;
        r.threshold = 0.0001;
        r.baseline = Some(summary_path.to_str().unwrap().to_string());
        r.trace = Some(trace_path.to_str().unwrap().to_string());
        dispatch(&r).unwrap();
        // …and without the trace the gate fails up front, naming the flag.
        r.trace = None;
        let e = dispatch(&r).unwrap_err();
        assert!(format!("{e:#}").contains("--trace"), "{e:#}");
        std::fs::remove_file(&trace_path).ok();
        std::fs::remove_file(&summary_path).ok();
    }

    #[test]
    fn cluster_writes_fleet_and_summary_and_summary_regresses_clean() {
        let dir = std::env::temp_dir();
        let fleet_path = dir.join("gvb_test_cluster_fleet.csv");
        let summary_path = dir.join("gvb_test_cluster_summary.csv");
        let mut a = Args::default();
        a.command = Command::Cluster;
        a.system = "native".into();
        a.system_set = true;
        a.quick = true;
        a.cluster_policies = Some(vec!["first-fit".into()]);
        a.cluster_nodes = Some(vec![2]);
        a.dyn_scenarios = Some(vec!["churn".into()]);
        a.format = "csv".into();
        a.out = Some(fleet_path.to_str().unwrap().to_string());
        a.summary_out = Some(summary_path.to_str().unwrap().to_string());
        dispatch(&a).unwrap();
        let fleet = std::fs::read_to_string(&fleet_path).unwrap();
        let lines: Vec<&str> = fleet.lines().collect();
        assert_eq!(lines[0], crate::report::cluster::CSV_HEADER);
        // Header + one row per node of the single fleet cell.
        assert_eq!(lines.len(), 3);
        assert!(lines[1].starts_with("native,first-fit,2,churn,0,"), "{fleet}");
        // The summary CSV is directly consumable by `gvbench regress`
        // and passes against itself.
        let summary = std::fs::read_to_string(&summary_path).unwrap();
        let b = crate::regress::parse_baseline_csv(&summary, "native").unwrap();
        assert_eq!(b.schema, crate::regress::BaselineSchema::Cluster);
        assert_eq!(b.rows.len(), 5);
        assert_eq!(b.rows[0].cell_label(), "first-fit@2n/churn");
        let cfg = RunConfig::quick("native");
        let out = crate::regress::run_regression(&cfg, &b, 0.0001).unwrap();
        assert!(out.passed(), "{:?}", out.regressions());
        std::fs::remove_file(&fleet_path).ok();
        std::fs::remove_file(&summary_path).ok();
    }

    #[test]
    fn cluster_rejects_bad_grid_values_from_config_path() {
        let mut a = Args::default();
        a.command = Command::Cluster;
        a.quick = true;
        a.cluster_nodes = Some(vec![0]);
        assert!(dispatch(&a).is_err());
        a.cluster_nodes = None;
        a.cluster_policies = Some(vec!["worst-fit".into()]);
        assert!(dispatch(&a).is_err());
    }

    #[test]
    fn all_systems_csv_is_one_table() {
        let mut a = Args::default();
        a.command = Command::Run;
        a.all_systems = true;
        a.metric = Some("OH-009".into());
        a.quick = true;
        a.format = "csv".into();
        let path = std::env::temp_dir().join("gvb_test_all_systems.csv");
        let path_str = path.to_str().unwrap().to_string();
        a.out = Some(path_str.clone());
        dispatch(&a).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        // Exactly one header; one row per system; no blank separators —
        // i.e. directly usable as a multi-system regress baseline.
        assert_eq!(text.lines().filter(|l| l.starts_with("id,")).count(), 1);
        assert_eq!(text.lines().count(), 5);
        let b = crate::regress::parse_baseline_csv(&text, "native").unwrap();
        assert_eq!(b.schema, crate::regress::BaselineSchema::Point);
        assert_eq!(b.rows.len(), 4);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(format!("{path_str}.timings.csv")).ok();
    }

    #[test]
    fn regress_cmd_writes_reports_and_passes_on_own_baseline() {
        use crate::coordinator::executor;
        // Produce a tiny point baseline the same way `gvbench run` derives
        // its values, then regress against it with report outputs.
        let cfg = RunConfig::quick("native");
        let tasks = vec![executor::Task { system: "native".into(), metric_id: "OH-009" }];
        let (results, _) = executor::execute(&cfg, &tasks, 1);
        let csv = format!("id,system,value\nOH-009,native,{:.6}\n", results[0].value);
        let dir = std::env::temp_dir();
        let bpath = dir.join("gvb_test_regress_baseline.csv");
        let jpath = dir.join("gvb_test_regress_report.json");
        let mpath = dir.join("gvb_test_regress_report.md");
        std::fs::write(&bpath, csv).unwrap();
        let mut a = Args::default();
        a.command = Command::Regress;
        a.quick = true;
        a.baseline = Some(bpath.to_str().unwrap().to_string());
        a.report_json = Some(jpath.to_str().unwrap().to_string());
        a.report_md = Some(mpath.to_str().unwrap().to_string());
        dispatch(&a).unwrap();
        let j = std::fs::read_to_string(&jpath).unwrap();
        assert!(j.contains("\"passed\": true"), "{j}");
        assert!(j.contains("\"schema\": \"point\""), "{j}");
        let m = std::fs::read_to_string(&mpath).unwrap();
        assert!(m.contains("PASS"), "{m}");
        for p in [&bpath, &jpath, &mpath] {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn spec_file_yields_one_token_per_line_skipping_comments() {
        let path = std::env::temp_dir().join("gvb_test_specfile.txt");
        std::fs::write(&path, "# a served quick run\nrun\n--system\nnative\n\n--quick\n")
            .unwrap();
        let mut a = Args::default();
        a.spec_file = Some(path.to_str().unwrap().to_string());
        let argv = job_argv(&a).unwrap();
        assert_eq!(argv, vec!["run", "--system", "native", "--quick"]);
        // An all-comment file is an error, not an empty job.
        std::fs::write(&path, "# nothing here\n\n").unwrap();
        assert!(job_argv(&a).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn grid_input_builders_resolve_defaults() {
        let mut a = Args::default();
        a.quick = true;
        let s = sweep_inputs(&a).unwrap();
        assert_eq!(s.spec.tenants, vec![1, 2, 4, 8]);
        assert_eq!(s.spec.quotas, vec![25, 50, 100]);
        assert_eq!(s.spec.systems.len(), ALL_SYSTEMS.len());
        let d = dynamics_inputs(&a).unwrap();
        assert_eq!(d.spec.scenarios, dynsim::PRESETS.to_vec());
        let c = cluster_inputs(&a).unwrap();
        assert_eq!(c.spec.arrivals, cluster::DEFAULT_ARRIVALS);
        assert_eq!(c.spec.node_counts, cluster::DEFAULT_NODE_COUNTS.to_vec());
    }

    #[test]
    fn run_report_on_scoped_matches_cmd_run_rendering() {
        // The serve daemon's run path and the CLI's must agree byte-for-
        // byte; CSV avoids the host-timing execution object JSON embeds.
        let mut a = Args::default();
        a.command = Command::Run;
        a.system = "native".into();
        a.metric = Some("OH-009".into());
        a.quick = true;
        a.format = "csv".into();
        let (one, _) = run_report_on(&a, &Backend::Scoped(1), None).unwrap();
        let (eight, _) = run_report_on(&a, &Backend::Scoped(8), None).unwrap();
        assert_eq!(one, eight);
        assert!(one.starts_with("id,"));
    }

    #[test]
    fn csv_out_writes_timings_sidecar() {
        let mut a = Args::default();
        a.command = Command::Run;
        a.system = "native".into();
        a.metric = Some("OH-009".into());
        a.quick = true;
        a.format = "csv".into();
        let path = std::env::temp_dir().join("gvb_test_out.csv");
        let path_str = path.to_str().unwrap().to_string();
        a.out = Some(path_str.clone());
        dispatch(&a).unwrap();
        let main = std::fs::read_to_string(&path).unwrap();
        assert!(main.starts_with("id,"));
        let tpath = format!("{path_str}.timings.csv");
        let timings = std::fs::read_to_string(&tpath).unwrap();
        assert!(timings.starts_with("metric_id,system,worker,wall_ms"));
        assert!(timings.contains("OH-009,native,"));
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&tpath).ok();
    }
}
