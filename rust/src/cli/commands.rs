//! Command implementations for `gvbench`.

use crate::anyhow::{bail, Context, Result};

use crate::config::FileConfig;
use crate::coordinator::SuiteRunner;
use crate::metrics::{taxonomy, Category, RunConfig};
use crate::report::{Format, Report};
use crate::virt::ALL_SYSTEMS;

use super::args::{Args, Command, USAGE};

/// Dispatch the parsed command.
pub fn dispatch(args: &Args) -> Result<()> {
    match args.command {
        Command::Help => {
            println!("{USAGE}");
            Ok(())
        }
        Command::List => cmd_list(args),
        Command::Run => cmd_run(args),
        Command::Compare => cmd_compare(args),
        Command::Regress => cmd_regress(args),
    }
}

fn cmd_regress(args: &Args) -> Result<()> {
    let path = args.baseline.as_ref().expect("validated");
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let baseline = super::regress::parse_baseline_csv(&text)?;
    let cfg = build_config(args)?;
    println!(
        "Regression check: system={}, {} baseline metrics, threshold {:.1}%",
        cfg.system,
        baseline.len(),
        args.threshold
    );
    let (regressions, checked) = super::regress::run_regression(&cfg, &baseline, args.threshold)?;
    if regressions.is_empty() {
        println!("OK — {checked} metrics within threshold.");
        return Ok(());
    }
    println!("{} regressions / {checked} metrics:", regressions.len());
    for r in &regressions {
        let d = taxonomy::by_id(&r.id).unwrap();
        println!(
            "  {:<10} {:<32} {:.3} -> {:.3} {}  ({:+.1}% worse)",
            r.id, d.name, r.baseline, r.current, d.unit, r.regression_percent
        );
    }
    bail!("{} metric(s) regressed beyond {:.1}%", regressions.len(), args.threshold)
}

fn build_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = if args.quick {
        RunConfig::quick(&args.system)
    } else {
        RunConfig::for_system(&args.system)
    };
    if let Some(path) = &args.config {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        cfg = FileConfig::parse(&text)?.apply(cfg)?;
    }
    if let Some(v) = args.iterations {
        cfg.iterations = v;
    }
    if let Some(v) = args.warmup {
        cfg.warmup = v;
    }
    if let Some(v) = args.tenants {
        cfg.tenants = v;
    }
    if let Some(v) = args.seed {
        cfg.seed = v;
    }
    if let Some(v) = args.jobs {
        cfg.jobs = v;
    }
    Ok(cfg)
}

fn build_runner(args: &Args, cfg: RunConfig) -> SuiteRunner {
    let mut runner = SuiteRunner::new(cfg);
    if let Some(m) = &args.metric {
        runner = runner.with_metrics(vec![m.clone()]);
    } else if let Some(c) = &args.category {
        let cat = Category::from_key(c).expect("validated");
        runner = runner.with_categories(vec![cat]);
    }
    runner
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = build_config(args)?;
    let mut runner = build_runner(args, cfg);
    let systems: Vec<&str> =
        if args.all_systems { ALL_SYSTEMS.to_vec() } else { vec![args.system.as_str()] };
    let format = Format::from_key(&args.format).expect("validated");
    let mut rendered = String::new();
    let mut all_stats = crate::coordinator::executor::ExecutionStats::default();
    for system in systems {
        let suite = runner.run(system);
        let baseline = runner.baseline().to_vec();
        let report =
            Report::new(system, &suite.results, &baseline, &suite.card).with_stats(&suite.stats);
        rendered.push_str(&report.render(format));
        rendered.push('\n');
        eprintln!(
            "[gvbench] {system}: {} tasks on {} workers in {:.2}s (busy/wall {:.2}x)",
            suite.stats.tasks.len(),
            suite.stats.jobs,
            suite.stats.wall_ns as f64 / 1e9,
            suite.stats.speedup_estimate(),
        );
        all_stats.tasks.extend(suite.stats.tasks.iter().cloned());
    }
    match &args.out {
        Some(path) => {
            std::fs::write(path, &rendered).with_context(|| format!("writing {path}"))?;
            eprintln!("wrote {path}");
            // CSV keeps the metric table parseable as a regress baseline;
            // executor timings go to a sidecar file instead.
            if format == Format::Csv {
                let tpath = format!("{path}.timings.csv");
                std::fs::write(&tpath, crate::report::csv::render_timings(&all_stats))
                    .with_context(|| format!("writing {tpath}"))?;
                eprintln!("wrote {tpath}");
            }
        }
        None => print!("{rendered}"),
    }
    Ok(())
}

fn cmd_list(args: &Args) -> Result<()> {
    if args.list_systems {
        println!("Supported systems (Table 2):");
        println!("  native  Bare metal baseline");
        println!("  hami    HAMi-core-like CUDA interception");
        println!("  fcsp    BUD-FCSP-like enhanced SM partitioning");
        println!("  mig     Simulated ideal MIG (from specs)");
        println!("  timeslice  Kubernetes-style time slicing (no isolation; §1.2 extension)");
        return Ok(());
    }
    if args.list_categories {
        println!("{:<18} {:>6} {:>7}", "Category", "Count", "Weight");
        for c in Category::ALL {
            println!("{:<18} {:>6} {:>7.2}", c.name(), taxonomy::by_category(c).len(), c.weight());
        }
        return Ok(());
    }
    // Metric list (Table 1 overview, or Table 8 with --full).
    if args.list_full {
        for d in &taxonomy::ALL {
            println!(
                "{:<10} {:<34} [{:<8}] {:<16} {}",
                d.id,
                d.name,
                d.unit,
                d.category.name(),
                d.description
            );
        }
    } else {
        println!("{:<18} {:>6}  (use --full for all 56 metrics)", "Category", "Count");
        for c in Category::ALL {
            println!("{:<18} {:>6}", c.name(), taxonomy::by_category(c).len());
        }
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let mut cfg =
        if args.quick { RunConfig::quick("native") } else { RunConfig::for_system("native") };
    if let Some(v) = args.jobs {
        cfg.jobs = v;
    }
    let mut runner = SuiteRunner::new(cfg);
    println!("Running the full 56-metric suite for all systems (this runs");
    println!("the simulated A100 in virtual time; ~seconds per system)...\n");
    println!("{:<12} {:>8} {:>12} {:>8}", "System", "Score", "MIG Parity", "Grade");
    println!("{}", "-".repeat(44));
    for system in ["mig", "native", "fcsp", "hami"] {
        let suite = runner.run(system);
        println!(
            "{:<12} {:>7.1}% {:>11.1}% {:>8}",
            system,
            suite.card.overall * 100.0,
            suite.card.mig_parity_percent(),
            suite.card.grade().letter()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn list_commands_run() {
        let mut a = Args::default();
        a.command = Command::List;
        assert!(dispatch(&a).is_ok());
        a.list_full = true;
        assert!(dispatch(&a).is_ok());
        a.list_full = false;
        a.list_systems = true;
        assert!(dispatch(&a).is_ok());
    }

    #[test]
    fn run_single_metric_txt() {
        let mut a = Args::default();
        a.command = Command::Run;
        a.system = "native".into();
        a.metric = Some("OH-009".into());
        a.quick = true;
        assert!(dispatch(&a).is_ok());
    }

    #[test]
    fn run_writes_output_file() {
        let mut a = Args::default();
        a.command = Command::Run;
        a.system = "hami".into();
        a.metric = Some("OH-009".into());
        a.quick = true;
        a.format = "json".into();
        let path = std::env::temp_dir().join("gvb_test_out.json");
        a.out = Some(path.to_str().unwrap().to_string());
        dispatch(&a).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"OH-009\""));
        assert!(text.contains("\"execution\""));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn csv_out_writes_timings_sidecar() {
        let mut a = Args::default();
        a.command = Command::Run;
        a.system = "native".into();
        a.metric = Some("OH-009".into());
        a.quick = true;
        a.format = "csv".into();
        let path = std::env::temp_dir().join("gvb_test_out.csv");
        let path_str = path.to_str().unwrap().to_string();
        a.out = Some(path_str.clone());
        dispatch(&a).unwrap();
        let main = std::fs::read_to_string(&path).unwrap();
        assert!(main.starts_with("id,"));
        let tpath = format!("{path_str}.timings.csv");
        let timings = std::fs::read_to_string(&tpath).unwrap();
        assert!(timings.starts_with("metric_id,system,worker,wall_ms"));
        assert!(timings.contains("OH-009,native,"));
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&tpath).ok();
    }
}
