//! Human-readable formatting of byte sizes, durations and rates used by the
//! TXT report writer and CLI output.

/// Format a byte count with binary units (`KiB`, `MiB`, `GiB`).
pub fn bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a nanosecond duration with an adaptive unit (ns/µs/ms/s).
pub fn duration_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Format a bandwidth in GB/s from bytes and nanoseconds.
pub fn bandwidth_gbps(bytes: f64, ns: f64) -> String {
    if ns <= 0.0 {
        return "inf".to_string();
    }
    format!("{:.2} GB/s", bytes / ns) // bytes/ns == GB/s
}

/// Format a ratio as a percentage with one decimal.
pub fn percent(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(2048), "2.00 KiB");
        assert_eq!(bytes(40 * 1024 * 1024 * 1024), "40.00 GiB");
    }

    #[test]
    fn duration_units() {
        assert_eq!(duration_ns(500.0), "500.0 ns");
        assert_eq!(duration_ns(4_200.0), "4.20 µs");
        assert_eq!(duration_ns(3_000_000.0), "3.00 ms");
        assert_eq!(duration_ns(2.5e9), "2.500 s");
    }

    #[test]
    fn bandwidth() {
        // 1555 GB in 1 s.
        assert_eq!(bandwidth_gbps(1555e9, 1e9), "1555.00 GB/s");
    }

    #[test]
    fn percent_fmt() {
        assert_eq!(percent(0.852), "85.2%");
    }
}
