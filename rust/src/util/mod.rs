//! Small shared utilities: deterministic RNG and human-readable formatting.
//!
//! The offline build environment has no `rand` crate, so [`rng`] implements
//! the SplitMix64 and xoshiro256** generators from the reference
//! implementations (Blackman & Vigna). These are used everywhere a seeded,
//! reproducible stream of pseudo-random numbers is needed (jitter models,
//! workload generators, property tests).

pub mod fmt;
pub mod rng;

pub use rng::Rng;
