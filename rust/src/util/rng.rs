//! Deterministic pseudo-random number generation.
//!
//! `SplitMix64` seeds `Xoshiro256StarStar` (the recommended pairing from
//! Blackman & Vigna, "Scrambled Linear Pseudorandom Number Generators").
//! All simulator jitter, workload arrival processes and property-test
//! generators draw from this so a fixed `--seed` reproduces a run bit-for-bit.

/// SplitMix64 step — used for seeding and as a cheap standalone generator.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Derive the per-task RNG seed for one (system, metric) cell of the
/// evaluation matrix: a pure function of the run seed and the task
/// coordinates, so the parallel executor produces bit-identical results at
/// any worker count and any completion order.
///
/// Construction: FNV-1a over `system \0 metric_id` (the separator prevents
/// concatenation aliasing), folded into the run seed, finalized with one
/// SplitMix64 step. SplitMix64's finalizer is a bijection, so two tasks
/// collide only if the FNV hashes of their (short, distinct) coordinate
/// strings collide — `prop_invariants` checks all 224 pairs stay distinct.
pub fn task_seed(seed: u64, system: &str, metric_id: &str) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325; // FNV-1a offset basis
    for b in system.bytes().chain(std::iter::once(0u8)).chain(metric_id.bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001B3); // FNV-1a prime
    }
    let mut state = seed.wrapping_add(h);
    splitmix64(&mut state)
}

/// Derive the scenario-level seed for one sweep cell's (tenant count,
/// quota percent) coordinates. The sweep subsystem composes this with
/// [`task_seed`] — the per-task seed of a sweep cell is
/// `task_seed(scenario_seed(run_seed, tenants, quota_pct), system,
/// metric_id)` — so every cell of a (systems × tenants × quotas × metrics)
/// matrix is a pure function of the run seed and its coordinates, and a
/// sweep is bit-identical at any `--jobs` count.
///
/// Construction mirrors [`task_seed`]: FNV-1a over the two fixed-width
/// little-endian coordinate encodings (fixed widths make aliasing
/// impossible; the 0xFF separator is belt-and-braces), folded into the run
/// seed and finalized with one SplitMix64 step. `prop_invariants` checks
/// the composed seeds stay collision-free across the full expanded matrix.
pub fn scenario_seed(seed: u64, tenants: u32, quota_pct: u32) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325; // FNV-1a offset basis
    for b in tenants
        .to_le_bytes()
        .into_iter()
        .chain(std::iter::once(0xFFu8))
        .chain(quota_pct.to_le_bytes())
    {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001B3); // FNV-1a prime
    }
    let mut state = seed.wrapping_add(h);
    splitmix64(&mut state)
}

/// Derive the topology-level seed for one sweep cell's `(gpu_count,
/// link)` coordinates — the PR 4 extension of the sweep coordinate to
/// multi-GPU nodes. The sweep subsystem composes the full chain as
///
/// ```text
/// task_seed(topology_seed(scenario_seed(run_seed, tenants, quota_pct),
///                         gpu_count, link_key),
///           system, metric_id)
/// ```
///
/// so every cell of a (systems × tenants × quotas × gpu_counts × links ×
/// metrics) matrix is a pure function of the run seed and its
/// coordinates, and a sweep stays bit-identical at any `--jobs` count.
///
/// Construction mirrors [`scenario_seed`]: FNV-1a over the fixed-width
/// little-endian `gpu_count` encoding, a `0xFE` separator (distinct from
/// `scenario_seed`'s `0xFF`, so the two layers cannot alias even on
/// equal byte streams), and the link kind's stable key (`nvlink` /
/// `pcie`), folded into the incoming seed and finalized with one
/// SplitMix64 step. `prop_invariants` checks the composed seeds stay
/// collision-free across the fully expanded matrix.
pub fn topology_seed(seed: u64, gpu_count: u32, link_key: &str) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325; // FNV-1a offset basis
    for b in gpu_count
        .to_le_bytes()
        .into_iter()
        .chain(std::iter::once(0xFEu8))
        .chain(link_key.bytes())
    {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001B3); // FNV-1a prime
    }
    let mut state = seed.wrapping_add(h);
    splitmix64(&mut state)
}

/// Derive the dynamics-level seed for one `(scenario, duration_ms,
/// window_ms)` coordinate of a dynamic-scenario grid — the seed layer the
/// `dynsim` virtual-time engine folds under [`task_seed`]. The per-run
/// seed of one (system, scenario) dynamics task is
///
/// ```text
/// task_seed(dynamics_seed(run_seed, scenario, duration_ms, window_ms),
///           system, scenario)
/// ```
///
/// — a pure function of the run seed and the task's coordinates, so a
/// `gvbench dynamics` grid is bit-identical at any `--jobs` count and a
/// timeline re-runs exactly when the regression engine reconstructs it
/// from a summary baseline.
///
/// Construction mirrors [`topology_seed`]: FNV-1a over the scenario key,
/// a `0xFD` separator (distinct from `scenario_seed`'s `0xFF` and
/// `topology_seed`'s `0xFE`, so no two layers can alias even on equal
/// byte streams), and the fixed-width little-endian duration/window
/// encodings, folded into the run seed and finalized with one SplitMix64
/// step. `prop_invariants` checks the composed seeds stay collision-free
/// across a (systems × scenarios × durations × windows) grid.
pub fn dynamics_seed(seed: u64, scenario: &str, duration_ms: u64, window_ms: u64) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325; // FNV-1a offset basis
    for b in scenario
        .bytes()
        .chain(std::iter::once(0xFDu8))
        .chain(duration_ms.to_le_bytes())
        .chain(window_ms.to_le_bytes())
    {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001B3); // FNV-1a prime
    }
    let mut state = seed.wrapping_add(h);
    splitmix64(&mut state)
}

/// Derive the cluster-level seed for one `(policy, nodes, scenario)`
/// coordinate of a fleet placement grid — the seed layer the `cluster`
/// placement simulator folds under [`task_seed`]. The per-cell seed of
/// one (system, policy, nodes, scenario) fleet replay is
///
/// ```text
/// task_seed(cluster_seed(run_seed, policy, nodes, scenario),
///           system, scenario)
/// ```
///
/// — a pure function of the run seed and the cell's coordinates, so a
/// `gvbench cluster` grid is bit-identical at any `--jobs` count and a
/// fleet replay re-runs exactly when the regression engine reconstructs
/// it from a summary baseline.
///
/// Construction mirrors [`dynamics_seed`]: FNV-1a over the policy key, a
/// `0xFC` separator (distinct from `scenario_seed`'s `0xFF`,
/// `topology_seed`'s `0xFE` and `dynamics_seed`'s `0xFD`, so no two
/// layers can alias even on equal byte streams), the fixed-width
/// little-endian node count, a second `0xFC` separator, and the scenario
/// key, folded into the run seed and finalized with one SplitMix64 step.
/// `prop_invariants` checks the composed seeds stay collision-free
/// across the expanded (policy × nodes × scenario) matrix.
pub fn cluster_seed(seed: u64, policy: &str, nodes: u32, scenario: &str) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325; // FNV-1a offset basis
    for b in policy
        .bytes()
        .chain(std::iter::once(0xFCu8))
        .chain(nodes.to_le_bytes())
        .chain(std::iter::once(0xFCu8))
        .chain(scenario.bytes())
    {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001B3); // FNV-1a prime
    }
    let mut state = seed.wrapping_add(h);
    splitmix64(&mut state)
}

/// xoshiro256** — fast, high-quality, 256-bit state PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed via SplitMix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform u64 in `[0, n)`. Panics if `n == 0`.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        // Lemire's multiply-shift rejection method.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)` (half-open). Panics if `lo >= hi`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "Rng::range: empty range {lo}..{hi}");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple and
    /// adequate for jitter models).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal jitter multiplier with multiplicative sigma `s`
    /// (e.g. `s = 0.05` ⇒ ~5 % spread around 1.0). Used for latency noise.
    pub fn jitter(&mut self, s: f64) -> f64 {
        (self.normal() * s).exp()
    }

    /// Exponential inter-arrival sample with rate `lambda` (per unit time).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(f64::MIN_POSITIVE).ln() / lambda
    }

    /// Fork an independent stream (for per-tenant determinism regardless of
    /// thread interleaving).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments_roughly_correct() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.08, "var={var}");
    }

    #[test]
    fn jitter_centred_on_one() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let mean = (0..n).map(|_| r.jitter(0.05)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn task_seed_pure_and_sensitive() {
        // Stable across calls.
        assert_eq!(task_seed(42, "hami", "OH-001"), task_seed(42, "hami", "OH-001"));
        // Sensitive to every coordinate.
        assert_ne!(task_seed(42, "hami", "OH-001"), task_seed(43, "hami", "OH-001"));
        assert_ne!(task_seed(42, "hami", "OH-001"), task_seed(42, "fcsp", "OH-001"));
        assert_ne!(task_seed(42, "hami", "OH-001"), task_seed(42, "hami", "OH-002"));
        // Separator prevents concatenation aliasing.
        assert_ne!(task_seed(42, "ab", "c"), task_seed(42, "a", "bc"));
    }

    #[test]
    fn scenario_seed_pure_and_sensitive() {
        // Stable across calls.
        assert_eq!(scenario_seed(42, 4, 50), scenario_seed(42, 4, 50));
        // Sensitive to every coordinate.
        assert_ne!(scenario_seed(42, 4, 50), scenario_seed(43, 4, 50));
        assert_ne!(scenario_seed(42, 4, 50), scenario_seed(42, 8, 50));
        assert_ne!(scenario_seed(42, 4, 50), scenario_seed(42, 4, 100));
        // Coordinates don't alias across the field boundary.
        assert_ne!(scenario_seed(42, 1, 100), scenario_seed(42, 100, 1));
    }

    #[test]
    fn topology_seed_pure_and_sensitive() {
        // Stable across calls.
        assert_eq!(topology_seed(42, 4, "pcie"), topology_seed(42, 4, "pcie"));
        // Sensitive to every coordinate.
        assert_ne!(topology_seed(42, 4, "pcie"), topology_seed(43, 4, "pcie"));
        assert_ne!(topology_seed(42, 4, "pcie"), topology_seed(42, 8, "pcie"));
        assert_ne!(topology_seed(42, 4, "pcie"), topology_seed(42, 4, "nvlink"));
        // The 0xFE separator keeps this layer distinct from scenario_seed
        // even on coordinate values that encode to similar byte streams.
        assert_ne!(topology_seed(42, 4, ""), scenario_seed(42, 4, 0));
    }

    #[test]
    fn dynamics_seed_pure_and_sensitive() {
        // Stable across calls.
        assert_eq!(dynamics_seed(42, "churn", 1000, 100), dynamics_seed(42, "churn", 1000, 100));
        // Sensitive to every coordinate.
        assert_ne!(dynamics_seed(42, "churn", 1000, 100), dynamics_seed(43, "churn", 1000, 100));
        assert_ne!(dynamics_seed(42, "churn", 1000, 100), dynamics_seed(42, "spike", 1000, 100));
        assert_ne!(dynamics_seed(42, "churn", 1000, 100), dynamics_seed(42, "churn", 2000, 100));
        assert_ne!(dynamics_seed(42, "churn", 1000, 100), dynamics_seed(42, "churn", 1000, 50));
        // The 0xFD separator keeps this layer distinct from the sweep
        // layers even on byte streams that would otherwise coincide.
        assert_ne!(dynamics_seed(42, "", 4, 0), topology_seed(42, 4, ""));
        assert_ne!(dynamics_seed(42, "", 4, 0), scenario_seed(42, 4, 0));
    }

    #[test]
    fn cluster_seed_pure_and_sensitive() {
        // Stable across calls.
        assert_eq!(
            cluster_seed(42, "first-fit", 8, "churn"),
            cluster_seed(42, "first-fit", 8, "churn")
        );
        // Sensitive to every coordinate.
        assert_ne!(cluster_seed(42, "first-fit", 8, "churn"), cluster_seed(43, "first-fit", 8, "churn"));
        assert_ne!(cluster_seed(42, "first-fit", 8, "churn"), cluster_seed(42, "best-fit", 8, "churn"));
        assert_ne!(cluster_seed(42, "first-fit", 8, "churn"), cluster_seed(42, "first-fit", 16, "churn"));
        assert_ne!(cluster_seed(42, "first-fit", 8, "churn"), cluster_seed(42, "first-fit", 8, "spike"));
        // The 0xFC separator keeps this layer distinct from every other
        // seed layer even on byte streams that would otherwise coincide.
        assert_ne!(cluster_seed(42, "", 4, ""), dynamics_seed(42, "", 4, 0));
        assert_ne!(cluster_seed(42, "", 4, ""), topology_seed(42, 4, ""));
        assert_ne!(cluster_seed(42, "", 4, ""), scenario_seed(42, 4, 0));
    }

    #[test]
    fn scenario_and_task_seed_compose_distinctly() {
        // The composed per-task sweep seed distinguishes scenarios that
        // share (system, metric) coordinates.
        let a = task_seed(scenario_seed(42, 1, 100), "hami", "OH-001");
        let b = task_seed(scenario_seed(42, 4, 25), "hami", "OH-001");
        assert_ne!(a, b);
    }

    #[test]
    fn fork_streams_independent() {
        let mut a = Rng::new(3);
        let mut f1 = a.fork();
        let mut f2 = a.fork();
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
