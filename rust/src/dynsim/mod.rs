//! Virtual-time dynamic-scenario engine (`gvbench dynamics`).
//!
//! Every sweep cell is a *static point*: a fixed tenant population at a
//! fixed quota, measured at steady state. The deployment-critical
//! behaviours of multi-tenant GPU sharing — serving-tail latency,
//! scheduling under churn, fragmentation evolution, fault recovery — are
//! *temporal*: MISO (arXiv 2207.11428) and fragmentation-aware
//! scheduling (arXiv 2511.18906) both show they are dominated by
//! arrival/departure dynamics, not steady state. This subsystem makes
//! the timeline itself the unit of measurement:
//!
//! - [`scenario`] declares a timeline ([`ScenarioSpec`]): tenant
//!   arrive/depart/burst/fail events on a `duration_ms` horizon, with
//!   six named presets (`steady`, `churn`, `spike`, `failover`,
//!   `train-steady`, `mixed-churn`). Tenants carry a
//!   [`scenario::WorkloadKind`] — inference request streams or training
//!   jobs — so mixed train+infer populations are first-class timelines.
//! - [`trace`] parses external line-oriented trace files
//!   (`gvbench dynamics --trace FILE`) into a [`ScenarioSpec`] under the
//!   reserved [`scenario::TRACE_SCENARIO`] key, replaying recorded
//!   production timelines bit-identically at any `--jobs` count.
//! - [`engine`] replays one timeline against one virtualization backend
//!   on a discrete-event core: [`queue`]'s deterministic min-queue pops
//!   every occurrence (window boundary, scenario event, work arrival)
//!   in `(t, kind rank, key)` order; per-tenant Poisson request streams
//!   ([`crate::coordinator::workload::RequestGenerator`]) drive
//!   prefill/decode-phased LLM traffic and paced training streams
//!   ([`crate::coordinator::workload::TrainingGenerator`]) drive
//!   fwd/bwd/optimizer triples with gradient allreduce through the full
//!   `cudalite` driver path, and the run reduces to **windowed time
//!   series** (latency p50/p99, throughput, per-tenant SM/memory
//!   occupancy, fragmentation ratio, fault recovery time) plus
//!   per-scenario summary statistics, including the gateable
//!   `DYN-EVENTS` occurrence count and — on timelines with training
//!   tenants — the train-step/allreduce/interference statistics.
//!   The committed goldens under `rust/tests/goldens/` pin the event
//!   core's behavior (the frozen pre-rewrite engine has been retired).
//!   [`engine::run_scenario_traced`] additionally records virtual-time
//!   [`crate::obs::trace::VSpan`]s for Chrome trace export
//!   (`--trace-out`, see [`crate::obs`]).
//! - [`run_dynamics`] expands a [`DynSpec`] — systems × scenarios on one
//!   (duration, window) geometry, optionally carrying one parsed trace
//!   timeline — into one flat task list sharded through the parallel
//!   executor ([`crate::coordinator::executor::execute_indexed_with`]).
//!
//! **Determinism:** each (system, scenario) task derives its seed as
//! `task_seed(dynamics_seed(run_seed, scenario, duration_ms, window_ms),
//! system, scenario)` ([`crate::util::rng::dynamics_seed`]) — a pure
//! function of the task coordinates — so a dynamics grid is
//! bit-identical at any `--jobs` count (`rust/tests/
//! dynamics_determinism.rs`) and the regression engine can re-run a
//! summary baseline exactly ([`crate::regress`], `dynamics` schema).
//! Reporting lives in [`crate::report::dynamics`]; the operator guide in
//! `docs/dynamics.md`.

pub mod engine;
pub mod queue;
pub mod scenario;
pub mod trace;

pub use engine::{Recovery, ScenarioRun, SeriesPoint};
pub use scenario::{ScenarioSpec, PRESETS, TRACE_SCENARIO};
pub use trace::{parse_trace, render_trace};

use std::sync::Arc;

use crate::coordinator::executor::{self, Backend, ExecutionStats, Observer, Task, TaskDone};
use crate::metrics::RunConfig;
use crate::obs::trace::{SpanSink, TaskSpans};
use crate::util::rng::{dynamics_seed, task_seed};

/// Default timeline horizon, ms.
pub const DEFAULT_DURATION_MS: u64 = 1000;
/// Default reporting window, ms.
pub const DEFAULT_WINDOW_MS: u64 = 100;

/// A dynamics grid: which systems replay which scenario timelines, on
/// one (duration, window) reporting geometry.
#[derive(Clone, Debug)]
pub struct DynSpec {
    /// Backend keys (`native` / `hami` / `fcsp` / `mig` / `timeslice`).
    pub systems: Vec<String>,
    /// Canonical timeline keys: preset names (see [`scenario::PRESETS`])
    /// and/or [`TRACE_SCENARIO`] when `trace` is set.
    pub scenarios: Vec<&'static str>,
    pub duration_ms: u64,
    pub window_ms: u64,
    /// Parsed external trace timeline, replayed for every scenario entry
    /// equal to [`TRACE_SCENARIO`]. Its geometry (already validated by
    /// the parser/CLI) supplies `duration_ms`/`window_ms` when the grid
    /// runs a trace.
    pub trace: Option<ScenarioSpec>,
}

impl DynSpec {
    /// Derived per-task seed for one (system, scenario) run of this grid.
    pub fn run_seed(&self, base_seed: u64, system: &str, scenario: &str) -> u64 {
        task_seed(
            dynamics_seed(base_seed, scenario, self.duration_ms, self.window_ms),
            system,
            scenario,
        )
    }
}

/// A completed dynamics grid: every (system, scenario) timeline plus the
/// executor's timings.
pub struct DynSurface {
    /// The run seed the per-task dynamics seeds were derived from.
    pub seed: u64,
    pub duration_ms: u64,
    pub window_ms: u64,
    /// Runs in deterministic order: spec's system order (outer) ×
    /// scenario order (inner).
    pub runs: Vec<ScenarioRun>,
    pub stats: ExecutionStats,
}

/// Expand `spec` into one (system × scenario) task list, execute it on
/// `jobs` executor workers (0 = available parallelism), and collect the
/// timelines. `base` supplies the run seed and the backend-independent
/// config; system, scenario and per-task seeds are derived per task.
pub fn run_dynamics(base: &RunConfig, spec: &DynSpec, jobs: usize) -> DynSurface {
    run_dynamics_on(&Backend::Scoped(jobs), base, spec, None)
}

/// [`run_dynamics`] with virtual-time span tracing: the same surface
/// (bit-identical — see [`engine::run_scenario_traced`]) plus one
/// [`TaskSpans`] per (system, scenario) task, merged in task-index
/// order regardless of completion order, so the Chrome trace rendered
/// from them (`gvbench dynamics --trace-out`) is byte-identical at any
/// `--jobs` count.
pub fn run_dynamics_traced(
    base: &RunConfig,
    spec: &DynSpec,
    jobs: usize,
) -> (DynSurface, Vec<TaskSpans>) {
    let sink = Arc::new(SpanSink::new());
    let surface =
        run_dynamics_inner(&Backend::Scoped(jobs), base, spec, None, Some(Arc::clone(&sink)));
    (surface, sink.drain_sorted())
}

/// [`run_dynamics`] generalized over the pool shape: the same task list
/// and seed derivation, executed on `exec` (scoped threads or a
/// persistent serve-daemon pool), with an optional per-task completion
/// observer (timelines are not single scalars, so observed values are
/// NaN). Bit-identical to [`run_dynamics`] at any worker count.
pub fn run_dynamics_on(
    exec: &Backend<'_>,
    base: &RunConfig,
    spec: &DynSpec,
    observer: Option<Observer>,
) -> DynSurface {
    run_dynamics_inner(exec, base, spec, observer, None)
}

fn run_dynamics_inner(
    exec: &Backend<'_>,
    base: &RunConfig,
    spec: &DynSpec,
    observer: Option<Observer>,
    sink: Option<Arc<SpanSink>>,
) -> DynSurface {
    let mut tasks: Vec<Task> = Vec::with_capacity(spec.systems.len() * spec.scenarios.len());
    let mut cfgs: Vec<RunConfig> = Vec::with_capacity(tasks.capacity());
    for system in &spec.systems {
        for &sc in &spec.scenarios {
            let mut cfg = base.clone();
            cfg.system = system.clone();
            cfg.seed = spec.run_seed(base.seed, system, sc);
            tasks.push(Task { system: system.clone(), metric_id: sc });
            cfgs.push(cfg);
        }
    }
    let tasks = Arc::new(tasks);
    let total = tasks.len();
    let cfgs = Arc::new(cfgs);
    let (duration_ms, window_ms) = (spec.duration_ms, spec.window_ms);
    let trace_spec = spec.trace.clone();
    let run = {
        let cfgs = Arc::clone(&cfgs);
        move |i: usize, task: &Task| {
            let sc = if task.metric_id == TRACE_SCENARIO {
                trace_spec.clone()?
            } else {
                ScenarioSpec::preset(task.metric_id, duration_ms, window_ms)?
            };
            let replay = match sink.as_ref() {
                Some(sink) => {
                    let (replay, spans) = engine::run_scenario_traced(&cfgs[i], &sc);
                    sink.push(TaskSpans {
                        index: i,
                        system: task.system.clone(),
                        label: task.metric_id.to_string(),
                        spans,
                    });
                    replay
                }
                None => engine::run_scenario(&cfgs[i], &sc),
            };
            if let Some(obs) = observer.as_ref() {
                obs(TaskDone {
                    index: i,
                    total,
                    system: task.system.clone(),
                    label: task.metric_id.to_string(),
                    value: f64::NAN,
                });
            }
            Some(replay)
        }
    };
    let (slots, stats) = executor::execute_indexed_on(exec, Arc::clone(&tasks), run);
    let runs: Vec<ScenarioRun> = slots
        .into_iter()
        .zip(tasks.iter())
        .map(|(slot, task)| {
            slot.unwrap_or_else(|| {
                panic!(
                    "dynamics scenario `{}` is not a known preset or replayable trace",
                    task.metric_id
                )
            })
        })
        .collect();
    DynSurface {
        seed: base.seed,
        duration_ms: spec.duration_ms,
        window_ms: spec.window_ms,
        runs,
        stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> DynSpec {
        DynSpec {
            systems: vec!["native".into(), "hami".into()],
            scenarios: vec!["steady", "failover"],
            duration_ms: 250,
            window_ms: 50,
            trace: None,
        }
    }

    #[test]
    fn grid_expands_system_major() {
        let base = RunConfig::quick("native");
        let surface = run_dynamics(&base, &small_spec(), 2);
        assert_eq!(surface.runs.len(), 4);
        assert_eq!(surface.stats.tasks.len(), 4);
        let coords: Vec<(&str, &str)> =
            surface.runs.iter().map(|r| (r.system.as_str(), r.scenario)).collect();
        assert_eq!(
            coords,
            vec![
                ("native", "steady"),
                ("native", "failover"),
                ("hami", "steady"),
                ("hami", "failover"),
            ]
        );
        for r in &surface.runs {
            assert_eq!(r.windows, 5);
            assert!(r.completed > 0, "{}/{} completed nothing", r.system, r.scenario);
        }
    }

    #[test]
    fn per_task_seeds_are_distinct_and_pure() {
        let spec = small_spec();
        let a = spec.run_seed(42, "hami", "steady");
        assert_eq!(a, spec.run_seed(42, "hami", "steady"));
        assert_ne!(a, spec.run_seed(42, "hami", "failover"));
        assert_ne!(a, spec.run_seed(42, "native", "steady"));
        assert_ne!(a, spec.run_seed(43, "hami", "steady"));
        let mut wider = spec.clone();
        wider.duration_ms += 250;
        assert_ne!(a, wider.run_seed(42, "hami", "steady"));
    }

    #[test]
    fn job_counts_agree_bitwise() {
        let base = RunConfig::quick("native");
        let s1 = run_dynamics(&base, &small_spec(), 1);
        let s4 = run_dynamics(&base, &small_spec(), 4);
        assert_eq!(s1.stats.jobs, 1);
        assert_eq!(s4.stats.jobs, 4);
        for (a, b) in s1.runs.iter().zip(&s4.runs) {
            assert_eq!(a.system, b.system);
            assert_eq!(a.scenario, b.scenario);
            assert_eq!(a.series.len(), b.series.len());
            for (x, y) in a.series.iter().zip(&b.series) {
                assert_eq!(x.value.to_bits(), y.value.to_bits(), "{}/{}", a.system, x.id);
            }
        }
    }

    #[test]
    fn traced_grid_merges_spans_in_task_order() {
        let base = RunConfig::quick("native");
        let (s1, t1) = run_dynamics_traced(&base, &small_spec(), 1);
        let (s4, t4) = run_dynamics_traced(&base, &small_spec(), 4);
        assert_eq!(t1.len(), 4);
        for (i, t) in t1.iter().enumerate() {
            assert_eq!(t.index, i);
            assert!(!t.spans.is_empty(), "{}/{}", t.system, t.label);
        }
        // Identical spans at any job count (the --trace-out contract) …
        for (a, b) in t1.iter().zip(&t4) {
            assert_eq!(a.index, b.index);
            assert_eq!(a.system, b.system);
            assert_eq!(a.label, b.label);
            assert_eq!(a.spans, b.spans, "{}/{}", a.system, a.label);
        }
        // … and the surface matches the untraced grid bitwise.
        let plain = run_dynamics(&base, &small_spec(), 2);
        for (x, y) in plain.runs.iter().zip(&s1.runs) {
            assert_eq!(x.series, y.series, "{}/{}", x.system, x.scenario);
        }
        assert_eq!(s1.runs.len(), s4.runs.len());
    }

    #[test]
    fn trace_timelines_ride_the_grid() {
        let base = RunConfig::quick("native");
        let tr = trace::parse_trace(
            "duration-ms 250\nwindow-ms 50\n\
             at 0 arrive 1 infer rate=30 quota=40\n\
             at 100 arrive 2 train rate=10 quota=40\n",
        )
        .unwrap();
        let spec = DynSpec {
            systems: vec!["native".into()],
            scenarios: vec![TRACE_SCENARIO],
            duration_ms: tr.duration_ms,
            window_ms: tr.window_ms,
            trace: Some(tr),
        };
        let a = run_dynamics(&base, &spec, 1);
        let b = run_dynamics(&base, &spec, 4);
        assert_eq!(a.runs.len(), 1);
        assert_eq!(a.runs[0].scenario, TRACE_SCENARIO);
        // The trace carries a training tenant: the training statistics
        // are on the summary surface.
        assert!(a.runs[0].summary_value("DYN-TRAIN-STEP-P99").is_some());
        for (x, y) in a.runs[0].series.iter().zip(&b.runs[0].series) {
            assert_eq!(x.value.to_bits(), y.value.to_bits(), "{}/{}", x.id, x.window);
        }
    }
}
