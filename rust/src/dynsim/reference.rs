//! The **frozen pre-rewrite replay loop**, kept verbatim as the
//! executable specification of [`super::engine::run_scenario`]'s output.
//!
//! This is the O(occurrences × tenants) min-scan engine the event-queue
//! core replaced. It must never be optimized or otherwise diverge: the
//! byte-identity contract of the rewrite ("same CSV/JSON surfaces at any
//! `--jobs` count") is proven by `rust/tests/dynamics_determinism.rs`
//! replaying grids through both engines and asserting bit-identical
//! [`ScenarioRun`]s, and by the committed golden surfaces in
//! `rust/tests/goldens/`. Production paths (CLI, regress, benches'
//! scaling sections) call the event-queue core; only the equivalence
//! test and the old-vs-new bench comparison call this.
//!
//! The only additions over the historical loop are the occurrence
//! counter feeding the `DYN-EVENTS` summary statistic and the
//! [`ScenarioRun::occurrences`] field, which the event core must
//! reproduce exactly: one count per window-boundary snapshot, processed
//! scenario event, and serviced request arrival.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use crate::coordinator::workload::{Request, RequestGenerator};
use crate::cudalite::Api;
use crate::metrics::RunConfig;
use crate::simgpu::error::{GpuError, GpuFault};
use crate::simgpu::memory::DevicePtr;
use crate::simgpu::TenantId;
use crate::virt::TenantConfig;

use super::engine::{
    tenant_stream_seed, window_of, Recovery, ScenarioRun, SeriesPoint, KV_BYTES_PER_TOKEN,
    KV_RING, MAX_GEN, MAX_PROMPT,
};
use super::scenario::{EventKind, ScenarioSpec, WorkloadKind};

/// Live per-tenant state of the reference loop.
struct Tenant {
    gen: RequestGenerator,
    quota_cfg: TenantConfig,
    base_rate_hz: f64,
    burst_until_ns: Option<u64>,
    /// The next request, drawn ahead so its arrival time is known.
    pending: Request,
    next_arrival_ns: u64,
    /// Resident KV blocks `(ptr, bytes)`, oldest first.
    ring: VecDeque<(DevicePtr, u64)>,
    held_bytes: u64,
}

/// Drive one request through the virtualized driver path (frozen copy;
/// the live engine's version routes busy spans through its dense
/// ledger instead of a `BTreeMap`).
#[allow(clippy::too_many_arguments)]
fn service_request(
    api: &mut Api,
    tenant: TenantId,
    req: &Request,
    state: &mut Tenant,
    busy: &mut BTreeMap<(usize, TenantId), f64>,
    window_ns: u64,
    duration_ns: u64,
    n_windows: usize,
) -> Result<(), GpuError> {
    let kv_bytes = (req.prompt_len + req.gen_len).max(1) * KV_BYTES_PER_TOKEN;
    match api.mem_alloc(tenant, kv_bytes) {
        Ok(p) => {
            state.ring.push_back((p, kv_bytes));
            state.held_bytes += kv_bytes;
            if state.ring.len() > KV_RING {
                let (old, sz) = state.ring.pop_front().expect("ring non-empty");
                state.held_bytes = state.held_bytes.saturating_sub(sz);
                api.mem_free(tenant, old)?;
            }
        }
        Err(GpuError::QuotaExceeded) | Err(GpuError::OutOfMemory) => {
            // Quota pressure: evict the oldest cached block and serve the
            // request without caching this one.
            if let Some((old, sz)) = state.ring.pop_front() {
                state.held_bytes = state.held_bytes.saturating_sub(sz);
                api.mem_free(tenant, old)?;
            }
        }
        Err(e) => return Err(e),
    }
    let prefill = api.launch_kernel(tenant, 0, &req.prefill_kernel())?;
    let decode = api.launch_kernel(tenant, 0, &req.decode_kernel())?;
    api.sync_device(tenant)?;
    for (s, e) in [prefill, decode] {
        record_busy(busy, tenant, s, e, window_ns, duration_ns, n_windows);
    }
    Ok(())
}

/// Distribute a kernel's `[start, end)` busy span over the windows it
/// overlaps (clipped at the horizon).
#[allow(clippy::too_many_arguments)]
fn record_busy(
    busy: &mut BTreeMap<(usize, TenantId), f64>,
    tenant: TenantId,
    start: u64,
    end: u64,
    window_ns: u64,
    duration_ns: u64,
    n_windows: usize,
) {
    let end = end.min(duration_ns);
    let mut s = start.min(end);
    while s < end {
        let w = window_of(s, window_ns, n_windows);
        let w_end = ((w as u64 + 1) * window_ns).min(duration_ns).max(s + 1);
        let e = end.min(w_end);
        *busy.entry((w, tenant)).or_insert(0.0) += (e - s) as f64;
        s = e;
    }
}

/// Execute one scenario timeline with the pre-rewrite min-scan loop.
/// Same contract as [`super::engine::run_scenario`]; used only to prove
/// the event-queue core bit-identical.
pub fn run_scenario_reference(cfg: &RunConfig, spec: &ScenarioSpec) -> ScenarioRun {
    let mut api = Api::with_backend(&cfg.system, cfg.seed);
    let dev_mem = api.dev.spec.hbm_bytes;
    let duration_ns = spec.duration_ms.max(1) * 1_000_000;
    let window_ns = spec.window_ms.max(1) * 1_000_000;
    let n_windows = spec.windows().max(1);

    let mut events = spec.events.clone();
    events.sort_by_key(|e| (e.at_ms, e.tenant));
    let mut ev_idx = 0usize;

    let mut active: BTreeMap<TenantId, Tenant> = BTreeMap::new();
    let mut ever: BTreeSet<TenantId> = BTreeSet::new();
    // (tenant, arrival_ns, completion_ns) of successful requests.
    let mut samples: Vec<(TenantId, u64, u64)> = Vec::new();
    let mut failed = 0usize;
    let mut busy: BTreeMap<(usize, TenantId), f64> = BTreeMap::new();
    let mut snap_mem: Vec<f64> = Vec::with_capacity(n_windows);
    let mut snap_frag: Vec<f64> = Vec::with_capacity(n_windows);
    let mut snap_tenant_mem: Vec<BTreeMap<TenantId, f64>> = Vec::with_capacity(n_windows);
    let mut fault: Option<(TenantId, u64)> = None;
    let mut recovery: Option<Recovery> = None;
    let mut occurrences = 0u64;

    let boundary_ns = |w: usize| ((w as u64 + 1) * window_ns).min(duration_ns);

    loop {
        let next_event_ns = events.get(ev_idx).map(|e| e.at_ms * 1_000_000);
        let next_arrival: Option<(u64, TenantId)> =
            active.iter().map(|(t, s)| (s.next_arrival_ns, *t)).min();
        let t = match (next_event_ns, next_arrival) {
            (None, None) => break,
            (Some(te), None) => te,
            (None, Some((ta, _))) => ta,
            (Some(te), Some((ta, _))) => te.min(ta),
        };
        if t >= duration_ns {
            break;
        }
        // Snapshot every window boundary reached before this occurrence:
        // nothing changes between consecutive occurrences, so the current
        // state *is* the boundary state.
        while snap_mem.len() < n_windows && boundary_ns(snap_mem.len()) <= t {
            occurrences += 1;
            snap_mem.push(api.dev.memory.used() as f64 / dev_mem as f64);
            snap_frag.push(api.dev.memory.frag_stats().fragmentation_index * 100.0);
            snap_tenant_mem.push(
                active
                    .iter()
                    .map(|(tid, s)| (*tid, s.held_bytes as f64 / dev_mem as f64))
                    .collect(),
            );
        }
        // Scenario events take precedence over request arrivals on ties.
        if next_event_ns == Some(t) {
            let ev = events[ev_idx];
            ev_idx += 1;
            occurrences += 1;
            match ev.kind {
                EventKind::Arrive { rate_hz, quota_pct, workload: WorkloadKind::Infer } => {
                    let quota = dev_mem.saturating_mul(quota_pct as u64) / 100;
                    let tc = TenantConfig::unlimited()
                        .with_mem_limit(quota)
                        .with_sm_limit(quota_pct as f64 / 100.0);
                    api.dev.clock.advance_to(t);
                    if api.ctx_create(ev.tenant, tc).is_ok() {
                        let mut gen =
                            RequestGenerator::new(tenant_stream_seed(cfg.seed, ev.tenant), rate_hz)
                                .with_lengths(MAX_PROMPT, MAX_GEN);
                        let pending = gen.next_request();
                        let next_arrival_ns = t + pending.inter_arrival_ns.max(1.0) as u64;
                        ever.insert(ev.tenant);
                        active.insert(
                            ev.tenant,
                            Tenant {
                                gen,
                                quota_cfg: tc,
                                base_rate_hz: rate_hz,
                                burst_until_ns: None,
                                pending,
                                next_arrival_ns,
                                ring: VecDeque::new(),
                                held_bytes: 0,
                            },
                        );
                    }
                }
                EventKind::Depart => {
                    if active.remove(&ev.tenant).is_some() {
                        api.dev.clock.advance_to(t);
                        let _ = api.ctx_destroy(ev.tenant);
                    }
                }
                EventKind::Burst { factor, until_ms } => {
                    if let Some(s) = active.get_mut(&ev.tenant) {
                        s.gen.rate_hz = s.base_rate_hz * factor;
                        s.burst_until_ns = Some(until_ms * 1_000_000);
                    }
                }
                EventKind::Fail => {
                    api.dev.clock.advance_to(t);
                    api.inject_fault(ev.tenant, GpuFault::IllegalAddress);
                    fault = Some((ev.tenant, t));
                }
                // Post-freeze timeline constructs (training tenants and
                // trace-injected requests) are never replayed here: the
                // equivalence suite only feeds this loop the frozen
                // inference presets, and the loop predates both kinds.
                EventKind::Arrive { .. } | EventKind::Request => {}
            }
            continue;
        }
        // Request arrival: service in arrival order on the shared device.
        let (_, tenant) = next_arrival.expect("an arrival chose t");
        let state = active.get_mut(&tenant).expect("arrival of an active tenant");
        let req = state.pending.clone();
        occurrences += 1;
        api.dev.clock.advance_to(t);
        let served = service_request(
            &mut api, tenant, &req, state, &mut busy, window_ns, duration_ns, n_windows,
        );
        match served {
            Ok(()) => samples.push((tenant, t, api.now_ns())),
            Err(_) => {
                // Fault path: the ERR-002 recovery cycle (destroy +
                // recreate clears the poison and every held block), then
                // one retry of the request.
                let tc = state.quota_cfg;
                state.ring.clear();
                state.held_bytes = 0;
                let _ = api.ctx_destroy(tenant);
                let recovered = api.ctx_create(tenant, tc).is_ok()
                    && service_request(
                        &mut api, tenant, &req, state, &mut busy, window_ns, duration_ns,
                        n_windows,
                    )
                    .is_ok();
                if recovered {
                    let completion = api.now_ns();
                    samples.push((tenant, t, completion));
                    if recovery.is_none() {
                        if let Some((ft, fns)) = fault {
                            if ft == tenant {
                                recovery =
                                    Some(Recovery { tenant, fault_ns: fns, recovered_ns: completion });
                                fault = None;
                            }
                        }
                    }
                } else {
                    failed += 1;
                }
            }
        }
        // Burst expiry is checked lazily at the next draw.
        if let Some(until) = state.burst_until_ns {
            if t >= until {
                state.gen.rate_hz = state.base_rate_hz;
                state.burst_until_ns = None;
            }
        }
        state.pending = state.gen.next_request();
        state.next_arrival_ns = t + state.pending.inter_arrival_ns.max(1.0) as u64;
    }
    // Trailing windows (no further occurrences): the final state holds.
    while snap_mem.len() < n_windows {
        occurrences += 1;
        snap_mem.push(api.dev.memory.used() as f64 / dev_mem as f64);
        snap_frag.push(api.dev.memory.frag_stats().fragmentation_index * 100.0);
        snap_tenant_mem.push(
            active
                .iter()
                .map(|(tid, s)| (*tid, s.held_bytes as f64 / dev_mem as f64))
                .collect(),
        );
    }

    // ---- reduce to windowed series --------------------------------------
    let tenants: Vec<TenantId> = ever.iter().copied().collect();
    let mut window_lats: Vec<Vec<f64>> = vec![Vec::new(); n_windows];
    for &(_, arrival, completion) in &samples {
        let w = window_of(completion, window_ns, n_windows);
        window_lats[w].push((completion.saturating_sub(arrival)) as f64 / 1e6);
    }
    let recovery_window = recovery.map(|r| window_of(r.recovered_ns, window_ns, n_windows));
    let mut series: Vec<SeriesPoint> = Vec::new();
    let mut window_p99: Vec<f64> = Vec::with_capacity(n_windows);
    for w in 0..n_windows {
        let win_len_ns = (boundary_ns(w) - (w as u64) * window_ns).max(1) as f64;
        let lats = &window_lats[w];
        let (p50, p99) = if lats.is_empty() {
            (f64::NAN, f64::NAN)
        } else {
            (crate::stats::percentile(lats, 50.0), crate::stats::percentile(lats, 99.0))
        };
        window_p99.push(p99);
        let thr = lats.len() as f64 / (win_len_ns / 1e9);
        let agg_busy: f64 =
            tenants.iter().map(|t| busy.get(&(w, *t)).copied().unwrap_or(0.0)).sum();
        series.push(SeriesPoint { window: w, tenant: None, id: "DYN-LAT-P50", value: p50 });
        series.push(SeriesPoint { window: w, tenant: None, id: "DYN-LAT-P99", value: p99 });
        series.push(SeriesPoint { window: w, tenant: None, id: "DYN-THR", value: thr });
        series.push(SeriesPoint {
            window: w,
            tenant: None,
            id: "DYN-SM",
            value: agg_busy / win_len_ns,
        });
        series.push(SeriesPoint { window: w, tenant: None, id: "DYN-MEM", value: snap_mem[w] });
        series.push(SeriesPoint { window: w, tenant: None, id: "DYN-FRAG", value: snap_frag[w] });
        for &t in &tenants {
            series.push(SeriesPoint {
                window: w,
                tenant: Some(t),
                id: "DYN-SM",
                value: busy.get(&(w, t)).copied().unwrap_or(0.0) / win_len_ns,
            });
            series.push(SeriesPoint {
                window: w,
                tenant: Some(t),
                id: "DYN-MEM",
                value: snap_tenant_mem[w].get(&t).copied().unwrap_or(0.0),
            });
        }
        if recovery_window == Some(w) {
            let r = recovery.expect("recovery window implies recovery");
            series.push(SeriesPoint {
                window: w,
                tenant: Some(r.tenant),
                id: "DYN-RECOVERY",
                value: r.recovery_ms(),
            });
        }
    }

    // ---- per-scenario summary (the regress-gateable surface) ------------
    let p99s: Vec<f64> = window_p99.iter().copied().filter(|v| v.is_finite()).collect();
    let steady = if p99s.is_empty() { 0.0 } else { crate::stats::percentile(&p99s, 50.0) };
    let worst = p99s.iter().copied().fold(0.0f64, f64::max);
    let worst_win = if steady > 0.0 { (worst / steady - 1.0) * 100.0 } else { 0.0 };
    let thr_mean = samples.len() as f64 / (spec.duration_ms.max(1) as f64 / 1e3);
    // 0 = no fault injected. A fault that never recovered inside the
    // horizon must not read as 0 too (lower-better would score total
    // recovery failure as perfection): report the full horizon instead.
    let recovery_ms = match (recovery, fault) {
        (Some(r), _) => r.recovery_ms(),
        (None, Some(_)) => spec.duration_ms as f64,
        (None, None) => 0.0,
    };
    let summary = vec![
        ("DYN-P99-STEADY", steady),
        ("DYN-WORST-WIN", worst_win),
        ("DYN-THR-MEAN", thr_mean),
        ("DYN-RECOVERY", recovery_ms),
        ("DYN-EVENTS", occurrences as f64),
    ];

    ScenarioRun {
        system: cfg.system.clone(),
        scenario: spec.name,
        duration_ms: spec.duration_ms,
        window_ms: spec.window_ms,
        windows: n_windows,
        tenants,
        series,
        summary,
        completed: samples.len(),
        train_steps: 0,
        failed,
        recovery,
        occurrences,
    }
}
