//! Deterministic discrete-event queue for the dynsim replay loop.
//!
//! The engine schedules every timeline occurrence — window boundaries,
//! scenario events, tenant request arrivals — on one min-queue over
//! virtual time, popping the next occurrence in O(log n) instead of the
//! pre-rewrite O(tenants) min-scan. Determinism at any `--jobs` count
//! requires a *total* order, so ties at equal timestamps break on
//! `(kind rank, key)`:
//!
//! 1. **Boundary** — window-boundary snapshots observe the state *before*
//!    any same-instant occurrence mutates it (the old loop snapshotted
//!    every boundary `<= t` before processing the occurrence at `t`);
//! 2. **Event** — scenario events take precedence over request arrivals
//!    on ties (the old loop's `continue` semantics), equal-time events
//!    keeping their `(at_ms, tenant)`-sorted list order via the index;
//! 3. **Arrival** — equal-time arrivals of different tenants pop
//!    tenant-ascending, matching the old min-scan over
//!    `(next_arrival_ns, tenant)` tuples.
//!
//! The order is pure data (no hash state, no insertion order), so a heap
//! rebuilt from any permutation of the same occurrences drains
//! identically — the property `rust/tests/prop_invariants.rs` checks.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::simgpu::TenantId;

/// What a queued occurrence is. Variant declaration order *is* the
/// tie-break rank at equal timestamps (the derived [`Ord`] compares
/// discriminants first, then fields lexicographically).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum OccKind {
    /// Snapshot boundary of window `w`.
    Boundary(usize),
    /// Scenario event, as an index into the spec's `(at_ms, tenant)`-
    /// sorted event list.
    Event(usize),
    /// Next request arrival of a tenant. `epoch` identifies the tenant
    /// incarnation that scheduled it: a pop whose epoch no longer matches
    /// the live state (the tenant departed, or departed and re-arrived)
    /// is stale and must be skipped.
    Arrival { tenant: TenantId, epoch: u64 },
}

/// One timestamped occurrence. Ordered by `(t_ns, kind)` — virtual time
/// first, then the [`OccKind`] tie-break.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Occ {
    /// Virtual time of the occurrence, ns.
    pub t_ns: u64,
    pub kind: OccKind,
}

/// Min-queue over [`Occ`] in the deterministic `(t, kind rank, key)`
/// total order.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Occ>>,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    /// Preallocate for `cap` occurrences (the engine sizes the queue from
    /// the window count, event count and tenant universe up front).
    pub fn with_capacity(cap: usize) -> EventQueue {
        EventQueue { heap: BinaryHeap::with_capacity(cap) }
    }

    pub fn push(&mut self, occ: Occ) {
        self.heap.push(Reverse(occ));
    }

    /// Pop the earliest occurrence (ties broken by kind rank, then key).
    pub fn pop(&mut self) -> Option<Occ> {
        self.heap.pop().map(|Reverse(o)| o)
    }

    /// The earliest occurrence without removing it.
    pub fn peek(&self) -> Option<&Occ> {
        self.heap.peek().map(|Reverse(o)| o)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(Occ { t_ns: 30, kind: OccKind::Event(0) });
        q.push(Occ { t_ns: 10, kind: OccKind::Arrival { tenant: 5, epoch: 1 } });
        q.push(Occ { t_ns: 20, kind: OccKind::Boundary(0) });
        let times: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|o| o.t_ns).collect();
        assert_eq!(times, vec![10, 20, 30]);
        assert!(q.is_empty());
    }

    #[test]
    fn equal_time_ties_break_boundary_event_arrival() {
        let mut q = EventQueue::with_capacity(4);
        q.push(Occ { t_ns: 100, kind: OccKind::Arrival { tenant: 1, epoch: 3 } });
        q.push(Occ { t_ns: 100, kind: OccKind::Event(2) });
        q.push(Occ { t_ns: 100, kind: OccKind::Boundary(1) });
        q.push(Occ { t_ns: 100, kind: OccKind::Event(1) });
        assert_eq!(q.len(), 4);
        assert_eq!(q.peek().unwrap().kind, OccKind::Boundary(1));
        let kinds: Vec<OccKind> = std::iter::from_fn(|| q.pop()).map(|o| o.kind).collect();
        assert_eq!(
            kinds,
            vec![
                OccKind::Boundary(1),
                OccKind::Event(1),
                OccKind::Event(2),
                OccKind::Arrival { tenant: 1, epoch: 3 },
            ]
        );
    }

    #[test]
    fn equal_time_arrivals_pop_tenant_ascending() {
        let mut q = EventQueue::new();
        for tenant in [4u32, 1, 3, 2] {
            q.push(Occ { t_ns: 7, kind: OccKind::Arrival { tenant, epoch: tenant as u64 } });
        }
        let tenants: Vec<TenantId> = std::iter::from_fn(|| q.pop())
            .map(|o| match o.kind {
                OccKind::Arrival { tenant, .. } => tenant,
                other => panic!("unexpected {other:?}"),
            })
            .collect();
        assert_eq!(tenants, vec![1, 2, 3, 4]);
    }
}
