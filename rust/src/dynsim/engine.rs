//! The virtual-time event engine: replay one [`ScenarioSpec`] timeline
//! against one simulated GPU node and reduce it to windowed time series.
//!
//! The engine is a discrete-event simulation over the `cudalite` API's
//! single virtual clock, driven by one [`super::queue::EventQueue`] of
//! timestamped occurrences — window-boundary snapshots, scenario events
//! and tenant request arrivals — popped in the deterministic
//! `(t, kind rank, key)` order. Popping the next occurrence is
//! O(log n); the pre-rewrite loop rescanned every active tenant per
//! occurrence (O(occurrences × tenants)), which is the difference
//! between minutes and seconds at 10³-tenant / 10⁶-occurrence horizons.
//! The rewrite's behavior is pinned by the committed goldens under
//! `rust/tests/goldens/` (the frozen pre-rewrite engine has been
//! retired now that those goldens carry the bit-identity proof).
//!
//! - **Arrivals are open-loop**: each active tenant owns a
//!   [`RequestGenerator`] whose Poisson process schedules request arrival
//!   times independently of service completion — the correct model for
//!   an LLM serving front door. Requests are serviced in arrival order;
//!   when the device (clock) is behind the arrival backlog, queueing
//!   delay emerges naturally and shows up in the windowed latency tails.
//!   Generation is batched: tenants draw [`ProtoRequest`]s from their
//!   stream in blocks and realize them against the current arrival rate,
//!   which is bit-identical to per-request draws (the unit-rate
//!   exponential divides by the rate at realization) but amortizes the
//!   generator call overhead across the block.
//! - **Service is the virtualized driver path**: each request allocates
//!   its KV block through `cuMemAlloc` (held in a bounded per-tenant
//!   ring, so the heap churns like a real serving node), launches its
//!   prefill and decode kernels ([`Request::prefill_kernel`] /
//!   [`Request::decode_kernel`]) and synchronizes. Every hook, quota
//!   check and throttle of the system under test is therefore on the
//!   request path, which is exactly where the paper's §8 finding ("LLM
//!   workloads are sensitive to allocation overhead") lives.
//! - **Faults recover through the driver**: an injected fault surfaces at
//!   the tenant's first failing call; the engine performs the
//!   destroy+recreate recovery the ERR-002 metric measures and records
//!   the fault→first-successful-request recovery time.
//! - **Training tenants are closed-loop**: a tenant arriving with
//!   [`WorkloadKind::Train`] owns a [`TrainingGenerator`] whose paced
//!   optimizer steps ride the same arrival queue and epoch rules. Each
//!   step allocates its activation block, launches the fwd/bwd kernel
//!   pair, and on gradient-sync steps performs an allreduce over the
//!   node's interconnect (the NCCL-001 collective model) that busies the
//!   *shared* device clock — which is exactly the train/infer
//!   interference the `DYN-MIX-INTERFERENCE` statistic measures — before
//!   the optimizer update. Training step completions feed their own
//!   summary statistics (`DYN-TRAIN-STEP-P99`, `DYN-ALLREDUCE`), emitted
//!   only for timelines that start a training tenant so inference-only
//!   scenarios keep their frozen 5-statistic surface.
//!
//! Determinism: everything derives from `cfg.seed` (the caller passes the
//! composed `task_seed(dynamics_seed(..), system, scenario)` — see
//! [`crate::util::rng::dynamics_seed`]); per-tenant request streams are
//! keyed by tenant id, so timelines are bit-identical at any `--jobs`
//! count and any completion order. The engine also counts every
//! occurrence it processes — the `DYN-EVENTS` summary statistic — which
//! is itself deterministic and therefore gateable; wall-clock events/sec
//! lives in the JSON `execution` stats instead, since host timings can
//! never be value-gated.

use std::collections::VecDeque;

use crate::coordinator::workload::{
    ProtoRequest, Request, RequestGenerator, TrainStep, TrainingGenerator,
};
use crate::cudalite::{Api, CollectiveCtx};
use crate::metrics::RunConfig;
use crate::obs::trace::VSpan;
use crate::simgpu::error::{GpuError, GpuFault};
use crate::simgpu::memory::DevicePtr;
use crate::simgpu::{TenantId, VirtualClock};
use crate::util::rng::splitmix64;
use crate::virt::TenantConfig;

use super::queue::{EventQueue, Occ, OccKind};
use super::scenario::{EventKind, ScenarioSpec, WorkloadKind};

/// KV-cache bytes per (prompt + generated) token held by a request.
pub(crate) const KV_BYTES_PER_TOKEN: u64 = 128 << 10;
/// Recent request KV blocks each tenant keeps resident (a serving
/// engine's prefix/session cache) — old blocks free as new ones land,
/// which is what keeps the allocator churning.
pub(crate) const KV_RING: usize = 12;
/// Prompt/generation caps for the serving-scaled request shapes.
pub(crate) const MAX_PROMPT: u64 = 512;
pub(crate) const MAX_GEN: u64 = 64;
/// Proto-requests drawn per generator call: one block refills a tenant's
/// arena and is realized request-by-request at the then-current rate.
const PROTO_BATCH: usize = 64;
/// Activation bytes per micro-batch token held by a training step.
pub(crate) const ACT_BYTES_PER_TOKEN: u64 = 64 << 10;
/// Recent activation blocks a training tenant keeps resident (double
/// buffering: the in-flight step plus the previous one's recompute
/// stash) — far fewer than a serving tenant's KV ring, but each block is
/// batch-sized, so the allocator churn is comparable.
pub(crate) const TRAIN_RING: usize = 2;

/// One value of one windowed series.
#[derive(Clone, Debug, PartialEq)]
pub struct SeriesPoint {
    /// Window index (0-based).
    pub window: usize,
    /// `None` = aggregate over all tenants; `Some(t)` = per-tenant series.
    pub tenant: Option<TenantId>,
    /// Series id from [`crate::metrics::taxonomy::DYN_SERIES`] (plus the
    /// `DYN-RECOVERY` marker row in the recovery window).
    pub id: &'static str,
    pub value: f64,
}

/// Recovery record of the first injected-fault recovery of the timeline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Recovery {
    /// The tenant the fault was attributed to.
    pub tenant: TenantId,
    /// Virtual time of fault injection, ns.
    pub fault_ns: u64,
    /// Virtual completion time of the tenant's first successful request
    /// after recovery, ns.
    pub recovered_ns: u64,
}

impl Recovery {
    /// Fault-to-recovered interval, ms.
    pub fn recovery_ms(&self) -> f64 {
        (self.recovered_ns.saturating_sub(self.fault_ns)) as f64 / 1e6
    }
}

/// One executed (system, scenario) timeline: the windowed time series
/// plus the per-scenario summary statistics.
#[derive(Clone, Debug)]
pub struct ScenarioRun {
    pub system: String,
    /// Canonical scenario key.
    pub scenario: &'static str,
    pub duration_ms: u64,
    pub window_ms: u64,
    /// Number of reporting windows.
    pub windows: usize,
    /// Every tenant that ever arrived, ascending.
    pub tenants: Vec<TenantId>,
    /// Long-format series points in deterministic order: windows
    /// ascending; within a window the aggregate series first (taxonomy
    /// order), then per-tenant series per tenant ascending, then the
    /// recovery marker when this is the recovery window.
    pub series: Vec<SeriesPoint>,
    /// Per-scenario summary statistics, in
    /// [`crate::metrics::taxonomy::DYN_SUMMARY`] order.
    pub summary: Vec<(&'static str, f64)>,
    /// Inference requests completed successfully.
    pub completed: usize,
    /// Training steps completed successfully (0 on inference-only
    /// timelines). Training completions feed the `DYN-TRAIN-STEP-P99`
    /// statistic, not the request latency/throughput series.
    pub train_steps: usize,
    /// Work items abandoned (service failed even after recovery),
    /// requests and training steps alike.
    pub failed: usize,
    /// First injected-fault recovery, when the scenario injected one and
    /// the tenant recovered within the horizon.
    pub recovery: Option<Recovery>,
    /// Occurrences the event core processed: window-boundary snapshots +
    /// scenario events inside the horizon + serviced request arrivals.
    /// Deterministic (virtual-time), so it is also the `DYN-EVENTS`
    /// summary statistic and gateable like any other summary value.
    pub occurrences: u64,
}

impl ScenarioRun {
    /// Summary value by id.
    pub fn summary_value(&self, id: &str) -> Option<f64> {
        self.summary.iter().find(|(i, _)| *i == id).map(|(_, v)| *v)
    }

    /// All points of one series id (aggregate and per-tenant alike).
    pub fn points(&self, id: &str) -> Vec<&SeriesPoint> {
        self.series.iter().filter(|p| p.id == id).collect()
    }

    /// The window index containing virtual time `t_ns` (clamped to the
    /// last window, where late completions accumulate).
    pub fn window_of(&self, t_ns: u64) -> usize {
        window_of(t_ns, self.window_ms * 1_000_000, self.windows)
    }

    /// End of window `w` on the timeline, ms (the last window truncates
    /// at the horizon) — the `t_ms` column of the time-series CSV.
    pub fn window_end_ms(&self, w: usize) -> u64 {
        ((w as u64 + 1) * self.window_ms).min(self.duration_ms)
    }
}

pub(crate) fn window_of(t_ns: u64, window_ns: u64, n_windows: usize) -> usize {
    ((t_ns / window_ns.max(1)) as usize).min(n_windows.saturating_sub(1))
}

/// Deterministic per-tenant stream seed: pure in (run seed, tenant id),
/// so a tenant's request trace is independent of arrival interleaving.
pub(crate) fn tenant_stream_seed(seed: u64, tenant: TenantId) -> u64 {
    let mut s = seed ^ 0xD1B54A32D192ED03u64.wrapping_mul(tenant as u64 + 1);
    splitmix64(&mut s)
}

/// Training-stream counterpart of [`tenant_stream_seed`]: a distinct
/// mixing constant keeps a tenant's training stream decorrelated from
/// the request stream the same `(seed, tenant)` pair would draw.
pub(crate) fn train_stream_seed(seed: u64, tenant: TenantId) -> u64 {
    let mut s = seed ^ 0xA0761D6478BD642Fu64.wrapping_mul(tenant as u64 + 1);
    splitmix64(&mut s)
}

/// The workload a tenant incarnation runs: an open-loop inference
/// request stream or a closed-loop training job. Everything
/// workload-shaped (generator, pending work, per-job communicator)
/// lives here; the shared lifecycle state (quota, bursts, epoch, the
/// resident-block ring) stays on [`Tenant`].
enum Driver {
    Infer {
        gen: RequestGenerator,
        /// Arena of pre-drawn proto-requests, refilled [`PROTO_BATCH`]
        /// at a time and realized against the current rate at
        /// consumption.
        protos: VecDeque<ProtoRequest>,
        /// The next request, drawn ahead so its arrival time is known.
        pending: Request,
    },
    Train {
        gen: TrainingGenerator,
        /// The next optimizer step, drawn ahead so its time is known.
        pending: TrainStep,
        /// Per-job gradient communicator over the node's interconnect.
        /// Built on a *detached* clock: the engine applies each
        /// allreduce's returned latency to the shared device clock
        /// itself, so collective time serializes with every tenant's
        /// kernel work instead of advancing a private timeline.
        comms: CollectiveCtx,
    },
}

impl Driver {
    /// One pending unit of work, detached from the borrow of `self`.
    fn pending_work(&self) -> Work {
        match self {
            Driver::Infer { pending, .. } => Work::Req(pending.clone()),
            Driver::Train { pending, .. } => Work::Step(*pending),
        }
    }

    /// Set the effective rate (burst scaling / expiry).
    fn set_rate(&mut self, rate_hz: f64) {
        match self {
            Driver::Infer { gen, .. } => gen.rate_hz = rate_hz,
            Driver::Train { gen, .. } => gen.rate_hz = rate_hz,
        }
    }

    /// Draw the next pending work item; returns its inter-arrival ns.
    fn redraw(&mut self) -> f64 {
        match self {
            Driver::Infer { gen, protos, pending } => {
                *pending = draw_request(gen, protos);
                pending.inter_arrival_ns
            }
            Driver::Train { gen, pending, .. } => {
                *pending = gen.next_step();
                pending.inter_arrival_ns
            }
        }
    }
}

/// One unit of tenant work pulled off the arrival queue (or injected by
/// a trace `request` event), cloned out of the driver so servicing can
/// borrow the tenant mutably.
enum Work {
    Req(Request),
    Step(TrainStep),
}

/// Live per-tenant state. Arrival *times* live in the event queue, not
/// here: a queued [`OccKind::Arrival`] carries the tenant's `epoch` so
/// that occurrences scheduled by a departed (or replaced) incarnation
/// pop as stale and are skipped.
struct Tenant {
    driver: Driver,
    quota_cfg: TenantConfig,
    base_rate_hz: f64,
    burst_until_ns: Option<u64>,
    /// Incarnation counter value at this tenant's last (re-)arrival.
    epoch: u64,
    /// Resident blocks `(ptr, bytes)`, oldest first: KV cache for
    /// inference tenants, activation stash for training tenants.
    ring: VecDeque<(DevicePtr, u64)>,
    held_bytes: u64,
}

/// Draw the tenant's next request, refilling the proto arena from the
/// generator when it runs dry. Bit-identical to calling
/// [`RequestGenerator::next_request`] at the same point: the stream
/// consumes the same draws in the same order, and realization divides
/// the unit-rate exponential by the same rate the direct call would
/// have used.
fn draw_request(gen: &mut RequestGenerator, protos: &mut VecDeque<ProtoRequest>) -> Request {
    if protos.is_empty() {
        for _ in 0..PROTO_BATCH {
            protos.push_back(gen.next_proto());
        }
    }
    gen.realize(protos.pop_front().expect("arena just refilled"))
}

/// Dense `(window × tenant-slot)` busy-time ledger. Replaces the old
/// `BTreeMap<(window, tenant), f64>`: one flat allocation sized up front
/// from `spec.windows()` and the tenant universe, O(1) accumulate.
/// Accumulation order per cell is chronological in both engines, so the
/// f64 sums are bit-identical.
struct BusyLedger {
    window_ns: u64,
    duration_ns: u64,
    n_windows: usize,
    n_slots: usize,
    cells: Vec<f64>,
}

impl BusyLedger {
    fn new(window_ns: u64, duration_ns: u64, n_windows: usize, n_slots: usize) -> BusyLedger {
        BusyLedger { window_ns, duration_ns, n_windows, n_slots, cells: vec![0.0; n_windows * n_slots] }
    }

    /// Distribute a kernel's `[start, end)` busy span over the windows it
    /// overlaps (clipped at the horizon; spans past it fold into the last
    /// window's accounting only up to the horizon).
    fn record(&mut self, slot: usize, start: u64, end: u64) {
        let end = end.min(self.duration_ns);
        let mut s = start.min(end);
        while s < end {
            let w = window_of(s, self.window_ns, self.n_windows);
            let w_end = ((w as u64 + 1) * self.window_ns).min(self.duration_ns).max(s + 1);
            let e = end.min(w_end);
            self.cells[w * self.n_slots + slot] += (e - s) as f64;
            s = e;
        }
    }

    fn cell(&self, w: usize, slot: usize) -> f64 {
        self.cells[w * self.n_slots + slot]
    }
}

/// Drive one request through the virtualized driver path. Quota/OOM
/// rejections shrink the tenant's KV ring and carry on; fault-class
/// errors propagate so the caller can run the recovery path.
///
/// When `spans` is `Some`, the prefill/decode kernel intervals are also
/// recorded as virtual-time [`VSpan`]s — pure observation of values the
/// engine computes anyway, so tracing never perturbs the timeline.
fn service_request(
    api: &mut Api,
    tenant: TenantId,
    slot: usize,
    req: &Request,
    state: &mut Tenant,
    busy: &mut BusyLedger,
    spans: &mut Option<Vec<VSpan>>,
) -> Result<(), GpuError> {
    let kv_bytes = (req.prompt_len + req.gen_len).max(1) * KV_BYTES_PER_TOKEN;
    match api.mem_alloc(tenant, kv_bytes) {
        Ok(p) => {
            state.ring.push_back((p, kv_bytes));
            state.held_bytes += kv_bytes;
            if state.ring.len() > KV_RING {
                let (old, sz) = state.ring.pop_front().expect("ring non-empty");
                state.held_bytes = state.held_bytes.saturating_sub(sz);
                api.mem_free(tenant, old)?;
            }
        }
        Err(GpuError::QuotaExceeded) | Err(GpuError::OutOfMemory) => {
            // Quota pressure: evict the oldest cached block and serve the
            // request without caching this one.
            if let Some((old, sz)) = state.ring.pop_front() {
                state.held_bytes = state.held_bytes.saturating_sub(sz);
                api.mem_free(tenant, old)?;
            }
        }
        Err(e) => return Err(e),
    }
    let prefill = api.launch_kernel(tenant, 0, &req.prefill_kernel())?;
    let decode = api.launch_kernel(tenant, 0, &req.decode_kernel())?;
    api.sync_device(tenant)?;
    for (s, e) in [prefill, decode] {
        busy.record(slot, s, e);
    }
    if let Some(spans) = spans {
        spans.push(VSpan::complete("kernel", "prefill", Some(tenant), prefill.0, prefill.1));
        spans.push(VSpan::complete("kernel", "decode", Some(tenant), decode.0, decode.1));
    }
    Ok(())
}

/// Drive one training step through the virtualized driver path:
/// activation alloc (bounded ring, same quota/OOM evict-oldest semantics
/// as the KV ring), forward + backward launch, sync; on gradient-sync
/// steps an allreduce whose latency busies the *shared* device clock
/// (serializing against every tenant's kernels — the interference the
/// mixed-workload statistics measure), then the optimizer update.
///
/// When `spans` is `Some`, the fwd/bwd/allreduce/optimizer intervals
/// are also recorded as virtual-time [`VSpan`]s (pure observation).
#[allow(clippy::too_many_arguments)]
fn service_train_step(
    api: &mut Api,
    tenant: TenantId,
    slot: usize,
    step: &TrainStep,
    state: &mut Tenant,
    busy: &mut BusyLedger,
    allreduce_lats_ms: &mut Vec<f64>,
    spans: &mut Option<Vec<VSpan>>,
) -> Result<(), GpuError> {
    let act_bytes = step.batch_tokens.max(1) * ACT_BYTES_PER_TOKEN;
    match api.mem_alloc(tenant, act_bytes) {
        Ok(p) => {
            state.ring.push_back((p, act_bytes));
            state.held_bytes += act_bytes;
            if state.ring.len() > TRAIN_RING {
                let (old, sz) = state.ring.pop_front().expect("ring non-empty");
                state.held_bytes = state.held_bytes.saturating_sub(sz);
                api.mem_free(tenant, old)?;
            }
        }
        Err(GpuError::QuotaExceeded) | Err(GpuError::OutOfMemory) => {
            // Quota pressure: drop the oldest activation stash and run
            // this step without caching its activations.
            if let Some((old, sz)) = state.ring.pop_front() {
                state.held_bytes = state.held_bytes.saturating_sub(sz);
                api.mem_free(tenant, old)?;
            }
        }
        Err(e) => return Err(e),
    }
    let fwd = api.launch_kernel(tenant, 0, &step.forward_kernel())?;
    let bwd = api.launch_kernel(tenant, 0, &step.backward_kernel())?;
    api.sync_device(tenant)?;
    for (s, e) in [fwd, bwd] {
        busy.record(slot, s, e);
    }
    if let Some(spans) = spans.as_mut() {
        spans.push(VSpan::complete("kernel", "fwd", Some(tenant), fwd.0, fwd.1));
        spans.push(VSpan::complete("kernel", "bwd", Some(tenant), bwd.0, bwd.1));
    }
    if step.grad_sync {
        let Driver::Train { comms, .. } = &mut state.driver else {
            unreachable!("train steps only run on train drivers");
        };
        let us = comms.allreduce(step.allreduce_bytes());
        // The communicator's own clock is detached; occupy the shared
        // device timeline for the collective's duration instead.
        let ar_start = api.now_ns();
        api.dev.clock.advance_f(us * 1e3);
        allreduce_lats_ms.push(us / 1e3);
        if let Some(spans) = spans.as_mut() {
            spans.push(VSpan::complete("comm", "allreduce", Some(tenant), ar_start, api.now_ns()));
        }
        let opt = api.launch_kernel(tenant, 0, &step.optimizer_kernel())?;
        api.sync_device(tenant)?;
        busy.record(slot, opt.0, opt.1);
        if let Some(spans) = spans.as_mut() {
            spans.push(VSpan::complete("kernel", "optimizer", Some(tenant), opt.0, opt.1));
        }
    }
    Ok(())
}

/// Dispatch one unit of work to its service path.
#[allow(clippy::too_many_arguments)]
fn service_work(
    api: &mut Api,
    tenant: TenantId,
    slot: usize,
    work: &Work,
    state: &mut Tenant,
    busy: &mut BusyLedger,
    allreduce_lats_ms: &mut Vec<f64>,
    spans: &mut Option<Vec<VSpan>>,
) -> Result<(), GpuError> {
    match work {
        Work::Req(req) => service_request(api, tenant, slot, req, state, busy, spans),
        Work::Step(step) => {
            service_train_step(api, tenant, slot, step, state, busy, allreduce_lats_ms, spans)
        }
    }
}

/// Everything a serviced work item can produce: completion samples (per
/// workload kind), allreduce latencies, abandonment counts and the
/// fault/recovery bookkeeping. Bundled so the service-and-recover path
/// is shared between queue arrivals and trace-injected `request` events.
struct Outcomes {
    /// `(tenant, arrival_ns, completion_ns)` of successful requests.
    samples: Vec<(TenantId, u64, u64)>,
    /// `(tenant, step_start_ns, completion_ns)` of successful train steps.
    train_samples: Vec<(TenantId, u64, u64)>,
    /// Allreduce latencies, ms, in execution order.
    allreduce_lats_ms: Vec<f64>,
    failed: usize,
    fault: Option<(TenantId, u64)>,
    recovery: Option<Recovery>,
    /// Virtual-time spans recorded along the way; `None` = tracing off
    /// (the default — recording is pure observation either way).
    spans: Option<Vec<VSpan>>,
}

/// Service one work item at virtual time `t`, running the ERR-002
/// destroy+recreate recovery cycle (plus one retry) on failure, and
/// record the outcome. The caller has already advanced the clock to `t`.
#[allow(clippy::too_many_arguments)]
fn serve_and_recover(
    api: &mut Api,
    tenant: TenantId,
    slot: usize,
    t: u64,
    work: &Work,
    state: &mut Tenant,
    busy: &mut BusyLedger,
    out: &mut Outcomes,
) {
    let record = |out: &mut Outcomes, completion: u64| match work {
        Work::Req(_) => out.samples.push((tenant, t, completion)),
        Work::Step(_) => out.train_samples.push((tenant, t, completion)),
    };
    let (lats, spans) = (&mut out.allreduce_lats_ms, &mut out.spans);
    let served = service_work(api, tenant, slot, work, state, busy, lats, spans);
    match served {
        Ok(()) => record(out, api.now_ns()),
        Err(_) => {
            // Fault path: the ERR-002 recovery cycle (destroy + recreate
            // clears the poison and every held block), then one retry.
            let tc = state.quota_cfg;
            state.ring.clear();
            state.held_bytes = 0;
            let _ = api.ctx_destroy(tenant);
            let (lats, spans) = (&mut out.allreduce_lats_ms, &mut out.spans);
            let recovered = api.ctx_create(tenant, tc).is_ok()
                && service_work(api, tenant, slot, work, state, busy, lats, spans).is_ok();
            if recovered {
                let completion = api.now_ns();
                record(out, completion);
                if out.recovery.is_none() {
                    if let Some((ft, fns)) = out.fault {
                        if ft == tenant {
                            out.recovery =
                                Some(Recovery { tenant, fault_ns: fns, recovered_ns: completion });
                            out.fault = None;
                        }
                    }
                }
            } else {
                out.failed += 1;
            }
        }
    }
}

/// Execute one scenario timeline on one system. `cfg.system` selects the
/// backend and `cfg.seed` must already be the composed per-task dynamics
/// seed (see [`super::run_dynamics`], which derives it per task).
pub fn run_scenario(cfg: &RunConfig, spec: &ScenarioSpec) -> ScenarioRun {
    run_scenario_inner(cfg, spec, false).0
}

/// [`run_scenario`] with virtual-time span tracing enabled: the same
/// timeline (bit-identical `ScenarioRun` — tracing is pure observation)
/// plus the recorded [`VSpan`]s — kernel sub-spans (prefill/decode,
/// fwd/bwd/optimizer, allreduces) captured inline, request / train-step
/// lifecycles, the fault-recovery window and scenario-event markers
/// synthesized from the outcome record. Everything is on the virtual
/// clock, so the span list is as deterministic as the run itself.
pub fn run_scenario_traced(cfg: &RunConfig, spec: &ScenarioSpec) -> (ScenarioRun, Vec<VSpan>) {
    run_scenario_inner(cfg, spec, true)
}

fn run_scenario_inner(
    cfg: &RunConfig,
    spec: &ScenarioSpec,
    traced: bool,
) -> (ScenarioRun, Vec<VSpan>) {
    let mut api = Api::with_backend(&cfg.system, cfg.seed);
    let dev_mem = api.dev.spec.hbm_bytes;
    let duration_ns = spec.duration_ms.max(1) * 1_000_000;
    let window_ns = spec.window_ms.max(1) * 1_000_000;
    let n_windows = spec.windows().max(1);

    let mut events = spec.events.clone();
    events.sort_by_key(|e| (e.at_ms, e.tenant));

    // Dense tenant universe: every tenant the timeline can ever touch is
    // named by a scenario event, so per-tenant state lives in flat slots
    // addressed by rank instead of tree maps keyed by id.
    let mut universe: Vec<TenantId> = events.iter().map(|e| e.tenant).collect();
    universe.sort_unstable();
    universe.dedup();
    let n_slots = universe.len();
    let slot_of =
        |tenant: TenantId| universe.binary_search(&tenant).expect("tenant in universe");

    let mut slots: Vec<Option<Tenant>> = (0..n_slots).map(|_| None).collect();
    let mut ever: Vec<bool> = vec![false; n_slots];
    // (tenant, arrival_ns, completion_ns) of successful requests, sized
    // from the scenario's aggregate Poisson rate.
    let expected_arrivals = events
        .iter()
        .filter_map(|e| match e.kind {
            EventKind::Arrive { rate_hz, .. } => Some(rate_hz),
            _ => None,
        })
        .sum::<f64>()
        * (spec.duration_ms as f64 / 1e3);
    let mut out = Outcomes {
        samples: Vec::with_capacity((expected_arrivals as usize).min(1 << 22) + 16),
        train_samples: Vec::new(),
        allreduce_lats_ms: Vec::new(),
        failed: 0,
        fault: None,
        recovery: None,
        spans: traced.then(Vec::new),
    };
    let mut busy = BusyLedger::new(window_ns, duration_ns, n_windows, n_slots);
    let mut snap_mem: Vec<f64> = Vec::with_capacity(n_windows);
    let mut snap_frag: Vec<f64> = Vec::with_capacity(n_windows);
    // SoA (window × slot) tenant-memory snapshots; 0.0 = not resident.
    let mut snap_tenant_mem: Vec<f64> = vec![0.0; n_windows * n_slots];
    let mut occurrences = 0u64;
    // Tenant incarnation counter: bumped on every successful Arrive so
    // arrival occurrences scheduled by superseded incarnations pop stale.
    let mut epoch_counter = 0u64;

    let boundary_ns = |w: usize| ((w as u64 + 1) * window_ns).min(duration_ns);

    // Seed the queue: all boundaries (snapshots happen even on an empty
    // timeline) and every scenario event inside the horizon. The old
    // loop broke at the first occurrence >= duration and back-filled
    // trailing windows; filtering here plus letting boundaries drain is
    // the same schedule, since state only changes on API-touching
    // occurrences and those all sit strictly inside the horizon.
    let mut queue = EventQueue::with_capacity(n_windows + events.len() + n_slots + 1);
    for w in 0..n_windows {
        queue.push(Occ { t_ns: boundary_ns(w), kind: OccKind::Boundary(w) });
    }
    for (i, ev) in events.iter().enumerate() {
        let t = ev.at_ms * 1_000_000;
        if t < duration_ns {
            queue.push(Occ { t_ns: t, kind: OccKind::Event(i) });
        }
    }

    while let Some(occ) = queue.pop() {
        let t = occ.t_ns;
        match occ.kind {
            // Boundary pops rank first at equal t: the snapshot observes
            // the state *before* any same-instant occurrence mutates it,
            // exactly like the old loop's snapshot-before-process scan.
            OccKind::Boundary(w) => {
                occurrences += 1;
                snap_mem.push(api.dev.memory.used() as f64 / dev_mem as f64);
                snap_frag.push(api.dev.memory.frag_stats().fragmentation_index * 100.0);
                for (slot, s) in slots.iter().enumerate() {
                    if let Some(s) = s {
                        snap_tenant_mem[w * n_slots + slot] =
                            s.held_bytes as f64 / dev_mem as f64;
                    }
                }
            }
            // Scenario events take precedence over request arrivals on
            // ties; equal-time events keep `(at_ms, tenant)` list order
            // via the index.
            OccKind::Event(i) => {
                occurrences += 1;
                let ev = events[i];
                match ev.kind {
                    EventKind::Arrive { rate_hz, quota_pct, workload } => {
                        let quota = dev_mem.saturating_mul(quota_pct as u64) / 100;
                        let tc = TenantConfig::unlimited()
                            .with_mem_limit(quota)
                            .with_sm_limit(quota_pct as f64 / 100.0);
                        api.dev.clock.advance_to(t);
                        if api.ctx_create(ev.tenant, tc).is_ok() {
                            let (driver, first_ia_ns) = match workload {
                                WorkloadKind::Infer => {
                                    let mut gen = RequestGenerator::new(
                                        tenant_stream_seed(cfg.seed, ev.tenant),
                                        rate_hz,
                                    )
                                    .with_lengths(MAX_PROMPT, MAX_GEN);
                                    let mut protos = VecDeque::with_capacity(PROTO_BATCH);
                                    let pending = draw_request(&mut gen, &mut protos);
                                    let ia = pending.inter_arrival_ns;
                                    (Driver::Infer { gen, protos, pending }, ia)
                                }
                                WorkloadKind::Train => {
                                    let mut gen = TrainingGenerator::new(
                                        train_stream_seed(cfg.seed, ev.tenant),
                                        rate_hz,
                                    );
                                    let pending = gen.next_step();
                                    let ia = pending.inter_arrival_ns;
                                    // Per-job communicator over the cell's
                                    // node topology, mirroring the NCCL-001
                                    // construction (warm the hook cache,
                                    // then read it; ring collectives launch
                                    // ~2 intercepted kernels per rank). The
                                    // detached clock makes the collective's
                                    // internal advance a no-op; the engine
                                    // bills the returned latency to the
                                    // shared device clock itself.
                                    let topo = cfg.node_topology(&api.dev.spec);
                                    api.virt.hook_overhead_ns(&mut api.dev);
                                    let hook = api.virt.hook_overhead_ns(&mut api.dev);
                                    let ranks = cfg.gpu_count.max(2);
                                    let comms = CollectiveCtx::new(topo, VirtualClock::new())
                                        .with_virt_overhead(hook, 2 * ranks);
                                    (Driver::Train { gen, pending, comms }, ia)
                                }
                            };
                            let next_arrival_ns = t + first_ia_ns.max(1.0) as u64;
                            epoch_counter += 1;
                            let epoch = epoch_counter;
                            let slot = slot_of(ev.tenant);
                            ever[slot] = true;
                            slots[slot] = Some(Tenant {
                                driver,
                                quota_cfg: tc,
                                base_rate_hz: rate_hz,
                                burst_until_ns: None,
                                epoch,
                                ring: VecDeque::with_capacity(KV_RING + 1),
                                held_bytes: 0,
                            });
                            if next_arrival_ns < duration_ns {
                                queue.push(Occ {
                                    t_ns: next_arrival_ns,
                                    kind: OccKind::Arrival { tenant: ev.tenant, epoch },
                                });
                            }
                        }
                    }
                    EventKind::Depart => {
                        if slots[slot_of(ev.tenant)].take().is_some() {
                            api.dev.clock.advance_to(t);
                            let _ = api.ctx_destroy(ev.tenant);
                        }
                    }
                    EventKind::Burst { factor, until_ms } => {
                        if let Some(s) = slots[slot_of(ev.tenant)].as_mut() {
                            let rate = s.base_rate_hz * factor;
                            s.driver.set_rate(rate);
                            s.burst_until_ns = Some(until_ms * 1_000_000);
                        }
                    }
                    EventKind::Fail => {
                        api.dev.clock.advance_to(t);
                        api.inject_fault(ev.tenant, GpuFault::IllegalAddress);
                        out.fault = Some((ev.tenant, t));
                    }
                    // Trace-injected one-shot: service one extra unit of
                    // the tenant's pending work immediately, without
                    // consuming the stream or rescheduling its arrivals
                    // (a recorded out-of-band request/step in a replayed
                    // production trace).
                    EventKind::Request => {
                        let slot = slot_of(ev.tenant);
                        if let Some(state) = slots[slot].as_mut() {
                            let work = state.driver.pending_work();
                            api.dev.clock.advance_to(t);
                            serve_and_recover(
                                &mut api, ev.tenant, slot, t, &work, state, &mut busy, &mut out,
                            );
                        }
                    }
                }
            }
            // Work arrival (request or training step): service in
            // arrival order on the shared device. Equal-time arrivals
            // pop tenant-ascending.
            OccKind::Arrival { tenant, epoch } => {
                let slot = slot_of(tenant);
                let Some(state) = slots[slot].as_mut() else {
                    continue; // stale: scheduled by a departed incarnation
                };
                if state.epoch != epoch {
                    continue; // stale: the tenant re-arrived since
                }
                occurrences += 1;
                let work = state.driver.pending_work();
                api.dev.clock.advance_to(t);
                serve_and_recover(&mut api, tenant, slot, t, &work, state, &mut busy, &mut out);
                // Burst expiry is checked lazily at the next draw.
                if let Some(until) = state.burst_until_ns {
                    if t >= until {
                        let rate = state.base_rate_hz;
                        state.driver.set_rate(rate);
                        state.burst_until_ns = None;
                    }
                }
                let next_ia_ns = state.driver.redraw();
                let next_arrival_ns = t + next_ia_ns.max(1.0) as u64;
                if next_arrival_ns < duration_ns {
                    queue.push(Occ {
                        t_ns: next_arrival_ns,
                        kind: OccKind::Arrival { tenant, epoch },
                    });
                }
            }
        }
    }
    debug_assert_eq!(snap_mem.len(), n_windows, "every boundary popped exactly once");

    // ---- reduce to windowed series --------------------------------------
    let tenant_slots: Vec<(usize, TenantId)> = universe
        .iter()
        .enumerate()
        .filter(|(slot, _)| ever[*slot])
        .map(|(slot, t)| (slot, *t))
        .collect();
    let tenants: Vec<TenantId> = tenant_slots.iter().map(|&(_, t)| t).collect();
    // SoA latency buckets: counts → prefix offsets → one flat fill, no
    // per-window Vec allocations. Within-window order is completion
    // order, same as the old per-window pushes (and `stats::percentile`
    // sorts a copy, so only the multiset matters anyway).
    let mut lat_counts = vec![0usize; n_windows];
    for &(_, _, completion) in &out.samples {
        lat_counts[window_of(completion, window_ns, n_windows)] += 1;
    }
    let mut lat_starts = vec![0usize; n_windows + 1];
    for w in 0..n_windows {
        lat_starts[w + 1] = lat_starts[w] + lat_counts[w];
    }
    let mut lats_flat = vec![0.0f64; out.samples.len()];
    let mut fill = lat_starts.clone();
    for &(_, arrival, completion) in &out.samples {
        let w = window_of(completion, window_ns, n_windows);
        lats_flat[fill[w]] = (completion.saturating_sub(arrival)) as f64 / 1e6;
        fill[w] += 1;
    }
    let recovery_window = out.recovery.map(|r| window_of(r.recovered_ns, window_ns, n_windows));
    let mut series: Vec<SeriesPoint> =
        Vec::with_capacity(n_windows * (6 + 2 * tenants.len()) + 1);
    let mut window_p99: Vec<f64> = Vec::with_capacity(n_windows);
    for w in 0..n_windows {
        let win_len_ns = (boundary_ns(w) - (w as u64) * window_ns).max(1) as f64;
        let lats = &lats_flat[lat_starts[w]..lat_starts[w + 1]];
        let (p50, p99) = if lats.is_empty() {
            (f64::NAN, f64::NAN)
        } else {
            (crate::stats::percentile(lats, 50.0), crate::stats::percentile(lats, 99.0))
        };
        window_p99.push(p99);
        let thr = lats.len() as f64 / (win_len_ns / 1e9);
        let agg_busy: f64 = tenant_slots.iter().map(|&(slot, _)| busy.cell(w, slot)).sum();
        series.push(SeriesPoint { window: w, tenant: None, id: "DYN-LAT-P50", value: p50 });
        series.push(SeriesPoint { window: w, tenant: None, id: "DYN-LAT-P99", value: p99 });
        series.push(SeriesPoint { window: w, tenant: None, id: "DYN-THR", value: thr });
        series.push(SeriesPoint {
            window: w,
            tenant: None,
            id: "DYN-SM",
            value: agg_busy / win_len_ns,
        });
        series.push(SeriesPoint { window: w, tenant: None, id: "DYN-MEM", value: snap_mem[w] });
        series.push(SeriesPoint { window: w, tenant: None, id: "DYN-FRAG", value: snap_frag[w] });
        for &(slot, t) in &tenant_slots {
            series.push(SeriesPoint {
                window: w,
                tenant: Some(t),
                id: "DYN-SM",
                value: busy.cell(w, slot) / win_len_ns,
            });
            series.push(SeriesPoint {
                window: w,
                tenant: Some(t),
                id: "DYN-MEM",
                value: snap_tenant_mem[w * n_slots + slot],
            });
        }
        if recovery_window == Some(w) {
            let r = out.recovery.expect("recovery window implies recovery");
            series.push(SeriesPoint {
                window: w,
                tenant: Some(r.tenant),
                id: "DYN-RECOVERY",
                value: r.recovery_ms(),
            });
        }
    }

    // ---- per-scenario summary (the regress-gateable surface) ------------
    let p99s: Vec<f64> = window_p99.iter().copied().filter(|v| v.is_finite()).collect();
    let steady = if p99s.is_empty() { 0.0 } else { crate::stats::percentile(&p99s, 50.0) };
    let worst = p99s.iter().copied().fold(0.0f64, f64::max);
    let worst_win = if steady > 0.0 { (worst / steady - 1.0) * 100.0 } else { 0.0 };
    let thr_mean = out.samples.len() as f64 / (spec.duration_ms.max(1) as f64 / 1e3);
    // 0 = no fault injected. A fault that never recovered inside the
    // horizon must not read as 0 too (lower-better would score total
    // recovery failure as perfection): report the full horizon instead.
    let recovery_ms = match (out.recovery, out.fault) {
        (Some(r), _) => r.recovery_ms(),
        (None, Some(_)) => spec.duration_ms as f64,
        (None, None) => 0.0,
    };
    let mut summary = vec![
        ("DYN-P99-STEADY", steady),
        ("DYN-WORST-WIN", worst_win),
        ("DYN-THR-MEAN", thr_mean),
        ("DYN-RECOVERY", recovery_ms),
        ("DYN-EVENTS", occurrences as f64),
    ];
    // The training statistics are emitted only for timelines that start
    // a training tenant (a static property of the spec): inference-only
    // scenarios keep their frozen 5-statistic summary, so every
    // pre-training golden and baseline stays byte-stable.
    if spec.has_training() {
        let train_lats: Vec<f64> = out
            .train_samples
            .iter()
            .map(|&(_, start, completion)| (completion.saturating_sub(start)) as f64 / 1e6)
            .collect();
        let step_p99 =
            if train_lats.is_empty() { 0.0 } else { crate::stats::percentile(&train_lats, 99.0) };
        let allreduce_mean = if out.allreduce_lats_ms.is_empty() {
            0.0
        } else {
            out.allreduce_lats_ms.iter().sum::<f64>() / out.allreduce_lats_ms.len() as f64
        };
        // Interference: mean inference latency in train-active windows
        // (windows where >= 1 training step completed) over the mean in
        // train-idle windows, as a percent degradation. 0 when either
        // regime is empty (e.g. train-steady has no inference tenants).
        let mut train_active = vec![false; n_windows];
        for &(_, _, completion) in &out.train_samples {
            train_active[window_of(completion, window_ns, n_windows)] = true;
        }
        let (mut act_sum, mut act_n, mut idle_sum, mut idle_n) = (0.0f64, 0usize, 0.0f64, 0usize);
        for &(_, arrival, completion) in &out.samples {
            let lat_ms = (completion.saturating_sub(arrival)) as f64 / 1e6;
            if train_active[window_of(completion, window_ns, n_windows)] {
                act_sum += lat_ms;
                act_n += 1;
            } else {
                idle_sum += lat_ms;
                idle_n += 1;
            }
        }
        let interference = if act_n > 0 && idle_n > 0 {
            ((act_sum / act_n as f64) / (idle_sum / idle_n as f64) - 1.0) * 100.0
        } else {
            0.0
        };
        summary.push(("DYN-TRAIN-STEP-P99", step_p99));
        summary.push(("DYN-ALLREDUCE", allreduce_mean));
        summary.push(("DYN-MIX-INTERFERENCE", interference));
    }

    // ---- synthesize the lifecycle spans (tracing only) ------------------
    // Kernel sub-spans were recorded inline; the wider request/train-step
    // spans, the fault-recovery window and the scenario-event markers all
    // derive from data the engine collected anyway, so they are appended
    // here without ever touching the replay.
    if let Some(spans) = out.spans.as_mut() {
        for ev in &events {
            let t = ev.at_ms * 1_000_000;
            if t >= duration_ns {
                continue;
            }
            let name = match ev.kind {
                EventKind::Arrive { workload: WorkloadKind::Infer, .. } => "arrive",
                EventKind::Arrive { workload: WorkloadKind::Train, .. } => "arrive-train",
                EventKind::Depart => "depart",
                EventKind::Burst { .. } => "burst",
                EventKind::Fail => "fail",
                EventKind::Request => "inject",
            };
            spans.push(VSpan::instant("lifecycle", name, Some(ev.tenant), t));
        }
        for &(tenant, arrival, completion) in &out.samples {
            spans.push(VSpan::complete("request", "request", Some(tenant), arrival, completion));
        }
        for &(tenant, start, completion) in &out.train_samples {
            spans.push(VSpan::complete("train", "train-step", Some(tenant), start, completion));
        }
        if let Some(r) = out.recovery {
            spans.push(VSpan::complete(
                "fault",
                "recovery",
                Some(r.tenant),
                r.fault_ns,
                r.recovered_ns,
            ));
        }
    }

    let run = ScenarioRun {
        system: cfg.system.clone(),
        scenario: spec.name,
        duration_ms: spec.duration_ms,
        window_ms: spec.window_ms,
        windows: n_windows,
        tenants,
        series,
        summary,
        completed: out.samples.len(),
        train_steps: out.train_samples.len(),
        failed: out.failed,
        recovery: out.recovery,
        occurrences,
    };
    (run, out.spans.unwrap_or_default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{dynamics_seed, task_seed};

    fn cfg_for(system: &str, scenario: &str, duration_ms: u64, window_ms: u64) -> RunConfig {
        let mut cfg = RunConfig::quick(system);
        cfg.seed = task_seed(dynamics_seed(42, scenario, duration_ms, window_ms), system, scenario);
        cfg
    }

    fn run(system: &str, scenario: &str, duration_ms: u64, window_ms: u64) -> ScenarioRun {
        let spec = ScenarioSpec::preset(scenario, duration_ms, window_ms).unwrap();
        run_scenario(&cfg_for(system, scenario, duration_ms, window_ms), &spec)
    }

    #[test]
    fn steady_timeline_completes_requests_and_fills_windows() {
        let r = run("native", "steady", 300, 50);
        assert_eq!(r.windows, 6);
        assert_eq!(r.tenants, vec![1, 2, 3, 4]);
        // 4 tenants × 40 Hz × 0.3 s ≈ 48 expected arrivals.
        assert!(r.completed > 20, "completed={}", r.completed);
        assert_eq!(r.failed, 0);
        assert!(r.recovery.is_none());
        // Aggregate series present for every window.
        assert_eq!(r.points("DYN-LAT-P99").iter().filter(|p| p.tenant.is_none()).count(), 6);
        assert_eq!(r.points("DYN-THR").len(), 6);
        // Throughput is positive in the bulk of the run.
        let thr: Vec<f64> = r.points("DYN-THR").iter().map(|p| p.value).collect();
        assert!(thr.iter().sum::<f64>() > 0.0);
        // Memory is actually held (KV rings) and fragmentation is a
        // finite percentage.
        let mem = r.points("DYN-MEM");
        assert!(mem.iter().any(|p| p.tenant.is_none() && p.value > 0.0), "{mem:?}");
        assert!(r.points("DYN-FRAG").iter().all(|p| p.value.is_finite()));
        // Summary stats all finite (the regress surface requires it).
        for (id, v) in &r.summary {
            assert!(v.is_finite(), "{id}={v}");
        }
        assert!(r.summary_value("DYN-THR-MEAN").unwrap() > 50.0);
        assert_eq!(r.summary_value("DYN-RECOVERY"), Some(0.0));
        // DYN-EVENTS is the exact occurrence count: every window boundary,
        // every scenario event (steady's 4 arrivals at t=0), and every
        // serviced request arrival (completed or abandoned).
        assert_eq!(r.summary_value("DYN-EVENTS"), Some(r.occurrences as f64));
        assert_eq!(r.occurrences as usize, r.windows + 4 + r.completed + r.failed);
    }

    #[test]
    fn bit_identical_across_repeat_runs() {
        let a = run("hami", "churn", 300, 50);
        let b = run("hami", "churn", 300, 50);
        assert_eq!(a.series.len(), b.series.len());
        for (x, y) in a.series.iter().zip(&b.series) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.window, y.window);
            assert_eq!(x.tenant, y.tenant);
            assert_eq!(x.value.to_bits(), y.value.to_bits(), "{}/{}", x.id, x.window);
        }
        assert_eq!(a.summary, b.summary);
        assert_eq!(a.occurrences, b.occurrences);
    }

    #[test]
    fn seed_changes_the_timeline() {
        let spec = ScenarioSpec::preset("steady", 300, 50).unwrap();
        let a = run_scenario(&cfg_for("hami", "steady", 300, 50), &spec);
        let mut cfg = cfg_for("hami", "steady", 300, 50);
        cfg.seed = cfg.seed.wrapping_add(1);
        let b = run_scenario(&cfg, &spec);
        assert!(
            a.series
                .iter()
                .zip(&b.series)
                .any(|(x, y)| x.value.to_bits() != y.value.to_bits()),
            "seed change did not affect the timeline"
        );
    }

    #[test]
    fn failover_records_recovery_for_the_failing_tenant() {
        let r = run("hami", "failover", 400, 50);
        let rec = r.recovery.expect("failover must recover");
        // The preset faults tenant 2 at 40% of the horizon.
        assert_eq!(rec.tenant, 2);
        assert!(rec.fault_ns == 160 * 1_000_000, "fault at {}", rec.fault_ns);
        assert!(rec.recovered_ns > rec.fault_ns);
        assert!(rec.recovery_ms() > 0.0);
        assert_eq!(r.summary_value("DYN-RECOVERY"), Some(rec.recovery_ms()));
        // The marker lands in the recovery window, attributed to tenant 2.
        let markers = r.points("DYN-RECOVERY");
        assert_eq!(markers.len(), 1);
        assert_eq!(markers[0].tenant, Some(2));
        assert_eq!(markers[0].window, r.window_of(rec.recovered_ns));
        assert!(markers[0].window >= 3, "window={}", markers[0].window);
    }

    #[test]
    fn churn_departures_change_population_and_free_memory() {
        let r = run("native", "churn", 400, 50);
        assert_eq!(r.tenants, vec![1, 2, 3, 4, 5]);
        // Tenant 2 departs at 60%: its per-tenant memory series must drop
        // back to zero in the tail windows.
        let t2_mem: Vec<f64> = r
            .series
            .iter()
            .filter(|p| p.id == "DYN-MEM" && p.tenant == Some(2))
            .map(|p| p.value)
            .collect();
        assert_eq!(t2_mem.len(), r.windows);
        assert!(t2_mem.iter().any(|v| *v > 0.0), "t2 never held memory: {t2_mem:?}");
        assert_eq!(*t2_mem.last().unwrap(), 0.0, "t2 still resident after departing");
    }

    #[test]
    fn spike_raises_tail_latency_mid_run() {
        let r = run("hami", "spike", 500, 50);
        let p99: Vec<f64> = r.points("DYN-LAT-P99").iter().map(|p| p.value).collect();
        let worst = r.summary_value("DYN-WORST-WIN").unwrap();
        // The 4x burst through the middle must make some window visibly
        // worse than the steady state.
        assert!(worst > 0.0, "worst-window degradation {worst}% (p99s {p99:?})");
    }

    #[test]
    fn train_steady_produces_training_statistics() {
        let r = run("hami", "train-steady", 300, 50);
        assert!(r.train_steps > 0, "no training steps completed");
        assert_eq!(r.completed, 0, "train-steady has no inference tenants");
        assert_eq!(r.failed, 0);
        // 5 classic statistics + the 3 training ones.
        assert_eq!(r.summary.len(), 8);
        assert!(r.summary_value("DYN-TRAIN-STEP-P99").unwrap() > 0.0);
        // 20 steps/s with accum 4 syncs well inside a 300 ms horizon.
        assert!(r.summary_value("DYN-ALLREDUCE").unwrap() > 0.0);
        // No inference regime at all: interference reads 0 by definition.
        assert_eq!(r.summary_value("DYN-MIX-INTERFERENCE"), Some(0.0));
        // Training busy time and activation memory ride the existing
        // series unchanged.
        assert!(r.points("DYN-SM").iter().any(|p| p.value > 0.0));
        assert!(r.points("DYN-MEM").iter().any(|p| p.tenant.is_none() && p.value > 0.0));
        // Occurrence accounting: boundaries + the 2 arrive events +
        // every serviced training step.
        assert_eq!(r.occurrences as usize, r.windows + 2 + r.train_steps + r.failed);
    }

    #[test]
    fn mixed_churn_runs_both_regimes_and_is_deterministic() {
        let a = run("hami", "mixed-churn", 400, 50);
        assert!(a.completed > 0, "no inference requests completed");
        assert!(a.train_steps > 0, "no training steps completed");
        assert_eq!(a.summary.len(), 8);
        for (id, v) in &a.summary {
            assert!(v.is_finite(), "{id}={v}");
        }
        // The training tenant joins at 30%: there are both train-idle and
        // train-active windows, so the interference statistic compares
        // two non-empty regimes.
        assert!(a.summary_value("DYN-MIX-INTERFERENCE").unwrap().is_finite());
        let b = run("hami", "mixed-churn", 400, 50);
        for (x, y) in a.series.iter().zip(&b.series) {
            assert_eq!(x.value.to_bits(), y.value.to_bits(), "{}/{}", x.id, x.window);
        }
        assert_eq!(a.summary, b.summary);
    }

    #[test]
    fn inference_only_presets_keep_the_frozen_summary_shape() {
        for scenario in ["steady", "churn", "spike", "failover"] {
            let r = run("native", scenario, 300, 50);
            assert_eq!(r.summary.len(), 5, "{scenario} summary grew");
            assert_eq!(r.train_steps, 0, "{scenario}");
            assert!(r.summary_value("DYN-TRAIN-STEP-P99").is_none(), "{scenario}");
        }
    }

    #[test]
    fn trace_request_events_inject_one_shot_work() {
        use crate::dynsim::scenario::{TenantEvent, WorkloadKind, TRACE_SCENARIO};
        let arrive = TenantEvent {
            at_ms: 0,
            tenant: 1,
            kind: EventKind::Arrive { rate_hz: 10.0, quota_pct: 50, workload: WorkloadKind::Infer },
        };
        let without = ScenarioSpec {
            name: TRACE_SCENARIO,
            duration_ms: 300,
            window_ms: 50,
            events: vec![arrive],
        };
        let with = ScenarioSpec {
            events: vec![
                arrive,
                TenantEvent { at_ms: 100, tenant: 1, kind: EventKind::Request },
                // Tenant 2 never arrived: the injected request is a no-op.
                TenantEvent { at_ms: 150, tenant: 2, kind: EventKind::Request },
            ],
            ..without.clone()
        };
        let cfg = cfg_for("hami", TRACE_SCENARIO, 300, 50);
        let base = run_scenario(&cfg, &without);
        let injected = run_scenario(&cfg, &with);
        // The one-shot services the pending request without consuming the
        // stream: exactly one extra completion, same arrival schedule.
        assert_eq!(injected.completed, base.completed + 1);
        // Both request events count as processed occurrences (the no-op
        // one included), and the injected service is not an arrival.
        assert_eq!(
            injected.occurrences as usize,
            injected.windows + 3 + (injected.completed - 1) + injected.failed
        );
    }

    #[test]
    fn tracing_is_pure_observation() {
        // The traced run must produce a bit-identical `ScenarioRun`:
        // span recording reads values the engine computes anyway and
        // never touches the clock, the RNG streams or the allocator.
        for (system, scenario) in [("hami", "mixed-churn"), ("native", "failover")] {
            let spec = ScenarioSpec::preset(scenario, 400, 50).unwrap();
            let cfg = cfg_for(system, scenario, 400, 50);
            let plain = run_scenario(&cfg, &spec);
            let (traced, spans) = run_scenario_traced(&cfg, &spec);
            assert_eq!(plain.tenants, traced.tenants, "{system}/{scenario}");
            assert_eq!(plain.series, traced.series, "{system}/{scenario}");
            for ((xi, xv), (yi, yv)) in plain.summary.iter().zip(&traced.summary) {
                assert_eq!(xi, yi);
                assert_eq!(xv.to_bits(), yv.to_bits(), "{system}/{scenario}: {xi}");
            }
            assert_eq!(plain.completed, traced.completed, "{system}/{scenario}");
            assert_eq!(plain.failed, traced.failed, "{system}/{scenario}");
            assert_eq!(plain.recovery, traced.recovery, "{system}/{scenario}");
            assert_eq!(plain.occurrences, traced.occurrences, "{system}/{scenario}");
            // And the spans actually carry the replay: every completed
            // request has its lifecycle span, markers cover the scenario
            // events, and no span ends before it starts (saturating dur).
            assert!(!spans.is_empty(), "{system}/{scenario}: no spans recorded");
            let requests = spans.iter().filter(|s| s.cat == "request").count();
            assert_eq!(requests, traced.completed, "{system}/{scenario}");
            let markers = spans.iter().filter(|s| s.cat == "lifecycle").count();
            assert_eq!(markers, spec.events.len(), "{system}/{scenario}");
            if traced.recovery.is_some() {
                assert_eq!(spans.iter().filter(|s| s.cat == "fault").count(), 1);
            }
            for s in &spans {
                assert!(s.end_ns() >= s.start_ns, "{system}/{scenario}: {s:?}");
                assert!(s.tenant.is_some(), "dynsim spans are all tenant-laned");
            }
            // Traced twice = byte-identical spans (the export contract).
            let (_, again) = run_scenario_traced(&cfg, &spec);
            assert_eq!(spans, again, "{system}/{scenario}: spans not deterministic");
        }
    }
}
