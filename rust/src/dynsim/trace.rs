//! Line-oriented external trace format for `gvbench dynamics --trace`.
//!
//! A trace file is a recorded (or hand-written) tenant timeline replayed
//! as a [`ScenarioSpec`] under the reserved [`TRACE_SCENARIO`] key —
//! bit-identical at any `--jobs` count, because the replay rides the
//! same `dynamics_seed` derivation as the presets.
//!
//! # Format
//!
//! ```text
//! # comments and blank lines are skipped
//! duration-ms 400
//! window-ms 50
//! at 0   arrive 1 infer rate=40 quota=25
//! at 0   arrive 3 train rate=15 quota=40
//! at 100 burst 1 factor=4 until-ms=200
//! at 150 request 1
//! at 200 depart 1
//! at 250 fail 3
//! ```
//!
//! Two headers (`duration-ms`, `window-ms`, in that order) fix the
//! replay geometry; every following line is one event at an explicit
//! millisecond timestamp. Timestamps must be non-decreasing and inside
//! the horizon. `depart` / `burst` / `fail` / `request` must name a
//! tenant that previously arrived (and has not departed); re-arrival of
//! a departed tenant is allowed and replays as a fresh incarnation,
//! mirroring the engine's epoch rules. Parse errors name the offending
//! line and field, in the style of the regress baseline's row rejection.

use anyhow::{bail, Result};

use super::scenario::{EventKind, ScenarioSpec, TenantEvent, WorkloadKind, TRACE_SCENARIO};
use crate::simgpu::TenantId;

/// Longest replayable horizon, ms (matches the regress baseline's bound).
const MAX_DURATION_MS: u64 = 3_600_000;

fn parse_u64(lineno: usize, field: &str, tok: &str) -> Result<u64> {
    match tok.parse::<u64>() {
        Ok(v) => Ok(v),
        Err(_) => bail!("line {lineno}: {field} `{tok}` is not a non-negative integer"),
    }
}

fn parse_f64(lineno: usize, field: &str, tok: &str) -> Result<f64> {
    match tok.parse::<f64>() {
        Ok(v) if v.is_finite() && v > 0.0 => Ok(v),
        _ => bail!("line {lineno}: {field} `{tok}` must be a positive finite number"),
    }
}

/// Split a `key=value` token, insisting on the expected key.
fn keyed<'a>(lineno: usize, expect: &str, tok: Option<&'a str>) -> Result<&'a str> {
    let Some(tok) = tok else {
        bail!("line {lineno}: missing `{expect}=` field");
    };
    match tok.split_once('=') {
        Some((k, v)) if k == expect => Ok(v),
        _ => bail!("line {lineno}: expected `{expect}=<value>`, found `{tok}`"),
    }
}

/// Parse a trace file into a replayable [`ScenarioSpec`] (named
/// [`TRACE_SCENARIO`]). Errors name the offending line and field.
pub fn parse_trace(text: &str) -> Result<ScenarioSpec> {
    // (lineno, content) for every non-blank, non-comment line.
    let mut lines = text
        .lines()
        .enumerate()
        .map(|(i, l)| (i + 1, l.trim()))
        .filter(|(_, l)| !l.is_empty() && !l.starts_with('#'));

    let mut header = |key: &str| -> Result<u64> {
        let Some((lineno, line)) = lines.next() else {
            bail!("trace ends before the `{key}` header");
        };
        match line.split_whitespace().collect::<Vec<_>>()[..] {
            [k, v] if k == key => parse_u64(lineno, key, v),
            _ => bail!("line {lineno}: expected `{key} <ms>`, found `{line}`"),
        }
    };
    let duration_ms = header("duration-ms")?;
    let window_ms = header("window-ms")?;
    if duration_ms == 0 || duration_ms > MAX_DURATION_MS {
        bail!("duration-ms {duration_ms} out of range 1..={MAX_DURATION_MS}");
    }
    if window_ms == 0 || window_ms > duration_ms {
        bail!("window-ms {window_ms} out of range 1..={duration_ms}");
    }

    let mut events: Vec<TenantEvent> = Vec::new();
    let mut active: std::collections::BTreeSet<TenantId> = std::collections::BTreeSet::new();
    let mut last_at = 0u64;
    for (lineno, line) in lines {
        let mut toks = line.split_whitespace();
        match toks.next() {
            Some("at") => {}
            Some(other) => bail!("line {lineno}: expected `at <ms> <event> ...`, found `{other}`"),
            None => unreachable!("blank lines are filtered"),
        }
        let at_ms = match toks.next() {
            Some(t) => parse_u64(lineno, "timestamp", t)?,
            None => bail!("line {lineno}: missing timestamp after `at`"),
        };
        if at_ms < last_at {
            bail!("line {lineno}: timestamp {at_ms} goes backwards (previous event at {last_at})");
        }
        if at_ms >= duration_ms {
            bail!("line {lineno}: timestamp {at_ms} is outside the {duration_ms} ms horizon");
        }
        last_at = at_ms;
        let Some(kind_tok) = toks.next() else {
            bail!("line {lineno}: missing event kind after the timestamp");
        };
        let tenant = match toks.next() {
            Some(t) => parse_u64(lineno, "tenant", t)? as TenantId,
            None => bail!("line {lineno}: missing tenant id after `{kind_tok}`"),
        };
        let kind = match kind_tok {
            "arrive" => {
                let workload = match toks.next() {
                    Some(w) => match WorkloadKind::from_key(w) {
                        Some(k) => k,
                        None => bail!(
                            "line {lineno}: unknown workload `{w}` (expected: infer, train)"
                        ),
                    },
                    None => bail!("line {lineno}: missing workload (infer|train) after the tenant"),
                };
                let rate_hz = parse_f64(lineno, "rate", keyed(lineno, "rate", toks.next())?)?;
                let quota_tok = keyed(lineno, "quota", toks.next())?;
                let quota_pct = parse_u64(lineno, "quota", quota_tok)?;
                if quota_pct == 0 || quota_pct > 100 {
                    bail!("line {lineno}: quota {quota_pct} out of range 1..=100");
                }
                active.insert(tenant);
                EventKind::Arrive { rate_hz, quota_pct: quota_pct as u32, workload }
            }
            "depart" => {
                if !active.remove(&tenant) {
                    bail!("line {lineno}: depart names unknown tenant {tenant} (never arrived or already departed)");
                }
                EventKind::Depart
            }
            "burst" => {
                if !active.contains(&tenant) {
                    bail!("line {lineno}: burst names unknown tenant {tenant} (never arrived or already departed)");
                }
                let factor = parse_f64(lineno, "factor", keyed(lineno, "factor", toks.next())?)?;
                let until_ms =
                    parse_u64(lineno, "until-ms", keyed(lineno, "until-ms", toks.next())?)?;
                EventKind::Burst { factor, until_ms }
            }
            "fail" => {
                if !active.contains(&tenant) {
                    bail!("line {lineno}: fail names unknown tenant {tenant} (never arrived or already departed)");
                }
                EventKind::Fail
            }
            "request" => {
                if !active.contains(&tenant) {
                    bail!("line {lineno}: request names unknown tenant {tenant} (never arrived or already departed)");
                }
                EventKind::Request
            }
            other => bail!(
                "line {lineno}: unknown event kind `{other}` (expected: arrive, depart, burst, fail, request)"
            ),
        };
        if let Some(extra) = toks.next() {
            bail!("line {lineno}: trailing token `{extra}`");
        }
        events.push(TenantEvent { at_ms, tenant, kind });
    }
    Ok(ScenarioSpec { name: TRACE_SCENARIO, duration_ms, window_ms, events })
}

/// Render a timeline back to the trace format. `parse_trace ∘
/// render_trace` is the identity on any spec whose events are in
/// non-decreasing timestamp order with a consistent tenant population
/// (f64 fields use Rust's shortest round-trip `Display`, so rates and
/// burst factors survive exactly).
pub fn render_trace(spec: &ScenarioSpec) -> String {
    let mut out = String::new();
    out.push_str("# gvbench dynamics trace\n");
    out.push_str(&format!("duration-ms {}\n", spec.duration_ms));
    out.push_str(&format!("window-ms {}\n", spec.window_ms));
    for e in &spec.events {
        let line = match e.kind {
            EventKind::Arrive { rate_hz, quota_pct, workload } => format!(
                "at {} arrive {} {} rate={} quota={}",
                e.at_ms,
                e.tenant,
                workload.key(),
                rate_hz,
                quota_pct
            ),
            EventKind::Depart => format!("at {} depart {}", e.at_ms, e.tenant),
            EventKind::Burst { factor, until_ms } => format!(
                "at {} burst {} factor={} until-ms={}",
                e.at_ms, e.tenant, factor, until_ms
            ),
            EventKind::Fail => format!("at {} fail {}", e.at_ms, e.tenant),
            EventKind::Request => format!("at {} request {}", e.at_ms, e.tenant),
        };
        out.push_str(&line);
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = "\
# a mixed train+infer trace
duration-ms 400
window-ms 50

at 0 arrive 1 infer rate=40 quota=25
at 0 arrive 3 train rate=15.5 quota=40
at 100 burst 1 factor=4 until-ms=200
at 150 request 1
at 200 depart 1
at 250 fail 3
at 300 arrive 1 infer rate=20 quota=25
";

    #[test]
    fn parses_the_full_event_vocabulary() {
        let sc = parse_trace(GOOD).unwrap();
        assert_eq!(sc.name, TRACE_SCENARIO);
        assert_eq!((sc.duration_ms, sc.window_ms), (400, 50));
        assert_eq!(sc.events.len(), 7);
        assert!(sc.has_training());
        assert_eq!(
            sc.events[1].kind,
            EventKind::Arrive { rate_hz: 15.5, quota_pct: 40, workload: WorkloadKind::Train }
        );
        assert_eq!(sc.events[3].kind, EventKind::Request);
        // Tenant 1 departs and re-arrives: a fresh incarnation.
        assert_eq!(sc.events[6].at_ms, 300);
    }

    #[test]
    fn round_trips_through_render() {
        let sc = parse_trace(GOOD).unwrap();
        let again = parse_trace(&render_trace(&sc)).unwrap();
        assert_eq!(sc, again);
    }

    #[test]
    fn rejects_unknown_event_kind_naming_the_line() {
        let bad = "duration-ms 400\nwindow-ms 50\nat 0 arrive 1 infer rate=40 quota=25\nat 10 evict 1\n";
        let err = parse_trace(bad).unwrap_err().to_string();
        assert!(err.contains("line 4"), "{err}");
        assert!(err.contains("unknown event kind `evict`"), "{err}");
    }

    #[test]
    fn rejects_non_monotonic_timestamps_naming_the_line() {
        let bad =
            "duration-ms 400\nwindow-ms 50\nat 100 arrive 1 infer rate=40 quota=25\nat 50 depart 1\n";
        let err = parse_trace(bad).unwrap_err().to_string();
        assert!(err.contains("line 4"), "{err}");
        assert!(err.contains("goes backwards"), "{err}");
    }

    #[test]
    fn rejects_unknown_tenants_naming_the_line() {
        for (kind, suffix) in [
            ("depart", ""),
            ("burst", " factor=2 until-ms=100"),
            ("fail", ""),
            ("request", ""),
        ] {
            let bad = format!("duration-ms 400\nwindow-ms 50\nat 0 {kind} 9{suffix}\n");
            let err = parse_trace(&bad).unwrap_err().to_string();
            assert!(err.contains("line 3"), "{kind}: {err}");
            assert!(err.contains("unknown tenant 9"), "{kind}: {err}");
        }
        // A departed tenant is unknown again.
        let bad = "duration-ms 400\nwindow-ms 50\nat 0 arrive 1 infer rate=40 quota=25\nat 10 depart 1\nat 20 fail 1\n";
        let err = parse_trace(bad).unwrap_err().to_string();
        assert!(err.contains("line 5") && err.contains("unknown tenant 1"), "{err}");
    }

    #[test]
    fn rejects_bad_headers_and_geometry() {
        let err = parse_trace("").unwrap_err().to_string();
        assert!(err.contains("`duration-ms` header"), "{err}");
        let err = parse_trace("duration-ms 400\n").unwrap_err().to_string();
        assert!(err.contains("`window-ms` header"), "{err}");
        let err = parse_trace("window-ms 50\nduration-ms 400\n").unwrap_err().to_string();
        assert!(err.contains("line 1") && err.contains("duration-ms"), "{err}");
        let err = parse_trace("duration-ms 400\nwindow-ms 0\n").unwrap_err().to_string();
        assert!(err.contains("window-ms 0 out of range"), "{err}");
        let err = parse_trace("duration-ms 100\nwindow-ms 200\n").unwrap_err().to_string();
        assert!(err.contains("window-ms 200 out of range"), "{err}");
        let err = parse_trace("duration-ms 0\nwindow-ms 1\n").unwrap_err().to_string();
        assert!(err.contains("duration-ms 0 out of range"), "{err}");
    }

    #[test]
    fn rejects_bad_fields_naming_line_and_field() {
        let cases: [(&str, &str); 7] = [
            ("at 0 arrive 1 batch rate=40 quota=25", "unknown workload `batch`"),
            ("at 0 arrive 1 infer rate=0 quota=25", "rate `0`"),
            ("at 0 arrive 1 infer rate=40 quota=0", "quota 0 out of range"),
            ("at 0 arrive 1 infer rate=40 quota=250", "quota 250 out of range"),
            ("at 0 arrive 1 infer quota=25 rate=40", "expected `rate=<value>`"),
            ("at 500 arrive 1 infer rate=40 quota=25", "outside the 400 ms horizon"),
            ("at 0 arrive 1 infer rate=40 quota=25 junk", "trailing token `junk`"),
        ];
        for (line, needle) in cases {
            let bad = format!("duration-ms 400\nwindow-ms 50\n{line}\n");
            let err = parse_trace(&bad).unwrap_err().to_string();
            assert!(err.contains("line 3"), "{line}: {err}");
            assert!(err.contains(needle), "{line}: {err}");
        }
        let bad = "duration-ms 400\nwindow-ms 50\nat 0 arrive 1 infer rate=40 quota=25\nat 10 burst 1 factor=-1 until-ms=100\n";
        let err = parse_trace(bad).unwrap_err().to_string();
        assert!(err.contains("line 4") && err.contains("factor `-1`"), "{err}");
    }
}
