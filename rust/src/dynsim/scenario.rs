//! Scenario declarations for the virtual-time dynamic-scenario engine.
//!
//! A [`ScenarioSpec`] is a *timeline*: a list of [`TenantEvent`]s placed
//! on a `duration_ms` horizon, reduced into `window_ms` reporting
//! windows by the engine. Events model the tenant dynamics MISO
//! (arXiv 2207.11428) and fragmentation-aware scheduling work
//! (arXiv 2511.18906) identify as the dominant regime of multi-tenant
//! GPU behaviour: arrivals, departures, load bursts and injected faults.
//!
//! Tenants carry a [`WorkloadKind`]: open-loop LLM *inference* request
//! streams, or *training* jobs stepping fwd/bwd/optimizer kernel triples
//! with periodic gradient allreduce — MIGPerf (arXiv 2301.00407) shows
//! the two stress GPU partitions in opposite directions, which is
//! exactly what the `mixed-churn` preset co-locates.
//!
//! Six named presets cover the deployment-critical shapes; events are
//! placed at fixed *fractions* of the horizon so the same preset scales
//! to any `--duration-ms` without re-tuning. A seventh timeline kind is
//! not a preset at all: an external trace file
//! ([`crate::dynsim::trace`]) parsed into a `ScenarioSpec` under the
//! reserved [`TRACE_SCENARIO`] key.

use crate::simgpu::TenantId;

/// The named scenario presets, in CLI/reporting order.
pub const PRESETS: [&str; 6] =
    ["steady", "churn", "spike", "failover", "train-steady", "mixed-churn"];

/// The reserved timeline key of externally supplied trace files
/// (`gvbench dynamics --trace FILE`). Not a preset: it never appears in
/// [`PRESETS`], but the seed derivation, the reporting surfaces and the
/// regress schema treat it like any other canonical scenario key.
pub const TRACE_SCENARIO: &str = "trace";

/// Resolve a user-supplied scenario name to its canonical `'static` key
/// (`None` for unknown names). The executor's task labels and the seed
/// derivation both use the canonical key.
pub fn canonical(name: &str) -> Option<&'static str> {
    PRESETS.iter().copied().find(|p| *p == name)
}

/// Like [`canonical`], additionally resolving the reserved
/// [`TRACE_SCENARIO`] key — the set of timeline names that can appear in
/// a dynamics summary surface (and therefore a regress baseline).
pub fn canonical_timeline(name: &str) -> Option<&'static str> {
    if name == TRACE_SCENARIO {
        return Some(TRACE_SCENARIO);
    }
    canonical(name)
}

/// What a tenant runs once arrived: an open-loop inference request
/// stream or a paced training job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadKind {
    /// LLM serving: Poisson request arrivals at `rate_hz`, each request a
    /// prefill/decode kernel pair.
    Infer,
    /// Training: paced optimizer steps at `rate_hz` steps/second, each a
    /// fwd/bwd/optimizer kernel triple with periodic gradient allreduce.
    Train,
}

impl WorkloadKind {
    /// The trace-format key (`infer` / `train`).
    pub fn key(&self) -> &'static str {
        match self {
            WorkloadKind::Infer => "infer",
            WorkloadKind::Train => "train",
        }
    }

    /// Parse a trace-format key (`None` for unknown keys).
    pub fn from_key(key: &str) -> Option<WorkloadKind> {
        match key {
            "infer" => Some(WorkloadKind::Infer),
            "train" => Some(WorkloadKind::Train),
            _ => None,
        }
    }
}

/// What happens to a tenant at one point of the timeline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EventKind {
    /// The tenant's container starts: context creation, quota
    /// registration, and an open-loop workload at `rate_hz` (requests/s
    /// for inference, optimizer steps/s for training).
    Arrive {
        rate_hz: f64,
        /// Per-tenant quota in percent of the whole device (memory and
        /// SM alike, mirroring the sweep's quota axis).
        quota_pct: u32,
        /// What the tenant runs.
        workload: WorkloadKind,
    },
    /// The tenant's container stops: context destruction releases every
    /// allocation it holds (carving holes into the heap).
    Depart,
    /// The tenant's request rate is multiplied by `factor` until
    /// `until_ms` on the scenario timeline.
    Burst { factor: f64, until_ms: u64 },
    /// A GPU fault is injected and attributed to the tenant; the engine
    /// recovers it (context destroy + recreate) at the first failing call
    /// and records the recovery time.
    Fail,
    /// One extra unit of the tenant's pending work is injected and
    /// serviced immediately (a recorded one-shot request). Only trace
    /// files produce this kind; no preset does.
    Request,
}

/// One scheduled event of a scenario timeline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TenantEvent {
    /// Offset from scenario start, ms.
    pub at_ms: u64,
    pub tenant: TenantId,
    pub kind: EventKind,
}

/// A declared dynamic scenario: named timeline + reporting geometry.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioSpec {
    /// Canonical preset key (see [`PRESETS`]) or [`TRACE_SCENARIO`].
    pub name: &'static str,
    /// Timeline horizon, ms.
    pub duration_ms: u64,
    /// Reporting window length, ms.
    pub window_ms: u64,
    /// Events in timeline order (ties broken by tenant id).
    pub events: Vec<TenantEvent>,
}

impl ScenarioSpec {
    /// Build a named preset on a `duration_ms` horizon with `window_ms`
    /// reporting windows. Returns `None` for unknown names.
    ///
    /// # Examples
    ///
    /// ```
    /// use gvb::dynsim::scenario::ScenarioSpec;
    ///
    /// let sc = ScenarioSpec::preset("failover", 1000, 100).unwrap();
    /// assert_eq!(sc.name, "failover");
    /// assert_eq!(sc.windows(), 10);
    /// // The fault lands at 40% of the horizon.
    /// assert!(sc.events.iter().any(|e| e.at_ms == 400));
    /// // Training presets carry training tenants; inference ones do not.
    /// assert!(ScenarioSpec::preset("mixed-churn", 1000, 100).unwrap().has_training());
    /// assert!(!sc.has_training());
    /// assert!(ScenarioSpec::preset("meltdown", 1000, 100).is_none());
    /// ```
    pub fn preset(name: &str, duration_ms: u64, window_ms: u64) -> Option<ScenarioSpec> {
        let name = canonical(name)?;
        let at = |pct: u64| duration_ms * pct / 100;
        let arrive = |at_ms: u64, tenant: TenantId, rate_hz: f64, quota_pct: u32| TenantEvent {
            at_ms,
            tenant,
            kind: EventKind::Arrive { rate_hz, quota_pct, workload: WorkloadKind::Infer },
        };
        let train = |at_ms: u64, tenant: TenantId, rate_hz: f64, quota_pct: u32| TenantEvent {
            at_ms,
            tenant,
            kind: EventKind::Arrive { rate_hz, quota_pct, workload: WorkloadKind::Train },
        };
        let events = match name {
            // Fixed population at the paper's default equal-share-of-four
            // operating point: the control every dynamic shape is read
            // against.
            "steady" => vec![
                arrive(0, 1, 40.0, 25),
                arrive(0, 2, 40.0, 25),
                arrive(0, 3, 40.0, 25),
                arrive(0, 4, 40.0, 25),
            ],
            // MISO-style arrival/departure churn: population 2 → 4 → 3 →
            // 4 → 3 over the horizon, so fragmentation and scheduling
            // state evolve instead of reaching steady state.
            "churn" => vec![
                arrive(0, 1, 40.0, 25),
                arrive(0, 2, 40.0, 25),
                arrive(at(25), 3, 40.0, 25),
                arrive(at(40), 4, 40.0, 25),
                TenantEvent { at_ms: at(60), tenant: 2, kind: EventKind::Depart },
                arrive(at(70), 5, 40.0, 25),
                TenantEvent { at_ms: at(85), tenant: 3, kind: EventKind::Depart },
            ],
            // One tenant turns noisy: a 4x rate burst through the middle
            // of the horizon, then back off — the transient-overload shape
            // QoS consistency is about.
            "spike" => vec![
                arrive(0, 1, 30.0, 30),
                arrive(0, 2, 30.0, 30),
                arrive(0, 3, 30.0, 30),
                TenantEvent {
                    at_ms: at(40),
                    tenant: 2,
                    kind: EventKind::Burst { factor: 4.0, until_ms: at(70) },
                },
            ],
            // A mid-run fault on the busiest tenant; the engine recovers
            // it and the time series shows the outage + recovery window.
            // The fault lands at 40% and the faulted tenant runs hot
            // (60 Hz) so recovery completes well inside any reasonable
            // horizon.
            "failover" => vec![
                arrive(0, 1, 40.0, 30),
                arrive(0, 2, 60.0, 30),
                arrive(0, 3, 40.0, 30),
                TenantEvent { at_ms: at(40), tenant: 2, kind: EventKind::Fail },
            ],
            // Two co-located training jobs from t=0: the pure-training
            // control for the step-time and allreduce statistics.
            "train-steady" => vec![
                train(0, 1, 20.0, 40),
                train(0, 2, 20.0, 40),
            ],
            // Train/infer co-location under churn: an inference-only
            // opening phase, a training job joining mid-run (so the
            // interference statistic has both regimes to compare), then
            // more serving churn around it.
            "mixed-churn" => vec![
                arrive(0, 1, 40.0, 25),
                arrive(0, 2, 40.0, 25),
                train(at(30), 3, 15.0, 40),
                arrive(at(50), 4, 40.0, 25),
                TenantEvent { at_ms: at(70), tenant: 2, kind: EventKind::Depart },
            ],
            _ => unreachable!("canonical() returned an unknown preset"),
        };
        Some(ScenarioSpec { name, duration_ms, window_ms, events })
    }

    /// Build an ad-hoc uniform-load timeline: `tenants` tenants all
    /// arriving at `t = 0` with the same rate and quota. Not a named
    /// preset — the CLI only exposes [`ScenarioSpec::preset`] — but the
    /// scale harness (`benches/dynamics_scaling.rs`) uses it to push the
    /// event core to 10³-tenant / 10⁶-occurrence horizons that no preset
    /// reaches.
    ///
    /// # Examples
    ///
    /// ```
    /// use gvb::dynsim::scenario::ScenarioSpec;
    ///
    /// let sc = ScenarioSpec::uniform_load("bench-uniform", 1000, 10.0, 1, 100_000, 1_000);
    /// assert_eq!(sc.events.len(), 1000);
    /// assert_eq!(sc.windows(), 100);
    /// ```
    pub fn uniform_load(
        name: &'static str,
        tenants: u32,
        rate_hz: f64,
        quota_pct: u32,
        duration_ms: u64,
        window_ms: u64,
    ) -> ScenarioSpec {
        let events = (1..=tenants)
            .map(|tenant| TenantEvent {
                at_ms: 0,
                tenant,
                kind: EventKind::Arrive { rate_hz, quota_pct, workload: WorkloadKind::Infer },
            })
            .collect();
        ScenarioSpec { name, duration_ms, window_ms, events }
    }

    /// Number of reporting windows (the last window is truncated when
    /// `window_ms` does not divide `duration_ms`; see
    /// [`crate::dynsim::ScenarioRun::window_end_ms`] for window ends).
    pub fn windows(&self) -> usize {
        if self.window_ms == 0 {
            return 0;
        }
        (self.duration_ms.div_ceil(self.window_ms)) as usize
    }

    /// Whether the timeline ever starts a training tenant — the condition
    /// under which the engine emits the training summary statistics
    /// (`DYN-TRAIN-STEP-P99` / `DYN-ALLREDUCE` / `DYN-MIX-INTERFERENCE`).
    pub fn has_training(&self) -> bool {
        self.events.iter().any(|e| {
            matches!(e.kind, EventKind::Arrive { workload: WorkloadKind::Train, .. })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_and_unknowns_do_not() {
        for p in PRESETS {
            let sc = ScenarioSpec::preset(p, 1000, 100).unwrap();
            assert_eq!(sc.name, p);
            assert!(!sc.events.is_empty());
            assert!(sc.events.iter().all(|e| e.at_ms < 1000));
        }
        assert!(ScenarioSpec::preset("bogus", 1000, 100).is_none());
        assert_eq!(canonical("churn"), Some("churn"));
        assert_eq!(canonical("Churn"), None);
        assert_eq!(canonical("mixed-churn"), Some("mixed-churn"));
        // `trace` is a reserved timeline key, never a preset.
        assert_eq!(canonical(TRACE_SCENARIO), None);
        assert!(ScenarioSpec::preset(TRACE_SCENARIO, 1000, 100).is_none());
        assert_eq!(canonical_timeline(TRACE_SCENARIO), Some("trace"));
        assert_eq!(canonical_timeline("failover"), Some("failover"));
        assert_eq!(canonical_timeline("meltdown"), None);
    }

    #[test]
    fn window_geometry() {
        let sc = ScenarioSpec::preset("steady", 1000, 100).unwrap();
        assert_eq!(sc.windows(), 10);
        // Non-dividing horizon: the last window is truncated.
        let sc = ScenarioSpec::preset("steady", 250, 100).unwrap();
        assert_eq!(sc.windows(), 3);
    }

    #[test]
    fn events_scale_with_the_horizon() {
        let short = ScenarioSpec::preset("failover", 400, 50).unwrap();
        let long = ScenarioSpec::preset("failover", 4000, 500).unwrap();
        let fail_at = |sc: &ScenarioSpec| {
            sc.events
                .iter()
                .find(|e| e.kind == EventKind::Fail)
                .map(|e| e.at_ms)
                .unwrap()
        };
        assert_eq!(fail_at(&short), 160);
        assert_eq!(fail_at(&long), 1600);
    }

    #[test]
    fn churn_population_peaks_at_four() {
        let sc = ScenarioSpec::preset("churn", 1000, 100).unwrap();
        let mut pop = 0i32;
        let mut max_pop = 0i32;
        for e in &sc.events {
            match e.kind {
                EventKind::Arrive { .. } => pop += 1,
                EventKind::Depart => pop -= 1,
                _ => {}
            }
            max_pop = max_pop.max(pop);
        }
        assert_eq!(max_pop, 4);
        assert_eq!(pop, 3); // final population
    }

    #[test]
    fn workload_kinds_partition_the_presets() {
        // The four original presets are inference-only; the two new ones
        // carry training tenants.
        for p in ["steady", "churn", "spike", "failover"] {
            assert!(!ScenarioSpec::preset(p, 1000, 100).unwrap().has_training(), "{p}");
        }
        for p in ["train-steady", "mixed-churn"] {
            assert!(ScenarioSpec::preset(p, 1000, 100).unwrap().has_training(), "{p}");
        }
        // mixed-churn opens inference-only: its training tenant arrives
        // strictly after t=0, so interference has an idle phase to
        // compare against.
        let mixed = ScenarioSpec::preset("mixed-churn", 1000, 100).unwrap();
        let train_at = mixed
            .events
            .iter()
            .find(|e| {
                matches!(e.kind, EventKind::Arrive { workload: WorkloadKind::Train, .. })
            })
            .map(|e| e.at_ms)
            .unwrap();
        assert!(train_at > 0, "training must join mid-run, not at t=0");
    }

    #[test]
    fn workload_keys_round_trip() {
        for k in [WorkloadKind::Infer, WorkloadKind::Train] {
            assert_eq!(WorkloadKind::from_key(k.key()), Some(k));
        }
        assert_eq!(WorkloadKind::from_key("batch"), None);
    }
}
