//! CSV export: one row per metric, stable column order for analysis tools.

use super::{unit_of, Report};
use crate::metrics::taxonomy;

fn esc(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Render the report as CSV.
pub fn render(rep: &Report) -> String {
    let mut out = String::from(
        "id,name,category,unit,system,value,mean,stddev,median,p95,p99,cv,expected,deviation_percent,score\n",
    );
    for r in rep.results {
        let d = taxonomy::by_id(r.id);
        let expected = rep.baseline_for(r.id).map(|b| b.value).unwrap_or(f64::NAN);
        let score = rep
            .card
            .per_metric
            .iter()
            .find(|(id, _)| *id == r.id)
            .map(|(_, s)| *s)
            .unwrap_or(f64::NAN);
        out.push_str(&format!(
            "{},{},{},{},{},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.3},{:.4}\n",
            r.id,
            esc(d.map(|d| d.name).unwrap_or("")),
            d.map(|d| d.category.name()).unwrap_or(""),
            esc(unit_of(r.id)),
            rep.system,
            r.value,
            r.summary.mean,
            r.summary.stddev,
            r.summary.median,
            r.summary.p95,
            r.summary.p99,
            r.summary.cv,
            expected,
            rep.deviation(r),
            score,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escaping() {
        assert_eq!(esc("plain"), "plain");
        assert_eq!(esc("a,b"), "\"a,b\"");
        assert_eq!(esc("say \"hi\""), "\"say \"\"hi\"\"\"");
    }
}
