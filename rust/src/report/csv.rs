//! CSV export: one row per metric, stable column order for analysis tools.

use super::{unit_of, Report};
use crate::metrics::taxonomy;

fn esc(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Render the report as CSV.
pub fn render(rep: &Report) -> String {
    let mut out = String::from(
        "id,name,category,unit,system,value,mean,stddev,median,p95,p99,cv,expected,deviation_percent,score\n",
    );
    for r in rep.results {
        let d = taxonomy::by_id(r.id);
        let expected = rep.baseline_for(r.id).map(|b| b.value).unwrap_or(f64::NAN);
        let score = rep
            .card
            .per_metric
            .iter()
            .find(|(id, _)| *id == r.id)
            .map(|(_, s)| *s)
            .unwrap_or(f64::NAN);
        out.push_str(&format!(
            "{},{},{},{},{},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.3},{:.4}\n",
            r.id,
            esc(d.map(|d| d.name).unwrap_or("")),
            d.map(|d| d.category.name()).unwrap_or(""),
            esc(unit_of(r.id)),
            rep.system,
            r.value,
            r.summary.mean,
            r.summary.stddev,
            r.summary.median,
            r.summary.p95,
            r.summary.p99,
            r.summary.cv,
            expected,
            rep.deviation(r),
            score,
        ));
    }
    out
}

/// Render executor timings ([`ExecutionStats`]) as a task-timing CSV —
/// one row per executed (system, metric) task, stable column order.
pub fn render_timings(stats: &crate::coordinator::executor::ExecutionStats) -> String {
    let mut out = String::from("metric_id,system,worker,wall_ms\n");
    for t in &stats.tasks {
        out.push_str(&format!(
            "{},{},{},{:.3}\n",
            esc(t.metric_id),
            esc(&t.system),
            t.worker,
            t.wall_ns as f64 / 1e6
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::{ExecutionStats, TaskTiming};

    #[test]
    fn escaping() {
        assert_eq!(esc("plain"), "plain");
        assert_eq!(esc("a,b"), "\"a,b\"");
        assert_eq!(esc("say \"hi\""), "\"say \"\"hi\"\"\"");
    }

    #[test]
    fn timings_rows() {
        let stats = ExecutionStats {
            jobs: 2,
            tasks: vec![
                TaskTiming { system: "hami".into(), metric_id: "OH-001", wall_ns: 2_500_000, start_ns: 0, worker: 0 },
                TaskTiming { system: "hami".into(), metric_id: "OH-002", wall_ns: 1_000_000, start_ns: 500_000, worker: 1 },
            ],
            wall_ns: 3_000_000,
        };
        let csv = render_timings(&stats);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "metric_id,system,worker,wall_ms");
        assert_eq!(lines[1], "OH-001,hami,0,2.500");
        assert_eq!(lines[2], "OH-002,hami,1,1.000");
    }
}
