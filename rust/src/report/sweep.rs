//! Sweep-surface reporting: the aggregated (system × tenants × quota ×
//! gpu_count × link) results from `coordinator::sweep`, rendered as JSON,
//! CSV or a TXT summary that highlights the worst-degrading cells per
//! system and per link kind.
//!
//! The CSV is the canonical "sweep surface": **long format**, one row per
//! (cell × metric) with the cell's score summary denormalized onto every
//! row — so it doubles as a per-cell regression baseline for
//! `gvbench regress` (`crate::regress` keys rows by the full
//! `(system, tenants, quota_pct, gpu_count, link, metric)` coordinate).
//! Infeasible cells contribute a single marker row (`feasible=false`,
//! empty id/value) that the regress engine skips. No host timings appear
//! in the CSV, so identical sweeps render byte-identical CSV at any job
//! count (`rust/tests/sweep_determinism.rs`). The JSON adds per-category
//! scores, the per-link worst-cell summary and the `execution` timing
//! object as metadata.

use crate::coordinator::sweep::{SweepCell, SweepSurface};

use super::json::{array, render_execution, Obj};
use super::Format;

/// Render the surface in the requested format.
pub fn render(surface: &SweepSurface, format: Format) -> String {
    match format {
        Format::Json => render_json(surface),
        Format::Csv => render_csv(surface),
        Format::Txt => render_txt(surface),
    }
}

/// Column header of the long-format CSV surface (also the schema the
/// regress baseline parser detects extended sweep baselines by).
pub const CSV_HEADER: &str =
    "system,tenants,quota_pct,gpu_count,link,is_baseline,feasible,id,value,overall_score,delta_vs_baseline_pct,grade";

/// Long format: one row per (cell, metric), cell summary denormalized;
/// one marker row per infeasible cell. Stable column order for analysis
/// tools and regress baselines.
pub fn render_csv(surface: &SweepSurface) -> String {
    let mut out = String::from(CSV_HEADER);
    out.push('\n');
    for cell in &surface.cells {
        let prefix = format!(
            "{},{},{},{},{},{},{}",
            cell.system,
            cell.tenants,
            cell.quota_pct,
            cell.gpu_count,
            cell.link.key(),
            cell.is_baseline,
            cell.feasible
        );
        if !cell.feasible {
            out.push_str(&format!("{prefix},,,NaN,0.000,-\n"));
            continue;
        }
        let summary = format!(
            "{:.6},{:.3},{}",
            cell.overall,
            cell.delta_vs_baseline_pct,
            cell.grade.letter()
        );
        for r in &cell.results {
            out.push_str(&format!("{prefix},{},{:.6},{summary}\n", r.id, r.value));
        }
    }
    out
}

/// The full surface plus executor timings, in the Listing-7 JSON style.
pub fn render_json(surface: &SweepSurface) -> String {
    let cells: Vec<String> = surface
        .cells
        .iter()
        .map(|c| {
            let cats: Vec<String> = c
                .per_category
                .iter()
                .map(|(cat, score)| {
                    Obj::new().str("category", cat.key()).num("score", *score).build()
                })
                .collect();
            let metrics: Vec<String> = c
                .results
                .iter()
                .map(|r| Obj::new().str("id", r.id).num("value", r.value).build())
                .collect();
            cell_obj(c)
                .field("categories", array(cats))
                .field("metrics", array(metrics))
                .build()
        })
        .collect();
    let worst: Vec<String> =
        surface.worst_cells().iter().map(|c| cell_obj(c).build()).collect();
    let worst_by_link: Vec<String> =
        surface.worst_cells_per_link().iter().map(|c| cell_obj(c).build()).collect();
    let ids: Vec<String> =
        surface.metric_ids.iter().map(|id| super::json::quote(id)).collect();
    Obj::new()
        .str("benchmark_version", crate::VERSION)
        .field("seed", surface.seed.to_string())
        .field("metric_ids", array(ids))
        .field("cells", array(cells))
        .field("worst_degrading", array(worst))
        .field("worst_degrading_by_link", array(worst_by_link))
        .field("execution", render_execution(&surface.stats))
        .build()
}

fn cell_obj(c: &SweepCell) -> Obj {
    Obj::new()
        .str("system", &c.system)
        .field("tenants", c.tenants.to_string())
        .field("quota_pct", c.quota_pct.to_string())
        .field("gpu_count", c.gpu_count.to_string())
        .str("link", c.link.key())
        .bool("is_baseline", c.is_baseline)
        .bool("feasible", c.feasible)
        .num("overall_score", c.overall) // NaN renders as null when infeasible
        .num("delta_vs_baseline_pct", c.delta_vs_baseline_pct)
        .str("grade", if c.feasible { c.grade.letter() } else { "-" })
}

/// Human-readable summary: the cell table plus the worst-degrading cells
/// per system and per (system, link).
pub fn render_txt(surface: &SweepSurface) -> String {
    let mut out = String::new();
    out.push_str("GPU-Virt-Bench — scenario sweep surface\n");
    out.push_str(&format!(
        "  seed {}, {} metrics per cell, {} cells\n\n",
        surface.seed,
        surface.metric_ids.len(),
        surface.cells.len()
    ));
    out.push_str(&format!(
        "{:<12} {:>7} {:>7} {:>5} {:>7} {:>9} {:>15} {:>6}\n",
        "System", "Tenants", "Quota%", "GPUs", "Link", "Overall%", "Δ vs baseline", "Grade"
    ));
    out.push_str(&format!("{}\n", "-".repeat(76)));
    for c in &surface.cells {
        let marker = if c.is_baseline { "*" } else { "" };
        if !c.feasible {
            out.push_str(&format!(
                "{:<12} {:>7} {:>7} {:>5} {:>7} {:>9} {:>15} {:>6}\n",
                format!("{}{}", c.system, marker),
                c.tenants,
                c.quota_pct,
                c.gpu_count,
                c.link.key(),
                "n/a",
                "infeasible",
                "-"
            ));
            continue;
        }
        out.push_str(&format!(
            "{:<12} {:>7} {:>7} {:>5} {:>7} {:>9.1} {:>14.1}% {:>6}\n",
            format!("{}{}", c.system, marker),
            c.tenants,
            c.quota_pct,
            c.gpu_count,
            c.link.key(),
            c.overall * 100.0,
            c.delta_vs_baseline_pct,
            c.grade.letter()
        ));
    }
    out.push_str("  (* = baseline cell: 1 tenant, 100% quota on its topology)\n\n");
    out.push_str("Worst-degrading cells per system:\n");
    let worst = surface.worst_cells();
    if worst.is_empty() {
        out.push_str("  (no non-baseline cells)\n");
    }
    for c in worst {
        out.push_str(&format!(
            "  {:<10} {} tenants @ {:>3}% quota on {}g/{} — overall {:.1}% ({:+.1}% vs baseline)\n",
            c.system,
            c.tenants,
            c.quota_pct,
            c.gpu_count,
            c.link.key(),
            c.overall * 100.0,
            c.delta_vs_baseline_pct
        ));
    }
    // Only worth a second section when the surface spans >1 link kind.
    let worst_by_link = surface.worst_cells_per_link();
    let multi_link = {
        let mut kinds: Vec<&str> = worst_by_link.iter().map(|c| c.link.key()).collect();
        kinds.sort_unstable();
        kinds.dedup();
        kinds.len() > 1
    };
    if multi_link {
        out.push_str("\nWorst-degrading cells per system and link:\n");
        for c in worst_by_link {
            out.push_str(&format!(
                "  {:<10} {:<6} {} tenants @ {:>3}% quota on {} GPUs — overall {:.1}% ({:+.1}% vs baseline)\n",
                c.system,
                c.link.key(),
                c.tenants,
                c.quota_pct,
                c.gpu_count,
                c.overall * 100.0,
                c.delta_vs_baseline_pct
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::ExecutionStats;
    use crate::metrics::{Category, MetricResult};
    use crate::scoring::Grade;
    use crate::simgpu::nvlink::LinkKind;

    fn cell_on(
        system: &str,
        tenants: u32,
        quota: u32,
        gpus: u32,
        link: LinkKind,
        overall: f64,
        delta: f64,
    ) -> SweepCell {
        SweepCell {
            system: system.to_string(),
            tenants,
            quota_pct: quota,
            gpu_count: gpus,
            link,
            overall,
            delta_vs_baseline_pct: delta,
            per_category: vec![(Category::Pcie, overall)],
            grade: Grade::from_score(overall),
            is_baseline: tenants == 1 && quota == 100,
            feasible: true,
            results: vec![
                MetricResult::from_value("PCIE-001", system, 12.5),
                MetricResult::from_value("PCIE-004", system, overall),
            ],
        }
    }

    fn cell(system: &str, tenants: u32, quota: u32, overall: f64, delta: f64) -> SweepCell {
        cell_on(system, tenants, quota, 4, LinkKind::Pcie, overall, delta)
    }

    fn surface() -> SweepSurface {
        SweepSurface {
            seed: 42,
            metric_ids: vec!["PCIE-001", "PCIE-004"],
            cells: vec![
                cell("hami", 1, 100, 0.80, 0.0),
                cell("hami", 4, 25, 0.60, -25.0),
                cell("hami", 8, 25, 0.56, -30.0),
            ],
            stats: ExecutionStats::default(),
        }
    }

    #[test]
    fn csv_rows_and_columns() {
        let s = surface();
        let csv = render_csv(&s);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], CSV_HEADER);
        // 3 cells × 2 metrics, long format.
        assert_eq!(lines.len(), 7);
        assert_eq!(
            lines[1],
            "hami,1,100,4,pcie,true,true,PCIE-001,12.500000,0.800000,0.000,B"
        );
        assert_eq!(
            lines[2],
            "hami,1,100,4,pcie,true,true,PCIE-004,0.800000,0.800000,0.000,B"
        );
        assert_eq!(
            lines[3],
            "hami,4,25,4,pcie,false,true,PCIE-001,12.500000,0.600000,-25.000,D"
        );
        // The long CSV parses directly as an extended sweep regress
        // baseline carrying the topology coordinate.
        let b = crate::regress::parse_baseline_csv(&csv, "native").unwrap();
        assert_eq!(b.schema, crate::regress::BaselineSchema::Sweep);
        assert_eq!(b.rows.len(), 6);
        let c = b.rows[0].cell.unwrap();
        assert_eq!((c.tenants, c.quota_pct), (1, 100));
        assert_eq!(c.topo, Some((4, LinkKind::Pcie)));
        assert_eq!(b.rows[0].value, 12.5);
    }

    #[test]
    fn infeasible_cells_render_as_such() {
        let mut s = surface();
        s.cells.push(SweepCell {
            system: "mig".to_string(),
            tenants: 8,
            quota_pct: 25,
            gpu_count: 4,
            link: LinkKind::Pcie,
            overall: f64::NAN,
            delta_vs_baseline_pct: 0.0,
            per_category: Vec::new(),
            grade: Grade::F,
            is_baseline: false,
            feasible: false,
            results: Vec::new(),
        });
        let csv = render_csv(&s);
        assert!(csv.contains("mig,8,25,4,pcie,false,false,,,NaN,0.000,-"), "{csv}");
        let b = crate::regress::parse_baseline_csv(&csv, "native").unwrap();
        assert_eq!(b.infeasible.len(), 1);
        assert_eq!(b.infeasible[0].0, "mig");
        assert_eq!(
            (b.infeasible[0].1.tenants, b.infeasible[0].1.quota_pct),
            (8, 25)
        );
        let j = render_json(&s);
        assert!(j.contains("\"feasible\": false"));
        assert!(j.contains("\"overall_score\": null"));
        let t = render_txt(&s);
        assert!(t.contains("infeasible"));
    }

    #[test]
    fn json_contains_cells_and_worst() {
        let s = surface();
        let j = render_json(&s);
        assert!(j.contains("\"cells\""));
        assert!(j.contains("\"worst_degrading\""));
        assert!(j.contains("\"worst_degrading_by_link\""));
        assert!(j.contains("\"quota_pct\": 25"));
        assert!(j.contains("\"gpu_count\": 4"));
        assert!(j.contains("\"link\": \"pcie\""));
        assert!(j.contains("\"execution\""));
        assert!(j.contains("\"metrics\": [{\"id\": \"PCIE-001\""));
        // The worst hami cell is the 8-tenant one.
        let worst_idx = j.find("worst_degrading").unwrap();
        assert!(j[worst_idx..].contains("\"tenants\": 8"));
        assert!(!j[worst_idx..].contains("\"tenants\": 4"));
    }

    #[test]
    fn txt_highlights_worst_cells() {
        let s = surface();
        let t = render_txt(&s);
        assert!(t.contains("scenario sweep surface"));
        assert!(t.contains("Worst-degrading cells per system:"));
        assert!(t.contains("8 tenants"));
        assert!(t.contains("baseline cell"));
        // Single-link surface: no per-link section.
        assert!(!t.contains("per system and link"), "{t}");
    }

    #[test]
    fn txt_multi_link_surface_adds_per_link_section() {
        let mut s = surface();
        s.cells.push(cell_on("hami", 1, 100, 4, LinkKind::NvLink, 0.82, 0.0));
        s.cells.push(cell_on("hami", 4, 25, 4, LinkKind::NvLink, 0.70, -14.6));
        let t = render_txt(&s);
        assert!(t.contains("Worst-degrading cells per system and link:"), "{t}");
        assert!(t.contains("nvlink"), "{t}");
        assert!(t.contains("pcie"), "{t}");
        let j = render_json(&s);
        let idx = j.find("worst_degrading_by_link").unwrap();
        assert!(j[idx..].contains("\"link\": \"nvlink\""), "{j}");
    }
}
