//! Dynamics-surface reporting: the windowed time series from
//! [`crate::dynsim`], rendered as long-format CSV, JSON or a TXT summary
//! of the worst windows per system, plus the **summary CSV** — the
//! regress-compatible per-scenario surface (`gvbench dynamics
//! --summary-out`) the regression engine gates like sweep cells.
//!
//! The time-series CSV is long format: one row per (system × scenario ×
//! window × series), with per-tenant series keyed by the `tenant` column
//! (`all` = aggregate). It carries no host timings, so identical grids
//! render byte-identical CSV at any `--jobs` count
//! (`rust/tests/dynamics_determinism.rs`). The JSON adds the executor
//! timing object as metadata.

use crate::dynsim::{DynSurface, ScenarioRun};

use super::json::{array, execution_obj, num, Obj};
use super::Format;

/// Column header of the long-format time-series CSV.
pub const CSV_HEADER: &str = "system,scenario,duration_ms,window_ms,window,t_ms,tenant,id,value";

/// Column header of the regress-compatible summary CSV (one row per
/// system × scenario × summary statistic; the `dynamics` baseline schema
/// of [`crate::regress`]).
pub const SUMMARY_CSV_HEADER: &str = "system,scenario,duration_ms,window_ms,id,value";

/// Render the time-series surface in the requested format.
pub fn render(surface: &DynSurface, format: Format) -> String {
    match format {
        Format::Json => render_json(surface),
        Format::Csv => render_csv(surface),
        Format::Txt => render_txt(surface),
    }
}

/// Long-format time-series CSV. Windows with no completed request render
/// `NaN` latency percentiles (documented in `docs/dynamics.md`); every
/// other value is finite.
pub fn render_csv(surface: &DynSurface) -> String {
    let mut out = String::from(CSV_HEADER);
    out.push('\n');
    for run in &surface.runs {
        let prefix = format!(
            "{},{},{},{}",
            run.system, run.scenario, run.duration_ms, run.window_ms
        );
        for p in &run.series {
            let tenant = match p.tenant {
                None => "all".to_string(),
                Some(t) => t.to_string(),
            };
            let t_ms = run.window_end_ms(p.window);
            out.push_str(&format!(
                "{prefix},{},{},{},{},{:.6}\n",
                p.window, t_ms, tenant, p.id, p.value
            ));
        }
    }
    out
}

/// The regress-compatible summary CSV: every value finite, keyed by the
/// full `(system, scenario, duration_ms, window_ms, id)` coordinate.
pub fn render_summary_csv(surface: &DynSurface) -> String {
    let mut out = String::from(SUMMARY_CSV_HEADER);
    out.push('\n');
    for run in &surface.runs {
        for (id, value) in &run.summary {
            out.push_str(&format!(
                "{},{},{},{},{},{:.6}\n",
                run.system, run.scenario, run.duration_ms, run.window_ms, id, value
            ));
        }
    }
    out
}

fn run_obj(run: &ScenarioRun) -> Obj {
    let summary: Vec<String> = run
        .summary
        .iter()
        .map(|(id, v)| Obj::new().str("id", id).num("value", *v).build())
        .collect();
    let series: Vec<String> = run
        .series
        .iter()
        .map(|p| {
            let mut o = Obj::new().field("window", p.window.to_string());
            o = match p.tenant {
                None => o.str("tenant", "all"),
                Some(t) => o.field("tenant", t.to_string()),
            };
            o.str("id", p.id).num("value", p.value).build()
        })
        .collect();
    let tenants: Vec<String> = run.tenants.iter().map(|t| t.to_string()).collect();
    let mut o = Obj::new()
        .str("system", &run.system)
        .str("scenario", run.scenario)
        .field("duration_ms", run.duration_ms.to_string())
        .field("window_ms", run.window_ms.to_string())
        .field("windows", run.windows.to_string())
        .field("tenants", array(tenants))
        .field("completed", run.completed.to_string())
        .field("failed", run.failed.to_string());
    if let Some(r) = run.recovery {
        o = o.field(
            "recovery",
            Obj::new()
                .field("tenant", r.tenant.to_string())
                .num("fault_ms", r.fault_ns as f64 / 1e6)
                .num("recovered_ms", r.recovered_ns as f64 / 1e6)
                .num("recovery_ms", r.recovery_ms())
                .build(),
        );
    } else {
        o = o.field("recovery", "null".to_string());
    }
    o.field("summary", array(summary)).field("series", array(series))
}

/// The full surface plus executor timings, in the Listing-7 JSON style.
/// The `execution` object carries the event core's replay throughput —
/// total occurrences processed across runs and wall-clock events/sec.
/// Occurrence counts are virtual-time-deterministic (they equal the sum
/// of the per-run `DYN-EVENTS` summary values); events/sec is a host
/// timing like the rest of `execution`, reported but never gated.
pub fn render_json(surface: &DynSurface) -> String {
    let runs: Vec<String> = surface.runs.iter().map(|r| run_obj(r).build()).collect();
    let events: u64 = surface.runs.iter().map(|r| r.occurrences).sum();
    let events_per_sec = if surface.stats.wall_ns > 0 {
        events as f64 / (surface.stats.wall_ns as f64 / 1e9)
    } else {
        0.0
    };
    let execution = execution_obj(&surface.stats)
        .field("events_processed", events.to_string())
        .num("events_per_sec", events_per_sec)
        .build();
    Obj::new()
        .str("benchmark_version", crate::VERSION)
        .field("seed", surface.seed.to_string())
        .field("duration_ms", surface.duration_ms.to_string())
        .field("window_ms", surface.window_ms.to_string())
        .field("runs", array(runs))
        .field("execution", execution)
        .build()
}

/// Human-readable summary: per (system, scenario) the summary statistics
/// and the worst window.
pub fn render_txt(surface: &DynSurface) -> String {
    let mut out = String::new();
    out.push_str("GPU-Virt-Bench — dynamic-scenario surface\n");
    out.push_str(&format!(
        "  seed {}, horizon {} ms, window {} ms, {} timeline(s)\n\n",
        surface.seed,
        surface.duration_ms,
        surface.window_ms,
        surface.runs.len()
    ));
    out.push_str(&format!(
        "{:<12} {:<10} {:>9} {:>12} {:>12} {:>11} {:>10}\n",
        "System", "Scenario", "Requests", "P99 steady", "Worst win", "Thr (req/s)", "Recovery"
    ));
    out.push_str(&format!("{}\n", "-".repeat(82)));
    for run in &surface.runs {
        let get = |id: &str| run.summary_value(id).unwrap_or(f64::NAN);
        let recovery = get("DYN-RECOVERY");
        out.push_str(&format!(
            "{:<12} {:<10} {:>9} {:>9.2} ms {:>11.1}% {:>11.1} {}\n",
            run.system,
            run.scenario,
            run.completed,
            get("DYN-P99-STEADY"),
            get("DYN-WORST-WIN"),
            get("DYN-THR-MEAN"),
            if recovery > 0.0 { format!("{recovery:>7.2} ms") } else { "      n/a".to_string() },
        ));
    }
    out.push('\n');
    out.push_str("Worst window per timeline (highest P99):\n");
    for run in &surface.runs {
        let worst = run
            .series
            .iter()
            .filter(|p| p.id == "DYN-LAT-P99" && p.tenant.is_none() && p.value.is_finite())
            .max_by(|a, b| a.value.partial_cmp(&b.value).expect("finite"));
        match worst {
            Some(p) => out.push_str(&format!(
                "  {:<10} {:<10} window {:>3} (t={} ms): p99 {} ms\n",
                run.system,
                run.scenario,
                p.window,
                run.window_end_ms(p.window),
                num(p.value)
            )),
            None => out.push_str(&format!(
                "  {:<10} {:<10} (no completed requests)\n",
                run.system, run.scenario
            )),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::ExecutionStats;
    use crate::dynsim::{Recovery, SeriesPoint};

    fn run(system: &str, scenario: &'static str) -> ScenarioRun {
        ScenarioRun {
            system: system.to_string(),
            scenario,
            duration_ms: 200,
            window_ms: 100,
            windows: 2,
            tenants: vec![1, 2],
            series: vec![
                SeriesPoint { window: 0, tenant: None, id: "DYN-LAT-P99", value: 2.5 },
                SeriesPoint { window: 0, tenant: None, id: "DYN-THR", value: 120.0 },
                SeriesPoint { window: 0, tenant: Some(1), id: "DYN-SM", value: 0.25 },
                SeriesPoint { window: 1, tenant: None, id: "DYN-LAT-P99", value: f64::NAN },
                SeriesPoint { window: 1, tenant: Some(2), id: "DYN-RECOVERY", value: 31.25 },
            ],
            summary: vec![
                ("DYN-P99-STEADY", 2.5),
                ("DYN-WORST-WIN", 12.0),
                ("DYN-THR-MEAN", 110.0),
                ("DYN-RECOVERY", 31.25),
                ("DYN-EVENTS", 30.0),
            ],
            completed: 24,
            failed: 0,
            recovery: Some(Recovery {
                tenant: 2,
                fault_ns: 100_000_000,
                recovered_ns: 131_250_000,
            }),
            occurrences: 30,
        }
    }

    fn surface() -> DynSurface {
        DynSurface {
            seed: 42,
            duration_ms: 200,
            window_ms: 100,
            runs: vec![run("native", "steady"), run("hami", "failover")],
            stats: ExecutionStats::default(),
        }
    }

    #[test]
    fn csv_long_format_rows() {
        let csv = render_csv(&surface());
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], CSV_HEADER);
        // 2 runs × 5 points.
        assert_eq!(lines.len(), 11);
        assert_eq!(lines[1], "native,steady,200,100,0,100,all,DYN-LAT-P99,2.500000");
        assert_eq!(lines[3], "native,steady,200,100,0,100,1,DYN-SM,0.250000");
        // Empty windows carry NaN latency; recovery rows name the tenant.
        assert!(lines[4].ends_with("DYN-LAT-P99,NaN"), "{}", lines[4]);
        assert_eq!(lines[5], "native,steady,200,100,1,200,2,DYN-RECOVERY,31.250000");
    }

    #[test]
    fn summary_csv_is_regress_parseable() {
        let csv = render_summary_csv(&surface());
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], SUMMARY_CSV_HEADER);
        assert_eq!(lines.len(), 11); // 2 runs × 5 summary stats
        assert_eq!(lines[1], "native,steady,200,100,DYN-P99-STEADY,2.500000");
        assert_eq!(lines[5], "native,steady,200,100,DYN-EVENTS,30.000000");
        let b = crate::regress::parse_baseline_csv(&csv, "native").unwrap();
        assert_eq!(b.schema, crate::regress::BaselineSchema::Dynamics);
        assert_eq!(b.rows.len(), 10);
        let d = b.rows[0].dyn_cell.as_ref().unwrap();
        assert_eq!(d.scenario, "steady");
        assert_eq!((d.duration_ms, d.window_ms), (200, 100));
        assert_eq!(b.rows[0].cell_label(), "steady@200ms/100ms");
    }

    #[test]
    fn json_carries_runs_series_and_recovery() {
        let j = render_json(&surface());
        assert!(j.contains("\"runs\""), "{j}");
        assert!(j.contains("\"scenario\": \"failover\""), "{j}");
        assert!(j.contains("\"summary\""), "{j}");
        assert!(j.contains("\"id\": \"DYN-P99-STEADY\""), "{j}");
        assert!(j.contains("\"recovery_ms\": 31.25"), "{j}");
        assert!(j.contains("\"tenant\": \"all\""), "{j}");
        assert!(j.contains("\"execution\""), "{j}");
        // The event core's replay throughput rides the execution object:
        // the deterministic total (2 fixture runs × 30 occurrences) plus
        // wall-clock events/sec (0 here — the default stats have no wall).
        assert!(j.contains("\"events_processed\": 60"), "{j}");
        assert!(j.contains("\"events_per_sec\": 0.0"), "{j}");
        // NaN series values render as null.
        assert!(j.contains("\"value\": null"), "{j}");
    }

    #[test]
    fn txt_summarises_worst_windows() {
        let t = render_txt(&surface());
        assert!(t.contains("dynamic-scenario surface"), "{t}");
        assert!(t.contains("steady"), "{t}");
        assert!(t.contains("Worst window per timeline"), "{t}");
        assert!(t.contains("31.25 ms"), "{t}");
        assert!(t.contains("window   0"), "{t}");
    }
}
