//! Cluster-surface reporting: the fleet replays from [`crate::cluster`],
//! rendered as long-format CSV, JSON or a TXT summary per cell, plus the
//! **summary CSV** — the regress-compatible per-cell surface (`gvbench
//! cluster --summary-out`) the regression engine gates like sweep cells.
//!
//! The fleet CSV is long format: one row per (system × policy × nodes ×
//! scenario × node), carrying each node's final utilization. It carries
//! no host timings, so identical grids render byte-identical CSV at any
//! `--jobs` count (`rust/tests/cluster_determinism.rs`). The JSON adds
//! the executor timing object as metadata.

use crate::cluster::{ClusterSurface, FleetRun};

use super::json::{array, render_execution, Obj};
use super::Format;

/// Column header of the long-format per-node fleet CSV.
pub const CSV_HEADER: &str = "system,policy,nodes,scenario,node,alive,mem_util,sm_util,tenants";

/// Column header of the regress-compatible summary CSV (one row per
/// system × policy × nodes × scenario × summary statistic; the `cluster`
/// baseline schema of [`crate::regress`]).
pub const SUMMARY_CSV_HEADER: &str = "system,policy,nodes,scenario,id,value";

/// Render the fleet surface in the requested format.
pub fn render(surface: &ClusterSurface, format: Format) -> String {
    match format {
        Format::Json => render_json(surface),
        Format::Csv => render_csv(surface),
        Format::Txt => render_txt(surface),
    }
}

/// Long-format per-node fleet CSV: every value finite and pure in the
/// cell coordinates.
pub fn render_csv(surface: &ClusterSurface) -> String {
    let mut out = String::from(CSV_HEADER);
    out.push('\n');
    for run in &surface.runs {
        let prefix = format!("{},{},{},{}", run.system, run.policy, run.nodes, run.scenario);
        for (i, n) in run.node_stats.iter().enumerate() {
            out.push_str(&format!(
                "{prefix},{i},{},{:.6},{:.6},{}\n",
                n.alive,
                n.mem_util(),
                n.sm_util(),
                n.tenants
            ));
        }
    }
    out
}

/// The regress-compatible summary CSV: every value finite, keyed by the
/// full `(system, policy, nodes, scenario, id)` coordinate. The first
/// line is a `# arrivals=N` provenance comment recording the arrival
/// count the surface was replayed with; [`crate::regress`] parses it
/// back and warns when a gate re-runs the baseline at a different count.
pub fn render_summary_csv(surface: &ClusterSurface) -> String {
    let mut out = format!("# arrivals={}\n", surface.arrivals);
    out.push_str(SUMMARY_CSV_HEADER);
    out.push('\n');
    for run in &surface.runs {
        for (id, value) in &run.summary {
            out.push_str(&format!(
                "{},{},{},{},{},{:.6}\n",
                run.system, run.policy, run.nodes, run.scenario, id, value
            ));
        }
    }
    out
}

fn run_obj(run: &FleetRun) -> Obj {
    let summary: Vec<String> = run
        .summary
        .iter()
        .map(|(id, v)| Obj::new().str("id", id).num("value", *v).build())
        .collect();
    let nodes: Vec<String> = run
        .node_stats
        .iter()
        .enumerate()
        .map(|(i, n)| {
            Obj::new()
                .field("node", i.to_string())
                .bool("alive", n.alive)
                .num("mem_util", n.mem_util())
                .num("sm_util", n.sm_util())
                .field("tenants", n.tenants.to_string())
                .build()
        })
        .collect();
    Obj::new()
        .str("system", &run.system)
        .str("policy", run.policy)
        .field("nodes", run.nodes.to_string())
        .str("scenario", run.scenario)
        .field("arrivals", run.arrivals.to_string())
        .field("placed", run.placed.to_string())
        .field("migrations", run.migrations.to_string())
        .field("evictions", run.evictions.to_string())
        .field("summary", array(summary))
        .field("node_stats", array(nodes))
}

/// The full surface plus executor timings, in the Listing-7 JSON style.
pub fn render_json(surface: &ClusterSurface) -> String {
    let runs: Vec<String> = surface.runs.iter().map(|r| run_obj(r).build()).collect();
    Obj::new()
        .str("benchmark_version", crate::VERSION)
        .field("seed", surface.seed.to_string())
        .field("arrivals", surface.arrivals.to_string())
        .field("runs", array(runs))
        .field("execution", render_execution(&surface.stats))
        .build()
}

/// Human-readable summary: one line per (system, policy, nodes,
/// scenario) cell with the `CL-*` statistics.
pub fn render_txt(surface: &ClusterSurface) -> String {
    let mut out = String::new();
    out.push_str("GPU-Virt-Bench — cluster placement surface\n");
    out.push_str(&format!(
        "  seed {}, {} arrivals per replay, {} fleet cell(s)\n\n",
        surface.seed,
        surface.arrivals,
        surface.runs.len()
    ));
    out.push_str(&format!(
        "{:<12} {:<14} {:>5} {:<10} {:>9} {:>8} {:>8} {:>9} {:>8}\n",
        "System", "Policy", "Nodes", "Scenario", "Success", "Frag", "Imbal", "Migrate", "Evict"
    ));
    out.push_str(&format!("{}\n", "-".repeat(92)));
    for run in &surface.runs {
        let get = |id: &str| run.summary_value(id).unwrap_or(f64::NAN);
        out.push_str(&format!(
            "{:<12} {:<14} {:>5} {:<10} {:>8.1}% {:>7.1}% {:>7.1}% {:>9.0} {:>8.0}\n",
            run.system,
            run.policy,
            run.nodes,
            run.scenario,
            get("CL-SUCCESS"),
            get("CL-FRAG"),
            get("CL-IMBAL"),
            get("CL-MIGRATE"),
            get("CL-EVICT"),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NodeState;
    use crate::coordinator::executor::ExecutionStats;

    fn run(system: &str, policy: &'static str) -> FleetRun {
        let gib = 1u64 << 30;
        let mut dead = NodeState::new(160 * gib, 4.0);
        dead.alive = false;
        let mut busy = NodeState::new(160 * gib, 4.0);
        busy.mem_used = 80 * gib;
        busy.sm_used = 2.0;
        busy.tenants = 10;
        FleetRun {
            system: system.to_string(),
            policy,
            nodes: 2,
            scenario: "churn",
            arrivals: 100,
            placed: 88,
            migrations: 3,
            evictions: 1,
            node_stats: vec![busy, dead],
            summary: vec![
                ("CL-SUCCESS", 88.0),
                ("CL-FRAG", 12.5),
                ("CL-IMBAL", 40.0),
                ("CL-MIGRATE", 3.0),
                ("CL-EVICT", 1.0),
            ],
        }
    }

    fn surface() -> ClusterSurface {
        ClusterSurface {
            seed: 42,
            arrivals: 100,
            runs: vec![run("native", "first-fit"), run("hami", "frag-gradient")],
            stats: ExecutionStats::default(),
        }
    }

    #[test]
    fn csv_long_format_rows() {
        let csv = render_csv(&surface());
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], CSV_HEADER);
        // 2 runs × 2 nodes.
        assert_eq!(lines.len(), 5);
        assert_eq!(lines[1], "native,first-fit,2,churn,0,true,0.500000,0.500000,10");
        assert_eq!(lines[2], "native,first-fit,2,churn,1,false,0.000000,0.000000,0");
        assert!(lines[3].starts_with("hami,frag-gradient,2,churn,0,"));
    }

    #[test]
    fn summary_csv_is_regress_parseable() {
        let csv = render_summary_csv(&surface());
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "# arrivals=100");
        assert_eq!(lines[1], SUMMARY_CSV_HEADER);
        assert_eq!(lines.len(), 12); // comment + header + 2 runs × 5 stats
        assert_eq!(lines[2], "native,first-fit,2,churn,CL-SUCCESS,88.000000");
        let b = crate::regress::parse_baseline_csv(&csv, "native").unwrap();
        assert_eq!(b.schema, crate::regress::BaselineSchema::Cluster);
        assert_eq!(b.recorded_arrivals, Some(100));
        assert_eq!(b.rows.len(), 10);
        let c = b.rows[0].cluster_cell.as_ref().unwrap();
        assert_eq!(c.policy, "first-fit");
        assert_eq!((c.nodes, c.scenario), (2, "churn"));
        assert_eq!(b.rows[0].cell_label(), "first-fit@2n/churn");
    }

    #[test]
    fn json_carries_runs_nodes_and_summary() {
        let j = render_json(&surface());
        assert!(j.contains("\"runs\""), "{j}");
        assert!(j.contains("\"policy\": \"frag-gradient\""), "{j}");
        assert!(j.contains("\"id\": \"CL-SUCCESS\""), "{j}");
        assert!(j.contains("\"alive\": false"), "{j}");
        assert!(j.contains("\"mem_util\": 0.5"), "{j}");
        assert!(j.contains("\"execution\""), "{j}");
    }

    #[test]
    fn txt_summarises_cells() {
        let t = render_txt(&surface());
        assert!(t.contains("cluster placement surface"), "{t}");
        assert!(t.contains("first-fit"), "{t}");
        assert!(t.contains("88.0%"), "{t}");
    }
}
