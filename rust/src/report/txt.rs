//! Human-readable TXT summary with per-category scores and the letter
//! grade (the format the paper's §5.4 calls "human-readable summary with
//! grades").

use super::{unit_of, Report};
use crate::metrics::{taxonomy, Category};

/// Render the text report.
pub fn render(rep: &Report) -> String {
    let mut out = String::new();
    out.push_str("==============================================================\n");
    out.push_str(&format!(
        " GPU-Virt-Bench v{} — system: {}\n",
        crate::VERSION,
        rep.system
    ));
    out.push_str("==============================================================\n\n");
    for c in Category::ALL {
        let metrics: Vec<_> =
            rep.results.iter().filter(|r| taxonomy::by_id(r.id).map(|d| d.category) == Some(c)).collect();
        if metrics.is_empty() {
            continue;
        }
        let cat_score = rep.card.per_category.get(&c).copied().unwrap_or(f64::NAN);
        out.push_str(&format!(
            "--- {} (weight {:.2}, score {:.1}%) ---\n",
            c.name(),
            c.weight(),
            cat_score * 100.0
        ));
        for r in metrics {
            let d = taxonomy::by_id(r.id).unwrap();
            let dev = rep.deviation(r);
            let value_str = match r.pass {
                Some(true) => "Pass".to_string(),
                Some(false) => "FAIL".to_string(),
                None => format!("{:.3} {}", r.value, unit_of(r.id)),
            };
            out.push_str(&format!(
                "  {:<10} {:<32} {:>16}   Δmig {:+6.1}%\n",
                r.id, d.name, value_str, dev
            ));
        }
        out.push('\n');
    }
    out.push_str("--------------------------------------------------------------\n");
    out.push_str(&format!(
        " OVERALL: {:.1}%   MIG parity: {:.1}%   Grade: {} ({})\n",
        rep.card.overall * 100.0,
        rep.card.mig_parity_percent(),
        rep.card.grade().letter(),
        rep.card.grade().interpretation()
    ));
    out.push_str("--------------------------------------------------------------\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::MetricResult;
    use crate::report::Format;
    use crate::scoring::ScoreCard;

    #[test]
    fn renders_grade_line() {
        let results = vec![MetricResult::from_samples("OH-001", "fcsp", &[8.7])];
        let baseline = vec![MetricResult::from_samples("OH-001", "mig", &[4.3])];
        let card = ScoreCard::build("fcsp", &results, &baseline);
        let rep = Report::new("fcsp", &results, &baseline, &card);
        let t = rep.render(Format::Txt);
        assert!(t.contains("OVERALL"));
        assert!(t.contains("Grade:"));
        assert!(t.contains("OH-001"));
    }
}
