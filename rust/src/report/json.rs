//! Minimal JSON encoder + the Listing 7 output schema.

use super::{unit_of, Report};
use crate::metrics::taxonomy;

/// Escape and quote a JSON string.
pub fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Format a finite JSON number (NaN/Inf become null).
pub fn num(x: f64) -> String {
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 1e15 {
            format!("{:.1}", x)
        } else {
            format!("{}", (x * 1e6).round() / 1e6)
        }
    } else {
        "null".to_string()
    }
}

/// A tiny JSON builder for objects/arrays.
#[derive(Default)]
pub struct Obj {
    fields: Vec<(String, String)>,
}

impl Obj {
    pub fn new() -> Obj {
        Obj::default()
    }

    pub fn field(mut self, key: &str, raw_value: String) -> Obj {
        self.fields.push((key.to_string(), raw_value));
        self
    }

    pub fn str(self, key: &str, value: &str) -> Obj {
        let v = quote(value);
        self.field(key, v)
    }

    pub fn num(self, key: &str, value: f64) -> Obj {
        let v = num(value);
        self.field(key, v)
    }

    pub fn bool(self, key: &str, value: bool) -> Obj {
        self.field(key, value.to_string())
    }

    pub fn build(&self) -> String {
        let inner: Vec<String> =
            self.fields.iter().map(|(k, v)| format!("{}: {}", quote(k), v)).collect();
        format!("{{{}}}", inner.join(", "))
    }
}

/// Encode an array from raw JSON values.
pub fn array(items: Vec<String>) -> String {
    format!("[{}]", items.join(", "))
}

/// Render the full report in the paper's Listing 7 schema.
pub fn render(rep: &Report) -> String {
    let metrics: Vec<String> = rep
        .results
        .iter()
        .map(|r| {
            let d = taxonomy::by_id(r.id);
            let stats = Obj::new()
                .num("mean", r.summary.mean)
                .num("stddev", r.summary.stddev)
                .num("median", r.summary.median)
                .num("p95", r.summary.p95)
                .num("p99", r.summary.p99)
                .num("cv", r.summary.cv)
                .field("count", r.summary.count.to_string())
                .build();
            let baseline = rep.baseline_for(r.id).map(|b| b.value).unwrap_or(f64::NAN);
            let score = rep
                .card
                .per_metric
                .iter()
                .find(|(id, _)| *id == r.id)
                .map(|(_, s)| *s)
                .unwrap_or(f64::NAN);
            let mig = Obj::new()
                .num("expected", baseline)
                .num("deviation_percent", rep.deviation(r))
                .num("score", score)
                .build();
            let mut o = Obj::new()
                .str("id", r.id)
                .str("name", d.map(|d| d.name).unwrap_or(""))
                .str("unit", unit_of(r.id))
                .num("value", r.value)
                .field("statistics", stats)
                .field("mig_comparison", mig);
            if let Some(p) = r.pass {
                o = o.bool("pass", p);
            }
            o.build()
        })
        .collect();
    let categories: Vec<String> = crate::metrics::Category::ALL
        .iter()
        .filter_map(|c| {
            rep.card.per_category.get(c).map(|s| {
                Obj::new()
                    .str("category", c.name())
                    .num("weight", c.weight())
                    .num("score", *s)
                    .build()
            })
        })
        .collect();
    let mut top = Obj::new()
        .str("benchmark_version", crate::VERSION)
        .field("system", Obj::new().str("name", rep.system).build())
        .field("metrics", array(metrics))
        .field("categories", array(categories))
        .num("overall_score", rep.card.overall)
        .num("mig_parity_percent", rep.card.mig_parity_percent())
        .str("grade", rep.card.grade().letter());
    if let Some(stats) = rep.stats {
        top = top.field("execution", render_execution(stats));
    }
    top.build()
}

/// Encode [`crate::coordinator::executor::ExecutionStats`] (wall-clock +
/// per-task timings) as JSON.
pub fn render_execution(stats: &crate::coordinator::executor::ExecutionStats) -> String {
    execution_obj(stats).build()
}

/// The execution-stats object as an open [`Obj`], so surface-specific
/// renderers (e.g. dynamics' events/sec throughput) can append their own
/// reporting-only fields before building.
pub fn execution_obj(stats: &crate::coordinator::executor::ExecutionStats) -> Obj {
    let tasks: Vec<String> = stats
        .tasks
        .iter()
        .map(|t| {
            Obj::new()
                .str("metric_id", t.metric_id)
                .str("system", &t.system)
                .field("worker", t.worker.to_string())
                .num("wall_ms", t.wall_ns as f64 / 1e6)
                .build()
        })
        .collect();
    Obj::new()
        .field("jobs", stats.jobs.to_string())
        .num("wall_ms", stats.wall_ns as f64 / 1e6)
        .num("busy_ms", stats.total_task_ns() as f64 / 1e6)
        .num("speedup_estimate", stats.speedup_estimate())
        .field("tasks", array(tasks))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quoting_escapes() {
        assert_eq!(quote("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(quote("tab\there"), "\"tab\\there\"");
    }

    #[test]
    fn numbers() {
        assert_eq!(num(4.2), "4.2");
        assert_eq!(num(100.0), "100.0");
        assert_eq!(num(f64::NAN), "null");
        assert_eq!(num(f64::INFINITY), "null");
    }

    #[test]
    fn object_building() {
        let o = Obj::new().str("a", "x").num("b", 1.5).bool("c", true).build();
        assert_eq!(o, "{\"a\": \"x\", \"b\": 1.5, \"c\": true}");
    }

    #[test]
    fn array_building() {
        assert_eq!(array(vec!["1".into(), "2".into()]), "[1, 2]");
        assert_eq!(array(vec![]), "[]");
    }
}
