//! Report generation (paper §5.4): JSON (Listing 7 schema), CSV and a
//! human-readable TXT summary with grades.
//!
//! The offline build has no serde; [`json`] is a small, correct JSON
//! encoder (string escaping, finite-number handling) sufficient for the
//! output schema.

pub mod cluster;
pub mod csv;
pub mod dynamics;
pub mod json;
pub mod sweep;
pub mod txt;

use crate::coordinator::executor::ExecutionStats;
use crate::metrics::{taxonomy, MetricResult};
use crate::scoring::{mig_deviation_percent, ScoreCard};

/// A full benchmark report for one system: its results, the baseline run
/// they are scored against, the resulting scorecard, and (optionally) the
/// executor's wall-clock statistics.
pub struct Report<'a> {
    pub system: &'a str,
    pub results: &'a [MetricResult],
    pub baseline: &'a [MetricResult],
    pub card: &'a ScoreCard,
    /// Execution timings from the parallel executor (None = not recorded;
    /// omitted from rendered output).
    pub stats: Option<&'a ExecutionStats>,
}

impl<'a> Report<'a> {
    pub fn new(
        system: &'a str,
        results: &'a [MetricResult],
        baseline: &'a [MetricResult],
        card: &'a ScoreCard,
    ) -> Report<'a> {
        Report { system, results, baseline, card, stats: None }
    }

    /// Attach executor timings; JSON output gains an `execution` object.
    pub fn with_stats(mut self, stats: &'a ExecutionStats) -> Report<'a> {
        self.stats = Some(stats);
        self
    }

    /// Baseline result for a metric id.
    pub fn baseline_for(&self, id: &str) -> Option<&MetricResult> {
        self.baseline.iter().find(|r| r.id == id)
    }

    /// Signed MIG deviation for one metric (paper eqs. 29–30).
    pub fn deviation(&self, r: &MetricResult) -> f64 {
        self.baseline_for(r.id).map(|b| mig_deviation_percent(r, b)).unwrap_or(0.0)
    }

    /// Render to the requested format.
    pub fn render(&self, format: Format) -> String {
        match format {
            Format::Json => json::render(self),
            Format::Csv => csv::render(self),
            Format::Txt => txt::render(self),
        }
    }
}

/// Output formats (paper §5.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    Json,
    Csv,
    Txt,
}

impl Format {
    pub fn from_key(s: &str) -> Option<Format> {
        match s {
            "json" => Some(Format::Json),
            "csv" => Some(Format::Csv),
            "txt" | "text" => Some(Format::Txt),
            _ => None,
        }
    }
}

/// Unit string for a metric id (Table 8).
pub fn unit_of(id: &str) -> &'static str {
    taxonomy::by_id(id).map(|d| d.unit).unwrap_or("")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::MetricResult;
    use crate::scoring::ScoreCard;

    fn sample_report() -> (Vec<MetricResult>, Vec<MetricResult>) {
        let results = vec![
            MetricResult::from_samples("OH-001", "hami", &[15.0, 15.3, 15.6]),
            MetricResult::from_pass("IS-005", "hami", true),
        ];
        let baseline = vec![
            MetricResult::from_samples("OH-001", "mig", &[4.2, 4.2, 4.2]),
            MetricResult::from_pass("IS-005", "mig", true),
        ];
        (results, baseline)
    }

    #[test]
    fn all_formats_render() {
        let (results, baseline) = sample_report();
        let card = ScoreCard::build("hami", &results, &baseline);
        let rep = Report::new("hami", &results, &baseline, &card);
        let j = rep.render(Format::Json);
        assert!(j.contains("\"OH-001\""));
        assert!(j.contains("benchmark_version"));
        let c = rep.render(Format::Csv);
        assert!(c.starts_with("id,"));
        let t = rep.render(Format::Txt);
        assert!(t.contains("GPU-Virt-Bench"));
    }

    #[test]
    fn deviation_negative_for_slower() {
        let (results, baseline) = sample_report();
        let card = ScoreCard::build("hami", &results, &baseline);
        let rep = Report::new("hami", &results, &baseline, &card);
        assert!(rep.deviation(&results[0]) < 0.0);
    }

    #[test]
    fn format_keys() {
        assert_eq!(Format::from_key("json"), Some(Format::Json));
        assert_eq!(Format::from_key("text"), Some(Format::Txt));
        assert_eq!(Format::from_key("xml"), None);
    }
}
