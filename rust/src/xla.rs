//! In-tree stub for the `xla` PJRT bindings (offline build, no registry).
//!
//! The PJRT runtime ([`crate::runtime`]) is written against the API shape of
//! the real `xla` crate (PJRT CPU client + HLO-text compilation). This
//! environment cannot link the native `xla_extension` library, so the stub
//! presents the same types and signatures but fails at client creation with
//! a clear message. Everything downstream (`runtime::Engine`, the Table 6
//! bench, `examples/multi_tenant_llm.rs`) already degrades gracefully when
//! the engine cannot load, so the pure-Rust suite is unaffected.

use std::fmt;

/// Error type for all stubbed PJRT operations.
#[derive(Clone, Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable() -> XlaError {
    XlaError(
        "PJRT runtime unavailable: this build uses the offline `xla` stub \
         (no xla_extension library in the environment)"
            .to_string(),
    )
}

type Result<T> = std::result::Result<T, XlaError>;

/// PJRT client handle (stub: creation always fails).
pub struct PjRtClient;

impl PjRtClient {
    /// Create the CPU client. Always fails in the stub — callers treat the
    /// runtime as absent, exactly like a missing `artifacts/` directory.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Host-side literal (stub).
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_reports_stub() {
        let e = PjRtClient::cpu().err().expect("stub must fail");
        assert!(e.to_string().contains("stub"));
    }

    #[test]
    fn literal_constructors_exist() {
        let l = Literal::vec1(&[1.0, 2.0]);
        assert!(l.reshape(&[2]).is_err());
    }
}
