//! Run configuration files: a small parser for a `key = value` format
//! (INI-like, with `#` comments) that configures iterations, tenants,
//! quotas and custom category weights — the paper's "users can customize
//! weights via configuration files" (§6.3).

use std::collections::HashMap;

use crate::metrics::{Category, RunConfig};

/// Parsed configuration file.
#[derive(Clone, Debug, Default)]
pub struct FileConfig {
    values: HashMap<String, String>,
}

/// Parse error with line number.
#[derive(Debug, PartialEq)]
pub enum ConfigError {
    Syntax(usize, String),
    Value(String, String),
    Weights(f64),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Syntax(line, got) => {
                write!(f, "line {line}: expected `key = value`, got `{got}`")
            }
            ConfigError::Value(key, val) => write!(f, "invalid value for `{key}`: `{val}`"),
            ConfigError::Weights(sum) => write!(f, "weights must sum to 1.0 (got {sum})"),
        }
    }
}

impl std::error::Error for ConfigError {}

impl FileConfig {
    /// Parse `key = value` lines; `#`/`;` start comments; blanks ignored.
    pub fn parse(text: &str) -> Result<FileConfig, ConfigError> {
        let mut values = HashMap::new();
        for (i, raw) in text.lines().enumerate() {
            let line = match raw.find(['#', ';']) {
                Some(p) => &raw[..p],
                None => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| ConfigError::Syntax(i + 1, raw.to_string()))?;
            values.insert(k.trim().to_lowercase(), v.trim().to_string());
        }
        Ok(FileConfig { values })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    fn get_num<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, ConfigError> {
        match self.values.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| ConfigError::Value(key.to_string(), v.clone())),
        }
    }

    /// Apply file settings over a base [`RunConfig`].
    pub fn apply(&self, mut cfg: RunConfig) -> Result<RunConfig, ConfigError> {
        if let Some(s) = self.get("system") {
            cfg.system = s.to_string();
        }
        if let Some(v) = self.get_num::<usize>("iterations")? {
            cfg.iterations = v;
        }
        if let Some(v) = self.get_num::<usize>("warmup")? {
            cfg.warmup = v;
        }
        if let Some(v) = self.get_num::<u32>("tenants")? {
            cfg.tenants = v;
        }
        if let Some(v) = self.get_num::<u64>("seed")? {
            cfg.seed = v;
        }
        if let Some(v) = self.get_num::<u64>("mem_limit_mb")? {
            cfg.mem_limit = v << 20;
        }
        if let Some(v) = self.get_num::<f64>("sm_limit")? {
            cfg.sm_limit = v;
        }
        if let Some(v) = self.get_num::<usize>("jobs")? {
            cfg.jobs = v;
        }
        Ok(cfg)
    }

    /// Custom category weights: keys `weight.<category-key>`. Returns the
    /// default weights overlaid with any file-provided ones; validates the
    /// sum is 1.0 (±1e-6).
    pub fn weights(&self) -> Result<HashMap<Category, f64>, ConfigError> {
        let mut weights: HashMap<Category, f64> =
            Category::ALL.iter().map(|c| (*c, c.weight())).collect();
        for c in Category::ALL {
            let key = format!("weight.{}", c.key());
            if let Some(v) = self.get_num::<f64>(&key)? {
                weights.insert(c, v);
            }
        }
        let sum: f64 = weights.values().sum();
        if (sum - 1.0).abs() > 1e-6 {
            return Err(ConfigError::Weights(sum));
        }
        Ok(weights)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_and_applies() {
        let fc = FileConfig::parse(
            "# comment\nsystem = fcsp\niterations = 50\ntenants=8\nmem_limit_mb = 4096 ; inline\njobs = 6\n",
        )
        .unwrap();
        let cfg = fc.apply(RunConfig::default()).unwrap();
        assert_eq!(cfg.system, "fcsp");
        assert_eq!(cfg.iterations, 50);
        assert_eq!(cfg.tenants, 8);
        assert_eq!(cfg.mem_limit, 4096 << 20);
        assert_eq!(cfg.jobs, 6);
    }

    #[test]
    fn syntax_error_reports_line() {
        let e = FileConfig::parse("good = 1\nbad line\n").unwrap_err();
        assert_eq!(e, ConfigError::Syntax(2, "bad line".to_string()));
    }

    #[test]
    fn value_error() {
        let fc = FileConfig::parse("iterations = lots\n").unwrap();
        assert!(matches!(fc.apply(RunConfig::default()), Err(ConfigError::Value(_, _))));
    }

    #[test]
    fn default_weights_pass_validation() {
        let fc = FileConfig::parse("").unwrap();
        let w = fc.weights().unwrap();
        assert_eq!(w.len(), 10);
    }

    #[test]
    fn custom_weights_must_sum_to_one() {
        let fc = FileConfig::parse("weight.overhead = 0.5\n").unwrap();
        assert!(matches!(fc.weights(), Err(ConfigError::Weights(_))));
        // Rebalanced: shift 0.05 overhead→isolation keeps the sum at 1.
        let fc = FileConfig::parse("weight.overhead = 0.10\nweight.isolation = 0.25\n").unwrap();
        let w = fc.weights().unwrap();
        assert!((w[&Category::Overhead] - 0.10).abs() < 1e-12);
        assert!((w[&Category::Isolation] - 0.25).abs() < 1e-12);
    }
}
