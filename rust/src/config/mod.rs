//! Run configuration files: a small parser for a `key = value` format
//! (INI-like, with `#` comments and `[section]` headers) that configures
//! iterations, tenants, quotas, custom category weights — the paper's
//! "users can customize weights via configuration files" (§6.3) — the
//! `[sweep]` scenario grid consumed by `gvbench sweep`, the `[dynsim]`
//! dynamics grid consumed by `gvbench dynamics`, and the `[cluster]`
//! fleet grid consumed by `gvbench cluster`.
//!
//! A `[section]` header prefixes subsequent keys with `section.`, so
//!
//! ```text
//! jobs = 8
//! [sweep]
//! tenants = 1,2,4,8
//! quota = 25,50,100
//! ```
//!
//! stores `jobs` and `sweep.tenants` / `sweep.quota`.

use std::collections::HashMap;

use crate::metrics::{Category, RunConfig};

/// Parsed configuration file.
#[derive(Clone, Debug, Default)]
pub struct FileConfig {
    values: HashMap<String, String>,
}

/// Values from a config file's `[sweep]` section (`None` = key absent; the
/// CLI overlays its own flags on top and falls back to the default grid).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SweepOverlay {
    pub tenants: Option<Vec<u32>>,
    pub quotas: Option<Vec<u32>>,
    /// Node GPU counts (`gpus = 2,4,8`), the `--gpus` axis.
    pub gpus: Option<Vec<u32>>,
    /// Node link kinds (`link = nvlink,pcie`), the `--link` axis
    /// (validated by the CLI layer against the known kinds).
    pub links: Option<Vec<String>>,
    pub systems: Option<Vec<String>>,
    pub categories: Option<Vec<String>>,
}

/// Values from a config file's `[dynsim]` section (`None` = key absent;
/// `gvbench dynamics` overlays its own flags on top and falls back to
/// the default grid). Scenario names and ranges are validated by the
/// CLI layer against the preset registry.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DynOverlay {
    /// Scenario preset keys (`scenarios = churn, failover`).
    pub scenarios: Option<Vec<String>>,
    /// Timeline horizon (`duration_ms = 2000`).
    pub duration_ms: Option<u64>,
    /// Reporting window (`window_ms = 200`).
    pub window_ms: Option<u64>,
    pub systems: Option<Vec<String>>,
}

/// Values from a config file's `[cluster]` section (`None` = key absent;
/// `gvbench cluster` overlays its own flags on top and falls back to
/// the default grid). Policy/scenario names and ranges are validated by
/// the CLI layer against the policy/preset registries.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ClusterOverlay {
    /// Placement policy keys (`policies = first-fit, frag-gradient`).
    pub policies: Option<Vec<String>>,
    /// Fleet sizes in nodes (`nodes = 8, 16`).
    pub nodes: Option<Vec<u32>>,
    /// Scenario preset keys (`scenarios = churn, failover`).
    pub scenarios: Option<Vec<String>>,
    /// Tenant arrivals per replay (`arrivals = 5000`).
    pub arrivals: Option<u32>,
    pub systems: Option<Vec<String>>,
}

/// Parse error with line number.
#[derive(Debug, PartialEq)]
pub enum ConfigError {
    Syntax(usize, String),
    Value(String, String),
    Weights(f64),
    UnknownKey(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Syntax(line, got) => {
                write!(f, "line {line}: expected `key = value`, got `{got}`")
            }
            ConfigError::Value(key, val) => write!(f, "invalid value for `{key}`: `{val}`"),
            ConfigError::Weights(sum) => write!(f, "weights must sum to 1.0 (got {sum})"),
            ConfigError::UnknownKey(key) => write!(
                f,
                "unrecognized key `{key}` (known [sweep] keys: tenants, quota, gpus, link, \
                 systems, categories; known [dynsim] keys: scenarios, duration_ms, window_ms, \
                 systems; known [cluster] keys: policies, nodes, scenarios, arrivals, systems)"
            ),
        }
    }
}

impl std::error::Error for ConfigError {}

impl FileConfig {
    /// Parse `key = value` lines; `#`/`;` start comments; blanks ignored;
    /// `[section]` headers prefix subsequent keys with `section.`.
    pub fn parse(text: &str) -> Result<FileConfig, ConfigError> {
        let mut values = HashMap::new();
        let mut section = String::new();
        for (i, raw) in text.lines().enumerate() {
            let line = match raw.find(['#', ';']) {
                Some(p) => &raw[..p],
                None => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') && line.ends_with(']') {
                section = line[1..line.len() - 1].trim().to_lowercase();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| ConfigError::Syntax(i + 1, raw.to_string()))?;
            let key = if section.is_empty() {
                k.trim().to_lowercase()
            } else {
                format!("{section}.{}", k.trim().to_lowercase())
            };
            values.insert(key, v.trim().to_string());
        }
        Ok(FileConfig { values })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    fn get_num<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, ConfigError> {
        match self.values.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| ConfigError::Value(key.to_string(), v.clone())),
        }
    }

    /// Apply file settings over a base [`RunConfig`].
    pub fn apply(&self, mut cfg: RunConfig) -> Result<RunConfig, ConfigError> {
        if let Some(s) = self.get("system") {
            cfg.system = s.to_string();
        }
        if let Some(v) = self.get_num::<usize>("iterations")? {
            cfg.iterations = v;
        }
        if let Some(v) = self.get_num::<usize>("warmup")? {
            cfg.warmup = v;
        }
        if let Some(v) = self.get_num::<u32>("tenants")? {
            cfg.tenants = v;
        }
        if let Some(v) = self.get_num::<u64>("seed")? {
            cfg.seed = v;
        }
        if let Some(v) = self.get_num::<u64>("mem_limit_mb")? {
            cfg.mem_limit = v << 20;
        }
        if let Some(v) = self.get_num::<f64>("sm_limit")? {
            cfg.sm_limit = v;
        }
        if let Some(v) = self.get_num::<usize>("jobs")? {
            cfg.jobs = v;
        }
        Ok(cfg)
    }

    /// Parse a comma-separated list value (e.g. `1, 2, 4`).
    fn get_list<T: std::str::FromStr>(&self, key: &str) -> Result<Option<Vec<T>>, ConfigError> {
        match self.values.get(key) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse::<T>()
                        .map_err(|_| ConfigError::Value(key.to_string(), v.clone()))
                })
                .collect::<Result<Vec<T>, ConfigError>>()
                .map(Some),
        }
    }

    /// A comma-separated string list (no parsing beyond trimming).
    fn get_str_list(&self, key: &str) -> Option<Vec<String>> {
        self.values
            .get(key)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
    }

    /// The `[sweep]` section's scenario grid, if any keys are present.
    /// Recognized keys: `sweep.tenants`, `sweep.quota`, `sweep.gpus`
    /// (u32 lists), `sweep.link`, `sweep.systems`, `sweep.categories`
    /// (string lists; validated by the CLI layer against the link-kind /
    /// backend / category registries). The `sweep.*` namespace is closed:
    /// any other key in the section — a `quotas` typo, a global key like
    /// `seed` placed below the header — is an error rather than a
    /// silently ignored setting.
    pub fn sweep(&self) -> Result<SweepOverlay, ConfigError> {
        const KNOWN: [&str; 6] = [
            "sweep.tenants",
            "sweep.quota",
            "sweep.gpus",
            "sweep.link",
            "sweep.systems",
            "sweep.categories",
        ];
        for key in self.values.keys() {
            if key.starts_with("sweep.") && !KNOWN.contains(&key.as_str()) {
                return Err(ConfigError::UnknownKey(key.clone()));
            }
        }
        Ok(SweepOverlay {
            tenants: self.get_list::<u32>("sweep.tenants")?,
            quotas: self.get_list::<u32>("sweep.quota")?,
            gpus: self.get_list::<u32>("sweep.gpus")?,
            links: self.get_str_list("sweep.link"),
            systems: self.get_str_list("sweep.systems"),
            categories: self.get_str_list("sweep.categories"),
        })
    }

    /// The `[dynsim]` section's dynamics grid, if any keys are present.
    /// Recognized keys: `dynsim.scenarios`, `dynsim.systems` (string
    /// lists), `dynsim.duration_ms`, `dynsim.window_ms` (u64). Like the
    /// `sweep.*` namespace, `dynsim.*` is closed: unknown keys are an
    /// error rather than silently ignored settings.
    pub fn dynsim(&self) -> Result<DynOverlay, ConfigError> {
        const KNOWN: [&str; 4] = [
            "dynsim.scenarios",
            "dynsim.duration_ms",
            "dynsim.window_ms",
            "dynsim.systems",
        ];
        for key in self.values.keys() {
            if key.starts_with("dynsim.") && !KNOWN.contains(&key.as_str()) {
                return Err(ConfigError::UnknownKey(key.clone()));
            }
        }
        Ok(DynOverlay {
            scenarios: self.get_str_list("dynsim.scenarios"),
            duration_ms: self.get_num::<u64>("dynsim.duration_ms")?,
            window_ms: self.get_num::<u64>("dynsim.window_ms")?,
            systems: self.get_str_list("dynsim.systems"),
        })
    }

    /// The `[cluster]` section's fleet grid, if any keys are present.
    /// Recognized keys: `cluster.policies`, `cluster.scenarios`,
    /// `cluster.systems` (string lists), `cluster.nodes` (u32 list),
    /// `cluster.arrivals` (u32). Like the other section namespaces,
    /// `cluster.*` is closed: unknown keys are an error rather than
    /// silently ignored settings.
    pub fn cluster(&self) -> Result<ClusterOverlay, ConfigError> {
        const KNOWN: [&str; 5] = [
            "cluster.policies",
            "cluster.nodes",
            "cluster.scenarios",
            "cluster.arrivals",
            "cluster.systems",
        ];
        for key in self.values.keys() {
            if key.starts_with("cluster.") && !KNOWN.contains(&key.as_str()) {
                return Err(ConfigError::UnknownKey(key.clone()));
            }
        }
        Ok(ClusterOverlay {
            policies: self.get_str_list("cluster.policies"),
            nodes: self.get_list::<u32>("cluster.nodes")?,
            scenarios: self.get_str_list("cluster.scenarios"),
            arrivals: self.get_num::<u32>("cluster.arrivals")?,
            systems: self.get_str_list("cluster.systems"),
        })
    }

    /// Custom category weights: keys `weight.<category-key>`. Returns the
    /// default weights overlaid with any file-provided ones; validates the
    /// sum is 1.0 (±1e-6).
    pub fn weights(&self) -> Result<HashMap<Category, f64>, ConfigError> {
        let mut weights: HashMap<Category, f64> =
            Category::ALL.iter().map(|c| (*c, c.weight())).collect();
        for c in Category::ALL {
            let key = format!("weight.{}", c.key());
            if let Some(v) = self.get_num::<f64>(&key)? {
                weights.insert(c, v);
            }
        }
        let sum: f64 = weights.values().sum();
        if (sum - 1.0).abs() > 1e-6 {
            return Err(ConfigError::Weights(sum));
        }
        Ok(weights)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_and_applies() {
        let fc = FileConfig::parse(
            "# comment\nsystem = fcsp\niterations = 50\ntenants=8\nmem_limit_mb = 4096 ; inline\njobs = 6\n",
        )
        .unwrap();
        let cfg = fc.apply(RunConfig::default()).unwrap();
        assert_eq!(cfg.system, "fcsp");
        assert_eq!(cfg.iterations, 50);
        assert_eq!(cfg.tenants, 8);
        assert_eq!(cfg.mem_limit, 4096 << 20);
        assert_eq!(cfg.jobs, 6);
    }

    #[test]
    fn syntax_error_reports_line() {
        let e = FileConfig::parse("good = 1\nbad line\n").unwrap_err();
        assert_eq!(e, ConfigError::Syntax(2, "bad line".to_string()));
    }

    #[test]
    fn value_error() {
        let fc = FileConfig::parse("iterations = lots\n").unwrap();
        assert!(matches!(fc.apply(RunConfig::default()), Err(ConfigError::Value(_, _))));
    }

    #[test]
    fn sections_prefix_keys() {
        let fc = FileConfig::parse(
            "jobs = 8\n[sweep]\ntenants = 1, 2,4\nquota = 25,100\ngpus = 2, 4\nlink = nvlink, pcie\nsystems = hami, fcsp\n",
        )
        .unwrap();
        assert_eq!(fc.get("jobs"), Some("8"));
        assert_eq!(fc.get("sweep.tenants"), Some("1, 2,4"));
        let s = fc.sweep().unwrap();
        assert_eq!(s.tenants, Some(vec![1, 2, 4]));
        assert_eq!(s.quotas, Some(vec![25, 100]));
        assert_eq!(s.gpus, Some(vec![2, 4]));
        assert_eq!(s.links, Some(vec!["nvlink".to_string(), "pcie".to_string()]));
        assert_eq!(s.systems, Some(vec!["hami".to_string(), "fcsp".to_string()]));
        assert_eq!(s.categories, None);
    }

    #[test]
    fn sweep_topology_keys_absent_and_bad_values() {
        let fc = FileConfig::parse("[sweep]\ntenants = 1,2\n").unwrap();
        let s = fc.sweep().unwrap();
        assert!(s.gpus.is_none() && s.links.is_none());
        let bad = FileConfig::parse("[sweep]\ngpus = 2,lots\n").unwrap();
        assert!(matches!(bad.sweep(), Err(ConfigError::Value(_, _))));
    }

    #[test]
    fn sweep_overlay_absent_and_bad_values() {
        let fc = FileConfig::parse("iterations = 5\n").unwrap();
        let s = fc.sweep().unwrap();
        assert!(s.tenants.is_none() && s.quotas.is_none());
        let bad = FileConfig::parse("[sweep]\ntenants = 1,lots\n").unwrap();
        assert!(matches!(bad.sweep(), Err(ConfigError::Value(_, _))));
    }

    #[test]
    fn sweep_namespace_is_closed() {
        // A `quotas` typo or a global key under [sweep] errors instead of
        // being silently ignored.
        let typo = FileConfig::parse("[sweep]\nquotas = 25,50\n").unwrap();
        assert!(matches!(typo.sweep(), Err(ConfigError::UnknownKey(_))));
        let stray = FileConfig::parse("[sweep]\ntenants = 1,2\nseed = 7\n").unwrap();
        assert_eq!(stray.sweep(), Err(ConfigError::UnknownKey("sweep.seed".to_string())));
    }

    #[test]
    fn dynsim_section_parses_and_is_closed() {
        let fc = FileConfig::parse(
            "[dynsim]\nscenarios = churn, failover\nduration_ms = 2000\nwindow_ms = 200\nsystems = hami\n",
        )
        .unwrap();
        let d = fc.dynsim().unwrap();
        assert_eq!(
            d.scenarios,
            Some(vec!["churn".to_string(), "failover".to_string()])
        );
        assert_eq!(d.duration_ms, Some(2000));
        assert_eq!(d.window_ms, Some(200));
        assert_eq!(d.systems, Some(vec!["hami".to_string()]));
        // Absent section: all-None overlay.
        let empty = FileConfig::parse("jobs = 4\n").unwrap();
        assert_eq!(empty.dynsim().unwrap(), DynOverlay::default());
        // Typos and stray keys are errors, not silently ignored settings.
        let typo = FileConfig::parse("[dynsim]\nscenario = churn\n").unwrap();
        assert!(matches!(typo.dynsim(), Err(ConfigError::UnknownKey(_))));
        let bad = FileConfig::parse("[dynsim]\nduration_ms = lots\n").unwrap();
        assert!(matches!(bad.dynsim(), Err(ConfigError::Value(_, _))));
    }

    #[test]
    fn cluster_section_parses_and_is_closed() {
        let fc = FileConfig::parse(
            "[cluster]\npolicies = first-fit, frag-gradient\nnodes = 8, 16\n\
             scenarios = churn\narrivals = 5000\nsystems = hami\n",
        )
        .unwrap();
        let c = fc.cluster().unwrap();
        assert_eq!(
            c.policies,
            Some(vec!["first-fit".to_string(), "frag-gradient".to_string()])
        );
        assert_eq!(c.nodes, Some(vec![8, 16]));
        assert_eq!(c.scenarios, Some(vec!["churn".to_string()]));
        assert_eq!(c.arrivals, Some(5000));
        assert_eq!(c.systems, Some(vec!["hami".to_string()]));
        // Absent section: all-None overlay.
        let empty = FileConfig::parse("jobs = 4\n").unwrap();
        assert_eq!(empty.cluster().unwrap(), ClusterOverlay::default());
        // Typos and stray keys are errors, not silently ignored settings.
        let typo = FileConfig::parse("[cluster]\npolicy = first-fit\n").unwrap();
        assert_eq!(
            typo.cluster(),
            Err(ConfigError::UnknownKey("cluster.policy".to_string()))
        );
        let bad = FileConfig::parse("[cluster]\nnodes = 8,lots\n").unwrap();
        assert!(matches!(bad.cluster(), Err(ConfigError::Value(_, _))));
    }

    #[test]
    fn default_weights_pass_validation() {
        let fc = FileConfig::parse("").unwrap();
        let w = fc.weights().unwrap();
        assert_eq!(w.len(), 10);
    }

    #[test]
    fn custom_weights_must_sum_to_one() {
        let fc = FileConfig::parse("weight.overhead = 0.5\n").unwrap();
        assert!(matches!(fc.weights(), Err(ConfigError::Weights(_))));
        // Rebalanced: shift 0.05 overhead→isolation keeps the sum at 1.
        let fc = FileConfig::parse("weight.overhead = 0.10\nweight.isolation = 0.25\n").unwrap();
        let w = fc.weights().unwrap();
        assert!((w[&Category::Overhead] - 0.10).abs() < 1e-12);
        assert!((w[&Category::Isolation] - 0.25).abs() < 1e-12);
    }
}
