//! Dispatch table: metric id → implementation. The runner, CLI and benches
//! all go through [`run_metric`] / [`run_category`] / [`run_all`].
//!
//! `run_category` and `run_all` execute through the parallel sharded
//! executor ([`crate::coordinator::executor`]): tasks run on `cfg.jobs`
//! workers (0 = available parallelism), each with a per-task derived seed,
//! and results come back in Table-8 order — bit-identical at any job
//! count. [`run_metric`] stays a direct call with `cfg.seed` untouched;
//! callers that need parity with executor-produced numbers (e.g. the
//! regression checker) derive the task seed themselves via
//! [`crate::coordinator::executor::derive_cfg`].

use crate::coordinator::executor;

use super::{
    bandwidth, cache, error_recovery, fragmentation, isolation, llm, nccl, overhead, pcie,
    scheduling, taxonomy, Category, MetricResult, RunConfig,
};

/// A metric implementation.
pub type MetricFn = fn(&RunConfig) -> MetricResult;

/// All (id, fn) pairs in Table 8 order.
pub const REGISTRY: [(&str, MetricFn); 56] = [
    ("OH-001", overhead::oh_001),
    ("OH-002", overhead::oh_002),
    ("OH-003", overhead::oh_003),
    ("OH-004", overhead::oh_004),
    ("OH-005", overhead::oh_005),
    ("OH-006", overhead::oh_006),
    ("OH-007", overhead::oh_007),
    ("OH-008", overhead::oh_008),
    ("OH-009", overhead::oh_009),
    ("OH-010", overhead::oh_010),
    ("IS-001", isolation::is_001),
    ("IS-002", isolation::is_002),
    ("IS-003", isolation::is_003),
    ("IS-004", isolation::is_004),
    ("IS-005", isolation::is_005),
    ("IS-006", isolation::is_006),
    ("IS-007", isolation::is_007),
    ("IS-008", isolation::is_008),
    ("IS-009", isolation::is_009),
    ("IS-010", isolation::is_010),
    ("LLM-001", llm::llm_001),
    ("LLM-002", llm::llm_002),
    ("LLM-003", llm::llm_003),
    ("LLM-004", llm::llm_004),
    ("LLM-005", llm::llm_005),
    ("LLM-006", llm::llm_006),
    ("LLM-007", llm::llm_007),
    ("LLM-008", llm::llm_008),
    ("LLM-009", llm::llm_009),
    ("LLM-010", llm::llm_010),
    ("BW-001", bandwidth::bw_001),
    ("BW-002", bandwidth::bw_002),
    ("BW-003", bandwidth::bw_003),
    ("BW-004", bandwidth::bw_004),
    ("CACHE-001", cache::cache_001),
    ("CACHE-002", cache::cache_002),
    ("CACHE-003", cache::cache_003),
    ("CACHE-004", cache::cache_004),
    ("PCIE-001", pcie::pcie_001),
    ("PCIE-002", pcie::pcie_002),
    ("PCIE-003", pcie::pcie_003),
    ("PCIE-004", pcie::pcie_004),
    ("NCCL-001", nccl::nccl_001),
    ("NCCL-002", nccl::nccl_002),
    ("NCCL-003", nccl::nccl_003),
    ("NCCL-004", nccl::nccl_004),
    ("SCHED-001", scheduling::sched_001),
    ("SCHED-002", scheduling::sched_002),
    ("SCHED-003", scheduling::sched_003),
    ("SCHED-004", scheduling::sched_004),
    ("FRAG-001", fragmentation::frag_001),
    ("FRAG-002", fragmentation::frag_002),
    ("FRAG-003", fragmentation::frag_003),
    ("ERR-001", error_recovery::err_001),
    ("ERR-002", error_recovery::err_002),
    ("ERR-003", error_recovery::err_003),
];

/// Run a single metric by id.
pub fn run_metric(id: &str, cfg: &RunConfig) -> Option<MetricResult> {
    REGISTRY.iter().find(|(mid, _)| *mid == id).map(|(_, f)| f(cfg))
}

/// Execute a list of metric ids for `cfg.system` through the parallel
/// executor, preserving the input order of `ids`.
fn run_ids(ids: &[&'static str], cfg: &RunConfig) -> Vec<MetricResult> {
    let tasks: Vec<executor::Task> = ids
        .iter()
        .map(|id| executor::Task { system: cfg.system.clone(), metric_id: *id })
        .collect();
    executor::execute(cfg, &tasks, cfg.jobs).0
}

/// Run all metrics of a category, in Table 8 order (parallel, sharded).
pub fn run_category(category: Category, cfg: &RunConfig) -> Vec<MetricResult> {
    let ids: Vec<&'static str> =
        taxonomy::by_category(category).iter().map(|d| d.id).collect();
    run_ids(&ids, cfg)
}

/// Run the full 56-metric suite (parallel, sharded).
pub fn run_all(cfg: &RunConfig) -> Vec<MetricResult> {
    run_ids(&all_ids(), cfg)
}

/// All metric ids, in Table 8 order.
pub fn all_ids() -> Vec<&'static str> {
    REGISTRY.iter().map(|(id, _)| *id).collect()
}

/// Metric ids belonging to any of `cats`, in global Table-8 order
/// (not grouped by the order of `cats`) — so restricted runs and the
/// scenario sweep report metrics in the same order as full runs.
pub fn ids_for_categories(cats: &[Category]) -> Vec<&'static str> {
    taxonomy::ALL.iter().filter(|d| cats.contains(&d.category)).map(|d| d.id).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_taxonomy_exactly() {
        assert_eq!(REGISTRY.len(), taxonomy::ALL.len());
        for (i, d) in taxonomy::ALL.iter().enumerate() {
            assert_eq!(REGISTRY[i].0, d.id, "registry order mismatch at {i}");
        }
    }

    #[test]
    fn run_metric_dispatches() {
        let cfg = RunConfig::quick("native");
        let r = run_metric("OH-001", &cfg).unwrap();
        assert_eq!(r.id, "OH-001");
        assert!(run_metric("NOPE-1", &cfg).is_none());
    }

    #[test]
    fn run_category_counts() {
        let cfg = RunConfig::quick("native");
        assert_eq!(run_category(Category::Fragmentation, &cfg).len(), 3);
        assert_eq!(run_category(Category::Pcie, &cfg).len(), 4);
    }

    #[test]
    fn id_list_helpers() {
        assert_eq!(all_ids().len(), 56);
        assert_eq!(all_ids()[0], "OH-001");
        let ids = ids_for_categories(&[Category::Pcie, Category::MemoryBandwidth]);
        // Global Table-8 order: BW before PCIE regardless of argument order.
        assert_eq!(ids, vec!["BW-001", "BW-002", "BW-003", "BW-004", "PCIE-001", "PCIE-002", "PCIE-003", "PCIE-004"]);
        assert!(ids_for_categories(&[]).is_empty());
    }
}
