//! PCIe bandwidth metrics PCIE-001..004 (paper §3.6).
//!
//! Host↔device transfers are keyed to the sweep cell's topology: the
//! simulated host exposes [`HOST_ROOT_PORTS`] dedicated x16 root ports
//! (a DGX-like chassis), so cells with `RunConfig::gpu_count` beyond
//! that share ports behind PCIe switches and every GPU on a port pays
//! saturating sibling traffic in both directions. At the default
//! 4-GPU node this is a no-op and the numbers match the paper's
//! single-link §7.1 testbed. The link *kind* does not enter here: SXM
//! nodes still reach the host over PCIe, so `--link nvlink` changes
//! only the collective (NCCL/P2P) path.

use crate::cudalite::Api;
use crate::simgpu::pcie::Direction;
use crate::simgpu::TenantId;
use crate::virt::TenantConfig;

use super::{MetricResult, RunConfig};

const TENANT: TenantId = 1;

/// Upstream x16 root ports on the simulated host. Up to this many GPUs
/// get dedicated host links; larger `gpu_count` cells divide sustained
/// host bandwidth among the GPUs sharing one port.
pub const HOST_ROOT_PORTS: u32 = 4;

/// Pseudo-tenant id base for sibling-GPU background flows — real tenant
/// ids stay in `1..=64`, so these can never collide.
const SIBLING_FLOW_BASE: TenantId = 1_000;

fn api_for(cfg: &RunConfig) -> Api {
    let mut api = Api::with_backend(&cfg.system, cfg.seed);
    api.ctx_create(TENANT, TenantConfig::unlimited()).expect("ctx");
    // Thread the cell topology into the host link: every sibling GPU
    // sharing this GPU's root port saturates its fair share of the
    // upstream bandwidth in both directions.
    let per_port = (cfg.gpu_count + HOST_ROOT_PORTS - 1) / HOST_ROOT_PORTS;
    for s in 1..per_port {
        let flow = SIBLING_FLOW_BASE + s;
        let demand = api.dev.spec.pcie_gbps;
        api.dev.pcie.set_background(flow, Direction::HostToDevice, demand);
        api.dev.pcie.set_background(flow, Direction::DeviceToHost, demand);
    }
    api
}

fn measure_bw(cfg: &RunConfig, dir: Direction, pinned: bool) -> MetricResult {
    let mut api = api_for(cfg);
    let id = match dir {
        Direction::HostToDevice => "PCIE-001",
        Direction::DeviceToHost => "PCIE-002",
    };
    let mut col = crate::stats::Collector::new(cfg.warmup, cfg.iterations);
    for _ in 0..cfg.warmup + cfg.iterations {
        let bw = api.memcpy(TENANT, dir, 256 << 20, pinned).expect("memcpy");
        col.record(bw);
    }
    MetricResult::from_samples(id, &cfg.system, col.samples())
}

/// PCIE-001: host-to-device bandwidth, GB/s (pinned).
pub fn pcie_001(cfg: &RunConfig) -> MetricResult {
    measure_bw(cfg, Direction::HostToDevice, true)
}

/// PCIE-002: device-to-host bandwidth, GB/s (pinned).
pub fn pcie_002(cfg: &RunConfig) -> MetricResult {
    measure_bw(cfg, Direction::DeviceToHost, true)
}

/// PCIE-003: bandwidth drop under multi-tenant PCIe traffic, %.
pub fn pcie_003(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    let solo = api.memcpy(TENANT, Direction::HostToDevice, 256 << 20, true).unwrap();
    // n-1 neighbours saturating the same direction. PCIe is *not*
    // partitioned by MIG (instances share the host link) — the paper's
    // MIG-Ideal inherits this, so contention applies to every backend.
    for t in 2..=cfg.tenants.max(2) {
        api.dev.pcie.set_background(t, Direction::HostToDevice, api.dev.spec.pcie_gbps);
    }
    let contended = api.memcpy(TENANT, Direction::HostToDevice, 256 << 20, true).unwrap();
    api.dev.pcie.clear_background();
    let drop = ((solo - contended) / solo * 100.0).max(0.0);
    MetricResult::from_value("PCIE-003", &cfg.system, drop)
}

/// PCIE-004: pinned vs pageable transfer ratio.
pub fn pcie_004(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    let pinned = api.memcpy(TENANT, Direction::HostToDevice, 256 << 20, true).unwrap();
    let pageable = api.memcpy(TENANT, Direction::HostToDevice, 256 << 20, false).unwrap();
    MetricResult::from_value("PCIE-004", &cfg.system, pinned / pageable)
}

/// Run the whole category in Table 8 order.
pub fn run_all(cfg: &RunConfig) -> Vec<MetricResult> {
    vec![pcie_001(cfg), pcie_002(cfg), pcie_003(cfg), pcie_004(cfg)]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(system: &str) -> RunConfig {
        RunConfig::quick(system)
    }

    #[test]
    fn pcie001_near_gen4_peak() {
        let n = pcie_001(&quick("native")).value;
        assert!(n > 22.0 && n <= 25.5, "h2d={n} GB/s");
    }

    #[test]
    fn pcie002_symmetric() {
        let d2h = pcie_002(&quick("native")).value;
        let h2d = pcie_001(&quick("native")).value;
        assert!((d2h - h2d).abs() / h2d < 0.05);
    }

    #[test]
    fn pcie003_contention_applies_to_all_backends() {
        for sys in ["native", "hami", "mig"] {
            let d = pcie_003(&quick(sys)).value;
            assert!(d > 60.0, "{sys} drop={d}%"); // 3 saturating neighbours
        }
    }

    #[test]
    fn pcie004_pinned_ratio() {
        let r = pcie_004(&quick("native")).value;
        assert!((r - 2.4).abs() < 0.2, "ratio={r}");
    }

    #[test]
    fn host_port_sharing_keys_bandwidth_to_gpu_count() {
        // Up to HOST_ROOT_PORTS GPUs each own a root port: bit-identical
        // to the single-link testbed numbers.
        let mut two = quick("native");
        two.gpu_count = 2;
        let mut four = quick("native");
        four.gpu_count = 4;
        assert_eq!(
            pcie_001(&two).value.to_bits(),
            pcie_001(&four).value.to_bits(),
            "dedicated-port cells must match the single-link testbed"
        );
        // An 8-GPU cell shares each port between two GPUs: sustained
        // host bandwidth halves.
        let mut eight = quick("native");
        eight.gpu_count = 8;
        let solo = pcie_001(&four).value;
        let shared = pcie_001(&eight).value;
        assert!(
            shared < solo * 0.55 && shared > solo * 0.4,
            "solo={solo} shared={shared}"
        );
        // The pinned/pageable ratio is share-invariant.
        let r4 = pcie_004(&four).value;
        let r8 = pcie_004(&eight).value;
        assert!((r4 - r8).abs() / r4 < 0.02, "r4={r4} r8={r8}");
    }

    #[test]
    fn virt_overhead_negligible_for_large_transfers() {
        let n = pcie_001(&quick("native")).value;
        let h = pcie_001(&quick("hami")).value;
        assert!((n - h) / n < 0.02, "native={n} hami={h}");
    }
}
