//! The paper's 56-metric taxonomy (§3, Table 8) and the machinery to run it.
//!
//! Each category lives in its own module; [`taxonomy`] holds the static
//! descriptor table (id, name, unit, direction, category). A metric is a
//! function `fn(&RunConfig) -> MetricResult`; [`registry`] maps ids to
//! functions so the runner, CLI and benches share one dispatch table.

pub mod bandwidth;
pub mod cache;
pub mod error_recovery;
pub mod fragmentation;
pub mod isolation;
pub mod llm;
pub mod nccl;
pub mod overhead;
pub mod pcie;
pub mod registry;
pub mod scheduling;
pub mod taxonomy;

use crate::simgpu::nvlink::{LinkKind, Topology};
use crate::simgpu::GpuSpec;
use crate::stats::Summary;

/// Metric category (Table 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Category {
    Overhead,
    Isolation,
    Llm,
    MemoryBandwidth,
    CacheIsolation,
    Pcie,
    Nccl,
    Scheduling,
    Fragmentation,
    ErrorRecovery,
}

impl Category {
    pub const ALL: [Category; 10] = [
        Category::Overhead,
        Category::Isolation,
        Category::Llm,
        Category::MemoryBandwidth,
        Category::CacheIsolation,
        Category::Pcie,
        Category::Nccl,
        Category::Scheduling,
        Category::Fragmentation,
        Category::ErrorRecovery,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Category::Overhead => "Overhead",
            Category::Isolation => "Isolation",
            Category::Llm => "LLM",
            Category::MemoryBandwidth => "Memory Bandwidth",
            Category::CacheIsolation => "Cache Isolation",
            Category::Pcie => "PCIe",
            Category::Nccl => "NCCL/P2P",
            Category::Scheduling => "Scheduling",
            Category::Fragmentation => "Fragmentation",
            Category::ErrorRecovery => "Error Recovery",
        }
    }

    /// Default production weights (paper §6.3).
    pub fn weight(&self) -> f64 {
        match self {
            Category::Overhead => 0.15,
            Category::Isolation => 0.20,
            Category::Llm => 0.20,
            Category::MemoryBandwidth => 0.10,
            Category::CacheIsolation => 0.08,
            Category::Pcie => 0.07,
            Category::Nccl => 0.05,
            Category::Scheduling => 0.07,
            Category::Fragmentation => 0.04,
            Category::ErrorRecovery => 0.04,
        }
    }

    /// CLI key (`--category overhead`).
    pub fn key(&self) -> &'static str {
        match self {
            Category::Overhead => "overhead",
            Category::Isolation => "isolation",
            Category::Llm => "llm",
            Category::MemoryBandwidth => "bandwidth",
            Category::CacheIsolation => "cache",
            Category::Pcie => "pcie",
            Category::Nccl => "nccl",
            Category::Scheduling => "scheduling",
            Category::Fragmentation => "fragmentation",
            Category::ErrorRecovery => "error",
        }
    }

    pub fn from_key(key: &str) -> Option<Category> {
        Category::ALL.iter().copied().find(|c| c.key() == key)
    }
}

/// Whether larger metric values are better (Table 8 "Better" column).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    HigherBetter,
    LowerBetter,
    /// Boolean pass/fail (True is better).
    Boolean,
}

/// Static description of one metric (one row of Table 8).
#[derive(Clone, Copy, Debug)]
pub struct Descriptor {
    pub id: &'static str,
    pub name: &'static str,
    pub description: &'static str,
    pub unit: &'static str,
    pub category: Category,
    pub direction: Direction,
}

/// Configuration of a metric run (paper §4.4 defaults).
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Backend key: `native` / `hami` / `fcsp` / `mig`.
    pub system: String,
    /// Measured iterations per metric (default 100).
    pub iterations: usize,
    /// Warmup iterations discarded (default 10).
    pub warmup: usize,
    /// Concurrent tenants in multi-tenant scenarios (default 4).
    pub tenants: u32,
    /// RNG seed.
    pub seed: u64,
    /// Memory quota per tenant in multi-tenant scenarios (bytes).
    pub mem_limit: u64,
    /// SM limit per tenant in multi-tenant scenarios (fraction).
    pub sm_limit: f64,
    /// GPUs in the simulated multi-GPU node — the NCCL/P2P rank count and
    /// the PCIe host-complex population (default 4, the node the
    /// NCCL-001..004 category evaluated before the topology became a
    /// sweep axis). Swept by `gvbench sweep --gpus 2,4,8`.
    pub gpu_count: u32,
    /// Interconnect joining the node's GPUs (default PCIe — the paper's
    /// A100 PCIe testbed). Swept by `gvbench sweep --link nvlink,pcie`.
    pub link: LinkKind,
    /// Worker threads for suite execution (0 = available parallelism).
    /// Results are bit-identical at any job count: each (system, metric)
    /// task derives its own seed via [`crate::util::rng::task_seed`].
    pub jobs: usize,
}

impl Default for RunConfig {
    fn default() -> RunConfig {
        RunConfig {
            system: "native".to_string(),
            iterations: 100,
            warmup: 10,
            tenants: 4,
            seed: 42,
            mem_limit: 10 << 30, // 10 GiB = equal quarter of an A100-40GB
            sm_limit: 0.25,
            gpu_count: 4,
            link: LinkKind::Pcie,
            jobs: 0,
        }
    }
}

impl RunConfig {
    pub fn for_system(system: &str) -> RunConfig {
        RunConfig { system: system.to_string(), ..Default::default() }
    }

    /// Smaller iteration counts for quick runs / CI.
    pub fn quick(system: &str) -> RunConfig {
        RunConfig {
            system: system.to_string(),
            iterations: 25,
            warmup: 3,
            ..Default::default()
        }
    }

    /// The multi-GPU node topology of this run's cell: `gpu_count`
    /// devices joined by `link`. PCIe nodes use `spec`'s host-link
    /// bandwidth; NVLink nodes use `spec`'s per-direction NVLink
    /// bandwidth when the profile has one, falling back to the A100-SXM
    /// sibling's NVLink3 figure for PCIe SKUs (whose spec carries
    /// `nvlink_gbps = 0`). The NCCL/P2P metric backends build their
    /// communicator from this, so collective numbers are keyed to the
    /// sweep cell's topology coordinates.
    pub fn node_topology(&self, spec: &GpuSpec) -> Topology {
        match self.link {
            LinkKind::NvLink => {
                let bw = if spec.nvlink_gbps > 0.0 {
                    spec.nvlink_gbps
                } else {
                    GpuSpec::a100_80gb_sxm().nvlink_gbps
                };
                Topology::nvlink_node(self.gpu_count, bw)
            }
            LinkKind::Pcie => Topology::pcie_node(self.gpu_count, spec.pcie_gbps),
        }
    }
}

/// Outcome of one metric on one system.
#[derive(Clone, Debug)]
pub struct MetricResult {
    pub id: &'static str,
    pub system: String,
    /// Headline value (mean for latency metrics, the computed ratio/index
    /// for derived metrics, 1.0/0.0 for booleans).
    pub value: f64,
    /// Full sample statistics where the metric is sample-based.
    pub summary: Summary,
    /// Boolean outcome for pass/fail metrics.
    pub pass: Option<bool>,
}

impl MetricResult {
    /// Build from raw samples: value = mean.
    pub fn from_samples(id: &'static str, system: &str, samples: &[f64]) -> MetricResult {
        let summary = Summary::from_samples(samples);
        MetricResult { id, system: system.to_string(), value: summary.mean, summary, pass: None }
    }

    /// Build from a single derived value.
    pub fn from_value(id: &'static str, system: &str, value: f64) -> MetricResult {
        MetricResult {
            id,
            system: system.to_string(),
            value,
            summary: Summary::from_samples(&[value]),
            pass: None,
        }
    }

    /// Build a boolean result.
    pub fn from_pass(id: &'static str, system: &str, pass: bool) -> MetricResult {
        MetricResult {
            id,
            system: system.to_string(),
            value: if pass { 1.0 } else { 0.0 },
            summary: Summary::from_samples(&[if pass { 1.0 } else { 0.0 }]),
            pass: Some(pass),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn category_weights_sum_to_one() {
        let sum: f64 = Category::ALL.iter().map(|c| c.weight()).sum();
        assert!((sum - 1.0).abs() < 1e-12, "sum={sum}");
    }

    #[test]
    fn category_keys_roundtrip() {
        for c in Category::ALL {
            assert_eq!(Category::from_key(c.key()), Some(c));
        }
        assert_eq!(Category::from_key("bogus"), None);
    }

    #[test]
    fn node_topology_follows_link_and_count() {
        let spec = GpuSpec::a100_40gb();
        let mut cfg = RunConfig::default();
        // Defaults reproduce the pre-PR-4 hardcoded node: 4 ranks, PCIe.
        assert_eq!(cfg.gpu_count, 4);
        assert_eq!(cfg.link, LinkKind::Pcie);
        let t = cfg.node_topology(&spec);
        assert_eq!(t.device_count, 4);
        assert_eq!(t.link_kind(), LinkKind::Pcie);
        assert_eq!(t.pcie_gbps, spec.pcie_gbps);
        cfg.link = LinkKind::NvLink;
        cfg.gpu_count = 8;
        let t = cfg.node_topology(&spec);
        assert_eq!(t.device_count, 8);
        assert_eq!(t.link_kind(), LinkKind::NvLink);
        // PCIe SKU (nvlink_gbps = 0): falls back to the SXM sibling.
        assert_eq!(t.nvlink_gbps, GpuSpec::a100_80gb_sxm().nvlink_gbps);
        let sxm = GpuSpec::a100_80gb_sxm();
        assert_eq!(cfg.node_topology(&sxm).nvlink_gbps, sxm.nvlink_gbps);
    }

    #[test]
    fn result_constructors() {
        let r = MetricResult::from_samples("OH-001", "native", &[1.0, 2.0, 3.0]);
        assert_eq!(r.value, 2.0);
        assert_eq!(r.summary.count, 3);
        let b = MetricResult::from_pass("IS-005", "hami", true);
        assert_eq!(b.pass, Some(true));
        assert_eq!(b.value, 1.0);
    }
}
