//! Overhead metrics OH-001..OH-010 (paper §3.1, Table 4).
//!
//! All latencies are measured with the virtual-clock stopwatch around the
//! `cudalite` call — the simulated analogue of the paper's
//! `clock_gettime(CLOCK_MONOTONIC)` pattern (Listings 3–4).

use crate::cudalite::Api;
use crate::simgpu::kernel::KernelDesc;
use crate::simgpu::TenantId;
use crate::virt::TenantConfig;

use super::{MetricResult, RunConfig};

const TENANT: TenantId = 1;

fn api_for(cfg: &RunConfig) -> Api {
    let mut api = Api::with_backend(&cfg.system, cfg.seed);
    api.ctx_create(TENANT, TenantConfig::unlimited()).expect("ctx");
    api
}

/// OH-001: `cuLaunchKernel` CPU-side latency over a null kernel.
pub fn oh_001(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    let kernel = KernelDesc::null();
    let mut col = crate::stats::Collector::new(cfg.warmup, cfg.iterations);
    for _ in 0..cfg.warmup + cfg.iterations {
        let t0 = api.now_ns();
        api.launch_kernel(TENANT, 0, &kernel).expect("launch");
        col.record((api.now_ns() - t0) as f64 / 1e3);
        api.sync_device(TENANT).unwrap();
    }
    MetricResult::from_samples("OH-001", &cfg.system, col.samples())
}

/// OH-002: `cuMemAlloc` latency (1 MiB requests).
pub fn oh_002(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    let mut col = crate::stats::Collector::new(cfg.warmup, cfg.iterations);
    for _ in 0..cfg.warmup + cfg.iterations {
        let t0 = api.now_ns();
        let ptr = api.mem_alloc(TENANT, 1 << 20).expect("alloc");
        col.record((api.now_ns() - t0) as f64 / 1e3);
        api.mem_free(TENANT, ptr).unwrap();
    }
    MetricResult::from_samples("OH-002", &cfg.system, col.samples())
}

/// OH-003: `cuMemFree` latency.
pub fn oh_003(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    let mut col = crate::stats::Collector::new(cfg.warmup, cfg.iterations);
    for _ in 0..cfg.warmup + cfg.iterations {
        let ptr = api.mem_alloc(TENANT, 1 << 20).expect("alloc");
        let t0 = api.now_ns();
        api.mem_free(TENANT, ptr).unwrap();
        col.record((api.now_ns() - t0) as f64 / 1e3);
    }
    MetricResult::from_samples("OH-003", &cfg.system, col.samples())
}

/// OH-004: context creation time (create/destroy cycles).
pub fn oh_004(cfg: &RunConfig) -> MetricResult {
    let mut api = Api::with_backend(&cfg.system, cfg.seed);
    let mut col = crate::stats::Collector::new(cfg.warmup.min(3), cfg.iterations);
    for i in 0..cfg.warmup.min(3) + cfg.iterations {
        let tenant = (i + 1) as TenantId;
        let t0 = api.now_ns();
        api.ctx_create(tenant, TenantConfig::unlimited()).expect("ctx");
        col.record((api.now_ns() - t0) as f64 / 1e3);
        api.ctx_destroy(tenant).unwrap();
    }
    MetricResult::from_samples("OH-004", &cfg.system, col.samples())
}

/// OH-005: per-call interception overhead, isolated by differencing the
/// same call (`cuMemGetInfo`, a pure hook path) against native (paper
/// Listing 4 method). Reported in ns.
pub fn oh_005(cfg: &RunConfig) -> MetricResult {
    let mut virt = api_for(cfg);
    let mut native = {
        let mut cfg_n = cfg.clone();
        cfg_n.system = "native".to_string();
        api_for(&cfg_n)
    };
    let mut col = crate::stats::Collector::new(cfg.warmup, cfg.iterations);
    for _ in 0..cfg.warmup + cfg.iterations {
        let t0 = virt.now_ns();
        virt.mem_get_info(TENANT);
        let t_virt = (virt.now_ns() - t0) as f64;
        let t0 = native.now_ns();
        native.mem_get_info(TENANT);
        let t_native = (native.now_ns() - t0) as f64;
        col.record((t_virt - t_native).max(0.0));
    }
    MetricResult::from_samples("OH-005", &cfg.system, col.samples())
}

/// OH-006: shared-region semaphore wait under multi-tenant churn, µs per
/// acquisition. `cfg.tenants` containers hammer alloc/free; the region's
/// M/D/1 contention model (calibrated to the observed lock rate) yields
/// the per-acquisition wait — sub-µs for HAMi's 400 ns critical section,
/// an order less for FCSP's atomic fast path.
pub fn oh_006(cfg: &RunConfig) -> MetricResult {
    let mut api = Api::with_backend(&cfg.system, cfg.seed);
    let tenants = cfg.tenants.max(2);
    for t in 0..tenants {
        api.ctx_create(
            t as TenantId + 1,
            TenantConfig::unlimited().with_sm_limit(1.0 / tenants as f64),
        )
        .unwrap();
    }
    for i in 0..(cfg.iterations * 8).max(200) {
        let tenant = (i as u32 % tenants) as TenantId + 1;
        let ptr = api.mem_alloc(tenant, 1 << 16).expect("alloc");
        api.mem_free(tenant, ptr).unwrap();
        api.virt.tick(&mut api.dev); // recalibrate the observed lock rate
    }
    let (wait_ns, acquisitions) = api.virt.contention_stats();
    let per_acq_us = if acquisitions == 0 { 0.0 } else { wait_ns / acquisitions as f64 / 1e3 };
    MetricResult::from_value("OH-006", &cfg.system, per_acq_us)
}

/// OH-007: per-allocation *tracking* cost — the accounting data structure
/// alone (hash-table insert/remove), excluding hooks, locks and NVML
/// reconciliation (those are OH-005/006 and part of OH-002), in ns.
pub fn oh_007(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    let base = api.virt.tracking_cost_ns();
    // Report with the same jitter treatment as any measured latency.
    let mut col = crate::stats::Collector::new(cfg.warmup, cfg.iterations);
    for _ in 0..cfg.warmup + cfg.iterations {
        let j = api.dev.jitter();
        col.record(base * j);
    }
    MetricResult::from_samples("OH-007", &cfg.system, col.samples())
}

/// OH-008: rate-limiter check latency — launch latency with a (lenient)
/// SM limit configured minus without, in ns. The limit is high enough that
/// no throttling engages, isolating the token-bucket arithmetic.
pub fn oh_008(cfg: &RunConfig) -> MetricResult {
    let mean_launch = |limited: bool| -> f64 {
        let mut api = Api::with_backend(&cfg.system, cfg.seed);
        let tc = if limited {
            TenantConfig::unlimited().with_sm_limit(0.99)
        } else {
            TenantConfig::unlimited()
        };
        api.ctx_create(TENANT, tc).unwrap();
        let kernel = KernelDesc::null();
        let mut col = crate::stats::Collector::new(cfg.warmup, cfg.iterations);
        for _ in 0..cfg.warmup + cfg.iterations {
            let t0 = api.now_ns();
            api.launch_kernel(TENANT, 0, &kernel).expect("launch");
            col.record((api.now_ns() - t0) as f64);
            api.sync_device(TENANT).unwrap();
        }
        col.summary().mean
    };
    let with = mean_launch(true);
    let without = mean_launch(false);
    MetricResult::from_value("OH-008", &cfg.system, (with - without).max(0.0))
}

/// OH-009: monitoring CPU overhead (paper eq. 4), in percent.
pub fn oh_009(cfg: &RunConfig) -> MetricResult {
    let api = api_for(cfg);
    MetricResult::from_value("OH-009", &cfg.system, api.virt.monitor_cpu_overhead() * 100.0)
}

/// OH-010: end-to-end throughput degradation vs native (paper eq. 5), in
/// percent. Workload: a mixed loop of alloc → H2D copy → compute kernels →
/// free, the shape of an inference serving step.
pub fn oh_010(cfg: &RunConfig) -> MetricResult {
    let throughput = |system: &str| -> f64 {
        let mut c = cfg.clone();
        c.system = system.to_string();
        let mut api = Api::with_backend(system, cfg.seed);
        // Configure like a real deployment: quota + SM limit that the
        // steady workload stays *under* (limits cost even when not binding).
        // Memory quota only — OH-010 measures virtualization overhead on
        // an unthrottled workload (the capacity trade of an SM limit is a
        // policy choice, not overhead).
        api.ctx_create(TENANT, TenantConfig::unlimited().with_mem_limit(20 << 30)).unwrap();
        let kernel = KernelDesc::gemm(1024, 1024, 1024, false);
        let steps = cfg.iterations.max(20);
        let t0 = api.now_ns();
        for _ in 0..steps {
            // An inference step: activation + KV-block + scratch
            // allocations, input copy, four layer kernels, frees.
            let a = api.mem_alloc(TENANT, 8 << 20).expect("alloc");
            let b = api.mem_alloc(TENANT, 2 << 20).expect("alloc");
            let c = api.mem_alloc(TENANT, 4 << 20).expect("alloc");
            api.memcpy(TENANT, crate::simgpu::pcie::Direction::HostToDevice, 8 << 20, true)
                .unwrap();
            for _ in 0..4 {
                api.launch_kernel(TENANT, 0, &kernel).expect("launch");
            }
            api.sync_device(TENANT).unwrap();
            for p in [a, b, c] {
                api.mem_free(TENANT, p).unwrap();
            }
        }
        steps as f64 / ((api.now_ns() - t0) as f64 / 1e9)
    };
    let native = throughput("native");
    let virt = throughput(&cfg.system);
    let degradation = ((native - virt) / native * 100.0).max(0.0);
    MetricResult::from_value("OH-010", &cfg.system, degradation)
}

/// Run the whole category in Table 8 order.
pub fn run_all(cfg: &RunConfig) -> Vec<MetricResult> {
    vec![
        oh_001(cfg),
        oh_002(cfg),
        oh_003(cfg),
        oh_004(cfg),
        oh_005(cfg),
        oh_006(cfg),
        oh_007(cfg),
        oh_008(cfg),
        oh_009(cfg),
        oh_010(cfg),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(system: &str) -> RunConfig {
        RunConfig::quick(system)
    }

    #[test]
    fn oh001_native_matches_table4() {
        let r = oh_001(&quick("native"));
        assert!((r.value - 4.2).abs() < 0.4, "native launch = {} µs", r.value);
    }

    #[test]
    fn oh001_ordering_native_fcsp_hami() {
        let n = oh_001(&quick("native")).value;
        let f = oh_001(&quick("fcsp")).value;
        let h = oh_001(&quick("hami")).value;
        assert!(n < f && f < h, "n={n} f={f} h={h}");
        // Paper: HAMi ≈ 3.6x native launch overall; ours is the CPU-side
        // component without throttle waits — still clearly elevated.
        assert!(h / n > 1.1, "h/n={}", h / n);
    }

    #[test]
    fn oh002_oh003_native_calibration() {
        let a = oh_002(&quick("native"));
        let f = oh_003(&quick("native"));
        assert!((a.value - 12.5).abs() < 1.0, "alloc={} µs", a.value);
        assert!((f.value - 8.1).abs() < 0.8, "free={} µs", f.value);
    }

    #[test]
    fn oh002_oh003_virt_match_table4() {
        // Table 4: alloc 45.2 (HAMi) / 28.3 (FCSP); free 32.4 / 18.6.
        let ah = oh_002(&quick("hami")).value;
        let af = oh_002(&quick("fcsp")).value;
        let fh = oh_003(&quick("hami")).value;
        let ff = oh_003(&quick("fcsp")).value;
        assert!((ah - 45.2).abs() < 4.0, "hami alloc={ah}");
        assert!((af - 28.3).abs() < 3.0, "fcsp alloc={af}");
        assert!((fh - 32.4).abs() < 3.5, "hami free={fh}");
        assert!((ff - 18.6).abs() < 2.5, "fcsp free={ff}");
    }

    #[test]
    fn oh004_hami_heaviest() {
        let n = oh_004(&quick("native")).value;
        let h = oh_004(&quick("hami")).value;
        let f = oh_004(&quick("fcsp")).value;
        let m = oh_004(&quick("mig")).value;
        assert!((n - 125.0).abs() < 12.0, "native ctx={n}");
        assert!((h - 312.0).abs() < 35.0, "hami ctx={h}");
        assert!((f - 198.0).abs() < 25.0, "fcsp ctx={f}");
        assert!((m - n).abs() < 12.0, "mig ctx={m}");
    }

    #[test]
    fn oh005_hook_costs() {
        let h = oh_005(&quick("hami")).value;
        let f = oh_005(&quick("fcsp")).value;
        let m = oh_005(&quick("mig")).value;
        assert!((h - 85.0).abs() < 20.0, "hami hook={h}");
        assert!((f - 42.0).abs() < 15.0, "fcsp hook={f}");
        assert!(m < 5.0, "mig hook={m}");
    }

    #[test]
    fn oh006_contention_positive_for_software() {
        let h = oh_006(&quick("hami")).value;
        let f = oh_006(&quick("fcsp")).value;
        let m = oh_006(&quick("mig")).value;
        assert!(h > 0.0, "hami lock wait = {h}");
        assert!(f < h, "fcsp={f} hami={h}");
        assert_eq!(m, 0.0);
    }

    #[test]
    fn oh009_polling() {
        assert!(oh_009(&quick("native")).value == 0.0);
        let h = oh_009(&quick("hami")).value;
        assert!((h - 0.055).abs() < 0.01, "hami poll = {h}%");
        assert!(oh_009(&quick("fcsp")).value < h);
    }

    #[test]
    fn oh010_degradation_ordering() {
        let h = oh_010(&quick("hami")).value;
        let f = oh_010(&quick("fcsp")).value;
        let m = oh_010(&quick("mig")).value;
        assert!(h > f, "hami={h} fcsp={f}");
        assert!(m < 3.0, "mig={m}");
        // Paper: HAMi 18.5 %, FCSP 9.2 %.
        assert!(h > 10.0 && h < 30.0, "hami={h}");
        assert!(f > 4.0 && f < 16.0, "fcsp={f}");
    }

    #[test]
    fn run_all_returns_ten() {
        let rs = run_all(&quick("native"));
        assert_eq!(rs.len(), 10);
        assert!(rs.iter().all(|r| r.id.starts_with("OH-")));
    }
}
