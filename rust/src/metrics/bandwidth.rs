//! Memory-bandwidth metrics BW-001..BW-004 (paper §3.4).

use crate::cudalite::Api;
use crate::simgpu::device::BackgroundLoad;
use crate::simgpu::kernel::KernelDesc;
use crate::simgpu::TenantId;
use crate::stats::jain_fairness;
use crate::virt::TenantConfig;

use super::{MetricResult, RunConfig};

const TENANT: TenantId = 1;

fn api_for(cfg: &RunConfig) -> Api {
    let mut api = Api::with_backend(&cfg.system, cfg.seed);
    api.ctx_create(TENANT, TenantConfig::unlimited()).expect("ctx");
    api
}

/// Achieved streaming bandwidth in GB/s for the victim.
fn stream_bw(api: &mut Api) -> f64 {
    let bytes = 2e9;
    let kernel = KernelDesc::streaming(bytes);
    let t0 = api.now_ns();
    api.launch_kernel(TENANT, 0, &kernel).expect("launch");
    api.sync_device(TENANT).unwrap();
    bytes / (api.now_ns() - t0) as f64
}

/// BW-001: bandwidth under contention as % of solo (paper eq. 23). MIG
/// slices have dedicated bandwidth, so neighbours don't apply — but a
/// slice's *solo* bandwidth is its partition share, which is the honest
/// trade MIG makes.
pub fn bw_001(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    let solo = stream_bw(&mut api);
    let pct = if api.virt.hardware_isolated() {
        100.0
    } else {
        // n-1 bandwidth-heavy neighbours, each SM-limited to 1/n: a
        // neighbour's flood is only resident for its duty cycle, so the
        // victim's expected share is averaged over the random overlap.
        let duty = (1.0 / cfg.tenants.max(2) as f64) * 1.15; // limiter overshoot margin
        let n = cfg.tenants.max(2) - 1;
        let mut total = 0.0;
        let reps = cfg.iterations.min(40).max(10);
        for _ in 0..reps {
            let active = (0..n).filter(|_| api.dev.rng().chance(duty)).count() as u32;
            for t in 0..active {
                api.dev.set_background(
                    2 + t,
                    crate::simgpu::device::BackgroundLoad {
                        membw_demand: 1.0,
                        resident_kernels: 0,
                    },
                );
            }
            total += stream_bw(&mut api);
            api.dev.clear_background();
        }
        (total / reps as f64) / solo * 100.0
    };
    MetricResult::from_value("BW-001", &cfg.system, pct)
}

/// BW-002: Jain fairness of bandwidth across tenants. Software backends
/// share the bus max-min fairly in hardware; what differentiates them is
/// how much each tenant's *demand* deviates under its limiter (HAMi
/// overshoot ⇒ unequal demands ⇒ unequal achieved bandwidth).
pub fn bw_002(cfg: &RunConfig) -> MetricResult {
    let api = api_for(cfg);
    let n = cfg.tenants.max(2);
    if api.virt.hardware_isolated() {
        // Dedicated slices: everyone gets exactly their share.
        return MetricResult::from_value("BW-002", &cfg.system, 1.0);
    }
    // Per-tenant achieved bandwidth: proportional to its duty cycle under
    // its own limiter with heterogeneous kernels (as in IS-008).
    let mut achieved = Vec::new();
    for t in 0..n {
        let mut api_t = Api::with_backend(&cfg.system, cfg.seed ^ (t as u64 + 1));
        api_t
            .ctx_create(TENANT, TenantConfig::unlimited().with_sm_limit(1.0 / n as f64))
            .unwrap();
        // Tenant-specific kernel size (heterogeneous, as real tenants are).
        let dims = [4096, 2048, 3072, 2560];
        let d = dims[t as usize % dims.len()];
        let kernel = KernelDesc::gemm(d, d, d, false);
        let start = api_t.now_ns();
        api_t.dev.sms.reset_window(start);
        while api_t.now_ns() - start < 1_500_000_000 {
            api_t.launch_kernel(TENANT, 0, &kernel).expect("launch");
            api_t.sync_stream(TENANT, 0).unwrap();
        }
        let duty = api_t.dev.sms.utilization(TENANT, api_t.now_ns());
        achieved.push(duty);
    }
    MetricResult::from_value("BW-002", &cfg.system, jain_fairness(&achieved))
}

/// BW-003: streams needed to reach 95 % of max bandwidth (paper eq. 24).
/// A single streaming kernel wave reaches ~60 % of peak; concurrent
/// streams fill the memory pipeline. Virtualization launch overhead delays
/// the fill slightly but does not change the asymptote.
pub fn bw_003(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    let single_stream_frac: f64 = 0.62;
    // Launch overhead per stream reduces effective concurrency slightly:
    // measure the launch cost relative to kernel duration.
    let kernel = KernelDesc::streaming(1e9);
    let t0 = api.now_ns();
    api.launch_kernel(TENANT, 0, &kernel).expect("launch");
    let launch_ns = (api.now_ns() - t0) as f64;
    api.sync_device(TENANT).unwrap();
    let body_ns = 1e9 / (api.dev.spec.hbm_bw_gbps * 1e9) * 1e9;
    let overhead_frac = launch_ns / body_ns;
    let mut n = 1u32;
    loop {
        let eff = (n as f64 * single_stream_frac) / (1.0 + overhead_frac * n as f64);
        if eff >= 0.95 || n >= 16 {
            break;
        }
        n += 1;
    }
    MetricResult::from_value("BW-003", &cfg.system, n as f64)
}

/// BW-004: bandwidth drop from one full-rate competitor, percent.
pub fn bw_004(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    let solo = stream_bw(&mut api);
    let drop = if api.virt.hardware_isolated() {
        0.0
    } else {
        api.dev.set_background(2, BackgroundLoad { membw_demand: 1.0, resident_kernels: 0 });
        let contended = stream_bw(&mut api);
        api.dev.clear_background();
        (solo - contended) / solo * 100.0
    };
    MetricResult::from_value("BW-004", &cfg.system, drop)
}

/// Run the whole category in Table 8 order.
pub fn run_all(cfg: &RunConfig) -> Vec<MetricResult> {
    vec![bw_001(cfg), bw_002(cfg), bw_003(cfg), bw_004(cfg)]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(system: &str) -> RunConfig {
        RunConfig::quick(system)
    }

    #[test]
    fn bw001_contention_reduces_software_not_mig() {
        let h = bw_001(&quick("hami")).value;
        let m = bw_001(&quick("mig")).value;
        // Duty-cycled neighbours: victim keeps a majority share on average.
        assert!(h < 92.0 && h > 40.0, "hami={h}%");
        assert_eq!(m, 100.0);
    }

    #[test]
    fn bw002_fcsp_fairer() {
        let h = bw_002(&quick("hami")).value;
        let f = bw_002(&quick("fcsp")).value;
        assert!(f >= h, "fcsp={f} hami={h}");
        assert_eq!(bw_002(&quick("mig")).value, 1.0);
    }

    #[test]
    fn bw003_small_count() {
        let n = bw_003(&quick("native")).value;
        assert!(n >= 2.0 && n <= 4.0, "saturation={n}");
    }

    #[test]
    fn bw004_drop_half_for_one_competitor() {
        let n = bw_004(&quick("native")).value;
        assert!((n - 50.0).abs() < 8.0, "drop={n}%");
        assert_eq!(bw_004(&quick("mig")).value, 0.0);
    }
}
