//! Scheduling metrics SCHED-001..004 (paper §3.8).

use crate::cudalite::Api;
use crate::simgpu::kernel::KernelDesc;
use crate::simgpu::stream::StreamPriority;
use crate::virt::TenantConfig;

use super::{MetricResult, RunConfig};

fn api_for(cfg: &RunConfig) -> Api {
    let mut api = Api::with_backend(&cfg.system, cfg.seed);
    api.ctx_create(1, TenantConfig::unlimited()).expect("ctx");
    api
}

/// SCHED-001: context switch latency (µs): ping-pong between two contexts.
pub fn sched_001(cfg: &RunConfig) -> MetricResult {
    // Two half-share contexts (fits MIG's slice geometry).
    let mut api = Api::with_backend(&cfg.system, cfg.seed);
    api.ctx_create(1, TenantConfig::unlimited().with_sm_limit(0.4)).expect("ctx");
    api.ctx_create(2, TenantConfig::unlimited().with_sm_limit(0.4)).unwrap();
    let mut col = crate::stats::Collector::new(cfg.warmup, cfg.iterations);
    let mut current = 1;
    for _ in 0..cfg.warmup + cfg.iterations {
        current = if current == 1 { 2 } else { 1 };
        let t0 = api.now_ns();
        api.ctx_switch(current).unwrap();
        col.record((api.now_ns() - t0) as f64 / 1e3);
    }
    MetricResult::from_samples("SCHED-001", &cfg.system, col.samples())
}

/// SCHED-002: minimal-kernel launch+complete time (µs) — launch overhead
/// plus the null-kernel body, measured to stream drain (unlike OH-001,
/// which measures only the CPU-side call).
pub fn sched_002(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    let kernel = KernelDesc::null();
    let mut col = crate::stats::Collector::new(cfg.warmup, cfg.iterations);
    for _ in 0..cfg.warmup + cfg.iterations {
        let t0 = api.now_ns();
        api.launch_kernel(1, 0, &kernel).expect("launch");
        api.sync_stream(1, 0).unwrap();
        col.record((api.now_ns() - t0) as f64 / 1e3);
    }
    MetricResult::from_samples("SCHED-002", &cfg.system, col.samples())
}

/// SCHED-003: stream concurrency efficiency (%): wall time of K kernels on
/// K streams vs serially on one stream. Kernels are launch-dominated, so
/// overlapped streams hide launch overhead; virtualization inflates the
/// serial launch path and so *reduces* the measured efficiency.
pub fn sched_003(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    let k = 4u32;
    // Kernel body ≈ 10 µs: launch overhead is a visible fraction.
    let kernel = KernelDesc::streaming(16e6);
    let reps = cfg.iterations.max(20);
    let streams: Vec<u32> = (0..k).map(|_| api.stream_create(StreamPriority::Normal)).collect();
    let mut serial = 0.0;
    let mut concurrent = 0.0;
    for _ in 0..reps {
        // Serial: k kernels back-to-back on one stream.
        let t0 = api.now_ns();
        for _ in 0..k {
            api.launch_kernel(1, 0, &kernel).expect("launch");
            api.sync_stream(1, 0).unwrap();
        }
        serial += (api.now_ns() - t0) as f64;
        // Concurrent: same work fanned across k streams.
        let t0 = api.now_ns();
        for s in &streams {
            api.launch_kernel(1, *s, &kernel).expect("launch");
        }
        api.sync_device(1).unwrap();
        concurrent += (api.now_ns() - t0) as f64;
    }
    // Ideal overlap hides everything but one body + the k launch calls;
    // efficiency = how much of the serial k× cost overlap recovered.
    let eff = (serial / concurrent / k as f64 * 100.0).min(100.0);
    MetricResult::from_value("SCHED-003", &cfg.system, eff)
}

/// SCHED-004: preemption latency (ms): a high-priority launch arrives
/// while a long low-priority kernel runs; measured delay until it starts.
pub fn sched_004(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    let mut col = crate::stats::Collector::new(2, cfg.iterations.min(40));
    let hi = api.stream_create(StreamPriority::High);
    for _ in 0..2 + cfg.iterations.min(40) {
        // Long kernel on the default stream (≈3 ms).
        let long = KernelDesc::gemm(3072, 3072, 3072, false);
        api.launch_kernel(1, 0, &long).expect("long");
        // Preemption slice on A100 ≈ 100 µs granularity.
        let delay = api.dev.streams.preemption_delay_ns(api.now_ns(), 100_000);
        let t0 = api.now_ns();
        api.dev.clock.advance(delay);
        let span = api.launch_kernel(1, hi, &KernelDesc::null()).expect("hi");
        api.dev.clock.advance_to(span.1);
        col.record((api.now_ns() - t0) as f64 / 1e6);
        api.sync_device(1).unwrap();
    }
    MetricResult::from_samples("SCHED-004", &cfg.system, col.samples())
}

/// Run the whole category in Table 8 order.
pub fn run_all(cfg: &RunConfig) -> Vec<MetricResult> {
    vec![sched_001(cfg), sched_002(cfg), sched_003(cfg), sched_004(cfg)]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(system: &str) -> RunConfig {
        RunConfig::quick(system)
    }

    #[test]
    fn sched001_native_calibration() {
        let n = sched_001(&quick("native")).value;
        assert!((n - 10.5).abs() < 1.0, "ctx switch={n} µs");
        let h = sched_001(&quick("hami")).value;
        assert!(h > n, "hami={h} native={n}");
    }

    #[test]
    fn sched002_includes_body() {
        let oh = super::super::overhead::oh_001(&quick("native")).value;
        let s2 = sched_002(&quick("native")).value;
        assert!(s2 >= oh, "sched002={s2} oh001={oh}");
    }

    #[test]
    fn sched003_efficiency_ordering() {
        let n = sched_003(&quick("native")).value;
        let h = sched_003(&quick("hami")).value;
        assert!(n > h + 1.0, "native={n}% hami={h}%");
        assert!(n > 35.0 && n <= 100.0, "native={n}%");
    }

    #[test]
    fn sched004_bounded_by_slice_plus_kernel() {
        let n = sched_004(&quick("native")).value;
        assert!(n < 0.5, "preemption={n} ms");
    }
}
