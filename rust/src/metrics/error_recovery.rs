//! Error recovery metrics ERR-001..003 (paper §3.10).

use crate::cudalite::Api;
use crate::simgpu::error::GpuFault;
use crate::simgpu::kernel::KernelDesc;
use crate::simgpu::TenantId;
use crate::virt::TenantConfig;

use super::{MetricResult, RunConfig};

const TENANT: TenantId = 1;

fn api_for(cfg: &RunConfig) -> Api {
    let mut api = Api::with_backend(&cfg.system, cfg.seed);
    api.ctx_create(TENANT, TenantConfig::unlimited()).expect("ctx");
    api
}

/// ERR-001: error detection latency (ms): time from fault injection to the
/// first API call that observes it (polling every 10 µs, like a driver
/// watchdog loop).
pub fn err_001(cfg: &RunConfig) -> MetricResult {
    let mut col = crate::stats::Collector::new(1, cfg.iterations.min(30));
    for i in 0..1 + cfg.iterations.min(30) {
        let mut api = api_for(&RunConfig { seed: cfg.seed + i as u64, ..cfg.clone() });
        let t0 = api.now_ns();
        api.inject_fault(TENANT, GpuFault::IllegalAddress);
        loop {
            api.dev.clock.advance(10_000);
            if api.launch_kernel(TENANT, 0, &KernelDesc::null()).is_err() {
                break;
            }
            if api.now_ns() - t0 > 1_000_000_000 {
                break;
            }
        }
        col.record((api.now_ns() - t0) as f64 / 1e6);
    }
    MetricResult::from_samples("ERR-001", &cfg.system, col.samples())
}

/// ERR-002: recovery time (ms): from fault observation to a working
/// context. Context-level faults recover via destroy+create; device-level
/// (ECC) require a full reset.
pub fn err_002(cfg: &RunConfig) -> MetricResult {
    let mut col = crate::stats::Collector::new(1, cfg.iterations.min(20));
    for i in 0..1 + cfg.iterations.min(20) {
        let mut api = api_for(&RunConfig { seed: cfg.seed + 31 * i as u64, ..cfg.clone() });
        api.inject_fault(TENANT, GpuFault::IllegalAddress);
        api.dev.clock.advance(1_000_000);
        assert!(api.launch_kernel(TENANT, 0, &KernelDesc::null()).is_err());
        let t0 = api.now_ns();
        api.ctx_destroy(TENANT).unwrap();
        api.ctx_create(TENANT, TenantConfig::unlimited()).unwrap();
        assert!(api.launch_kernel(TENANT, 0, &KernelDesc::null()).is_ok());
        col.record((api.now_ns() - t0) as f64 / 1e6);
    }
    MetricResult::from_samples("ERR-002", &cfg.system, col.samples())
}

/// ERR-003: graceful degradation score (paper eq. 28), %. Exhausts memory
/// and scores: survived (0.4) + proper error code (0.3) + recovery after
/// freeing (0.3).
pub fn err_003(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    // Exhaust: allocate 1 GiB chunks until failure.
    let mut ptrs = Vec::new();
    let failure = loop {
        match api.mem_alloc(TENANT, 1 << 30) {
            Ok(p) => ptrs.push(p),
            Err(e) => break e,
        }
        if ptrs.len() > 100 {
            break crate::simgpu::error::GpuError::OutOfMemory;
        }
    };
    // (a) no crash: the process (simulation) is still here.
    let no_crash = true;
    // (b) a proper OOM-class error code was returned.
    let error_returned = matches!(
        failure,
        crate::simgpu::error::GpuError::OutOfMemory
            | crate::simgpu::error::GpuError::QuotaExceeded
    );
    // (c) recovery: freeing memory lets allocation succeed again.
    let recovered = if let Some(p) = ptrs.pop() {
        api.mem_free(TENANT, p).unwrap();
        api.mem_alloc(TENANT, 1 << 29).is_ok()
    } else {
        false
    };
    let score = 0.4 * no_crash as u8 as f64
        + 0.3 * error_returned as u8 as f64
        + 0.3 * recovered as u8 as f64;
    MetricResult::from_value("ERR-003", &cfg.system, score * 100.0)
}

/// Run the whole category in Table 8 order.
pub fn run_all(cfg: &RunConfig) -> Vec<MetricResult> {
    vec![err_001(cfg), err_002(cfg), err_003(cfg)]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(system: &str) -> RunConfig {
        RunConfig::quick(system)
    }

    #[test]
    fn err001_detection_in_expected_band() {
        let n = err_001(&quick("native")).value;
        // Illegal-address detection ≈ 35 µs base.
        assert!(n > 0.01 && n < 1.0, "detection={n} ms");
    }

    #[test]
    fn err002_recovery_dominated_by_ctx_cycle() {
        let n = err_002(&quick("native")).value;
        let h = err_002(&quick("hami")).value;
        // destroy (60µs) + create (125µs / 312µs).
        assert!(n > 0.15 && n < 0.3, "native recovery={n} ms");
        assert!(h > n, "hami={h} native={n}");
    }

    #[test]
    fn err003_full_marks_for_graceful_sim() {
        for sys in ["native", "hami", "fcsp", "mig"] {
            let s = err_003(&quick(sys)).value;
            assert_eq!(s, 100.0, "{sys} score={s}");
        }
    }
}
