//! NCCL/P2P communication metrics NCCL-001..004 (paper §3.7).
//!
//! Collectives ride the simulated interconnect
//! ([`crate::simgpu::nvlink::Topology`]), built per run from the cell's
//! `RunConfig::gpu_count` / `RunConfig::link` coordinates rather than a
//! fixed node — `gvbench sweep --gpus 2,4,8 --link nvlink,pcie` therefore
//! measures every collective on every topology cell. The defaults
//! (4 GPUs over PCIe) reproduce the paper's §7.1 testbed. Software
//! virtualization intercepts NCCL's internal kernel launches, so each
//! collective pays `hook × kernels_per_op` of added CPU time.

use crate::cudalite::{Api, CollectiveCtx};
use crate::simgpu::TenantId;
use crate::virt::TenantConfig;

use super::{MetricResult, RunConfig};

const TENANT: TenantId = 1;

fn collective_ctx(cfg: &RunConfig) -> (Api, CollectiveCtx) {
    let mut api = Api::with_backend(&cfg.system, cfg.seed);
    api.ctx_create(TENANT, TenantConfig::unlimited()).expect("ctx");
    // The cell's node: `gpu_count` ranks joined by `link` (default: the
    // paper's 4-GPU A100 PCIe testbed).
    let topo = cfg.node_topology(&api.dev.spec);
    api.virt.hook_overhead_ns(&mut api.dev); // warm (FCSP caches on first call)
    let hook = api.virt.hook_overhead_ns(&mut api.dev);
    let clock = api.dev.clock.clone();
    // Ring collectives launch ~2 kernels per rank per operation (a ring
    // needs at least 2 ranks, matching the topology's internal clamp).
    let ranks = cfg.gpu_count.max(2);
    let coll = CollectiveCtx::new(topo, clock).with_virt_overhead(hook, 2 * ranks);
    (api, coll)
}

/// NCCL-001: allreduce latency, µs (64 MiB buffer).
pub fn nccl_001(cfg: &RunConfig) -> MetricResult {
    let (_api, mut coll) = collective_ctx(cfg);
    let mut col = crate::stats::Collector::new(cfg.warmup, cfg.iterations);
    for _ in 0..cfg.warmup + cfg.iterations {
        col.record(coll.allreduce(64 << 20));
    }
    MetricResult::from_samples("NCCL-001", &cfg.system, col.samples())
}

/// NCCL-002: allgather achieved bandwidth, GB/s.
pub fn nccl_002(cfg: &RunConfig) -> MetricResult {
    let (_api, mut coll) = collective_ctx(cfg);
    let mut col = crate::stats::Collector::new(cfg.warmup, cfg.iterations);
    for _ in 0..cfg.warmup + cfg.iterations {
        col.record(coll.allgather(256 << 20));
    }
    MetricResult::from_samples("NCCL-002", &cfg.system, col.samples())
}

/// NCCL-003: P2P bandwidth, GB/s.
pub fn nccl_003(cfg: &RunConfig) -> MetricResult {
    let (_api, mut coll) = collective_ctx(cfg);
    let mut col = crate::stats::Collector::new(cfg.warmup, cfg.iterations);
    for _ in 0..cfg.warmup + cfg.iterations {
        col.record(coll.p2p(256 << 20));
    }
    MetricResult::from_samples("NCCL-003", &cfg.system, col.samples())
}

/// NCCL-004: broadcast bandwidth, GB/s.
pub fn nccl_004(cfg: &RunConfig) -> MetricResult {
    let (_api, mut coll) = collective_ctx(cfg);
    let mut col = crate::stats::Collector::new(cfg.warmup, cfg.iterations);
    for _ in 0..cfg.warmup + cfg.iterations {
        col.record(coll.broadcast(256 << 20));
    }
    MetricResult::from_samples("NCCL-004", &cfg.system, col.samples())
}

/// Run the whole category in Table 8 order.
pub fn run_all(cfg: &RunConfig) -> Vec<MetricResult> {
    vec![nccl_001(cfg), nccl_002(cfg), nccl_003(cfg), nccl_004(cfg)]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(system: &str) -> RunConfig {
        RunConfig::quick(system)
    }

    #[test]
    fn nccl001_hami_adds_latency() {
        let n = nccl_001(&quick("native")).value;
        let h = nccl_001(&quick("hami")).value;
        let f = nccl_001(&quick("fcsp")).value;
        assert!(h > n && f > n && f < h, "n={n} f={f} h={h}");
    }

    #[test]
    fn nccl002_bandwidth_below_link_peak() {
        let bw = nccl_002(&quick("native")).value;
        // Allgather moves (n-1)/n of the payload per rank over a 25 GB/s
        // link: achieved output bandwidth can reach ~n/(n-1)·link ≈ 33.
        assert!(bw > 15.0 && bw < 35.0, "allgather bw={bw}");
    }

    #[test]
    fn nccl003_p2p_near_link() {
        let bw = nccl_003(&quick("native")).value;
        assert!(bw > 22.0 && bw <= 25.2, "p2p bw={bw}");
    }

    #[test]
    fn nccl004_broadcast_sane() {
        let bw = nccl_004(&quick("native")).value;
        assert!(bw > 20.0 && bw <= 25.2, "broadcast bw={bw}");
    }

    #[test]
    fn nvlink_cell_outruns_pcie_cell() {
        use crate::simgpu::nvlink::LinkKind;
        let pcie = quick("native");
        let mut nvlink = quick("native");
        nvlink.link = LinkKind::NvLink;
        // P2P bandwidth on an NVLink node approaches NVLink3 (300 GB/s),
        // an order of magnitude over the PCIe node's ~25 GB/s.
        let bw_pcie = nccl_003(&pcie).value;
        let bw_nvlink = nccl_003(&nvlink).value;
        assert!(bw_nvlink > bw_pcie * 5.0, "pcie={bw_pcie} nvlink={bw_nvlink}");
        // Allreduce latency drops accordingly.
        assert!(nccl_001(&nvlink).value < nccl_001(&pcie).value);
    }

    #[test]
    fn gpu_count_scales_collective_latency() {
        let mut small = quick("native");
        small.gpu_count = 2;
        let mut large = quick("native");
        large.gpu_count = 8;
        // More ranks: more ring hops and more intercepted launches, so
        // allreduce latency grows with the node's GPU count.
        let t2 = nccl_001(&small).value;
        let t8 = nccl_001(&large).value;
        assert!(t8 > t2, "2-gpu={t2} 8-gpu={t8}");
    }
}
