//! Isolation metrics IS-001..IS-010 (paper §3.2, Table 5).
//!
//! The multi-tenant scenarios here are co-simulations: background tenants
//! are driven through the *same* virtualization layer as the victim, so the
//! differences the paper measures (HAMi's limiter overshoot hurting
//! neighbours, FCSP's WFQ restoring fairness) come out of the mechanisms,
//! not out of constants.

use crate::cudalite::Api;
use crate::simgpu::device::BackgroundLoad;
use crate::simgpu::error::{GpuError, GpuFault};
use crate::simgpu::kernel::KernelDesc;
use crate::simgpu::TenantId;
use crate::stats::{coefficient_of_variation, jain_fairness};
use crate::virt::TenantConfig;

use super::{MetricResult, RunConfig};

const VICTIM: TenantId = 1;

/// IS-001: memory-limit accuracy — probe the maximum allocatable total and
/// compare against the configured quota (paper eq. 6), in percent.
pub fn is_001(cfg: &RunConfig) -> MetricResult {
    let mut api = Api::with_backend(&cfg.system, cfg.seed);
    let quota = cfg.mem_limit;
    api.ctx_create(VICTIM, TenantConfig::unlimited().with_mem_limit(quota)).unwrap();
    // Allocate in 64 MiB chunks until the layer refuses.
    let chunk = 64 << 20;
    let mut total: u64 = 0;
    let mut ptrs = Vec::new();
    loop {
        match api.mem_alloc(VICTIM, chunk) {
            Ok(p) => {
                ptrs.push(p);
                total += chunk;
            }
            Err(_) => break,
        }
        if total > quota * 2 {
            break; // unlimited backend (native): cap the probe
        }
    }
    let accuracy = total.min(quota) as f64 / total.max(quota) as f64 * 100.0;
    MetricResult::from_value("IS-001", &cfg.system, accuracy)
}

/// IS-002: over-allocation detection latency, µs.
pub fn is_002(cfg: &RunConfig) -> MetricResult {
    let mut api = Api::with_backend(&cfg.system, cfg.seed);
    api.ctx_create(VICTIM, TenantConfig::unlimited().with_mem_limit(1 << 30)).unwrap();
    let mut col = crate::stats::Collector::new(cfg.warmup, cfg.iterations);
    for _ in 0..cfg.warmup + cfg.iterations {
        let t0 = api.now_ns();
        let r = api.mem_alloc(VICTIM, 4 << 30); // 4 GiB >> 1 GiB quota
        let dt = (api.now_ns() - t0) as f64 / 1e3;
        match r {
            Err(_) => col.record(dt),
            Ok(p) => {
                // Native: no quota → allocation succeeds; measure the
                // device's own OOM path instead by exhausting memory.
                api.mem_free(VICTIM, p).unwrap();
                col.record(dt);
            }
        }
    }
    MetricResult::from_samples("IS-002", &cfg.system, col.samples())
}

/// Drive a sustained serial kernel load for `sim_ns` of virtual time and
/// return achieved device utilization for the tenant.
fn drive_utilization(api: &mut Api, tenant: TenantId, kernel: &KernelDesc, sim_ns: u64) -> f64 {
    let start = api.now_ns();
    api.dev.sms.reset_window(start);
    while api.now_ns() - start < sim_ns {
        api.launch_kernel(tenant, 0, kernel).expect("launch");
        api.sync_stream(tenant, 0).unwrap();
    }
    api.dev.sms.utilization(tenant, api.now_ns())
}

/// IS-003: SM utilization accuracy at the configured limit (paper eq. 7),
/// in percent. Kernel duration (~7 ms) deliberately does not divide HAMi's
/// 100 ms window, exposing its quantized, debt-forgiving refill.
pub fn is_003(cfg: &RunConfig) -> MetricResult {
    let mut api = Api::with_backend(&cfg.system, cfg.seed);
    api.ctx_create(VICTIM, TenantConfig::unlimited().with_sm_limit(cfg.sm_limit)).unwrap();
    let kernel = KernelDesc::gemm(4096, 4096, 4096, false); // ≈7 ms
    let achieved = drive_utilization(&mut api, VICTIM, &kernel, 3_000_000_000);
    let target = api.virt.sm_limit(VICTIM);
    let accuracy = (1.0 - (target - achieved).abs() / target).clamp(0.0, 1.0) * 100.0;
    MetricResult::from_value("IS-003", &cfg.system, accuracy)
}

/// IS-004: latency for a dynamic SM-limit change to take effect, ms.
/// Measured as the time until a 100 ms rolling utilization window lands
/// within 20 % of the new target.
pub fn is_004(cfg: &RunConfig) -> MetricResult {
    let mut api = Api::with_backend(&cfg.system, cfg.seed);
    api.ctx_create(VICTIM, TenantConfig::unlimited().with_sm_limit(0.6)).unwrap();
    let kernel = KernelDesc::gemm(2048, 2048, 2048, false); // ≈0.9 ms
    // Reach steady state at 0.6.
    drive_utilization(&mut api, VICTIM, &kernel, 1_000_000_000);
    // Reconfigure to 0.3 and measure convergence.
    let online = api.virt.update_sm_limit(VICTIM, 0.3);
    if !online {
        // MIG/native: reconfiguration requires quiescing + re-registration
        // (MIG) or is unsupported (native). Model MIG reconfig as a
        // context drain + instance reprogram: reset + re-create.
        let t0 = api.now_ns();
        api.sync_device(VICTIM).unwrap();
        api.ctx_destroy(VICTIM).unwrap();
        api.ctx_create(VICTIM, TenantConfig::unlimited().with_sm_limit(0.3)).unwrap();
        let ms = (api.now_ns() - t0) as f64 / 1e6;
        return MetricResult::from_value("IS-004", &cfg.system, ms);
    }
    let t_change = api.now_ns();
    // Convergence judged on a τ = 200 ms exponentially-weighted moving
    // average of instantaneous utilization (HAMi's bang-bang oscillation
    // stays inside the ±25 % band only once the EWMA transient decays;
    // FCSP's paced launches settle within a few kernels).
    let tau = 200e6;
    let mut ewma = 0.6;
    let mut in_band = 0;
    loop {
        let t0 = api.now_ns();
        api.launch_kernel(VICTIM, 0, &kernel).expect("launch");
        api.sync_stream(VICTIM, 0).unwrap();
        let dt = (api.now_ns() - t0) as f64;
        let est = crate::simgpu::kernel::duration_ns(
            &api.dev.spec,
            &kernel,
            &crate::simgpu::kernel::ExecContext::uncontended(api.dev.spec.sm_count),
        );
        let inst = (est / dt).min(1.0);
        let alpha = (dt / tau).min(1.0);
        ewma += (inst - ewma) * alpha;
        if (ewma - 0.3).abs() / 0.3 < 0.25 {
            in_band += 1;
            if in_band >= 5 {
                break;
            }
        } else {
            in_band = 0;
        }
        if api.now_ns() - t_change > 3_000_000_000 {
            break; // cap at 3 s: never converged
        }
    }
    MetricResult::from_value("IS-004", &cfg.system, (api.now_ns() - t_change) as f64 / 1e6)
}

/// IS-005: cross-tenant memory isolation (boolean). Writes a pattern in
/// tenant A's allocation and checks tenant B can neither read it nor reach
/// the address without faulting its own context.
pub fn is_005(cfg: &RunConfig) -> MetricResult {
    let mut api = Api::with_backend(&cfg.system, cfg.seed);
    // Two tenants with 40 % shares (fits MIG's 7-slice geometry too).
    api.ctx_create(1, TenantConfig::unlimited().with_sm_limit(0.4)).unwrap();
    api.ctx_create(2, TenantConfig::unlimited().with_sm_limit(0.4)).unwrap();
    let p1 = api.mem_alloc(1, 1 << 20).unwrap();
    let owner_ok = api.try_read(1, p1).is_ok();
    let leak = api.try_read(2, p1).is_ok();
    // The probe must also not have crashed tenant 1.
    let victim_fine = api.launch_kernel(1, 0, &KernelDesc::null()).is_ok();
    MetricResult::from_pass("IS-005", &cfg.system, owner_ok && !leak && victim_fine)
}

/// Measured achievable duty cycle of a background tenant under its own
/// limiter — HAMi's overshoot shows up here.
fn background_duty(cfg: &RunConfig) -> f64 {
    let mut api = Api::with_backend(&cfg.system, cfg.seed ^ 0x9E37);
    api.ctx_create(9, TenantConfig::unlimited().with_sm_limit(cfg.sm_limit)).unwrap();
    let kernel = KernelDesc::gemm(4096, 4096, 4096, false);
    drive_utilization(&mut api, 9, &kernel, 2_000_000_000)
}

/// Victim inference-step time. `active_neighbors` kernels are resident
/// right now, each demanding `demand_each` of HBM bandwidth; resident
/// neighbours also space-share SMs with the victim.
fn victim_step_ns(api: &mut Api, active_neighbors: u32, demand_each: f64) -> f64 {
    api.dev.clear_background();
    for t in 0..active_neighbors {
        api.dev.set_background(
            90 + t,
            BackgroundLoad { membw_demand: demand_each, resident_kernels: 1 },
        );
    }
    // 50 % compute-bound + 50 % memory-bound step (inference mix).
    let compute = KernelDesc::gemm(2048, 2048, 2048, false);
    let stream = KernelDesc::streaming(1.4e9);
    let t0 = api.now_ns();
    api.launch_kernel(VICTIM, 0, &compute).expect("launch");
    api.launch_kernel(VICTIM, 0, &stream).expect("launch");
    api.sync_device(VICTIM).unwrap();
    api.dev.clear_background();
    (api.now_ns() - t0) as f64
}

/// Effective overlap duty of a neighbour: its limiter-achieved duty,
/// reduced when the backend fair-schedules (WFQ interleaves cross-tenant
/// submissions instead of letting bursts stack on the victim).
fn effective_duty(api: &Api, duty: f64) -> f64 {
    if api.virt.fair_scheduler() {
        duty * 0.55
    } else {
        duty
    }
}

/// IS-006: compute interference ratio `perf_contended / perf_solo`
/// (paper eq. 8), clamped to [0, 1].
pub fn is_006(cfg: &RunConfig) -> MetricResult {
    let mut api = Api::with_backend(&cfg.system, cfg.seed);
    // The victim itself is unthrottled: the metric isolates *neighbour*
    // interference, not the victim's own limiter.
    api.ctx_create(VICTIM, TenantConfig::unlimited()).unwrap();
    let solo = victim_step_ns(&mut api, 0, 0.0);
    let ratio = if api.virt.hardware_isolated() {
        // Dedicated SM/L2/bandwidth slices: neighbours cannot interfere.
        1.0
    } else {
        // n-1 compute-mix neighbours, each resident with probability equal
        // to its limiter-achieved duty cycle; a GEMM mix demands ~35 % of
        // peak bandwidth while resident.
        let duty = effective_duty(&api, background_duty(cfg));
        let n = cfg.tenants.saturating_sub(1);
        let mut total_solo = 0.0;
        let mut total_cont = 0.0;
        for _ in 0..cfg.iterations.min(40).max(10) {
            let active = (0..n).filter(|_| api.dev.rng().chance(duty)).count() as u32;
            total_cont += victim_step_ns(&mut api, active, 0.35);
            total_solo += solo;
        }
        (total_solo / total_cont).clamp(0.0, 1.0)
    };
    MetricResult::from_value("IS-006", &cfg.system, ratio)
}

/// IS-007: QoS consistency — CV of victim step latency under bursty
/// contention (paper eq. 9).
pub fn is_007(cfg: &RunConfig) -> MetricResult {
    let mut api = Api::with_backend(&cfg.system, cfg.seed);
    api.ctx_create(VICTIM, TenantConfig::unlimited()).unwrap();
    let duty = if api.virt.hardware_isolated() {
        0.0
    } else {
        effective_duty(&api, background_duty(cfg))
    };
    let n = cfg.tenants.saturating_sub(1);
    let mut samples = Vec::with_capacity(cfg.iterations);
    for _ in 0..cfg.warmup + cfg.iterations {
        let active = (0..n).filter(|_| api.dev.rng().chance(duty)).count() as u32;
        samples.push(victim_step_ns(&mut api, active, 0.35));
    }
    let cv = coefficient_of_variation(&samples[cfg.warmup.min(samples.len())..]);
    MetricResult::from_value("IS-007", &cfg.system, cv)
}

/// IS-008: Jain fairness of achieved throughput across `cfg.tenants`
/// concurrent tenants with heterogeneous kernel sizes (paper eq. 10). The
/// device serves serially; arbitration is the backend's (`FIFO` for HAMi,
/// WFQ for FCSP); each tenant's admission is gated by its own limiter.
pub fn is_008(cfg: &RunConfig) -> MetricResult {
    let n = cfg.tenants.max(2);
    let mut api = Api::with_backend(&cfg.system, cfg.seed);
    // Heterogeneous workloads: different kernel shapes per tenant. Under
    // round-robin (native/HAMi) service time is proportional to kernel
    // size; WFQ (FCSP) equalizes by cost.
    let shapes = [
        KernelDesc::gemm(4096, 4096, 4096, false), // ≈7.0 ms
        KernelDesc::gemm(3072, 3072, 2048, false), // ≈2.0 ms
        KernelDesc::gemm(3072, 3072, 3072, false), // ≈3.0 ms
        KernelDesc::gemm(4096, 4096, 2944, false), // ≈5.1 ms
    ];
    for t in 0..n {
        api.ctx_create(t + 1, TenantConfig::unlimited().with_sm_limit(1.0 / n as f64))
            .unwrap();
    }
    if api.virt.hardware_isolated() {
        // MIG: tenants run on dedicated slices in parallel — throughput is
        // proportional to slices, which are equal → near-perfect fairness
        // up to slice rounding.
        let shares: Vec<f64> = (0..n).map(|t| api.virt.sm_limit(t + 1)).collect();
        return MetricResult::from_value("IS-008", &cfg.system, jain_fairness(&shares));
    }
    // Software: device-serial service. Every tenant is always backlogged;
    // each round the backend arbitrates among head-of-line requests whose
    // limiter admits them now.
    let mut served_flops = vec![0.0f64; n as usize];
    let horizon = 4_000_000_000u64; // 4 s of device time
    while api.now_ns() < horizon {
        let pending: Vec<(TenantId, KernelDesc)> = (0..n)
            .map(|t| (t + 1, shapes[(t as usize) % shapes.len()]))
            .collect();
        let pick = api.virt.arbitrate(&pending);
        let (tenant, kernel) = pending[pick];
        match api.launch_kernel(tenant, 0, &kernel) {
            Ok(_) => {
                api.sync_device(tenant).unwrap();
                served_flops[(tenant - 1) as usize] += kernel.flops;
            }
            Err(_) => break,
        }
    }
    let elapsed = api.now_ns() as f64;
    let throughputs: Vec<f64> = served_flops.iter().map(|f| f / elapsed).collect();
    MetricResult::from_value("IS-008", &cfg.system, jain_fairness(&throughputs))
}

/// IS-009: noisy-neighbour impact (paper eq. 11), percent. The aggressive
/// neighbour floods with large kernels; its achieved duty cycle (limiter
/// overshoot included) converts to bandwidth pressure on the victim.
pub fn is_009(cfg: &RunConfig) -> MetricResult {
    let mut api = Api::with_backend(&cfg.system, cfg.seed);
    api.ctx_create(VICTIM, TenantConfig::unlimited()).unwrap();
    let quiet = victim_step_ns(&mut api, 0, 0.0);
    let impact = if api.virt.hardware_isolated() {
        0.0
    } else {
        // One aggressive neighbour flooding memory-heavy kernels at its
        // nominal limit; its achieved duty (overshoot included) is the
        // probability the victim's step collides with a resident,
        // full-bandwidth-demand kernel.
        let duty = effective_duty(&api, background_duty(cfg));
        let mut total_noisy = 0.0;
        let mut total_quiet = 0.0;
        for _ in 0..cfg.iterations.min(40).max(10) {
            let active = api.dev.rng().chance(duty) as u32;
            total_noisy += victim_step_ns(&mut api, active, 1.0);
            total_quiet += quiet;
        }
        ((total_noisy - total_quiet) / total_noisy * 100.0).max(0.0)
    };
    MetricResult::from_value("IS-009", &cfg.system, impact)
}

/// IS-010: fault isolation (boolean): a fault in one container must not
/// affect the others.
pub fn is_010(cfg: &RunConfig) -> MetricResult {
    let mut api = Api::with_backend(&cfg.system, cfg.seed);
    api.ctx_create(1, TenantConfig::unlimited().with_sm_limit(0.4)).unwrap();
    api.ctx_create(2, TenantConfig::unlimited().with_sm_limit(0.4)).unwrap();
    api.inject_fault(1, GpuFault::IllegalAddress);
    api.dev.clock.advance(1_000_000); // let the fault mature
    let faulty_sees_error = matches!(
        api.launch_kernel(1, 0, &KernelDesc::null()),
        Err(GpuError::IllegalAddress)
    );
    let neighbor_fine = api.launch_kernel(2, 0, &KernelDesc::null()).is_ok()
        && api.mem_alloc(2, 1 << 20).is_ok();
    MetricResult::from_pass("IS-010", &cfg.system, faulty_sees_error && neighbor_fine)
}

/// Run the whole category in Table 8 order.
pub fn run_all(cfg: &RunConfig) -> Vec<MetricResult> {
    vec![
        is_001(cfg),
        is_002(cfg),
        is_003(cfg),
        is_004(cfg),
        is_005(cfg),
        is_006(cfg),
        is_007(cfg),
        is_008(cfg),
        is_009(cfg),
        is_010(cfg),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(system: &str) -> RunConfig {
        RunConfig::quick(system)
    }

    #[test]
    fn is001_accuracy_ordering() {
        let h = is_001(&quick("hami")).value;
        let f = is_001(&quick("fcsp")).value;
        let m = is_001(&quick("mig")).value;
        // Table 5: HAMi 98.2, FCSP 99.1.
        assert!((h - 98.2) < 1.2 && h > 96.5, "hami={h}");
        assert!(f > h, "fcsp={f} hami={h}");
        assert!(m > 99.0, "mig={m}");
    }

    #[test]
    fn is002_software_rejection_fast() {
        let h = is_002(&quick("hami")).value;
        let n = is_002(&quick("native")).value;
        // Software quota rejection happens before the driver allocation.
        assert!(h < n, "hami={h} native={n}");
    }

    #[test]
    fn is003_accuracy_band() {
        let h = is_003(&quick("hami")).value;
        let f = is_003(&quick("fcsp")).value;
        let m = is_003(&quick("mig")).value;
        // Paper §8: software SM limiting 85–93 %.
        assert!(h > 75.0 && h < 97.0, "hami={h}");
        assert!(f > h, "fcsp={f} hami={h}");
        assert!(m > 93.0, "mig={m}");
    }

    #[test]
    fn is005_and_is010_pass_everywhere() {
        for sys in ["native", "hami", "fcsp", "mig"] {
            assert_eq!(is_005(&quick(sys)).pass, Some(true), "{sys} IS-005");
            assert_eq!(is_010(&quick(sys)).pass, Some(true), "{sys} IS-010");
        }
    }

    #[test]
    fn is006_mig_perfect() {
        assert!((is_006(&quick("mig")).value - 1.0).abs() < 1e-9);
        let h = is_006(&quick("hami")).value;
        assert!(h < 1.0 && h > 0.4, "hami={h}");
    }

    #[test]
    fn is008_fcsp_fairer_than_hami() {
        let h = is_008(&quick("hami")).value;
        let f = is_008(&quick("fcsp")).value;
        let m = is_008(&quick("mig")).value;
        assert!(f > h, "fcsp={f} hami={h}");
        assert!(h > 0.6, "hami={h}");
        assert!(m > 0.99, "mig={m}");
    }

    #[test]
    fn is009_ordering_matches_table5() {
        let h = is_009(&quick("hami")).value;
        let f = is_009(&quick("fcsp")).value;
        let m = is_009(&quick("mig")).value;
        assert_eq!(m, 0.0);
        assert!(f < h, "fcsp={f} hami={h}");
        assert!(h > 5.0 && h < 45.0, "hami={h}");
    }

    #[test]
    fn is004_fcsp_reacts_faster_than_hami() {
        let h = is_004(&quick("hami")).value;
        let f = is_004(&quick("fcsp")).value;
        assert!(f < h, "fcsp={f}ms hami={h}ms");
    }
}
