//! L2 cache isolation metrics CACHE-001..004 (paper §3.5).
//!
//! Measured by replaying tenant access streams through the set-associative
//! L2 model. MIG way-partitions the cache; software backends share it —
//! the hit-rate / eviction differences are the replacement policy's doing.

use crate::cudalite::Api;
use crate::simgpu::kernel::{duration_ns, ExecContext, KernelDesc};
use crate::simgpu::TenantId;
use crate::virt::TenantConfig;

use super::{MetricResult, RunConfig};

const VICTIM: TenantId = 1;

fn api_for(cfg: &RunConfig) -> Api {
    let mut api = Api::with_backend(&cfg.system, cfg.seed);
    for t in 1..=cfg.tenants.max(2) {
        // MIG carves L2 ways per tenant at registration.
        api.ctx_create(t, TenantConfig::unlimited().with_sm_limit(1.0 / cfg.tenants.max(2) as f64))
            .expect("ctx");
    }
    api
}

/// Replay: victim works over a working set that fits its fair share of L2;
/// neighbours stream over large buffers (the cache-hostile pattern).
fn run_replay(api: &mut Api, cfg: &RunConfig, rounds: usize) {
    // Victim working set sized to fit even a 1-slice MIG partition
    // (~2/16 of L2): the test probes *cross-tenant* pressure, not the
    // victim's own capacity.
    let ws = api.dev.spec.l2_bytes / 12;
    // Neighbour pressure is bursty: per-round stream sizes straddle the
    // LRU eviction threshold (≈ ways·sets·line / round), so the victim's
    // hit rate lands between the all-hit and all-miss extremes — as real
    // mixed workloads do.
    let mean_stream = api.dev.spec.l2_bytes as f64 / 3.2;
    api.dev.l2.access_range(VICTIM, 0, ws);
    api.dev.l2.reset_stats();
    let mut rng = api.dev.rng().fork();
    for r in 0..rounds {
        // Victim touches its set...
        api.dev.l2.access_range(VICTIM, 0, ws);
        // ...while each neighbour streams fresh gigabyte-spaced regions
        // (cache-hostile: never re-touches a line).
        for t in 2..=cfg.tenants.max(2) {
            let stream = (mean_stream * rng.f64_range(0.2, 1.8)) as u64;
            let base = ((t as u64) << 34) | (r as u64 * (1u64 << 28));
            api.dev.l2.access_range(t, base, stream);
        }
    }
}

/// CACHE-001: victim L2 hit rate under multi-tenant load (paper eq. 25), %.
pub fn cache_001(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    run_replay(&mut api, cfg, 12);
    let hit = api.dev.l2.stats(VICTIM).hit_rate() * 100.0;
    MetricResult::from_value("CACHE-001", &cfg.system, hit)
}

/// CACHE-002: fraction of victim evictions caused by other tenants, %.
pub fn cache_002(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    run_replay(&mut api, cfg, 12);
    let rate = api.dev.l2.stats(VICTIM).cross_eviction_rate() * 100.0;
    MetricResult::from_value("CACHE-002", &cfg.system, rate)
}

/// CACHE-003: performance drop from working-set collision, %: kernel
/// duration with the multi-tenant hit rate vs the solo hit rate.
pub fn cache_003(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    let ws = api.dev.spec.l2_bytes / 12;
    // Solo hit rate.
    api.dev.l2.access_range(VICTIM, 0, ws);
    api.dev.l2.reset_stats();
    api.dev.l2.access_range(VICTIM, 0, ws);
    let hit_solo = api.dev.l2.stats(VICTIM).hit_rate();
    // Contended hit rate.
    api.dev.l2.reset_stats();
    run_replay(&mut api, cfg, 12);
    let hit_cont = api.dev.l2.stats(VICTIM).hit_rate();
    // Translate hit rates into kernel time via the roofline model.
    let kernel = KernelDesc::streaming(ws as f64 * 16.0);
    let spec = &api.dev.spec;
    let t_solo = duration_ns(spec, &kernel, &ExecContext { sms: spec.sm_count, l2_hit_rate: hit_solo, bw_share: 1.0 });
    let t_cont = duration_ns(spec, &kernel, &ExecContext { sms: spec.sm_count, l2_hit_rate: hit_cont, bw_share: 1.0 });
    let drop = ((t_cont - t_solo) / t_solo * 100.0).max(0.0);
    MetricResult::from_value("CACHE-003", &cfg.system, drop)
}

/// CACHE-004: added latency from L2 contention, %: like CACHE-003 but for
/// a latency-sensitive small kernel repeatedly touching a hot buffer.
pub fn cache_004(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    let hot = api.dev.spec.l2_bytes / 16;
    api.dev.l2.access_range(VICTIM, 0, hot);
    api.dev.l2.reset_stats();
    api.dev.l2.access_range(VICTIM, 0, hot);
    let hit_solo = api.dev.l2.stats(VICTIM).hit_rate();
    // Neighbours blast the cache between victim touches.
    api.dev.l2.reset_stats();
    for r in 0..10u64 {
        for t in 2..=cfg.tenants.max(2) {
            api.dev.l2.access_range(t, (t as u64) << 32 | (r * 64 << 20), 8 << 20);
        }
        api.dev.l2.access_range(VICTIM, 0, hot);
    }
    let hit_cont = api.dev.l2.stats(VICTIM).hit_rate();
    let kernel = KernelDesc::streaming(hot as f64 * 4.0);
    let spec = &api.dev.spec;
    let t_solo = duration_ns(spec, &kernel, &ExecContext { sms: spec.sm_count, l2_hit_rate: hit_solo, bw_share: 1.0 });
    let t_cont = duration_ns(spec, &kernel, &ExecContext { sms: spec.sm_count, l2_hit_rate: hit_cont, bw_share: 1.0 });
    let overhead = ((t_cont - t_solo) / t_solo * 100.0).max(0.0);
    MetricResult::from_value("CACHE-004", &cfg.system, overhead)
}

/// Run the whole category in Table 8 order.
pub fn run_all(cfg: &RunConfig) -> Vec<MetricResult> {
    vec![cache_001(cfg), cache_002(cfg), cache_003(cfg), cache_004(cfg)]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(system: &str) -> RunConfig {
        RunConfig::quick(system)
    }

    #[test]
    fn cache001_mig_retains_hits_under_load() {
        let m = cache_001(&quick("mig")).value;
        let h = cache_001(&quick("hami")).value;
        assert!(m > h, "mig={m}% hami={h}%");
        assert!(m > 90.0, "mig={m}%");
    }

    #[test]
    fn cache002_no_cross_eviction_under_mig() {
        assert_eq!(cache_002(&quick("mig")).value, 0.0);
        let h = cache_002(&quick("hami")).value;
        assert!(h > 10.0, "hami cross-eviction={h}%");
    }

    #[test]
    fn cache003_collision_hurts_shared_cache() {
        let m = cache_003(&quick("mig")).value;
        let h = cache_003(&quick("hami")).value;
        assert!(h > m, "hami={h}% mig={m}%");
    }

    #[test]
    fn cache004_contention_overhead_positive_shared() {
        let h = cache_004(&quick("hami")).value;
        let m = cache_004(&quick("mig")).value;
        assert!(h >= m, "hami={h}% mig={m}%");
    }
}
