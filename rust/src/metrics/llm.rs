//! LLM metrics LLM-001..LLM-010 (paper §3.3, Table 6).
//!
//! These drive transformer-shaped workloads through the virtualized API —
//! the same synthetic-kernel approach the paper uses (§7.5 explicitly uses
//! custom kernels, not PyTorch). The **real** attention numerics run in the
//! three-layer path (`runtime::llm` loads the AOT-compiled JAX/Pallas HLO
//! and executes it via PJRT) — see `examples/multi_tenant_llm.rs` and the
//! Table 6 bench, which report both.

use crate::cudalite::Api;
use crate::simgpu::kernel::KernelDesc;
use crate::simgpu::nvlink::Topology;
use crate::simgpu::stream::StreamPriority;
use crate::simgpu::TenantId;
use crate::virt::TenantConfig;

use super::{MetricResult, RunConfig};

const TENANT: TenantId = 1;

/// Model shape used across the LLM metrics (a ~7B-class decoder layer,
/// scaled to keep sim time reasonable).
pub const BATCH: u64 = 8;
pub const SEQ: u64 = 1024;
pub const HEAD_DIM: u64 = 64;

fn api_for(cfg: &RunConfig) -> Api {
    let mut api = Api::with_backend(&cfg.system, cfg.seed);
    // Memory quota configured (a realistic deployment) but no SM throttle:
    // the LLM category isolates allocation/launch-path overheads, matching
    // the paper's single-tenant LLM runs (§7.5).
    api.ctx_create(TENANT, TenantConfig::unlimited().with_mem_limit(20 << 30)).expect("ctx");
    api
}

/// LLM-001: attention kernel throughput as TFLOPS via the paper's proxy
/// (eq. 12): `2·B·S²·D / t`. Faithful to Listing 6: each iteration
/// allocates Q, K, V (and the output) through the virtualized
/// `cuMemAlloc`, runs the kernel, and frees — LLM serving reallocates
/// per-request buffers constantly, which is exactly where interception
/// overhead bites (the paper's §8 "LLM workloads are sensitive to memory
/// allocation overhead").
pub fn llm_001(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    let (b, s, d) = (2 * BATCH, 2 * SEQ, HEAD_DIM);
    let kernel = KernelDesc::attention(b, s, d, false);
    let buf = b * s * d * 4; // f32
    let mut col = crate::stats::Collector::new(cfg.warmup, cfg.iterations);
    for _ in 0..cfg.warmup + cfg.iterations {
        let t0 = api.now_ns();
        let q = api.mem_alloc(TENANT, buf).expect("q");
        let k = api.mem_alloc(TENANT, buf).expect("k");
        let v = api.mem_alloc(TENANT, buf).expect("v");
        let o = api.mem_alloc(TENANT, buf).expect("o");
        api.launch_kernel(TENANT, 0, &kernel).expect("launch");
        api.sync_device(TENANT).unwrap();
        for p in [q, k, v, o] {
            api.mem_free(TENANT, p).unwrap();
        }
        let t_ns = (api.now_ns() - t0) as f64;
        let proxy_flops = 2.0 * (b * s * s * d) as f64;
        col.record(proxy_flops / (t_ns / 1e9) / 1e12);
    }
    MetricResult::from_samples("LLM-001", &cfg.system, col.samples())
}

/// LLM-002: KV-cache allocation speed — allocations/second of growing
/// per-token cache blocks (paper eq. 13).
pub fn llm_002(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    // Per-token KV block ≈ 2 MiB; each growth step also runs the decode
    // compute that fills it (~150 M-param layer group) — allocation rate
    // in context, as a serving engine experiences it.
    let block = 2 << 20;
    let work = KernelDesc {
        flops: 2.0 * 80e6 * BATCH as f64,
        bytes: 80e6 * 2.0,
        half_precision: true,
        occupancy: 1.0,
    };
    let n = (cfg.iterations * 4).max(100);
    let t0 = api.now_ns();
    let mut ptrs = Vec::with_capacity(n);
    for _ in 0..n {
        ptrs.push(api.mem_alloc(TENANT, block).expect("alloc"));
        api.launch_kernel(TENANT, 0, &work).expect("launch");
        api.sync_device(TENANT).unwrap();
    }
    let dt_s = (api.now_ns() - t0) as f64 / 1e9;
    for p in ptrs {
        api.mem_free(TENANT, p).unwrap();
    }
    MetricResult::from_value("LLM-002", &cfg.system, n as f64 / dt_s)
}

/// Transformer depth used by the decode/prefill loops (7B-class model).
pub const LAYERS: u64 = 32;

/// Per-layer decode kernel: weight-read bound at low batch (the classic
/// LLM decode regime), compute grows with batch. ~200 M params per layer
/// ⇒ ≈0.26 ms/layer memory-bound on an A100.
fn decode_kernel(batch: u64) -> KernelDesc {
    let params = 200_000_000u64;
    KernelDesc {
        flops: 2.0 * params as f64 * batch as f64,
        bytes: params as f64 * 2.0, // bf16 weights read once per step
        half_precision: true,
        occupancy: 1.0,
    }
}

/// Time one full decode token: per layer, allocate the K and V cache
/// blocks for the new token (the growth pattern LLM-002 isolates), then
/// run the layer kernel. This is where virtualized alloc overhead bites
/// every single token (paper §8).
fn decode_step_ns(api: &mut Api, batch: u64) -> f64 {
    let t0 = api.now_ns();
    let mut blocks = Vec::with_capacity(2 * LAYERS as usize);
    for _ in 0..LAYERS {
        blocks.push(api.mem_alloc(TENANT, 128 * 1024 * batch).expect("k"));
        blocks.push(api.mem_alloc(TENANT, 128 * 1024 * batch).expect("v"));
        api.launch_kernel(TENANT, 0, &decode_kernel(batch)).expect("launch");
    }
    api.sync_device(TENANT).unwrap();
    let dt = (api.now_ns() - t0) as f64;
    for b in blocks {
        api.mem_free(TENANT, b).unwrap();
    }
    dt
}

/// LLM-003: batch-size scaling `thr(N) / (N · thr(1))` (paper eq. 14).
pub fn llm_003(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    let reps = cfg.iterations.max(20);
    let mut mean_step = |b: u64| -> f64 {
        let mut total = 0.0;
        for _ in 0..reps {
            total += decode_step_ns(&mut api, b);
        }
        total / reps as f64
    };
    let t1 = mean_step(1);
    let t8 = mean_step(8);
    // thr(N)/(N·thr(1)) = (N/t_N) / (N · 1/t_1) = t_1/t_N.
    let scaling = t1 / t8;
    MetricResult::from_value("LLM-003", &cfg.system, scaling)
}

/// LLM-004: token generation latency — reported value is TTFT in ms
/// (eq. 15); the sample distribution carries the ITLs (eq. 16).
pub fn llm_004(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    // Per-layer prefill attention over the prompt, with the layer's K and
    // V prompt-cache allocations (the real prefill memory pattern).
    let prefill_layer = KernelDesc::attention(BATCH, SEQ, HEAD_DIM, true);
    let decode_tokens = 8;
    let mut ttfts = Vec::new();
    let mut itls = Vec::new();
    for _ in 0..(cfg.iterations / 8).max(3) {
        let t_req = api.now_ns();
        let mut kv = Vec::with_capacity(2 * LAYERS as usize);
        for _ in 0..LAYERS {
            kv.push(api.mem_alloc(TENANT, 2 << 20).expect("k"));
            kv.push(api.mem_alloc(TENANT, 2 << 20).expect("v"));
            api.launch_kernel(TENANT, 0, &prefill_layer).expect("prefill");
        }
        api.sync_device(TENANT).unwrap();
        ttfts.push((api.now_ns() - t_req) as f64 / 1e6);
        // Decode loop.
        let mut last = api.now_ns();
        for _ in 0..decode_tokens {
            decode_step_ns(&mut api, BATCH);
            let now = api.now_ns();
            itls.push((now - last) as f64 / 1e6);
            last = now;
        }
        for p in kv {
            api.mem_free(TENANT, p).unwrap();
        }
    }
    let mut r = MetricResult::from_samples("LLM-004", &cfg.system, &ttfts);
    r.value = crate::stats::Summary::from_samples(&ttfts).mean;
    r
}

/// Companion to [`llm_004`]: mean inter-token latency in ms (Table 6's
/// second LLM-004 row).
pub fn llm_004_itl(cfg: &RunConfig) -> f64 {
    let mut api = api_for(cfg);
    let mut itls = Vec::new();
    for _ in 0..(cfg.iterations / 2).max(10) {
        itls.push(decode_step_ns(&mut api, BATCH) / 1e6);
    }
    crate::stats::Summary::from_samples(&itls).mean
}

/// LLM-005: memory-pool efficiency (paper eq. 17): pool-based allocation
/// overhead vs direct allocation, percent (negative = pool is faster,
/// which is the point of pooling under virtualization).
pub fn llm_005(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    let block = 2 << 20;
    let reps = cfg.iterations.max(50);
    // Direct: alloc/free per step.
    let t0 = api.now_ns();
    for _ in 0..reps {
        let p = api.mem_alloc(TENANT, block).expect("alloc");
        api.mem_free(TENANT, p).unwrap();
    }
    let t_direct = (api.now_ns() - t0) as f64 / reps as f64;
    // Pool: allocate once, reuse (one quota interaction, zero per-step).
    let pool: Vec<u64> = (0..8).map(|_| api.mem_alloc(TENANT, block).expect("pool")).collect();
    let t0 = api.now_ns();
    for i in 0..reps {
        // Pop/push from the pool: constant-time, no driver call.
        let _slot = pool[i % pool.len()];
        api.dev.clock.advance(120); // free-list pop + bookkeeping
    }
    let t_pool = (api.now_ns() - t0) as f64 / reps as f64;
    for p in pool {
        api.mem_free(TENANT, p).unwrap();
    }
    let overhead = (t_pool - t_direct) / t_direct * 100.0;
    MetricResult::from_value("LLM-005", &cfg.system, overhead)
}

/// LLM-006: multi-stream pipeline efficiency (paper eq. 18), percent.
pub fn llm_006(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    let streams = 4u64;
    let kernel = KernelDesc::gemm(1536, 1536, 1536, true);
    let reps = cfg.iterations.max(20) as u64;
    // Single stream.
    let t0 = api.now_ns();
    for _ in 0..reps {
        api.launch_kernel(TENANT, 0, &kernel).expect("launch");
    }
    api.sync_device(TENANT).unwrap();
    let t_single = (api.now_ns() - t0) as f64;
    let thr_single = reps as f64 / t_single;
    // Multi-stream: same total work split across streams. The device
    // space-shares SMs between concurrently resident kernels, so ideal
    // overlap gains nothing on a saturated GPU — what multi-stream buys is
    // hiding the *launch overhead*, which is exactly where virtualization
    // hurts.
    let ids: Vec<u32> = (0..streams).map(|_| api.stream_create(StreamPriority::Normal)).collect();
    let t0 = api.now_ns();
    for i in 0..reps {
        let s = ids[(i % streams) as usize];
        api.launch_kernel(TENANT, s, &kernel).expect("launch");
    }
    api.sync_device(TENANT).unwrap();
    let t_multi = (api.now_ns() - t0) as f64;
    let thr_multi = reps as f64 / t_multi;
    // eq. 18 normalizes by stream count for *pipeline* stages; for a
    // saturated single device the attainable ideal is 1.0× total
    // throughput, so we report thr_multi/thr_single as the efficiency.
    let eff = (thr_multi / thr_single * 100.0).min(120.0);
    MetricResult::from_value("LLM-006", &cfg.system, eff)
}

/// LLM-007: large contiguous allocation latency (>1 GiB) under a
/// fragmented heap, ms (paper eq. 19).
pub fn llm_007(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    // Fragment the heap: many medium allocations, free every other.
    let mut ptrs = Vec::new();
    for _ in 0..256 {
        ptrs.push(api.mem_alloc(TENANT, 32 << 20).expect("frag"));
    }
    for (i, p) in ptrs.iter().enumerate() {
        if i % 2 == 0 {
            api.mem_free(TENANT, *p).unwrap();
        }
    }
    let mut col = crate::stats::Collector::new(2, cfg.iterations.min(30));
    for _ in 0..2 + cfg.iterations.min(30) {
        let t0 = api.now_ns();
        let p = api.mem_alloc(TENANT, 1 << 30).expect("large");
        col.record((api.now_ns() - t0) as f64 / 1e6);
        api.mem_free(TENANT, p).unwrap();
    }
    MetricResult::from_samples("LLM-007", &cfg.system, col.samples())
}

/// LLM-008: FP16/BF16 vs FP32 throughput ratio (paper eq. 20).
pub fn llm_008(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    let reps = cfg.iterations.max(20);
    let mut mean_ns = |half: bool| -> f64 {
        let kernel = KernelDesc::gemm(4096, 4096, 1024, half);
        let mut total = 0.0;
        for _ in 0..reps {
            let t0 = api.now_ns();
            api.launch_kernel(TENANT, 0, &kernel).expect("launch");
            api.sync_device(TENANT).unwrap();
            total += (api.now_ns() - t0) as f64;
        }
        total / reps as f64
    };
    let t32 = mean_ns(false);
    let t16 = mean_ns(true);
    MetricResult::from_value("LLM-008", &cfg.system, t32 / t16)
}

/// LLM-009: dynamic-batching latency variance (paper eq. 21) — variance of
/// per-step latency (ms²) across random batch sizes 1..=16.
pub fn llm_009(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    let mut samples = Vec::new();
    for _ in 0..cfg.iterations.max(40) {
        let b = api.dev.rng().range(1, 17) as u64;
        samples.push(decode_step_ns(&mut api, b) / 1e6);
    }
    let s = crate::stats::Summary::from_samples(&samples);
    MetricResult::from_value("LLM-009", &cfg.system, s.stddev * s.stddev)
}

/// LLM-010: tensor-parallel scaling across 4 GPUs (paper eq. 22):
/// per-layer partial GEMM + allreduce, `thr_N / (N · thr_1)`.
pub fn llm_010(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    let n_gpus = 4u32;
    // Tensor parallelism is only deployed on NVLink-connected nodes (the
    // PCIe testbed of §7 is single-GPU); model an A100-SXM sibling.
    let topo = Topology::nvlink_node(n_gpus, 300.0);
    api.virt.hook_overhead_ns(&mut api.dev); // warm the hook cache
    let hook = api.virt.hook_overhead_ns(&mut api.dev);
    let mut coll = crate::cudalite::CollectiveCtx::new(topo, api.dev.clock.clone())
        .with_virt_overhead(hook, 2 * n_gpus);
    let reps = cfg.iterations.max(10) as u64;
    // Single GPU: a full transformer layer's GEMM work (QKV + out-proj +
    // two MLP mats ≈ one 4096x4096x49152 contraction).
    let full = KernelDesc::gemm(4096, 4096, 49152, true);
    let t0 = api.now_ns();
    for _ in 0..reps {
        api.launch_kernel(TENANT, 0, &full).expect("launch");
        api.sync_device(TENANT).unwrap();
    }
    let t1 = (api.now_ns() - t0) as f64;
    // 4-way TP: each rank runs a quarter GEMM, then allreduce of the
    // activations (4096·4096·2 bytes bf16).
    let part = KernelDesc::gemm(4096, 4096, 49152 / n_gpus as u64, true);
    let t0 = api.now_ns();
    for _ in 0..reps {
        api.launch_kernel(TENANT, 0, &part).expect("launch");
        api.sync_device(TENANT).unwrap();
        coll.allreduce(4096 * 4096 * 2);
    }
    let tn = (api.now_ns() - t0) as f64;
    // Paper eq. 22: thr_N / (N · thr_1). Speedup = t1/tn; efficiency =
    // speedup / N.
    let efficiency = (t1 / tn) / n_gpus as f64;
    MetricResult::from_value("LLM-010", &cfg.system, efficiency)
}

/// Run the whole category in Table 8 order.
pub fn run_all(cfg: &RunConfig) -> Vec<MetricResult> {
    vec![
        llm_001(cfg),
        llm_002(cfg),
        llm_003(cfg),
        llm_004(cfg),
        llm_005(cfg),
        llm_006(cfg),
        llm_007(cfg),
        llm_008(cfg),
        llm_009(cfg),
        llm_010(cfg),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(system: &str) -> RunConfig {
        RunConfig::quick(system)
    }

    #[test]
    fn llm001_relative_to_native_matches_table6() {
        let n = llm_001(&quick("native")).value;
        let h = llm_001(&quick("hami")).value;
        let f = llm_001(&quick("fcsp")).value;
        let rh = h / n * 100.0;
        let rf = f / n * 100.0;
        // Table 6: HAMi 82.3 %, FCSP 91.5 % of native.
        assert!(rh < rf, "hami={rh}% fcsp={rf}%");
        assert!(rf <= 100.5, "fcsp={rf}%");
    }

    #[test]
    fn llm002_kv_alloc_ordering() {
        let n = llm_002(&quick("native")).value;
        let h = llm_002(&quick("hami")).value;
        let f = llm_002(&quick("fcsp")).value;
        assert!(h < f && f < n, "n={n} f={f} h={h}");
    }

    #[test]
    fn llm003_scaling_below_one_and_ordered() {
        let h = llm_003(&quick("hami")).value;
        let f = llm_003(&quick("fcsp")).value;
        assert!(h < f, "hami={h} fcsp={f}");
        assert!(h > 0.4 && f <= 1.01, "h={h} f={f}");
    }

    #[test]
    fn llm004_ttft_ordering() {
        let h = llm_004(&quick("hami")).value;
        let f = llm_004(&quick("fcsp")).value;
        assert!(f < h, "fcsp={f}ms hami={h}ms");
    }

    #[test]
    fn llm005_pool_beats_direct_under_virt() {
        let h = llm_005(&quick("hami")).value;
        // Pool avoids the interception-heavy alloc path → strongly negative.
        assert!(h < -50.0, "overhead={h}%");
    }

    #[test]
    fn llm008_mixed_precision_gain() {
        let r = llm_008(&quick("native")).value;
        assert!(r > 1.5, "fp16/fp32 ratio={r}");
    }

    #[test]
    fn llm010_tp_efficiency_sane() {
        let e = llm_010(&quick("native")).value;
        assert!(e > 0.3 && e <= 1.05, "tp efficiency={e}");
    }

    #[test]
    fn run_all_returns_ten() {
        let rs = run_all(&quick("native"));
        assert_eq!(rs.len(), 10);
    }
}
