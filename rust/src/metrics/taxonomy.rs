//! The complete 56-metric taxonomy — a direct transcription of the paper's
//! Table 8 (id, name, description, unit, direction) organized by category.

use super::{Category, Descriptor, Direction};

use Category as C;
use Direction as D;

/// All 56 metric descriptors, in Table 8 order.
pub const ALL: [Descriptor; 56] = [
    // --- Overhead (10) ---------------------------------------------------
    Descriptor { id: "OH-001", name: "Kernel Launch Latency", description: "Time from cuLaunchKernel to execution", unit: "µs", category: C::Overhead, direction: D::LowerBetter },
    Descriptor { id: "OH-002", name: "Memory Allocation Latency", description: "cuMemAlloc completion time", unit: "µs", category: C::Overhead, direction: D::LowerBetter },
    Descriptor { id: "OH-003", name: "Memory Free Latency", description: "cuMemFree completion time", unit: "µs", category: C::Overhead, direction: D::LowerBetter },
    Descriptor { id: "OH-004", name: "Context Creation Overhead", description: "Additional context creation time", unit: "µs", category: C::Overhead, direction: D::LowerBetter },
    Descriptor { id: "OH-005", name: "API Interception Overhead", description: "dlsym hook overhead per call", unit: "ns", category: C::Overhead, direction: D::LowerBetter },
    Descriptor { id: "OH-006", name: "Shared Region Lock Contention", description: "Semaphore wait time", unit: "µs", category: C::Overhead, direction: D::LowerBetter },
    Descriptor { id: "OH-007", name: "Memory Tracking Overhead", description: "Per-allocation accounting cost", unit: "ns", category: C::Overhead, direction: D::LowerBetter },
    Descriptor { id: "OH-008", name: "Rate Limiter Overhead", description: "Token bucket check latency", unit: "ns", category: C::Overhead, direction: D::LowerBetter },
    Descriptor { id: "OH-009", name: "NVML Polling Overhead", description: "CPU cycles in monitoring", unit: "%", category: C::Overhead, direction: D::LowerBetter },
    Descriptor { id: "OH-010", name: "Total Throughput Degradation", description: "End-to-end performance loss", unit: "%", category: C::Overhead, direction: D::LowerBetter },
    // --- Isolation (10) ---------------------------------------------------
    Descriptor { id: "IS-001", name: "Memory Limit Accuracy", description: "Actual vs configured limit", unit: "%", category: C::Isolation, direction: D::HigherBetter },
    Descriptor { id: "IS-002", name: "Memory Limit Enforcement", description: "Over-allocation detection time", unit: "µs", category: C::Isolation, direction: D::LowerBetter },
    Descriptor { id: "IS-003", name: "SM Utilization Accuracy", description: "Actual vs configured SM limit", unit: "%", category: C::Isolation, direction: D::HigherBetter },
    Descriptor { id: "IS-004", name: "SM Limit Response Time", description: "Utilization adjustment latency", unit: "ms", category: C::Isolation, direction: D::LowerBetter },
    Descriptor { id: "IS-005", name: "Cross-Tenant Memory Isolation", description: "Memory leak detection", unit: "bool", category: C::Isolation, direction: D::Boolean },
    Descriptor { id: "IS-006", name: "Cross-Tenant Compute Isolation", description: "Compute interference ratio", unit: "0-1", category: C::Isolation, direction: D::HigherBetter },
    Descriptor { id: "IS-007", name: "QoS Consistency", description: "Performance variance under contention", unit: "CV", category: C::Isolation, direction: D::LowerBetter },
    Descriptor { id: "IS-008", name: "Fairness Index", description: "Jain's fairness across tenants", unit: "0-1", category: C::Isolation, direction: D::HigherBetter },
    Descriptor { id: "IS-009", name: "Noisy Neighbor Impact", description: "Degradation from aggressive neighbor", unit: "%", category: C::Isolation, direction: D::LowerBetter },
    Descriptor { id: "IS-010", name: "Fault Isolation", description: "Error propagation prevention", unit: "bool", category: C::Isolation, direction: D::Boolean },
    // --- LLM (10) ----------------------------------------------------------
    Descriptor { id: "LLM-001", name: "Attention Kernel Throughput", description: "Transformer attention performance", unit: "TFLOPS", category: C::Llm, direction: D::HigherBetter },
    Descriptor { id: "LLM-002", name: "KV Cache Allocation Speed", description: "Dynamic cache growth handling", unit: "allocs/s", category: C::Llm, direction: D::HigherBetter },
    Descriptor { id: "LLM-003", name: "Batch Size Scaling", description: "Throughput vs batch size curve", unit: "ratio", category: C::Llm, direction: D::HigherBetter },
    Descriptor { id: "LLM-004", name: "Token Generation Latency", description: "TTFT and inter-token latency", unit: "ms", category: C::Llm, direction: D::LowerBetter },
    Descriptor { id: "LLM-005", name: "Memory Pool Efficiency", description: "Pool allocation overhead", unit: "%", category: C::Llm, direction: D::LowerBetter },
    Descriptor { id: "LLM-006", name: "Multi-Stream Performance", description: "Pipeline parallel efficiency", unit: "%", category: C::Llm, direction: D::HigherBetter },
    Descriptor { id: "LLM-007", name: "Large Tensor Allocation", description: "Large allocation handling", unit: "ms", category: C::Llm, direction: D::LowerBetter },
    Descriptor { id: "LLM-008", name: "Mixed Precision Support", description: "FP16/BF16 kernel ratio", unit: "ratio", category: C::Llm, direction: D::HigherBetter },
    Descriptor { id: "LLM-009", name: "Dynamic Batching Impact", description: "Variable batch handling", unit: "variance", category: C::Llm, direction: D::LowerBetter },
    Descriptor { id: "LLM-010", name: "Multi-GPU Scaling", description: "Tensor parallel efficiency", unit: "factor", category: C::Llm, direction: D::HigherBetter },
    // --- Memory Bandwidth (4) ----------------------------------------------
    Descriptor { id: "BW-001", name: "Memory Bandwidth Isolation", description: "Bandwidth under contention", unit: "%", category: C::MemoryBandwidth, direction: D::HigherBetter },
    Descriptor { id: "BW-002", name: "Bandwidth Fairness Index", description: "Jain's fairness for bandwidth", unit: "0-1", category: C::MemoryBandwidth, direction: D::HigherBetter },
    Descriptor { id: "BW-003", name: "Memory Bus Saturation Point", description: "Streams to reach 95% BW", unit: "count", category: C::MemoryBandwidth, direction: D::LowerBetter },
    Descriptor { id: "BW-004", name: "Bandwidth Interference Impact", description: "BW drop from competition", unit: "%", category: C::MemoryBandwidth, direction: D::LowerBetter },
    // --- Cache Isolation (4) -----------------------------------------------
    Descriptor { id: "CACHE-001", name: "L2 Cache Hit Rate", description: "Hit rate under multi-tenant load", unit: "%", category: C::CacheIsolation, direction: D::HigherBetter },
    Descriptor { id: "CACHE-002", name: "Cache Eviction Rate", description: "Evictions from other tenants", unit: "%", category: C::CacheIsolation, direction: D::LowerBetter },
    Descriptor { id: "CACHE-003", name: "Working Set Collision Impact", description: "Perf drop from cache overlap", unit: "%", category: C::CacheIsolation, direction: D::LowerBetter },
    Descriptor { id: "CACHE-004", name: "Cache Contention Overhead", description: "Latency from L2 contention", unit: "%", category: C::CacheIsolation, direction: D::LowerBetter },
    // --- PCIe (4) ------------------------------------------------------------
    Descriptor { id: "PCIE-001", name: "Host-to-Device Bandwidth", description: "H2D transfer rate", unit: "GB/s", category: C::Pcie, direction: D::HigherBetter },
    Descriptor { id: "PCIE-002", name: "Device-to-Host Bandwidth", description: "D2H transfer rate", unit: "GB/s", category: C::Pcie, direction: D::HigherBetter },
    Descriptor { id: "PCIE-003", name: "PCIe Contention Impact", description: "BW drop under multi-tenant", unit: "%", category: C::Pcie, direction: D::LowerBetter },
    Descriptor { id: "PCIE-004", name: "Pinned Memory Performance", description: "Pinned vs pageable ratio", unit: "ratio", category: C::Pcie, direction: D::HigherBetter },
    // --- NCCL/P2P (4) ----------------------------------------------------------
    Descriptor { id: "NCCL-001", name: "AllReduce Latency", description: "Collective allreduce time", unit: "µs", category: C::Nccl, direction: D::LowerBetter },
    Descriptor { id: "NCCL-002", name: "AllGather Bandwidth", description: "Allgather achieved bandwidth", unit: "GB/s", category: C::Nccl, direction: D::HigherBetter },
    Descriptor { id: "NCCL-003", name: "P2P GPU Bandwidth", description: "Direct GPU-to-GPU transfer", unit: "GB/s", category: C::Nccl, direction: D::HigherBetter },
    Descriptor { id: "NCCL-004", name: "Broadcast Bandwidth", description: "Broadcast collective bandwidth", unit: "GB/s", category: C::Nccl, direction: D::HigherBetter },
    // --- Scheduling (4) ----------------------------------------------------------
    Descriptor { id: "SCHED-001", name: "Context Switch Latency", description: "CUDA context switch time", unit: "µs", category: C::Scheduling, direction: D::LowerBetter },
    Descriptor { id: "SCHED-002", name: "Kernel Launch Overhead", description: "Minimal kernel launch time", unit: "µs", category: C::Scheduling, direction: D::LowerBetter },
    Descriptor { id: "SCHED-003", name: "Stream Concurrency Efficiency", description: "Concurrent stream efficiency", unit: "%", category: C::Scheduling, direction: D::HigherBetter },
    Descriptor { id: "SCHED-004", name: "Preemption Latency", description: "High-priority preemption delay", unit: "ms", category: C::Scheduling, direction: D::LowerBetter },
    // --- Fragmentation (3) ----------------------------------------------------------
    Descriptor { id: "FRAG-001", name: "Fragmentation Index", description: "Memory fragmentation level", unit: "%", category: C::Fragmentation, direction: D::LowerBetter },
    Descriptor { id: "FRAG-002", name: "Allocation Latency Degradation", description: "Latency increase with fragmentation", unit: "%", category: C::Fragmentation, direction: D::LowerBetter },
    Descriptor { id: "FRAG-003", name: "Memory Compaction Efficiency", description: "Memory reclaimed after defrag", unit: "%", category: C::Fragmentation, direction: D::HigherBetter },
    // --- Error Recovery (3) ----------------------------------------------------------
    Descriptor { id: "ERR-001", name: "Error Detection Latency", description: "Time to detect CUDA errors", unit: "ms", category: C::ErrorRecovery, direction: D::LowerBetter },
    Descriptor { id: "ERR-002", name: "Error Recovery Time", description: "Time to recover to usable state", unit: "ms", category: C::ErrorRecovery, direction: D::LowerBetter },
    Descriptor { id: "ERR-003", name: "Graceful Degradation Score", description: "Resource exhaustion handling", unit: "%", category: C::ErrorRecovery, direction: D::HigherBetter },
];

/// Spec-derived MIG-Ideal baseline for each metric (paper §4.5: "expected
/// MIG baseline values derived from hardware specifications and published
/// benchmarks"). These are the `expected` values in eqs. 29-32. Real MIG
/// is *not* a zero-overhead system: instances still pay driver costs,
/// share the host PCIe link, and reconfiguration requires quiescing — the
/// non-zero entries below encode that, in this testbed's units/scales.
pub fn mig_baseline(id: &str) -> f64 {
    match id {
        // Overhead: MIG ≈ native driver costs + small instance routing.
        "OH-001" => 5.0,     // µs (paper's own example: 15.3 vs 5.0 ⇒ -206 %)
        "OH-002" => 14.0,    // µs
        "OH-003" => 9.0,     // µs
        "OH-004" => 135.0,   // µs
        "OH-005" => 20.0,    // ns — measurement floor; MIG has no hooks
        "OH-006" => 0.05,    // µs — driver-internal locking floor
        "OH-007" => 100.0,   // ns — driver's own allocation bookkeeping
        "OH-008" => 15.0,    // ns — hardware partition check is ~free
        "OH-009" => 0.01,    // % — DCGM-level monitoring
        "OH-010" => 4.0,     // % — MIG instances lose a few % to partition overheads
        // Isolation: hardware guarantees, but reconfiguration quiesces.
        "IS-001" => 99.5,    // %
        "IS-002" => 12.0,    // µs
        "IS-003" => 97.0,    // %
        "IS-004" => 250.0,   // ms — MIG repartition requires draining work
        "IS-005" => 1.0,
        "IS-006" => 0.98,
        "IS-007" => 0.05,    // CV
        "IS-008" => 0.98,
        "IS-009" => 3.0,     // % — residual PCIe/host interference
        "IS-010" => 1.0,
        // LLM (this testbed's scales; see metrics::llm for shapes).
        "LLM-001" => 8.6,    // TFLOPS proxy
        "LLM-002" => 4600.0, // allocs/s
        "LLM-003" => 0.97,   // ratio
        "LLM-004" => 1.0,    // ms TTFT
        "LLM-005" => -95.0,  // % (pool is ~free vs direct)
        "LLM-006" => 110.0,  // %
        "LLM-007" => 0.05,   // ms
        "LLM-008" => 14.0,   // ratio
        "LLM-009" => 0.01,   // ms² variance
        "LLM-010" => 0.85,   // factor
        // Memory bandwidth.
        "BW-001" => 97.0,    // %
        "BW-002" => 0.98,
        "BW-003" => 2.0,     // streams
        "BW-004" => 3.0,     // %
        // Cache.
        "CACHE-001" => 95.0, // %
        "CACHE-002" => 3.0,  // %
        "CACHE-003" => 5.0,  // %
        "CACHE-004" => 4.0,  // %
        // PCIe (shared even under MIG).
        "PCIE-001" => 24.5,  // GB/s
        "PCIE-002" => 24.5,  // GB/s
        "PCIE-003" => 76.0,  // % (the host link IS shared)
        "PCIE-004" => 2.3,   // ratio
        // NCCL (PCIe node).
        "NCCL-001" => 4100.0, // µs
        "NCCL-002" => 32.0,   // GB/s
        "NCCL-003" => 24.0,   // GB/s
        "NCCL-004" => 24.0,   // GB/s
        // Scheduling.
        "SCHED-001" => 11.0, // µs
        "SCHED-002" => 5.0,  // µs
        "SCHED-003" => 52.0, // %
        "SCHED-004" => 0.12, // ms
        // Fragmentation.
        "FRAG-001" => 25.0,  // %
        "FRAG-002" => 5.0,   // %
        "FRAG-003" => 20.0,  // %
        // Error recovery.
        "ERR-001" => 0.05,   // ms
        "ERR-002" => 0.25,   // ms
        "ERR-003" => 100.0,  // %
        _ => 1.0,
    }
}

/// Windowed time-series ids emitted by the `dynsim` dynamic-scenario
/// engine (one value per scenario window; see `docs/dynamics.md`). These
/// are *series*, not Table-8 metrics: they never enter the 56-metric
/// runnable registry or the scoring pipeline, so [`ALL`] stays exactly
/// the paper's taxonomy.
pub const DYN_SERIES: [Descriptor; 6] = [
    Descriptor { id: "DYN-LAT-P50", name: "Windowed Latency P50", description: "Median request latency within the window", unit: "ms", category: C::Llm, direction: D::LowerBetter },
    Descriptor { id: "DYN-LAT-P99", name: "Windowed Latency P99", description: "Tail request latency within the window", unit: "ms", category: C::Llm, direction: D::LowerBetter },
    Descriptor { id: "DYN-THR", name: "Windowed Throughput", description: "Completed requests per second within the window", unit: "req/s", category: C::Llm, direction: D::HigherBetter },
    Descriptor { id: "DYN-SM", name: "Windowed SM Occupancy", description: "Kernel-busy fraction of the window (per tenant or aggregate)", unit: "0-1", category: C::Scheduling, direction: D::HigherBetter },
    Descriptor { id: "DYN-MEM", name: "Windowed Memory Occupancy", description: "Device memory held at window end (per tenant or aggregate)", unit: "0-1", category: C::Fragmentation, direction: D::HigherBetter },
    Descriptor { id: "DYN-FRAG", name: "Windowed Fragmentation Ratio", description: "Allocator fragmentation index at window end", unit: "%", category: C::Fragmentation, direction: D::LowerBetter },
];

/// Per-scenario summary statistics the dynsim engine reduces each
/// timeline to — the regress-compatible surface (`gvbench dynamics
/// --summary-out`) the regression engine gates like sweep cells.
pub const DYN_SUMMARY: [Descriptor; 8] = [
    Descriptor { id: "DYN-P99-STEADY", name: "Steady-State P99 Latency", description: "Median across windows of the per-window P99 latency", unit: "ms", category: C::Llm, direction: D::LowerBetter },
    Descriptor { id: "DYN-WORST-WIN", name: "Worst-Window Degradation", description: "Worst window P99 vs the steady-state P99", unit: "%", category: C::Scheduling, direction: D::LowerBetter },
    Descriptor { id: "DYN-THR-MEAN", name: "Mean Throughput", description: "Completed requests per second over the whole timeline", unit: "req/s", category: C::Llm, direction: D::HigherBetter },
    Descriptor { id: "DYN-RECOVERY", name: "Fault Recovery Time", description: "Injected fault to first successful request of the faulted tenant (0 = no fault; the full horizon = never recovered)", unit: "ms", category: C::ErrorRecovery, direction: D::LowerBetter },
    Descriptor { id: "DYN-EVENTS", name: "Occurrences Processed", description: "Event-core occurrences replayed: window boundaries + scenario events + serviced work arrivals (virtual-time-deterministic, so gateable)", unit: "count", category: C::Scheduling, direction: D::HigherBetter },
    Descriptor { id: "DYN-TRAIN-STEP-P99", name: "Training Step P99 Latency", description: "Tail optimizer-step latency across all training tenants (emitted only for timelines with training tenants; 0 if no step completed)", unit: "ms", category: C::Llm, direction: D::LowerBetter },
    Descriptor { id: "DYN-ALLREDUCE", name: "Mean Allreduce Latency", description: "Mean gradient-allreduce latency over the node interconnect (emitted only for timelines with training tenants; 0 if none ran)", unit: "ms", category: C::Nccl, direction: D::LowerBetter },
    Descriptor { id: "DYN-MIX-INTERFERENCE", name: "Train/Infer Interference", description: "Mean inference latency in train-active windows vs train-idle windows (emitted only for timelines with training tenants; 0 if either regime is empty)", unit: "%", category: C::Isolation, direction: D::LowerBetter },
];

/// Per-cell summary statistics the cluster placement simulator reduces
/// each fleet replay to — the regress-compatible surface (`gvbench
/// cluster --summary-out`) the regression engine gates like sweep
/// cells. Like the `DYN-*` tables these are *not* Table-8 metrics: they
/// never enter the 56-metric runnable registry or the scoring pipeline.
pub const CLUSTER_SUMMARY: [Descriptor; 5] = [
    Descriptor { id: "CL-SUCCESS", name: "Allocation Success Rate", description: "Tenant arrivals placed successfully over all arrival attempts", unit: "%", category: C::Scheduling, direction: D::HigherBetter },
    Descriptor { id: "CL-FRAG", name: "Fleet Fragmentation", description: "Free fleet memory stranded on nodes that cannot fit a reference request", unit: "%", category: C::Fragmentation, direction: D::LowerBetter },
    Descriptor { id: "CL-IMBAL", name: "Utilization Imbalance", description: "Coefficient of variation of per-node memory utilization", unit: "%", category: C::Scheduling, direction: D::LowerBetter },
    Descriptor { id: "CL-MIGRATE", name: "Migration Count", description: "Tenants re-placed onto another node after a node failure", unit: "count", category: C::ErrorRecovery, direction: D::LowerBetter },
    Descriptor { id: "CL-EVICT", name: "Eviction Count", description: "Tenants dropped because no node could host them after a failure", unit: "count", category: C::ErrorRecovery, direction: D::LowerBetter },
];

/// Look up a descriptor by id.
pub fn by_id(id: &str) -> Option<&'static Descriptor> {
    ALL.iter().find(|d| d.id == id)
}

/// Look up a dynsim windowed-series descriptor by id.
pub fn dyn_series_by_id(id: &str) -> Option<&'static Descriptor> {
    DYN_SERIES.iter().find(|d| d.id == id)
}

/// Look up a dynsim per-scenario summary descriptor by id.
pub fn dyn_summary_by_id(id: &str) -> Option<&'static Descriptor> {
    DYN_SUMMARY.iter().find(|d| d.id == id)
}

/// Look up a cluster per-cell summary descriptor by id.
pub fn cluster_summary_by_id(id: &str) -> Option<&'static Descriptor> {
    CLUSTER_SUMMARY.iter().find(|d| d.id == id)
}

/// All descriptors of a category, in Table 8 order.
pub fn by_category(c: Category) -> Vec<&'static Descriptor> {
    ALL.iter().filter(|d| d.category == c).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn exactly_56_metrics() {
        assert_eq!(ALL.len(), 56);
    }

    #[test]
    fn category_counts_match_table1() {
        // Table 1: 10/10/10/4/4/4/4/4/3/3.
        let counts: Vec<usize> =
            Category::ALL.iter().map(|c| by_category(*c).len()).collect();
        assert_eq!(counts, vec![10, 10, 10, 4, 4, 4, 4, 4, 3, 3]);
    }

    #[test]
    fn ids_unique() {
        let ids: HashSet<&str> = ALL.iter().map(|d| d.id).collect();
        assert_eq!(ids.len(), 56);
    }

    #[test]
    fn lookup_by_id() {
        let d = by_id("LLM-004").unwrap();
        assert_eq!(d.name, "Token Generation Latency");
        assert_eq!(d.category, Category::Llm);
        assert!(by_id("XX-999").is_none());
    }

    #[test]
    fn every_metric_has_a_baseline() {
        for d in &ALL {
            let b = mig_baseline(d.id);
            assert!(b.is_finite(), "{} baseline", d.id);
            if d.direction == Direction::HigherBetter {
                assert!(b > 0.0 || d.id == "LLM-005", "{} baseline={b}", d.id);
            }
        }
    }

    #[test]
    fn dyn_series_ids_distinct_from_table8() {
        // DYN ids are a separate namespace: unique among themselves and
        // never resolvable through the Table-8 lookup (so point/sweep
        // regress baselines keep rejecting them).
        let mut ids: HashSet<&str> = HashSet::new();
        for d in DYN_SERIES.iter().chain(&DYN_SUMMARY) {
            assert!(d.id.starts_with("DYN-"), "{}", d.id);
            assert!(by_id(d.id).is_none(), "{} leaked into Table 8", d.id);
        }
        // Ids are unique within each table (DYN-RECOVERY lives in the
        // summary table only; the engine reuses it as a windowed marker).
        ids.extend(DYN_SERIES.iter().map(|d| d.id));
        assert_eq!(ids.len(), DYN_SERIES.len());
        let sids: HashSet<&str> = DYN_SUMMARY.iter().map(|d| d.id).collect();
        assert_eq!(sids.len(), DYN_SUMMARY.len());
        assert_eq!(dyn_summary_by_id("DYN-RECOVERY").unwrap().unit, "ms");
        assert_eq!(dyn_summary_by_id("DYN-EVENTS").unwrap().unit, "count");
        assert_eq!(
            dyn_summary_by_id("DYN-EVENTS").unwrap().direction,
            Direction::HigherBetter
        );
        assert_eq!(dyn_series_by_id("DYN-LAT-P99").unwrap().category, Category::Llm);
        assert!(dyn_series_by_id("OH-001").is_none());
        assert!(dyn_summary_by_id("DYN-LAT-P99").is_none());
    }

    #[test]
    fn cluster_summary_ids_distinct_from_other_namespaces() {
        // CL ids are a separate namespace: unique among themselves and
        // never resolvable through the Table-8 or DYN lookups (so
        // point/sweep/dynamics regress baselines keep rejecting them).
        let ids: HashSet<&str> = CLUSTER_SUMMARY.iter().map(|d| d.id).collect();
        assert_eq!(ids.len(), CLUSTER_SUMMARY.len());
        for d in &CLUSTER_SUMMARY {
            assert!(d.id.starts_with("CL-"), "{}", d.id);
            assert!(by_id(d.id).is_none(), "{} leaked into Table 8", d.id);
            assert!(dyn_series_by_id(d.id).is_none(), "{} leaked into DYN series", d.id);
            assert!(dyn_summary_by_id(d.id).is_none(), "{} leaked into DYN summary", d.id);
        }
        assert_eq!(cluster_summary_by_id("CL-SUCCESS").unwrap().direction, Direction::HigherBetter);
        assert_eq!(cluster_summary_by_id("CL-FRAG").unwrap().unit, "%");
        assert!(cluster_summary_by_id("DYN-THR-MEAN").is_none());
        assert!(cluster_summary_by_id("OH-001").is_none());
    }

    #[test]
    fn boolean_metrics_are_the_two_isolation_checks() {
        let bools: Vec<&str> =
            ALL.iter().filter(|d| d.direction == Direction::Boolean).map(|d| d.id).collect();
        assert_eq!(bools, vec!["IS-005", "IS-010"]);
    }
}
