//! Memory fragmentation metrics FRAG-001..003 (paper §3.9).
//!
//! The churn workload mimics LLM serving: interleaved short-lived KV-cache
//! blocks and long-lived weight buffers. Fragmentation emerges from the
//! real free-list allocator in `simgpu::memory`.

use crate::cudalite::Api;
use crate::simgpu::TenantId;
use crate::virt::TenantConfig;

use super::{MetricResult, RunConfig};

const TENANT: TenantId = 1;

fn api_for(cfg: &RunConfig) -> Api {
    let mut api = Api::with_backend(&cfg.system, cfg.seed);
    api.ctx_create(TENANT, TenantConfig::unlimited()).expect("ctx");
    api
}

/// Run an alloc/free churn and leave the heap fragmented. Phase 1 fills
/// the device to ~85 % (a loaded serving node); phase 2 churns with
/// balanced alloc/free, carving holes across the whole address range.
/// Returns the surviving pointers.
fn churn(api: &mut Api, cfg: &RunConfig) -> Vec<u64> {
    let mut live: Vec<u64> = Vec::new();
    let mut rng = api.dev.rng().fork();
    let target = api.dev.memory.capacity() * 85 / 100;
    // Phase 1: fill with mixed sizes 2–128 MiB.
    while api.dev.memory.used() < target {
        let size = (2u64 << 20) << rng.range(0, 7);
        match api.mem_alloc(TENANT, size) {
            Ok(p) => live.push(p),
            Err(_) => break,
        }
    }
    // Phase 2: steady-state churn.
    for _ in 0..cfg.iterations.max(60) * 6 {
        if !live.is_empty() && rng.chance(0.5) {
            let idx = rng.range(0, live.len());
            let ptr = live.swap_remove(idx);
            api.mem_free(TENANT, ptr).unwrap();
        } else {
            let size = (2u64 << 20) << rng.range(0, 7);
            if let Ok(p) = api.mem_alloc(TENANT, size) {
                live.push(p);
            }
        }
    }
    live
}

/// FRAG-001: fragmentation index after churn (paper eq. 27), %.
pub fn frag_001(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    churn(&mut api, cfg);
    let frag = api.dev.memory.frag_stats().fragmentation_index * 100.0;
    MetricResult::from_value("FRAG-001", &cfg.system, frag)
}

/// FRAG-002: allocation latency degradation with fragmentation, %.
pub fn frag_002(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    let reps = cfg.iterations.max(30);
    let mean_alloc = |api: &mut Api| -> f64 {
        let mut total = 0.0;
        for _ in 0..reps {
            let t0 = api.now_ns();
            let p = api.mem_alloc(TENANT, 4 << 20).expect("alloc");
            total += (api.now_ns() - t0) as f64;
            api.mem_free(TENANT, p).unwrap();
        }
        total / reps as f64
    };
    let fresh = mean_alloc(&mut api);
    churn(&mut api, cfg);
    let fragmented = mean_alloc(&mut api);
    let degradation = ((fragmented - fresh) / fresh * 100.0).max(0.0);
    MetricResult::from_value("FRAG-002", &cfg.system, degradation)
}

/// FRAG-003: compaction efficiency, % — fraction of free memory returned
/// to the largest contiguous block by defragmentation.
pub fn frag_003(cfg: &RunConfig) -> MetricResult {
    let mut api = api_for(cfg);
    churn(&mut api, cfg);
    let before = api.dev.memory.frag_stats();
    let (moved, _reloc) = api.dev.memory.compact();
    // Charge the copy cost: moved bytes at HBM bandwidth (read+write).
    let cost_ns = moved as f64 * 2.0 / (api.dev.spec.hbm_bw_gbps * 1e9) * 1e9;
    api.dev.clock.advance_f(cost_ns);
    let after = api.dev.memory.frag_stats();
    let reclaimed = if after.total_free == 0 {
        100.0
    } else {
        (after.largest_free - before.largest_free) as f64 / after.total_free as f64 * 100.0
    };
    MetricResult::from_value("FRAG-003", &cfg.system, reclaimed.clamp(0.0, 100.0))
}

/// Run the whole category in Table 8 order.
pub fn run_all(cfg: &RunConfig) -> Vec<MetricResult> {
    vec![frag_001(cfg), frag_002(cfg), frag_003(cfg)]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(system: &str) -> RunConfig {
        RunConfig::quick(system)
    }

    #[test]
    fn frag001_churn_fragments() {
        let f = frag_001(&quick("native")).value;
        assert!(f > 5.0 && f < 100.0, "frag index={f}%");
    }

    #[test]
    fn frag002_degradation_positive() {
        let d = frag_002(&quick("native")).value;
        assert!(d > 0.0, "degradation={d}%");
    }

    #[test]
    fn frag003_compaction_reclaims() {
        let r = frag_003(&quick("native")).value;
        assert!(r > 10.0, "reclaimed={r}%");
    }

    #[test]
    fn deterministic_across_runs() {
        let a = frag_001(&quick("hami")).value;
        let b = frag_001(&quick("hami")).value;
        assert_eq!(a, b);
    }
}
