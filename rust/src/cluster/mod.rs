//! Cluster-scale placement simulator (`gvbench cluster`).
//!
//! Everything below this layer measures one node. Production GPU
//! virtualization is a *fleet* problem: the paper's "actionable insights
//! for practitioners deploying GPU resources in multi-tenant
//! environments" at scale hinge on **placement** — which node hosts
//! which tenant — not just per-GPU quotas. MISO (arXiv 2207.11428) and
//! the online fragmentation-aware scheduler of arXiv 2511.18906 both
//! show placement policy dominates achievable utilization under churn.
//! This subsystem makes the fleet the unit of measurement:
//!
//! - [`policy`] defines the pluggable [`PlacementPolicy`] trait with
//!   three in-tree policies (`first-fit`, `best-fit`, `frag-gradient`).
//! - A [`Fleet`] of N nodes — each sized from the run's
//!   [`RunConfig::node_topology`] (per-node memory = `gpu_count` ×
//!   device HBM; per-node compute = `gpu_count` whole-GPU SM units) —
//!   replays a dynsim-style churn timeline of 10³–10⁴ tenant arrivals
//!   ([`arrival_stream`], reusing the dynsim preset names — the
//!   training-bearing presets replay as arrivals-only) and places each
//!   arrival through the
//!   policy. Node failures re-place their tenants (migrations) or drop
//!   them (evictions).
//! - [`run_cluster`] expands a [`ClusterSpec`] — systems × policies ×
//!   node counts × scenarios — into one flat task list sharded through
//!   the parallel executor
//!   ([`crate::coordinator::executor::execute_indexed_with`]), reducing
//!   each cell to the `CL-*` summary metrics (allocation success rate,
//!   fleet fragmentation, utilization imbalance, migration/eviction
//!   counts; see [`crate::metrics::taxonomy::CLUSTER_SUMMARY`]).
//!
//! **Determinism:** each (system, policy, nodes, scenario) cell derives
//! its seed as `task_seed(cluster_seed(run_seed, policy, nodes,
//! scenario), system, scenario)` ([`crate::util::rng::cluster_seed`],
//! the `0xFC` layer) — a pure function of the cell coordinates — so a
//! cluster grid is bit-identical at any `--jobs` count
//! (`rust/tests/cluster_determinism.rs`) and the regression engine can
//! re-run a summary baseline exactly ([`crate::regress`], `cluster`
//! schema). Reporting lives in [`crate::report::cluster`]; the operator
//! guide in `docs/cluster.md`.

pub mod policy;

pub use policy::{canonical as canonical_policy, PlacementPolicy, POLICIES};

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::coordinator::executor::{self, Backend, ExecutionStats, Observer, Task, TaskDone};
use crate::metrics::RunConfig;
use crate::obs::trace::{SpanSink, TaskSpans, VSpan};
use crate::simgpu::spec::GpuSpec;
use crate::util::rng::{cluster_seed, task_seed};
use crate::util::Rng;

/// Default tenant-arrival count per fleet replay (the 10³ end of the
/// 10³–10⁴ design range; `--arrivals` raises it). Regression replays of
/// `cluster` summary baselines always use this count — the schema key
/// `(system, policy, nodes, scenario, id)` does not carry it, exactly
/// like the run seed.
pub const DEFAULT_ARRIVALS: u32 = 1000;
/// Default node-count axis.
pub const DEFAULT_NODE_COUNTS: [u32; 1] = [8];

/// One tenant's resource demand: device memory plus SM share in
/// whole-GPU units (1.0 = one full GPU's worth of SMs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Demand {
    pub mem: u64,
    pub sm: f64,
}

/// Live resource state of one fleet node.
#[derive(Clone, Debug)]
pub struct NodeState {
    pub mem_capacity: u64,
    /// SM capacity in whole-GPU units (= the node's GPU count).
    pub sm_capacity: f64,
    pub mem_used: u64,
    pub sm_used: f64,
    /// Live tenant count.
    pub tenants: u32,
    pub alive: bool,
}

impl NodeState {
    pub fn new(mem_capacity: u64, sm_capacity: f64) -> NodeState {
        NodeState { mem_capacity, sm_capacity, mem_used: 0, sm_used: 0.0, tenants: 0, alive: true }
    }

    /// Whether this node can host `d` (dead nodes host nothing).
    pub fn fits(&self, d: &Demand) -> bool {
        self.alive
            && self.mem_used + d.mem <= self.mem_capacity
            && self.sm_used + d.sm <= self.sm_capacity + 1e-9
    }

    pub fn free_mem(&self) -> u64 {
        self.mem_capacity - self.mem_used
    }

    pub fn mem_util(&self) -> f64 {
        self.mem_used as f64 / self.mem_capacity as f64
    }

    pub fn sm_util(&self) -> f64 {
        self.sm_used / self.sm_capacity
    }

    /// Stranding score: mismatch between the free fractions of the two
    /// resource dimensions. A node whose memory is drained far ahead of
    /// its SMs (or vice versa) strands the slower-draining resource —
    /// the fragmentation measure `frag-gradient` descends (arXiv
    /// 2511.18906).
    pub fn frag_score(&self) -> f64 {
        let free_mem = self.free_mem() as f64 / self.mem_capacity as f64;
        let free_sm = (self.sm_capacity - self.sm_used) / self.sm_capacity;
        (free_mem - free_sm).abs()
    }

    /// A copy of this node as if it hosted `d` (for gradient probes).
    pub fn hosting(&self, d: &Demand) -> NodeState {
        let mut n = self.clone();
        n.mem_used += d.mem;
        n.sm_used += d.sm;
        n.tenants += 1;
        n
    }
}

/// An N-node fleet with tenant placements. All mutation goes through
/// [`Fleet::place`] / [`Fleet::remove`] / [`Fleet::fail_node`], which
/// maintain the two placement invariants the property suite checks: a
/// tenant is on at most one node, and node usage equals the sum of its
/// live tenants' demands (so capacity can never be exceeded).
pub struct Fleet {
    nodes: Vec<NodeState>,
    placements: BTreeMap<u64, (usize, Demand)>,
}

impl Fleet {
    pub fn new(node_count: u32, mem_capacity: u64, sm_capacity: f64) -> Fleet {
        Fleet {
            nodes: vec![NodeState::new(mem_capacity, sm_capacity); node_count as usize],
            placements: BTreeMap::new(),
        }
    }

    pub fn nodes(&self) -> &[NodeState] {
        &self.nodes
    }

    /// tenant → (node index, demand) for every live placement.
    pub fn placements(&self) -> &BTreeMap<u64, (usize, Demand)> {
        &self.placements
    }

    /// Place `tenant` through `policy`. Returns the chosen node index,
    /// or `None` when no node fits. Panics if the tenant is already
    /// placed or the policy returns an infeasible node — both are
    /// simulator bugs, not workload conditions.
    pub fn place(
        &mut self,
        policy: &dyn PlacementPolicy,
        tenant: u64,
        d: Demand,
    ) -> Option<usize> {
        assert!(
            !self.placements.contains_key(&tenant),
            "tenant {tenant} is already placed"
        );
        let node = policy.place(&self.nodes, &d)?;
        assert!(self.nodes[node].fits(&d), "policy {} chose an infeasible node", policy.name());
        self.nodes[node].mem_used += d.mem;
        self.nodes[node].sm_used += d.sm;
        self.nodes[node].tenants += 1;
        self.placements.insert(tenant, (node, d));
        Some(node)
    }

    /// Remove a tenant (departure), freeing its node's resources.
    pub fn remove(&mut self, tenant: u64) -> Option<usize> {
        let (node, d) = self.placements.remove(&tenant)?;
        self.nodes[node].mem_used -= d.mem;
        self.nodes[node].sm_used = (self.nodes[node].sm_used - d.sm).max(0.0);
        self.nodes[node].tenants -= 1;
        Some(node)
    }

    /// Kill a node: mark it dead, clear its usage, and return its former
    /// tenants (ascending id order) for the caller to re-place.
    pub fn fail_node(&mut self, node: usize) -> Vec<(u64, Demand)> {
        let displaced: Vec<(u64, Demand)> = self
            .placements
            .iter()
            .filter(|(_, (n, _))| *n == node)
            .map(|(t, (_, d))| (*t, *d))
            .collect();
        for (t, _) in &displaced {
            self.placements.remove(t);
        }
        let n = &mut self.nodes[node];
        n.alive = false;
        n.mem_used = 0;
        n.sm_used = 0.0;
        n.tenants = 0;
        displaced
    }

    /// Fleet fragmentation %: the share of free fleet memory stranded on
    /// nodes that can no longer fit `reference` (the workload's typical
    /// request). 0 on an empty or fully usable fleet.
    pub fn fragmentation(&self, reference: &Demand) -> f64 {
        let (mut stranded, mut free) = (0u64, 0u64);
        for n in &self.nodes {
            if !n.alive {
                continue;
            }
            free += n.free_mem();
            if !n.fits(reference) {
                stranded += n.free_mem();
            }
        }
        if free == 0 {
            0.0
        } else {
            100.0 * stranded as f64 / free as f64
        }
    }

    /// Per-node utilization imbalance %: the coefficient of variation of
    /// memory utilization across alive nodes. 0 on an idle fleet.
    pub fn imbalance(&self) -> f64 {
        let utils: Vec<f64> =
            self.nodes.iter().filter(|n| n.alive).map(|n| n.mem_util()).collect();
        if utils.is_empty() {
            return 0.0;
        }
        let mean = utils.iter().sum::<f64>() / utils.len() as f64;
        if mean <= 0.0 {
            return 0.0;
        }
        let var = utils.iter().map(|u| (u - mean).powi(2)).sum::<f64>() / utils.len() as f64;
        100.0 * var.sqrt() / mean
    }
}

/// One event of a fleet timeline.
#[derive(Clone, Debug, PartialEq)]
pub enum FleetEvent {
    Arrive { tenant: u64, demand: Demand },
    Depart { tenant: u64 },
    Fail { node: usize },
}

/// Sample one tenant demand: memory log-uniform across 1–16 GiB, SM
/// share uniform in 0.05–0.25 of a GPU.
pub fn sample_demand(rng: &mut Rng) -> Demand {
    let exp = rng.f64_range(30.0, 34.0);
    Demand { mem: (2f64).powf(exp) as u64, sm: rng.f64_range(0.05, 0.25) }
}

/// The distribution's typical request (geometric-mean memory, mean SM
/// share) — the reference [`Fleet::fragmentation`] measures stranding
/// against.
pub fn reference_demand() -> Demand {
    Demand { mem: 4 << 30, sm: 0.15 }
}

/// Generate a fleet timeline of `arrivals` tenant arrivals shaped by the
/// dynsim scenario preset names:
///
/// - `steady` — arrivals only.
/// - `churn` — past the first quarter, each arrival is preceded with
///   p=0.45 by the departure of a random live tenant.
/// - `spike` — the middle third of arrivals demand double resources.
/// - `failover` — one node fails after 15% of arrivals; the replay
///   re-places its tenants (migrations) or drops them (evictions).
/// - any other preset (the training-bearing `train-steady` /
///   `mixed-churn`) — arrivals only, like `steady`: placement sees a
///   tenant's resource footprint, not its workload kind, but the cell
///   still draws its own seed so the scenario axis stays collision-free.
pub fn arrival_stream(
    scenario: &str,
    arrivals: u32,
    nodes: u32,
    rng: &mut Rng,
) -> Vec<FleetEvent> {
    let mut events = Vec::with_capacity(arrivals as usize + arrivals as usize / 2);
    let mut live: Vec<u64> = Vec::new();
    let fail_at = arrivals as u64 * 15 / 100;
    for t in 0..arrivals as u64 {
        if scenario == "failover" && t == fail_at && nodes > 0 {
            events.push(FleetEvent::Fail { node: rng.below(nodes as u64) as usize });
        }
        if scenario == "churn" && t > arrivals as u64 / 4 && !live.is_empty() && rng.chance(0.45)
        {
            let idx = rng.range(0, live.len());
            events.push(FleetEvent::Depart { tenant: live.swap_remove(idx) });
        }
        let mut d = sample_demand(rng);
        if scenario == "spike"
            && t >= arrivals as u64 / 3
            && t < arrivals as u64 * 2 / 3
        {
            d.mem *= 2;
            d.sm = (d.sm * 2.0).min(1.0);
        }
        events.push(FleetEvent::Arrive { tenant: t, demand: d });
        live.push(t);
    }
    events
}

/// Shape one raw demand through a virtualization backend's placement
/// footprint: HAMi/FCSP pay small per-tenant tracking overheads, MIG
/// rounds both dimensions up to 1/7-of-a-GPU slice granularity, and
/// time slicing enforces no SM partition at all (memory is the only
/// binding dimension — at the cost of interference this layer does not
/// model).
pub fn system_demand(system: &str, d: Demand, spec: &GpuSpec) -> Demand {
    match system {
        "hami" => Demand { mem: d.mem + d.mem / 50, sm: d.sm },
        "fcsp" => Demand { mem: d.mem + d.mem / 100, sm: d.sm },
        "mig" => {
            let slice = spec.hbm_bytes / 7;
            Demand { mem: d.mem.div_ceil(slice) * slice, sm: (d.sm * 7.0).ceil() / 7.0 }
        }
        "timeslice" => Demand { mem: d.mem, sm: 0.0 },
        _ => d,
    }
}

/// One completed fleet replay: final per-node state plus the `CL-*`
/// summary metrics.
#[derive(Clone, Debug)]
pub struct FleetRun {
    pub system: String,
    pub policy: &'static str,
    pub nodes: u32,
    pub scenario: &'static str,
    /// Arrival attempts replayed.
    pub arrivals: u32,
    /// Arrivals placed successfully.
    pub placed: u32,
    /// Tenants re-placed onto another node after a failure.
    pub migrations: u32,
    /// Tenants dropped because no node could host them after a failure.
    pub evictions: u32,
    /// Final per-node state, in node-index order.
    pub node_stats: Vec<NodeState>,
    /// `(id, value)` pairs in [`crate::metrics::taxonomy::CLUSTER_SUMMARY`] order.
    pub summary: Vec<(&'static str, f64)>,
}

impl FleetRun {
    /// Look up one summary value by `CL-*` id.
    pub fn summary_value(&self, id: &str) -> Option<f64> {
        self.summary.iter().find(|(i, _)| *i == id).map(|(_, v)| *v)
    }
}

/// Replay one (system, policy, nodes, scenario) fleet cell. `cfg.seed`
/// must already be the composed per-cell seed (see [`ClusterSpec::run_seed`]);
/// `cfg.gpu_count`/`cfg.link` size each node via [`RunConfig::node_topology`].
pub fn replay_fleet(
    cfg: &RunConfig,
    policy: &dyn PlacementPolicy,
    nodes: u32,
    scenario: &'static str,
    arrivals: u32,
) -> FleetRun {
    replay_fleet_inner(cfg, policy, nodes, scenario, arrivals, &mut None)
}

/// [`replay_fleet`] with placement-marker tracing: the same replay
/// (bit-identical `FleetRun` — recording is pure observation) plus one
/// virtual-time [`VSpan`] instant per placement decision. The virtual
/// clock is the event sequence index (1 µs per timeline event — the
/// fleet replay has no device clock); lanes are node indices
/// (lane = node + 1), with rejections and evictions on the timeline
/// lane since they land on no node.
pub fn replay_fleet_traced(
    cfg: &RunConfig,
    policy: &dyn PlacementPolicy,
    nodes: u32,
    scenario: &'static str,
    arrivals: u32,
) -> (FleetRun, Vec<VSpan>) {
    let mut spans = Some(Vec::new());
    let run = replay_fleet_inner(cfg, policy, nodes, scenario, arrivals, &mut spans);
    (run, spans.unwrap_or_default())
}

fn replay_fleet_inner(
    cfg: &RunConfig,
    policy: &dyn PlacementPolicy,
    nodes: u32,
    scenario: &'static str,
    arrivals: u32,
    spans: &mut Option<Vec<VSpan>>,
) -> FleetRun {
    let spec = GpuSpec::a100_40gb();
    let topo = cfg.node_topology(&spec);
    let mem_capacity = topo.device_count as u64 * spec.hbm_bytes;
    let sm_capacity = topo.device_count as f64;
    let mut fleet = Fleet::new(nodes, mem_capacity, sm_capacity);
    let mut rng = Rng::new(cfg.seed);
    let stream = arrival_stream(scenario, arrivals, nodes, &mut rng);
    let (mut attempts, mut placed, mut migrations, mut evictions) = (0u32, 0u32, 0u32, 0u32);
    let node_lane = |node: usize| Some(node as u32 + 1);
    for (idx, ev) in stream.iter().enumerate() {
        let t_ns = idx as u64 * 1_000;
        match ev {
            FleetEvent::Arrive { tenant, demand } => {
                let d = system_demand(&cfg.system, *demand, &spec);
                attempts += 1;
                match fleet.place(policy, *tenant, d) {
                    Some(node) => {
                        placed += 1;
                        if let Some(spans) = spans.as_mut() {
                            spans.push(VSpan::instant("placement", "place", node_lane(node), t_ns));
                        }
                    }
                    None => {
                        if let Some(spans) = spans.as_mut() {
                            spans.push(VSpan::instant("placement", "reject", None, t_ns));
                        }
                    }
                }
            }
            FleetEvent::Depart { tenant } => {
                // Departures of never-placed tenants are no-ops.
                if let Some(node) = fleet.remove(*tenant) {
                    if let Some(spans) = spans.as_mut() {
                        spans.push(VSpan::instant("placement", "depart", node_lane(node), t_ns));
                    }
                }
            }
            FleetEvent::Fail { node } => {
                if let Some(spans) = spans.as_mut() {
                    spans.push(VSpan::instant("fault", "fail", node_lane(*node), t_ns));
                }
                for (tenant, d) in fleet.fail_node(*node) {
                    match fleet.place(policy, tenant, d) {
                        Some(to) => {
                            migrations += 1;
                            if let Some(spans) = spans.as_mut() {
                                spans.push(VSpan::instant("fault", "migrate", node_lane(to), t_ns));
                            }
                        }
                        None => {
                            evictions += 1;
                            if let Some(spans) = spans.as_mut() {
                                spans.push(VSpan::instant("fault", "evict", None, t_ns));
                            }
                        }
                    }
                }
            }
        }
    }
    let success =
        if attempts == 0 { 100.0 } else { 100.0 * placed as f64 / attempts as f64 };
    let reference = system_demand(&cfg.system, reference_demand(), &spec);
    let summary = vec![
        ("CL-SUCCESS", success),
        ("CL-FRAG", fleet.fragmentation(&reference)),
        ("CL-IMBAL", fleet.imbalance()),
        ("CL-MIGRATE", migrations as f64),
        ("CL-EVICT", evictions as f64),
    ];
    FleetRun {
        system: cfg.system.clone(),
        policy: policy::canonical(policy.name()).unwrap_or("first-fit"),
        nodes,
        scenario,
        arrivals,
        placed,
        migrations,
        evictions,
        node_stats: fleet.nodes().to_vec(),
        summary,
    }
}

/// A cluster grid: which systems replay which placement policies on
/// which fleet sizes and scenario shapes, at one arrival count.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    /// Backend keys (`native` / `hami` / `fcsp` / `mig` / `timeslice`).
    pub systems: Vec<String>,
    /// Canonical policy keys (see [`policy::POLICIES`]).
    pub policies: Vec<&'static str>,
    /// Node counts (the fleet-size axis).
    pub node_counts: Vec<u32>,
    /// Canonical scenario preset keys (see [`crate::dynsim::PRESETS`]).
    pub scenarios: Vec<&'static str>,
    /// Tenant arrivals per replay.
    pub arrivals: u32,
}

impl ClusterSpec {
    /// Derived per-cell seed for one (system, policy, nodes, scenario)
    /// replay of this grid.
    pub fn run_seed(
        &self,
        base_seed: u64,
        system: &str,
        policy: &str,
        nodes: u32,
        scenario: &str,
    ) -> u64 {
        task_seed(cluster_seed(base_seed, policy, nodes, scenario), system, scenario)
    }
}

/// A completed cluster grid: every (system, policy, nodes, scenario)
/// fleet replay plus the executor's timings.
pub struct ClusterSurface {
    /// The run seed the per-cell cluster seeds were derived from.
    pub seed: u64,
    pub arrivals: u32,
    /// Runs in deterministic order: spec's system order (outer) ×
    /// policy × node count × scenario order (inner).
    pub runs: Vec<FleetRun>,
    pub stats: ExecutionStats,
}

/// Expand `spec` into one (system × policy × nodes × scenario) task
/// list, execute it on `jobs` executor workers (0 = available
/// parallelism), and collect the fleet replays. `base` supplies the run
/// seed and node topology; per-cell seeds are derived per task.
pub fn run_cluster(base: &RunConfig, spec: &ClusterSpec, jobs: usize) -> ClusterSurface {
    run_cluster_on(&Backend::Scoped(jobs), base, spec, None)
}

/// [`run_cluster`] with placement-marker tracing: the same surface
/// (bit-identical — see [`replay_fleet_traced`]) plus one [`TaskSpans`]
/// per grid cell, merged in task-index order regardless of completion
/// order, so the Chrome trace rendered from them (`gvbench cluster
/// --trace-out`) is byte-identical at any `--jobs` count.
pub fn run_cluster_traced(
    base: &RunConfig,
    spec: &ClusterSpec,
    jobs: usize,
) -> (ClusterSurface, Vec<TaskSpans>) {
    let sink = Arc::new(SpanSink::new());
    let surface =
        run_cluster_inner(&Backend::Scoped(jobs), base, spec, None, Some(Arc::clone(&sink)));
    (surface, sink.drain_sorted())
}

/// [`run_cluster`] generalized over the pool shape: the same task list
/// and seed derivation, executed on `exec` (scoped threads or a
/// persistent serve-daemon pool), with an optional per-task completion
/// observer (observed values are the cell's `CL-SUCCESS` rate).
/// Bit-identical to [`run_cluster`] at any worker count.
pub fn run_cluster_on(
    exec: &Backend<'_>,
    base: &RunConfig,
    spec: &ClusterSpec,
    observer: Option<Observer>,
) -> ClusterSurface {
    run_cluster_inner(exec, base, spec, observer, None)
}

fn run_cluster_inner(
    exec: &Backend<'_>,
    base: &RunConfig,
    spec: &ClusterSpec,
    observer: Option<Observer>,
    sink: Option<Arc<SpanSink>>,
) -> ClusterSurface {
    let cells = spec.systems.len()
        * spec.policies.len()
        * spec.node_counts.len()
        * spec.scenarios.len();
    let mut tasks: Vec<Task> = Vec::with_capacity(cells);
    let mut cfgs: Vec<RunConfig> = Vec::with_capacity(cells);
    let mut coords: Vec<(&'static str, u32, &'static str)> = Vec::with_capacity(cells);
    for system in &spec.systems {
        for &p in &spec.policies {
            for &n in &spec.node_counts {
                for &sc in &spec.scenarios {
                    let mut cfg = base.clone();
                    cfg.system = system.clone();
                    cfg.seed = spec.run_seed(base.seed, system, p, n, sc);
                    tasks.push(Task { system: system.clone(), metric_id: sc });
                    cfgs.push(cfg);
                    coords.push((p, n, sc));
                }
            }
        }
    }
    let tasks = Arc::new(tasks);
    let total = tasks.len();
    let cfgs = Arc::new(cfgs);
    let coords = Arc::new(coords);
    let arrivals = spec.arrivals;
    let run = {
        let cfgs = Arc::clone(&cfgs);
        let coords = Arc::clone(&coords);
        move |i: usize, task: &Task| {
            let (p, n, sc) = coords[i];
            let policy = policy::by_name(p)?;
            let replay = match sink.as_ref() {
                Some(sink) => {
                    let (replay, spans) = replay_fleet_traced(&cfgs[i], policy, n, sc, arrivals);
                    sink.push(TaskSpans {
                        index: i,
                        system: task.system.clone(),
                        label: format!("{p}@{n}n/{sc}"),
                        spans,
                    });
                    replay
                }
                None => replay_fleet(&cfgs[i], policy, n, sc, arrivals),
            };
            if let Some(obs) = observer.as_ref() {
                obs(TaskDone {
                    index: i,
                    total,
                    system: task.system.clone(),
                    label: format!("{p}@{n}n/{sc}"),
                    value: replay.summary_value("CL-SUCCESS").unwrap_or(f64::NAN),
                });
            }
            Some(replay)
        }
    };
    let (slots, stats) = executor::execute_indexed_on(exec, Arc::clone(&tasks), run);
    let runs: Vec<FleetRun> = slots
        .into_iter()
        .zip(coords.iter())
        .map(|(slot, (p, _, _))| {
            slot.unwrap_or_else(|| panic!("cluster policy `{p}` is not a known policy"))
        })
        .collect();
    ClusterSurface { seed: base.seed, arrivals: spec.arrivals, runs, stats }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> ClusterSpec {
        ClusterSpec {
            systems: vec!["native".into(), "hami".into()],
            policies: vec!["first-fit", "frag-gradient"],
            node_counts: vec![4],
            scenarios: vec!["steady", "failover"],
            arrivals: 200,
        }
    }

    #[test]
    fn grid_expands_system_major() {
        let base = RunConfig::quick("native");
        let surface = run_cluster(&base, &small_spec(), 2);
        assert_eq!(surface.runs.len(), 8);
        assert_eq!(surface.stats.tasks.len(), 8);
        let coords: Vec<(&str, &str, u32, &str)> = surface
            .runs
            .iter()
            .map(|r| (r.system.as_str(), r.policy, r.nodes, r.scenario))
            .collect();
        assert_eq!(coords[0], ("native", "first-fit", 4, "steady"));
        assert_eq!(coords[1], ("native", "first-fit", 4, "failover"));
        assert_eq!(coords[2], ("native", "frag-gradient", 4, "steady"));
        assert_eq!(coords[7], ("hami", "frag-gradient", 4, "failover"));
        for r in &surface.runs {
            assert_eq!(r.arrivals, 200);
            assert!(r.placed > 0, "{}/{} placed nothing", r.system, r.policy);
            assert_eq!(r.summary.len(), 5);
        }
    }

    #[test]
    fn per_cell_seeds_are_distinct_and_pure() {
        let spec = small_spec();
        let a = spec.run_seed(42, "hami", "first-fit", 4, "steady");
        assert_eq!(a, spec.run_seed(42, "hami", "first-fit", 4, "steady"));
        assert_ne!(a, spec.run_seed(42, "hami", "best-fit", 4, "steady"));
        assert_ne!(a, spec.run_seed(42, "hami", "first-fit", 8, "steady"));
        assert_ne!(a, spec.run_seed(42, "hami", "first-fit", 4, "churn"));
        assert_ne!(a, spec.run_seed(42, "native", "first-fit", 4, "steady"));
        assert_ne!(a, spec.run_seed(43, "hami", "first-fit", 4, "steady"));
    }

    #[test]
    fn job_counts_agree_bitwise() {
        let base = RunConfig::quick("native");
        let s1 = run_cluster(&base, &small_spec(), 1);
        let s4 = run_cluster(&base, &small_spec(), 4);
        assert_eq!(s1.stats.jobs, 1);
        assert_eq!(s4.stats.jobs, 4);
        for (a, b) in s1.runs.iter().zip(&s4.runs) {
            assert_eq!(a.system, b.system);
            assert_eq!((a.policy, a.nodes, a.scenario), (b.policy, b.nodes, b.scenario));
            assert_eq!((a.placed, a.migrations, a.evictions), (b.placed, b.migrations, b.evictions));
            for ((ia, va), (ib, vb)) in a.summary.iter().zip(&b.summary) {
                assert_eq!(ia, ib);
                assert_eq!(va.to_bits(), vb.to_bits(), "{}/{}/{}", a.system, a.policy, ia);
            }
        }
    }

    #[test]
    fn traced_replay_is_pure_observation() {
        let cfg = RunConfig::quick("hami");
        let policy = policy::by_name("first-fit").unwrap();
        let plain = replay_fleet(&cfg, policy, 4, "failover", 300);
        let (traced, spans) = replay_fleet_traced(&cfg, policy, 4, "failover", 300);
        assert_eq!(plain.placed, traced.placed);
        assert_eq!(plain.migrations, traced.migrations);
        assert_eq!(plain.evictions, traced.evictions);
        for ((ia, va), (ib, vb)) in plain.summary.iter().zip(&traced.summary) {
            assert_eq!(ia, ib);
            assert_eq!(va.to_bits(), vb.to_bits(), "{ia}");
        }
        // One marker per placement, one per displacement, one node fail.
        let places = spans.iter().filter(|s| s.name == "place").count();
        assert_eq!(places as u32, traced.placed);
        assert_eq!(spans.iter().filter(|s| s.name == "fail").count(), 1);
        let moved = spans.iter().filter(|s| s.name == "migrate" || s.name == "evict").count();
        assert_eq!(moved as u32, traced.migrations + traced.evictions);
        // Markers are instants on the event-index clock, node lanes only.
        for s in &spans {
            assert!(s.dur_ns.is_none(), "{s:?}");
            if let Some(lane) = s.tenant {
                assert!((1..=4).contains(&lane), "{s:?}");
            }
        }
        // Traced twice = identical spans, and the grid-level merge keeps
        // task order at any job count.
        let (_, again) = replay_fleet_traced(&cfg, policy, 4, "failover", 300);
        assert_eq!(spans, again);
        let base = RunConfig::quick("native");
        let (_, t1) = run_cluster_traced(&base, &small_spec(), 1);
        let (_, t4) = run_cluster_traced(&base, &small_spec(), 4);
        assert_eq!(t1.len(), 8);
        for (a, b) in t1.iter().zip(&t4) {
            assert_eq!((a.index, &a.system, &a.label), (b.index, &b.system, &b.label));
            assert_eq!(a.spans, b.spans, "{}/{}", a.system, a.label);
        }
    }

    #[test]
    fn capacity_is_never_exceeded_and_usage_balances() {
        let cfg = RunConfig::quick("native");
        let policy = policy::by_name("best-fit").unwrap();
        let run = replay_fleet(&cfg, policy, 3, "churn", 300);
        for n in &run.node_stats {
            assert!(n.mem_used <= n.mem_capacity);
            assert!(n.sm_used <= n.sm_capacity + 1e-9);
        }
    }

    #[test]
    fn failover_displaces_tenants() {
        let cfg = RunConfig::quick("native");
        let policy = policy::by_name("first-fit").unwrap();
        let run = replay_fleet(&cfg, policy, 4, "failover", 400);
        assert_eq!(run.node_stats.iter().filter(|n| !n.alive).count(), 1);
        assert!(
            run.migrations + run.evictions > 0,
            "failover produced no displacement at all"
        );
    }

    #[test]
    fn mig_granularity_rounds_demands_up() {
        let spec = GpuSpec::a100_40gb();
        let slice = spec.hbm_bytes / 7;
        let d = system_demand("mig", Demand { mem: 1, sm: 0.01 }, &spec);
        assert_eq!(d.mem, slice);
        assert!((d.sm - 1.0 / 7.0).abs() < 1e-12);
        // Native is untouched; timeslice drops the SM dimension.
        let raw = Demand { mem: 123, sm: 0.5 };
        assert_eq!(system_demand("native", raw, &spec), raw);
        assert_eq!(system_demand("timeslice", raw, &spec).sm, 0.0);
    }
}
