//! Pluggable placement policies for the fleet simulator.
//!
//! A [`PlacementPolicy`] maps one arriving tenant demand onto a node of
//! the fleet (or rejects it). Three classic policies ship in-tree:
//!
//! - **first-fit** — the lowest-index node with room. The baseline every
//!   scheduler paper compares against; fast and oblivious.
//! - **best-fit** — the feasible node left with the least free memory
//!   after placement (tightest fit). Packs tightly but concentrates
//!   residual slivers.
//! - **frag-gradient** — fragmentation-gradient descent per the online
//!   fragmentation-aware scheduler of arXiv 2511.18906: place where the
//!   fleet's *stranding* measure (mismatch between a node's free memory
//!   and free SM fractions) increases the least, keeping both resource
//!   dimensions drained evenly so late arrivals still find usable nodes.
//!
//! Policies are stateless and deterministic: ties always break toward
//! the lowest node index, so a fleet replay is a pure function of
//! `(seed, policy, arrival order)` (`prop_invariants` checks this).

use super::{Demand, NodeState};

/// Canonical placement-policy keys, in presentation order.
pub const POLICIES: [&str; 3] = ["first-fit", "best-fit", "frag-gradient"];

/// Resolve a user-supplied policy key to its canonical static name.
pub fn canonical(name: &str) -> Option<&'static str> {
    POLICIES.iter().find(|p| **p == name).copied()
}

/// A placement decision procedure: pick a node for `req`, or `None` when
/// no alive node can host it.
pub trait PlacementPolicy: Sync {
    fn name(&self) -> &'static str;
    fn place(&self, nodes: &[NodeState], req: &Demand) -> Option<usize>;
}

/// Look up a policy implementation by canonical key.
pub fn by_name(name: &str) -> Option<&'static dyn PlacementPolicy> {
    match name {
        "first-fit" => Some(&FirstFit),
        "best-fit" => Some(&BestFit),
        "frag-gradient" => Some(&FragGradient),
        _ => None,
    }
}

/// Lowest-index node with room.
pub struct FirstFit;

impl PlacementPolicy for FirstFit {
    fn name(&self) -> &'static str {
        "first-fit"
    }
    fn place(&self, nodes: &[NodeState], req: &Demand) -> Option<usize> {
        nodes.iter().position(|n| n.fits(req))
    }
}

/// Feasible node with the least free memory after placement.
pub struct BestFit;

impl PlacementPolicy for BestFit {
    fn name(&self) -> &'static str {
        "best-fit"
    }
    fn place(&self, nodes: &[NodeState], req: &Demand) -> Option<usize> {
        let mut best: Option<(u64, usize)> = None;
        for (i, n) in nodes.iter().enumerate() {
            if !n.fits(req) {
                continue;
            }
            let left = n.free_mem() - req.mem;
            // Strict `<` keeps ties on the lowest index.
            if best.map_or(true, |(b, _)| left < b) {
                best = Some((left, i));
            }
        }
        best.map(|(_, i)| i)
    }
}

/// Fragmentation-gradient descent (arXiv 2511.18906): feasible node whose
/// stranding score grows the least if it hosts the request.
pub struct FragGradient;

impl PlacementPolicy for FragGradient {
    fn name(&self) -> &'static str {
        "frag-gradient"
    }
    fn place(&self, nodes: &[NodeState], req: &Demand) -> Option<usize> {
        let mut best: Option<(f64, usize)> = None;
        for (i, n) in nodes.iter().enumerate() {
            if !n.fits(req) {
                continue;
            }
            let gradient = n.hosting(req).frag_score() - n.frag_score();
            // Strict `<` keeps ties on the lowest index.
            if best.map_or(true, |(b, _)| gradient < b) {
                best = Some((gradient, i));
            }
        }
        best.map(|(_, i)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet3() -> Vec<NodeState> {
        // Three 100-GiB / 4-SM nodes at different fill levels.
        let gib = 1u64 << 30;
        let mut nodes = vec![NodeState::new(100 * gib, 4.0); 3];
        nodes[0].mem_used = 90 * gib; // nearly full
        nodes[0].sm_used = 1.0;
        nodes[1].mem_used = 40 * gib;
        nodes[1].sm_used = 2.0;
        nodes
    }

    #[test]
    fn registry_resolves_all_canonical_keys() {
        for key in POLICIES {
            assert_eq!(canonical(key), Some(key));
            assert_eq!(by_name(key).unwrap().name(), key);
        }
        assert_eq!(canonical("worst-fit"), None);
        assert!(by_name("worst-fit").is_none());
    }

    #[test]
    fn first_fit_takes_lowest_index_that_fits() {
        let nodes = fleet3();
        let small = Demand { mem: 1 << 30, sm: 0.5 };
        assert_eq!(FirstFit.place(&nodes, &small), Some(0));
        let large = Demand { mem: 50 << 30, sm: 0.5 };
        assert_eq!(FirstFit.place(&nodes, &large), Some(2));
        let giant = Demand { mem: 200 << 30, sm: 0.5 };
        assert_eq!(FirstFit.place(&nodes, &giant), None);
    }

    #[test]
    fn best_fit_picks_tightest_node() {
        let nodes = fleet3();
        // Fits everywhere; node 0 leaves the least free memory.
        let small = Demand { mem: 1 << 30, sm: 0.5 };
        assert_eq!(BestFit.place(&nodes, &small), Some(0));
        // Too big for node 0; node 1 is tighter than node 2.
        let mid = Demand { mem: 20 << 30, sm: 0.5 };
        assert_eq!(BestFit.place(&nodes, &mid), Some(1));
    }

    #[test]
    fn frag_gradient_prefers_the_balanced_host() {
        let gib = 1u64 << 30;
        // Node 0 has memory drained far ahead of SM (a memory-heavy
        // request would balance it); node 1 is even.
        let mut nodes = vec![NodeState::new(100 * gib, 4.0); 2];
        nodes[0].mem_used = 60 * gib;
        nodes[0].sm_used = 0.4;
        let mem_heavy = Demand { mem: 30 * gib, sm: 2.0 };
        // Hosting on node 0 shrinks its stranding score; on node 1 it
        // creates a mismatch from zero.
        assert_eq!(FragGradient.place(&nodes, &mem_heavy), Some(0));
    }

    #[test]
    fn dead_nodes_are_never_chosen() {
        let mut nodes = fleet3();
        nodes[2].alive = false;
        let large = Demand { mem: 50 << 30, sm: 0.5 };
        for key in POLICIES {
            assert_eq!(by_name(key).unwrap().place(&nodes, &large), None, "{key}");
        }
    }
}
