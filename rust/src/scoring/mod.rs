//! Scoring methodology (paper §6): per-metric normalization against the
//! MIG-Ideal baseline, category aggregation, weighted overall score and
//! letter grades.
//!
//! The MIG baseline is what the `mig` backend *measures* (the paper
//! likewise simulates MIG-Ideal from specifications); by construction MIG
//! scores 100 %.

use std::collections::HashMap;

use crate::metrics::{taxonomy, Category, Direction, MetricResult};

/// Per-metric score ∈ [0, 1] (paper eqs. 31–32).
pub fn metric_score(result: &MetricResult, expected: &MetricResult) -> f64 {
    let d = match taxonomy::by_id(result.id) {
        Some(d) => d,
        None => return 0.0,
    };
    match d.direction {
        Direction::Boolean => {
            if result.pass.unwrap_or(result.value > 0.5) {
                1.0
            } else {
                0.0
            }
        }
        Direction::LowerBetter => {
            let (actual, exp) = (result.value, expected.value);
            if actual <= 0.0 {
                // Zero-or-negative latency/overhead: at least as good as
                // any baseline.
                1.0
            } else if exp <= 0.0 {
                // Baseline is zero (e.g. MIG has no hook overhead): score
                // against a small epsilon floor so finite overhead is
                // penalized smoothly rather than zeroed. The floor is 10 %
                // of the native-calibrated launch cost (420 ns) for ns/µs
                // metrics and 1 percentage point for % metrics.
                let floor = match d.unit {
                    "%" => 1.0,
                    "ns" => 40.0,
                    "ms" => 0.04,
                    _ => 0.4, // µs
                };
                (floor / actual).clamp(0.0, 1.0)
            } else {
                (exp / actual).clamp(0.0, 1.0)
            }
        }
        Direction::HigherBetter => {
            let (actual, exp) = (result.value, expected.value);
            if exp <= 0.0 {
                1.0
            } else {
                (actual / exp).clamp(0.0, 1.0)
            }
        }
    }
}

/// Scores for one system against a baseline run.
#[derive(Clone, Debug)]
pub struct ScoreCard {
    pub system: String,
    /// Per-metric scores keyed by id, in taxonomy order.
    pub per_metric: Vec<(&'static str, f64)>,
    /// Category → mean score (paper eq. 33).
    pub per_category: HashMap<Category, f64>,
    /// Weighted overall (paper eq. 34).
    pub overall: f64,
}

impl ScoreCard {
    /// Score `results` (one full suite run) against `baseline` (the
    /// MIG-Ideal suite run). Both must be in taxonomy order or at least
    /// share ids.
    pub fn build(system: &str, results: &[MetricResult], baseline: &[MetricResult]) -> ScoreCard {
        let base_by_id: HashMap<&str, &MetricResult> =
            baseline.iter().map(|r| (r.id, r)).collect();
        let mut per_metric = Vec::with_capacity(results.len());
        for r in results {
            if let Some(b) = base_by_id.get(r.id) {
                per_metric.push((r.id, metric_score(r, b)));
            }
        }
        let mut per_category: HashMap<Category, f64> = HashMap::new();
        for c in Category::ALL {
            let scores: Vec<f64> = per_metric
                .iter()
                .filter(|(id, _)| taxonomy::by_id(id).map(|d| d.category) == Some(c))
                .map(|(_, s)| *s)
                .collect();
            if !scores.is_empty() {
                per_category.insert(c, scores.iter().sum::<f64>() / scores.len() as f64);
            }
        }
        let overall: f64 = Category::ALL
            .iter()
            .filter_map(|c| per_category.get(c).map(|s| s * c.weight()))
            .sum::<f64>()
            / Category::ALL
                .iter()
                .filter(|c| per_category.contains_key(c))
                .map(|c| c.weight())
                .sum::<f64>();
        ScoreCard { system: system.to_string(), per_metric, per_category, overall }
    }

    /// "MIG parity" percentage (Table 7).
    pub fn mig_parity_percent(&self) -> f64 {
        self.overall * 100.0
    }

    pub fn grade(&self) -> Grade {
        Grade::from_score(self.overall)
    }
}

/// Letter grades (paper Table 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Grade {
    APlus,
    A,
    BPlus,
    B,
    C,
    D,
    F,
}

impl Grade {
    pub fn from_score(score: f64) -> Grade {
        let pct = score * 100.0;
        if pct >= 95.0 {
            Grade::APlus
        } else if pct >= 90.0 {
            Grade::A
        } else if pct >= 85.0 {
            Grade::BPlus
        } else if pct >= 80.0 {
            Grade::B
        } else if pct >= 70.0 {
            Grade::C
        } else if pct >= 60.0 {
            Grade::D
        } else {
            Grade::F
        }
    }

    pub fn letter(&self) -> &'static str {
        match self {
            Grade::APlus => "A+",
            Grade::A => "A",
            Grade::BPlus => "B+",
            Grade::B => "B",
            Grade::C => "C",
            Grade::D => "D",
            Grade::F => "F",
        }
    }

    /// Table 3 interpretation column.
    pub fn interpretation(&self) -> &'static str {
        match self {
            Grade::APlus => "Approaches MIG-level isolation",
            Grade::A => "Excellent",
            Grade::BPlus => "Very Good",
            Grade::B => "Good",
            Grade::C => "Fair",
            Grade::D => "Poor",
            Grade::F => "Significant improvement needed",
        }
    }
}

/// Signed MIG deviation (paper eqs. 29–30), percent. Positive = the
/// software solution outperforms the MIG baseline.
pub fn mig_deviation_percent(result: &MetricResult, expected: &MetricResult) -> f64 {
    let d = match taxonomy::by_id(result.id) {
        Some(d) => d,
        None => return 0.0,
    };
    match d.direction {
        Direction::HigherBetter | Direction::Boolean => {
            if expected.value.abs() < f64::EPSILON {
                0.0
            } else {
                (result.value - expected.value) / expected.value * 100.0
            }
        }
        Direction::LowerBetter => {
            if expected.value.abs() < f64::EPSILON {
                if result.value.abs() < f64::EPSILON { 0.0 } else { -100.0 }
            } else {
                (expected.value - result.value) / expected.value * 100.0
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::MetricResult;

    fn r(id: &'static str, v: f64) -> MetricResult {
        MetricResult::from_value(id, "test", v)
    }

    #[test]
    fn lower_better_scoring() {
        // OH-001 is lower-better. expected 4.2, actual 15.3 → 0.27.
        let s = metric_score(&r("OH-001", 15.3), &r("OH-001", 4.2));
        assert!((s - 4.2 / 15.3).abs() < 1e-12);
        // Better than baseline clamps at 1.
        assert_eq!(metric_score(&r("OH-001", 2.0), &r("OH-001", 4.2)), 1.0);
    }

    #[test]
    fn higher_better_scoring() {
        // IS-008 higher-better: 0.87 vs baseline 1.0 → 0.87.
        let s = metric_score(&r("IS-008", 0.87), &r("IS-008", 1.0));
        assert!((s - 0.87).abs() < 1e-12);
        assert_eq!(metric_score(&r("IS-008", 1.2), &r("IS-008", 1.0)), 1.0);
    }

    #[test]
    fn boolean_scoring() {
        let pass = MetricResult::from_pass("IS-005", "x", true);
        let fail = MetricResult::from_pass("IS-005", "x", false);
        let base = MetricResult::from_pass("IS-005", "mig", true);
        assert_eq!(metric_score(&pass, &base), 1.0);
        assert_eq!(metric_score(&fail, &base), 0.0);
    }

    #[test]
    fn zero_baseline_floor() {
        // MIG hook overhead = 0 ns; HAMi 85 ns → floored, small score.
        let s = metric_score(&r("OH-005", 85.0), &r("OH-005", 0.0));
        assert!(s > 0.0 && s < 0.6, "s={s}");
        // And zero actual = perfect.
        assert_eq!(metric_score(&r("OH-005", 0.0), &r("OH-005", 0.0)), 1.0);
    }

    #[test]
    fn grades_match_table3() {
        assert_eq!(Grade::from_score(0.96).letter(), "A+");
        assert_eq!(Grade::from_score(0.91).letter(), "A");
        assert_eq!(Grade::from_score(0.852).letter(), "B+"); // FCSP
        assert_eq!(Grade::from_score(0.81).letter(), "B");
        assert_eq!(Grade::from_score(0.72).letter(), "C"); // HAMi
        assert_eq!(Grade::from_score(0.65).letter(), "D");
        assert_eq!(Grade::from_score(0.2).letter(), "F");
    }

    #[test]
    fn scorecard_baseline_scores_one() {
        let baseline = vec![r("OH-001", 4.2), r("IS-008", 1.0)];
        let card = ScoreCard::build("mig", &baseline, &baseline);
        assert!((card.overall - 1.0).abs() < 1e-12);
        assert_eq!(card.grade().letter(), "A+");
    }

    #[test]
    fn deviation_signs() {
        // Lower-better: actual worse than baseline → negative.
        assert!(mig_deviation_percent(&r("OH-001", 15.3), &r("OH-001", 4.2)) < 0.0);
        // Higher-better: actual better → positive.
        assert!(mig_deviation_percent(&r("IS-008", 1.1), &r("IS-008", 1.0)) > 0.0);
    }
}
