//! # GPU-Virt-Bench
//!
//! A comprehensive benchmarking framework for software-based GPU
//! virtualization systems, reproducing the paper *GPU-Virt-Bench* (Bud
//! Ecosystem, 2025) on a fully simulated GPU substrate.
//!
//! The crate is organized in layers:
//!
//! - [`simgpu`] — a discrete-event simulated GPU (A100-like by default):
//!   SM pool, HBM allocator, L2 cache, PCIe link, NVLink topology, streams
//!   and a virtual nanosecond clock.
//! - [`cudalite`] — a CUDA-driver-shaped API over the simulator (contexts,
//!   memory, kernel launch, transfers, events, collectives). This is the
//!   interposition surface for virtualization layers.
//! - [`virt`] — the virtualization backends under test: `native`
//!   (passthrough), `hami` (HAMi-core-like dlsym interception, shared-region
//!   accounting, fixed token bucket, NVML polling), `fcsp` (BUD-FCSP-like:
//!   cached hooks, adaptive token bucket, weighted fair queuing) and `mig`
//!   (ideal hardware partitioning baseline).
//! - [`metrics`] — the paper's 56-metric taxonomy across 10 categories.
//! - [`stats`], [`scoring`], [`report`] — statistical reduction, MIG-parity
//!   scoring / grading, and JSON/CSV/TXT report generation.
//! - [`regress`] — the sweep-aware regression subsystem: baseline CSVs
//!   keyed by the full `(system, tenants, quota_pct, metric)` cell
//!   coordinate, a sharded re-run/compare engine, and JSON + markdown
//!   regression reports for the CI gates.
//! - [`coordinator`] — multi-tenant orchestration (thread-backed tenants,
//!   workload generators, the suite runner), the **parallel sharded
//!   executor** ([`coordinator::executor`]) that runs the (system × metric)
//!   task matrix across a `--jobs N` worker pool, and the
//!   **scenario-matrix sweep subsystem** ([`coordinator::sweep`]) that
//!   expands (systems × tenant counts × quota levels × metrics) grids into
//!   flat executor task lists.
//! - [`dynsim`] — the **virtual-time dynamic-scenario engine**
//!   (`gvbench dynamics`): tenant arrive/depart/burst/fail timelines
//!   replayed against the virtualized driver path with per-tenant
//!   LLM-serving request streams, reduced to windowed time series
//!   (latency tails, throughput, occupancy, fragmentation, fault
//!   recovery) and regress-gateable per-scenario summaries.
//! - [`cluster`] — the **fleet placement simulator** (`gvbench cluster`):
//!   N-node fleets replaying churn timelines of 10³–10⁴ tenant arrivals
//!   through pluggable placement policies (first-fit, best-fit,
//!   fragmentation-gradient), reduced to allocation success rate, fleet
//!   fragmentation, utilization imbalance and migration/eviction counts.
//! - [`obs`] — the **observability layer**: span tracing over the replay
//!   engines and the executor (Chrome trace-event JSON for Perfetto /
//!   `chrome://tracing`, exposed as `--trace-out`), plus the counters and
//!   bucketed histograms behind the serve daemon's `stats` telemetry
//!   endpoint (`gvbench jobs --stats` / `--stats-format prometheus`).
//! - [`runtime`] — PJRT wrapper that loads AOT-compiled JAX/Pallas HLO
//!   artifacts and executes them from the Rust request path (used by the
//!   LLM metric category and the examples).
//! - [`cli`], [`config`] — the `gvbench` command-line front end.
//! - [`benchkit`], [`testkit`], [`util`] — in-tree substitutes for
//!   criterion / proptest / rand, plus [`anyhow`] (error context) and
//!   [`xla`] (PJRT stub) for the offline environment.
//!
//! ## Parallel execution and determinism
//!
//! The full evaluation matrix (4 systems × 56 metrics = 224 tasks) is
//! executed by [`coordinator::executor`]: a `std::thread::scope`-based
//! worker pool that shards tasks across `--jobs N` workers (default:
//! available parallelism). Every task derives its own RNG seed as
//! `util::rng::task_seed(cfg.seed, system, metric_id)` — a pure function of
//! the run seed and the task's coordinates — and each metric builds its own
//! simulated device from that seed. Results are therefore **bit-identical
//! at any worker count and any completion order**; the executor only
//! re-assembles them into Table-8 order. `rust/tests/determinism.rs` proves
//! the guarantee by comparing full-suite runs at `jobs=1` and `jobs=8`
//! bit-for-bit. Wall-clock and per-task timings are recorded in
//! [`coordinator::executor::ExecutionStats`] and surfaced by the JSON/CSV
//! reporters.
//!
//! ## Scenario sweeps and the CI regression gate
//!
//! `gvbench sweep` evaluates multi-tenant **and multi-GPU** operating
//! points instead of the single default configuration:
//! [`coordinator::sweep`] expands a [`coordinator::sweep::SweepSpec`] —
//! systems × tenants × quotas × **gpu_counts × link kinds** × metrics —
//! into one flat task list. Each cell's per-tenant quota maps onto
//! memory/SM limits, its `gpu_count`/`link` coordinates select the
//! simulated node topology the NCCL/P2P and PCIe metric backends build
//! ([`metrics::RunConfig::node_topology`]), and its seed derives as
//! `task_seed(topology_seed(scenario_seed(run_seed, tenants, quota),
//! gpus, link), system, metric)`. The matrix executes via
//! [`coordinator::executor::execute_prepared_indexed`], and every cell is
//! scored against the MIG-Ideal spec baseline. [`report::sweep`] renders
//! the resulting surface — per-cell overall/category scores and the
//! delta vs the (1 tenant, 100 % quota) baseline cell of the same
//! (system, topology) block — as CSV, JSON or a TXT summary of the
//! worst-degrading cells per system and per link kind.
//! `rust/tests/sweep_determinism.rs` proves sweeps bit-identical at any
//! job count, topology axes included.
//!
//! The sweep CSV surface is **long format** — one row per (cell × metric),
//! with the cell's score summary denormalized onto every row — so it
//! doubles as a per-cell regression baseline. [`regress`] parses that
//! surface (with or without the PR-4 topology columns — PR-3-era
//! baselines re-run on the default 4-GPU PCIe node with their original
//! scenario-layer seed derivation) and the single-point
//! `gvbench run --format csv` table into one baseline model keyed by
//! `(system, tenants, quota_pct, gpu_count, link, metric)`, reconstructs
//! each cell's [`metrics::RunConfig`] with the producing run's exact
//! seed derivation, re-runs the cells through
//! [`coordinator::executor::execute_prepared_indexed`] (`--jobs`), and
//! applies direction-aware per-cell comparison. `gvbench regress` exposes
//! it (`--report-json` / `--report-md` emit machine-readable reports,
//! including a per-link-kind breakdown); CI wires it into two blocking
//! gates — quick-point and the 2×2×2 sweep — that publish those reports
//! as artifacts and into `$GITHUB_STEP_SUMMARY` (see `ci/README.md`).
//! `rust/tests/regress_engine.rs` proves the sweep→CSV→regress
//! round-trip clean at any job count for all three baseline schemas.
//!
//! ## Dynamic scenarios
//!
//! `gvbench dynamics` leaves the static-point regime entirely:
//! [`dynsim`] replays declared tenant timelines (named presets `steady`,
//! `churn`, `spike`, `failover`) against each system, sharding the
//! (system × scenario) grid through
//! [`coordinator::executor::execute_indexed_with`] with per-task seeds
//! `task_seed(dynamics_seed(run_seed, scenario, duration, window),
//! system, scenario)`, and emits windowed time series plus per-scenario
//! summary statistics. The summary CSV (`--summary-out`) is a third
//! [`regress`] baseline schema (`dynamics`), gated by CI's blocking
//! **dynamics-smoke** job. `rust/tests/dynamics_determinism.rs` proves
//! the surface bit-identical at any job count.
//!
//! ## Cluster placement
//!
//! `gvbench cluster` raises the unit of measurement from one node to a
//! fleet: [`cluster`] replays churn timelines of 10³–10⁴ tenant
//! arrivals against N-node fleets (each node sized via
//! [`metrics::RunConfig::node_topology`]), placing every arrival through
//! a pluggable [`cluster::PlacementPolicy`] (`first-fit`, `best-fit`,
//! `frag-gradient` per arXiv 2511.18906) and sharding the (system ×
//! policy × nodes × scenario) grid through
//! [`coordinator::executor::execute_indexed_with`] with per-cell seeds
//! `task_seed(cluster_seed(run_seed, policy, nodes, scenario), system,
//! scenario)`. The summary CSV (`--summary-out`) is a fourth [`regress`]
//! baseline schema (`cluster`), keyed by `(system, policy, nodes,
//! scenario, id)` and gated by CI's blocking **cluster-smoke** job.
//! `rust/tests/cluster_determinism.rs` proves the fleet surface
//! bit-identical at any job count.
//!
//! ## Benchmark service
//!
//! `gvbench serve` runs the whole framework as a daemon: [`serve`] owns
//! one persistent [`coordinator::executor::WorkerPool`] and a
//! FIFO-with-priorities job queue, accepts the argv of any one-shot
//! invocation (`run` / `sweep` / `dynamics` / `cluster` / `regress`) as
//! a job over a local Unix socket, and streams newline-delimited JSON
//! lifecycle events (`queued` → `scheduled` → `task_completed` × N →
//! `report` → `finished`/`failed`) with explicit idle-time accounting
//! (`queue_wait_ms`, `scheduler_idle_ms`, `worker_idle_ms`). Jobs run
//! through the same spec-building helpers and `*_on` executor entry
//! points as the CLI, so a served report is bit-identical to its
//! one-shot equivalent — pinned by `rust/tests/serve_determinism.rs`
//! and CI's blocking **serve-smoke** job. `gvbench submit` and
//! `gvbench jobs` are the client side (see `docs/serve.md`).
//!
//! ## Observability
//!
//! [`obs`] keeps the un-reduced story behind those surfaces: replay
//! engines record virtual-time spans (request lifecycles, train
//! fwd/bwd/optimizer kernels, allreduces, fault-recovery windows,
//! tenant and placement markers) that [`obs::chrome`] renders as Chrome
//! trace-event JSON (`--trace-out FILE` on `run`/`sweep`/`dynamics`/
//! `cluster`). Virtual-time traces are byte-identical at any `--jobs`;
//! wall-clock executor lanes stay quarantined like the JSON `execution`
//! objects. The serve daemon aggregates [`obs::counters`] telemetry and
//! answers a `stats` request, rendered by `gvbench jobs --stats` or
//! scraped as Prometheus text via `--stats-format prometheus`
//! (`rust/tests/trace_export.rs` pins trace determinism; see
//! `docs/observability.md`).
//!
//! Operator-facing guides live under `docs/` (`architecture.md`,
//! `sweeps.md`, `regression-gating.md`, `dynamics.md`, `cluster.md`,
//! `serve.md`, `observability.md`), with the quickstart in the top-level
//! `README.md`.

pub mod anyhow;
pub mod benchkit;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod cudalite;
pub mod dynsim;
pub mod metrics;
pub mod obs;
pub mod regress;
pub mod report;
pub mod runtime;
pub mod scoring;
pub mod serve;
pub mod simgpu;
pub mod stats;
pub mod testkit;
pub mod util;
pub mod virt;
pub mod xla;

/// Crate version reported in benchmark output (`benchmark_version`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
