//! Micro-benchmark harness (criterion substitute for the offline build).
//!
//! Used by the `benches/` targets (`harness = false`): warmup, timed
//! iterations, outlier-trimmed statistics, and aligned table printing so
//! each bench can regenerate its paper table verbatim.

use std::time::Instant;

use crate::stats::Summary;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Wall-clock per-iteration stats, ns.
    pub summary: Summary,
}

/// Run `f` with `warmup` + `iters` iterations, timing each.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    // Trim the top/bottom 5% (scheduler noise).
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let trim = samples.len() / 20;
    let trimmed = &samples[trim..samples.len() - trim];
    BenchResult { name: name.to_string(), summary: Summary::from_samples(trimmed) }
}

/// Print a fixed-width table: header row then data rows.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let fmt_row = |cells: &[String]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(8) + 2))
            .collect::<String>()
    };
    println!("{}", fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * ncol));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

/// Convenience: format ns as µs with 1 decimal (the paper's unit).
pub fn us(ns: f64) -> String {
    format!("{:.1}", ns / 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_iters() {
        let r = bench("noop", 2, 40, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.name, "noop");
        assert!(r.summary.count >= 36); // 40 - 2*trim
        assert!(r.summary.mean >= 0.0);
    }

    #[test]
    fn us_format() {
        assert_eq!(us(4200.0), "4.2");
    }
}
