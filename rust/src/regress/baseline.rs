//! Baseline model and CSV parsing for the regression engine.
//!
//! A baseline CSV is either a **point** table (`gvbench run --all-systems
//! --format csv`: `id,...,system,value`, no scenario columns) or a
//! **sweep** surface (`gvbench sweep --format csv`): one row per cell ×
//! metric. Two sweep generations are accepted:
//!
//! - the **extended** (PR 4+) schema with the full topology coordinate
//!   (`system,tenants,quota_pct,gpu_count,link,feasible,id,value` columns
//!   among others), and
//! - the **PR-3-era** 4-tuple schema without `gpu_count`/`link` columns,
//!   whose rows re-run on the default 4-GPU PCIe node with the
//!   scenario-layer seed derivation their producing sweep used
//!   ([`crate::coordinator::sweep::legacy_cell_cfg`]).
//!
//! A third schema, **dynamics**, is the per-scenario summary surface
//! `gvbench dynamics --summary-out` writes: rows keyed by
//! `(system, scenario, duration_ms, window_ms, id)` with ids from
//! [`crate::metrics::taxonomy::DYN_SUMMARY`], re-run by replaying the
//! whole scenario timeline (see `crate::regress::engine`).
//!
//! A fourth schema, **cluster**, is the fleet placement summary surface
//! `gvbench cluster --summary-out` writes: rows keyed by
//! `(system, policy, nodes, scenario, id)` with ids from
//! [`crate::metrics::taxonomy::CLUSTER_SUMMARY`], re-run by replaying the
//! whole fleet timeline through [`crate::cluster`]. Because both cluster
//! and dynamics surfaces carry a `scenario` column, the cluster columns
//! (`policy`/`nodes`) are checked first during detection.
//!
//! The schema is auto-detected from the header; generations must not be
//! mixed — a header carrying only one of `tenants`/`quota_pct`, only
//! one of `gpu_count`/`link`, only one of `policy`/`nodes`, or `scenario`
//! together with sweep columns, is rejected, as is any data row that does
//! not fit the detected schema. Every rejection names the offending row.

use std::collections::BTreeSet;

use crate::anyhow::{bail, Context, Result};
use crate::metrics::taxonomy;
use crate::simgpu::nvlink::LinkKind;

/// Which kind of baseline CSV was parsed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BaselineSchema {
    /// Per-metric rows at one operating point (`gvbench run` CSV); rows
    /// re-run at the regress invocation's own `RunConfig`.
    Point,
    /// Long-format sweep surface (`gvbench sweep --format csv`); rows
    /// carry a full (tenants, quota[, gpu_count, link]) cell coordinate.
    Sweep,
    /// Dynamic-scenario summary surface (`gvbench dynamics
    /// --summary-out`); rows carry a `(scenario, duration_ms, window_ms)`
    /// coordinate and a [`crate::metrics::taxonomy::DYN_SUMMARY`] id, and
    /// re-run by replaying the whole scenario timeline through
    /// [`crate::dynsim`] with the producing run's exact seed derivation.
    Dynamics,
    /// Cluster placement summary surface (`gvbench cluster
    /// --summary-out`); rows carry a `(policy, nodes, scenario)`
    /// coordinate and a [`crate::metrics::taxonomy::CLUSTER_SUMMARY`] id,
    /// and re-run by replaying the whole fleet timeline through
    /// [`crate::cluster`] with the producing run's exact seed derivation.
    Cluster,
}

impl BaselineSchema {
    pub fn key(&self) -> &'static str {
        match self {
            BaselineSchema::Point => "point",
            BaselineSchema::Sweep => "sweep",
            BaselineSchema::Dynamics => "dynamics",
            BaselineSchema::Cluster => "cluster",
        }
    }
}

/// Dynamics-cell coordinate of one summary baseline row.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct DynCoord {
    /// Canonical scenario preset key.
    pub scenario: &'static str,
    pub duration_ms: u64,
    pub window_ms: u64,
}

/// Render a dynamics coordinate as `churn@1000ms/100ms`.
pub fn dyn_label(d: DynCoord) -> String {
    format!("{}@{}ms/{}ms", d.scenario, d.duration_ms, d.window_ms)
}

/// Cluster-cell coordinate of one fleet summary baseline row.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct ClusterCoord {
    /// Canonical placement-policy key ([`crate::cluster::POLICIES`]).
    pub policy: &'static str,
    /// Fleet size in nodes.
    pub nodes: u32,
    /// Canonical scenario preset key.
    pub scenario: &'static str,
}

/// Render a cluster coordinate as `first-fit@2n/churn`.
pub fn cluster_label(c: ClusterCoord) -> String {
    format!("{}@{}n/{}", c.policy, c.nodes, c.scenario)
}

/// Full sweep-cell coordinate of one baseline row.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct CellCoord {
    pub tenants: u32,
    pub quota_pct: u32,
    /// Topology axes `(gpu_count, link)`; `None` in PR-3-era baselines
    /// without `gpu_count`/`link` columns — such rows re-run on the
    /// default node (4 GPUs over PCIe) with the scenario-layer seed
    /// derivation their producing sweep used.
    pub topo: Option<(u32, LinkKind)>,
}

/// One parsed baseline entry, keyed by its full cell coordinate.
#[derive(Clone, Debug)]
pub struct BaselineRow {
    pub system: String,
    /// Sweep cell coordinate; `None` for point rows, which re-run at the
    /// invocation's configured operating point.
    pub cell: Option<CellCoord>,
    /// Dynamics cell coordinate; `Some` exactly for dynamics-schema rows.
    pub dyn_cell: Option<DynCoord>,
    /// Cluster cell coordinate; `Some` exactly for cluster-schema rows.
    pub cluster_cell: Option<ClusterCoord>,
    pub id: String,
    pub value: f64,
    /// 1-based CSV line number, for error messages.
    pub line: usize,
}

impl BaselineRow {
    /// Short human label for the row's cell coordinate.
    pub fn cell_label(&self) -> String {
        if let Some(c) = self.cluster_cell {
            return cluster_label(c);
        }
        match self.dyn_cell {
            Some(d) => dyn_label(d),
            None => cell_label(self.cell),
        }
    }
}

/// Render a cell coordinate as `4t@25%` (PR-3-era rows),
/// `4t@25%/8g/nvlink` (extended rows) or `point` (absent).
pub fn cell_label(cell: Option<CellCoord>) -> String {
    match cell {
        Some(CellCoord { tenants, quota_pct, topo: Some((gpus, link)) }) => {
            format!("{tenants}t@{quota_pct}%/{gpus}g/{}", link.key())
        }
        Some(CellCoord { tenants, quota_pct, topo: None }) => format!("{tenants}t@{quota_pct}%"),
        None => "point".to_string(),
    }
}

/// A parsed baseline: re-runnable rows plus the infeasible cells the
/// surface recorded (skipped by the engine, never re-run).
#[derive(Clone, Debug)]
pub struct Baseline {
    pub schema: BaselineSchema,
    /// Feasible rows, in file order.
    pub rows: Vec<BaselineRow>,
    /// Distinct `(system, cell)` coordinates marked `feasible: false` in
    /// the file.
    pub infeasible: Vec<(String, CellCoord)>,
    /// Arrival count recorded in the surface's `# arrivals=N` header
    /// comment (`gvbench cluster --summary-out` embeds it); `None` when
    /// the file carries no such comment. The engine surfaces a mismatch
    /// against [`crate::cluster::DEFAULT_ARRIVALS`] so a baseline armed
    /// from a non-default recording is never silently gated against
    /// default-arrival re-runs.
    pub recorded_arrivals: Option<u32>,
}

impl Baseline {
    /// Parse a baseline CSV — an inherent-method alias for
    /// [`parse_baseline_csv`].
    ///
    /// # Examples
    ///
    /// ```
    /// use gvb::regress::{Baseline, BaselineSchema};
    ///
    /// // A PR-3-era sweep baseline without topology columns still parses…
    /// let legacy = "system,tenants,quota_pct,feasible,id,value\n\
    ///               hami,2,50,true,OH-001,15.3\n";
    /// let b = Baseline::parse(legacy, "native").unwrap();
    /// assert_eq!(b.schema, BaselineSchema::Sweep);
    /// assert!(b.rows[0].cell.unwrap().topo.is_none());
    /// assert_eq!(b.rows[0].cell_label(), "2t@50%");
    ///
    /// // …and the extended schema carries the full topology coordinate.
    /// let extended = "system,tenants,quota_pct,gpu_count,link,feasible,id,value\n\
    ///                 hami,2,50,8,nvlink,true,OH-001,15.3\n";
    /// let b = Baseline::parse(extended, "native").unwrap();
    /// assert_eq!(b.rows[0].cell_label(), "2t@50%/8g/nvlink");
    ///
    /// // Cluster summaries carry a (policy, nodes, scenario) coordinate.
    /// let cluster = "system,policy,nodes,scenario,id,value\n\
    ///                hami,first-fit,8,churn,CL-SUCCESS,97.2\n";
    /// let b = Baseline::parse(cluster, "native").unwrap();
    /// assert_eq!(b.schema, BaselineSchema::Cluster);
    /// assert_eq!(b.rows[0].cell_label(), "first-fit@8n/churn");
    /// ```
    pub fn parse(text: &str, default_system: &str) -> Result<Baseline> {
        parse_baseline_csv(text, default_system)
    }
}

/// Parse a baseline CSV. Point rows without a `system` column are
/// attributed to `default_system`. Unknown metric ids, unknown systems,
/// unknown link kinds, malformed fields, out-of-range cell coordinates
/// and duplicate `(system, cell, id)` keys are rejected with the
/// offending row named.
pub fn parse_baseline_csv(text: &str, default_system: &str) -> Result<Baseline> {
    // `#` comment lines may appear anywhere (the cluster summary CSV
    // prepends a `# arrivals=N` provenance comment). They never count as
    // header or data, but physical line numbers are preserved so `row N`
    // in an error always names the line an editor shows.
    let mut recorded_arrivals: Option<u32> = None;
    let mut data: Vec<(usize, &str)> = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let lineno = i + 1;
        if let Some(rest) = line.trim().strip_prefix('#') {
            if let Some(v) = rest.trim().strip_prefix("arrivals=") {
                let n: u32 = v.trim().parse().with_context(|| {
                    format!("line {lineno}: bad `# arrivals=` comment value `{}`", v.trim())
                })?;
                recorded_arrivals = Some(n);
            }
            continue;
        }
        data.push((lineno, line));
    }
    let mut lines = data.into_iter();
    let (_, header) = lines.next().context("empty baseline file")?;
    let cols = split_csv(header);
    let col = |name: &str| cols.iter().position(|c| c == name);
    let id_col = col("id").context("no `id` column in baseline header")?;
    let value_col = col("value").context("no `value` column in baseline header")?;
    let system_col = col("system");
    let tenants_col = col("tenants");
    let quota_col = col("quota_pct");
    let gpus_col = col("gpu_count");
    let link_col = col("link");
    let feasible_col = col("feasible");
    let scenario_col = col("scenario");
    let duration_col = col("duration_ms");
    let window_col = col("window_ms");
    let policy_col = col("policy");
    let nodes_col = col("nodes");
    // Cluster detection runs first: cluster summaries share the
    // `scenario` column with the dynamics schema.
    let schema = if policy_col.is_some() || nodes_col.is_some() {
        if policy_col.is_none() || nodes_col.is_none() {
            bail!("mixed-schema baseline header: `policy` and `nodes` must appear together");
        }
        if tenants_col.is_some() || quota_col.is_some() || gpus_col.is_some() || link_col.is_some()
        {
            bail!(
                "mixed-schema baseline header: cluster columns (`policy`/`nodes`) cannot be \
                 combined with sweep columns (`tenants`/`quota_pct`/`gpu_count`/`link`)"
            );
        }
        if duration_col.is_some() || window_col.is_some() {
            bail!(
                "mixed-schema baseline header: cluster columns (`policy`/`nodes`) cannot be \
                 combined with dynamics columns (`duration_ms`/`window_ms`)"
            );
        }
        if scenario_col.is_none() {
            bail!("cluster-schema baseline requires a `scenario` column alongside `policy`/`nodes`");
        }
        if system_col.is_none() {
            bail!("cluster-schema baseline requires a `system` column");
        }
        BaselineSchema::Cluster
    } else if scenario_col.is_some() {
        if tenants_col.is_some() || quota_col.is_some() || gpus_col.is_some() || link_col.is_some()
        {
            bail!(
                "mixed-schema baseline header: `scenario` cannot be combined with sweep \
                 columns (`tenants`/`quota_pct`/`gpu_count`/`link`)"
            );
        }
        if duration_col.is_none() || window_col.is_none() {
            bail!(
                "dynamics-schema baseline requires `duration_ms` and `window_ms` columns \
                 alongside `scenario`"
            );
        }
        if system_col.is_none() {
            bail!("dynamics-schema baseline requires a `system` column");
        }
        BaselineSchema::Dynamics
    } else {
        match (tenants_col, quota_col) {
            (Some(_), Some(_)) => BaselineSchema::Sweep,
            (None, None) => BaselineSchema::Point,
            _ => bail!(
                "mixed-schema baseline header: `tenants` and `quota_pct` must appear together"
            ),
        }
    };
    if gpus_col.is_some() != link_col.is_some() {
        bail!("mixed-schema baseline header: `gpu_count` and `link` must appear together");
    }
    if gpus_col.is_some() && schema == BaselineSchema::Point {
        bail!(
            "topology columns (`gpu_count`/`link`) require the sweep schema \
             (`tenants`/`quota_pct`)"
        );
    }
    if schema == BaselineSchema::Sweep {
        if system_col.is_none() {
            bail!("sweep-schema baseline requires a `system` column");
        }
        if feasible_col.is_none() {
            bail!("sweep-schema baseline requires a `feasible` column");
        }
    }

    let mut rows: Vec<BaselineRow> = Vec::new();
    let mut infeasible: Vec<(String, CellCoord)> = Vec::new();
    #[allow(clippy::type_complexity)]
    let mut seen: BTreeSet<(String, Option<CellCoord>, Option<DynCoord>, Option<ClusterCoord>, String)> =
        BTreeSet::new();
    for (lineno, line) in lines {
        if line.trim().is_empty() {
            continue;
        }
        let fields = split_csv(line);
        let system = match system_col {
            Some(c) => get_field(&fields, c, lineno, "system")?.clone(),
            None => default_system.to_string(),
        };
        if crate::virt::by_name(&system).is_none() {
            bail!(
                "row {lineno}: unknown system `{system}` (expected: native, hami, fcsp, mig, timeslice)"
            );
        }
        let cluster_cell = match schema {
            BaselineSchema::Cluster => {
                let name = get_field(&fields, policy_col.expect("cluster schema"), lineno, "policy")?;
                let policy = crate::cluster::canonical_policy(name).with_context(|| {
                    format!(
                        "row {lineno}: unknown placement policy `{name}` (expected: first-fit, \
                         best-fit, frag-gradient)"
                    )
                })?;
                let nodes: u32 =
                    get_field(&fields, nodes_col.expect("cluster schema"), lineno, "nodes")?
                        .parse()
                        .with_context(|| format!("row {lineno}: bad nodes value"))?;
                if !(1..=1024).contains(&nodes) {
                    bail!("row {lineno}: nodes value {nodes} out of range (1..=1024)");
                }
                let name = get_field(&fields, scenario_col.expect("cluster schema"), lineno, "scenario")?;
                let scenario = crate::dynsim::scenario::canonical(name).with_context(|| {
                    format!(
                        "row {lineno}: unknown scenario `{name}` (expected: steady, churn, \
                         spike, failover, train-steady, mixed-churn)"
                    )
                })?;
                Some(ClusterCoord { policy, nodes, scenario })
            }
            _ => None,
        };
        let dyn_cell = match schema {
            BaselineSchema::Dynamics => {
                let name = get_field(&fields, scenario_col.expect("dynamics schema"), lineno, "scenario")?;
                // `canonical_timeline` additionally admits the reserved
                // `trace` key: a summary recorded from `--trace FILE` is
                // re-runnable as long as the regress caller supplies the
                // same trace.
                let scenario = crate::dynsim::scenario::canonical_timeline(name).with_context(|| {
                    format!(
                        "row {lineno}: unknown scenario `{name}` (expected: steady, churn, \
                         spike, failover, train-steady, mixed-churn, trace)"
                    )
                })?;
                let duration_ms: u64 =
                    get_field(&fields, duration_col.expect("dynamics schema"), lineno, "duration_ms")?
                        .parse()
                        .with_context(|| format!("row {lineno}: bad duration_ms value"))?;
                let window_ms: u64 =
                    get_field(&fields, window_col.expect("dynamics schema"), lineno, "window_ms")?
                        .parse()
                        .with_context(|| format!("row {lineno}: bad window_ms value"))?;
                if !(1..=3_600_000).contains(&duration_ms) {
                    bail!("row {lineno}: duration_ms value {duration_ms} out of range (1..=3600000)");
                }
                if window_ms == 0 || window_ms > duration_ms {
                    bail!(
                        "row {lineno}: window_ms value {window_ms} out of range (1..=duration_ms)"
                    );
                }
                Some(DynCoord { scenario, duration_ms, window_ms })
            }
            _ => None,
        };
        let cell = match schema {
            BaselineSchema::Point | BaselineSchema::Dynamics | BaselineSchema::Cluster => None,
            BaselineSchema::Sweep => {
                let tenants: u32 = get_field(&fields, tenants_col.expect("sweep schema"), lineno, "tenants")?
                    .parse()
                    .with_context(|| format!("row {lineno}: bad tenants value"))?;
                let quota: u32 = get_field(&fields, quota_col.expect("sweep schema"), lineno, "quota_pct")?
                    .parse()
                    .with_context(|| format!("row {lineno}: bad quota_pct value"))?;
                if !(1..=64).contains(&tenants) {
                    bail!("row {lineno}: tenants value {tenants} out of range (1..=64)");
                }
                if !(1..=100).contains(&quota) {
                    bail!("row {lineno}: quota_pct value {quota} out of range (1..=100)");
                }
                let topo = match (gpus_col, link_col) {
                    (Some(gc), Some(lc)) => {
                        let gpus: u32 = get_field(&fields, gc, lineno, "gpu_count")?
                            .parse()
                            .with_context(|| format!("row {lineno}: bad gpu_count value"))?;
                        if !(1..=16).contains(&gpus) {
                            bail!("row {lineno}: gpu_count value {gpus} out of range (1..=16)");
                        }
                        let key = get_field(&fields, lc, lineno, "link")?;
                        let link = LinkKind::from_key(key).with_context(|| {
                            format!("row {lineno}: unknown link kind `{key}` (expected nvlink/pcie)")
                        })?;
                        Some((gpus, link))
                    }
                    _ => None,
                };
                Some(CellCoord { tenants, quota_pct: quota, topo })
            }
        };
        if schema == BaselineSchema::Sweep {
            // Cells a system cannot host ran no metrics when the surface
            // was produced; record them so the engine reports the skip.
            match get_field(&fields, feasible_col.expect("sweep schema"), lineno, "feasible")?.as_str() {
                "true" => {}
                "false" => {
                    let coord = cell.expect("sweep schema");
                    let key = (system.clone(), coord);
                    if !infeasible.contains(&key) {
                        infeasible.push(key);
                    }
                    continue;
                }
                other => {
                    bail!("row {lineno}: bad feasible value `{other}` (expected true/false)")
                }
            }
        }
        let id = get_field(&fields, id_col, lineno, "id")?.clone();
        if schema == BaselineSchema::Cluster {
            // Cluster summaries live in their own id namespace.
            if taxonomy::cluster_summary_by_id(&id).is_none() {
                bail!("row {lineno}: unknown cluster summary id `{id}` (system `{system}`)");
            }
        } else if schema == BaselineSchema::Dynamics {
            // Dynamics summaries live in their own id namespace.
            if taxonomy::dyn_summary_by_id(&id).is_none() {
                bail!("row {lineno}: unknown dynamics summary id `{id}` (system `{system}`)");
            }
        } else if taxonomy::by_id(&id).is_none() {
            bail!("row {lineno}: unknown metric id `{id}` (system `{system}`)");
        }
        let value: f64 = get_field(&fields, value_col, lineno, "value")?
            .parse()
            .with_context(|| format!("row {lineno}: bad value for {system}/{id}"))?;
        if !value.is_finite() {
            bail!("row {lineno}: non-finite value for {system}/{id} in a feasible row");
        }
        if !seen.insert((system.clone(), cell, dyn_cell, cluster_cell, id.clone())) {
            let label = if let Some(c) = cluster_cell {
                cluster_label(c)
            } else {
                match dyn_cell {
                    Some(d) => dyn_label(d),
                    None => cell_label(cell),
                }
            };
            bail!("row {lineno}: duplicate baseline entry for {system}/{label}/{id}");
        }
        rows.push(BaselineRow { system, cell, dyn_cell, cluster_cell, id, value, line: lineno });
    }
    if rows.is_empty() && infeasible.is_empty() {
        bail!("baseline contains no metrics");
    }
    Ok(Baseline { schema, rows, infeasible, recorded_arrivals })
}

/// Fetch column `c` of a split row, naming the row and column on absence.
fn get_field<'a>(
    fields: &'a [String],
    c: usize,
    lineno: usize,
    what: &str,
) -> Result<&'a String> {
    fields.get(c).with_context(|| format!("row {lineno}: missing {what}"))
}

/// Minimal CSV field splitter honouring double-quoted fields (the point
/// CSV quotes name/unit fields that may contain commas).
pub fn split_csv(line: &str) -> Vec<String> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes && chars.peek() == Some(&'"') => {
                cur.push('"');
                chars.next();
            }
            '"' => in_quotes = !in_quotes,
            ',' if !in_quotes => fields.push(std::mem::take(&mut cur)),
            c => cur.push(c),
        }
    }
    fields.push(cur);
    fields
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A PR-3-era cell coordinate (no topology columns).
    fn cc(tenants: u32, quota_pct: u32) -> CellCoord {
        CellCoord { tenants, quota_pct, topo: None }
    }

    /// An extended cell coordinate.
    fn cct(tenants: u32, quota_pct: u32, gpus: u32, link: LinkKind) -> CellCoord {
        CellCoord { tenants, quota_pct, topo: Some((gpus, link)) }
    }

    #[test]
    fn csv_splitter_handles_quotes() {
        assert_eq!(split_csv("a,\"b,c\",d"), vec!["a", "b,c", "d"]);
        assert_eq!(split_csv("x,\"say \"\"hi\"\"\",y"), vec!["x", "say \"hi\"", "y"]);
    }

    #[test]
    fn parses_point_baseline_with_system_column() {
        let csv = "id,name,category,unit,system,value\n\
                   OH-001,\"Kernel Launch, x\",Overhead,µs,hami,15.3\n\
                   OH-001,\"Kernel Launch, x\",Overhead,µs,fcsp,8.1\n";
        let b = parse_baseline_csv(csv, "native").unwrap();
        assert_eq!(b.schema, BaselineSchema::Point);
        assert_eq!(b.rows.len(), 2);
        assert_eq!(b.rows[0].system, "hami");
        assert_eq!(b.rows[0].value, 15.3);
        assert_eq!(b.rows[0].cell, None);
        assert_eq!(b.rows[0].line, 2);
        assert_eq!(b.rows[1].system, "fcsp");
        assert!(b.infeasible.is_empty());
    }

    #[test]
    fn parses_point_baseline_without_system_column() {
        let csv = "id,value\nOH-001,15.3\n";
        let b = parse_baseline_csv(csv, "fcsp").unwrap();
        assert_eq!(b.rows.len(), 1);
        assert_eq!(b.rows[0].system, "fcsp");
        assert_eq!(b.rows[0].id, "OH-001");
        assert_eq!(b.rows[0].cell_label(), "point");
    }

    #[test]
    fn parses_pr3_era_sweep_baseline_with_cells() {
        let csv = "system,tenants,quota_pct,is_baseline,feasible,id,value,overall_score,delta_vs_baseline_pct,grade\n\
                   hami,1,100,true,true,OH-001,15.3,0.8,0.000,B\n\
                   hami,4,25,false,true,OH-001,19.1,0.7,-12.500,C\n\
                   mig,8,25,false,false,,,NaN,0.000,-\n";
        let b = parse_baseline_csv(csv, "native").unwrap();
        assert_eq!(b.schema, BaselineSchema::Sweep);
        assert_eq!(b.rows.len(), 2);
        assert_eq!(b.rows[0].cell, Some(cc(1, 100)));
        assert_eq!(b.rows[1].cell, Some(cc(4, 25)));
        assert_eq!(b.rows[1].cell_label(), "4t@25%");
        assert_eq!(b.infeasible, vec![("mig".to_string(), cc(8, 25))]);
    }

    #[test]
    fn parses_extended_sweep_baseline_with_topology_cells() {
        let csv = "system,tenants,quota_pct,gpu_count,link,is_baseline,feasible,id,value,overall_score,delta_vs_baseline_pct,grade\n\
                   hami,1,100,4,pcie,true,true,OH-001,15.3,0.8,0.000,B\n\
                   hami,4,25,8,nvlink,false,true,OH-001,19.1,0.7,-12.500,C\n\
                   mig,8,25,8,nvlink,false,false,,,NaN,0.000,-\n";
        let b = parse_baseline_csv(csv, "native").unwrap();
        assert_eq!(b.schema, BaselineSchema::Sweep);
        assert_eq!(b.rows.len(), 2);
        assert_eq!(b.rows[0].cell, Some(cct(1, 100, 4, LinkKind::Pcie)));
        assert_eq!(b.rows[1].cell, Some(cct(4, 25, 8, LinkKind::NvLink)));
        assert_eq!(b.rows[1].cell_label(), "4t@25%/8g/nvlink");
        assert_eq!(b.infeasible, vec![("mig".to_string(), cct(8, 25, 8, LinkKind::NvLink))]);
    }

    #[test]
    fn parses_dynamics_summary_baseline() {
        let csv = "system,scenario,duration_ms,window_ms,id,value\n\
                   hami,churn,1000,100,DYN-P99-STEADY,2.125000\n\
                   hami,churn,1000,100,DYN-RECOVERY,0.000000\n\
                   native,failover,1000,100,DYN-RECOVERY,18.500000\n";
        let b = parse_baseline_csv(csv, "native").unwrap();
        assert_eq!(b.schema, BaselineSchema::Dynamics);
        assert_eq!(b.rows.len(), 3);
        assert!(b.infeasible.is_empty());
        let d = b.rows[0].dyn_cell.unwrap();
        assert_eq!(d.scenario, "churn");
        assert_eq!((d.duration_ms, d.window_ms), (1000, 100));
        assert_eq!(b.rows[0].cell, None);
        assert_eq!(b.rows[0].cell_label(), "churn@1000ms/100ms");
        assert_eq!(b.rows[2].system, "native");
        assert_eq!(b.rows[2].value, 18.5);
    }

    #[test]
    fn rejects_malformed_dynamics_rows_naming_the_row() {
        let hdr = "system,scenario,duration_ms,window_ms,id,value\n";
        // Unknown scenario.
        let e = parse_baseline_csv(&format!("{hdr}hami,meltdown,1000,100,DYN-RECOVERY,1\n"), "hami")
            .unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("row 2") && msg.contains("meltdown"), "{msg}");
        // Table-8 ids are not dynamics summaries.
        let e = parse_baseline_csv(&format!("{hdr}hami,churn,1000,100,OH-001,1\n"), "hami")
            .unwrap_err();
        assert!(format!("{e:#}").contains("unknown dynamics summary id"), "{e:#}");
        // Window must divide into the horizon's range.
        let e = parse_baseline_csv(&format!("{hdr}hami,churn,1000,2000,DYN-RECOVERY,1\n"), "hami")
            .unwrap_err();
        assert!(format!("{e:#}").contains("window_ms"), "{e:#}");
        let e = parse_baseline_csv(&format!("{hdr}hami,churn,0,0,DYN-RECOVERY,1\n"), "hami")
            .unwrap_err();
        assert!(format!("{e:#}").contains("duration_ms"), "{e:#}");
        // Duplicate full coordinate.
        let two = format!(
            "{hdr}hami,churn,1000,100,DYN-RECOVERY,1\nhami,churn,1000,100,DYN-RECOVERY,2\n"
        );
        let e = parse_baseline_csv(&two, "hami").unwrap_err();
        assert!(format!("{e:#}").contains("churn@1000ms/100ms"), "{e:#}");
        // Same id on a *different* geometry is not a duplicate.
        let ok = format!(
            "{hdr}hami,churn,1000,100,DYN-RECOVERY,1\nhami,churn,1000,50,DYN-RECOVERY,2\n"
        );
        assert_eq!(parse_baseline_csv(&ok, "hami").unwrap().rows.len(), 2);
        // Dynamics columns cannot mix with sweep columns, and the schema
        // requires system/duration/window.
        let e = parse_baseline_csv(
            "system,scenario,tenants,quota_pct,feasible,id,value\nhami,churn,2,50,true,DYN-RECOVERY,1\n",
            "hami",
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("mixed-schema"), "{e:#}");
        let e = parse_baseline_csv("system,scenario,id,value\nhami,churn,DYN-RECOVERY,1\n", "hami")
            .unwrap_err();
        assert!(format!("{e:#}").contains("duration_ms"), "{e:#}");
        let e = parse_baseline_csv(
            "scenario,duration_ms,window_ms,id,value\nchurn,1000,100,DYN-RECOVERY,1\n",
            "hami",
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("`system` column"), "{e:#}");
    }

    #[test]
    fn parses_cluster_summary_baseline() {
        let csv = "system,policy,nodes,scenario,id,value\n\
                   hami,first-fit,8,churn,CL-SUCCESS,97.200000\n\
                   hami,first-fit,8,churn,CL-FRAG,4.100000\n\
                   native,frag-gradient,16,failover,CL-EVICT,12.000000\n";
        let b = parse_baseline_csv(csv, "native").unwrap();
        assert_eq!(b.schema, BaselineSchema::Cluster);
        assert_eq!(b.rows.len(), 3);
        assert!(b.infeasible.is_empty());
        let c = b.rows[0].cluster_cell.unwrap();
        assert_eq!(c.policy, "first-fit");
        assert_eq!((c.nodes, c.scenario), (8, "churn"));
        assert_eq!(b.rows[0].cell, None);
        assert_eq!(b.rows[0].dyn_cell, None);
        assert_eq!(b.rows[0].cell_label(), "first-fit@8n/churn");
        assert_eq!(b.rows[2].system, "native");
        assert_eq!(b.rows[2].cell_label(), "frag-gradient@16n/failover");
        assert_eq!(b.rows[2].value, 12.0);
    }

    #[test]
    fn comment_lines_are_skipped_and_arrivals_captured() {
        // The cluster summary CSV prepends its recording arrival count as
        // a provenance comment; the parser must skip it, capture it, and
        // keep physical line numbers in row errors.
        let csv = "# arrivals=5\n\
                   system,policy,nodes,scenario,id,value\n\
                   hami,first-fit,8,churn,CL-SUCCESS,97.200000\n";
        let b = parse_baseline_csv(csv, "native").unwrap();
        assert_eq!(b.schema, BaselineSchema::Cluster);
        assert_eq!(b.recorded_arrivals, Some(5));
        assert_eq!(b.rows.len(), 1);
        assert_eq!(b.rows[0].line, 3);
        // Files without the comment record no arrival count…
        let plain = "system,policy,nodes,scenario,id,value\n\
                     hami,first-fit,8,churn,CL-SUCCESS,97.2\n";
        assert_eq!(parse_baseline_csv(plain, "native").unwrap().recorded_arrivals, None);
        // …and other comments are ignored wherever they appear.
        let noisy = "# produced by gvbench\nid,value\n# mid-file note\nOH-001,15.3\n";
        let b = parse_baseline_csv(noisy, "hami").unwrap();
        assert_eq!(b.recorded_arrivals, None);
        assert_eq!(b.rows[0].line, 4);
        // Row errors still name the physical line.
        let bad = "# arrivals=5\nid,value\nOH-001,15.3\nXX-1,3\n";
        let e = parse_baseline_csv(bad, "hami").unwrap_err();
        assert!(format!("{e:#}").contains("row 4"), "{e:#}");
        // A mangled arrivals comment is rejected, naming its line.
        let e = parse_baseline_csv("# arrivals=lots\nid,value\nOH-001,1\n", "hami").unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("line 1") && msg.contains("arrivals"), "{msg}");
        // A comment-only file still reads as empty.
        assert!(parse_baseline_csv("# arrivals=5\n", "hami").is_err());
    }

    #[test]
    fn rejects_malformed_cluster_rows_naming_the_row() {
        let hdr = "system,policy,nodes,scenario,id,value\n";
        // Unknown policy.
        let e = parse_baseline_csv(&format!("{hdr}hami,random,8,churn,CL-SUCCESS,1\n"), "hami")
            .unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("row 2") && msg.contains("random"), "{msg}");
        // Bad / out-of-range node counts.
        let e = parse_baseline_csv(&format!("{hdr}hami,first-fit,many,churn,CL-SUCCESS,1\n"), "hami")
            .unwrap_err();
        assert!(format!("{e:#}").contains("bad nodes"), "{e:#}");
        let e = parse_baseline_csv(&format!("{hdr}hami,first-fit,0,churn,CL-SUCCESS,1\n"), "hami")
            .unwrap_err();
        assert!(format!("{e:#}").contains("out of range (1..=1024)"), "{e:#}");
        let e = parse_baseline_csv(&format!("{hdr}hami,first-fit,4096,churn,CL-SUCCESS,1\n"), "hami")
            .unwrap_err();
        assert!(format!("{e:#}").contains("out of range (1..=1024)"), "{e:#}");
        // Unknown scenario.
        let e = parse_baseline_csv(&format!("{hdr}hami,first-fit,8,meltdown,CL-SUCCESS,1\n"), "hami")
            .unwrap_err();
        assert!(format!("{e:#}").contains("meltdown"), "{e:#}");
        // Dynamics and Table-8 ids are not cluster summaries.
        let e = parse_baseline_csv(&format!("{hdr}hami,first-fit,8,churn,DYN-RECOVERY,1\n"), "hami")
            .unwrap_err();
        assert!(format!("{e:#}").contains("unknown cluster summary id"), "{e:#}");
        let e = parse_baseline_csv(&format!("{hdr}hami,first-fit,8,churn,OH-001,1\n"), "hami")
            .unwrap_err();
        assert!(format!("{e:#}").contains("unknown cluster summary id"), "{e:#}");
        // Duplicate full coordinate names the cluster cell label.
        let two = format!(
            "{hdr}hami,first-fit,8,churn,CL-FRAG,1\nhami,first-fit,8,churn,CL-FRAG,2\n"
        );
        let e = parse_baseline_csv(&two, "hami").unwrap_err();
        assert!(format!("{e:#}").contains("first-fit@8n/churn"), "{e:#}");
        // Same id at a *different* coordinate is not a duplicate.
        let ok = format!(
            "{hdr}hami,first-fit,8,churn,CL-FRAG,1\nhami,best-fit,8,churn,CL-FRAG,2\n\
             hami,first-fit,16,churn,CL-FRAG,3\nhami,first-fit,8,spike,CL-FRAG,4\n"
        );
        assert_eq!(parse_baseline_csv(&ok, "hami").unwrap().rows.len(), 4);
    }

    #[test]
    fn rejects_mixed_cluster_headers() {
        // Half a cluster coordinate is no schema at all.
        let e = parse_baseline_csv(
            "system,policy,scenario,id,value\nhami,first-fit,churn,CL-SUCCESS,1\n",
            "hami",
        )
        .unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("policy") && msg.contains("nodes"), "{msg}");
        // Cluster columns cannot mix with sweep columns…
        let e = parse_baseline_csv(
            "system,policy,nodes,scenario,tenants,quota_pct,id,value\n\
             hami,first-fit,8,churn,2,50,CL-SUCCESS,1\n",
            "hami",
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("mixed-schema"), "{e:#}");
        // …nor with dynamics columns.
        let e = parse_baseline_csv(
            "system,policy,nodes,scenario,duration_ms,window_ms,id,value\n\
             hami,first-fit,8,churn,1000,100,CL-SUCCESS,1\n",
            "hami",
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("mixed-schema"), "{e:#}");
        // The schema requires scenario and system columns.
        let e = parse_baseline_csv(
            "system,policy,nodes,id,value\nhami,first-fit,8,CL-SUCCESS,1\n",
            "hami",
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("`scenario` column"), "{e:#}");
        let e = parse_baseline_csv(
            "policy,nodes,scenario,id,value\nfirst-fit,8,churn,CL-SUCCESS,1\n",
            "hami",
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("`system` column"), "{e:#}");
    }

    #[test]
    fn rejects_mixed_schema_headers() {
        let e = parse_baseline_csv("system,tenants,id,value\nhami,2,OH-001,1.0\n", "hami")
            .unwrap_err();
        assert!(format!("{e:#}").contains("mixed-schema"), "{e:#}");
        // Sweep header without a feasible column.
        let e = parse_baseline_csv(
            "system,tenants,quota_pct,id,value\nhami,2,50,OH-001,1.0\n",
            "hami",
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("feasible"), "{e:#}");
        // Half a topology coordinate is neither generation.
        let e = parse_baseline_csv(
            "system,tenants,quota_pct,gpu_count,feasible,id,value\nhami,2,50,4,true,OH-001,1.0\n",
            "hami",
        )
        .unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("gpu_count") && msg.contains("link"), "{msg}");
        // Topology columns glued onto the point schema.
        let e = parse_baseline_csv(
            "id,system,gpu_count,link,value\nOH-001,hami,4,pcie,1.0\n",
            "hami",
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("require the sweep schema"), "{e:#}");
    }

    #[test]
    fn rejects_unknown_system_and_metric_naming_the_row() {
        let e = parse_baseline_csv("id,value\nOH-001,3\nXX-1,3\n", "hami").unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("row 3"), "{msg}");
        assert!(msg.contains("XX-1"), "{msg}");
        let e = parse_baseline_csv("id,system,value\nOH-001,mps,1.0\n", "hami").unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("row 2"), "{msg}");
        assert!(msg.contains("mps"), "{msg}");
    }

    #[test]
    fn rejects_malformed_rows_naming_the_row() {
        // Bad value.
        let e = parse_baseline_csv("id,value\nOH-001,lots\n", "hami").unwrap_err();
        assert!(format!("{e:#}").contains("row 2"), "{e:#}");
        // Bad tenants / out-of-range quota on the sweep schema.
        let hdr = "system,tenants,quota_pct,feasible,id,value\n";
        let e = parse_baseline_csv(&format!("{hdr}hami,two,50,true,OH-001,1.0\n"), "hami")
            .unwrap_err();
        assert!(format!("{e:#}").contains("bad tenants"), "{e:#}");
        let e = parse_baseline_csv(&format!("{hdr}hami,2,101,true,OH-001,1.0\n"), "hami")
            .unwrap_err();
        assert!(format!("{e:#}").contains("out of range"), "{e:#}");
        let e = parse_baseline_csv(&format!("{hdr}hami,2,50,maybe,OH-001,1.0\n"), "hami")
            .unwrap_err();
        assert!(format!("{e:#}").contains("bad feasible"), "{e:#}");
        // A point-schema row glued under a sweep header (too few fields).
        let e = parse_baseline_csv(&format!("{hdr}OH-001,1.0\n"), "hami").unwrap_err();
        assert!(format!("{e:#}").contains("row 2"), "{e:#}");
        // Non-finite value in a feasible row.
        let e = parse_baseline_csv(&format!("{hdr}hami,2,50,true,OH-001,NaN\n"), "hami")
            .unwrap_err();
        assert!(format!("{e:#}").contains("non-finite"), "{e:#}");
    }

    #[test]
    fn rejects_malformed_topology_fields_naming_the_row() {
        let hdr = "system,tenants,quota_pct,gpu_count,link,feasible,id,value\n";
        // Bad gpu_count.
        let e = parse_baseline_csv(&format!("{hdr}hami,2,50,lots,pcie,true,OH-001,1.0\n"), "hami")
            .unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("row 2") && msg.contains("bad gpu_count"), "{msg}");
        // Out-of-range gpu_count.
        let e = parse_baseline_csv(&format!("{hdr}hami,2,50,32,pcie,true,OH-001,1.0\n"), "hami")
            .unwrap_err();
        assert!(format!("{e:#}").contains("out of range (1..=16)"), "{e:#}");
        // Unknown link kind.
        let e = parse_baseline_csv(&format!("{hdr}hami,2,50,4,sli,true,OH-001,1.0\n"), "hami")
            .unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("row 2") && msg.contains("sli"), "{msg}");
    }

    #[test]
    fn rejects_duplicates_and_empty() {
        assert!(parse_baseline_csv("id,value\n", "hami").is_err());
        let csv = "id,system,value\nOH-001,hami,1.0\nOH-001,hami,2.0\n";
        assert!(parse_baseline_csv(csv, "hami").is_err());
        // The same (system, metric) in *different* cells is not a duplicate.
        let hdr = "system,tenants,quota_pct,feasible,id,value\n";
        let csv = format!("{hdr}hami,1,100,true,OH-001,1.0\nhami,2,50,true,OH-001,1.2\n");
        assert_eq!(parse_baseline_csv(&csv, "hami").unwrap().rows.len(), 2);
        // ... but the same full coordinate is.
        let csv = format!("{hdr}hami,2,50,true,OH-001,1.0\nhami,2,50,true,OH-001,1.2\n");
        let e = parse_baseline_csv(&csv, "hami").unwrap_err();
        assert!(format!("{e:#}").contains("2t@50%"), "{e:#}");
        // The same scenario on *different topologies* is not a duplicate…
        let hdr = "system,tenants,quota_pct,gpu_count,link,feasible,id,value\n";
        let csv = format!(
            "{hdr}hami,2,50,4,pcie,true,OH-001,1.0\nhami,2,50,4,nvlink,true,OH-001,1.2\n"
        );
        assert_eq!(parse_baseline_csv(&csv, "hami").unwrap().rows.len(), 2);
        // …but the same full topology coordinate is.
        let csv = format!(
            "{hdr}hami,2,50,4,pcie,true,OH-001,1.0\nhami,2,50,4,pcie,true,OH-001,1.2\n"
        );
        let e = parse_baseline_csv(&csv, "hami").unwrap_err();
        assert!(format!("{e:#}").contains("2t@50%/4g/pcie"), "{e:#}");
    }

    #[test]
    fn a_second_header_line_is_a_named_row_error() {
        // Concatenating two exports leaves the second header as a data
        // row; it must be rejected with its line number, not silently
        // parsed or panicked on.
        let csv = "id,value\nOH-001,1.0\nid,value\n";
        let e = parse_baseline_csv(csv, "hami").unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("row 3"), "{msg}");
        assert!(msg.contains("unknown metric id `id`"), "{msg}");
    }
}
