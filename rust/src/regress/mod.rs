//! Sweep-aware regression checking — the paper's §9 "automated regression
//! testing" future-work item, promoted from a CLI helper into a
//! first-class subsystem.
//!
//! The single-point quick gate compares only the 1-tenant/100 %-quota
//! operating point — exactly the regime where MIGPerf (arXiv 2301.00407)
//! and fragmentation-aware scheduling work (arXiv 2511.18906) show that
//! multi-tenant degradation hides. This module therefore keys every
//! baseline entry by its **full cell coordinate** `(system, tenants,
//! quota_pct, metric)`, so one engine gates both:
//!
//! - **point baselines** — the per-metric CSV `gvbench run --all-systems
//!   --format csv` writes (no `tenants`/`quota_pct` columns; rows re-run
//!   at the invocation's [`RunConfig`] operating point), and
//! - **sweep surfaces** — the long-format CSV `gvbench sweep --format
//!   csv` writes (one row per cell × metric; rows re-run through
//!   [`crate::coordinator::sweep::cell_cfg`] so quota→mem/SM mapping and
//!   the `task_seed(scenario_seed(seed, tenants, quota), system, metric)`
//!   derivation are bit-identical to the original sweep).
//!
//! Layout:
//!
//! - [`baseline`] — the [`Baseline`] model and CSV parser (schema
//!   auto-detection, per-row validation that names the offending line,
//!   `feasible: false` cells recorded for skipping rather than re-run).
//! - [`engine`] — [`run_regression`]: reconstructs each baseline row as
//!   an explicit per-task [`RunConfig`], shards the re-run through
//!   [`crate::coordinator::executor::execute_prepared_indexed`]
//!   (`--jobs`), and applies direction-aware per-cell comparison with the
//!   6-decimal recording-resolution guard.
//! - [`report`] — machine-readable surfaces: a JSON regression report
//!   (per-cell deltas, threshold, pass/fail, executor timings) and a
//!   GitHub-flavored markdown summary (worst regressions per system;
//!   written to `$GITHUB_STEP_SUMMARY` by the CI gate jobs).
//!
//! `rust/tests/regress_engine.rs` proves the sweep-baseline round-trip
//! (fresh sweep → CSV → regress passes against itself at `--jobs 1` and
//! `--jobs 8`), infeasible-cell skipping, per-cell injected-regression
//! detection, and malformed/mixed-schema rejection.

pub mod baseline;
pub mod engine;
pub mod report;

pub use baseline::{parse_baseline_csv, Baseline, BaselineRow, BaselineSchema};
pub use engine::{run_regression, worse_percent, CellDelta, RegressOutcome};
pub use report::{render_json, render_markdown};
