//! Sweep-aware regression checking — the paper's §9 "automated regression
//! testing" future-work item, promoted from a CLI helper into a
//! first-class subsystem.
//!
//! The single-point quick gate compares only the 1-tenant/100 %-quota
//! operating point — exactly the regime where MIGPerf (arXiv 2301.00407)
//! and fragmentation-aware scheduling work (arXiv 2511.18906) show that
//! multi-tenant degradation hides. This module therefore keys every
//! baseline entry by its **full cell coordinate** `(system, tenants,
//! quota_pct, gpu_count, link, metric)`, so one engine gates all of:
//!
//! - **point baselines** — the per-metric CSV `gvbench run --all-systems
//!   --format csv` writes (no `tenants`/`quota_pct` columns; rows re-run
//!   at the invocation's [`crate::metrics::RunConfig`] operating point),
//! - **extended sweep surfaces** — the long-format CSV `gvbench sweep
//!   --format csv` writes (one row per cell × metric carrying the full
//!   topology coordinate; rows re-run through
//!   [`crate::coordinator::sweep::cell_cfg`] so quota→mem/SM mapping,
//!   the node topology and the `task_seed(topology_seed(scenario_seed(
//!   seed, tenants, quota), gpus, link), system, metric)` derivation are
//!   bit-identical to the original sweep), and
//! - **PR-3-era sweep surfaces** — 4-tuple baselines without
//!   `gpu_count`/`link` columns, auto-detected and re-run through
//!   [`crate::coordinator::sweep::legacy_cell_cfg`]: the default 4-GPU
//!   PCIe node *and* the scenario-layer seed derivation their producing
//!   sweep hardcoded, so genuinely old surfaces stay bit-identical, and
//! - **dynamics summaries** — the per-scenario surface `gvbench
//!   dynamics --summary-out` writes (rows keyed by `(system, scenario,
//!   duration_ms, window_ms, id)` with
//!   [`crate::metrics::taxonomy::DYN_SUMMARY`] ids); each distinct
//!   timeline replays once through [`crate::dynsim`] with the producing
//!   run's exact `task_seed(dynamics_seed(..), system, scenario)`
//!   derivation, then every summary row compares direction-aware —
//!   `trace`-scenario rows replay the external trace file re-supplied
//!   via `gvbench regress --trace FILE`
//!   ([`engine::run_regression_with_trace`]), and
//! - **cluster summaries** — the fleet-placement surface `gvbench
//!   cluster --summary-out` writes (rows keyed by `(system, policy,
//!   nodes, scenario, id)` with
//!   [`crate::metrics::taxonomy::CLUSTER_SUMMARY`] ids); each distinct
//!   fleet cell replays once through [`crate::cluster`] at
//!   [`crate::cluster::DEFAULT_ARRIVALS`] with the producing run's exact
//!   `task_seed(cluster_seed(..), system, scenario)` derivation, then
//!   every summary row compares direction-aware.
//!
//! Layout:
//!
//! - [`baseline`] — the [`Baseline`] model and CSV parser (schema
//!   auto-detection, per-row validation that names the offending line,
//!   `feasible: false` cells recorded for skipping rather than re-run).
//! - [`engine`] — [`run_regression`]: reconstructs each baseline row as
//!   an explicit per-task [`crate::metrics::RunConfig`], shards the re-run
//!   through [`crate::coordinator::executor::execute_prepared_indexed`]
//!   (`--jobs`), and applies direction-aware per-cell comparison with the
//!   6-decimal recording-resolution guard.
//! - [`report`] — machine-readable surfaces: a JSON regression report
//!   (per-cell deltas, threshold, pass/fail, executor timings, a
//!   per-link-kind breakdown) and a GitHub-flavored markdown summary
//!   (worst regressions per system, regressions grouped by link kind;
//!   written to `$GITHUB_STEP_SUMMARY` by the CI gate jobs).
//!
//! `rust/tests/regress_engine.rs` proves the sweep-baseline round-trip
//! (fresh sweep → CSV → regress passes against itself at `--jobs 1` and
//! `--jobs 8`, topology axes included), PR-3-era baseline acceptance,
//! infeasible-cell skipping, per-cell injected-regression detection with
//! the full coordinate named, and malformed/mixed-schema rejection. See
//! `docs/regression-gating.md` for the operator-facing guide.

pub mod baseline;
pub mod engine;
pub mod report;

pub use baseline::{
    parse_baseline_csv, Baseline, BaselineRow, BaselineSchema, CellCoord, ClusterCoord, DynCoord,
};
pub use engine::{
    run_regression, run_regression_on, run_regression_with_trace, worse_percent, CellDelta,
    RegressOutcome,
};
pub use report::{render_json, render_markdown};
